"""Master experiment driver reproducing the paper's tables on the synthetic
GSCD stand-in (DESIGN.md §4 — numbers differ from the paper's private data;
the ablation STRUCTURE and trends are the reproduction target).

Produces results/kws_results.json consumed by benchmarks/run.py:
  table2 — model accuracy / params / model bits        (paper Table II)
  table3 — hardware-constraint ablation                (paper Table III)
  table4 — customization ablation                      (paper Table IV)
  fig3   — trained offsets per layer
  fig7   — BN bias distribution + in-range fraction

Run:  PYTHONPATH=src python -m benchmarks.kws_experiments [--fast]
"""

from __future__ import annotations

import argparse
import json
import os
import pickle
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import imc
from repro.core.onchip_training import (OnChipTrainConfig, head_accuracy,
                                        quantized_head_finetune)
from repro.data import audio
from repro.models import kws as m
from repro.training import kws as tr

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")
MODEL_PKL = os.path.join(RESULTS, "kws_model.pkl")
OUT_JSON = os.path.join(RESULTS, "kws_results.json")

L = 2000                        # reduced window (1-core CPU budget); the
                                # full 16000-sample config is exercised by
                                # the smoke tests + energy model + dry-run
CFG = m.KWSConfig(sample_len=L)
SA_STD = 1.0
MAV_STD = 8.0


def get_data():
    trn, tst = audio.make_gscd_like(train_per_class=40, test_per_class=12,
                                    length=L)
    per_trn, per_tst = audio.make_personal(train_per_class=3,
                                           test_per_class=6,
                                           length=L, accent_shift=0.18)
    return trn, tst, per_trn, per_tst


def train_or_load(xtr, ytr, fast: bool):
    if os.path.exists(MODEL_PKL):
        with open(MODEL_PKL, "rb") as f:
            params, state = pickle.load(f)
        return (jax.tree_util.tree_map(jnp.asarray, params),
                m.KWSState(*[jax.tree_util.tree_map(jnp.asarray, s)
                             for s in state]))
    tcfg = tr.TrainConfig(
        epochs=24 if fast else 60, batch_size=100, lr=3e-3, log_every=48,
        alpha_schedule=((0.3, 2.0), (0.5, 5.0), (0.65, 12.0), (1.0, -8.0)),
        polarize_weight=5e-3)
    params, state = tr.train_base(xtr, ytr, CFG, tcfg)
    os.makedirs(RESULTS, exist_ok=True)
    with open(MODEL_PKL, "wb") as f:
        pickle.dump((jax.tree_util.tree_map(np.asarray, params),
                     tuple(jax.tree_util.tree_map(np.asarray, s)
                           for s in state)), f)
    return params, state


def chip_instances(n_seeds: int):
    chans = {f"conv{i}": CFG.channels[i]
             for i in range(1, CFG.num_conv_layers)}
    noise = imc.IMCNoiseParams(mav_offset_std=MAV_STD, sa_noise_std=SA_STD)
    return [imc.sample_chip_offsets(jax.random.PRNGKey(100 + s), chans,
                                    noise) for s in range(n_seeds)]


def run(fast: bool = False):
    t0 = time.time()
    (xtr, ytr), (xte, yte), (xp_tr, yp_tr), (xp_te, yp_te) = get_data()
    params, state = train_or_load(xtr, ytr, fast)
    results = {}

    # ---- Table II: the ideal model ----
    pc = CFG.param_count()
    hw_ideal = m.fold_params(params, state, CFG, bn_constraints=False,
                             fc_quant=False)
    acc_ideal = tr.evaluate_hw(hw_ideal, xte, yte, CFG)
    results["table2"] = {
        "accuracy": acc_ideal, "parameters": pc["total"],
        "model_bits": pc["model_bits"],
        "paper": {"accuracy": 0.9083, "parameters": 125_000,
                  "model_bits": 171_000},
    }
    print(f"[t2] ideal acc {acc_ideal:.3f} params {pc['total']} "
          f"bits {pc['model_bits']} ({time.time()-t0:.0f}s)", flush=True)

    # ---- Table III: hardware-constraint ablation ----
    hw_fcq = m.fold_params(params, state, CFG, bn_constraints=False,
                           fc_quant=True)
    acc_fcq = tr.evaluate_hw(hw_fcq, xte, yte, CFG)
    # the constrained fold is reused across every noisy evaluation below:
    # fold (and pack the fused-kernel operands) exactly once
    hw = m.fold_params(params, state, CFG, pack=True)   # + BN constraints
    acc_bn = tr.evaluate_hw(hw, xte, yte, CFG)

    n_seeds = 2 if fast else 5
    chips = chip_instances(n_seeds)
    acc_noise, acc_comp = [], []
    hw_comp_first = None
    for s, offs in enumerate(chips):
        acc_noise.append(tr.evaluate_hw(hw, xte, yte, CFG,
                                        chip_offsets=offs,
                                        sa_noise_std=SA_STD, seed=s))
        hw_c = tr.calibrate_and_compensate(hw, xtr[:150], offs, CFG)
        if hw_comp_first is None:
            hw_comp_first = hw_c
        acc_comp.append(tr.evaluate_hw(hw_c, xte, yte, CFG,
                                       chip_offsets=offs,
                                       sa_noise_std=SA_STD, seed=s))
    print(f"[t3] noise {np.mean(acc_noise):.3f} comp {np.mean(acc_comp):.3f}"
          f" ({time.time()-t0:.0f}s)", flush=True)

    # noise-aware fine-tuning on chip 0 (paper: a few epochs)
    ft_cfg = tr.TrainConfig(epochs=6, batch_size=100, lr=1e-3, log_every=999,
                            alpha_schedule=((1.0, -8.0),),
                            polarize_weight=0.0)
    p_ft, st_ft = tr.train_base(xtr, ytr, CFG, ft_cfg, params=params,
                                state=state, chip_offsets=chips[0],
                                sa_noise_std=SA_STD, verbose=False)
    hw_ft = m.fold_params(p_ft, st_ft, CFG)
    hw_ft = tr.calibrate_and_compensate(hw_ft, xtr[:150], chips[0], CFG)
    acc_ft = tr.evaluate_hw(hw_ft, xte, yte, CFG, chip_offsets=chips[0],
                            sa_noise_std=SA_STD, seed=0)
    results["table3"] = {
        "ideal": acc_ideal, "fc_quantized": acc_fcq,
        "bn_constraints": acc_bn,
        "mav_sa_noise": float(np.mean(acc_noise)),
        "mav_sa_noise_per_seed": list(map(float, acc_noise)),
        "bias_compensation": float(np.mean(acc_comp)),
        "compensation_finetune": float(acc_ft),
        "paper": {"ideal": 0.9083, "fc_quantized": 0.9039,
                  "bn_constraints": 0.8904, "mav_sa_noise": 0.5108,
                  "bias_compensation": 0.8884,
                  "compensation_finetune": 0.8976},
    }
    print(f"[t3] ft {acc_ft:.3f} ({time.time()-t0:.0f}s)", flush=True)

    # ---- Table IV: customization on the personal set ----
    # features through the compensated chip-0 hardware (the SRAM buffer)
    f_tr = tr.hw_features(hw_comp_first, xp_tr, CFG, chip_offsets=chips[0],
                          sa_noise_std=SA_STD)
    f_te = tr.hw_features(hw_comp_first, xp_te, CFG, chip_offsets=chips[0],
                          sa_noise_std=SA_STD)
    base_personal = tr.evaluate_hw(hw_comp_first, xp_te, yp_te, CFG,
                                   chip_offsets=chips[0],
                                   sa_noise_std=SA_STD)
    w0 = np.asarray(hw_comp_first.hw.fc_w)
    b0 = np.asarray(hw_comp_first.hw.fc_b)

    epochs = 400 if fast else 1000
    variants = {
        "baseline_fp": dict(quantized=False),
        "quantized_naive": dict(quantized=True, error_scaling=False,
                                sga=False, rgp=False),
        "error_scaling": dict(quantized=True, error_scaling=True, sga=False,
                              rgp=False),
        "es_sga": dict(quantized=True, error_scaling=True, sga=True,
                       rgp=False),
        "es_sga_rgp": dict(quantized=True, error_scaling=True, sga=True,
                           rgp=True, rgp_lambda=8.0),
    }
    t4 = {"before_customization": float(base_personal)}
    for name, kw in variants.items():
        ocfg = OnChipTrainConfig(epochs=epochs, **kw)
        w, b = quantized_head_finetune(jnp.asarray(f_tr), jnp.asarray(yp_tr),
                                       jnp.asarray(w0), jnp.asarray(b0),
                                       ocfg)
        t4[name] = float(head_accuracy(jnp.asarray(f_te),
                                       jnp.asarray(yp_te), w, b, ocfg))
        print(f"[t4] {name}: {t4[name]:.3f} ({time.time()-t0:.0f}s)",
              flush=True)
    t4["paper"] = {"baseline_fp": 0.9671, "quantized_naive": 0.7137,
                   "error_scaling": 0.8646, "es_sga": 0.9652,
                   "es_sga_rgp": 0.9691}
    results["table4"] = t4

    # ---- Fig 3: trained offsets (merged threshold beta+offset per layer) --
    results["fig3"] = {
        f"L{i+1}": float(jnp.mean(params[f"conv{i}"]["offset"]
                                  + params[f"conv{i}"]["beta"]))
        for i in range(CFG.num_conv_layers)}

    # ---- Fig 7: BN bias distribution ----
    hw_unconstrained = m.fold_params(params, state, CFG,
                                     bn_constraints=False)  # fold once,
    all_bias = np.concatenate([np.asarray(hw_unconstrained.bias[n])
                               for n in CFG.imc_layer_names()])
    results["fig7"] = {
        "bias_mean": float(all_bias.mean()), "bias_std": float(all_bias.std()),
        "fraction_in_range": float(np.mean(np.abs(all_bias) <= 64)),
        "histogram": np.histogram(all_bias, bins=16,
                                  range=(-80, 80))[0].tolist(),
    }

    os.makedirs(RESULTS, exist_ok=True)
    with open(OUT_JSON, "w") as f:
        json.dump(results, f, indent=1)
    print(f"[kws_experiments] wrote {OUT_JSON} ({time.time()-t0:.0f}s)")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    run(fast=args.fast)
