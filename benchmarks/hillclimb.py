"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> validate.

Three cells (selection rationale in EXPERIMENTS.md §Perf):
  1. xlstm-125m  x train_4k   — worst roofline fraction (0.024)
  2. qwen3-moe   x train_4k   — most collective-bound (coll 9.4x compute)
  3. mistral-123b x decode_32k — most representative of the paper's
     weight-stationary (in-SRAM) principle, applied to serving sharding.

Each iteration re-runs the dry-run cell with a policy variant and records
the three roofline terms before/after into results/hillclimb.json.

Run:  PYTHONPATH=src python -m benchmarks.hillclimb [--cell N]
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def measure(arch, shape, policy_opts=None, label="baseline",
            cfg_overrides=None):
    from repro.launch.dryrun import run_cell
    rec = run_cell(arch, shape, False, policy_opts=policy_opts,
                   opt_overrides=cfg_overrides)
    ro = rec["roofline"]
    pk = (rec.get("memory_analysis") or {}).get("peak_bytes") or 0
    out = {
        "label": label, "arch": arch, "shape": shape,
        "policy_opts": policy_opts or {},
        "compute_s": ro["compute_s"], "memory_s": ro["memory_s"],
        "collective_s": ro["collective_s"], "dominant": ro["dominant"],
        "frac": ro["roofline_fraction"],
        "frac_serial": ro["roofline_fraction_serial"],
        "peak_gb": pk / 1e9,
        "collectives_gb": {k: v / 1e9 for k, v in rec["collectives"].items()
                           if v},
    }
    print(f"[hillclimb] {arch} x {shape} [{label}]: dom={out['dominant']} "
          f"comp={out['compute_s']:.4f} coll={out['collective_s']:.4f} "
          f"mem={out['memory_s']:.4f} frac_serial={out['frac_serial']:.3f} "
          f"peak={out['peak_gb']:.2f}GB", flush=True)
    return out


def cell1_xlstm():
    """Hypothesis chain for xlstm-125m train_4k (see EXPERIMENTS.md)."""
    runs = []
    runs.append(measure("xlstm-125m", "train_4k", None, "baseline"))
    # H1: a 125M model does not need FSDP on 256 chips — the per-step
    # parameter all-gather (2 x 0.25GB x ...) plus gradient all-reduce in
    # fp32 dominates.  Expect the all-gather volume to collapse.
    runs.append(measure("xlstm-125m", "train_4k", {"no_fsdp": True},
                        "no_fsdp"))
    # H1 REFUTED: collectives unchanged (1.009 -> 1.025): the cost is not
    # FSDP but TP activation reshards — 4 heads / d=768 cannot shard over a
    # 16-way model axis, so every mLSTM block round-trips (B,T,d_inner)
    # through all-gathers.
    # H2: on the FIXED 16x16 mesh, fold the model axis into data
    # parallelism (batch 256 over 256 chips, params replicated, grad
    # all-reduce only: ~0.5GB fp32 grads).  Predict collective_s
    # 1.01 -> ~0.05, dominant term -> compute.
    runs.append(measure("xlstm-125m", "train_4k", {"pure_dp": True},
                        "pure_dp"))
    return runs


def cell2_moe():
    """qwen3-moe-30b-a3b train_4k."""
    runs = []
    runs.append(measure("qwen3-moe-30b-a3b", "train_4k", None, "baseline"))
    # H1: the dominant 122GB/device all-gather is FSDP re-materializing all
    # 128 experts' weights every step.  Making experts STATIONARY on the
    # data axis (EP over data, expert-FFN TP over model) removes per-step
    # weight movement entirely; the dispatch all-to-all (~16GB/device)
    # remains.  Predict collective_s: 4.49 -> ~0.5-1.0.
    runs.append(measure("qwen3-moe-30b-a3b", "train_4k",
                        {"ep_axis": "data"}, "ep_over_data"))
    # H1 CONFIRMED: all-gather 18.1 -> 1.8GB (expert weights stationary);
    # the dispatch all-to-all (collective-permute) remains, as predicted.
    # H2: remat (nothing_saveable) re-runs the dispatch all-to-alls during
    # the backward recompute; peak memory is only 3.8/16GB, so trade memory
    # for a third of the permute volume: remat=False.
    runs.append(measure("qwen3-moe-30b-a3b", "train_4k",
                        {"ep_axis": "data"}, "ep_data_noremat",
                        cfg_overrides={"remat": False}))
    # H3 (stop): remaining terms are the row-parallel activation
    # all-reduces of the dense attention sub-blocks (~26GB bf16, the
    # classic Megatron TP cost) — a Korthikanti-style sequence-parallel
    # norm/residual would overlap but not shrink the bytes; expected gain
    # <5%, stop per the rule.
    return runs


def cell3_decode():
    """mistral-large-123b decode_32k."""
    runs = []
    runs.append(measure("mistral-large-123b", "decode_32k", None,
                        "baseline"))
    # H1: decode all-gathers 2.5GB of weights per token (FSDP).  Serve-mode
    # sharding keeps weights stationary (2D-sharded) and replicates the
    # small decode activations; per-matmul collectives become
    # activation-sized psums.  Predict collective_s: 0.05 -> ~0.002 and the
    # bound moving to the memory term (weights read once per token).
    runs.append(measure("mistral-large-123b", "decode_32k",
                        {"serve_mode": True}, "serve_masked_write"))
    # Iterations (full log in git/EXPERIMENTS):
    #  - serve(hd-sharded cache): 2.50 -> 2.16GB (-13.6%): XLA gathers the
    #    hd-sharded cache per layer instead of partial-summing scores.
    #  - seq-over-(data x model) cache: REFUTED — 34GB full-cache gather
    #    (DUS + layout conflict).
    #  - masked elementwise cache write (this run): removes the DUS but the
    #    SPMD partitioner still falls back on the scan-stacked cache
    #    reshard (XLA b/433785288, printed in its own warning).
    #  - unrolled layers: REFUTED — 219GB (per-layer gathers, nothing
    #    amortized).
    # Net: bf16 serving params cut peak 13.9 -> 12.9GB; the residual
    # 2.5GB/token is an identified XLA SPMD artifact — the production fix
    # is per-layer donated cache buffers outside scan (or Shardy).
    runs.append(measure("mistral-large-123b", "decode_32k",
                        {"serve_mode": True}, "serve_unrolled",
                        cfg_overrides={"scan_layers": False}))
    return runs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", type=int, default=0, help="0=all")
    args = ap.parse_args()
    cells = {1: cell1_xlstm, 2: cell2_moe, 3: cell3_decode}
    todo = [args.cell] if args.cell else [1, 2, 3]
    path = os.path.join(RESULTS, "hillclimb.json")
    all_runs = []
    if os.path.exists(path):
        all_runs = json.load(open(path))
    for c in todo:
        all_runs.extend(cells[c]())
        with open(path, "w") as f:
            json.dump(all_runs, f, indent=1)
    print(f"[hillclimb] wrote {path}")


if __name__ == "__main__":
    main()
