"""Benchmark harness: one function per paper table/figure + kernel
microbenchmarks + the dry-run roofline summary.

Prints ``name,us_per_call,derived`` CSV rows.  Expensive artifacts
(results/kws_results.json from benchmarks.kws_experiments,
results/dryrun_baseline.json from repro.launch.dryrun) are loaded if present;
the table functions degrade to "run benchmarks.kws_experiments first"
markers instead of silently re-running multi-minute jobs.

Run:  PYTHONPATH=src python -m benchmarks.run
      PYTHONPATH=src python -m benchmarks.run --imc-fused
          (fused-vs-group-loop IMC layer benchmark, batch sweep {1,4,16};
           writes the per-layer and end-to-end hw_forward decisions/sec
           record to results/BENCH_imc_fused.json)
      PYTHONPATH=src python -m benchmarks.run --streaming
          (always-on serving: frame-incremental streaming vs full-window
           recompute, >=4 batched streams, plus the voice-activity-gated
           path on a --duty speech/silence mixture; writes decisions/sec,
           MACs and the duty-cycled uJ/decision to
           results/BENCH_streaming.json)
      PYTHONPATH=src python -m benchmarks.run --streaming --devices 2
          (adds the device-sharded serving section: the same total
           stream load on one device vs a ShardedStreamServer of N
           per-device slot pools, decisions/sec scaling from the max
           per-device compute wall into the 'sharded' section of
           BENCH_streaming.json; on CPU hosts the device count comes
           from --xla_force_host_platform_device_count, set before jax
           initializes; schema in docs/SHARDING.md)
      PYTHONPATH=src python -m benchmarks.run --streaming --compiled
          (adds the whole-tick compiled fast-path section: the same
           steady-state load served by the interpreted Python tick vs
           step_block's fused lax.scan dispatch — events asserted
           bit-identical, launch auditor in raise mode — decisions/sec
           speedup into the 'compiled' section of BENCH_streaming.json;
           schema in docs/SERVING.md)
      PYTHONPATH=src python -m benchmarks.run --customize --sessions 4
          (on-device customization as a serving workload: enrollment
           sessions driven through scheduler ticks — bias compensation +
           SGA fine-tuning as background jobs; writes the
           utterances-to-recovered-accuracy trajectory, the N-concurrent-
           session record with per-tick batched-launch accounting, the
           error-scaling ablation (fixed 1.375 vs dynamic ceil/floor) and
           the analytical uJ per fine-tune step to
           results/BENCH_customize.json; schemas in docs/ENERGY.md)
      PYTHONPATH=src python -m benchmarks.run --faults
          (fault-injected self-healing serving: drift / bit-flip / stuck
           scenarios through the canary health monitor, held-out accuracy
           before the fault, under the fault, and after the on-chip
           recompensation heal — drift and bit-flip heals must land
           within 2 points of the clean chip — plus detection/recovery
           latencies, recovery energy, and a crash-safety
           snapshot->restore record; writes results/BENCH_faults.json;
           schema in docs/RELIABILITY.md)
      PYTHONPATH=src python -m benchmarks.run --obs-overhead
          (observability tax: the gated streaming workload run
           telemetry-off vs fully instrumented — metrics registry +
           flight recorder + launch auditor in raise mode + trace
           spans — asserting the decision streams are bit-identical,
           recording the per-tick overhead percentage, the auditor's
           launch accounting and a Perfetto trace artifact; writes
           results/BENCH_obs.json; schema in docs/OBSERVABILITY.md)

Any single-bench flag also takes ``--trace-out PATH`` to emit a
Chrome/Perfetto trace-event timeline of the run (docs/OBSERVABILITY.md).

Every ``BENCH_*.json`` goes through one shared atomic writer
(:func:`_write_bench`): tmp + fsync + rename like
``repro.checkpoint.profiles.ProfileStore``, stamped with a ``bench``
header ``{name, schema_version, regen}`` so partially-written artifacts
can't be published and every record names the command that regenerates
it.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def _load(name):
    path = os.path.join(RESULTS, name)
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return None


def _row(name, us, derived):
    print(f"{name},{us},{derived}")


# schema_version per artifact: bump when a bench's JSON layout changes
# incompatibly (keys removed/renamed), not when keys are added
_BENCH_SCHEMAS = {
    "BENCH_imc_fused.json": 1,
    "BENCH_streaming.json": 1,
    "BENCH_customize.json": 1,
    "BENCH_faults.json": 1,
    "BENCH_obs.json": 1,
}


def _write_bench(report, out_path, default_name, regen):
    """The single write path for every ``BENCH_*.json``.

    Atomic (tmp + fsync + rename, the ``ProfileStore`` idiom) so a
    crash mid-dump can't publish a truncated artifact, and stamped with
    a deterministic ``bench`` header — artifact name, schema version,
    and the exact command that regenerates it.  No timestamps: reruns
    on identical results diff clean.  Returns the path written."""
    if out_path is None:
        out_path = os.path.normpath(os.path.join(RESULTS, default_name))
    if os.path.dirname(out_path):
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
    stamped = {"bench": {
        "name": os.path.splitext(default_name)[0],
        "schema_version": _BENCH_SCHEMAS[default_name],
        "regen": regen,
    }}
    stamped.update(report)
    tmp = f"{out_path}.tmp"
    with open(tmp, "w") as f:
        json.dump(stamped, f, indent=2)
        f.write("\n")
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, out_path)
    return out_path


# --trace-out: one shared TraceBuilder for the whole bench run.  Server
# benches attach it to their StreamServers (per-tick serving spans);
# kernel benches record their timed sections as top-level spans.
_TRACE = None


def _attach_trace(srv):
    """Point a StreamServer's span sink at the shared --trace-out
    builder (``srv.trace`` is the scheduler's only trace handle)."""
    if _TRACE is not None:
        srv.trace = _TRACE
    return srv


def _trace_span(name, t0, t1, **args):
    if _TRACE is not None:
        _TRACE.span(name, t0, t1, **args)


# ---------------------------------------------------------------------------
# Paper tables
# ---------------------------------------------------------------------------


def table2_model() -> None:
    """Paper Table II: ideal-model accuracy / parameters / model size."""
    r = _load("kws_results.json")
    if not r:
        _row("table2_model", "", "MISSING:run benchmarks.kws_experiments")
        return
    t = r["table2"]
    _row("table2_accuracy", "", f"{t['accuracy']:.4f}(paper:0.9083)")
    _row("table2_parameters", "", f"{t['parameters']}(paper:125K)")
    _row("table2_model_bits", "", f"{t['model_bits']}(paper:171K)")


def table3_hw_constraints() -> None:
    """Paper Table III: ideal -> FC-quant -> BN-constraints -> +noise ->
    +compensation -> +fine-tune."""
    r = _load("kws_results.json")
    if not r:
        _row("table3_hw_constraints", "",
             "MISSING:run benchmarks.kws_experiments")
        return
    t = r["table3"]
    for key in ("ideal", "fc_quantized", "bn_constraints", "mav_sa_noise",
                "bias_compensation", "compensation_finetune"):
        _row(f"table3_{key}", "",
             f"{t[key]:.4f}(paper:{t['paper'][key]:.4f})")


def table4_customization() -> None:
    """Paper Table IV: customization ablation on the personal set."""
    r = _load("kws_results.json")
    if not r:
        _row("table4_customization", "",
             "MISSING:run benchmarks.kws_experiments")
        return
    t = r["table4"]
    _row("table4_before_customization", "",
         f"{t['before_customization']:.4f}")
    for key in ("baseline_fp", "quantized_naive", "error_scaling", "es_sga",
                "es_sga_rgp"):
        _row(f"table4_{key}", "",
             f"{t[key]:.4f}(paper:{t['paper'][key]:.4f})")


def table5_energy() -> None:
    """Paper Fig 14/Table V: energy/latency/TOPS-W analytical chip model."""
    from repro.core.energy import kws_chip_report, training_energy_j
    from repro.models.kws import PAPER_KWS, layer_stats

    stats = layer_stats(PAPER_KWS)
    for freq, tag in ((1e6, "1MHz"), (1e8, "100MHz")):
        rep = kws_chip_report(stats, freq_hz=freq)
        _row(f"table5_energy_per_decision_{tag}", "",
             f"{rep.energy_j_per_decision * 1e6:.2f}uJ"
             + ("(paper:~14.3uJ)" if tag == "1MHz" else "(paper:~4.5uJ)"))
        _row(f"table5_power_{tag}", "",
             f"{rep.power_w * 1e6:.1f}uW"
             + ("(paper:89.5uW)" if tag == "1MHz" else "(paper:2833uW)"))
        _row(f"table5_tops_per_w_{tag}", "",
             f"{rep.tops_per_w:.1f}(paper:23.6-68)")
    _row("table5_latency", "", f"{kws_chip_report(stats).latency_s*1e3:.0f}ms"
         "(paper:160ms@1MHz)")
    e_train = training_energy_j(num_epochs=1, macs_per_epoch=90 * 586 * 10,
                                lut_ops=90 * 10, div_ops=90 * 10,
                                sram_bits=90 * 576 * 8)
    _row("table5_training_energy_per_epoch", "", f"{e_train*1e6:.1f}uJ")


def dryrun_summary() -> None:
    """Deliverable e/g: the 40-cell x 2-mesh dry-run + roofline terms."""
    rs = _load("dryrun_baseline.json")
    if not rs:
        _row("dryrun", "", "MISSING:run repro.launch.dryrun")
        return
    ok = sum(1 for r in rs if r.get("status") == "ok")
    skip = sum(1 for r in rs if r.get("status") == "skip")
    err = sum(1 for r in rs if r.get("status") == "error")
    _row("dryrun_cells", "", f"ok={ok};skip={skip};error={err}")
    for r in rs:
        if r.get("status") != "ok" or r.get("multi_pod"):
            continue
        ro = r["roofline"]
        _row(f"roofline_{r['arch']}_{r['shape']}", "",
             f"dom={ro['dominant']};comp={ro['compute_s']:.4f}s;"
             f"mem={ro['memory_s']:.4f}s;coll={ro['collective_s']:.4f}s;"
             f"frac={ro['roofline_fraction']:.3f};"
             f"frac_serial={ro.get('roofline_fraction_serial', 0):.3f}")


# ---------------------------------------------------------------------------
# Kernel microbenchmarks (CPU interpret mode: correctness-grade timings)
# ---------------------------------------------------------------------------


def _time_us(fn, *args, iters: int = 5) -> float:
    import jax
    fn(*args)                      # compile + warmup
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def kernel_bench() -> None:
    """us/call for each Pallas kernel vs its jnp oracle (interpret mode on
    CPU measures dispatch+semantics, not TPU perf — the BlockSpecs encode
    the TPU tiling; see DESIGN.md §3)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.imc_mav import ops as mav_ops
    from repro.kernels.imc_mav.ref import imc_mav_ref
    from repro.kernels.int8_matmul.int8_matmul import int8_matmul
    from repro.kernels.int8_matmul.ref import int8_matmul_ref
    from repro.kernels.sga_update.sga_update import sga_update
    from repro.kernels.sga_update.ref import sga_update_ref

    # independent keys per operand: reusing one key correlates x with w
    # (and xq with wq), which skews the agree/disagree statistics the ±1
    # and int8 kernels are exercised on
    kx, kw, kxq, kwq, kwv, kgv = jax.random.split(jax.random.PRNGKey(0), 6)
    x = jnp.where(jax.random.bernoulli(kx, 0.5, (512, 128)), 1.0, -1.0)
    w = jnp.where(jax.random.bernoulli(kw, 0.5, (128, 128)), 1.0, -1.0)
    bias = jnp.zeros((128,))
    flip = jnp.ones((128,))
    us = _time_us(lambda: mav_ops.mav_matmul(x, w, bias, flip))
    us_ref = _time_us(jax.jit(lambda: imc_mav_ref(x, w, bias, flip)))
    _row("kernel_imc_mav_512x128x128", f"{us:.0f}", f"ref_us={us_ref:.0f}")

    xq = jax.random.randint(kxq, (512, 128), -127, 128, jnp.int8)
    wq = jax.random.randint(kwq, (128, 128), -127, 128, jnp.int8)
    bq = jnp.zeros((128,), jnp.int32)
    us = _time_us(lambda: int8_matmul(xq, wq, bq, shift=7))
    us_ref = _time_us(jax.jit(lambda: int8_matmul_ref(xq, wq, bq, shift=7)))
    _row("kernel_int8_matmul_512x128x128", f"{us:.0f}",
         f"ref_us={us_ref:.0f}")

    n = 8192
    wv = jax.random.uniform(kwv, (n,), minval=-1, maxval=1)
    gv = jax.random.normal(kgv, (n,)) * 0.01
    av = jnp.zeros((n,))
    us = _time_us(lambda: sga_update(wv, gv, av, lr=1 / 16, g_th=0.078125))
    us_ref = _time_us(jax.jit(
        lambda: sga_update_ref(wv, gv, av, 1 / 16, 0.078125)))
    _row("kernel_sga_update_8192", f"{us:.0f}", f"ref_us={us_ref:.0f}")


# ---------------------------------------------------------------------------
# Fused IMC layer: per-layer + end-to-end hw_forward decisions/sec
# ---------------------------------------------------------------------------


def _grouploop_hw_forward(hw, x, cfg):
    """End-to-end seed baseline: one tiny pallas_call per conv group
    (conv_mav loop) with the digital shuffle/pool in jnp — the path the
    fused kernel replaces."""
    import jax.numpy as jnp
    from repro.core import imc
    from repro.core.binary import channel_shuffle, or_maxpool
    from repro.core.quantize import ACT_Q
    from repro.kernels.imc_mav import ops as mav_ops

    h = x[..., None]
    for i in range(cfg.num_conv_layers):
        name = f"conv{i}"
        if i == 0:
            counts = imc.binary_group_conv_counts(h, hw.w_bin[name],
                                                  groups=1,
                                                  stride=cfg.strides[i])
            h = imc.mav_sa(counts, hw.bias[name], hw.flip[name])
        else:
            h = mav_ops.conv_mav(h, hw.w_bin[name], hw.bias[name],
                                 hw.flip[name], groups=cfg.groups(i),
                                 stride=cfg.strides[i])
        h = channel_shuffle(h, cfg.groups(i))
        if cfg.pools[i] > 1:
            h = or_maxpool(h, cfg.pools[i], axis=1)
    feats = ACT_Q.quantize(jnp.mean(h, axis=1))
    return feats @ hw.fc_w + hw.fc_b


def imc_fused_bench(out_path: str | None = None, sample_len: int = 16_000,
                    iters: int = 3,
                    batches: tuple = (1, 4, 16)) -> dict:
    """Per-layer and end-to-end hw_forward timings, fused grouped kernel vs
    the seed per-group-loop path; emits BENCH_imc_fused.json so the perf
    trajectory is machine-readable from this PR on.

    The end-to-end section sweeps ``batches`` so the fused kernel's
    M-tiling amortization (weights stay VMEM-resident across the batch
    grid) is visible, not just batch=1."""
    import jax
    import jax.numpy as jnp
    from repro.core import imc
    from repro.core.binary import channel_shuffle, or_maxpool
    from repro.kernels import default_interpret
    from repro.kernels.imc_mav import ops as mav_ops
    from repro.models import kws as m

    cfg = m.KWSConfig(sample_len=sample_len)
    params = m.init_params(jax.random.PRNGKey(0), cfg)
    state = m.init_state(cfg)
    hw = m.fold_params(params, state, cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (1, sample_len),
                           minval=-1, maxval=1)

    report = {
        "backend": jax.default_backend(),
        "interpret": bool(default_interpret()),
        "sample_len": sample_len,
        "batches": list(batches),
        "per_layer": [],
        "end_to_end": {},
    }

    # per-layer: walk the net, timing each IMC layer both ways on its real
    # input shape (baseline = conv_mav group loop + jnp shuffle/pool)
    h = x[..., None]
    for i in range(cfg.num_conv_layers):
        name = f"conv{i}"
        g, pool = cfg.groups(i), cfg.pools[i]
        if i == 0:
            counts = imc.binary_group_conv_counts(h, hw.w_bin[name],
                                                  groups=1,
                                                  stride=cfg.strides[i])
            h = imc.mav_sa(counts, hw.bias[name], hw.flip[name])
            h = channel_shuffle(h, g)
            if pool > 1:
                h = or_maxpool(h, pool, axis=1)
            continue

        def baseline(h=h, name=name, g=g, pool=pool, i=i):
            o = mav_ops.conv_mav(h, hw.w_bin[name], hw.bias[name],
                                 hw.flip[name], groups=g,
                                 stride=cfg.strides[i])
            o = channel_shuffle(o, g)
            return or_maxpool(o, pool, axis=1) if pool > 1 else o

        def fused(h=h, name=name, g=g, pool=pool, i=i):
            return mav_ops.fused_conv_mav(h, hw.w_bin[name], hw.bias[name],
                                          hw.flip[name], groups=g,
                                          stride=cfg.strides[i], pool=pool)

        t0 = time.perf_counter()
        us_base = _time_us(baseline, iters=iters)
        t1 = time.perf_counter()
        us_fused = _time_us(fused, iters=iters)
        _trace_span(f"grouploop:{name}", t0, t1,
                    us_per_call=round(us_base, 1))
        _trace_span(f"fused:{name}", t1, time.perf_counter(),
                    us_per_call=round(us_fused, 1))
        cog = cfg.channels[i] // g
        layout = imc.make_group_pack_layout(g, cog, cfg.kernels[i],
                                            cfg.channels_per_group)
        report["per_layer"].append({
            "name": name, "groups": g, "cog": cog,
            "packs": layout.packs, "groups_per_block": layout.gpb,
            "grouploop_us": round(us_base, 1),
            "fused_us": round(us_fused, 1),
            "speedup": round(us_base / us_fused, 3),
        })
        _row(f"imc_fused_{name}", f"{us_fused:.0f}",
             f"grouploop_us={us_base:.0f};x{us_base / us_fused:.2f}")
        h = fused()

    hw_packed = m.pack_hw_params(hw, cfg)
    for b in batches:
        xb = jax.random.uniform(jax.random.PRNGKey(2), (b, sample_len),
                                minval=-1, maxval=1)
        t0 = time.perf_counter()
        us_loop = _time_us(lambda: _grouploop_hw_forward(hw, xb, cfg),
                           iters=iters)
        us_fused = _time_us(
            lambda: m.hw_forward(hw_packed, xb, cfg, use_kernel=True)[0],
            iters=iters)
        us_jnp = _time_us(
            lambda: m.hw_forward(hw, xb, cfg, use_kernel=False)[0],
            iters=iters)
        _trace_span(f"hw_forward:batch_{b}", t0, time.perf_counter(),
                    grouploop_us=round(us_loop, 1),
                    fused_us=round(us_fused, 1), jnp_us=round(us_jnp, 1))
        report["end_to_end"][f"batch_{b}"] = {
            "batch": b,
            "grouploop_us": round(us_loop, 1),
            "fused_us": round(us_fused, 1),
            "jnp_us": round(us_jnp, 1),
            "speedup_vs_grouploop": round(us_loop / us_fused, 3),
            "decisions_per_sec_fused": round(b * 1e6 / us_fused, 2),
            "decisions_per_sec_grouploop": round(b * 1e6 / us_loop, 2),
        }
        _row(f"imc_fused_hw_forward_b{b}", f"{us_fused:.0f}",
             f"grouploop_us={us_loop:.0f};jnp_us={us_jnp:.0f};"
             f"decisions_per_s={b * 1e6 / us_fused:.2f}")

    out_path = _write_bench(
        report, out_path, "BENCH_imc_fused.json",
        "PYTHONPATH=src python -m benchmarks.run --imc-fused")
    _row("imc_fused_json", "", out_path)
    return report


# ---------------------------------------------------------------------------
# Streaming serving: frame-incremental vs full-recompute decisions/sec
# ---------------------------------------------------------------------------


def streaming_bench(out_path: str | None = None, sample_len: int = 2_000,
                    hop: int = 256, slots: int = 4, hops: int = 6,
                    use_kernel: bool = True, duty: float = 0.2,
                    devices: int = 1, shard_hop: int = 512,
                    compiled: bool = False, compiled_ticks: int = 96,
                    compiled_block: int = 32) -> dict:
    """Always-on serving benchmark: ``slots`` concurrent streams batched
    through the StreamServer, frame-incremental (streaming) vs full-window
    recompute per hop, plus the voice-activity-gated path on a
    speech/silence mixture at ``duty`` speech duty cycle.  Records
    decisions/sec, per-decision MAC counts, the analytical uJ/decision for
    both ungated paths and the duty-cycled gated uJ/decision (the
    always-on power story: gated hops charge leakage + VAD only) into
    BENCH_streaming.json.

    Timing protocol: servers are stepped once past admission and once past
    the jit trace, then ``hops`` steady-state batched hops are timed; the
    gated run times the whole mixture drain instead (its per-step work is
    intentionally non-uniform).

    With ``devices > 1`` (the ``--devices N`` flag; ``main()`` sets
    ``--xla_force_host_platform_device_count`` before jax initializes) a
    ``sharded`` section is appended: the SAME total stream load —
    ``devices x slots`` streams at ``shard_hop`` — served by one
    N-wide-slot single-device server vs a ``ShardedStreamServer`` of N
    pools.  Both sides report the server-measured batched-compute wall
    (``hop_wall_s``: block-until-ready around every fused launch); the
    sharded side's headline wall is the MAX per-device wall, which is
    what bounds a real fleet where devices compute concurrently — host
    wall-clock is recorded alongside for honesty (on a single-core CI
    host the pools necessarily run sequentially, so host wall shows no
    speedup; the per-device walls are the hardware-truth quantity).
    ``shard_hop`` defaults to 512 rather than inheriting ``hop``: the
    section fixes TOTAL work while varying per-device batch, so it needs
    a regime where per-launch cost scales with batch (at small hops the
    CPU interpreter's fixed per-launch overhead dominates and batching
    is nearly free — splitting such a load across devices measures
    overhead, not compute).

    With ``compiled=True`` (the ``--compiled`` flag) a ``compiled``
    section is appended: the SAME steady-state load served by the
    interpreted Python tick vs the whole-tick compiled fast path
    (``repro.serving.compiled`` — ``compiled_block`` ticks fused into
    one jitted ``lax.scan`` dispatch, ``step_block``).  Events are
    asserted bit-identical in-bench and the candidate runs with the
    launch auditor in raise mode, so the recorded speedup is over a
    PROVEN-equal run.  The section uses the jnp reference path
    (``use_kernel=False``) at a small hop: tick fusion amortizes
    per-tick dispatch + host scheduling, the accelerator-relevant
    quantity; in Pallas interpret mode the per-scan-step kernel
    interpretation cost dominates both sides and the same fusion
    measures the interpreter instead."""
    import jax
    import numpy as np_
    from repro.core import energy
    from repro.kernels import default_interpret
    from repro.models import kws as m
    from repro.serving import StreamServer, VADConfig, streaming_layer_stats

    cfg = m.KWSConfig(sample_len=sample_len)
    params = m.init_params(jax.random.PRNGKey(0), cfg)
    state = m.init_state(cfg)
    hw = m.fold_params(params, state, cfg, pack=True)

    rng = np_.random.default_rng(0)
    total = sample_len + (hops + 2) * hop
    streams = {f"s{i}": rng.uniform(-1, 1, size=total).astype(np_.float32)
               for i in range(slots)}

    def run(streaming: bool) -> dict:
        srv = _attach_trace(
            StreamServer(hw, cfg, hop=hop, slots=slots,
                         use_kernel=use_kernel, streaming=streaming))
        for sid, audio in streams.items():
            srv.submit(sid, audio)
            srv.finish(sid)
        srv.step()                         # admissions (window 0)
        srv.step()                         # first hop: jit trace, untimed
        t0 = time.perf_counter()
        n = 0
        for _ in range(hops):
            n += len(srv.step())
        dt = time.perf_counter() - t0
        assert n == slots * hops, (n, slots, hops)
        return {
            "decisions": n,
            "wall_s": round(dt, 4),
            "us_per_decision": round(dt / n * 1e6, 1),
            "decisions_per_sec": round(n / dt, 2),
        }

    def run_gated() -> dict:
        """Speech/silence mixture: each stream is loud for the first
        ``duty`` fraction of its post-window hops (one utterance burst)
        and near-silent after; the VAD gates the silent tail so only
        ~duty of the hops run the IMC stack."""
        n_hops = max(hops * 4, 20)         # long tail: duty dominates
        n_speech = max(1, round(duty * n_hops))
        mix = {}
        for i in range(slots):
            wav = (1e-4 * rng.standard_normal(sample_len + n_hops * hop)
                   ).astype(np_.float32)
            loud = sample_len + n_speech * hop
            wav[:loud] = rng.uniform(-1, 1, size=loud)
            mix[f"g{i}"] = wav
        srv = _attach_trace(
            StreamServer(hw, cfg, hop=hop, slots=slots,
                         use_kernel=use_kernel,
                         vad=VADConfig(threshold_on_db=-40.0,
                                       threshold_off_db=-50.0,
                                       wake_margin=1, hang=0)))
        for sid, audio in mix.items():
            srv.submit(sid, audio)
            srv.finish(sid)
        t0 = time.perf_counter()
        n = len(srv.drain())
        dt = time.perf_counter() - t0
        s = srv.stats()
        return {
            "hops_per_stream": n_hops,
            "duty_cycle_target": duty,
            "duty_cycle_measured": s["duty_cycle"],
            "speech_hops": s["speech_hops"],
            "gated_hops": s["gated_hops"],
            "decisions": n,
            "wall_s": round(dt, 4),
            "decisions_per_sec": round(n / dt, 2),
        }

    from repro.models.kws import layer_stats
    from repro.serving import make_stream_geometry
    geom = make_stream_geometry(cfg, hop)
    stats_off = layer_stats(cfg)
    stats_str = streaming_layer_stats(cfg, geom)
    macs_off = sum(s["macs"] for s in stats_off)
    macs_str = sum(s["macs"] for s in stats_str)

    def run_sharded() -> dict:
        """Fixed total load, one device vs N pools: device-parallel
        decisions/sec from the max per-device compute wall."""
        from repro.serving import ShardedStreamServer
        total = devices * slots
        s_total = sample_len + (hops + 2) * shard_hop
        s_streams = {f"d{i}": rng.uniform(-1, 1, size=s_total)
                     .astype(np_.float32) for i in range(total)}

        def protocol(srv, submit, walls_of):
            for sid, audio in s_streams.items():
                submit(sid, audio)
                srv.finish(sid)
            srv.step()                     # admissions (window 0)
            srv.step()                     # first hop: jit trace, untimed
            base = walls_of()
            t0 = time.perf_counter()
            n = 0
            for _ in range(hops):
                n += len(srv.step())
            host = time.perf_counter() - t0
            assert n == total * hops, (n, total, hops)
            walls = [w - b for w, b in zip(walls_of(), base)]
            return n, host, walls

        one = StreamServer(hw, cfg, hop=shard_hop, slots=total,
                           use_kernel=use_kernel)
        n1, host1, (wall1,) = protocol(one, one.submit,
                                       lambda: [one._hop_wall_s])
        sh = ShardedStreamServer(hw, cfg, hop=shard_hop, devices=devices,
                                 slots=slots, use_kernel=use_kernel)
        nN, hostN, wallsN = protocol(
            sh, sh.submit, lambda: [p._hop_wall_s for p in sh.pools])
        dev_wall = max(wallsN)
        scaling = (nN / dev_wall) / (n1 / wall1)
        return {
            "devices": devices,
            "backend_devices": len(jax.devices()),
            "hop": shard_hop,
            "slots_per_device": slots,
            "streams": total,
            "timed_hops": hops,
            "metric": ("decisions/sec from the batched-compute wall "
                       "(hop_wall_s); sharded uses max per-device wall "
                       "= fleet throughput with devices computing "
                       "concurrently; host_wall_s includes the "
                       "sequential host dispatch"),
            "single_device": {
                "decisions": n1,
                "compute_wall_s": round(wall1, 4),
                "host_wall_s": round(host1, 4),
                "decisions_per_sec": round(n1 / wall1, 2),
            },
            "sharded": {
                "decisions": nN,
                "per_device_wall_s": [round(w, 4) for w in wallsN],
                "max_device_wall_s": round(dev_wall, 4),
                "host_wall_s": round(hostN, 4),
                "decisions_per_sec": round(nN / dev_wall, 2),
            },
            "scaling_decisions_per_sec": round(scaling, 3),
            "regen": ("PYTHONPATH=src python -m benchmarks.run "
                      f"--streaming --devices {devices}"),
        }

    def run_compiled() -> dict:
        """Python tick vs compiled whole-tick block on the same traffic:
        identical decisions asserted, auditor in raise mode, speedup
        from host wall over the timed steady-state ticks."""
        from repro.serving import (CompiledTickConfig, ObsConfig,
                                   StreamServer as _Srv)
        # one always-on stream at the paper's native hop: the deployment
        # regime the block fusion targets — per-tick device work is tiny,
        # so the Python tick's K host->device round trips are the cost
        # the scan amortizes away
        c_hop, c_slots = 64, 1
        warm = 2 * compiled_block          # untimed: trace + cache warm
        c_total = sample_len + (compiled_ticks + warm + 4) * c_hop
        c_streams = {f"c{i}": rng.uniform(-1, 1, size=c_total)
                     .astype(np_.float32) for i in range(c_slots)}

        def drive(fast: bool):
            srv = _Srv(hw, cfg, hop=c_hop, slots=c_slots,
                       use_kernel=False, obs=ObsConfig(audit="raise"),
                       compiled=(CompiledTickConfig(block=compiled_block)
                                 if fast else None))
            for sid, audio in c_streams.items():
                srv.submit(sid, audio)
                srv.finish(sid)
            ev = list(srv.step())          # admissions (window 0)
            while srv._steps < 1 + warm:   # untimed warmup
                ev += (srv.step_block(max_ticks=1 + warm - srv._steps)
                       if fast else srv.step())
            end = 1 + warm + compiled_ticks
            t0 = time.perf_counter()
            n = 0
            while srv._steps < end:
                evs = (srv.step_block(max_ticks=end - srv._steps)
                       if fast else srv.step())
                n += len(evs)
                ev += evs
            dt = time.perf_counter() - t0
            return ev, n, dt, srv

        def best_of(fast: bool, reps: int = 3):
            # deterministic traffic -> identical events every repeat;
            # best-of wall filters host scheduling noise out of the ratio
            kept = None
            for _ in range(reps):
                ev, n, dt, srv = drive(fast)
                if kept is None or dt < kept[2]:
                    kept = (ev, n, dt, srv)
            return kept

        ev_py, n_py, dt_py, _srv = best_of(False)
        ev_c, n_c, dt_c, srv_c = best_of(True)
        # the differential gate, in-bench: the timed runs themselves are
        # bit-identical, full event stream from tick 0 on
        assert ev_py == ev_c, "compiled tick diverged from Python tick"
        assert n_py == n_c == c_slots * compiled_ticks, (n_py, n_c)
        audit = srv_c.auditor.stats()
        assert audit["violations"] == 0    # raise mode would have thrown
        speedup = (n_c / dt_c) / (n_py / dt_py)
        return {
            "hop": c_hop,
            "slots": c_slots,
            "block": compiled_block,
            "timed_ticks": compiled_ticks,
            "use_kernel": False,
            "metric": ("decisions/sec from best-of-3 host wall over the "
                       "timed steady-state ticks; both sides serve the same "
                       "traffic and their event streams are asserted "
                       "bit-identical before the speedup is recorded; "
                       "the compiled side runs with the launch auditor "
                       "in raise mode (one block = the tick's entire "
                       "compute, one fused launch per IMC layer)"),
            "python_tick": {
                "decisions": n_py,
                "wall_s": round(dt_py, 4),
                "decisions_per_sec": round(n_py / dt_py, 2),
            },
            "compiled_tick": {
                "decisions": n_c,
                "wall_s": round(dt_c, 4),
                "decisions_per_sec": round(n_c / dt_c, 2),
                "blocks": srv_c._compiled_blocks,
                "ticks": srv_c._compiled_ticks,
            },
            "speedup_decisions_per_sec": round(speedup, 3),
            "events_bit_identical": True,
            "audit": {"mode": "raise",
                      "violations": audit["violations"],
                      "compiled_calls": audit["calls"]["compiled"]},
            "regen": ("PYTHONPATH=src python -m benchmarks.run "
                      "--streaming --compiled"
                      + (f" --devices {devices}" if devices > 1 else "")),
        }

    res_stream = run(streaming=True)
    res_recomp = run(streaming=False)
    res_gated = run_gated()
    res_sharded = run_sharded() if devices > 1 else None
    res_compiled = run_compiled() if compiled else None
    # charge the energy at the duty cycle the run actually measured (the
    # VAD's hangover/EMA tail makes it slightly above the target), so the
    # recorded reduction describes the attached run
    measured_duty = res_gated["duty_cycle_measured"]
    gated_energy = {
        k: round(v, 4) if isinstance(v, float) else v
        for k, v in energy.gated_energy_summary(
            stats_off, stats_str, hop_samples=hop,
            duty_cycle=measured_duty if measured_duty is not None
            else duty).items()
    }
    res_gated["energy"] = gated_energy
    speedup = (res_stream["decisions_per_sec"]
               / res_recomp["decisions_per_sec"])
    report = {
        "backend": jax.default_backend(),
        "interpret": bool(default_interpret()),
        "use_kernel": use_kernel,
        "window": sample_len,
        "hop": hop,
        "hop_over_window": round(hop / sample_len, 4),
        "slots": slots,
        "timed_hops": hops,
        "streaming": res_stream,
        "recompute": res_recomp,
        "gated": res_gated,
        "speedup_decisions_per_sec": round(speedup, 3),
        "macs_per_decision": {
            "offline": macs_off,
            "streaming": macs_str,
            "ratio": round(macs_str / macs_off, 4),
        },
        "energy": {
            k: round(v, 4) if isinstance(v, float) else v
            for k, v in energy.streaming_energy_summary(
                stats_off, stats_str).items()
        },
    }
    if res_compiled is not None:
        report["compiled"] = res_compiled
        _row("compiled_tick_speedup", "",
             f"x{res_compiled['speedup_decisions_per_sec']:.2f};"
             f"block={res_compiled['block']};"
             f"py={res_compiled['python_tick']['decisions_per_sec']};"
             f"compiled="
             f"{res_compiled['compiled_tick']['decisions_per_sec']}")
    if res_sharded is not None:
        report["sharded"] = res_sharded
        _row("sharded_scaling_decisions_per_sec", "",
             f"x{res_sharded['scaling_decisions_per_sec']:.2f}"
             f"@{devices}dev;"
             f"single={res_sharded['single_device']['decisions_per_sec']};"
             f"sharded={res_sharded['sharded']['decisions_per_sec']}")
    _row("streaming_decisions_per_sec",
         f"{res_stream['us_per_decision']:.0f}",
         f"recompute_us={res_recomp['us_per_decision']:.0f};"
         f"x{speedup:.2f};slots={slots};hop/window={hop / sample_len:.3f}")
    _row("streaming_macs_ratio", "", f"{macs_str / macs_off:.4f}")
    _row("streaming_gated_uj_per_decision", "",
         f"{gated_energy['gated_uj_per_decision']:.3f}uJ"
         f"@duty{gated_energy['duty_cycle']:.2f};"
         f"ungated={gated_energy['ungated_uj_per_decision']:.3f}uJ;"
         f"x{gated_energy['reduction_vs_ungated']:.2f}")

    out_path = _write_bench(
        report, out_path, "BENCH_streaming.json",
        "PYTHONPATH=src python -m benchmarks.run --streaming")
    _row("streaming_json", "", out_path)
    return report


def customize_bench(out_path: str | None = None, sample_len: int = 2_000,
                    hop: int = 256, slots: int = 4,
                    utts_per_class: tuple = (1, 3),
                    epochs: int = 120, sessions: int = 4) -> dict:
    """On-device customization as a serving workload: enrollment sessions
    driven through the StreamServer's scheduler ticks (bias compensation
    + error-scaled/SGA fine-tuning as background jobs), recording the
    utterances-to-recovered-accuracy trajectory and the analytical uJ per
    fine-tune step into BENCH_customize.json.

    Three sections land in the JSON: the single-session recovery
    trajectory over ``utts_per_class``; a ``--sessions N`` concurrent
    phase — N interleaved enrollment sessions plus a live inference
    stream through ONE StreamServer, with per-tick batched-call
    accounting proving the one-fused-launch-per-layer invariant holds on
    mixed inference + multi-session learning ticks (per-tick launches
    never scale with N); and the error-scaling ablation — the chip's
    fixed 1.375 factor vs the dynamic Eq-2 ceil exponent (which lands the
    largest error at/above the Q1.7 rail and can stall) vs the floored /
    clamped variants (``OnChipTrainConfig.error_scale_mode``).

    Uses the cached trained model (results/kws_model.pkl) when present —
    the recovery numbers are meaningful there; otherwise an untrained fold
    exercises the identical mechanics.  The 'before' row is the chip with
    static MAV offsets and no compensation (the Table IV premise)."""
    # the concurrent record (>= 2 sessions) is part of the JSON schema the
    # docs reference (results/BENCH_customize.json#concurrent_sessions.*,
    # CI-checked by scripts/check_docs.py) — reject a sessions-less regen
    # up front, before the multi-minute trajectory runs
    if sessions < 2:
        raise ValueError("--sessions must be >= 2: the concurrent-session "
                         "record is part of the BENCH_customize.json "
                         "schema the docs reference")
    import pickle

    import jax
    import jax.numpy as jnp
    from repro.core import imc
    from repro.core.onchip_training import (OnChipTrainConfig,
                                            head_accuracy)
    from repro.data import audio
    from repro.kernels import default_interpret
    from repro.models import kws as m
    from repro.serving import CustomizeConfig, StreamServer
    from repro.training import kws as tr

    cfg = m.KWSConfig(sample_len=sample_len)
    pkl = os.path.join(RESULTS, "kws_model.pkl")
    trained = os.path.exists(pkl) and sample_len == 2_000
    if trained:
        with open(pkl, "rb") as f:
            params, state = pickle.load(f)
        params = jax.tree_util.tree_map(jnp.asarray, params)
        state = m.KWSState(*[jax.tree_util.tree_map(jnp.asarray, s)
                             for s in state])
    else:
        params = m.init_params(jax.random.PRNGKey(0), cfg)
        state = m.init_state(cfg)
    hw = m.fold_params(params, state, cfg, pack=True)
    chans = {f"conv{i}": cfg.channels[i]
             for i in range(1, cfg.num_conv_layers)}
    offs = imc.sample_chip_offsets(jax.random.PRNGKey(7), chans,
                                   imc.IMCNoiseParams(mav_offset_std=8.0))

    n_max = max(utts_per_class)
    (xp_tr, yp_tr), (xp_te, yp_te) = audio.make_personal(
        train_per_class=n_max, test_per_class=4, length=sample_len,
        accent_shift=0.18)
    before = tr.evaluate_hw(hw, xp_te, yp_te, cfg, chip_offsets=offs)

    # the chip's error-scaling mode: fixed 1.375 (shift-add friendly, §V-C)
    tcfg = OnChipTrainConfig(epochs=epochs, fixed_error_scale=1.375)
    trajectory = []
    uj = None
    for n in utts_per_class:
        srv = _attach_trace(
            StreamServer(hw, cfg, hop=hop, slots=slots, use_kernel=True,
                         chip_offsets=offs))
        sess = srv.customize(f"user{n}", CustomizeConfig(
            train=tcfg, epochs_per_tick=24, layers_per_tick=5))
        # n utterances per keyword, in enrollment-UX order
        by_class = {}
        for wav, lab in zip(xp_tr, yp_tr):
            by_class.setdefault(int(lab), []).append(wav)
        t0 = time.perf_counter()
        for c, wavs in sorted(by_class.items()):
            for wav in wavs[:n]:
                sess.enroll(c, wav)
        sess.finish_enrollment()
        steps = 0
        while not sess.done and steps < 5000:
            srv.step()
            steps += 1
        assert sess.done, sess.phase
        wall = time.perf_counter() - t0
        res = sess.result
        hw_n = sess.refolded()
        f_te = tr.hw_features(hw_n, xp_te, cfg, chip_offsets=offs)
        acc = float(head_accuracy(jnp.asarray(f_te), jnp.asarray(yp_te),
                                  jnp.asarray(res.fc_w),
                                  jnp.asarray(res.fc_b), tcfg))
        uj = res.energy
        trajectory.append({
            "utterances_per_class": n,
            "utterances": res.n_utterances,
            "accuracy": round(acc, 4),
            "scheduler_ticks": steps,
            "wall_s": round(wall, 2),
            "train_history": res.history,
        })
        _row(f"customize_{n}_per_class", "",
             f"acc={acc:.4f};before={before:.4f};ticks={steps}")

    # -- concurrent sessions: N users enrolling at once, one server --------
    srv = _attach_trace(
        StreamServer(hw, cfg, hop=hop, slots=sessions + 4,
                     use_kernel=True, chip_offsets=offs))
    rng = np.random.default_rng(3)
    live = rng.uniform(-1, 1, sample_len + 4000 * hop
                       ).astype(np.float32)
    srv.submit("live", live[:sample_len])
    pos = sample_len
    by_class = {}
    for wav, lab in zip(xp_tr, yp_tr):
        by_class.setdefault(int(lab), []).append(wav)
    sess_list = []
    for k in range(sessions):
        s = srv.customize(f"user{k}", CustomizeConfig(
            train=tcfg, epochs_per_tick=24, layers_per_tick=5))
        for c, wavs in sorted(by_class.items()):
            s.enroll(c, wavs[k % len(wavs)])
        s.finish_enrollment()
        sess_list.append(s)
    done_tick = [None] * sessions
    per_tick_calls = []
    t0 = time.perf_counter()
    ticks = 0
    while not all(s.done for s in sess_list) and ticks < 20_000:
        if pos < len(live):
            srv.submit("live", live[pos:pos + hop])
            pos += hop
        before_calls = (srv._init_calls + srv._hop_calls
                        + srv._replay_calls)
        srv.step()
        per_tick_calls.append(srv._init_calls + srv._hop_calls
                              + srv._replay_calls - before_calls)
        ticks += 1
        for k, s in enumerate(sess_list):
            if s.done and done_tick[k] is None:
                done_tick[k] = ticks
    wall = time.perf_counter() - t0
    assert all(s.done for s in sess_list), \
        [s.phase for s in sess_list]
    imc_layers = cfg.num_conv_layers - 1
    max_calls = max(per_tick_calls)
    # the invariant: per-tick fused launches never scale with the
    # number of sessions — at most one batched init wave plus one
    # batched hop per tick, each = one launch per IMC layer
    # (launch-per-call is trace-enforced in tests/test_customize.py)
    assert max_calls <= 2, (max_calls, sessions)
    per_session = []
    for k, s in enumerate(sess_list):
        e = s.result.energy
        per_session.append({
            "stream": f"user{k}",
            "utterances": s.result.n_utterances,
            "epochs": s.result.epochs,
            "ticks_to_done": done_tick[k],
            "final_train_accuracy":
                s.history[-1]["train_accuracy"] if s.history else None,
            "uj_per_finetune_step":
                round(e["uj_per_finetune_step"], 4),
            "total_uj": round(e["total_uj"], 4),
        })
    total_calls = sum(per_tick_calls)
    concurrent = {
        "sessions": sessions,
        "slots": sessions + 4,
        "ticks": ticks,
        "wall_s": round(wall, 2),
        "live_decisions": srv._decisions,
        "learn_hops": srv.stats()["learn_hops"],
        "imc_layers": imc_layers,
        "batched_calls_total": total_calls,
        "fused_launches_total": total_calls * imc_layers,
        "max_batched_calls_per_tick": max_calls,
        "one_launch_per_layer_per_call": True,
        "per_session": per_session,
    }
    _row("customize_concurrent_sessions", "",
         f"n={sessions};ticks={ticks};"
         f"max_calls_per_tick={max_calls};"
         f"launches={total_calls * imc_layers}")

    # -- error-scaling ablation: fixed 1.375 vs dynamic ceil/floor ---------
    # run on the §IV-B-compensated chip (the real pipeline: calibrate ->
    # features -> fine-tune) — this is where the ROADMAP's Q1.7-rail
    # stall was observed: the dynamic ceil exponent lands the largest
    # error at/above the rail every batch and stalls on weakly separated
    # features, while the chip's fixed 1.375 recovers
    hw_comp = tr.calibrate_and_compensate(hw, xp_tr, offs, cfg)
    hwp, _ = m.as_hw_params(hw_comp)
    f_tr = tr.hw_features(hw_comp, xp_tr, cfg, chip_offsets=offs)
    f_te_a = tr.hw_features(hw_comp, xp_te, cfg, chip_offsets=offs)
    from repro.core.onchip_training import quantized_head_finetune
    ablation = {}
    for name, ocfg in {
        "fixed_1p375": OnChipTrainConfig(epochs=epochs,
                                         fixed_error_scale=1.375),
        "dynamic_ceil": OnChipTrainConfig(epochs=epochs),
        "dynamic_floor": OnChipTrainConfig(epochs=epochs,
                                           error_scale_mode="floor"),
        "dynamic_floor_clamp4": OnChipTrainConfig(
            epochs=epochs, error_scale_mode="floor",
            error_scale_max_exponent=4),
    }.items():
        w, b = quantized_head_finetune(
            jnp.asarray(f_tr), jnp.asarray(yp_tr), hwp.fc_w, hwp.fc_b,
            ocfg)
        tr_acc = float(head_accuracy(jnp.asarray(f_tr),
                                     jnp.asarray(yp_tr), w, b, ocfg))
        te_acc = float(head_accuracy(jnp.asarray(f_te_a),
                                     jnp.asarray(yp_te), w, b, ocfg))
        ablation[name] = {"train_accuracy": round(tr_acc, 4),
                          "test_accuracy": round(te_acc, 4)}
        _row(f"customize_escale_{name}", "",
             f"train={tr_acc:.4f};test={te_acc:.4f}")

    report = {
        "backend": jax.default_backend(),
        "interpret": bool(default_interpret()),
        "trained_model": trained,
        "window": sample_len,
        "hop": hop,
        "slots": slots,
        "epochs": epochs,
        "chip_mav_offset_std": 8.0,
        "accuracy_before": round(before, 4),
        "recovery_trajectory": trajectory,
        "concurrent_sessions": concurrent,
        "error_scaling_ablation": ablation,
        "energy_per_finetune_step": {
            k: round(v, 4) if isinstance(v, float) else v
            for k, v in (uj or {}).items()
        },
    }
    _row("customize_before_accuracy", "", f"{before:.4f}")
    _row("customize_uj_per_finetune_step", "",
         f"{report['energy_per_finetune_step'].get('uj_per_finetune_step')}")

    out_path = _write_bench(
        report, out_path, "BENCH_customize.json",
        "PYTHONPATH=src python -m benchmarks.run --customize --sessions 4")
    _row("customize_json", "", out_path)
    return report


def faults_bench(out_path: str | None = None, sample_len: int = 2_000,
                 hop: int = 256) -> dict:
    """Fault-injected self-healing serving (docs/RELIABILITY.md): for each
    fault scenario — offset drift, trim bit flips, stuck SA columns — a
    live StreamServer with the fault model and the canary health monitor
    detects the fault, localizes it, and recompensates through the chip's
    test mode; the bench records held-out accuracy on the clean chip,
    under the fault, and on the healed chip (pristine bias + the heal
    delta, evaluated WITH the fault still present).

    The acceptance gate baked in here: for the recoverable scenarios
    (drift, bit flips) the full recovery loop — the serving heal
    (SIV-B recompensation) plus a head re-enrollment on the healed chip
    (SV-C, the same offline chain the enrollment sessions run) — must
    land within 2 points of the clean chip; integer bit-flip faults must
    additionally heal within 2 points from the bias write alone.  Stuck
    columns cannot be healed by a bias write (the rail dominates any
    finite bias) — they are permanently masked and reported as a
    write-off, not gated.

    A crash-safety record rides along: snapshot the fault+health server
    mid-recovery, restore into a fresh process-equivalent server, and
    verify the next ticks' events are bit-identical (the same invariant
    tests/test_reliability.py trace-enforces), recording snapshot size
    and timings."""
    import pickle
    import tempfile

    import jax
    import jax.numpy as jnp
    from repro.core import faults as flt
    from repro.core import imc
    from repro.data import audio
    from repro.kernels import default_interpret
    from repro.models import kws as m
    from repro.serving import HealthConfig, StreamServer
    from repro.serving import customize as cz
    from repro.training import kws as tr

    cfg = m.KWSConfig(sample_len=sample_len)
    (x_tr, y_tr), (x_te, y_te) = audio.make_gscd_like(
        train_per_class=40, test_per_class=30, length=sample_len)
    # the accuracy gate below is meaningless at chance level, so a
    # trained model is required: load the shared cache
    # (results/kws_model.pkl, the benchmarks/kws_experiments.py artifact)
    # or train the fast config once and cache it for every later bench
    pkl = os.path.join(RESULTS, "kws_model.pkl")
    if sample_len != 2_000:
        raise SystemExit("--faults runs the trained 2000-sample config")
    trained = os.path.exists(pkl)
    if trained:
        with open(pkl, "rb") as f:
            params, state = pickle.load(f)
        params = jax.tree_util.tree_map(jnp.asarray, params)
        state = m.KWSState(*[jax.tree_util.tree_map(jnp.asarray, s)
                             for s in state])
    else:
        tcfg = tr.TrainConfig(
            epochs=24, batch_size=100, lr=3e-3, log_every=48,
            alpha_schedule=((0.3, 2.0), (0.5, 5.0), (0.65, 12.0),
                            (1.0, -8.0)),
            polarize_weight=5e-3)
        params, state = tr.train_base(jnp.asarray(x_tr), jnp.asarray(y_tr),
                                      cfg, tcfg)
        os.makedirs(RESULTS, exist_ok=True)
        with open(pkl, "wb") as f:
            pickle.dump((jax.tree_util.tree_map(np.asarray, params),
                         tuple(jax.tree_util.tree_map(np.asarray, s)
                               for s in state)), f)
        trained = True
    hw = m.fold_params(params, state, cfg, pack=True)
    chans = {f"conv{i}": cfg.channels[i]
             for i in range(1, cfg.num_conv_layers)}
    offs = imc.sample_chip_offsets(jax.random.PRNGKey(7), chans,
                                   imc.IMCNoiseParams(mav_offset_std=8.0))
    # the 'clean' baseline is a fully enrolled device — §IV-B bias
    # compensation plus the §V-C head fine-tune on the chip's own
    # features (the customization path) — so the fault scenarios measure
    # drops from a working operating point, not from chance
    from repro.core.onchip_training import (OnChipTrainConfig,
                                            quantized_head_finetune)
    hw_comp = tr.calibrate_and_compensate(hw, x_tr[:40], offs, cfg)
    hwp0, _ = m.as_hw_params(hw_comp)
    f_tr = tr.hw_features(hw_comp, x_tr, cfg, chip_offsets=offs)
    ocfg = OnChipTrainConfig(epochs=200, fixed_error_scale=1.375)
    fc_w, fc_b = quantized_head_finetune(
        jnp.asarray(f_tr), jnp.asarray(y_tr), hwp0.fc_w, hwp0.fc_b, ocfg)
    hw_comp = cz.refold(cz.CustomizationResult(
        bias={k: np.asarray(v) for k, v in hwp0.bias.items()},
        fc_w=np.asarray(fc_w), fc_b=np.asarray(fc_b), epochs=ocfg.epochs,
        n_utterances=int(len(y_tr)), history=[], energy={}), hw_comp, cfg)
    hwp, _ = m.as_hw_params(hw_comp)
    acc_clean = tr.evaluate_hw(hw_comp, x_te, y_te, cfg, chip_offsets=offs)
    _row("faults_clean_accuracy", "", f"{acc_clean:.4f}")

    def chip_with(delta):
        """The faulted chip as offline offsets: fault deltas add to the
        counts exactly like static MAV offsets do."""
        return {k: jnp.asarray(offs[k])
                + jnp.asarray(np.asarray(delta.get(k, 0.0), np.float32))
                for k in offs}

    def healed_fold(heal):
        """Pristine compensated bias + the serving heal delta, refolded."""
        bias = {name: np.asarray(hwp.bias[name], np.float32)
                + np.asarray(heal.get(name, 0.0), np.float32)
                for name in cfg.imc_layer_names()}
        res = cz.CustomizationResult(
            bias={k: np.rint(v).astype(np.int32) for k, v in bias.items()},
            fc_w=np.asarray(hwp.fc_w), fc_b=np.asarray(hwp.fc_b),
            epochs=0, n_utterances=0, history=[], energy={})
        return cz.refold(res, hw_comp, cfg)

    def inject_drift(f):
        # public-API surgery: a one-shot static drift burst (std 24
        # counts on two layers) via the fault model's own snapshot codec,
        # so it does not keep walking while the heal converges
        snap = f.snapshot()
        rng = np.random.default_rng(1)
        for name in ("conv2", "conv4"):
            snap["drift"][name] = rng.normal(
                0.0, 24.0, snap["drift"][name].shape).astype(np.float32)
        f.restore(snap)

    def run_scenario(name, inject):
        # recal_sa_noise_std 0.25 models the chip's test mode averaging
        # repeated SA reads (16 reads at unit noise): integer faults then
        # round to the exactly-correct even bias write, so bit-flip heals
        # are EXACT instead of carrying +-2-count measurement wobble.
        # recal_scope="all" re-runs the full SIV-B pass per recovery —
        # the direct test mode also cancels canary-invisible faults the
        # tail-only localization can never flag
        srv = _attach_trace(
            StreamServer(hw_comp, cfg, hop=hop, slots=3, use_kernel=True,
                         chip_offsets=offs,
                         faults=flt.FaultConfig(seed=5),
                         health=HealthConfig(interval=5,
                                             recal_sa_noise_std=0.25,
                                             recal_scope="all"),
                         seed=9))
        rng = np.random.default_rng(11)
        srv.submit("live", rng.uniform(-1, 1, sample_len)
                   .astype(np.float32))
        for _ in range(30):          # warm up to the first clean canary
            srv.submit("live", rng.uniform(-1, 1, hop).astype(np.float32))
            srv.step()
            if srv.health.canaries >= 1:
                break
        assert srv.health.state == "healthy", srv.health.state
        injected_tick = srv._steps
        inject(srv.faults)
        delta_f = {k: np.asarray(v).copy()
                   for k, v in srv.faults.deltas().items()}
        acc_faulted = tr.evaluate_hw(hw_comp, x_te, y_te, cfg,
                                     chip_offsets=chip_with(delta_f))
        healed_tick = None
        for _ in range(400):
            srv.submit("live", rng.uniform(-1, 1, hop).astype(np.float32))
            srv.step()
            h = srv.health
            if (h.detected_tick is not None
                    and h.detected_tick >= injected_tick
                    and h.state == "healthy"):
                healed_tick = srv._steps
                break
        h = srv.health
        assert healed_tick is not None, \
            f"{name}: not healed in 400 ticks (state={h.state})"
        heal = {k: np.asarray(v) for k, v in (srv._heal_delta or {}).items()}
        hw_healed = healed_fold(heal)
        co_f = chip_with(delta_f)
        acc_healed = tr.evaluate_hw(hw_healed, x_te, y_te, cfg,
                                    chip_offsets=co_f)
        # complete the paper's recovery loop: the serving heal is the
        # SIV-B compensation stage, and the paper's customization always
        # pairs it with the SV-C head fine-tune.  Integer bias writes
        # cannot cancel a fractional fault (the grid is even-parity, the
        # rail clips), and the enrolled head is fitted to the exact count
        # landscape — so the sub-count heal residual costs real accuracy
        # until the head is re-enrolled on the healed chip (same offline
        # chain the enrollment sessions run, fault still present)
        f_h = tr.hw_features(hw_healed, x_tr, cfg, chip_offsets=co_f)
        hwp_h, _ = m.as_hw_params(hw_healed)
        fcw2, fcb2 = quantized_head_finetune(
            jnp.asarray(f_h), jnp.asarray(y_tr), hwp_h.fc_w, hwp_h.fc_b,
            ocfg)
        hw_re = cz.refold(cz.CustomizationResult(
            bias={k: np.asarray(v) for k, v in hwp_h.bias.items()},
            fc_w=np.asarray(fcw2), fc_b=np.asarray(fcb2),
            epochs=ocfg.epochs, n_utterances=int(len(y_tr)), history=[],
            energy={}), hw_healed, cfg)
        acc_re = tr.evaluate_hw(hw_re, x_te, y_te, cfg, chip_offsets=co_f)
        hs = h.stats()
        rec = {
            "kind": name,
            "accuracy_faulted": round(acc_faulted, 4),
            "accuracy_healed": round(acc_healed, 4),
            "accuracy_reenrolled": round(acc_re, 4),
            "accuracy_drop_faulted": round(acc_clean - acc_faulted, 4),
            "accuracy_gap_healed": round(acc_clean - acc_healed, 4),
            "accuracy_gap_reenrolled": round(acc_clean - acc_re, 4),
            "detect_ticks": hs["detected_tick"] - injected_tick,
            "ticks_to_quarantine": (hs["quarantined_tick"] - injected_tick
                                    if hs["quarantined_tick"] is not None
                                    else None),
            "heal_ticks": healed_tick - injected_tick,
            "canaries": hs["canaries"],
            "failed_canaries": hs["failed_canaries"],
            "recoveries": hs["recoveries"],
            "recovery_energy_uj": hs["recovery_energy_uj"],
            "masked_channels": hs["masked_channels"],
        }
        _row(f"faults_{name}", "",
             f"faulted={acc_faulted:.4f};healed={acc_healed:.4f};"
             f"reenrolled={acc_re:.4f};detect={rec['detect_ticks']};"
             f"heal={rec['heal_ticks']}")
        return rec

    scenarios = {
        "drift": run_scenario("drift", inject_drift),
        "bit_flips": run_scenario(
            "bit_flips", lambda f: f.inject_bit_flips(n=8)),
        "stuck": run_scenario(
            "stuck", lambda f: f.inject_stuck("conv2", [3, 11])),
    }
    # acceptance: the full recovery loop (recompensation + re-enrollment)
    # lands the recoverable faults within 2 points of clean; integer
    # bit-flip faults additionally heal EXACTLY with the bias write alone
    # (even-integer shifts round to the correct even-grid correction)
    for name in ("drift", "bit_flips"):
        gap = scenarios[name]["accuracy_gap_reenrolled"]
        assert gap <= 0.02, (name, gap)
        scenarios[name]["reenrolled_within_2pts"] = True
    gap_bf = scenarios["bit_flips"]["accuracy_gap_healed"]
    assert gap_bf <= 0.02, ("bit_flips raw heal", gap_bf)
    scenarios["bit_flips"]["healed_within_2pts"] = True

    # -- crash safety: snapshot mid-recovery, restore, bit-identical -------
    srv = _attach_trace(
        StreamServer(hw_comp, cfg, hop=hop, slots=3, use_kernel=True,
                     chip_offsets=offs, faults=flt.FaultConfig(seed=5),
                     health=HealthConfig(interval=5), seed=9))
    rng = np.random.default_rng(12)
    srv.submit("live", rng.uniform(-1, 1, sample_len).astype(np.float32))
    srv.faults.inject_bit_flips(n=4)
    for _ in range(12):
        srv.submit("live", rng.uniform(-1, 1, hop).astype(np.float32))
        srv.step()
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "server.npz")
        t0 = time.perf_counter()
        srv.snapshot(path)
        snap_ms = (time.perf_counter() - t0) * 1e3
        snap_bytes = os.path.getsize(path)
        srv2 = StreamServer(hw_comp, cfg, hop=hop, slots=3,
                            use_kernel=True, chip_offsets=offs,
                            faults=flt.FaultConfig(seed=5),
                            health=HealthConfig(interval=5), seed=9)
        t0 = time.perf_counter()
        srv2.restore(path)
        restore_ms = (time.perf_counter() - t0) * 1e3
    future = [rng.uniform(-1, 1, hop).astype(np.float32)
              for _ in range(8)]
    ev1, ev2 = [], []
    for ch in future:
        srv.submit("live", ch)
        ev1.extend(srv.step())
    for ch in future:
        srv2.submit("live", ch)
        ev2.extend(srv2.step())
    assert ev1 == ev2, "restore is not bit-identical"
    crash = {
        "snapshot_bytes": snap_bytes,
        "snapshot_ms": round(snap_ms, 2),
        "restore_ms": round(restore_ms, 2),
        "replay_ticks": len(future),
        "events_bit_identical": True,
    }
    _row("faults_snapshot_restore", "",
         f"bytes={snap_bytes};identical=True")

    report = {
        "backend": jax.default_backend(),
        "interpret": bool(default_interpret()),
        "trained_model": trained,
        "window": sample_len,
        "hop": hop,
        "chip_mav_offset_std": 8.0,
        "test_utterances": int(len(y_te)),
        "baseline": {"accuracy_clean": round(acc_clean, 4)},
        "scenarios": scenarios,
        "snapshot_restore": crash,
    }
    out_path = _write_bench(
        report, out_path, "BENCH_faults.json",
        "PYTHONPATH=src python -m benchmarks.run --faults")
    _row("faults_json", "", out_path)
    return report


def obs_overhead_bench(out_path: str | None = None, sample_len: int = 2_000,
                       hop: int = 256, slots: int = 4, repeats: int = 2,
                       trace_out: str | None = None) -> dict:
    """Observability tax (docs/OBSERVABILITY.md): the gated streaming
    workload — speech head, silent stretch (gated fills + wake replay),
    speech tail — run telemetry-off vs fully instrumented: metrics
    registry + flight recorder + launch auditor in **raise** mode +
    per-tick trace spans.

    Records into BENCH_obs.json: the decision streams are bit-identical
    (asserted, not just reported), min-of-``repeats`` wall time and
    us/tick for both modes, the overhead percentage, the auditor's
    launch accounting (zero violations, max one batched hop per tick),
    and recorder/metrics/trace volumes.  A second *mixed-traffic*
    section drives live inference + canary health windows + an
    enrollment session through one auditor-raise server, proving the
    one-fused-launch-per-IMC-layer contract holds with learning and
    canary traffic riding the same ticks.  The telemetry-on run's
    Perfetto timeline lands next to the JSON (``trace_out`` overrides
    the default results/trace_obs.json)."""
    import jax
    import numpy as np_
    from repro.core import faults as flt
    from repro.core.onchip_training import OnChipTrainConfig
    from repro.kernels import default_interpret
    from repro.models import kws as m
    from repro.serving import (CustomizeConfig, HealthConfig, ObsConfig,
                               StreamServer, VADConfig)

    cfg = m.KWSConfig(sample_len=sample_len)
    params = m.init_params(jax.random.PRNGKey(0), cfg)
    state = m.init_state(cfg)
    hw = m.fold_params(params, state, cfg, pack=True)
    imc_layers = cfg.num_conv_layers - 1

    # speech / silence / speech per stream: exercises init, batched hops,
    # gated fills and the wake replay in one drain
    n_hops = 20
    rng = np_.random.default_rng(0)
    streams = {}
    for i in range(slots):
        wav = rng.uniform(-1, 1, sample_len + n_hops * hop
                          ).astype(np_.float32)
        lo = sample_len + (5 + i % 2) * hop
        wav[lo:lo + 7 * hop] *= 1e-4
        streams[f"s{i}"] = wav
    vad = VADConfig(threshold_on_db=-40.0, threshold_off_db=-50.0,
                    wake_margin=1, hang=0)

    def run(ocfg):
        srv = StreamServer(hw, cfg, hop=hop, slots=slots, use_kernel=True,
                           vad=vad, obs=ocfg)
        for sid, wav in streams.items():
            srv.submit(sid, wav)
            srv.finish(sid)
        t0 = time.perf_counter()
        events = srv.drain()
        return srv, events, time.perf_counter() - t0

    obs_off = ObsConfig()
    obs_on = ObsConfig(recorder=512, audit="raise", trace=True)
    run(obs_off)                       # jit-trace warmup, untimed
    wall_off, wall_on = [], []
    for _ in range(repeats):
        _, ev_off, dt = run(obs_off)
        wall_off.append(dt)
        srv_on, ev_on, dt = run(obs_on)
        wall_on.append(dt)
    assert ev_off == ev_on, "telemetry changed the decision stream"
    ticks = srv_on._steps
    t_off, t_on = min(wall_off), min(wall_on)
    overhead = (t_on - t_off) / t_off * 100.0
    audit = srv_on.auditor.stats()
    assert audit["violations"] == 0, srv_on.auditor.violations
    trace_path = trace_out or os.path.normpath(
        os.path.join(RESULTS, "trace_obs.json"))
    if os.path.dirname(trace_path):
        os.makedirs(os.path.dirname(trace_path), exist_ok=True)
    n_spans = srv_on.trace.dump(trace_path)
    prom = srv_on.metrics.prometheus_text()

    # -- mixed traffic: inference + canary windows + an enrollment session
    srv = StreamServer(hw, cfg, hop=hop, slots=slots + 2, use_kernel=True,
                       vad=vad, faults=flt.FaultConfig(seed=5),
                       health=HealthConfig(interval=7),
                       obs=ObsConfig(recorder=512, audit="raise"), seed=3)
    sess = srv.customize("enrollee", CustomizeConfig(
        train=OnChipTrainConfig(epochs=8, fixed_error_scale=1.375),
        epochs_per_tick=4, layers_per_tick=5))
    for c in range(2):
        sess.enroll(c, rng.uniform(-1, 1, sample_len).astype(np_.float32))
    sess.finish_enrollment()
    for sid, wav in streams.items():
        srv.submit(sid, wav)
        srv.finish(sid)
    mixed_events = len(srv.drain())
    steps = 0
    while not sess.done and steps < 2000:
        srv.step()
        steps += 1
    assert sess.done, sess.phase
    mixed_audit = srv.auditor.stats()
    assert mixed_audit["violations"] == 0, srv.auditor.violations
    assert mixed_audit["max_hop_calls_per_tick"] <= 1

    report = {
        "backend": jax.default_backend(),
        "interpret": bool(default_interpret()),
        "window": sample_len,
        "hop": hop,
        "slots": slots,
        "hops_per_stream": n_hops,
        "repeats": repeats,
        "ticks": ticks,
        "bit_identical": True,
        "telemetry_off": {
            "wall_s": round(t_off, 4),
            "us_per_tick": round(t_off / ticks * 1e6, 1),
        },
        "telemetry_on": {
            "wall_s": round(t_on, 4),
            "us_per_tick": round(t_on / ticks * 1e6, 1),
            "recorder_events": len(srv_on.recorder),
            "recorder_dropped": srv_on.recorder.dropped(),
            "metrics_cells": len(srv_on.metrics.collect()),
            "prometheus_bytes": len(prom),
            "trace_spans": n_spans,
        },
        "overhead_pct": round(overhead, 2),
        "audit": {
            "imc_layers": imc_layers,
            "batched_calls": audit["calls"],
            "max_hop_calls_per_tick": audit["max_hop_calls_per_tick"],
            "violations": audit["violations"],
            "one_launch_per_imc_layer_per_call": True,
        },
        "mixed_traffic": {
            "decisions": mixed_events,
            "session_epochs": sess.result.epochs,
            "canaries": srv.health.canaries,
            "learn_hops": srv.stats()["learn_hops"],
            "batched_calls": mixed_audit["calls"],
            "max_hop_calls_per_tick": mixed_audit["max_hop_calls_per_tick"],
            "violations": mixed_audit["violations"],
        },
        "trace_artifact": os.path.relpath(trace_path,
                                          os.path.dirname(RESULTS)),
    }
    _row("obs_overhead_pct", "", f"{overhead:.2f}%")
    _row("obs_bit_identical", "", "True")
    _row("obs_audit", "",
         f"violations={audit['violations']};"
         f"max_hop_calls_per_tick={audit['max_hop_calls_per_tick']};"
         f"mixed_violations={mixed_audit['violations']}")
    _row("obs_trace", "", f"{trace_path};spans={n_spans}")
    out_path = _write_bench(
        report, out_path, "BENCH_obs.json",
        "PYTHONPATH=src python -m benchmarks.run --obs-overhead")
    _row("obs_json", "", out_path)
    return report


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--imc-fused", action="store_true",
                    help="run only the fused IMC layer benchmark and emit "
                         "BENCH_imc_fused.json")
    ap.add_argument("--imc-fused-out", default=None, metavar="PATH",
                    help="output path for BENCH_imc_fused.json "
                         "(default: results/BENCH_imc_fused.json)")
    ap.add_argument("--sample-len", type=int, default=None,
                    help="audio samples per decision window "
                         "(--imc-fused default 16000; --streaming 2000)")
    ap.add_argument("--batches", default=None, metavar="B1,B2,...",
                    help="batch sizes for the --imc-fused end-to-end sweep "
                         "(default 1,4,16)")
    ap.add_argument("--streaming", action="store_true",
                    help="run the always-on serving benchmark (streaming "
                         "vs recompute) and emit BENCH_streaming.json")
    ap.add_argument("--streaming-out", default=None, metavar="PATH",
                    help="output path for BENCH_streaming.json")
    ap.add_argument("--hop", type=int, default=256,
                    help="--streaming hop size in samples (default 256)")
    ap.add_argument("--stream-slots", type=int, default=4,
                    help="--streaming concurrent streams (default 4)")
    ap.add_argument("--stream-hops", type=int, default=6,
                    help="--streaming timed hops per stream (default 6)")
    ap.add_argument("--duty", type=float, default=0.2,
                    help="--streaming speech duty cycle of the gated "
                         "mixture (default 0.2)")
    ap.add_argument("--devices", type=int, default=1,
                    help="--streaming: also run the sharded serving "
                         "section — the same total stream load on one "
                         "device vs a ShardedStreamServer of N per-device "
                         "pools — and record decisions/sec scaling into "
                         "the BENCH_streaming.json 'sharded' section "
                         "(sets --xla_force_host_platform_device_count "
                         "on CPU hosts; real devices used when present)")
    ap.add_argument("--compiled", action="store_true",
                    help="--streaming: also run the whole-tick compiled "
                         "fast-path section — the same steady-state load "
                         "served by the interpreted Python tick vs "
                         "step_block's fused lax.scan dispatch, events "
                         "asserted bit-identical and the launch auditor "
                         "in raise mode — and record the decisions/sec "
                         "speedup into the BENCH_streaming.json "
                         "'compiled' section")
    ap.add_argument("--compiled-ticks", type=int, default=96,
                    help="--compiled timed steady-state ticks per side "
                         "(default 96)")
    ap.add_argument("--compiled-block", type=int, default=32,
                    help="--compiled ticks fused per dispatch "
                         "(CompiledTickConfig.block; default 32)")
    ap.add_argument("--customize", action="store_true",
                    help="run the enrollment-session customization "
                         "benchmark (utterances-to-recovered-accuracy + "
                         "uJ per fine-tune step) and emit "
                         "BENCH_customize.json")
    ap.add_argument("--customize-out", default=None, metavar="PATH",
                    help="output path for BENCH_customize.json")
    ap.add_argument("--customize-epochs", type=int, default=120,
                    help="--customize fine-tune epochs per session "
                         "(default 120)")
    ap.add_argument("--sessions", type=int, default=4,
                    help="--customize concurrent enrollment sessions "
                         "driven through ONE StreamServer (default 4, "
                         "minimum 2 — the record is part of the "
                         "BENCH_customize.json schema)")
    ap.add_argument("--faults", action="store_true",
                    help="run the fault-injection / self-healing benchmark "
                         "(drift, bit-flip and stuck scenarios through the "
                         "canary health monitor; accuracy clean/faulted/"
                         "healed + crash-safety snapshot record) and emit "
                         "BENCH_faults.json")
    ap.add_argument("--faults-out", default=None, metavar="PATH",
                    help="output path for BENCH_faults.json "
                         "(default: results/BENCH_faults.json)")
    ap.add_argument("--obs-overhead", action="store_true",
                    help="run the observability-tax benchmark (gated "
                         "streaming workload telemetry-off vs metrics + "
                         "recorder + auditor-raise + trace, bit-identity "
                         "asserted; plus a mixed inference/canary/learning "
                         "audit section) and emit BENCH_obs.json + a "
                         "Perfetto trace artifact")
    ap.add_argument("--obs-out", default=None, metavar="PATH",
                    help="output path for BENCH_obs.json "
                         "(default: results/BENCH_obs.json)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="with any single-bench flag: write a Chrome/"
                         "Perfetto trace-event timeline of the bench run "
                         "(server benches emit per-tick serving spans; "
                         "--imc-fused emits per-section timing spans)")
    args = ap.parse_args(argv)
    bench_flags = (args.imc_fused, args.streaming, args.customize,
                   args.faults, args.obs_overhead)
    if sum(bench_flags) > 1:
        ap.error("--imc-fused/--streaming/--customize/--faults/"
                 "--obs-overhead are separate runs; pick one")
    if args.trace_out is not None and not any(bench_flags):
        ap.error("--trace-out needs one of --imc-fused/--streaming/"
                 "--customize/--faults/--obs-overhead")
    if not args.obs_overhead and args.obs_out is not None:
        ap.error("--obs-out only applies with --obs-overhead")
    if not args.faults and args.faults_out is not None:
        ap.error("--faults-out only applies with --faults")
    if not args.imc_fused and (args.imc_fused_out is not None
                               or args.batches is not None):
        ap.error("--imc-fused-out/--batches only apply with --imc-fused")
    if not args.streaming and (args.streaming_out is not None
                               or args.hop != 256 or args.stream_slots != 4
                               or args.stream_hops != 6
                               or args.duty != 0.2 or args.devices != 1
                               or args.compiled):
        ap.error("--streaming-out/--hop/--stream-slots/--stream-hops/"
                 "--duty/--devices/--compiled only apply with --streaming")
    if not args.compiled and (args.compiled_ticks != 96
                              or args.compiled_block != 32):
        ap.error("--compiled-ticks/--compiled-block only apply with "
                 "--compiled")
    if args.devices < 1:
        ap.error("--devices must be >= 1")
    if args.devices > 1:
        # must land before the first jax import anywhere in the process:
        # the host-platform device count locks on backend initialization
        # (harmless on real multi-device backends — jax ignores the flag
        # off-CPU; appended last so it wins over an inherited setting)
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}")
    if not args.customize and (args.customize_out is not None
                               or args.customize_epochs != 120
                               or args.sessions != 4):
        ap.error("--customize-out/--customize-epochs/--sessions only "
                 "apply with --customize")
    if args.sample_len is not None and not any(bench_flags):
        ap.error("--sample-len only applies with "
                 "--imc-fused/--streaming/--customize/--faults/"
                 "--obs-overhead")
    global _TRACE
    if args.trace_out is not None and not args.obs_overhead:
        # --obs-overhead dumps its own telemetry-on server's builder;
        # every other bench shares one module-level builder
        from repro.obs import TraceBuilder
        _TRACE = TraceBuilder(process_name="benchmarks.run")

    def dump_trace():
        if _TRACE is not None:
            n = _TRACE.dump(args.trace_out)
            _row("trace_json", "", f"{args.trace_out};spans={n}")

    print("name,us_per_call,derived")
    if args.imc_fused:
        batches = tuple(int(b) for b in
                        (args.batches or "1,4,16").split(","))
        imc_fused_bench(args.imc_fused_out,
                        sample_len=args.sample_len or 16_000,
                        batches=batches)
        dump_trace()
        return
    if args.streaming:
        streaming_bench(args.streaming_out,
                        sample_len=args.sample_len or 2_000,
                        hop=args.hop, slots=args.stream_slots,
                        hops=args.stream_hops, duty=args.duty,
                        devices=args.devices, compiled=args.compiled,
                        compiled_ticks=args.compiled_ticks,
                        compiled_block=args.compiled_block)
        dump_trace()
        return
    if args.customize:
        customize_bench(args.customize_out,
                        sample_len=args.sample_len or 2_000,
                        epochs=args.customize_epochs,
                        sessions=args.sessions)
        dump_trace()
        return
    if args.faults:
        faults_bench(args.faults_out, sample_len=args.sample_len or 2_000)
        dump_trace()
        return
    if args.obs_overhead:
        obs_overhead_bench(args.obs_out,
                           sample_len=args.sample_len or 2_000,
                           trace_out=args.trace_out)
        return
    table2_model()
    table3_hw_constraints()
    table4_customization()
    table5_energy()
    dryrun_summary()
    kernel_bench()


if __name__ == "__main__":
    main()
