"""Benchmark harness: one function per paper table/figure + kernel
microbenchmarks + the dry-run roofline summary.

Prints ``name,us_per_call,derived`` CSV rows.  Expensive artifacts
(results/kws_results.json from benchmarks.kws_experiments,
results/dryrun_baseline.json from repro.launch.dryrun) are loaded if present;
the table functions degrade to "run benchmarks.kws_experiments first"
markers instead of silently re-running multi-minute jobs.

Run:  PYTHONPATH=src python -m benchmarks.run
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def _load(name):
    path = os.path.join(RESULTS, name)
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return None


def _row(name, us, derived):
    print(f"{name},{us},{derived}")


# ---------------------------------------------------------------------------
# Paper tables
# ---------------------------------------------------------------------------


def table2_model() -> None:
    """Paper Table II: ideal-model accuracy / parameters / model size."""
    r = _load("kws_results.json")
    if not r:
        _row("table2_model", "", "MISSING:run benchmarks.kws_experiments")
        return
    t = r["table2"]
    _row("table2_accuracy", "", f"{t['accuracy']:.4f}(paper:0.9083)")
    _row("table2_parameters", "", f"{t['parameters']}(paper:125K)")
    _row("table2_model_bits", "", f"{t['model_bits']}(paper:171K)")


def table3_hw_constraints() -> None:
    """Paper Table III: ideal -> FC-quant -> BN-constraints -> +noise ->
    +compensation -> +fine-tune."""
    r = _load("kws_results.json")
    if not r:
        _row("table3_hw_constraints", "",
             "MISSING:run benchmarks.kws_experiments")
        return
    t = r["table3"]
    for key in ("ideal", "fc_quantized", "bn_constraints", "mav_sa_noise",
                "bias_compensation", "compensation_finetune"):
        _row(f"table3_{key}", "",
             f"{t[key]:.4f}(paper:{t['paper'][key]:.4f})")


def table4_customization() -> None:
    """Paper Table IV: customization ablation on the personal set."""
    r = _load("kws_results.json")
    if not r:
        _row("table4_customization", "",
             "MISSING:run benchmarks.kws_experiments")
        return
    t = r["table4"]
    _row("table4_before_customization", "",
         f"{t['before_customization']:.4f}")
    for key in ("baseline_fp", "quantized_naive", "error_scaling", "es_sga",
                "es_sga_rgp"):
        _row(f"table4_{key}", "",
             f"{t[key]:.4f}(paper:{t['paper'][key]:.4f})")


def table5_energy() -> None:
    """Paper Fig 14/Table V: energy/latency/TOPS-W analytical chip model."""
    from repro.core.energy import kws_chip_report, training_energy_j
    from repro.models.kws import PAPER_KWS, layer_stats

    stats = layer_stats(PAPER_KWS)
    for freq, tag in ((1e6, "1MHz"), (1e8, "100MHz")):
        rep = kws_chip_report(stats, freq_hz=freq)
        _row(f"table5_energy_per_decision_{tag}", "",
             f"{rep.energy_j_per_decision * 1e6:.2f}uJ"
             + ("(paper:~14.3uJ)" if tag == "1MHz" else "(paper:~4.5uJ)"))
        _row(f"table5_power_{tag}", "",
             f"{rep.power_w * 1e6:.1f}uW"
             + ("(paper:89.5uW)" if tag == "1MHz" else "(paper:2833uW)"))
        _row(f"table5_tops_per_w_{tag}", "",
             f"{rep.tops_per_w:.1f}(paper:23.6-68)")
    _row("table5_latency", "", f"{kws_chip_report(stats).latency_s*1e3:.0f}ms"
         "(paper:160ms@1MHz)")
    e_train = training_energy_j(num_epochs=1, macs_per_epoch=90 * 586 * 10,
                                lut_ops=90 * 10, div_ops=90 * 10,
                                sram_bits=90 * 576 * 8)
    _row("table5_training_energy_per_epoch", "", f"{e_train*1e6:.1f}uJ")


def dryrun_summary() -> None:
    """Deliverable e/g: the 40-cell x 2-mesh dry-run + roofline terms."""
    rs = _load("dryrun_baseline.json")
    if not rs:
        _row("dryrun", "", "MISSING:run repro.launch.dryrun")
        return
    ok = sum(1 for r in rs if r.get("status") == "ok")
    skip = sum(1 for r in rs if r.get("status") == "skip")
    err = sum(1 for r in rs if r.get("status") == "error")
    _row("dryrun_cells", "", f"ok={ok};skip={skip};error={err}")
    for r in rs:
        if r.get("status") != "ok" or r.get("multi_pod"):
            continue
        ro = r["roofline"]
        _row(f"roofline_{r['arch']}_{r['shape']}", "",
             f"dom={ro['dominant']};comp={ro['compute_s']:.4f}s;"
             f"mem={ro['memory_s']:.4f}s;coll={ro['collective_s']:.4f}s;"
             f"frac={ro['roofline_fraction']:.3f};"
             f"frac_serial={ro.get('roofline_fraction_serial', 0):.3f}")


# ---------------------------------------------------------------------------
# Kernel microbenchmarks (CPU interpret mode: correctness-grade timings)
# ---------------------------------------------------------------------------


def _time_us(fn, *args, iters: int = 5) -> float:
    import jax
    fn(*args)                      # compile + warmup
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def kernel_bench() -> None:
    """us/call for each Pallas kernel vs its jnp oracle (interpret mode on
    CPU measures dispatch+semantics, not TPU perf — the BlockSpecs encode
    the TPU tiling; see DESIGN.md §3)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.imc_mav import ops as mav_ops
    from repro.kernels.imc_mav.ref import imc_mav_ref
    from repro.kernels.int8_matmul.int8_matmul import int8_matmul
    from repro.kernels.int8_matmul.ref import int8_matmul_ref
    from repro.kernels.sga_update.sga_update import sga_update
    from repro.kernels.sga_update.ref import sga_update_ref

    k = jax.random.PRNGKey(0)
    x = jnp.where(jax.random.bernoulli(k, 0.5, (512, 128)), 1.0, -1.0)
    w = jnp.where(jax.random.bernoulli(k, 0.5, (128, 128)), 1.0, -1.0)
    bias = jnp.zeros((128,))
    flip = jnp.ones((128,))
    us = _time_us(lambda: mav_ops.mav_matmul(x, w, bias, flip))
    us_ref = _time_us(jax.jit(lambda: imc_mav_ref(x, w, bias, flip)))
    _row("kernel_imc_mav_512x128x128", f"{us:.0f}", f"ref_us={us_ref:.0f}")

    xq = jax.random.randint(k, (512, 128), -127, 128, jnp.int8)
    wq = jax.random.randint(k, (128, 128), -127, 128, jnp.int8)
    bq = jnp.zeros((128,), jnp.int32)
    us = _time_us(lambda: int8_matmul(xq, wq, bq, shift=7))
    us_ref = _time_us(jax.jit(lambda: int8_matmul_ref(xq, wq, bq, shift=7)))
    _row("kernel_int8_matmul_512x128x128", f"{us:.0f}",
         f"ref_us={us_ref:.0f}")

    n = 8192
    wv = jax.random.uniform(k, (n,), minval=-1, maxval=1)
    gv = jax.random.normal(k, (n,)) * 0.01
    av = jnp.zeros((n,))
    us = _time_us(lambda: sga_update(wv, gv, av, lr=1 / 16, g_th=0.078125))
    us_ref = _time_us(jax.jit(
        lambda: sga_update_ref(wv, gv, av, 1 / 16, 0.078125)))
    _row("kernel_sga_update_8192", f"{us:.0f}", f"ref_us={us_ref:.0f}")


def main() -> None:
    print("name,us_per_call,derived")
    table2_model()
    table3_hw_constraints()
    table4_customization()
    table5_energy()
    dryrun_summary()
    kernel_bench()


if __name__ == "__main__":
    main()
