"""Generate EXPERIMENTS.md from results/*.json (single source of truth).

Run:  PYTHONPATH=src python -m benchmarks.make_experiments_md
"""

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")
OUT = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")


def load(name):
    p = os.path.join(RESULTS, name)
    return json.load(open(p)) if os.path.exists(p) else None


def pct(x):
    return f"{100 * x:.2f}%"


def main():
    kws = load("kws_results.json")
    dr = load("dryrun_baseline.json")
    hc = load("hillclimb.json")
    L = []
    w = L.append

    w("# EXPERIMENTS — IMC-KWS reproduction + multi-pod framework results\n")
    w("All numbers produced by this repo on the CPU container "
      "(`results/*.json`); regenerate with the commands in each section.\n")

    # ---------------- Repro ----------------
    w("\n## §Repro — paper tables on the synthetic GSCD stand-in\n")
    w("Dataset caveat (DESIGN.md §4): GSCD and the authors' private personal"
      " set are unavailable offline; a synthetic keyword corpus with the"
      " same structure is used, so absolute accuracies differ from the"
      " paper — the ablation STRUCTURE (what each technique"
      " contributes) is the reproduction target.  Training recipe:"
      " annealed binarization (tanh alpha: 2->5->12) with a final"
      " hard-forward/surrogate-gradient phase that trains THROUGH the exact"
      " in-memory bias grid, so the deployed (folded) model is bit-identical"
      " to the training forward.  Command:"
      " `PYTHONPATH=src python -m benchmarks.kws_experiments`.\n")
    if kws:
        t2 = kws["table2"]
        w("\n### Table II — model\n")
        w("| metric | ours | paper |")
        w("|---|---|---|")
        w(f"| ideal accuracy | {pct(t2['accuracy'])} | 90.83% |")
        w(f"| parameters | {t2['parameters']:,} | ~125K |")
        w(f"| model size (bits) | {t2['model_bits']:,} | ~171K |")

        t3 = kws["table3"]
        w("\n### Table III — hardware-constraint ablation\n")
        w("| condition | ours | paper |")
        w("|---|---|---|")
        rows = [("ideal (unconstrained fold)", "ideal"),
                ("+ FC quantized (8b)", "fc_quantized"),
                ("+ BN constraints (even, [-64,64])", "bn_constraints"),
                ("+ MAV offset + SA variation", "mav_sa_noise"),
                ("+ bias compensation", "bias_compensation"),
                ("+ noise-aware fine-tune", "compensation_finetune")]
        for label, key in rows:
            w(f"| {label} | {pct(t3[key])} | {pct(t3['paper'][key])} |")
        w("\nNotes: (i) noise uses MAV offset std 8 counts + SA std 1,"
          " averaged over "
          f"{len(t3.get('mav_sa_noise_per_seed', []))} chip seeds"
          " (Monte-Carlo, as §IV-B); (ii) our 'ideal' (constraint-free fold)"
          " scores BELOW the constrained row because the final training"
          " phase optimizes the exact constrained forward — the paper's"
          " claim that the BN grid costs little holds a fortiori: the"
          " deployed constrained model is the best one; (iii) compensation"
          " uses the chip test mode (layer-local matched-input measurement,"
          " Fig 8) — chaining corrupted activations instead degrades the"
          " per-channel estimate to uselessness (est err ~6 of std 8),"
          " which we verified explicitly (§Perf-style refuted-hypothesis"
          " log in git history).\n")

        t4 = kws["table4"]
        w("### Table IV — on-chip customization (personal set)\n")
        w("| variant | ours | paper |")
        w("|---|---|---|")
        w(f"| before customization | {pct(t4['before_customization'])}"
          " | 51.08%* |")
        for label, key in [("full-precision baseline", "baseline_fp"),
                           ("quantized naive", "quantized_naive"),
                           ("+ error scaling", "error_scaling"),
                           ("+ SGA", "es_sga"),
                           ("+ RGP (lambda=8)", "es_sga_rgp")]:
            w(f"| {label} | {pct(t4[key])} | {pct(t4['paper'][key])} |")
        w("\n*paper's before-customization number is the noisy-chip accuracy"
          " on its own test set.\n")

        w("### Fig 3 — trained thresholds (beta+offset) per layer\n")
        w("`" + json.dumps({k: round(v, 3)
                            for k, v in kws["fig3"].items()}) + "`\n")
        w("### Fig 7 — BN bias distribution\n")
        f7 = kws["fig7"]
        w(f"bias mean {f7['bias_mean']:.2f}, std {f7['bias_std']:.2f}, "
          f"fraction inside [-64,64]: {pct(f7['fraction_in_range'])} "
          "(paper: 'most of the BN bias does not exceed the limitation')\n")

    w("\n### Table V / Fig 14 — chip energy model\n")
    w("Analytical model calibrated to the paper's anchors"
      " (`benchmarks/run.py table5`): 14.7uJ/decision @1MHz (paper ~14.3),"
      " 91.9uW (paper 89.5), 4.9uJ @100MHz (paper ~4.5), 17-51 TOPS/W"
      " (paper 23.6-68), latency 160ms @1MHz (paper 160ms).\n")

    # ---------------- Dry-run ----------------
    w("\n## §Dry-run — 40 cells x 2 meshes (deliverable e)\n")
    if dr:
        ok = sum(1 for r in dr if r.get("status") == "ok")
        skip = sum(1 for r in dr if r.get("status") == "skip")
        err = sum(1 for r in dr if r.get("status") == "error")
        w(f"`python -m repro.launch.dryrun --arch all --shape all"
          f" --both-meshes`: **{ok} ok / {skip} skip / {err} error**.")
        w("Skips = long_500k on the 8 pure full-attention archs (sub-"
          "quadratic requirement, DESIGN.md §6) x 2 meshes; every skip is"
          " listed below.  Every `ok` cell lowered AND compiled with"
          " explicit in/out shardings on BOTH the 16x16 (256-chip) and the"
          " 2x16x16 (512-chip) mesh; per-device peak memory from"
          " `compiled.memory_analysis()` is <16GB HBM for every cell"
          " (max: mistral-large-123b decode_32k at "
          "13.9GB).\n")
        skips = sorted({(r["arch"], r["shape"]) for r in dr
                        if r.get("status") == "skip"})
        w("Skipped cells: " + ", ".join(f"{a} x {s}" for a, s in skips)
          + "\n")

        # ------------- Roofline -------------
        w("\n## §Roofline — per (arch x shape), single-pod 16x16 baseline\n")
        w("Terms per DESIGN.md §8 (v5e: 197 TFLOP/s bf16, 819 GB/s HBM,"
          " 50 GB/s ICI).  `frac` = useful-compute / max(terms) (perfect"
          " overlap); `frac_serial` = useful-compute / sum(terms)."
          "  Collective bytes parsed from post-SPMD HLO with while-body"
          " trip-count multiplication; XLA `cost_analysis` does not"
          " multiply scan bodies, so analytic FLOPs (exact params x"
          " standard terms) are primary — the two agree within 2-5% on"
          " unrolled test modules.\n")
        w("| arch | shape | dominant | compute_s | memory_s | collective_s"
          " | frac | frac_serial | peak GB | useful/HLO |")
        w("|---|---|---|---|---|---|---|---|---|---|")
        for r in dr:
            if r.get("status") != "ok" or r.get("multi_pod"):
                continue
            ro = r["roofline"]
            pk = (r.get("memory_analysis") or {}).get("peak_bytes")
            w(f"| {r['arch']} | {r['shape']} | {ro['dominant']} "
              f"| {ro['compute_s']:.4f} | {ro['memory_s']:.4f} "
              f"| {ro['collective_s']:.4f} | {ro['roofline_fraction']:.3f} "
              f"| {ro['roofline_fraction_serial']:.3f} "
              f"| {pk / 1e9:.2f} | {ro['useful_ratio']:.3f} |")
        w("\nPer-cell bottleneck notes: train cells of the four DENSE archs"
          " are compute-dominant (frac 0.96-0.99 overlapped) — the lever"
          " is overlapping the remaining FSDP gathers;  MoE and small-model"
          " train cells are collective-dominant (expert/dispatch traffic,"
          " FSDP on tiny params) — §Perf cells 1-2 attack exactly these;"
          " decode cells are collective/memory-bound as expected (weights"
          " + KV reads per token), §Perf cell 3.  Multi-pod (2x16x16) rows"
          " compile identically with the `pod` axis carrying cross-pod DP;"
          " per-cell records in results/dryrun_baseline.json.\n")

    # ---------------- Perf ----------------
    w("\n## §Perf — hillclimb log (hypothesis -> change -> measure)\n")
    w("Paper-faithful BASELINE first (the table above), then beyond-paper"
      " optimization.  Three cells per the assignment; every iteration"
      " recorded, including refuted hypotheses.  Command:"
      " `PYTHONPATH=src python -m benchmarks.hillclimb`.\n")
    if hc:
        w("| cell | iteration | compute_s | memory_s | collective_s |"
          " frac_serial | peak GB |")
        w("|---|---|---|---|---|---|---|")
        for r in hc:
            w(f"| {r['arch']} x {r['shape']} | {r['label']} "
              f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} "
              f"| {r['collective_s']:.4f} | {r['frac_serial']:.3f} "
              f"| {r['peak_gb']:.2f} |")
    w("\nNarrative per cell is inline in benchmarks/hillclimb.py and"
      " summarized in README §Performance.\n")

    with open(OUT, "w") as f:
        f.write("\n".join(L) + "\n")
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
