import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.checkpoint.profiles import ProfileStore
from repro.launch.train import train_loop


def _tree_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_last=2)
    params = {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.zeros(3)}
    opt = {"mu": jax.tree_util.tree_map(jnp.zeros_like, params),
           "step": jnp.int32(7)}
    ck.save(10, params, opt, data_step=10, rng_key=jax.random.PRNGKey(1))
    got = ck.restore(params, opt)
    assert got is not None
    p2, o2, meta = got
    assert _tree_equal(params, p2) and _tree_equal(opt, o2)
    assert meta["step"] == 10 and meta["data_step"] == 10


def test_retention_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_last=2)
    params = {"w": jnp.ones(3)}
    for s in (1, 2, 3, 4):
        ck.save(s, params, params, data_step=s,
                rng_key=jax.random.PRNGKey(0))
    assert ck.all_steps() == [3, 4]
    assert ck.latest_step() == 4


def test_no_partial_checkpoint_on_failure(tmp_path):
    """Atomicity: a tmp dir never counts as a checkpoint."""
    ck = Checkpointer(str(tmp_path))
    os.makedirs(os.path.join(str(tmp_path), "step_00000099"))
    # missing meta.json -> not listed
    assert ck.all_steps() == []


def test_profile_store_roundtrip_and_hygiene(tmp_path):
    """Customization profiles: lossless array round trip, overwrite,
    listing, deletion, id validation, and partial dirs never listed.
    (The serving-level restart bit-equality lives in
    tests/test_customize.py.)"""
    from repro.serving.customize import CustomizationResult

    rng = np.random.default_rng(0)
    res = CustomizationResult(
        bias={"conv1": rng.integers(-64, 65, 96).astype(np.float32),
              "conv2": rng.integers(-64, 65, 192).astype(np.float32)},
        fc_w=(rng.integers(-128, 128, (576, 10)) / 128.0
              ).astype(np.float32),
        fc_b=np.zeros(10, np.float32), epochs=120, n_utterances=10,
        history=[{"epoch": 120, "train_accuracy": 1.0}],
        energy={"uj_per_finetune_step": 48.0})
    store = ProfileStore(str(tmp_path))
    assert store.list() == [] and store.latest() is None
    store.save("alice", res)
    got = store.load("alice")
    for k in res.bias:
        np.testing.assert_array_equal(got.bias[k], res.bias[k])
    np.testing.assert_array_equal(got.fc_w, res.fc_w)
    np.testing.assert_array_equal(got.fc_b, res.fc_b)
    assert (got.epochs, got.n_utterances) == (120, 10)
    assert got.history == res.history and got.energy == res.energy
    store.save("alice", res)                      # overwrite is atomic
    store.save("bob-2", res)
    assert store.list() == ["alice", "bob-2"]
    # latest follows the monotonic save counter, not mtime (coarse-mtime
    # filesystems give back-to-back saves identical timestamps)
    assert store.latest() == "bob-2"
    # crash leftovers / foreign entries never count as profiles: a stray
    # tmp file from an interrupted save and a non-profile directory
    with open(os.path.join(str(tmp_path), ".tmp.profile.xyz.npz"),
              "wb") as f:
        f.write(b"partial")
    os.makedirs(os.path.join(str(tmp_path), "broken"))
    assert store.list() == ["alice", "bob-2"]
    assert store.delete("alice") and not store.exists("alice")
    assert not store.delete("alice")
    with pytest.raises(ValueError):
        store.save("../escape", res)
    with pytest.raises(FileNotFoundError):
        store.load("nobody")


@pytest.mark.slow
def test_fault_tolerant_resume_matches_uninterrupted(tmp_path):
    """Train 12 steps straight vs (fail at 8 -> restart): same final loss.
    This is the checkpoint/restart deliverable end-to-end."""
    kw = dict(reduced=True, batch=4, seq=32, log_every=100)
    _, straight = train_loop("qwen2.5-14b", 12,
                             ckpt_dir=str(tmp_path / "a"), ckpt_every=4,
                             **kw)
    with pytest.raises(RuntimeError, match="simulated node failure"):
        train_loop("qwen2.5-14b", 12, ckpt_dir=str(tmp_path / "b"),
                   ckpt_every=4, fail_at=9, **kw)
    _, resumed = train_loop("qwen2.5-14b", 12, ckpt_dir=str(tmp_path / "b"),
                            ckpt_every=4, **kw)
    assert abs(straight["loss"] - resumed["loss"]) < 1e-4
