"""The fault-injection / self-healing / crash-safety contract:

* the fault model (repro.core.faults) is seeded-deterministic, resumable
  from its own snapshot, and its deltas ride the existing batched-launch
  operands — a faulted server equals a clean server whose jnp reference
  path is fed the same per-layer bias deltas, bit for bit;
* the one-launch-per-layer invariant HOLDS under fault + canary: a tick
  whose batch carries live hops and a canary hop still traces exactly one
  pallas_call per IMC layer (trace-enforced);
* canary health monitoring detects an injected fault within ticks,
  localizes the faulty layer and columns (in bias-channel coordinates —
  the injection's own coordinates), and walks healthy -> degraded ->
  quarantined; recompensation heals drift faults back to healthy;
  unrecoverable stuck columns are permanently masked and written into the
  expected reference so the monitor converges instead of flapping;
* snapshot/restore round-trips the COMPLETE serving state — slot carries,
  GAP rings, decision/VAD state, noise-field keys, fault + health state,
  mid-flight customization sessions — and the restored server continues
  bit-identically (events and states) to an uninterrupted run;
* satellites: profile auto-install at admission + stale-profile eviction
  on store mtime change; duty-aware dynamic hop (silence widens faster,
  forced-speech bit-exactness preserved); chip-accurate retention silence
  fill (pinned to the constant fill at zero read noise); a randomized
  soak interleaving admissions/evictions/faults/snapshots.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl

import _equiv as eq
from repro.core import faults as flt
from repro.core import imc
from repro.models import kws as m
from repro.serving import (DynamicHopConfig, HealthConfig, StreamServer,
                           VADConfig)
from repro.serving import customize as cz
from repro.serving import stream as sv
from repro.serving import vad as vd
from repro.checkpoint.profiles import ProfileStore

L, HOP = 640, 64
CFG = m.KWSConfig(sample_len=L)


@pytest.fixture(scope="module")
def folded():
    params = m.init_params(jax.random.PRNGKey(5), CFG)
    state = m.init_state(CFG)
    return m.fold_params(params, state, CFG, pack=True)


def _chip(std=4.0):
    chans = {f"conv{i}": CFG.channels[i]
             for i in range(1, CFG.num_conv_layers)}
    return imc.sample_chip_offsets(
        jax.random.PRNGKey(9), chans,
        imc.IMCNoiseParams(mav_offset_std=std))


def _result(hw, bump_layer=None, bump=1.0):
    """A synthetic CustomizationResult: the base fold's own arrays, with
    an optional integer bias bump on one layer (a visible rider)."""
    hwp, _ = m.as_hw_params(hw)
    bias = {n: np.asarray(hwp.bias[n], np.int32).copy()
            for n in CFG.imc_layer_names()}
    if bump_layer is not None:
        bias[bump_layer] = bias[bump_layer] + int(bump)
    return cz.CustomizationResult(
        bias=bias, fc_w=np.asarray(hwp.fc_w), fc_b=np.asarray(hwp.fc_b),
        epochs=1, n_utterances=2, history=[], energy={})


def _delta_result(hw, deltas):
    """The base fold's arrays with per-layer integer deltas folded into
    the biases — the 'same fault, via the profile rider path' result."""
    hwp, _ = m.as_hw_params(hw)
    bias = {n: np.asarray(hwp.bias[n], np.int32)
            + np.asarray(deltas[n], np.int32)
            for n in CFG.imc_layer_names()}
    return cz.CustomizationResult(
        bias=bias, fc_w=np.asarray(hwp.fc_w), fc_b=np.asarray(hwp.fc_b),
        epochs=1, n_utterances=2, history=[], energy={})


# ---------------------------------------------------------------------------
# Fault model (repro.core.faults)
# ---------------------------------------------------------------------------


def test_fault_model_deterministic_and_resumable():
    """Same seed + same injection sequence => identical deltas at every
    step; a model restored from a mid-run snapshot continues identically
    (drift is keyed by absolute step, not by accumulated RNG state)."""
    def build():
        return flt.FaultModel.for_config(
            CFG, flt.FaultConfig(drift_std=0.3, seed=7))

    a, b = build(), build()
    for t in range(5):
        a.tick()
        b.tick()
    a.inject_bit_flips(n=3)
    b.inject_bit_flips(n=3)
    a.inject_stuck("conv2", [1, 4], value=-1)
    b.inject_stuck("conv2", [1, 4], value=-1)
    snap = a.snapshot()
    for t in range(5):
        a.tick()
        b.tick()
    da, db = a.deltas(), b.deltas()
    for name in da:
        assert np.array_equal(da[name], db[name]), name

    c = build()
    c.restore(snap)
    assert c.pop_dirty()
    for t in range(5):
        c.tick()
    dc = c.deltas()
    for name in da:
        assert np.array_equal(da[name], dc[name]), name


def test_fault_model_delta_composition():
    """Stuck rails pin at +/-stuck_magnitude; macro dropout is a stuck
    range; bit flips land on single (layer, channel) cells with
    power-of-two magnitudes; clear() returns to inactive."""
    f = flt.FaultModel.for_config(CFG, flt.FaultConfig(seed=1))
    assert not f.active
    f.inject_stuck("conv3", [2], value=1)
    f.inject_macro_dropout("conv1", start=8, width=4)
    d = f.deltas()
    assert d["conv3"][2] == f.fcfg.stuck_magnitude
    assert np.all(d["conv1"][8:12] == -f.fcfg.stuck_magnitude)
    assert np.all(d["conv1"][:8] == 0)
    mask = f.stuck_mask()
    assert mask["conv1"].sum() == 4 and mask["conv3"].sum() == 1
    f.inject_bit_flips(n=2, layer="conv4")
    d = f.deltas()
    nz = np.nonzero(d["conv4"])[0]
    assert 1 <= nz.size <= 2
    for c in nz:
        assert abs(d["conv4"][c]) in {
            f.fcfg.flip_magnitude * (1 << b)
            for b in range(f.fcfg.flip_bits)}
    f.clear()
    assert not f.active and f.pop_dirty()


@pytest.mark.streaming
def test_faulted_server_bitexact_vs_delta_riders(folded):
    """Faults ARE bias-delta riders on the existing operands: a server
    with the fault model active is bit-identical (events and state
    leaves) to a CLEAN server serving the same deltas through the
    per-stream customization rider path — and both differ from pristine."""
    hw = folded
    offs = _chip()
    rng = np.random.default_rng(2)
    wav = rng.uniform(-1, 1, L + 5 * HOP).astype(np.float32)

    srv = StreamServer(hw, CFG, hop=HOP, slots=2, use_kernel=False,
                       chip_offsets=offs, sa_noise_std=1.5, seed=11)
    srv_f = StreamServer(hw, CFG, hop=HOP, slots=2, use_kernel=False,
                         chip_offsets=offs, sa_noise_std=1.5, seed=11,
                         faults=flt.FaultConfig(seed=3))
    srv_f.faults.inject_stuck("conv2", [0, 5])
    srv_f.faults.inject_bit_flips(n=2)      # integer-valued deltas
    deltas = srv_f.faults.deltas()

    # clean server, same deltas folded into an installed profile
    srv.install_custom("a", _delta_result(hw, deltas))
    srv.submit("a", wav)
    srv_f.submit("a", wav)
    ev_rider, ev_fault = srv.drain(), srv_f.drain()
    assert len(ev_fault) == 6
    eq.assert_events_equal(ev_rider, ev_fault, "rider vs fault")
    eq.assert_leaves_equal(srv._state, srv_f._state, "rider vs fault")

    clean = StreamServer(hw, CFG, hop=HOP, slots=2, use_kernel=False,
                         chip_offsets=offs, sa_noise_std=1.5, seed=11)
    clean.submit("a", wav)
    ev_clean = clean.drain()
    assert [e["score"] for e in ev_fault] != [e["score"] for e in ev_clean]


@pytest.mark.streaming
def test_one_launch_per_layer_under_fault_and_canary(folded, monkeypatch):
    """The tentpole invariant under fault: a tick whose batch carries live
    hops AND a canary hop, on a faulted chip, still traces exactly ONE
    pallas_call per IMC layer."""
    hw = folded
    srv = StreamServer(hw, CFG, hop=HOP, slots=4, use_kernel=True,
                       faults=flt.FaultConfig(drift_std=0.2, seed=3),
                       health=HealthConfig(interval=6))
    srv.faults.inject_bit_flips(n=2)
    rng = np.random.default_rng(0)
    for i in range(2):
        srv.submit(f"s{i}", rng.uniform(-1, 1, L + 16 * HOP)
                   .astype(np.float32))
    for _ in range(10):              # admission wave, then canary spawn
        srv.step()
        if any(rec.internal for rec in srv._streams.values()):
            break
    assert any(rec.internal for rec in srv._streams.values()), \
        "canary should have been submitted"
    srv.step()                       # canary init rides the admission wave
    # next tick: live hops + the canary's hop share ONE batched call
    jax.clear_caches()
    calls = []
    real = pl.pallas_call

    def counting(*args, **kwargs):
        calls.append(kwargs.get("grid"))
        return real(*args, **kwargs)

    monkeypatch.setattr(pl, "pallas_call", counting)
    srv.step()
    monkeypatch.setattr(pl, "pallas_call", real)
    assert len(calls) == CFG.num_conv_layers - 1, calls
    assert srv.health.canaries >= 1


# ---------------------------------------------------------------------------
# Canary detection, localization, healing
# ---------------------------------------------------------------------------


def _run(srv, rng, n, sid="a"):
    for _ in range(n):
        srv.submit(sid, rng.standard_normal(HOP).astype(np.float32))
        srv.step()


@pytest.mark.streaming
def test_canary_detects_localizes_and_masks_stuck(folded):
    """A stuck column fails canaries within ~2 intervals, is localized to
    the injected layer AND channels (bias-channel coordinates), cannot
    heal (the bias clip saturates), gets permanently masked, and the
    monitor returns to healthy with the write-off recorded."""
    hw = folded
    srv = StreamServer(hw, CFG, hop=HOP, slots=3, use_kernel=False,
                       faults=flt.FaultConfig(seed=3),
                       health=HealthConfig(interval=4, layers_per_tick=2))
    rng = np.random.default_rng(0)
    srv.submit("a", rng.standard_normal(L).astype(np.float32))
    _run(srv, rng, 12)
    assert srv.health.state == "healthy" and srv.health.canaries >= 1
    assert srv.health.failed_canaries == 0

    srv.faults.inject_stuck("conv3", [2, 7])
    injected_tick = srv._steps
    _run(srv, rng, 60)
    h = srv.health.stats()
    assert h["state"] == "healthy"
    assert h["masked_channels"] == {"conv3": [2, 7]}
    assert h["recoveries"] >= 1
    states = [e["state"] for e in h["history"]]
    assert states[:1] == ["healthy"]
    assert ["degraded", "quarantined", "recovering"] == [
        s for s in states if s != "healthy"][:3]
    # detection latency: within ~2 canary intervals of injection
    assert h["detected_tick"] - injected_tick <= 2 * 4 + 2


@pytest.mark.streaming
def test_drift_fault_heals_back_to_healthy(folded):
    """A large uniform offset drift is detected, recompensated through the
    chip-global rider, and the monitor returns to healthy with zero
    post-heal divergence — the self-healing loop closes."""
    hw = folded
    srv = StreamServer(hw, CFG, hop=HOP, slots=3, use_kernel=False,
                       chip_offsets=_chip(),
                       faults=flt.FaultConfig(seed=3),
                       health=HealthConfig(interval=4))
    rng = np.random.default_rng(0)
    srv.submit("a", rng.standard_normal(L).astype(np.float32))
    _run(srv, rng, 12)
    srv.faults._drift["conv2"][:] = 40.0
    srv.faults._dirty = True
    _run(srv, rng, 60)
    h = srv.health.stats()
    assert h["state"] == "healthy"
    assert h["recoveries"] == 1
    assert h["masked_channels"] == {}
    assert all(v == 0.0 for v in h["divergence"].values())
    assert h["recovery_energy_uj"] > 0
    # the heal rides the chip-global delta, not the per-slot rows
    assert srv._heal_delta is not None and "conv2" in srv._heal_delta
    # events emitted while degraded/quarantined carried the flag
    srv.submit("a", rng.standard_normal(HOP).astype(np.float32))
    ev = srv.step()
    assert all(e["degraded"] is False for e in ev)


@pytest.mark.streaming
def test_canaries_pause_without_live_traffic(folded):
    """No live stream -> no canary spawns (drain terminates); traffic
    resumes -> canaries resume."""
    hw = folded
    srv = StreamServer(hw, CFG, hop=HOP, slots=2, use_kernel=False,
                       health=HealthConfig(interval=1))
    for _ in range(5):
        srv.step()
    assert srv.health.canaries == 0
    rng = np.random.default_rng(0)
    srv.submit("a", rng.standard_normal(L + 4 * HOP).astype(np.float32))
    _run(srv, rng, 6)
    assert srv.health.canaries >= 1
    srv.evict("a")
    srv.drain()                         # must terminate


# ---------------------------------------------------------------------------
# Crash safety: snapshot / restore
# ---------------------------------------------------------------------------


@pytest.mark.streaming
def test_snapshot_restore_bit_identical(folded, tmp_path):
    """Snapshot to disk mid-run (faults + health + VAD + SA noise + chip
    offsets active), restore into a freshly constructed server, and both
    servers' next 12 ticks produce identical events and identical state
    leaves — the restart is invisible."""
    hw = folded
    chip = _chip()

    def mk():
        return StreamServer(hw, CFG, hop=HOP, slots=3, use_kernel=False,
                            chip_offsets=chip, sa_noise_std=2.0,
                            vad=VADConfig(),
                            faults=flt.FaultConfig(drift_std=0.2, seed=3),
                            health=HealthConfig(interval=4), seed=7)

    rng = np.random.default_rng(0)
    srv = mk()
    srv.submit("a", rng.standard_normal(L + HOP).astype(np.float32))
    srv.submit("b", (0.001 * rng.standard_normal(L + HOP))
               .astype(np.float32))
    for _ in range(8):
        srv.submit("a", rng.standard_normal(HOP).astype(np.float32))
        srv.step()
    srv.faults.inject_bit_flips(n=2)

    path = os.fspath(tmp_path / "server.npz")
    assert srv.snapshot(path) == path
    future = [rng.standard_normal(HOP).astype(np.float32)
              for _ in range(12)]

    def play(s):
        evs = []
        for ch in future:
            s.submit("a", ch)
            s.submit("b", 0.001 * ch)
            evs.extend(s.step())
        return evs

    ev1 = play(srv)

    srv2 = mk()
    srv2.restore(path)
    ev2 = play(srv2)
    eq.assert_events_equal(ev1, ev2, "restored vs uninterrupted")
    eq.assert_leaves_equal(srv._state, srv2._state,
                           "restored vs uninterrupted")
    assert srv.health.stats() == srv2.health.stats()
    assert srv.faults.stats() == srv2.faults.stats()


@pytest.mark.streaming
def test_snapshot_restore_mid_customization_session(folded):
    """A snapshot taken while an enrollment session is mid-flight restores
    the session (captures, calibration progress, head state) and drives
    it to the SAME CustomizationResult, bit for bit."""
    hw = folded

    def mk():
        return StreamServer(hw, CFG, hop=HOP, slots=4, use_kernel=False,
                            sa_noise_std=1.0, seed=2)

    rng = np.random.default_rng(1)
    utts = [rng.standard_normal(L).astype(np.float32) for _ in range(4)]
    srv = mk()
    sess = srv.customize("enroll", cz.CustomizeConfig())
    for j, u in enumerate(utts):
        sess.enroll(j % CFG.num_classes, u)
    sess.finish_enrollment()
    for _ in range(6):
        srv.step()
    snap = srv.snapshot()            # in-memory snapshot, mid-session

    def finishing(s):
        se = s._cust.sessions[0]
        for _ in range(300):
            s.step()
            if se.phase in ("ready", "swapped"):
                return se
        raise AssertionError(f"session stuck in {se.phase}")

    s1 = finishing(srv)
    srv2 = mk()
    srv2.restore(snap)
    s2 = finishing(srv2)
    r1, r2 = s1.result, s2.result
    for name in r1.bias:
        assert np.array_equal(np.asarray(r1.bias[name]),
                              np.asarray(r2.bias[name])), name
    assert np.array_equal(np.asarray(r1.fc_w), np.asarray(r2.fc_w))
    assert np.array_equal(np.asarray(r1.fc_b), np.asarray(r2.fc_b))


def test_snapshot_restore_rejects_mismatched_config(folded):
    hw = folded
    srv = StreamServer(hw, CFG, hop=HOP, slots=2, use_kernel=False)
    snap = srv.snapshot()
    other = StreamServer(hw, CFG, hop=2 * HOP, slots=2, use_kernel=False)
    with pytest.raises(ValueError, match="configuration mismatch"):
        other.restore(snap)
    with_faults = StreamServer(hw, CFG, hop=HOP, slots=2, use_kernel=False,
                               faults=flt.FaultConfig(seed=0))
    with pytest.raises(ValueError, match="fault-model mismatch"):
        with_faults.restore(snap)


# ---------------------------------------------------------------------------
# Satellites: profiles at admission, duty-aware hop, retention fills
# ---------------------------------------------------------------------------


def test_profile_auto_install_and_stale_eviction(folded, tmp_path):
    """submit(user_id=...) installs the stored profile on admission; a
    re-saved profile hot-swaps on the next tick (mtime moved); a deleted
    profile resets the stream to the base model."""
    hw = folded
    store = ProfileStore(os.fspath(tmp_path / "profiles"))
    store.save("alice", _result(hw, "conv2", 1))
    srv = StreamServer(hw, CFG, hop=HOP, slots=2, use_kernel=False,
                       profiles=store)
    rng = np.random.default_rng(0)
    srv.submit("mic0", rng.standard_normal(L).astype(np.float32),
               user_id="alice")
    rec = srv._streams["mic0"]
    assert rec.custom is not None and rec.profile_mtime is not None
    assert np.all(np.asarray(rec.custom["delta"]["conv2"]) == 1.0)
    srv.step()

    store.save("alice", _result(hw, "conv2", 2))      # fresh inode
    srv.submit("mic0", rng.standard_normal(HOP).astype(np.float32))
    srv.step()
    assert np.all(np.asarray(rec.custom["delta"]["conv2"]) == 2.0)
    assert srv.stats()["profile_swaps"] == 1

    store.delete("alice")
    srv.step()
    assert rec.custom is None and rec.profile_mtime is None
    assert srv.stats()["profile_swaps"] == 2

    # a user with no stored profile serves the base model but is tracked:
    # a later save is installed by the sweep
    srv.submit("mic1", rng.standard_normal(L).astype(np.float32),
               user_id="bob")
    rec1 = srv._streams["mic1"]
    assert rec1.custom is None
    store.save("bob", _result(hw, "conv3", 1))
    srv.step()
    assert rec1.custom is not None

    # user_id without a store is a usage error
    bare = StreamServer(hw, CFG, hop=HOP, slots=2, use_kernel=False)
    with pytest.raises(ValueError, match="profile store"):
        bare.submit("x", np.zeros((HOP,), np.float32), user_id="alice")


def test_duty_aware_hop_widen_faster_when_silent(folded):
    """With calm_silence set, an all-silent stream earns the wider hop in
    calm_silence ticks instead of widen_after; an all-speech stream is
    bit-identical to the same server without the knob (forced-speech
    contract)."""
    hw = folded
    rng = np.random.default_rng(4)
    quiet = (1e-4 * rng.standard_normal(L + 20 * HOP)).astype(np.float32)

    def run(calm_silence, wav, force=None):
        srv = StreamServer(
            hw, CFG, hop=HOP, slots=2, use_kernel=False,
            vad=VADConfig() if force is None else VADConfig(force=force),
            dynamic_hop=DynamicHopConfig(widen_after=50,
                                         calm_silence=calm_silence))
        srv.submit("s", wav)
        mults, events = [], []
        for _ in range(16):
            events.extend(srv.step())
            mults.append(srv.hop_multiplier)
        return mults, events

    mults_fast, _ = run(3, quiet)
    mults_slow, _ = run(None, quiet)
    assert max(mults_fast) > 1          # widened within 16 ticks
    assert max(mults_slow) == 1         # widen_after=50 never reached

    loud = rng.uniform(-1, 1, L + 20 * HOP).astype(np.float32)
    _, ev_knob = run(3, loud, force="speech")
    _, ev_base = run(None, loud, force="speech")
    eq.assert_events_equal(ev_knob, ev_base,   # forced speech: the knob
                           "calm_silence knob")  # is invisible


def test_retention_fill_modes(folded):
    """retention_fills at zero read noise IS silence_fills (the pinned
    default); with noise it differs but stays shape/dtype-compatible; the
    scheduler validates the mode string."""
    hw = folded
    base = sv.silence_fills(CFG, m.silence_columns(hw, CFG))
    ret0 = sv.retention_fills(hw, CFG, key=jax.random.PRNGKey(0),
                              sa_noise_std=0.0)
    for a, b in zip(base, ret0):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    retn = sv.retention_fills(hw, CFG, key=jax.random.PRNGKey(0),
                              sa_noise_std=2.0, chip_offsets=_chip())
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(base, retn))
    for a, b in zip(base, retn):
        assert np.asarray(a).shape == np.asarray(b).shape
    with pytest.raises(ValueError, match="silence_fill"):
        StreamServer(hw, CFG, hop=HOP, slots=2, use_kernel=False,
                     silence_fill="nope")
    srv = StreamServer(hw, CFG, hop=HOP, slots=2, use_kernel=False,
                       vad=VADConfig(), sa_noise_std=2.0,
                       silence_fill="retention")
    rng = np.random.default_rng(0)
    srv.submit("a", rng.standard_normal(L + 4 * HOP).astype(np.float32))
    for _ in range(6):
        srv.step()
    assert srv.stats()["silence_fill"] == "retention"


# ---------------------------------------------------------------------------
# Soak: everything at once, randomized
# ---------------------------------------------------------------------------


def _soak(folded, seed, ticks, snapshot_every):
    """Randomized interleaving of admissions, evictions, VAD-gated audio,
    fault injections, and periodic snapshot+restore-into-fresh-server
    swaps.  Invariants checked every tick; returns final stats."""
    hw = folded
    chip = _chip()

    def mk():
        return StreamServer(hw, CFG, hop=HOP, slots=3, use_kernel=False,
                            chip_offsets=chip, sa_noise_std=1.0,
                            vad=VADConfig(),
                            faults=flt.FaultConfig(drift_std=0.1,
                                                   seed=seed),
                            health=HealthConfig(interval=5), seed=seed)

    rng = np.random.default_rng(seed)
    srv = mk()
    alive = {}
    for t in range(ticks):
        r = rng.random()
        if r < 0.25 and len(alive) < 5:
            sid = f"s{t}"
            alive[sid] = True
            srv.submit(sid, rng.uniform(-1, 1, L).astype(np.float32))
        elif r < 0.35 and alive:
            sid = rng.choice(sorted(alive))
            del alive[sid]
            srv.evict(sid)
        elif r < 0.45 and srv.faults is not None:
            kind = rng.integers(3)
            if kind == 0:
                srv.faults.inject_bit_flips(n=1)
            elif kind == 1:
                name = f"conv{1 + int(rng.integers(CFG.num_conv_layers - 1))}"
                srv.faults.inject_stuck(
                    name, [int(rng.integers(CFG.channels[int(name[4:])]))])
            else:
                srv.faults.clear()
        for sid in list(alive):
            amp = 1.0 if rng.random() < 0.5 else 1e-4
            srv.submit(sid, (amp * rng.standard_normal(HOP))
                       .astype(np.float32))
        srv.step()
        if (t + 1) % snapshot_every == 0:
            snap = srv.snapshot()
            srv2 = mk()
            srv2.restore(snap)
            assert srv2.health.stats() == srv.health.stats()
            assert srv2.faults.stats() == srv.faults.stats()
            srv = srv2               # continue on the restored server
        assert srv.health.state in srv.health.STATES
        live_slots = [rec.stream_id for rec in srv._slots
                      if rec is not None and not rec.internal]
        assert len(live_slots) == len(set(live_slots))
    st = srv.stats()
    assert st["steps"] == ticks
    return st


@pytest.mark.streaming
def test_soak_quick(folded):
    st = _soak(folded, seed=13, ticks=24, snapshot_every=8)
    assert st["health"]["canaries"] >= 1


@pytest.mark.slow
@pytest.mark.streaming
@pytest.mark.parametrize("seed", [101, 202])
def test_soak_long(folded, seed):
    st = _soak(folded, seed=seed, ticks=120, snapshot_every=25)
    assert st["health"]["canaries"] >= 3


# ---------------------------------------------------------------------------
# Sharded soak: the same chaos across a 2-device fleet
# ---------------------------------------------------------------------------


def _sharded_soak(folded, seed, ticks, snapshot_every):
    """The PR-6 soak across a 2-device fleet: random admissions (beyond
    per-pool capacity, so placement + queueing engage), evictions that
    free capacity for later streams to land on either device
    (cross-device re-routing), fleet-wide fault campaigns with per-pool
    canary heals, and periodic sharded snapshot -> restore-into-fresh-
    fleet swaps.  Invariants checked every tick; returns fleet stats."""
    hw = folded
    chip = _chip()
    from repro.serving import ShardedStreamServer

    def mk():
        return ShardedStreamServer(
            hw, CFG, devices=2, slots=2, hop=HOP, use_kernel=False,
            chip_offsets=chip, sa_noise_std=1.0, vad=VADConfig(),
            faults=flt.FaultConfig(drift_std=0.1, seed=seed),
            health=HealthConfig(interval=5), seed=seed)

    rng = np.random.default_rng(seed)
    sh = mk()
    alive = {}
    placed_on = set()
    for t in range(ticks):
        r = rng.random()
        if r < 0.3 and len(alive) < 6:
            sid = f"s{t}"
            alive[sid] = True
            sh.submit(sid, rng.uniform(-1, 1, L).astype(np.float32))
            placed_on.add(sh.where(sid))
        elif r < 0.4 and alive:
            sid = rng.choice(sorted(alive))
            del alive[sid]
            sh.evict(sid)
        elif r < 0.5:
            kind = rng.integers(3)
            for fm in sh.fault_models:      # fleet-wide campaign: every
                if kind == 0:               # pool mutates identically
                    fm.inject_bit_flips(n=1)
                elif kind == 1:
                    fm.inject_stuck("conv2", [3])
                else:
                    fm.clear()
        for sid in list(alive):
            amp = 1.0 if rng.random() < 0.5 else 1e-4
            sh.submit(sid, (amp * rng.standard_normal(HOP))
                      .astype(np.float32))
        events = sh.step()
        for ev in events:                   # device tags track placement
            assert ev["device"] == sh.where(ev["stream"])
        if (t + 1) % snapshot_every == 0:
            snap = sh.snapshot()
            sh2 = mk()
            sh2.restore(snap)
            for a, b in zip(sh2.pools, sh.pools):
                assert a.health.stats() == b.health.stats()
                assert a.faults.stats() == b.faults.stats()
            assert sh2._where == sh._where
            sh = sh2                        # continue on the restored fleet
        for srv in sh.pools:
            assert srv.health.state in srv.health.STATES
        live = [rec.stream_id for srv in sh.pools for rec in srv._slots
                if rec is not None and not rec.internal]
        assert len(live) == len(set(live))  # no stream on two devices
        for sid in live:
            assert sh.where(sid) is not None
    assert placed_on == {0, 1}              # both devices took streams
    st = sh.stats()
    assert st["steps"] == ticks
    return st


@pytest.mark.streaming
def test_sharded_soak_quick(folded):
    st = _sharded_soak(folded, seed=17, ticks=24, snapshot_every=8)
    assert sum(d["health"]["canaries"]
               for d in st["per_device"]) >= 2    # >=1 canary per pool


@pytest.mark.slow
@pytest.mark.streaming
@pytest.mark.parametrize("seed", [303, 404])
def test_sharded_soak_long(folded, seed):
    st = _sharded_soak(folded, seed=seed, ticks=120, snapshot_every=25)
    assert sum(d["health"]["canaries"]
               for d in st["per_device"]) >= 6
