"""The sharded-serving contract (repro.serving.shard + repro.sharding):

* a ``ShardedStreamServer`` — N per-device slot pools behind the
  deterministic placement router — is bit-identical PER STREAM to one
  single-device ``StreamServer`` fed the same streams: SA-noise fields
  (global uid parity), chip offsets, ``FaultConfig`` deltas (bit flips,
  stuck columns, tick-lockstep drift) and VAD gating included;
* a property soak drives random interleavings of submit / speech /
  silence / evict / finish / fault-inject / snapshot-restore ops through
  both servers and compares every stream's full decision sequence;
* the sharded snapshot bundle (per-pool v2 snapshots + router state in
  one atomic npz) restores bit-identically into a fresh fleet, and
  refuses a mismatched device count;
* the placement policy is deterministic: least-loaded spreads streams
  across pools, exact ties rotate round-robin, and the router never
  consumes a global uid for a rejected stream;
* every event carries its ``device`` tag and the fleet ``stats()``
  rollup (the tier's only cross-device gather) sums the per-device pool
  counters with zero launch-audit violations.

Pools map to ``jax.devices()[d % len(devices)]``, so this file runs N
logical pools on one physical device; the CI sharding gate re-runs it
under ``XLA_FLAGS=--xla_force_host_platform_device_count=2`` for real
per-device placement.
"""

import numpy as np
import jax
import pytest
from _hypothesis_shim import given, settings, st

import _equiv as eq
from repro.core import faults as flt
from repro.core import imc
from repro.models import kws as m
from repro.serving import (HealthConfig, ObsConfig, ShardedStreamServer,
                           StreamServer, VADConfig)
from repro.sharding import PlacementConfig, PlacementPolicy, PoolLoad

L, HOP = 640, 64
CFG = m.KWSConfig(sample_len=L)


@pytest.fixture(scope="module")
def folded():
    params = m.init_params(jax.random.PRNGKey(5), CFG)
    state = m.init_state(CFG)
    return m.fold_params(params, state, CFG, pack=True)


def _chip(std=4.0):
    chans = {f"conv{i}": CFG.channels[i]
             for i in range(1, CFG.num_conv_layers)}
    return imc.sample_chip_offsets(
        jax.random.PRNGKey(9), chans,
        imc.IMCNoiseParams(mav_offset_std=std))


def _wav(key, n):
    return np.asarray(jax.random.uniform(jax.random.PRNGKey(key), (n,),
                                         minval=-1, maxval=1), np.float32)


def _assert_equiv(ev_oracle, ev_sharded):
    # per-stream, device tags stripped — the shared harness's by_stream
    # mode (tests/_equiv.py): the sharded server must match the oracle
    # on everything else, field for field
    return eq.assert_events_equal(ev_oracle, ev_sharded,
                                  "sharded vs oracle", by_stream=True)


# ---------------------------------------------------------------------------
# Placement policy (repro.sharding.placement)
# ---------------------------------------------------------------------------


def test_placement_least_loaded_then_queue_then_rr():
    p = PlacementPolicy(3)
    # most free slots wins
    assert p.place([PoolLoad(1, 0), PoolLoad(3, 0), PoolLoad(2, 0)]) == 1
    # equal slots: shortest queue wins
    assert p.place([PoolLoad(2, 4), PoolLoad(2, 1), PoolLoad(2, 2)]) == 1
    # exact ties rotate via the cursor (last pick was 1 -> cursor at 2)
    assert p.place([PoolLoad(2, 0), PoolLoad(2, 0), PoolLoad(2, 0)]) == 2
    assert p.place([PoolLoad(2, 0), PoolLoad(2, 0), PoolLoad(2, 0)]) == 0
    # duty-aware tie-break: quietest pool absorbs the new talker
    pd = PlacementPolicy(2, PlacementConfig(duty_aware=True))
    assert pd.place([PoolLoad(2, 0, duty=0.9),
                     PoolLoad(2, 0, duty=0.1)]) == 1


def test_placement_round_robin_and_snapshot():
    p = PlacementPolicy(2, PlacementConfig(strategy="round_robin"))
    loads = [PoolLoad(0, 9), PoolLoad(4, 0)]
    assert [p.place(loads) for _ in range(4)] == [0, 1, 0, 1]
    snap = p.snapshot()
    q = PlacementPolicy(2, PlacementConfig(strategy="round_robin"))
    q.restore(snap)
    assert q.place(loads) == p.place(loads)
    with pytest.raises(ValueError):
        PlacementPolicy(2).restore(snap)          # strategy mismatch
    with pytest.raises(ValueError):
        PlacementConfig(strategy="hash")
    with pytest.raises(ValueError):
        p.place([PoolLoad(1, 0)])                 # wrong arity


# ---------------------------------------------------------------------------
# Directed bit-identity: sharded == single-device per stream
# ---------------------------------------------------------------------------


@pytest.mark.streaming
def test_sharded_bitident_noise_and_chip_offsets(folded):
    """2 pools x 2 slots vs one 4-slot oracle on the full noisy path —
    fused kernels, SA-noise fields keyed by the GLOBAL uid, chip
    offsets.  The crux: stream s3 lands on device 1 slot 1, but its
    noise field must equal the one the oracle drew for its slot."""
    kw = dict(hop=HOP, sa_noise_std=0.3, chip_offsets=_chip(), seed=0)
    oracle = StreamServer(folded, CFG, slots=4, **kw)
    sh = ShardedStreamServer(folded, CFG, devices=2, slots=2, **kw)
    wavs = {f"s{i}": _wav(100 + i, L + 6 * HOP) for i in range(4)}
    for sid, w in wavs.items():
        oracle.submit(sid, w)
        oracle.finish(sid)
        sh.submit(sid, w)
        sh.finish(sid)
    po = _assert_equiv(oracle.drain(), sh.drain())
    assert all(len(v) == 7 for v in po.values())   # init + 6 hops each
    # balanced placement: two streams per pool
    assert sorted(sh.where(s) for s in wavs) == [0, 0, 1, 1]


@pytest.mark.streaming
def test_sharded_bitident_vad_gating(folded):
    """Per-stream VAD gating (silent fills + wake replay) shards
    transparently: gating state is per slot, so a mid-stream quiet
    stretch gates on whichever device the stream lives on exactly as it
    would on the oracle."""
    vad = VADConfig(threshold_on_db=-40.0, threshold_off_db=-50.0,
                    wake_margin=1, hang=0)
    kw = dict(hop=HOP, sa_noise_std=0.2, vad=vad, seed=0)
    oracle = StreamServer(folded, CFG, slots=4, **kw)
    sh = ShardedStreamServer(folded, CFG, devices=2, slots=2, **kw)
    rng = np.random.default_rng(11)
    for i in range(4):
        w = rng.uniform(-1, 1, L + 12 * HOP).astype(np.float32)
        w[L + 4 * HOP:L + 9 * HOP] *= 1e-4        # silent stretch
        oracle.submit(f"s{i}", w)
        oracle.finish(f"s{i}")
        sh.submit(f"s{i}", w)
        sh.finish(f"s{i}")
    _assert_equiv(oracle.drain(), sh.drain())
    st = sh.stats()
    assert st["fleet"]["gated_hops"] > 0          # the gate actually ran
    assert (st["fleet"]["gated_hops"]
            == oracle.stats()["gated_hops"])


@pytest.mark.streaming
def test_sharded_bitident_faults_and_drift(folded):
    """One FaultConfig, one seeded FaultModel PER POOL: every pool ticks
    its model once per router tick, so tick-keyed drift stays in
    lockstep with the oracle, and a fleet-wide bit-flip campaign
    (same draws on every model) perturbs each stream identically."""
    fcfg = flt.FaultConfig(drift_std=0.2, seed=3)
    kw = dict(hop=HOP, sa_noise_std=0.2, seed=0)
    oracle = StreamServer(folded, CFG, slots=4, faults=fcfg, **kw)
    sh = ShardedStreamServer(folded, CFG, devices=2, slots=2,
                             faults=fcfg, **kw)
    assert len(sh.fault_models) == 2
    for i in range(4):
        w = _wav(300 + i, L + 8 * HOP)
        oracle.submit(f"s{i}", w)
        oracle.finish(f"s{i}")
        sh.submit(f"s{i}", w)
        sh.finish(f"s{i}")
    ev_o, ev_s = [], []
    for t in range(4):
        ev_o += oracle.step()
        ev_s += sh.step()
    oracle.faults.inject_bit_flips(n=4)
    oracle.faults.inject_stuck("conv2", [1, 5], value=-1)
    for fm in sh.fault_models:
        fm.inject_bit_flips(n=4)
        fm.inject_stuck("conv2", [1, 5], value=-1)
    ev_o += oracle.drain()
    ev_s += sh.drain()
    _assert_equiv(ev_o, ev_s)
    # a shared FaultModel instance would double-tick across pools
    with pytest.raises(ValueError):
        ShardedStreamServer(folded, CFG, devices=2, slots=2,
                            faults=oracle.faults, **kw)


@pytest.mark.streaming
def test_sharded_snapshot_restore_bit_identical(folded, tmp_path):
    """Mid-run sharded bundle -> fresh identically-configured fleet ->
    the remaining decisions match an uninterrupted oracle exactly.
    The bundle carries per-pool v2 snapshots plus router state (stream
    placements, global uid counter, policy cursor)."""
    fcfg = flt.FaultConfig(seed=5)
    kw = dict(hop=HOP, sa_noise_std=0.25, chip_offsets=_chip(),
              faults=fcfg, seed=0)
    oracle = StreamServer(folded, CFG, slots=4, **kw)

    def mk():
        return ShardedStreamServer(folded, CFG, devices=2, slots=2, **kw)

    sh = mk()
    for i in range(4):
        w = _wav(400 + i, L + 8 * HOP)
        oracle.submit(f"s{i}", w)
        oracle.finish(f"s{i}")
        sh.submit(f"s{i}", w)
        sh.finish(f"s{i}")
    ev_o, ev_s = [], []
    for _ in range(3):
        ev_o += oracle.step()
        ev_s += sh.step()
    path = str(tmp_path / "fleet.npz")
    assert sh.snapshot(path) == path
    sh2 = mk()
    sh2.restore(path)
    assert sh2.where("s0") == sh.where("s0")
    assert sh2._next_uid == sh._next_uid
    ev_o += oracle.drain()
    ev_s += sh2.drain()
    po = _assert_equiv(ev_o, ev_s)
    assert sum(len(v) for v in po.values()) > 0
    # a fleet of the wrong width must refuse the bundle
    with pytest.raises(ValueError):
        ShardedStreamServer(folded, CFG, devices=3, slots=2,
                            **kw).restore(path)


@pytest.mark.streaming
def test_router_rejection_consumes_no_uid(folded):
    """A stream rejected by its pool's admission queue leaves the router
    untouched — no placement, no global uid — so the noise-field
    identities of later streams still match the single-device oracle
    (whose rejected submits don't advance its uid either)."""
    from repro.serving import AdmissionConfig
    kw = dict(hop=HOP, seed=0,
              admission=AdmissionConfig(max_queue=0))
    sh = ShardedStreamServer(folded, CFG, devices=2, slots=1, **kw)
    for i in range(2):                      # fill both pools' only slots
        assert sh.submit(f"s{i}", _wav(i, L)) == "slot"
    uid_before = sh._next_uid
    assert sh.submit("overflow", _wav(9, L)) == "rejected"
    assert sh.where("overflow") is None
    assert sh._next_uid == uid_before
    st = sh.stats()
    assert st["fleet"]["rejected_streams"] == 1


@pytest.mark.streaming
def test_events_device_tags_and_fleet_rollup(folded):
    """Every decision event names the device that produced it (matching
    the router's placement), and the fleet stats rollup equals the sum
    of the per-device pools with zero audit violations."""
    obs = ObsConfig(recorder=32, audit="raise", trace=False)
    sh = ShardedStreamServer(folded, CFG, devices=2, slots=2, hop=HOP,
                             seed=0, obs=obs)
    for i in range(4):
        sh.submit(f"s{i}", _wav(500 + i, L + 4 * HOP))
        sh.finish(f"s{i}")
    events = sh.drain()
    assert events
    for ev in events:
        assert ev["device"] == sh.where(ev["stream"])
    st = sh.stats()
    assert st["devices"] == 2 and len(st["per_device"]) == 2
    assert st["fleet"]["decisions"] == sum(
        d["decisions"] for d in st["per_device"])
    assert st["fleet"]["decisions"] == len(events)
    assert st["audit"]["violations"] == 0
    assert [a["device"] for a in st["audit"]["per_device"]] == [0, 1]


# ---------------------------------------------------------------------------
# Property soak: random op interleavings, sharded == oracle throughout
# ---------------------------------------------------------------------------


def _dual_soak(folded, seed, ticks=12):
    """Drive one random interleaving of submit/speech/silence/evict/
    finish/fault/snapshot ops through a 2x2 sharded fleet AND a 4-slot
    single-device oracle, then compare every stream's full decision
    sequence.  Live streams are capped at the slot capacity (4) so
    admission is immediate on both sides — the timing alignment that
    makes tick-keyed fault drift comparable."""
    hw = folded
    fcfg = flt.FaultConfig(drift_std=0.1, seed=seed)
    vad = VADConfig(threshold_on_db=-40.0, threshold_off_db=-50.0,
                    wake_margin=1, hang=0)
    kw = dict(hop=HOP, use_kernel=False, sa_noise_std=0.5, vad=vad,
              faults=fcfg, seed=seed)
    oracle = StreamServer(hw, CFG, slots=4, **kw)

    def mk():
        return ShardedStreamServer(hw, CFG, devices=2, slots=2, **kw)

    sh = mk()
    rng = np.random.default_rng(seed)
    alive = {}
    ev_o, ev_s = [], []
    for t in range(ticks):
        r = rng.random()
        if r < 0.35 and len(alive) < 4:
            sid = f"s{t}"
            alive[sid] = True
            w = rng.uniform(-1, 1, L).astype(np.float32)
            oracle.submit(sid, w)
            sh.submit(sid, w)
        elif r < 0.45 and alive:
            sid = rng.choice(sorted(alive))
            del alive[sid]
            oracle.evict(sid)
            sh.evict(sid)
        elif r < 0.55 and alive:
            sid = rng.choice(sorted(alive))
            del alive[sid]
            oracle.finish(sid)
            sh.finish(sid)
        elif r < 0.65:
            oracle.faults.inject_bit_flips(n=1)
            for fm in sh.fault_models:
                fm.inject_bit_flips(n=1)
        for sid in list(alive):
            amp = 1.0 if rng.random() < 0.6 else 1e-4   # speech/silence
            w = (amp * rng.standard_normal(HOP)).astype(np.float32)
            oracle.submit(sid, w)
            sh.submit(sid, w)
        ev_o += oracle.step()
        ev_s += sh.step()
        if t == ticks // 2:                   # mid-soak fleet swap
            sh2 = mk()
            sh2.restore(sh.snapshot())
            sh = sh2
    for sid in alive:
        oracle.finish(sid)
        sh.finish(sid)
    ev_o += oracle.drain()
    ev_s += sh.drain()
    return _assert_equiv(ev_o, ev_s)


_HW_CACHE = []


def _hw():
    # the property wrapper exposes a zero-arg signature (hypothesis and
    # the shim alike), so the module fixture can't be injected — fold
    # once and cache instead
    if not _HW_CACHE:
        params = m.init_params(jax.random.PRNGKey(5), CFG)
        state = m.init_state(CFG)
        _HW_CACHE.append(m.fold_params(params, state, CFG, pack=True))
    return _HW_CACHE[0]


@pytest.mark.streaming
@settings(max_examples=4, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_sharded_soak_property(seed):
    """Any op interleaving keeps the sharded fleet bit-identical to the
    oracle — noise, gating, drift + flip faults and a mid-soak sharded
    snapshot swap included."""
    _dual_soak(_hw(), seed)
