"""End-to-end system test: the paper's full pipeline at smoke scale —
train base BNN -> fold to hardware -> inject chip noise -> compensate ->
customize the head on a shifted personal set with quantized on-chip
training.  Asserts the *trend structure* of Tables III/IV."""

import numpy as np
import pytest

import jax

from repro.core import imc
from repro.core.onchip_training import (OnChipTrainConfig, head_accuracy,
                                        quantized_head_finetune)
from repro.data import audio
from repro.models import kws as m
from repro.training import kws as tr


@pytest.fixture(scope="module")
def pipeline():
    L = 1000
    cfg = m.KWSConfig(sample_len=L)
    (xtr, ytr), (xte, yte) = audio.make_gscd_like(
        train_per_class=24, test_per_class=8, length=L)
    tcfg = tr.TrainConfig(epochs=48, batch_size=80, lr=3e-3, log_every=1000,
                          alpha_schedule=((0.3, 2.0), (0.5, 5.0),
                                          (0.65, 12.0), (1.0, -8.0)))
    params, state = tr.train_base(xtr, ytr, cfg, tcfg, verbose=False)
    return cfg, params, state, (xtr, ytr), (xte, yte)


def test_base_model_beats_chance_solidly(pipeline):
    cfg, params, state, _, (xte, yte) = pipeline
    acc = tr.evaluate(params, state, xte, yte, cfg)
    # smoke budget (30 epochs, L=1000): mechanics check only — the full
    # benchmark run (benchmarks/kws_experiments, L=2000, 60 epochs) reaches
    # ~0.96 hardware accuracy; here we only require solidly above chance
    assert acc > 0.22


def test_hw_noise_collapse_and_recovery(pipeline):
    cfg, params, state, (xtr, ytr), (xte, yte) = pipeline
    hw = m.fold_params(params, state, cfg)
    clean = tr.evaluate_hw(hw, xte, yte, cfg)

    chans = {f"conv{i}": cfg.channels[i]
             for i in range(1, cfg.num_conv_layers)}
    noise = imc.IMCNoiseParams(mav_offset_std=8.0, sa_noise_std=1.0)
    offs = imc.sample_chip_offsets(jax.random.PRNGKey(11), chans, noise)
    noisy = tr.evaluate_hw(hw, xte, yte, cfg, chip_offsets=offs,
                           sa_noise_std=1.0)
    hw_comp = tr.calibrate_and_compensate(hw, xtr[:100], offs, cfg)
    comp = tr.evaluate_hw(hw_comp, xte, yte, cfg, chip_offsets=offs,
                          sa_noise_std=1.0)
    # Table III structure: noise hurts, compensation recovers (the full
    # benchmark shows 0.96 -> 0.18 -> 0.92; smoke scale is noisier)
    assert noisy < clean
    assert comp >= noisy - 0.02


def test_customization_recovers_personal_accuracy(pipeline):
    cfg, params, state, _, _ = pipeline
    (xp_tr, yp_tr), (xp_te, yp_te) = audio.make_personal(
        train_per_class=3, test_per_class=5, length=cfg.sample_len,
        accent_shift=0.18)
    hw = m.fold_params(params, state, cfg)
    base_acc = tr.evaluate_hw(hw, xp_te, yp_te, cfg)

    feats_tr = tr.hw_features(hw, xp_tr, cfg)
    feats_te = tr.hw_features(hw, xp_te, cfg)
    ocfg = OnChipTrainConfig(epochs=300, error_scaling=True, sga=True)
    w, b = quantized_head_finetune(feats_tr, yp_tr,
                                   np.asarray(hw.fc_w),
                                   np.asarray(hw.fc_b), ocfg)
    acc = float(head_accuracy(feats_te, yp_te, w, b, ocfg))
    train_acc = float(head_accuracy(feats_tr, yp_tr, w, b, ocfg))
    # Integration mechanics at smoke scale: the quantized trainer must run
    # end-to-end on hardware-path features and produce valid on-grid
    # weights.  (Accuracy claims are covered by test_onchip_training's
    # separable-feature recovery test and the full-scale benchmark run,
    # which reaches 0.97 on the personal test set — a smoke-budget trunk
    # yields near-constant features on which any head collapses.)
    assert np.isfinite(acc) and np.isfinite(train_acc)
    codes = np.asarray(w) * 128
    np.testing.assert_allclose(codes, np.round(codes), atol=1e-4)
    assert np.all(np.abs(np.asarray(w)) <= 1.0)
