import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core import imc
from repro.core.binary import binarize


def test_bias_parity_and_range():
    b = jnp.linspace(-200, 200, 401)
    for method in imc.BIAS_MAPPING_METHODS:
        q = np.asarray(imc.map_bias(b, method))
        assert np.all(q % 2 == 0), method          # 64-wide array: even only
        assert np.all(np.abs(q) <= 64), method     # one word line of cells


@given(st.floats(-100, 100, allow_nan=False, width=32))
@settings(max_examples=60, deadline=None)
def test_bias_mapping_semantics(b):
    b = float(np.float32(b))       # match the on-device precision
    if 0 < abs(b) < 1e-30:
        return                     # XLA flushes subnormals to zero
    add = float(imc.map_bias(jnp.asarray(b), "add"))
    sub = float(imc.map_bias(jnp.asarray(b), "sub"))
    best = float(imc.map_bias(jnp.asarray(b), "best"))
    if abs(b) <= 62:
        assert sub <= b <= add
        assert abs(best - b) <= 1.0 + 1e-6         # nearest even within 1


def test_fold_bn_sign_flip():
    gamma = jnp.asarray([2.0, -1.5])
    beta = jnp.asarray([0.3, 0.3])
    mean = jnp.asarray([1.0, 1.0])
    var = jnp.asarray([4.0, 4.0])
    off = jnp.asarray([0.0, 0.0])
    bias, flip = imc.fold_bn_to_bias(gamma, beta, mean, var, off)
    counts = jnp.asarray([[0.5, 0.5]])
    # reference: sign of BN output
    ref = jnp.sign(gamma * (counts - mean) / jnp.sqrt(var + 1e-5) + beta)
    got = binarize((counts + bias) * flip)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref))


def test_mav_sa_noise_determinism():
    counts = jnp.zeros((4, 7, 8))
    bias = jnp.zeros((8,))
    flip = jnp.ones((8,))
    k = jax.random.PRNGKey(3)
    a = imc.mav_sa(counts, bias, flip, sa_key=k, sa_noise_std=1.0)
    b = imc.mav_sa(counts, bias, flip, sa_key=k, sa_noise_std=1.0)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert set(np.unique(np.asarray(a))) <= {-1.0, 1.0}


def test_chip_offsets_reproducible_per_seed():
    ch = {"conv1": 8, "conv2": 16}
    noise = imc.IMCNoiseParams(mav_offset_std=4.0)
    o1 = imc.sample_chip_offsets(jax.random.PRNGKey(7), ch, noise)
    o2 = imc.sample_chip_offsets(jax.random.PRNGKey(7), ch, noise)
    o3 = imc.sample_chip_offsets(jax.random.PRNGKey(8), ch, noise)
    np.testing.assert_array_equal(np.asarray(o1["conv1"]),
                                  np.asarray(o2["conv1"]))
    assert not np.allclose(np.asarray(o1["conv1"]), np.asarray(o3["conv1"]))


def test_binary_group_conv_counts_integer():
    key = jax.random.PRNGKey(0)
    x = binarize(jax.random.normal(key, (2, 20, 8)))
    w = binarize(jax.random.normal(jax.random.fold_in(key, 1), (3, 4, 16)))
    counts = imc.binary_group_conv_counts(x, w, groups=2)
    c = np.asarray(counts)
    assert c.shape == (2, 18, 16)
    assert np.all(c == np.round(c))
    assert np.all(np.abs(c) <= 12)                # fan-in 4*3
    # parity: sum of 12 (+/-1)s is even
    assert np.all(c % 2 == 0)


def test_macro_allocation_matches_chip():
    """CIM SRAM budget: paper uses 7 macros of 4KB for L2..L6 (Fig 14/17)."""
    from repro.models.kws import PAPER_KWS
    total = 0
    for i in range(1, PAPER_KWS.num_conv_layers):
        m = imc.map_layer_to_macros(
            f"conv{i}", PAPER_KWS.channels[i], PAPER_KWS.channels_per_group,
            PAPER_KWS.kernels[i], 1.0)
        total += m.macros
    # paper: 7 (exact per-bank packing is not recoverable from the text;
    # our capacity model books the bias word-lines separately)
    assert 5 <= total <= 10
