"""Per-architecture smoke tests (deliverable f): a REDUCED config of each
assigned architecture runs one train step + one decode step on CPU with
finite outputs and correct shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.launch.steps import (init_params_for, make_decode_step,
                                make_optimizer, make_train_step)
from repro.models import encdec as ED
from repro.models import lm as LM

B, S = 2, 32


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = get_config(request.param).reduced()
    params = init_params_for(cfg, jax.random.PRNGKey(0))
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(2, cfg.vocab_size, (B, S)),
        jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family in ("vlm", "encdec"):
        batch["frames"] = jnp.ones((B, cfg.frontend_len, cfg.d_model),
                                   jnp.bfloat16)
    return request.param, cfg, params, batch


def test_train_step_finite(arch_setup):
    arch, cfg, params, batch = arch_setup
    opt = make_optimizer(cfg)
    step = jax.jit(make_train_step(cfg, optimizer=opt))
    p2, o2, metrics = step(params, opt.init(params), batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), arch
    # loss starts near ln(vocab) for random init
    assert 0.5 * np.log(cfg.vocab_size) < loss < 2.5 * np.log(cfg.vocab_size)
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), params, p2)
    assert max(jax.tree_util.tree_leaves(moved)) > 0


def test_decode_step_finite_and_cache_updates(arch_setup):
    arch, cfg, params, batch = arch_setup
    dstep = jax.jit(make_decode_step(cfg))
    if cfg.family == "encdec":
        cache = ED.init_dec_cache(cfg, B, S)
        dbatch = {"tokens": batch["tokens"][:, :1],
                  "memory": batch["frames"], "index": jnp.int32(0)}
        logits, new_cache = dstep(params, cache, dbatch)
    else:
        cache = LM.init_cache(cfg, B, S)
        dbatch = {"tokens": batch["tokens"][:, :1], "index": jnp.int32(0)}
        logits, new_cache = dstep(params, cache, dbatch)
    assert logits.shape == (B, 1, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch
    before = jax.tree_util.tree_leaves(cache)
    after = jax.tree_util.tree_leaves(new_cache)
    changed = any(not np.array_equal(np.asarray(x), np.asarray(y))
                  for x, y in zip(before, after))
    assert changed, f"{arch}: decode did not update its cache"


def test_decode_matches_forward_logits():
    """Teacher-forced decode step-by-step == full forward (dense family)."""
    cfg = get_config("qwen2.5-14b").reduced()
    params = init_params_for(cfg, jax.random.PRNGKey(1))
    tokens = jnp.asarray(
        np.random.default_rng(1).integers(2, cfg.vocab_size, (1, 8)),
        jnp.int32)
    full_logits, _ = LM.forward_lm(params, cfg, tokens, train=False)
    cache = LM.init_cache(cfg, 1, 8)
    dstep = jax.jit(make_decode_step(cfg))
    outs = []
    for t in range(8):
        logits, cache = dstep(params, cache,
                              {"tokens": tokens[:, t:t + 1],
                               "index": jnp.int32(t)})
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(dec),
                               np.asarray(full_logits.astype(jnp.float32)),
                               atol=0.15, rtol=0.05)
