"""Tiny fallback for the optional ``hypothesis`` dependency.

The property tests only use ``given``/``settings`` with three strategies
(floats, integers, lists-of-floats).  When hypothesis is installed we
re-export the real thing; otherwise this shim runs each property over a
deterministic pseudo-random sample (seeded, endpoints first) so the suite
still collects and exercises the properties without the dependency.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

    import random
    import struct

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng, i):
            return self._draw(rng, i)

    def _f32(v: float) -> float:
        return struct.unpack("f", struct.pack("f", v))[0]

    class st:  # noqa: N801 - mimics `hypothesis.strategies as st`
        @staticmethod
        def floats(min_value, max_value, allow_nan=True, width=64):
            def draw(rng, i):
                # endpoints and zero first, then uniform samples
                if i == 0:
                    v = float(min_value)
                elif i == 1:
                    v = float(max_value)
                elif i == 2 and min_value <= 0.0 <= max_value:
                    v = 0.0
                else:
                    v = rng.uniform(min_value, max_value)
                return _f32(v) if width == 32 else v
            return _Strategy(draw)

        @staticmethod
        def integers(min_value, max_value):
            def draw(rng, i):
                if i == 0:
                    return int(min_value)
                if i == 1:
                    return int(max_value)
                return rng.randint(min_value, max_value)
            return _Strategy(draw)

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng, i):
                size = min_size if i == 0 else rng.randint(min_size, max_size)
                return [elements.draw(rng, 3 + j) for j in range(size)]
            return _Strategy(draw)

    def given(*strats):
        def deco(fn):
            def wrapper():
                n = getattr(wrapper, "_shim_max_examples", 20)
                rng = random.Random(0xC0FFEE)
                for i in range(n):
                    fn(*[s.draw(rng, i) for s in strats])
            # NOT functools.wraps: the wrapper must expose a zero-arg
            # signature or pytest would look for fixtures named after the
            # property's parameters.
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__dict__.update(fn.__dict__)
            return wrapper
        return deco

    def settings(max_examples=20, **_):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco
