"""Compiled whole-tick fast path (repro.serving.compiled).

The differential proof for the tentpole claim: K steady-state scheduler
ticks fused into ONE jitted ``lax.scan`` dispatch are bit-identical to K
interpreted Python ticks — decision events, stream/decision/VAD carry
state and every metrics-registry cell (``tests/_equiv.py`` defines the
shared notion of equal, excluding only wall time and the
``serving.compiled`` dispatch counters).  Coverage spans the configs the
invariants live in: SA-noise fields, chip offsets, fault riders (drift
and injected flips), VAD gating with wake-margin replay, dynamic hop,
slot autoscaling + SLO shedding, admissions/evictions mid-run, snapshot/
restore across tick modes, sharded pools, and the launch auditor's
``compiled``-cause rules in raise mode.

Golden decision-trace regression: ``tests/golden/decision_trace.json``
pins the full event stream of a fixed compiled run, byte for byte.
Regenerate (after an INTENTIONAL decision-path change) with::

    REPRO_REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest \
        tests/test_compiled.py -k golden -q
"""

import json
import os
import pathlib

import numpy as np
import jax
import pytest
from _hypothesis_shim import given, settings, st

import _equiv as eq
from repro.core import faults as flt
from repro.core import imc
from repro.models import kws as m
from repro.obs import LaunchAuditError, LaunchAuditor, ObsConfig
from repro.serving import (AdmissionConfig, CompiledTickConfig,
                           DynamicHopConfig, ShardedStreamServer,
                           StreamServer, VADConfig)

L, HOP = 640, 64
CFG = m.KWSConfig(sample_len=L)
GOLDEN = pathlib.Path(__file__).parent / "golden" / "decision_trace.json"

pytestmark = [pytest.mark.streaming, pytest.mark.compiled]


@pytest.fixture(scope="module")
def folded():
    params = m.init_params(jax.random.PRNGKey(5), CFG)
    state = m.init_state(CFG)
    return m.fold_params(params, state, CFG, pack=True)


def _chip(std=4.0):
    chans = {f"conv{i}": CFG.channels[i]
             for i in range(1, CFG.num_conv_layers)}
    return imc.sample_chip_offsets(jax.random.PRNGKey(9), chans,
                                   imc.IMCNoiseParams(mav_offset_std=std))


def _duty(n, seed, duty=0.45, period=3 * HOP):
    """Speech/silence duty-cycled audio: uniform noise with seeded runs
    of near-silence, so VAD gating, wake replay and calm ticks all
    actually exercise."""
    r = np.random.default_rng(seed)
    x = r.uniform(-1.0, 1.0, n).astype(np.float32)
    t = 0
    while t < n:
        if r.random() > duty:
            x[t:t + period] *= 1e-4
        t += period
    return x


def _run_pair(hw, kw, ticks=30, n_streams=3, block=8, slots=3,
              inject=None, audio_len=None):
    """Drive a Python-tick reference and a compiled-block candidate over
    identical traffic to the same absolute tick, then assert the full
    equivalence contract.  Returns ``(ref, cand, events)``."""
    ref = StreamServer(hw, CFG, hop=HOP, slots=slots, **kw)
    cand = StreamServer(hw, CFG, hop=HOP, slots=slots,
                        compiled=CompiledTickConfig(block=block), **kw)
    if inject is not None:
        inject(ref)
        inject(cand)
    n = audio_len if audio_len is not None else L + 22 * HOP
    auds = [_duty(n, 100 + i) for i in range(n_streams)]
    for srv in (ref, cand):
        for i, x in enumerate(auds):
            srv.submit(f"s{i}", x)
    ev_ref = eq.advance_to(ref, ticks)
    ev_cand = eq.advance_to(cand, ticks)
    assert cand._steps == ref._steps == ticks
    eq.assert_events_equal(ev_ref, ev_cand, "compiled vs python")
    eq.assert_server_equal(ref, cand, "compiled vs python")
    return ref, cand, ev_ref


# ---------------------------------------------------------------------------
# bit-identity across the invariant-bearing configs
# ---------------------------------------------------------------------------


CASES = {
    "gated_clean": lambda: dict(vad=VADConfig()),
    "ungated": lambda: dict(),
    "noise_and_chip": lambda: dict(vad=VADConfig(), sa_noise_std=0.15,
                                   chip_offsets=_chip()),
    "wake_margin2": lambda: dict(
        vad=VADConfig(threshold_on_db=-40.0, threshold_off_db=-50.0,
                      wake_margin=2, hang=0), sa_noise_std=0.2),
    "fault_drift": lambda: dict(vad=VADConfig(),
                                faults=flt.FaultConfig(drift_std=0.5)),
    "dynamic_hop": lambda: dict(
        vad=VADConfig(),
        dynamic_hop=DynamicHopConfig(widen_after=4, max_multiplier=2)),
    "dynhop_duty_aware": lambda: dict(
        vad=VADConfig(),
        dynamic_hop=DynamicHopConfig(widen_after=5, max_multiplier=2,
                                     calm_silence=2)),
    "autoscale": lambda: dict(
        vad=VADConfig(),
        admission=AdmissionConfig(min_slots=1, max_slots=3,
                                  scale_up_after=2, scale_down_after=3)),
}


@pytest.mark.parametrize("case", sorted(CASES))
def test_compiled_block_bitident(folded, case):
    """One fused dispatch for a block of steady-state ticks equals the
    interpreted ticks bit for bit — events, carries, counters."""
    _, cand, events = _run_pair(folded, CASES[case](), ticks=30)
    assert events                           # the case actually decided
    assert cand._compiled_ticks > 0, "fast path never engaged"
    assert cand._compiled_blocks <= cand._compiled_ticks


def test_compiled_injected_faults_bitident(folded):
    """Stuck-column + bit-flip deltas (integer-valued riders) flow into
    the compiled block through the same staged operands as drift."""
    def inject(srv):
        srv.faults.inject_stuck("conv2", [0, 5])
        srv.faults.inject_bit_flips(n=2)
    _, cand, _ = _run_pair(
        folded, dict(vad=VADConfig(), sa_noise_std=0.3,
                     chip_offsets=_chip(),
                     faults=flt.FaultConfig(seed=3)),
        inject=inject)
    assert cand._compiled_ticks > 0


def test_compiled_slo_shed_falls_back(folded):
    """A backlog over the latency SLO is a structural event: the horizon
    refuses to fuse and the Python tick sheds — equivalence holds with
    the fast path engaging only on the SLO-clean stretches."""
    kw = dict(vad=VADConfig(),
              admission=AdmissionConfig(max_lag_s=(L + 6 * HOP) / 16000))
    _run_pair(folded, kw, ticks=30, audio_len=L + 40 * HOP)


# ---------------------------------------------------------------------------
# tick-mode plumbing: step() routing, block sizes, drain
# ---------------------------------------------------------------------------


def test_step_routes_single_tick_blocks(folded):
    """``step()`` on a compiled server serves eligible ticks as K=1
    blocks — same events as the Python tick, one dispatch per tick."""
    ref = StreamServer(folded, CFG, hop=HOP, slots=2, vad=VADConfig())
    cand = StreamServer(folded, CFG, hop=HOP, slots=2, vad=VADConfig(),
                        compiled=CompiledTickConfig(block=8))
    for srv in (ref, cand):
        for i in range(2):
            srv.submit(f"s{i}", _duty(L + 10 * HOP, 40 + i))
    ev_ref, ev_cand = [], []
    for _ in range(14):
        ev_ref.extend(ref.step())
        ev_cand.extend(cand.step())         # NOT step_block
    eq.assert_events_equal(ev_ref, ev_cand, "step() routing")
    eq.assert_server_equal(ref, cand, "step() routing")
    assert cand._compiled_ticks > 0
    assert cand._compiled_blocks == cand._compiled_ticks   # K=1 blocks


def test_block_sizes_all_equal(folded):
    """Every block size serves the same decisions; bigger blocks just
    use fewer dispatches."""
    kw = dict(vad=VADConfig(), sa_noise_std=0.2)
    runs = {}
    for block in (1, 2, 3, 8, 32):
        srv = StreamServer(folded, CFG, hop=HOP, slots=2,
                           compiled=CompiledTickConfig(block=block), **kw)
        for i in range(2):
            srv.submit(f"s{i}", _duty(L + 16 * HOP, 70 + i))
        ev = eq.advance_to(srv, 20)
        runs[block] = (srv, ev)
    ref_srv, ref_ev = runs[1]
    for block, (srv, ev) in runs.items():
        eq.assert_events_equal(ref_ev, ev, f"block={block}")
        eq.assert_server_equal(ref_srv, srv, f"block={block}")
    assert runs[32][0]._compiled_blocks < runs[1][0]._compiled_blocks


def test_compiled_drain_matches(folded):
    """``drain()`` on a compiled server (which drains via step_block)
    retires everything the interpreted drain does, in as many ticks."""
    ref = StreamServer(folded, CFG, hop=HOP, slots=2, vad=VADConfig())
    cand = StreamServer(folded, CFG, hop=HOP, slots=2, vad=VADConfig(),
                        compiled=CompiledTickConfig(block=8))
    for srv in (ref, cand):
        for i in range(2):
            srv.submit(f"s{i}", _duty(L + 12 * HOP, 55 + i))
            srv.finish(f"s{i}")
    ev_ref, ev_cand = ref.drain(), cand.drain()
    eq.assert_events_equal(ev_ref, ev_cand, "drain")
    assert ref._steps == cand._steps
    eq.assert_server_equal(ref, cand, "drain")
    assert cand._compiled_ticks > 0


def test_compiled_admission_eviction_mid_run(folded):
    """Admissions and evictions are block boundaries, not failures: a
    stream submitted or evicted mid-run breaks the block, the Python
    tick handles the structural work, and fusing resumes after."""
    kw = dict(vad=VADConfig(), sa_noise_std=0.2)
    ref = StreamServer(folded, CFG, hop=HOP, slots=3, **kw)
    cand = StreamServer(folded, CFG, hop=HOP, slots=3,
                        compiled=CompiledTickConfig(block=4), **kw)
    for srv in (ref, cand):
        srv.submit("a", _duty(L + 20 * HOP, 1))
        srv.submit("b", _duty(L + 20 * HOP, 2))
    ev_ref = eq.advance_to(ref, 6)
    ev_cand = eq.advance_to(cand, 6)
    for srv in (ref, cand):
        srv.submit("c", _duty(L + 12 * HOP, 3))    # mid-run admission
        srv.evict("a")                             # and an eviction
    ev_ref += eq.advance_to(ref, 18)
    ev_cand += eq.advance_to(cand, 18)
    eq.assert_events_equal(ev_ref, ev_cand, "admit/evict mid-run")
    eq.assert_server_equal(ref, cand, "admit/evict mid-run")
    assert cand._compiled_ticks > 0


def test_snapshot_restore_across_tick_modes(folded):
    """v2 snapshots are tick-mode agnostic: a snapshot taken mid-run by
    a COMPILED server restores into a Python-tick server (and vice
    versa) and both futures stay bit-identical."""
    kw = dict(vad=VADConfig(), sa_noise_std=0.25, chip_offsets=_chip(),
              faults=flt.FaultConfig(seed=5))

    def mk(compiled):
        return StreamServer(folded, CFG, hop=HOP, slots=2,
                            compiled=(CompiledTickConfig(block=4)
                                      if compiled else None), **kw)

    cand = mk(True)
    for i in range(2):
        cand.submit(f"s{i}", _duty(L + 18 * HOP, 90 + i))
    eq.advance_to(cand, 7)
    snap = cand.snapshot()

    plain = mk(False)
    plain.restore(snap)
    resumed = mk(True)
    resumed.restore(snap)
    ev_plain = eq.advance_to(plain, 20)
    ev_resumed = eq.advance_to(resumed, 20)
    ev_cand = eq.advance_to(cand, 20)
    eq.assert_events_equal(ev_cand, ev_plain, "compiled->python restore")
    eq.assert_events_equal(ev_cand, ev_resumed,
                           "compiled->compiled restore")
    eq.assert_server_equal(cand, plain, "compiled->python restore",
                           counters=False)
    eq.assert_server_equal(cand, resumed, "compiled->compiled restore")
    assert resumed._compiled_ticks > 0


# ---------------------------------------------------------------------------
# property: random interleavings (hypothesis or the deterministic shim)
# ---------------------------------------------------------------------------


_HW_CACHE = []


def _hw():
    # the property wrapper exposes a zero-arg signature, so the module
    # fixture can't be injected — fold once and cache instead
    if not _HW_CACHE:
        params = m.init_params(jax.random.PRNGKey(5), CFG)
        state = m.init_state(CFG)
        _HW_CACHE.append(m.fold_params(params, state, CFG, pack=True))
    return _HW_CACHE[0]


def _compiled_soak(hw, seed, rounds=8):
    """One random interleaving of submit/speech/silence/evict/finish/
    snapshot ops, served by a Python-tick oracle and a compiled-block
    candidate advanced to the same tick after every round."""
    rng = np.random.default_rng(seed)
    kw = dict(hop=HOP, use_kernel=False, sa_noise_std=0.5,
              vad=VADConfig(threshold_on_db=-40.0,
                            threshold_off_db=-50.0,
                            wake_margin=1, hang=0),
              dynamic_hop=DynamicHopConfig(widen_after=3,
                                           max_multiplier=2),
              faults=flt.FaultConfig(drift_std=0.1, seed=seed),
              seed=seed)
    oracle = StreamServer(hw, CFG, slots=3, **kw)

    def mk():
        return StreamServer(hw, CFG, slots=3,
                            compiled=CompiledTickConfig(block=4), **kw)

    cand = mk()
    alive = {}
    ev_o, ev_c = [], []
    for t in range(rounds):
        r = rng.random()
        if r < 0.4 and len(alive) < 3:
            sid = f"s{t}"
            alive[sid] = True
            w = rng.uniform(-1, 1, L).astype(np.float32)
            oracle.submit(sid, w)
            cand.submit(sid, w)
        elif r < 0.5 and alive:
            sid = rng.choice(sorted(alive))
            del alive[sid]
            oracle.evict(sid)
            cand.evict(sid)
        elif r < 0.6 and alive:
            sid = rng.choice(sorted(alive))
            del alive[sid]
            oracle.finish(sid)
            cand.finish(sid)
        for sid in list(alive):             # speech/silence duty bursts
            amp = 1.0 if rng.random() < 0.6 else 1e-4
            n = int(rng.integers(1, 4)) * HOP
            w = (amp * rng.standard_normal(n)).astype(np.float32)
            oracle.submit(sid, w)
            cand.submit(sid, w)
        target = oracle._steps + int(rng.integers(1, 5))
        ev_o += eq.advance_to(oracle, target)
        ev_c += eq.advance_to(cand, target)
        if t == rounds // 2:                # mid-soak snapshot swap
            cand2 = mk()
            cand2.restore(cand.snapshot())
            cand = cand2
    for sid in alive:
        oracle.finish(sid)
        cand.finish(sid)
    ev_o += oracle.drain()
    ev_c += cand.drain()
    eq.assert_events_equal(ev_o, ev_c, f"soak seed={seed}")
    eq.assert_server_equal(oracle, cand, f"soak seed={seed}",
                           counters=False)   # snapshot swap resets wall


@settings(max_examples=4, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_compiled_soak_property(seed):
    """Any op interleaving keeps the compiled server bit-identical to
    the Python-tick oracle — gating, dynamic hop, drift faults and a
    mid-soak snapshot swap included."""
    _compiled_soak(_hw(), seed)


# ---------------------------------------------------------------------------
# golden decision trace
# ---------------------------------------------------------------------------


def _golden_run(hw):
    """The pinned run: fixed traffic, noise + chip offsets + gating,
    served entirely by the compiled fast path where eligible."""
    srv = StreamServer(hw, CFG, hop=HOP, slots=2, sa_noise_std=0.3,
                       chip_offsets=_chip(), vad=VADConfig(), seed=0,
                       compiled=CompiledTickConfig(block=8))
    for i in range(2):
        srv.submit(f"s{i}", _duty(L + 16 * HOP, 1234 + i))
        srv.finish(f"s{i}")
    events = srv.drain()
    return {"config": {"sample_len": L, "hop": HOP, "slots": 2,
                       "sa_noise_std": 0.3, "chip_std": 4.0,
                       "vad": "default", "block": 8, "seed": 0},
            "compiled_ticks": srv._compiled_ticks,
            "events": events}


def _render(trace):
    # sort_keys + fixed indent + trailing newline: the byte-stable form
    return (json.dumps(trace, indent=2, sort_keys=True) + "\n").encode()


def test_golden_decision_trace(folded):
    """The compiled server's full decision trace matches the checked-in
    golden file BYTE for byte.  Regen (see module docstring) with
    REPRO_REGEN_GOLDEN=1 after an intentional decision-path change."""
    got = _render(_golden_run(folded))
    if os.environ.get("REPRO_REGEN_GOLDEN"):
        GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        GOLDEN.write_bytes(got)
    want = GOLDEN.read_bytes()
    assert got == want, (
        "golden decision trace diverged — if the change is intentional, "
        "regenerate with REPRO_REGEN_GOLDEN=1 (module docstring)")


# ---------------------------------------------------------------------------
# launch auditor: the `compiled` cause
# ---------------------------------------------------------------------------


def test_auditor_compiled_cause_rules():
    """Unit rules: one compiled block per tick, never co-issued with
    interpreted calls, trace bounded by imc_layers like a hop's."""
    a = LaunchAuditor(3, mode="flag")
    a.begin_tick(0)
    a._on_call("compiled", 3)               # fresh trace: full bound OK
    a.end_tick()
    assert not a.violations

    a.begin_tick(1)                         # two blocks in one tick
    a._on_call("compiled", 0)
    a._on_call("compiled", 0)
    a.end_tick()
    assert any(v["cause"] == "compiled" for v in a.violations)

    b = LaunchAuditor(3, mode="flag")
    b.begin_tick(0)                         # block + interpreted hop
    b._on_call("compiled", 0)
    b._on_call("hop", 0)
    b.end_tick()
    assert any("co-issued" in v["detail"] for v in b.violations)

    c = LaunchAuditor(3, mode="raise")
    c.begin_tick(0)
    with pytest.raises(LaunchAuditError):   # per-slot loop leaked in
        c._on_call("compiled", 7)


def test_compiled_audit_raise_clean_env(folded, monkeypatch):
    """REPRO_OBS_AUDIT=raise + compiled tick: a full gated noisy run
    stays violation-free, the block attributes to its first tick and
    the remaining fused ticks legitimately show zero launches."""
    monkeypatch.setenv("REPRO_OBS_AUDIT", "raise")
    srv = StreamServer(folded, CFG, hop=HOP, slots=2, vad=VADConfig(),
                       sa_noise_std=0.2, chip_offsets=_chip(),
                       compiled=CompiledTickConfig(block=8))
    assert srv.obs.audit == "raise"
    for i in range(2):
        srv.submit(f"s{i}", _duty(L + 16 * HOP, 20 + i))
        srv.finish(f"s{i}")
    srv.drain()                             # raise mode: would throw
    s = srv.auditor.stats()
    assert s["violations"] == 0
    assert s["calls"]["compiled"] == srv._compiled_blocks > 0
    hist = srv.auditor.history()
    block_ticks = [h for h in hist if h["calls"]["compiled"]]
    assert block_ticks and all(h["calls"]["compiled"] == 1
                               for h in block_ticks)
    # fused non-first ticks show zero launches of any cause
    assert any(h["launches"] == 0 for h in hist)


# ---------------------------------------------------------------------------
# sharded: per-device pools, per-device auditors
# ---------------------------------------------------------------------------


def test_sharded_compiled_bitident(folded):
    """A sharded fleet with compiled pools serves every stream the same
    decisions as the sharded Python-tick fleet AND the single-device
    oracle — with each device's auditor raise-clean and attributing
    compiled blocks to its own pool."""
    obs = ObsConfig(audit="raise")
    kw = dict(hop=HOP, sa_noise_std=0.2, vad=VADConfig(), seed=0,
              obs=obs)
    oracle = StreamServer(folded, CFG, slots=4, **kw)
    plain = ShardedStreamServer(folded, CFG, devices=2, slots=2, **kw)
    fast = ShardedStreamServer(folded, CFG, devices=2, slots=2,
                               compiled=CompiledTickConfig(block=8),
                               **kw)
    for i in range(4):
        w = _duty(L + 12 * HOP, 500 + i)
        for srv in (oracle, plain, fast):
            srv.submit(f"s{i}", w)
            srv.finish(f"s{i}")
    ev_o, ev_p, ev_f = oracle.drain(), plain.drain(), fast.drain()
    eq.assert_events_equal(ev_p, ev_f, "sharded python vs compiled",
                           by_stream=True)
    eq.assert_events_equal(ev_o, ev_f, "oracle vs sharded compiled",
                           by_stream=True)
    for d, pool in enumerate(fast.pools):
        assert pool._compiled_ticks > 0
        s = pool.auditor.stats()
        assert s["violations"] == 0
        assert s["device"] == d
        assert s["calls"]["compiled"] == pool._compiled_blocks > 0


def test_compiled_stats_section(folded):
    srv = StreamServer(folded, CFG, hop=HOP, slots=2,
                       compiled=CompiledTickConfig(block=4))
    srv.submit("s0", _duty(L + 8 * HOP, 7))
    srv.finish("s0")
    srv.drain()
    st_ = srv.stats()["compiled"]
    assert st_["block"] == 4
    assert st_["ticks"] >= st_["blocks"] > 0
