import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core.quantize import (ACCUM_Q, ACT_Q, ERROR_Q, GRAD_Q, WEIGHT_Q,
                                 QFormat, error_scale_exponent, scale_error)

FORMATS = [WEIGHT_Q, ACT_Q, GRAD_Q, ERROR_Q, ACCUM_Q]


@pytest.mark.parametrize("fmt", FORMATS, ids=str)
def test_grid_roundtrip(fmt):
    # every representable code maps to itself
    codes = np.arange(fmt.qmin, fmt.qmax + 1)
    vals = codes * fmt.scale
    q = fmt.quantize(jnp.asarray(vals))
    np.testing.assert_allclose(np.asarray(q), vals, atol=0)


@pytest.mark.parametrize("fmt", FORMATS, ids=str)
def test_saturation(fmt):
    assert float(fmt.quantize(jnp.asarray(1e9))) == fmt.max_value
    assert float(fmt.quantize(jnp.asarray(-1e9))) == fmt.min_value


@given(st.lists(st.floats(-3, 3, allow_nan=False), min_size=1, max_size=64))
@settings(max_examples=30, deadline=None)
def test_quantize_is_nearest_grid_point(vals):
    fmt = WEIGHT_Q
    x = np.asarray(vals, np.float32)
    q = np.asarray(fmt.quantize(jnp.asarray(x)))
    # error bounded by half an LSB inside the range
    inside = (x >= fmt.min_value) & (x <= fmt.max_value)
    assert np.all(np.abs(q[inside] - x[inside]) <= fmt.scale / 2 + 1e-7)


def test_ste_gradient_clipped():
    fmt = WEIGHT_Q
    g = jax.grad(lambda x: jnp.sum(fmt.quantize_ste(x)))(
        jnp.asarray([0.5, 0.99, 2.0, -3.0]))
    np.testing.assert_allclose(np.asarray(g), [1.0, 1.0, 0.0, 0.0])


def test_error_scale_exponent_matches_eq2():
    err = jnp.asarray([0.001, -0.003, 0.002])   # all below half an LSB
    s = int(error_scale_exponent(err))
    assert s == int(np.ceil(np.log2(1.0 / 0.003)))
    scaled, scale = scale_error(err)
    # saturating quantization may clamp at qmin (= -1.0 for Q1.7)
    assert float(jnp.max(jnp.abs(scaled))) <= -ERROR_Q.min_value + 1e-9
    # scaling rescues sub-LSB errors from truncation to zero
    assert float(jnp.sum(jnp.abs(ERROR_Q.quantize(err)))) == 0.0
    assert float(jnp.sum(jnp.abs(scaled))) > 0.0


def test_fixed_hardware_scale():
    err = jnp.asarray([0.001, -0.004, 0.002])
    scaled, scale = scale_error(err, fixed_scale=1.375)
    assert float(scale) == 1.375


def test_error_scale_exponent_floor_keeps_headroom():
    """mode='floor': 2**s * max|err| lands in (1/2, 1] — the dominant
    error stays on-grid instead of saturating AT/ABOVE the rail the way
    the ceil form does by construction (the Q1.7-rail learning stall)."""
    err = jnp.asarray([0.001, -0.003, 0.002])
    s_c = int(error_scale_exponent(err))
    s_f = int(error_scale_exponent(err, mode="floor"))
    assert s_f == s_c - 1 == int(np.floor(np.log2(1.0 / 0.003)))
    m = float(jnp.max(jnp.abs(err)))
    assert m * 2.0 ** s_c >= 1.0          # ceil: at/above the rail
    assert 0.5 < m * 2.0 ** s_f <= 1.0    # floor: one bit of headroom
    # power-of-two max touches exactly 1.0 (the only rail contact)
    err2 = jnp.asarray([0.25, -0.125])
    s2 = int(error_scale_exponent(err2, mode="floor"))
    assert float(jnp.max(jnp.abs(err2))) * 2.0 ** s2 == 1.0
    # scale_error threads the mode through
    scaled, scale = scale_error(err, mode="floor")
    assert float(scale) == 2.0 ** s_f
    assert float(jnp.sum(jnp.abs(scaled))) > 0.0   # still rescues sub-LSB


def test_error_scale_exponent_clamped():
    err = jnp.asarray([1e-6, -2e-6])
    assert int(error_scale_exponent(err)) > 12
    assert int(error_scale_exponent(err, max_exponent=8)) == 8
    assert int(error_scale_exponent(err, mode="floor", max_exponent=8)) == 8
    # no-op clamp + zero-error identity preserved
    big = jnp.asarray([0.4])
    assert int(error_scale_exponent(big, max_exponent=8)) \
        == int(error_scale_exponent(big))
    assert int(error_scale_exponent(jnp.zeros(4), max_exponent=8)) == 0
    with pytest.raises(ValueError):
        error_scale_exponent(err, mode="round")


def test_paper_formats():
    assert WEIGHT_Q.total_bits == 8 and WEIGHT_Q.scale == 1 / 128
    assert ACT_Q.total_bits == 8 and ACT_Q.scale == 1 / 16
    assert ACT_Q.max_value == 127 / 16 and ACT_Q.min_value == -8.0
    assert ACCUM_Q.total_bits == 16
