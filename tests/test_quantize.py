import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core.quantize import (ACCUM_Q, ACT_Q, ERROR_Q, GRAD_Q, WEIGHT_Q,
                                 QFormat, error_scale_exponent, scale_error)

FORMATS = [WEIGHT_Q, ACT_Q, GRAD_Q, ERROR_Q, ACCUM_Q]


@pytest.mark.parametrize("fmt", FORMATS, ids=str)
def test_grid_roundtrip(fmt):
    # every representable code maps to itself
    codes = np.arange(fmt.qmin, fmt.qmax + 1)
    vals = codes * fmt.scale
    q = fmt.quantize(jnp.asarray(vals))
    np.testing.assert_allclose(np.asarray(q), vals, atol=0)


@pytest.mark.parametrize("fmt", FORMATS, ids=str)
def test_saturation(fmt):
    assert float(fmt.quantize(jnp.asarray(1e9))) == fmt.max_value
    assert float(fmt.quantize(jnp.asarray(-1e9))) == fmt.min_value


@given(st.lists(st.floats(-3, 3, allow_nan=False), min_size=1, max_size=64))
@settings(max_examples=30, deadline=None)
def test_quantize_is_nearest_grid_point(vals):
    fmt = WEIGHT_Q
    x = np.asarray(vals, np.float32)
    q = np.asarray(fmt.quantize(jnp.asarray(x)))
    # error bounded by half an LSB inside the range
    inside = (x >= fmt.min_value) & (x <= fmt.max_value)
    assert np.all(np.abs(q[inside] - x[inside]) <= fmt.scale / 2 + 1e-7)


def test_ste_gradient_clipped():
    fmt = WEIGHT_Q
    g = jax.grad(lambda x: jnp.sum(fmt.quantize_ste(x)))(
        jnp.asarray([0.5, 0.99, 2.0, -3.0]))
    np.testing.assert_allclose(np.asarray(g), [1.0, 1.0, 0.0, 0.0])


def test_error_scale_exponent_matches_eq2():
    err = jnp.asarray([0.001, -0.003, 0.002])   # all below half an LSB
    s = int(error_scale_exponent(err))
    assert s == int(np.ceil(np.log2(1.0 / 0.003)))
    scaled, scale = scale_error(err)
    # saturating quantization may clamp at qmin (= -1.0 for Q1.7)
    assert float(jnp.max(jnp.abs(scaled))) <= -ERROR_Q.min_value + 1e-9
    # scaling rescues sub-LSB errors from truncation to zero
    assert float(jnp.sum(jnp.abs(ERROR_Q.quantize(err)))) == 0.0
    assert float(jnp.sum(jnp.abs(scaled))) > 0.0


def test_fixed_hardware_scale():
    err = jnp.asarray([0.001, -0.004, 0.002])
    scaled, scale = scale_error(err, fixed_scale=1.375)
    assert float(scale) == 1.375


def test_paper_formats():
    assert WEIGHT_Q.total_bits == 8 and WEIGHT_Q.scale == 1 / 128
    assert ACT_Q.total_bits == 8 and ACT_Q.scale == 1 / 16
    assert ACT_Q.max_value == 127 / 16 and ACT_Q.min_value == -8.0
    assert ACCUM_Q.total_bits == 16
