"""Shared differential-equivalence harness for the serving test suite.

Every serving equivalence claim in this repo has the same shape: drive two
differently-configured servers (batched vs sequential admissions, gated vs
ungated, faulted vs rider-emulated, sharded vs single-device oracle,
compiled whole-tick block vs interpreted Python tick) over identical
traffic, then prove the observable record is BIT-identical — the decision
events, the stream/decision/VAD carry state, and the metrics-registry
counters.  These comparison loops used to be copy-pasted per test file;
they live here so the compiled fast path (``repro.serving.compiled``) is
proven against the exact same notion of "equal" as every older claim.

Counter comparison excludes exactly two registry names
(:data:`COUNTER_EXCLUDES`): wall-clock hop timing, which is real time and
can never be equal, and the ``serving.compiled`` block/tick counters,
which are the one deliberate observable difference between a compiled and
an interpreted run.  Everything else — hops, gated hops, decisions,
admissions, sheds, retires, latency histograms — must match cell for cell.
"""

from __future__ import annotations

import jax
import numpy as np

__all__ = [
    "COUNTER_EXCLUDES", "advance_to", "assert_counters_equal",
    "assert_events_equal", "assert_leaves_equal", "assert_server_equal",
    "counter_cells", "per_stream",
]

# registry names excluded from counter equality: wall time is physical,
# and serving.compiled counts blocks/ticks only the compiled server has
COUNTER_EXCLUDES = ("serving.hop_wall_s", "serving.compiled")


# ---------------------------------------------------------------------------
# events
# ---------------------------------------------------------------------------


def per_stream(events, strip=("device",)):
    """Events grouped per stream id, with ``strip`` tags removed.

    The sharded server tags each event with the device that produced it;
    per-stream equivalence against a single-device oracle compares every
    OTHER field, in per-stream order (global order across streams is a
    scheduling artifact, per-stream order is the contract)."""
    out = {}
    for ev in events:
        e = {k: v for k, v in ev.items() if k not in strip}
        out.setdefault(e.pop("stream"), []).append(e)
    return out


def assert_events_equal(ev_a, ev_b, what="", by_stream=False,
                        strip=("device",)):
    """Assert two event lists are identical, field for field.

    ``by_stream=False`` (the default) demands the exact same global event
    order — right when both sides run the same scheduler.  ``by_stream=
    True`` compares each stream's own event sequence after stripping
    ``strip`` tags — right when a sharded fleet's pools interleave
    differently than the oracle but every stream must still see the same
    decisions.  Returns the per-stream grouping of ``ev_a``."""
    if by_stream:
        pa, pb = per_stream(ev_a, strip), per_stream(ev_b, strip)
        assert pa.keys() == pb.keys(), \
            f"{what}: stream sets differ: {sorted(pa)} vs {sorted(pb)}"
        for sid in pa:
            assert pa[sid] == pb[sid], f"{what}: stream {sid} diverged"
        return pa
    assert ev_a == ev_b, (f"{what}: event lists diverged "
                          f"({len(ev_a)} vs {len(ev_b)} events)")
    return per_stream(ev_a, strip)


# ---------------------------------------------------------------------------
# pytree state
# ---------------------------------------------------------------------------


def assert_leaves_equal(tree_a, tree_b, what=""):
    """Bitwise equality of every array leaf of two pytrees."""
    la = jax.tree_util.tree_leaves(tree_a)
    lb = jax.tree_util.tree_leaves(tree_b)
    assert len(la) == len(lb), \
        f"{what}: leaf count {len(la)} vs {len(lb)}"
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y),
            err_msg=f"{what}: leaf {i} diverged")


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def counter_cells(srv, exclude=COUNTER_EXCLUDES):
    """The server's registry as a comparable ``{(name, labels): value}``
    dict; histogram cells flatten to ``(count, total, min, max)``."""
    out = {}
    for (name, labels), cell in srv._metrics._cells.items():
        if name in exclude:
            continue
        if hasattr(cell, "count"):          # histogram cell
            out[(name, labels)] = (cell.count, cell.total,
                                   cell.min, cell.max)
        else:
            out[(name, labels)] = cell
    return out


def assert_counters_equal(srv_a, srv_b, what="",
                          exclude=COUNTER_EXCLUDES):
    ca, cb = counter_cells(srv_a, exclude), counter_cells(srv_b, exclude)
    diff = {k: (ca.get(k), cb.get(k))
            for k in set(ca) | set(cb) if ca.get(k) != cb.get(k)}
    assert not diff, f"{what}: counter cells diverged: {diff}"


# ---------------------------------------------------------------------------
# whole-server comparison + lockstep driving
# ---------------------------------------------------------------------------


def assert_server_equal(srv_a, srv_b, what="", counters=True):
    """Full carry-state comparison between two StreamServers: stream
    rings, decision heads, VAD state, and (optionally) every registry
    cell outside :data:`COUNTER_EXCLUDES`."""
    assert_leaves_equal(srv_a._state, srv_b._state, f"{what} [stream]")
    assert_leaves_equal(srv_a._dstate, srv_b._dstate, f"{what} [decision]")
    assert (srv_a._vstate is None) == (srv_b._vstate is None), \
        f"{what}: VAD state presence differs"
    if srv_a._vstate is not None:
        assert_leaves_equal(srv_a._vstate, srv_b._vstate, f"{what} [vad]")
    if counters:
        assert_counters_equal(srv_a, srv_b, what)


def advance_to(srv, ticks):
    """Advance a server to an absolute tick count, via the compiled block
    path when one is attached (``step_block`` never overshoots ``ticks``)
    and the interpreted ``step`` otherwise.  Returns the events."""
    events = []
    if getattr(srv, "_compiled", None) is not None:
        while srv._steps < ticks:
            events.extend(srv.step_block(max_ticks=ticks - srv._steps))
    else:
        while srv._steps < ticks:
            events.extend(srv.step())
    return events
