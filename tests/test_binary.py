import jax
import jax.numpy as jnp
import numpy as np

from repro.core.binary import (binarize, binarize_sg, channel_shuffle,
                               or_maxpool, rsign)


def test_binarize_values_and_ste():
    x = jnp.asarray([-2.0, -0.5, 0.0, 0.5, 2.0])
    np.testing.assert_array_equal(np.asarray(binarize(x)),
                                  [-1, -1, 1, 1, 1])
    g = jax.grad(lambda x: jnp.sum(binarize(x)))(x)
    np.testing.assert_array_equal(np.asarray(g), [0, 1, 1, 1, 0])


def test_binarize_sg_forward_hard_backward_smooth():
    x = jnp.asarray([-0.5, 0.0, 0.5])
    np.testing.assert_array_equal(np.asarray(binarize_sg(x, 5.0)),
                                  [-1, 1, 1])
    g = jax.grad(lambda x: jnp.sum(binarize_sg(x, 5.0)))(x)
    # surrogate: alpha * sech^2(alpha x); peaked at 0
    assert float(g[1]) == 5.0
    assert 0 < float(g[0]) < 5.0


def test_rsign_offset_shifts_threshold():
    x = jnp.zeros((1, 4, 2))
    out_pos = rsign(x, jnp.asarray([0.1, 0.1]))
    out_neg = rsign(x, jnp.asarray([-0.1, -0.1]))
    assert np.all(np.asarray(out_pos) == 1)
    assert np.all(np.asarray(out_neg) == -1)


def test_channel_shuffle_is_permutation():
    x = jnp.arange(12.0).reshape(1, 1, 12)
    y = channel_shuffle(x, 3)
    assert sorted(np.asarray(y).ravel()) == sorted(np.asarray(x).ravel())
    assert not np.array_equal(np.asarray(y), np.asarray(x))
    # groups=1 is identity
    np.testing.assert_array_equal(np.asarray(channel_shuffle(x, 1)),
                                  np.asarray(x))


def test_or_maxpool_is_or():
    x = jnp.asarray([[-1, -1, 1, -1, 1, 1]], jnp.float32)[..., None]
    y = or_maxpool(x, 2, axis=1)
    np.testing.assert_array_equal(np.asarray(y)[0, :, 0], [-1, 1, 1])
