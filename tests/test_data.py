import numpy as np

from repro.data import audio
from repro.data.tokens import TokenPipelineConfig, batch_at_step


def test_audio_dataset_shapes_and_grid():
    (xtr, ytr), (xte, yte) = audio.make_gscd_like(train_per_class=3,
                                                  test_per_class=2,
                                                  length=800)
    assert xtr.shape == (30, 800) and xte.shape == (20, 800)
    assert set(np.unique(ytr)) == set(range(10))
    # 8-bit raw audio: values on the int8 grid (paper §II)
    codes = xtr * 127
    np.testing.assert_allclose(codes, np.round(codes), atol=1e-4)
    assert np.abs(xtr).max() <= 1.0


def test_audio_determinism():
    a, _ = audio.make_dataset(seed=5, n_per_class=2, n_speakers=3,
                              length=400)
    b, _ = audio.make_dataset(seed=5, n_per_class=2, n_speakers=3,
                              length=400)
    np.testing.assert_array_equal(a, b)


def test_personal_set_is_shifted():
    """Accent shift moves spectral mass (the customization premise)."""
    (xb, yb), _ = audio.make_gscd_like(train_per_class=6, test_per_class=2,
                                       length=1000)
    (xp, yp), _ = audio.make_personal(train_per_class=6, test_per_class=1,
                                      length=1000)
    def centroid(x):
        f = np.abs(np.fft.rfft(x, axis=1))
        freqs = np.arange(f.shape[1])
        return (f * freqs).sum(1) / (f.sum(1) + 1e-9)
    # personal speakers have systematically higher formants
    assert centroid(xp).mean() > centroid(xb).mean() * 1.02


def test_token_pipeline_deterministic_and_resumable():
    cfg = TokenPipelineConfig(vocab_size=1000, seq_len=64, global_batch=8,
                              seed=3)
    a1, b1 = batch_at_step(cfg, 17)
    a2, b2 = batch_at_step(cfg, 17)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(b1, b2)
    a3, _ = batch_at_step(cfg, 18)
    assert not np.array_equal(a1, a3)


def test_token_pipeline_host_sharding():
    full = TokenPipelineConfig(vocab_size=500, seq_len=32, global_batch=8,
                               seed=1)
    h0 = TokenPipelineConfig(vocab_size=500, seq_len=32, global_batch=8,
                             seed=1, num_hosts=2, host_id=0)
    h1 = TokenPipelineConfig(vocab_size=500, seq_len=32, global_batch=8,
                             seed=1, num_hosts=2, host_id=1)
    t0, _ = batch_at_step(h0, 0)
    t1, _ = batch_at_step(h1, 0)
    assert t0.shape == (4, 32) and t1.shape == (4, 32)
    assert not np.array_equal(t0, t1)       # hosts draw different data
    # labels are next-token shifted
    tokens, labels = batch_at_step(full, 2)
    np.testing.assert_array_equal(tokens[:, 1:], labels[:, :-1])
