import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import imc
from repro.models import kws as m

TINY = m.KWSConfig(sample_len=600)


def _rand_audio(n=4, cfg=TINY, seed=0):
    x = jax.random.uniform(jax.random.PRNGKey(seed), (n, cfg.sample_len),
                           minval=-1, maxval=1)
    return jnp.round(x * 127) / 127


def test_param_count_matches_paper():
    pc = m.PAPER_KWS.param_count()
    # paper: ~125K params, 171K model bits (Table II)
    assert 100_000 < pc["total"] < 135_000
    assert 140_000 < pc["model_bits"] < 180_000


def test_forward_shapes_and_finiteness():
    p = m.init_params(jax.random.PRNGKey(0), TINY)
    st = m.init_state(TINY)
    x = _rand_audio()
    logits, ns = m.forward_train(p, st, x, TINY)
    assert logits.shape == (4, 10)
    assert bool(jnp.isfinite(logits).all())
    logits2, feats = m.forward_eval(p, ns, x, TINY)
    assert feats.shape == (4, TINY.channels[-1])


def test_fold_consistency_eval_vs_hw():
    """With fixed BN, no noise, and biases inside the grid the hardware path
    must agree with the float eval path on (nearly) every decision."""
    p = m.init_params(jax.random.PRNGKey(1), TINY)
    st = m.init_state(TINY)
    x = _rand_audio(8, seed=2)
    lg_eval, feats_eval = m.forward_eval(p, st, x, TINY)
    hw = m.fold_params(p, st, TINY)
    lg_hw, feats_hw = m.hw_forward(hw, x, TINY)
    # The UNCONSTRAINED fold must match the float eval path bit-exactly —
    # the core fold-correctness property.  (The parity/range-constrained
    # fold diverges freely at random init because single-bit threshold
    # flips cascade through six binary layers; its accuracy cost on a
    # TRAINED model is what Table III measures, and the hw-exact training
    # phase drives it to ~zero — see benchmarks/kws_experiments.)
    hw_u = m.fold_params(p, st, TINY, bn_constraints=False)
    _, feats_u = m.hw_forward(hw_u, x, TINY)
    np.testing.assert_allclose(np.asarray(feats_u),
                               np.asarray(feats_eval), atol=1e-5)
    assert feats_hw.shape == feats_eval.shape


def test_hw_bias_on_grid():
    p = m.init_params(jax.random.PRNGKey(1), TINY)
    st = m.init_state(TINY)
    hw = m.fold_params(p, st, TINY)
    for name in TINY.imc_layer_names():
        b = np.asarray(hw.bias[name])
        assert np.all(b % 2 == 0) and np.all(np.abs(b) <= 64)


def test_mav_noise_changes_outputs_and_compensation_restores():
    p = m.init_params(jax.random.PRNGKey(3), TINY)
    st = m.init_state(TINY)
    x = _rand_audio(16, seed=4)
    hw = m.fold_params(p, st, TINY)
    chans = {f"conv{i}": TINY.channels[i]
             for i in range(1, TINY.num_conv_layers)}
    noise = imc.IMCNoiseParams(mav_offset_std=6.0, sa_noise_std=0.0)
    offs = imc.sample_chip_offsets(jax.random.PRNGKey(9), chans, noise)

    _, f_clean = m.hw_forward(hw, x, TINY)
    _, f_noisy = m.hw_forward(hw, x, TINY, chip_offsets=offs)
    assert np.mean(np.asarray(f_clean) != np.asarray(f_noisy)) > 0.01

    from repro.training.kws import calibrate_and_compensate
    hw_comp = calibrate_and_compensate(hw, np.asarray(x), offs, TINY)
    _, f_comp = m.hw_forward(hw_comp, x, TINY, chip_offsets=offs)
    d_noisy = np.mean(np.abs(np.asarray(f_clean) - np.asarray(f_noisy)))
    d_comp = np.mean(np.abs(np.asarray(f_clean) - np.asarray(f_comp)))
    assert d_comp < d_noisy                      # compensation helps


def test_hw_forward_kernel_path_matches():
    p = m.init_params(jax.random.PRNGKey(5), TINY)
    st = m.init_state(TINY)
    x = _rand_audio(2, seed=6)
    hw = m.fold_params(p, st, TINY)
    lg_a, f_a = m.hw_forward(hw, x, TINY, use_kernel=False)
    lg_b, f_b = m.hw_forward(hw, x, TINY, use_kernel=True)
    np.testing.assert_allclose(np.asarray(lg_a), np.asarray(lg_b),
                               atol=1e-5)


def test_layer_stats_energy_model():
    from repro.core.energy import kws_chip_report
    stats = m.layer_stats(m.PAPER_KWS)
    rep = kws_chip_report(stats, freq_hz=1e6)
    # the title claim: ~14 uJ per decision at 1 MHz
    assert 5e-6 < rep.energy_j_per_decision < 40e-6
    assert rep.latency_s == 0.16
