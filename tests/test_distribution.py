"""Distribution tests on a small host-platform mesh (subprocess: the device
count must be set before jax initializes, so these run in worker processes).

Covers: sharded train-step compile+run on a debug mesh, gradient compression
all-reduce numerics, and the dry-run driver on a tiny config."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


@pytest.mark.slow
def test_sharded_train_step_runs_on_debug_mesh():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_config
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.steps import (init_params_for, make_optimizer,
                                        make_train_step)
        from repro.launch.mesh_policy import MeshPolicy

        cfg = get_config("qwen2.5-14b").reduced()
        mesh = make_debug_mesh(2, 4)
        mp = MeshPolicy(mesh)
        with mesh:
            params = init_params_for(cfg)
            opt = make_optimizer(cfg)
            opt_state = opt.init(params)
            pspecs = mp.param_specs(params)
            step = jax.jit(
                make_train_step(cfg, mp.activation_policy(), opt),
                in_shardings=(mp.shardings(pspecs),
                              mp.shardings(mp.opt_state_specs(opt_state,
                                                              pspecs)),
                              None),
            )
            tokens = jnp.zeros((4, 32), jnp.int32) + 3
            batch = {"tokens": tokens, "labels": tokens}
            p2, o2, m = step(params, opt_state, batch)
            print("LOSS", float(m["loss"]))
    """)
    assert "LOSS" in out
    loss = float(out.strip().split("LOSS")[-1])
    import math
    assert math.isfinite(loss)


@pytest.mark.slow
def test_compressed_allreduce_close_to_exact():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.core.grad_compress import (compressed_allreduce_mean,
                                              exact_allreduce_mean)

        mesh = jax.make_mesh((8,), ("dp",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 1000)) * 0.01
        res = jnp.zeros((8, 1000))

        @jax.jit
        def run(g, res):
            def f(g, res):
                m, r = compressed_allreduce_mean(g[0], res[0], "dp")
                e = exact_allreduce_mean(g[0], "dp")
                return m[None], r[None], e[None]
            return shard_map(f, mesh=mesh,
                             in_specs=(P("dp"), P("dp")),
                             out_specs=(P("dp"), P("dp"), P("dp")))(g, res)

        mean, resid, exact = run(g, res)
        err = float(jnp.max(jnp.abs(mean - exact)))
        rel = err / float(jnp.max(jnp.abs(exact)))
        print("REL", rel)
        # error feedback residual bounded by one quantization step
        step = float(jnp.max(jnp.abs(g))) / 127
        print("RESID_OK", bool(jnp.max(jnp.abs(resid)) <= step * 1.01))
        # every device agrees on the reduced value
        print("AGREE", bool(jnp.max(jnp.abs(mean - mean[0:1])) == 0))
    """)
    rel = float(out.split("REL")[1].split()[0])
    assert rel < 0.05
    assert "RESID_OK True" in out
    assert "AGREE True" in out


@pytest.mark.slow
def test_dryrun_driver_tiny():
    """The dry-run driver end-to-end on a reduced arch and a small mesh."""
    out = _run("""
        import os
        import repro.launch.dryrun as dr
        import repro.launch.mesh as mesh_mod
        import jax
        # shrink the production mesh for the test process
        mesh_mod.make_production_mesh = (
            lambda multi_pod=False: jax.make_mesh((2, 4), ("data", "model")))
        import repro.configs.base as base
        import dataclasses
        cfg = base.get_config("qwen2.5-14b").reduced()
        base.SHAPES["tiny_train"] = dict(seq_len=64, global_batch=4,
                                         kind="train")
        import repro.configs.qwen2_5_14b as q
        q.CONFIG = cfg
        rec = dr.run_cell("qwen2.5-14b", "tiny_train", False)
        print("STATUS", rec["status"])
        print("DOM", rec["roofline"]["dominant"])
        print("COLL", rec["collectives"]["total"] > 0)
    """, devices=8)
    assert "STATUS ok" in out
    assert "COLL True" in out
