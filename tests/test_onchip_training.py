import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core.onchip_training import (OnChipTrainConfig, head_accuracy,
                                        lut_softmax, quantized_head_finetune,
                                        rgp_noise, sga_step, sga_threshold)
from repro.core.quantize import ACCUM_Q, ACT_Q, GRAD_Q, WEIGHT_Q


def test_lut_softmax_close_to_float():
    logits = ACT_Q.quantize(jax.random.normal(jax.random.PRNGKey(0),
                                              (32, 10)) * 2)
    p = lut_softmax(logits)
    ref = jax.nn.softmax(logits, axis=-1)
    assert float(jnp.max(jnp.abs(p - ref))) < 0.03     # 8-bit division grid
    np.testing.assert_allclose(np.asarray(jnp.sum(p, -1)), 1.0, atol=0.1)
    # argmax preserved (the decision the chip needs)
    np.testing.assert_array_equal(np.asarray(jnp.argmax(p, -1)),
                                  np.asarray(jnp.argmax(ref, -1)))


def test_sga_threshold_eq3():
    # Table I / Eq (3): min(weight)=1/128
    assert abs(float(sga_threshold(0.05)) - 0.078125) < 1e-6
    assert abs(float(sga_threshold(0.01)) - 0.390625) < 1e-6


def test_sga_small_gradients_bank_and_fire():
    g_th = jnp.asarray(0.1)
    g = jnp.full((4,), 0.04)
    accum = jnp.zeros((4,))
    fired = []
    for _ in range(5):
        upd, accum = sga_step(g, accum, g_th)
        fired.append(np.asarray(upd))
    fired = np.stack(fired)
    # updates are zero until the bank crosses the threshold, then release
    assert np.all(fired[0] == 0) and np.all(fired[1] == 0)
    assert fired.sum() > 0
    # released mass approximates the banked gradient sum (16-bit grid)
    total = fired.sum(axis=0) + np.asarray(accum)
    np.testing.assert_allclose(total, 0.2, atol=ACCUM_Q.scale * 10)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_sga_never_loses_gradient_mass(seed):
    """Property: banked + released == sum of applied gradients (up to the
    16-bit accumulator grid) — the error-feedback invariant."""
    key = jax.random.PRNGKey(seed)
    g_th = jnp.asarray(0.2)
    gs = jax.random.uniform(key, (20, 8), minval=-0.15, maxval=0.15)
    accum = jnp.zeros((8,))
    released = jnp.zeros((8,))
    for t in range(20):
        upd, accum = sga_step(gs[t], accum, g_th)
        released = released + upd
    total = np.asarray(released + accum)
    want = np.asarray(jnp.sum(gs, axis=0))
    np.testing.assert_allclose(total, want, atol=20 * ACCUM_Q.scale + 1e-6)


def test_large_gradients_pass_through():
    g = jnp.asarray([0.5, -0.7])
    upd, accum = sga_step(g, jnp.zeros(2), jnp.asarray(0.1))
    np.testing.assert_allclose(np.asarray(upd), np.asarray(g))
    np.testing.assert_allclose(np.asarray(accum), 0.0)


def test_rgp_noise_on_grid():
    n = rgp_noise(jax.random.PRNGKey(0), (1000,), lam=8.0)
    codes = np.asarray(n) * 128
    np.testing.assert_allclose(codes, np.round(codes), atol=1e-5)
    assert np.abs(np.asarray(n)).mean() < 0.2


def _toy_head_problem(n=90, d=64, c=10, seed=0, sep=2.0):
    """Linearly separable features like the customization setting."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(c, d)) * sep / np.sqrt(d)
    y = np.repeat(np.arange(c), n // c)
    x = centers[y] + 0.3 * rng.normal(size=(len(y), d)) / np.sqrt(d)
    return jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.int32)


def test_quantized_finetune_recovers_accuracy():
    """The paper's Table IV structure on a toy head: naive quantized FT is
    poor; + error scaling + SGA approaches the FP baseline."""
    x, y = _toy_head_problem()
    d, c = x.shape[1], 10
    k = jax.random.PRNGKey(1)
    w0 = jax.random.normal(k, (d, c)) * 0.05
    b0 = jnp.zeros((c,))

    accs = {}
    for name, kw in {
        "fp": dict(quantized=False, epochs=300),
        "naive": dict(quantized=True, error_scaling=False, sga=False,
                      epochs=300),
        "es_sga": dict(quantized=True, error_scaling=True, sga=True,
                       epochs=300),
    }.items():
        cfg = OnChipTrainConfig(**kw)
        w, b = quantized_head_finetune(x, y, w0, b0, cfg)
        accs[name] = float(head_accuracy(x, y, w, b, cfg))
    assert accs["fp"] > 0.9
    assert accs["es_sga"] >= accs["naive"] - 0.05
    assert accs["es_sga"] > 0.8
