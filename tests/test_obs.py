"""The observability contract (repro.obs + its serving integration):

* the metrics registry keys cells by (name, labels), keeps counter /
  gauge / histogram kinds apart, snapshots/restores and merges with the
  documented semantics (counters sum, gauges last-write, hists pool);
* the flight recorder is a bounded ring: wraparound keeps the newest
  events, ``dropped()`` counts the fallen, snapshots round-trip;
* the launch auditor catches a deliberately doubled batched hop (flag
  records, raise throws), a gate region that traces kernels, and a
  per-call over-trace — and reports ZERO violations on real gated /
  faulted / canary / learning traffic in raise mode;
* telemetry fully on (registry + recorder + auditor raise + trace) is
  bit-identical to telemetry off — SA noise, chip offsets and fault
  models included;
* ``StreamServer.snapshot()`` v2 round-trips the registry and recorder,
  and the restored server's subsequent events are bit-identical;
* the Chrome/Perfetto export and the Prometheus text render are
  well-formed.
"""

import json
import re

import jax
import numpy as np
import pytest

from repro.core import faults as flt
from repro.core import imc
from repro.models import kws as m
from repro.obs import (FlightRecorder, LaunchAuditError, LaunchAuditor,
                       MetricsRegistry, ObsConfig, TraceBuilder,
                       counter_property)
from repro.serving import HealthConfig, StreamServer, VADConfig

L, HOP = 640, 64
CFG = m.KWSConfig(sample_len=L)


@pytest.fixture(scope="module")
def folded():
    params = m.init_params(jax.random.PRNGKey(5), CFG)
    state = m.init_state(CFG)
    return m.fold_params(params, state, CFG, pack=True)


def _chip(std=4.0):
    chans = {f"conv{i}": CFG.channels[i]
             for i in range(1, CFG.num_conv_layers)}
    return imc.sample_chip_offsets(
        jax.random.PRNGKey(9), chans,
        imc.IMCNoiseParams(mav_offset_std=std))


def _gated_wav(rng, n_hops=12, quiet=(4, 9)):
    """Speech with a mid-stream silent stretch: init + hops + gated
    fills + a wake replay in one drain."""
    wav = rng.uniform(-1, 1, L + n_hops * HOP).astype(np.float32)
    wav[L + quiet[0] * HOP:L + quiet[1] * HOP] *= 1e-4
    return wav


_VAD = VADConfig(threshold_on_db=-40.0, threshold_off_db=-50.0,
                 wake_margin=1, hang=0)

_OBS_ON = ObsConfig(recorder=64, audit="raise", trace=True)


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------


def test_registry_kinds_labels_values():
    reg = MetricsRegistry()
    reg.inc("calls", cause="hop")
    reg.inc("calls", 3, cause="hop")
    reg.inc("calls", cause="gate")
    reg.set_gauge("depth", 7)
    reg.observe("uj", 2.0)
    reg.observe("uj", 4.0)
    assert reg.value("calls", cause="hop") == 4
    assert reg.value("calls", cause="gate") == 1
    assert reg.value("calls") == 0               # unlabelled cell absent
    assert reg.total("calls") == 5
    assert reg.value("depth") == 7
    h = reg.value("uj")
    assert h["count"] == 2 and h["sum"] == 6.0
    assert h["min"] == 2.0 and h["max"] == 4.0 and h["mean"] == 3.0
    assert {"cause": "hop"} in reg.labels("calls")
    col = reg.collect()
    assert col["calls"]["kind"] == "counter"
    assert col["uj"]["kind"] == "histogram"
    # label order never splits a cell
    reg.inc("pair", a=1, b=2)
    reg.inc("pair", b=2, a=1)
    assert reg.value("pair", a=1, b=2) == 2


def test_registry_kind_conflict_raises():
    reg = MetricsRegistry()
    reg.inc("x")
    with pytest.raises(ValueError, match="already registered"):
        reg.set_gauge("x", 1)
    with pytest.raises(ValueError, match="already registered"):
        reg.observe("x", 1.0)


def test_registry_snapshot_restore_roundtrip():
    reg = MetricsRegistry()
    reg.inc("c", 5, cause="hop")
    reg.set_gauge("g", -2.5)
    reg.observe("h", 1.0, layer="conv2")
    reg.observe("h", 9.0, layer="conv2")
    snap = reg.snapshot()
    json.dumps(snap)                             # JSON-serializable
    reg2 = MetricsRegistry()
    reg2.inc("junk")                             # must be cleared
    reg2.restore(snap)
    assert reg2.snapshot() == snap
    assert reg2.value("junk", default=None) is None
    assert reg2.value("h", layer="conv2") == reg.value("h", layer="conv2")
    # restored cells keep their write paths working
    reg2.inc("c", cause="hop")
    assert reg2.value("c", cause="hop") == 6
    with pytest.raises(ValueError, match="version"):
        reg2.restore({"version": 99, "cells": []})


def test_registry_merge_semantics():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.inc("c", 2)
    b.inc("c", 3)
    a.set_gauge("g", 1)
    b.set_gauge("g", 9)
    a.observe("h", 1.0)
    b.observe("h", 5.0)
    b.inc("only_b", kind="x")
    a.merge(b)
    assert a.value("c") == 5                     # counters sum
    assert a.value("g") == 9                     # gauges last-write
    h = a.value("h")                             # histograms pool
    assert h["count"] == 2 and h["min"] == 1.0 and h["max"] == 5.0
    assert a.value("only_b", kind="x") == 1
    b2 = MetricsRegistry()
    b2.set_gauge("c", 1)
    with pytest.raises(ValueError, match="already registered"):
        a.merge(b2)


def test_registry_prometheus_text():
    reg = MetricsRegistry()
    reg.inc("serving.batched_calls", 4, cause="hop")
    reg.set_gauge("health.state", 0)
    reg.observe("serving.tick_uj", 2.5)
    text = reg.prometheus_text()
    lines = text.strip().split("\n")
    assert "# TYPE serving_batched_calls counter" in lines
    assert 'serving_batched_calls{cause="hop"} 4' in lines
    assert "# TYPE health_state gauge" in lines
    assert "# TYPE serving_tick_uj summary" in lines
    assert "serving_tick_uj_count 1" in lines
    assert "serving_tick_uj_sum 2.5" in lines
    # every sample line is name{labels}? value
    sample = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*"
                        r"(\{[a-zA-Z0-9_]+=\"[^\"]*\""
                        r"(,[a-zA-Z0-9_]+=\"[^\"]*\")*\})? \S+$")
    for line in lines:
        if not line.startswith("#"):
            assert sample.match(line), line


def test_counter_property_attribute_api():
    class Holder:
        n = counter_property("demo.n")
        k = counter_property("demo.k", cause="hop")

        def __init__(self):
            self._metrics = MetricsRegistry()

    h = Holder()
    assert h.n == 0
    h.n += 1
    h.n += 1
    h.k = 5
    assert h.n == 2
    assert h._metrics.value("demo.n") == 2
    assert h._metrics.value("demo.k", cause="hop") == 5
    h._metrics.set_counter("demo.n", 9)
    assert h.n == 9                              # reads go through too


# ---------------------------------------------------------------------------
# Flight recorder
# ---------------------------------------------------------------------------


def test_recorder_wraparound_and_dropped():
    rec = FlightRecorder(capacity=4)
    for i in range(10):
        rec.record(i, "tick", uj=float(i))
    assert len(rec) == 4
    assert rec.dropped() == 6
    evs = rec.events()
    assert [e["seq"] for e in evs] == [6, 7, 8, 9]
    assert [e["tick"] for e in evs] == [6, 7, 8, 9]
    rec.record(10, "admit", stream="s0")
    assert rec.events("admit")[0]["stream"] == "s0"
    assert len(rec.events("tick")) == 3
    with pytest.raises(ValueError):
        FlightRecorder(capacity=0)


def test_recorder_snapshot_roundtrip_and_dump(tmp_path):
    rec = FlightRecorder(capacity=3)
    for i in range(5):
        rec.record(i, "tick", computed=i)
    snap = rec.snapshot()
    json.dumps(snap)
    rec2 = FlightRecorder(capacity=8)
    rec2.restore(snap)
    assert rec2.capacity == 3
    assert rec2.events() == rec.events()
    assert rec2.dropped() == rec.dropped()
    rec2.record(5, "tick")                       # seq continues
    assert rec2.events()[-1]["seq"] == 5
    path = tmp_path / "flight.jsonl"
    assert rec.dump(path) == 3
    got = [json.loads(line) for line in path.read_text().splitlines()]
    assert got == rec.events()
    with pytest.raises(ValueError, match="version"):
        rec2.restore({"version": 99})


# ---------------------------------------------------------------------------
# ObsConfig
# ---------------------------------------------------------------------------


def test_obsconfig_validation_and_env(monkeypatch):
    assert ObsConfig() == ObsConfig(recorder=0, audit="off", trace=False)
    with pytest.raises(ValueError):
        ObsConfig(audit="bogus")
    with pytest.raises(ValueError):
        ObsConfig(recorder=-1)
    monkeypatch.setenv("REPRO_OBS_RECORDER", "32")
    monkeypatch.setenv("REPRO_OBS_AUDIT", "raise")
    monkeypatch.setenv("REPRO_OBS_TRACE", "1")
    assert ObsConfig.from_env() == ObsConfig(recorder=32, audit="raise",
                                             trace=True)
    monkeypatch.setenv("REPRO_OBS_TRACE", "0")
    assert not ObsConfig.from_env().trace


# ---------------------------------------------------------------------------
# Launch auditor
# ---------------------------------------------------------------------------


def test_auditor_catches_doubled_hop():
    """Two batched hop calls in one tick — a per-slot hop loop — must be
    flagged: that is exactly the regression the one-launch contract
    forbids."""
    aud = LaunchAuditor(imc_layers=5, mode="flag")
    aud.begin_tick(0)
    with aud.region("hop"):
        pass
    with aud.region("hop"):
        pass
    aud.end_tick()
    assert len(aud.violations) == 1
    assert aud.violations[0]["cause"] == "hop"
    assert aud.stats()["max_hop_calls_per_tick"] == 2

    aud = LaunchAuditor(imc_layers=5, mode="raise")
    aud.begin_tick(0)
    with aud.region("hop"):
        pass
    with aud.region("hop"):
        pass
    with pytest.raises(LaunchAuditError, match="hop"):
        aud.end_tick()


def test_auditor_gate_and_overtrace_rules():
    aud = LaunchAuditor(imc_layers=5, mode="raise")
    aud.begin_tick(0)
    # a gate fill must trace zero kernels
    with pytest.raises(LaunchAuditError, match="gate"):
        aud._on_call("gate", traced=1)
    # a compute call may trace up to imc_layers fresh launches (nested
    # per-layer jits cache across outer traces), never more
    aud = LaunchAuditor(imc_layers=5, mode="raise")
    aud.begin_tick(0)
    aud._on_call("hop", traced=5)
    with pytest.raises(LaunchAuditError, match="replay"):
        aud._on_call("replay", traced=6)
    # doubled init only violates on batched-admission servers
    aud = LaunchAuditor(imc_layers=5, mode="flag", batch_init=False)
    aud.begin_tick(0)
    aud._on_call("init", traced=0)
    aud._on_call("init", traced=0)
    aud.end_tick()
    assert aud.violations == []
    aud = LaunchAuditor(imc_layers=5, mode="flag", batch_init=True)
    aud.begin_tick(0)
    aud._on_call("init", traced=0)
    aud._on_call("init", traced=0)
    aud.end_tick()
    assert [v["cause"] for v in aud.violations] == ["init"]

    with pytest.raises(ValueError):
        LaunchAuditor(imc_layers=5, mode="sometimes")
    with pytest.raises(ValueError):
        LaunchAuditor(imc_layers=0)
    aud = LaunchAuditor(imc_layers=5)
    with pytest.raises(ValueError):
        with aud.region("bogus"):
            pass


def test_auditor_device_label_attribution():
    """Sharded pools label their auditors: the device rides every
    violation dict, the raise message, and stats() — so fleet rollups
    can attribute a broken launch contract to the pool that broke it."""
    aud = LaunchAuditor(imc_layers=5, mode="flag", device=1)
    aud.begin_tick(0)
    with aud.region("hop"):
        pass
    with aud.region("hop"):
        pass
    aud.end_tick()
    assert aud.violations[0]["device"] == 1
    assert aud.stats()["device"] == 1
    aud = LaunchAuditor(imc_layers=5, mode="raise", device=3)
    aud.begin_tick(0)
    with pytest.raises(LaunchAuditError, match=r"device 3"):
        aud._on_call("gate", traced=1)
    # unlabeled auditors keep the historical stats shape
    assert "device" not in LaunchAuditor(imc_layers=5).stats()


def test_auditor_history_attribution():
    aud = LaunchAuditor(imc_layers=5, mode="flag", history=2)
    for tick in range(3):
        aud.begin_tick(tick)
        with aud.region("hop"):
            pass
        if tick == 0:
            with aud.region("gate"):
                pass
        aud.end_tick()
    hist = aud.history()
    assert len(hist) == 2                        # bounded
    assert [h["tick"] for h in hist] == [1, 2]
    assert all(h["calls"]["hop"] == 1 for h in hist)
    assert all(h["launches_per_layer"] == 1 for h in hist)
    s = aud.stats()
    assert s["ticks"] == 3 and s["violations"] == 0
    assert s["calls"]["hop"] == 3 and s["calls"]["gate"] == 1


# ---------------------------------------------------------------------------
# Serving integration: bit-exactness, audit-clean traffic, snapshots
# ---------------------------------------------------------------------------


def _run(folded, obs, wavs, **kw):
    srv = StreamServer(folded, CFG, hop=HOP, slots=len(wavs),
                       use_kernel=True, vad=_VAD, seed=3, obs=obs, **kw)
    for k, v in wavs.items():
        srv.submit(k, v)
        srv.finish(k)
    return srv, srv.drain()


@pytest.mark.streaming
def test_telemetry_bitexact_gated_noise_offsets(folded):
    """Telemetry fully on — registry + recorder + auditor in raise mode +
    trace spans — must not change a single decision on the gated
    SA-noise + chip-offset configuration."""
    rng = np.random.default_rng(7)
    wavs = {f"s{i}": _gated_wav(rng) for i in range(2)}
    kw = dict(sa_noise_std=0.9, chip_offsets=_chip())
    _, ev_off = _run(folded, ObsConfig(), wavs, **kw)
    srv, ev_on = _run(folded, _OBS_ON, wavs, **kw)
    assert ev_on == ev_off
    assert len(ev_off) > 0
    s = srv.auditor.stats()
    assert s["violations"] == 0
    assert s["max_hop_calls_per_tick"] <= 1
    assert s["calls"]["gate"] > 0                # silence actually gated
    assert s["calls"]["replay"] > 0              # wake replay ran audited
    assert len(srv.recorder.events("tick")) > 0
    assert len(srv.trace) > 0


@pytest.mark.streaming
def test_telemetry_bitexact_with_faults_and_canaries(folded):
    """Same bit-identity with the fault model loaded and canary health
    windows riding the ticks — and the auditor stays clean in raise mode
    across the canary traffic."""
    rng = np.random.default_rng(8)
    wavs = {"s0": _gated_wav(rng, n_hops=14)}
    kw = dict(chip_offsets=_chip(), faults=flt.FaultConfig(seed=5),
              health=HealthConfig(interval=4))
    srv_off, ev_off = _run(folded, ObsConfig(), wavs, **kw)
    srv_on, ev_on = _run(folded, _OBS_ON, wavs, **kw)
    assert ev_on == ev_off
    assert srv_on.health.canaries >= 1           # canaries actually ran
    assert srv_on.health.canaries == srv_off.health.canaries
    assert srv_on.auditor.stats()["violations"] == 0


@pytest.mark.streaming
def test_audit_clean_mixed_learning_traffic(folded):
    """The one-launch contract holds with an enrollment session's
    learning hops sharing ticks with live inference: auditor in raise
    mode, zero violations, at most one batched hop per tick."""
    from repro.core.onchip_training import OnChipTrainConfig
    from repro.serving import CustomizeConfig

    rng = np.random.default_rng(9)
    srv = StreamServer(folded, CFG, hop=HOP, slots=3, use_kernel=True,
                       vad=_VAD, seed=3, obs=_OBS_ON)
    sess = srv.customize("u0", CustomizeConfig(
        train=OnChipTrainConfig(epochs=8, fixed_error_scale=1.375),
        epochs_per_tick=4, layers_per_tick=5))
    for c in range(2):
        sess.enroll(c, rng.uniform(-1, 1, L).astype(np.float32))
    sess.finish_enrollment()
    srv.submit("live", _gated_wav(rng))
    srv.finish("live")
    events = srv.drain()
    steps = 0
    while not sess.done and steps < 500:
        srv.step()
        steps += 1
    assert sess.done
    assert len(events) > 0
    s = srv.auditor.stats()
    assert s["violations"] == 0
    assert s["max_hop_calls_per_tick"] <= 1
    assert srv.stats()["learn_hops"] > 0
    assert srv.metrics.value("customize.sessions") == 1
    assert srv.metrics.value("customize.epochs") == sess.result.epochs


@pytest.mark.streaming
def test_server_counters_live_in_registry(folded):
    """The scheduler/health stats() counters are views over the one
    registry — no parallel hand-rolled counter lists left to drift."""
    rng = np.random.default_rng(10)
    srv, events = _run(folded, _OBS_ON, {"s0": _gated_wav(rng)})
    reg = srv.metrics
    st = srv.stats()
    assert reg.value("serving.steps") == srv._steps
    assert reg.value("serving.decisions") == len(events)
    assert reg.value("serving.batched_calls", cause="hop") == srv._hop_calls
    assert (reg.value("serving.batched_calls", cause="gate")
            == st["batched_calls"]["gate"])
    assert reg.value("serving.hops", kind="speech") == st["speech_hops"]
    assert reg.value("serving.hops", kind="gated") == st["gated_hops"]
    assert reg.value("serving.tick_uj")["count"] > 0
    assert st["obs"]["recorder"]["events"] == len(srv.recorder)
    assert st["obs"]["audit"]["violations"] == 0


@pytest.mark.streaming
def test_snapshot_v2_roundtrips_registry_and_recorder(folded, tmp_path):
    """Snapshot mid-run with telemetry on; the restored server carries
    the same registry cells and recorder ring, and its subsequent
    decisions are bit-identical."""
    rng = np.random.default_rng(11)
    wav = _gated_wav(rng, n_hops=12)
    head, tail = wav[:L + 5 * HOP], wav[L + 5 * HOP:]

    srv = StreamServer(folded, CFG, hop=HOP, slots=1, use_kernel=True,
                       vad=_VAD, seed=3, obs=_OBS_ON)
    srv.submit("s0", head)
    for _ in range(6):
        srv.step()
    path = tmp_path / "server.npz"
    srv.snapshot(path)
    srv2 = StreamServer(folded, CFG, hop=HOP, slots=1, use_kernel=True,
                        vad=_VAD, seed=3, obs=_OBS_ON)
    srv2.restore(path)
    assert srv2.metrics.snapshot() == srv.metrics.snapshot()
    assert srv2.recorder.events() == srv.recorder.events()
    assert srv2._steps == srv._steps
    ev1, ev2 = [], []
    for s, ev in ((srv, ev1), (srv2, ev2)):
        s.submit("s0", tail)
        s.finish("s0")
        ev.extend(s.drain())
    assert ev1 == ev2

    def deterministic(reg):
        # wall-clock counters legitimately differ between processes
        return [c for c in reg.snapshot()["cells"]
                if "wall" not in c[0]]

    assert deterministic(srv2.metrics) == deterministic(srv.metrics)


@pytest.mark.streaming
def test_trace_export_and_prometheus_render(folded, tmp_path):
    rng = np.random.default_rng(12)
    srv, _ = _run(folded, _OBS_ON, {"s0": _gated_wav(rng)})
    doc = srv.trace.to_chrome()
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert evs[0] == {"name": "process_name", "ph": "M", "pid": 0,
                      "args": {"name": "repro.serving"}}
    names = {e["name"] for e in evs[1:]}
    assert {"tick", "hop", "gate", "riders"} <= names
    for e in evs[1:]:
        assert e["ph"] == "X"
        assert e["ts"] >= 0 and e["dur"] >= 0
        assert "tick" in e["args"]
    ticks = [e for e in evs[1:] if e["name"] == "tick"]
    assert all("uj" in e["args"] for e in ticks)
    path = tmp_path / "trace.json"
    n = srv.trace.dump(path)
    assert n == len(srv.trace)
    assert json.loads(path.read_text())["traceEvents"][0]["ph"] == "M"
    text = srv.metrics.prometheus_text()
    assert 'serving_batched_calls{cause="hop"}' in text
    assert "serving_tick_uj_count" in text


@pytest.mark.streaming
def test_sharded_per_device_one_launch_audit(folded, monkeypatch):
    """The one-launch-per-layer contract is PER DEVICE under sharding:
    with inference, canary health windows and an enrollment session's
    learning hops mixed across 2 device pools, every pool's auditor
    (armed via ``REPRO_OBS_AUDIT=raise``) sees at most one batched hop
    per tick, zero traced kernels on gate fills, and zero violations —
    and each auditor carries its pool's device label."""
    from repro.core.onchip_training import OnChipTrainConfig
    from repro.serving import (CustomizeConfig, HealthConfig,
                               ShardedStreamServer)

    monkeypatch.setenv("REPRO_OBS_AUDIT", "raise")
    rng = np.random.default_rng(21)
    sh = ShardedStreamServer(folded, CFG, devices=2, slots=3, hop=HOP,
                             use_kernel=True, vad=_VAD, seed=3,
                             health=HealthConfig(interval=4))
    sess = sh.customize("u0", CustomizeConfig(
        train=OnChipTrainConfig(epochs=8, fixed_error_scale=1.375),
        epochs_per_tick=4, layers_per_tick=5))
    for c in range(2):
        sess.enroll(c, rng.uniform(-1, 1, L).astype(np.float32))
    sess.finish_enrollment()
    for i in range(3):                      # live gated traffic, both pools
        sh.submit(f"live{i}", _gated_wav(rng))
        sh.finish(f"live{i}")
    events = sh.drain()
    steps = 0
    while not sess.done and steps < 500:
        sh.step()
        steps += 1
    assert sess.done and len(events) > 0
    assert {sh.where(f"live{i}") for i in range(3)} == {0, 1}
    st = sh.stats()
    assert st["audit"]["violations"] == 0
    for d, srv in enumerate(sh.pools):
        s = srv.auditor.stats()
        assert s["device"] == d
        assert s["mode"] == "raise"          # the env arming reached it
        assert s["violations"] == 0
        assert s["max_hop_calls_per_tick"] <= 1
        assert s["calls"]["hop"] > 0         # every pool actually computed
        assert s["traced_launches"] > 0      # fresh pallas traces counted
        for h in srv.auditor.history():
            assert h["launches_per_layer"] <= 3   # init+hop+replay bound
    # gate fills ran somewhere in the fleet and traced nothing (a traced
    # gate would have raised above)
    assert sum(p.auditor.stats()["calls"]["gate"] for p in sh.pools) > 0
    learn = sum(p.stats()["learn_hops"] for p in sh.pools)
    assert learn > 0                         # learning rode the batches


def test_trace_builder_relative_timestamps():
    tb = TraceBuilder(process_name="p")
    tb.span("a", 10.0, 10.5, tick=0)
    tb.span("b", 11.0, 11.25, tick=1)
    tb.counter("c", 11.5, depth=3)
    tb.instant("i", 12.0)
    evs = tb.to_chrome()["traceEvents"][1:]
    assert evs[0]["ts"] == 0.0 and evs[0]["dur"] == 5e5
    assert evs[1]["ts"] == 1e6 and evs[1]["dur"] == 2.5e5
    assert evs[2]["ph"] == "C" and evs[2]["args"] == {"depth": 3}
    assert evs[3]["ph"] == "i" and evs[3]["ts"] == 2e6
