"""Smoke-run the runnable examples so they can't silently rot.

Each example honours REPRO_EXAMPLES_SMOKE=1 (reduced window / stream
count / epoch counts — seconds-scale, mechanics identical).  They run
in-process via runpy (sharing the already-initialized JAX runtime), with
stdout captured and a couple of landmark lines asserted.
"""

import os
import runpy

import pytest

EXAMPLES = os.path.join(os.path.dirname(__file__), "..", "examples")


def _run(name, monkeypatch, capsys):
    monkeypatch.setenv("REPRO_EXAMPLES_SMOKE", "1")
    runpy.run_path(os.path.join(EXAMPLES, name), run_name="__main__")
    return capsys.readouterr().out


@pytest.mark.slow
def test_customize_onchip_example(monkeypatch, capsys):
    out = _run("customize_onchip.py", monkeypatch, capsys)
    assert "before customization" in out
    assert "+ SGA" in out
    # the serving-session demo ran and matched the offline loop bit-exactly
    assert "bit-identical to the offline loop" in out


@pytest.mark.slow
def test_stream_kws_example(monkeypatch, capsys):
    out = _run("stream_kws.py", monkeypatch, capsys)
    assert "serving 1 streams" in out
    assert "decisions" in out
    assert "VAD duty cycle" in out
