"""The streaming serving contract (repro.serving):

* N hops of the frame-incremental path are bit-identical to per-window
  ``hw_forward`` — clean, chip-offset and SA-noise configurations (the
  noise comes from the per-absolute-column field; the offline window
  evaluates the same field via ``hw_forward(sa_noise=...)``);
* the GAP ring and every layer carry survive full wraparound;
* the ``streaming=False`` fallback recomputes exactly ``hw_forward``;
* the scheduler batches every ready slot into ONE fused-kernel launch per
  IMC layer, admits/evicts under randomized arrival, and each stream's
  decisions match a dedicated single-stream engine bit-for-bit;
* the decision head smooths, fires once (hysteresis) and respects the
  refractory window;
* voice-activity gating: with the VAD forced to "speech" the gated server
  is bit-identical to an ungated one (noise + chip offsets included);
  silent hops launch NO Pallas kernels (the no-op fill advance); a
  silence run within ``wake_margin`` is replayed on wake so the decision
  sequence matches ungated streaming exactly; gated hops are charged
  leakage-only in the energy model (>= 3x reduction at 20% duty);
* backpressure: bounded admission queue rejects, the latency SLO sheds
  backlog, slots autoscale between min_slots/max_slots;
* dynamic hop: calm posteriors widen the effective hop, activity narrows
  it back, states are rebuilt across the change.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st
from jax.experimental import pallas as pl

import _equiv as eq

from repro.core import energy, imc
from repro.models import kws as m
from repro.serving import (AdmissionConfig, DecisionConfig,
                           DynamicHopConfig, StreamEngine, StreamServer,
                           VADConfig, decision_init, decision_step,
                           hop_alignment, make_stream_geometry,
                           streaming_layer_stats, vad_init, vad_step,
                           window_sa_noise)
from repro.serving import stream as sv

L, HOP = 640, 64
CFG = m.KWSConfig(sample_len=L)


@pytest.fixture(scope="module")
def folded():
    params = m.init_params(jax.random.PRNGKey(5), CFG)
    state = m.init_state(CFG)
    return m.fold_params(params, state, CFG, pack=True)


def _audio(key, n, batch=1):
    return jax.random.uniform(jax.random.PRNGKey(key), (batch, n),
                              minval=-1, maxval=1)


def _chip(std=4.0):
    chans = {f"conv{i}": CFG.channels[i]
             for i in range(1, CFG.num_conv_layers)}
    return imc.sample_chip_offsets(
        jax.random.PRNGKey(9), chans,
        imc.IMCNoiseParams(mav_offset_std=std))


# ---------------------------------------------------------------------------
# Geometry
# ---------------------------------------------------------------------------


def test_geometry_alignment_and_shapes():
    assert hop_alignment(CFG) == 64
    geom = make_stream_geometry(CFG, HOP)
    t_in, d_in = L, HOP
    for i, lg in enumerate(geom.layers):
        k, s, p = CFG.kernels[i], CFG.strides[i], CFG.pools[i]
        assert lg.t_in == t_in and lg.d_in == d_in
        assert lg.t_conv == (t_in - k) // s + 1
        assert lg.t_out == lg.t_conv // p
        assert lg.carry == lg.tail_in - lg.d_in
        # the tail's conv start is pool-aligned in the full window
        assert lg.conv_lo % p == 0
        # conv over the tail produces exactly the fresh (+re-pooled) columns
        assert (lg.tail_in - k) // s + 1 == lg.t_conv - lg.conv_lo
        t_in, d_in = lg.t_out, lg.d_out
    with pytest.raises(ValueError):
        make_stream_geometry(CFG, HOP + 1)       # misaligned hop
    with pytest.raises(ValueError):
        make_stream_geometry(CFG, L)             # hop >= window


def test_streaming_macs_fraction():
    geom = make_stream_geometry(CFG, HOP)
    off = m.layer_stats(CFG)
    strm = streaming_layer_stats(CFG, geom)
    assert len(off) == len(strm)
    ratio = sum(s["macs"] for s in strm) / sum(s["macs"] for s in off)
    # per-decision work collapses toward hop/window (0.1), plus carries
    assert ratio < 0.3
    assert strm[-1] == off[-1]                   # gap+fc runs in full


# ---------------------------------------------------------------------------
# Bit-exactness vs offline hw_forward (the acceptance contract)
# ---------------------------------------------------------------------------


@pytest.mark.streaming
@pytest.mark.parametrize("noisy", [False, True], ids=["clean", "noise"])
def test_streaming_bitexact_vs_offline_hops(folded, noisy):
    """Every hop's logits == hw_forward on that full window, across enough
    hops (10) to fully wrap the GAP ring (t_feat=7) and every layer carry.
    Streaming runs the fused kernels; the offline oracle runs the jnp path,
    so this also crosses the kernel/jnp boundary."""
    hw = folded
    geom = make_stream_geometry(CFG, HOP)
    n_hops = 10
    audio = _audio(1, L + n_hops * HOP)
    keys = jax.random.PRNGKey(42)[None]
    offs = _chip() if noisy else None
    std = 1.2 if noisy else 0.0

    logits, state = sv.stream_init(hw, audio[:, :L], keys, CFG, geom,
                                   chip_offsets=offs, sa_noise_std=std,
                                   use_kernel=True)
    for t in range(n_hops + 1):
        if t > 0:
            chunk = audio[:, L + (t - 1) * HOP:L + t * HOP]
            logits, state = sv.stream_step(hw, state, chunk, CFG, geom,
                                           chip_offsets=offs,
                                           sa_noise_std=std,
                                           use_kernel=True)
        window = audio[:, t * HOP:t * HOP + L]
        noise = (window_sa_noise(keys[0], CFG, geom, t, std)
                 if noisy else None)
        ref, _ = m.hw_forward(hw, window, CFG, chip_offsets=offs,
                              sa_noise=noise, sa_noise_std=std,
                              use_kernel=False)
        np.testing.assert_array_equal(np.asarray(logits), np.asarray(ref),
                                      err_msg=f"hop {t}")
    assert int(state.hop[0]) == n_hops + 1
    if noisy:
        # the noise actually flips decisions relative to the clean path
        clean, _ = m.hw_forward(hw, audio[:, :L], CFG, use_kernel=False)
        noisy0, _ = m.hw_forward(hw, audio[:, :L], CFG, chip_offsets=offs,
                                 sa_noise=window_sa_noise(keys[0], CFG,
                                                          geom, 0, std),
                                 sa_noise_std=std, use_kernel=False)
        assert not np.array_equal(np.asarray(clean), np.asarray(noisy0))


@pytest.mark.streaming
def test_streaming_jnp_and_kernel_paths_agree(folded):
    """use_kernel=False streaming == use_kernel=True streaming, batched."""
    hw = folded
    geom = make_stream_geometry(CFG, HOP)
    audio = _audio(2, L + 3 * HOP, batch=2)
    keys = jnp.stack([jax.random.PRNGKey(1), jax.random.PRNGKey(2)])
    outs = []
    for uk in (False, True):
        logits, state = sv.stream_init(hw, audio[:, :L], keys, CFG, geom,
                                       sa_noise_std=0.8, use_kernel=uk)
        acc = [np.asarray(logits)]
        for t in range(1, 4):
            chunk = audio[:, L + (t - 1) * HOP:L + t * HOP]
            logits, state = sv.stream_step(hw, state, chunk, CFG, geom,
                                           sa_noise_std=0.8, use_kernel=uk)
            acc.append(np.asarray(logits))
        outs.append(np.stack(acc))
    np.testing.assert_array_equal(outs[0], outs[1])


def test_recompute_fallback_is_hw_forward(folded):
    """streaming=False: every hop is exactly hw_forward on the window."""
    hw = folded
    eng = StreamEngine(hw, CFG, HOP, use_kernel=False, streaming=False)
    audio = _audio(3, L + 2 * HOP)
    keys = jax.random.PRNGKey(7)[None]
    logits, state = eng.init(audio[:, :L], keys)
    ref, _ = m.hw_forward(hw, audio[:, :L], CFG, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(ref))
    for t in (1, 2):
        logits, state = eng.step(
            state, audio[:, L + (t - 1) * HOP:L + t * HOP])
        ref, _ = m.hw_forward(hw, audio[:, t * HOP:t * HOP + L], CFG,
                              use_kernel=False)
        np.testing.assert_array_equal(np.asarray(logits), np.asarray(ref))


# ---------------------------------------------------------------------------
# PackedHWParams: fold-time packing off the per-decision path
# ---------------------------------------------------------------------------


def test_packed_hw_params_no_repacking(folded, monkeypatch):
    """With PackedHWParams, hw_forward(use_kernel=True) never repacks the
    weights — pack_grouped_weights runs at fold time only."""
    hw = folded
    assert isinstance(hw, m.PackedHWParams)
    x = _audio(4, L)
    calls = []
    real = imc.pack_grouped_weights

    def counting(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(imc, "pack_grouped_weights", counting)
    _, f_packed = m.hw_forward(hw, x, CFG, use_kernel=True)
    assert not calls, "packed path must not repack weights per decision"
    _, f_raw = m.hw_forward(hw.hw, x, CFG, use_kernel=True)
    assert len(calls) == CFG.num_conv_layers - 1
    np.testing.assert_array_equal(np.asarray(f_packed), np.asarray(f_raw))


# ---------------------------------------------------------------------------
# Scheduler: batching, admit/evict, per-stream correctness
# ---------------------------------------------------------------------------


def test_batched_admission_one_launch_and_bitexact(folded, monkeypatch):
    """A wave of 4 simultaneous admissions initializes in ONE masked
    batched stream_init — exactly one pallas_call per IMC layer for the
    whole wave — and the decision sequences are bit-identical to the
    sequential (batch_init=False) B=1 admission path, SA noise and chip
    offsets included."""
    hw = folded
    offs = _chip()
    rng = np.random.default_rng(17)
    wavs = {f"s{i}": rng.uniform(-1, 1, L + 3 * HOP).astype(np.float32)
            for i in range(4)}

    def run(batch_init, count_first_step=False):
        srv = StreamServer(hw, CFG, hop=HOP, slots=4, use_kernel=True,
                           chip_offsets=offs, sa_noise_std=0.7,
                           batch_init=batch_init, seed=5)
        for sid, wav in wavs.items():
            srv.submit(sid, wav)
            srv.finish(sid)
        calls = None
        events = []
        if count_first_step:
            jax.clear_caches()
            calls = []
            real = pl.pallas_call

            def counting(*args, **kwargs):
                calls.append(kwargs.get("grid"))
                return real(*args, **kwargs)

            monkeypatch.setattr(pl, "pallas_call", counting)
            events.extend(srv.step())       # the 4-stream admission wave
            monkeypatch.setattr(pl, "pallas_call", real)
        events.extend(srv.drain())
        return events, calls, srv.stats()["batched_calls"]["init"]

    ev_b, calls, init_b = run(True, count_first_step=True)
    assert len(calls) == CFG.num_conv_layers - 1, calls
    assert init_b == 1                      # one wave, one batched call
    ev_s, _, init_s = run(False)
    assert init_s == 4                      # B=1 per admission
    eq.assert_events_equal(ev_b, ev_s, "batched vs sequential init")


def test_scheduler_one_fused_launch_per_layer(folded, monkeypatch):
    """A batched hop over 4 concurrent streams traces exactly one
    pallas_call per IMC layer — the slot batch shares each launch."""
    hw = folded
    srv = StreamServer(hw, CFG, hop=HOP, slots=4, use_kernel=True)
    rng = np.random.default_rng(0)
    for i in range(4):
        srv.submit(f"s{i}", rng.uniform(-1, 1, L + 3 * HOP)
                   .astype(np.float32))
    srv.step()                                   # admissions (init path)
    # drop jit caches so the batched-hop trace re-runs every kernel wrapper
    # (the B=1 admission traces can otherwise shadow same-shaped tail calls)
    jax.clear_caches()
    calls = []
    real = pl.pallas_call

    def counting(*args, **kwargs):
        calls.append(kwargs.get("grid"))
        return real(*args, **kwargs)

    monkeypatch.setattr(pl, "pallas_call", counting)
    events = srv.step()                          # first batched hop: traces
    assert len(events) == 4
    assert len(calls) == CFG.num_conv_layers - 1


def test_scheduler_matches_single_stream_engine(folded):
    """Streams interleaved through the shared slots produce bit-identical
    decisions to a dedicated engine per stream (same per-stream keys)."""
    hw = folded
    seed = 3
    srv = StreamServer(hw, CFG, hop=HOP, slots=2, use_kernel=True,
                       sa_noise_std=0.9, seed=seed,
                       decision=DecisionConfig(smooth=3, threshold_on=0.4,
                                               threshold_off=0.3,
                                               refractory=2))
    rng = np.random.default_rng(1)
    lens = [L + 4 * HOP, L + 2 * HOP, L + 3 * HOP]
    streams = {f"s{i}": rng.uniform(-1, 1, n).astype(np.float32)
               for i, n in enumerate(lens)}
    cursors = {k: 0 for k in streams}
    events = []
    while (any(cursors[k] < len(v) for k, v in streams.items())
           or srv.active_streams()):
        for k, v in streams.items():
            if cursors[k] < len(v):
                n = int(rng.integers(40, 500))
                srv.submit(k, v[cursors[k]:cursors[k] + n])
                cursors[k] += n
                if cursors[k] >= len(v):
                    srv.finish(k)
        events.extend(srv.step())
    events.extend(srv.drain())

    eng = StreamEngine(hw, CFG, HOP, use_kernel=False, sa_noise_std=0.9)
    base = jax.random.PRNGKey(seed)
    for uid, (k, v) in enumerate(streams.items()):
        n_hops = (len(v) - L) // HOP + 1
        key = jax.random.fold_in(base, uid)[None]
        logits, s0 = eng.init(jnp.asarray(v[None, :L]), key)
        ref_logits = [np.asarray(logits[0])]
        for t in range(1, n_hops):
            logits, s0 = eng.step(
                s0, jnp.asarray(v[None, L + (t - 1) * HOP:L + t * HOP]))
            ref_logits.append(np.asarray(logits[0]))
        # decisions: replay the head over the reference logits
        dstate = decision_init(1, CFG.num_classes, srv.dcfg)
        got = sorted((e for e in events if e["stream"] == k),
                     key=lambda e: e["hop"])
        assert [e["hop"] for e in got] == list(range(n_hops))
        for t, ev in enumerate(got):
            dstate, out = decision_step(srv.dcfg, dstate,
                                        jnp.asarray(ref_logits[t][None]))
            assert ev["keyword"] == int(out.keyword[0])
            assert ev["trigger"] == bool(out.trigger[0])
            # logits are bit-exact (asserted via keyword/trigger); the
            # smoothed score may differ by float-fusion ulps under jit
            np.testing.assert_allclose(np.float32(ev["score"]),
                                       np.asarray(out.score[0]),
                                       rtol=1e-5, atol=1e-6)


@pytest.mark.streaming
@pytest.mark.slow
@settings(max_examples=3, deadline=None)
@given(st.integers(0, 10_000))
def test_scheduler_soak_randomized_admit_evict(seed):
    """Soak: more streams than slots, random chunk sizes and arrival order,
    mid-stream evictions.  Invariants: every surviving stream gets exactly
    (len - window)//hop + 1 decisions, slots never exceed capacity, evicted
    slots are reused."""
    params = m.init_params(jax.random.PRNGKey(5), CFG)
    state = m.init_state(CFG)
    hw = m.fold_params(params, state, CFG, pack=True)
    rng = np.random.default_rng(seed)
    srv = StreamServer(hw, CFG, hop=HOP, slots=3, use_kernel=True)
    n_streams = 6
    streams = {f"s{i}": rng.uniform(-1, 1, L + int(rng.integers(1, 6)) * HOP)
               .astype(np.float32) for i in range(n_streams)}
    evict_at = {f"s{rng.integers(0, n_streams)}": 2}
    cursors = {k: 0 for k in streams}
    evicted = set()
    events = []
    for step_i in range(200):
        for k, v in streams.items():
            if k in evicted or cursors[k] >= len(v):
                continue
            n = int(rng.integers(30, 600))
            srv.submit(k, v[cursors[k]:cursors[k] + n])
            cursors[k] += n
            if cursors[k] >= len(v):
                srv.finish(k)
        assert len(srv.active_streams()) <= 3
        events.extend(srv.step())
        for k, at in evict_at.items():
            if step_i == at and k not in evicted:
                srv.evict(k)
                evicted.add(k)
        if (all(cursors[k] >= len(v) for k, v in streams.items())
                and not srv.active_streams()):
            break
    events.extend(srv.drain())
    assert not srv.active_streams()
    by_stream = {}
    for e in events:
        by_stream.setdefault(e["stream"], []).append(e)
    for k, v in streams.items():
        expect = (len(v) - L) // HOP + 1
        got = len(by_stream.get(k, []))
        if k in evicted:
            assert got <= expect
        else:
            assert got == expect, (k, got, expect)


# ---------------------------------------------------------------------------
# Voice-activity gating
# ---------------------------------------------------------------------------


def test_vad_hysteresis_hangover_and_force():
    vcfg = VADConfig(threshold_on_db=-30.0, threshold_off_db=-40.0,
                     ema=0.0, hang=2)
    state = vad_init(1)
    loud = jnp.full((1, 64), 0.5)          # ~ -6 dBFS
    mid = jnp.full((1, 64), 0.02)          # ~ -34 dBFS: inside the band
    quiet = jnp.full((1, 64), 1e-4)        # ~ -80 dBFS

    state, sp = vad_step(vcfg, state, quiet)
    assert not bool(sp[0])
    state, sp = vad_step(vcfg, state, mid)   # below on: still silence
    assert not bool(sp[0])
    state, sp = vad_step(vcfg, state, loud)  # onset
    assert bool(sp[0])
    state, sp = vad_step(vcfg, state, mid)   # above off: speech held
    assert bool(sp[0])
    state, sp = vad_step(vcfg, state, quiet)  # below off: hangover 2 hops
    assert bool(sp[0])
    state, sp = vad_step(vcfg, state, quiet)
    assert bool(sp[0])
    state, sp = vad_step(vcfg, state, quiet)  # hangover expired
    assert not bool(sp[0])

    # mask-aware: inactive rows keep state and classification
    state2 = vad_init(2)
    both_loud = jnp.tile(loud, (2, 1))
    state2, sp = vad_step(vcfg, state2, both_loud,
                          active=jnp.asarray([True, False]))
    assert bool(sp[0]) and not bool(sp[1])
    assert int(state2.seen[0]) == 1 and int(state2.seen[1]) == 0

    for force, want in (("speech", True), ("silence", False)):
        fs, sp = vad_step(VADConfig(force=force), vad_init(1), quiet)
        assert bool(sp[0]) is want

    with pytest.raises(ValueError):
        VADConfig(force="maybe")
    with pytest.raises(ValueError):
        VADConfig(threshold_on_db=-50.0, threshold_off_db=-40.0)


def test_hop_noise_fields_match_per_layer_draws():
    """The cross-layer hoisted draw (one batched fold_in chain per hop) is
    bit-identical to the per-layer per-column field evaluation."""
    geom = make_stream_geometry(CFG, HOP)
    keys = jnp.stack([jax.random.PRNGKey(11), jax.random.PRNGKey(12)])
    hops = jnp.asarray([0, 9], jnp.int32)
    allf = sv.hop_sa_noise_fields(keys, hops, CFG, geom, 0.9)
    for i in range(1, CFG.num_conv_layers):
        ref = sv._hop_sa_noise(keys, hops, i, CFG, geom, 0.9)
        np.testing.assert_array_equal(np.asarray(allf[f"conv{i}"]),
                                      np.asarray(ref), err_msg=f"layer {i}")


@pytest.mark.streaming
def test_gated_forced_speech_bitexact_vs_ungated(folded):
    """The gating-equivalence gate: with the VAD forced to 'speech' on
    every hop, the gated server's decision events are bit-identical to an
    ungated server's — SA noise and chip offsets included (all-speech
    audio never gates, so the extra machinery must be a perfect no-op)."""
    hw = folded
    offs = _chip()
    rng = np.random.default_rng(2)
    wavs = {f"s{i}": rng.uniform(-1, 1, L + 4 * HOP).astype(np.float32)
            for i in range(2)}

    def run(vad):
        srv = StreamServer(hw, CFG, hop=HOP, slots=2, use_kernel=True,
                           sa_noise_std=0.9, chip_offsets=offs, vad=vad,
                           seed=3)
        for k, v in wavs.items():
            srv.submit(k, v)
            srv.finish(k)
        return srv.drain()

    ev_plain = run(None)
    ev_forced = run(VADConfig(force="speech"))
    eq.assert_events_equal(ev_forced, ev_plain, "forced-speech vs ungated")
    assert len(ev_plain) == 2 * 5


@pytest.mark.streaming
def test_gated_silence_advances_without_kernel_launches(folded, monkeypatch):
    """Silent hops must not launch any Pallas kernel: the state advances by
    the masked no-op column fill (each layer's constant silence response
    shifts into the carries and the GAP ring) while the chip sleeps."""
    hw = folded
    srv = StreamServer(hw, CFG, hop=HOP, slots=2, use_kernel=True,
                       vad=VADConfig(threshold_on_db=-40.0,
                                     threshold_off_db=-50.0,
                                     wake_margin=0, hang=0))
    rng = np.random.default_rng(3)
    for i in range(2):
        # loud first window (ring holds real activations), silent tail
        wav = (1e-4 * rng.standard_normal(L + 4 * HOP)).astype(np.float32)
        wav[:L] = rng.uniform(-1, 1, L)
        srv.submit(f"q{i}", wav)
        srv.finish(f"q{i}")
    events = srv.step()                      # admissions (init: kernels OK)
    assert len(events) == 2
    ring_before = np.asarray(srv._state.ring)

    calls = []
    real = pl.pallas_call

    def counting(*args, **kwargs):
        calls.append(kwargs.get("grid"))
        return real(*args, **kwargs)

    monkeypatch.setattr(pl, "pallas_call", counting)
    for _ in range(2):
        assert srv.step() == []              # silent hops: no events
    assert calls == [], "gated hops must not launch kernels"
    ring_after = np.asarray(srv._state.ring)
    assert not np.array_equal(ring_before, ring_after)
    # the shifted-in ring columns are the last layer's silence response
    fill = np.asarray(srv._fills[-1])
    d = srv.geom.d_feat
    np.testing.assert_array_equal(ring_after[:, -d:],
                                  np.broadcast_to(fill, (2, d, fill.size)))
    s = srv.stats()
    assert s["gated_hops"] == 4 and s["speech_hops"] == 0
    assert s["duty_cycle"] == 0.0


@pytest.mark.streaming
def test_wake_margin_replays_keyword_prefix(folded):
    """A keyword straddling a silence->speech edge is still detected: a
    silent run no longer than ``wake_margin`` is deferred (not gated), and
    the wake replays it through the real IMC path, so the gated decision
    sequence is bit-identical to ungated streaming."""
    hw = folded
    rng = np.random.default_rng(4)
    wav = rng.uniform(-1, 1, L + 8 * HOP).astype(np.float32)
    wav[L + 2 * HOP:L + 5 * HOP] *= 1e-4     # 3 silent hops mid-stream
    dcfg = DecisionConfig(smooth=3, threshold_on=0.05, threshold_off=0.02,
                          refractory=4)      # low bar: untrained net fires

    def run(vad):
        srv = StreamServer(hw, CFG, hop=HOP, slots=1, use_kernel=True,
                           decision=dcfg, vad=vad, seed=5)
        srv.submit("s", wav)
        srv.finish("s")
        return srv.drain(), srv

    ev_ungated, _ = run(None)
    ev_gated, srv = run(VADConfig(threshold_on_db=-40.0,
                                  threshold_off_db=-50.0,
                                  wake_margin=3, hang=0))
    eq.assert_events_equal(ev_gated, ev_ungated,   # every hop decided,
                           "wake-margin replay vs ungated")  # bit-equal
    assert any(e["trigger"] for e in ev_gated)
    s = srv.stats()
    assert s["gated_hops"] == 0              # silence stayed within margin
    assert s["speech_hops"] == 8


def test_gated_energy_leakage_only_and_reduction():
    """Idle-hop accounting: a gated hop charges the VAD's dynamic energy
    plus leakage for the VAD's awake cycles — nothing else — and at 20%
    speech duty the duty-cycled uJ/decision drops >= 3x vs ungated
    streaming (the acceptance target)."""
    cfg = m.KWSConfig(sample_len=2000)
    geom = make_stream_geometry(cfg, 256)
    off = m.layer_stats(cfg)
    strm = streaming_layer_stats(cfg, geom)
    g = energy.gated_energy_summary(off, strm, hop_samples=256,
                                    duty_cycle=0.2)
    v = energy.vad_stats(256)
    vad_dyn = (v["macs"] * energy.E_DIG_MAC8
               + v["in_bits"] * energy.E_SRAM_RD_BIT
               + v["out_bits"] * energy.E_SRAM_WR_BIT
               + v["cycles"] * energy.E_CTRL_CYCLE)
    vad_leak = energy.LEAKAGE_W * v["cycles"] / g["freq_hz"]
    # leakage-only: the idle hop is exactly VAD dynamic + VAD-awake leakage
    np.testing.assert_allclose(g["idle_uj_per_hop"],
                               (vad_dyn + vad_leak) * 1e6, rtol=1e-9)
    np.testing.assert_allclose(g["vad_leakage_uj"], vad_leak * 1e6,
                               rtol=1e-9)
    strm_uj = energy.kws_streaming_report(strm).energy_j_per_decision * 1e6
    assert g["idle_uj_per_hop"] < 0.05 * strm_uj
    assert g["ungated_uj_per_decision"] == pytest.approx(
        strm_uj + g["idle_uj_per_hop"])
    # the acceptance target: >= 3x at 20% duty
    assert g["reduction_vs_ungated"] >= 3.0
    # duty 1.0 degenerates to ungated (gating never penalizes speech)
    g1 = energy.gated_energy_summary(off, strm, hop_samples=256,
                                     duty_cycle=1.0)
    assert g1["gated_uj_per_decision"] == pytest.approx(
        g1["ungated_uj_per_decision"])
    with pytest.raises(ValueError):
        energy.gated_energy_summary(off, strm, hop_samples=256,
                                    duty_cycle=1.5)


# ---------------------------------------------------------------------------
# Backpressure: bounded queue, latency SLO shedding, slot autoscaling
# ---------------------------------------------------------------------------


@pytest.mark.streaming
def test_backpressure_reject_shed_autoscale(folded):
    hw = folded
    rng = np.random.default_rng(6)
    srv = StreamServer(hw, CFG, hop=HOP, slots=1, use_kernel=True,
                       admission=AdmissionConfig(max_queue=1, max_lag_s=0.06,
                                                 min_slots=1, max_slots=2,
                                                 scale_up_after=1,
                                                 scale_down_after=2))
    mk = lambda n: rng.uniform(-1, 1, n).astype(np.float32)
    assert srv.submit("a", mk(L)) == "slot"
    assert srv.submit("b", mk(L)) == "queued"
    assert srv.submit("c", mk(L)) == "rejected"   # queue bound hit
    assert "c" not in srv.stats()["per_stream"]
    srv.step()
    assert srv.slots == 2                    # scaled up under queue pressure

    # over-admitted soak: keep flooding 'a' past the 0.06 s SLO (960
    # samples); the server sheds its oldest backlog and re-inits rather
    # than serving arbitrarily stale audio
    for _ in range(4):
        srv.submit("a", mk(4000))
        srv.step()
    s = srv.stats()
    assert s["shed"]["events"] >= 1
    assert s["per_stream"]["a"]["sheds"] >= 1
    assert s["rejected_streams"] == 1
    # after shedding, the backlog is at the low-water mark, not growing
    rec = srv._streams["a"]
    assert len(rec.buf) <= max(srv.geom.window,
                               int(0.06 * CFG.sample_rate))
    # streams keep making progress (decisions continue post-shed)
    assert s["decisions"] > 0
    for k in ("a", "b"):
        srv.finish(k)
    srv.drain()
    for _ in range(3):                       # idle ticks -> scale down
        srv.step()
    assert srv.slots == 1
    assert not srv.active_streams()


# ---------------------------------------------------------------------------
# Dynamic hop
# ---------------------------------------------------------------------------


@pytest.mark.streaming
def test_dynamic_hop_widens_on_calm_and_narrows_on_activity(folded):
    """Quiet audio widens the effective hop (x2 then x4); the loud tail
    wakes the VAD, which narrows back to the base hop; states are rebuilt
    across every change and serving continues."""
    hw = folded
    rng = np.random.default_rng(7)
    wav = (1e-4 * rng.standard_normal(L + 40 * HOP)).astype(np.float32)
    wav[:L] = rng.uniform(-1, 1, L)
    wav[L + 30 * HOP:] = rng.uniform(-1, 1, 10 * HOP)
    srv = StreamServer(hw, CFG, hop=HOP, slots=1, use_kernel=True,
                       vad=VADConfig(threshold_on_db=-40.0,
                                     threshold_off_db=-50.0,
                                     wake_margin=1, hang=0),
                       dynamic_hop=DynamicHopConfig(max_multiplier=4,
                                                    widen_after=3,
                                                    calm_score=0.35))
    srv.submit("d", wav)
    srv.finish("d")
    mults = []
    while srv.active_streams():
        srv.step()
        mults.append(srv.hop_multiplier)
    assert max(mults) == 4                   # widened during the calm run
    first4 = mults.index(4)
    assert 1 in mults[first4:]               # narrowed after the wake
    assert srv.stats()["hop_retargets"] >= 2
    assert srv.hop == HOP * srv.hop_multiplier

    # misaligned/oversize multiples are rejected by the geometry guard
    assert not srv._feasible_mult(L // HOP)  # hop == window: invalid
    assert srv._feasible_mult(2)


# ---------------------------------------------------------------------------
# Decision head
# ---------------------------------------------------------------------------


def test_decision_smoothing_hysteresis_refractory():
    dcfg = DecisionConfig(smooth=3, threshold_on=0.6, threshold_off=0.4,
                          refractory=4, background_class=1)
    state = decision_init(1, 4, dcfg)
    hot = jnp.asarray([[8.0, 0.0, 0.0, 0.0]])
    cold = jnp.asarray([[0.0, 8.0, 0.0, 0.0]])

    # hop 0: one hot posterior, smoothing divides by hops seen (1) -> fires
    state, out = decision_step(dcfg, state, hot)
    assert bool(out.trigger[0]) and int(out.keyword[0]) == 0
    # held-down key: score stays high but hysteresis blocks a second fire
    for _ in range(3):
        state, out = decision_step(dcfg, state, hot)
        assert not bool(out.trigger[0])
    # release below threshold_off -> re-arms; refractory also expires
    for _ in range(3):
        state, out = decision_step(dcfg, state, cold)
        assert not bool(out.trigger[0])
    state, out = decision_step(dcfg, state, hot)
    assert not bool(out.trigger[0])       # smoothed over 3 hops: not yet
    state, out = decision_step(dcfg, state, hot)
    assert bool(out.trigger[0])           # 2/3 hot hops clears 0.6

    # refractory: immediately re-armed + hot cannot fire for 4 hops
    state, out = decision_step(dcfg, state, cold)
    state, out = decision_step(dcfg, state, cold)  # re-armed now
    state, out = decision_step(dcfg, state, hot)
    state, out = decision_step(dcfg, state, hot)
    assert not bool(out.trigger[0])       # refractory still counting down


def test_decision_mask_freezes_inactive_streams():
    dcfg = DecisionConfig(smooth=2, threshold_on=0.6, threshold_off=0.4,
                          refractory=1)
    state = decision_init(2, 3, dcfg)
    hot = jnp.asarray([[9.0, 0.0, 0.0], [9.0, 0.0, 0.0]])
    mask = jnp.asarray([True, False])
    state, out = decision_step(dcfg, state, hot, active=mask)
    assert bool(out.trigger[0]) and not bool(out.trigger[1])
    assert int(state.seen[0]) == 1 and int(state.seen[1]) == 0
    np.testing.assert_array_equal(np.asarray(state.posteriors[1]), 0.0)
