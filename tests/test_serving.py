"""The streaming serving contract (repro.serving):

* N hops of the frame-incremental path are bit-identical to per-window
  ``hw_forward`` — clean, chip-offset and SA-noise configurations (the
  noise comes from the per-absolute-column field; the offline window
  evaluates the same field via ``hw_forward(sa_noise=...)``);
* the GAP ring and every layer carry survive full wraparound;
* the ``streaming=False`` fallback recomputes exactly ``hw_forward``;
* the scheduler batches every ready slot into ONE fused-kernel launch per
  IMC layer, admits/evicts under randomized arrival, and each stream's
  decisions match a dedicated single-stream engine bit-for-bit;
* the decision head smooths, fires once (hysteresis) and respects the
  refractory window.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st
from jax.experimental import pallas as pl

from repro.core import imc
from repro.models import kws as m
from repro.serving import (DecisionConfig, StreamEngine, StreamServer,
                           decision_init, decision_step, hop_alignment,
                           make_stream_geometry, streaming_layer_stats,
                           window_sa_noise)
from repro.serving import stream as sv

L, HOP = 640, 64
CFG = m.KWSConfig(sample_len=L)


@pytest.fixture(scope="module")
def folded():
    params = m.init_params(jax.random.PRNGKey(5), CFG)
    state = m.init_state(CFG)
    return m.fold_params(params, state, CFG, pack=True)


def _audio(key, n, batch=1):
    return jax.random.uniform(jax.random.PRNGKey(key), (batch, n),
                              minval=-1, maxval=1)


def _chip(std=4.0):
    chans = {f"conv{i}": CFG.channels[i]
             for i in range(1, CFG.num_conv_layers)}
    return imc.sample_chip_offsets(
        jax.random.PRNGKey(9), chans,
        imc.IMCNoiseParams(mav_offset_std=std))


# ---------------------------------------------------------------------------
# Geometry
# ---------------------------------------------------------------------------


def test_geometry_alignment_and_shapes():
    assert hop_alignment(CFG) == 64
    geom = make_stream_geometry(CFG, HOP)
    t_in, d_in = L, HOP
    for i, lg in enumerate(geom.layers):
        k, s, p = CFG.kernels[i], CFG.strides[i], CFG.pools[i]
        assert lg.t_in == t_in and lg.d_in == d_in
        assert lg.t_conv == (t_in - k) // s + 1
        assert lg.t_out == lg.t_conv // p
        assert lg.carry == lg.tail_in - lg.d_in
        # the tail's conv start is pool-aligned in the full window
        assert lg.conv_lo % p == 0
        # conv over the tail produces exactly the fresh (+re-pooled) columns
        assert (lg.tail_in - k) // s + 1 == lg.t_conv - lg.conv_lo
        t_in, d_in = lg.t_out, lg.d_out
    with pytest.raises(ValueError):
        make_stream_geometry(CFG, HOP + 1)       # misaligned hop
    with pytest.raises(ValueError):
        make_stream_geometry(CFG, L)             # hop >= window


def test_streaming_macs_fraction():
    geom = make_stream_geometry(CFG, HOP)
    off = m.layer_stats(CFG)
    strm = streaming_layer_stats(CFG, geom)
    assert len(off) == len(strm)
    ratio = sum(s["macs"] for s in strm) / sum(s["macs"] for s in off)
    # per-decision work collapses toward hop/window (0.1), plus carries
    assert ratio < 0.3
    assert strm[-1] == off[-1]                   # gap+fc runs in full


# ---------------------------------------------------------------------------
# Bit-exactness vs offline hw_forward (the acceptance contract)
# ---------------------------------------------------------------------------


@pytest.mark.streaming
@pytest.mark.parametrize("noisy", [False, True], ids=["clean", "noise"])
def test_streaming_bitexact_vs_offline_hops(folded, noisy):
    """Every hop's logits == hw_forward on that full window, across enough
    hops (10) to fully wrap the GAP ring (t_feat=7) and every layer carry.
    Streaming runs the fused kernels; the offline oracle runs the jnp path,
    so this also crosses the kernel/jnp boundary."""
    hw = folded
    geom = make_stream_geometry(CFG, HOP)
    n_hops = 10
    audio = _audio(1, L + n_hops * HOP)
    keys = jax.random.PRNGKey(42)[None]
    offs = _chip() if noisy else None
    std = 1.2 if noisy else 0.0

    logits, state = sv.stream_init(hw, audio[:, :L], keys, CFG, geom,
                                   chip_offsets=offs, sa_noise_std=std,
                                   use_kernel=True)
    for t in range(n_hops + 1):
        if t > 0:
            chunk = audio[:, L + (t - 1) * HOP:L + t * HOP]
            logits, state = sv.stream_step(hw, state, chunk, CFG, geom,
                                           chip_offsets=offs,
                                           sa_noise_std=std,
                                           use_kernel=True)
        window = audio[:, t * HOP:t * HOP + L]
        noise = (window_sa_noise(keys[0], CFG, geom, t, std)
                 if noisy else None)
        ref, _ = m.hw_forward(hw, window, CFG, chip_offsets=offs,
                              sa_noise=noise, sa_noise_std=std,
                              use_kernel=False)
        np.testing.assert_array_equal(np.asarray(logits), np.asarray(ref),
                                      err_msg=f"hop {t}")
    assert int(state.hop[0]) == n_hops + 1
    if noisy:
        # the noise actually flips decisions relative to the clean path
        clean, _ = m.hw_forward(hw, audio[:, :L], CFG, use_kernel=False)
        noisy0, _ = m.hw_forward(hw, audio[:, :L], CFG, chip_offsets=offs,
                                 sa_noise=window_sa_noise(keys[0], CFG,
                                                          geom, 0, std),
                                 sa_noise_std=std, use_kernel=False)
        assert not np.array_equal(np.asarray(clean), np.asarray(noisy0))


@pytest.mark.streaming
def test_streaming_jnp_and_kernel_paths_agree(folded):
    """use_kernel=False streaming == use_kernel=True streaming, batched."""
    hw = folded
    geom = make_stream_geometry(CFG, HOP)
    audio = _audio(2, L + 3 * HOP, batch=2)
    keys = jnp.stack([jax.random.PRNGKey(1), jax.random.PRNGKey(2)])
    outs = []
    for uk in (False, True):
        logits, state = sv.stream_init(hw, audio[:, :L], keys, CFG, geom,
                                       sa_noise_std=0.8, use_kernel=uk)
        acc = [np.asarray(logits)]
        for t in range(1, 4):
            chunk = audio[:, L + (t - 1) * HOP:L + t * HOP]
            logits, state = sv.stream_step(hw, state, chunk, CFG, geom,
                                           sa_noise_std=0.8, use_kernel=uk)
            acc.append(np.asarray(logits))
        outs.append(np.stack(acc))
    np.testing.assert_array_equal(outs[0], outs[1])


def test_recompute_fallback_is_hw_forward(folded):
    """streaming=False: every hop is exactly hw_forward on the window."""
    hw = folded
    eng = StreamEngine(hw, CFG, HOP, use_kernel=False, streaming=False)
    audio = _audio(3, L + 2 * HOP)
    keys = jax.random.PRNGKey(7)[None]
    logits, state = eng.init(audio[:, :L], keys)
    ref, _ = m.hw_forward(hw, audio[:, :L], CFG, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(ref))
    for t in (1, 2):
        logits, state = eng.step(
            state, audio[:, L + (t - 1) * HOP:L + t * HOP])
        ref, _ = m.hw_forward(hw, audio[:, t * HOP:t * HOP + L], CFG,
                              use_kernel=False)
        np.testing.assert_array_equal(np.asarray(logits), np.asarray(ref))


# ---------------------------------------------------------------------------
# PackedHWParams: fold-time packing off the per-decision path
# ---------------------------------------------------------------------------


def test_packed_hw_params_no_repacking(folded, monkeypatch):
    """With PackedHWParams, hw_forward(use_kernel=True) never repacks the
    weights — pack_grouped_weights runs at fold time only."""
    hw = folded
    assert isinstance(hw, m.PackedHWParams)
    x = _audio(4, L)
    calls = []
    real = imc.pack_grouped_weights

    def counting(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(imc, "pack_grouped_weights", counting)
    _, f_packed = m.hw_forward(hw, x, CFG, use_kernel=True)
    assert not calls, "packed path must not repack weights per decision"
    _, f_raw = m.hw_forward(hw.hw, x, CFG, use_kernel=True)
    assert len(calls) == CFG.num_conv_layers - 1
    np.testing.assert_array_equal(np.asarray(f_packed), np.asarray(f_raw))


# ---------------------------------------------------------------------------
# Scheduler: batching, admit/evict, per-stream correctness
# ---------------------------------------------------------------------------


def test_scheduler_one_fused_launch_per_layer(folded, monkeypatch):
    """A batched hop over 4 concurrent streams traces exactly one
    pallas_call per IMC layer — the slot batch shares each launch."""
    hw = folded
    srv = StreamServer(hw, CFG, hop=HOP, slots=4, use_kernel=True)
    rng = np.random.default_rng(0)
    for i in range(4):
        srv.submit(f"s{i}", rng.uniform(-1, 1, L + 3 * HOP)
                   .astype(np.float32))
    srv.step()                                   # admissions (init path)
    # drop jit caches so the batched-hop trace re-runs every kernel wrapper
    # (the B=1 admission traces can otherwise shadow same-shaped tail calls)
    jax.clear_caches()
    calls = []
    real = pl.pallas_call

    def counting(*args, **kwargs):
        calls.append(kwargs.get("grid"))
        return real(*args, **kwargs)

    monkeypatch.setattr(pl, "pallas_call", counting)
    events = srv.step()                          # first batched hop: traces
    assert len(events) == 4
    assert len(calls) == CFG.num_conv_layers - 1


def test_scheduler_matches_single_stream_engine(folded):
    """Streams interleaved through the shared slots produce bit-identical
    decisions to a dedicated engine per stream (same per-stream keys)."""
    hw = folded
    seed = 3
    srv = StreamServer(hw, CFG, hop=HOP, slots=2, use_kernel=True,
                       sa_noise_std=0.9, seed=seed,
                       decision=DecisionConfig(smooth=3, threshold_on=0.4,
                                               threshold_off=0.3,
                                               refractory=2))
    rng = np.random.default_rng(1)
    lens = [L + 4 * HOP, L + 2 * HOP, L + 3 * HOP]
    streams = {f"s{i}": rng.uniform(-1, 1, n).astype(np.float32)
               for i, n in enumerate(lens)}
    cursors = {k: 0 for k in streams}
    events = []
    while (any(cursors[k] < len(v) for k, v in streams.items())
           or srv.active_streams()):
        for k, v in streams.items():
            if cursors[k] < len(v):
                n = int(rng.integers(40, 500))
                srv.submit(k, v[cursors[k]:cursors[k] + n])
                cursors[k] += n
                if cursors[k] >= len(v):
                    srv.finish(k)
        events.extend(srv.step())
    events.extend(srv.drain())

    eng = StreamEngine(hw, CFG, HOP, use_kernel=False, sa_noise_std=0.9)
    base = jax.random.PRNGKey(seed)
    for uid, (k, v) in enumerate(streams.items()):
        n_hops = (len(v) - L) // HOP + 1
        key = jax.random.fold_in(base, uid)[None]
        logits, s0 = eng.init(jnp.asarray(v[None, :L]), key)
        ref_logits = [np.asarray(logits[0])]
        for t in range(1, n_hops):
            logits, s0 = eng.step(
                s0, jnp.asarray(v[None, L + (t - 1) * HOP:L + t * HOP]))
            ref_logits.append(np.asarray(logits[0]))
        # decisions: replay the head over the reference logits
        dstate = decision_init(1, CFG.num_classes, srv.dcfg)
        got = sorted((e for e in events if e["stream"] == k),
                     key=lambda e: e["hop"])
        assert [e["hop"] for e in got] == list(range(n_hops))
        for t, ev in enumerate(got):
            dstate, out = decision_step(srv.dcfg, dstate,
                                        jnp.asarray(ref_logits[t][None]))
            assert ev["keyword"] == int(out.keyword[0])
            assert ev["trigger"] == bool(out.trigger[0])
            # logits are bit-exact (asserted via keyword/trigger); the
            # smoothed score may differ by float-fusion ulps under jit
            np.testing.assert_allclose(np.float32(ev["score"]),
                                       np.asarray(out.score[0]),
                                       rtol=1e-5, atol=1e-6)


@pytest.mark.streaming
@pytest.mark.slow
@settings(max_examples=3, deadline=None)
@given(st.integers(0, 10_000))
def test_scheduler_soak_randomized_admit_evict(seed):
    """Soak: more streams than slots, random chunk sizes and arrival order,
    mid-stream evictions.  Invariants: every surviving stream gets exactly
    (len - window)//hop + 1 decisions, slots never exceed capacity, evicted
    slots are reused."""
    params = m.init_params(jax.random.PRNGKey(5), CFG)
    state = m.init_state(CFG)
    hw = m.fold_params(params, state, CFG, pack=True)
    rng = np.random.default_rng(seed)
    srv = StreamServer(hw, CFG, hop=HOP, slots=3, use_kernel=True)
    n_streams = 6
    streams = {f"s{i}": rng.uniform(-1, 1, L + int(rng.integers(1, 6)) * HOP)
               .astype(np.float32) for i in range(n_streams)}
    evict_at = {f"s{rng.integers(0, n_streams)}": 2}
    cursors = {k: 0 for k in streams}
    evicted = set()
    events = []
    for step_i in range(200):
        for k, v in streams.items():
            if k in evicted or cursors[k] >= len(v):
                continue
            n = int(rng.integers(30, 600))
            srv.submit(k, v[cursors[k]:cursors[k] + n])
            cursors[k] += n
            if cursors[k] >= len(v):
                srv.finish(k)
        assert len(srv.active_streams()) <= 3
        events.extend(srv.step())
        for k, at in evict_at.items():
            if step_i == at and k not in evicted:
                srv.evict(k)
                evicted.add(k)
        if (all(cursors[k] >= len(v) for k, v in streams.items())
                and not srv.active_streams()):
            break
    events.extend(srv.drain())
    assert not srv.active_streams()
    by_stream = {}
    for e in events:
        by_stream.setdefault(e["stream"], []).append(e)
    for k, v in streams.items():
        expect = (len(v) - L) // HOP + 1
        got = len(by_stream.get(k, []))
        if k in evicted:
            assert got <= expect
        else:
            assert got == expect, (k, got, expect)


# ---------------------------------------------------------------------------
# Decision head
# ---------------------------------------------------------------------------


def test_decision_smoothing_hysteresis_refractory():
    dcfg = DecisionConfig(smooth=3, threshold_on=0.6, threshold_off=0.4,
                          refractory=4, background_class=1)
    state = decision_init(1, 4, dcfg)
    hot = jnp.asarray([[8.0, 0.0, 0.0, 0.0]])
    cold = jnp.asarray([[0.0, 8.0, 0.0, 0.0]])

    # hop 0: one hot posterior, smoothing divides by hops seen (1) -> fires
    state, out = decision_step(dcfg, state, hot)
    assert bool(out.trigger[0]) and int(out.keyword[0]) == 0
    # held-down key: score stays high but hysteresis blocks a second fire
    for _ in range(3):
        state, out = decision_step(dcfg, state, hot)
        assert not bool(out.trigger[0])
    # release below threshold_off -> re-arms; refractory also expires
    for _ in range(3):
        state, out = decision_step(dcfg, state, cold)
        assert not bool(out.trigger[0])
    state, out = decision_step(dcfg, state, hot)
    assert not bool(out.trigger[0])       # smoothed over 3 hops: not yet
    state, out = decision_step(dcfg, state, hot)
    assert bool(out.trigger[0])           # 2/3 hot hops clears 0.6

    # refractory: immediately re-armed + hot cannot fire for 4 hops
    state, out = decision_step(dcfg, state, cold)
    state, out = decision_step(dcfg, state, cold)  # re-armed now
    state, out = decision_step(dcfg, state, hot)
    state, out = decision_step(dcfg, state, hot)
    assert not bool(out.trigger[0])       # refractory still counting down


def test_decision_mask_freezes_inactive_streams():
    dcfg = DecisionConfig(smooth=2, threshold_on=0.6, threshold_off=0.4,
                          refractory=1)
    state = decision_init(2, 3, dcfg)
    hot = jnp.asarray([[9.0, 0.0, 0.0], [9.0, 0.0, 0.0]])
    mask = jnp.asarray([True, False])
    state, out = decision_step(dcfg, state, hot, active=mask)
    assert bool(out.trigger[0]) and not bool(out.trigger[1])
    assert int(state.seen[0]) == 1 and int(state.seen[1]) == 0
    np.testing.assert_array_equal(np.asarray(state.posteriors[1]), 0.0)
