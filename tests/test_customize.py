"""The customization-serving contract (repro.serving.customize):

* a CustomizationSession driven through scheduler ticks lands on EXACTLY
  the offline loop's result on the same recorded utterances — same
  compensated biases (calibrate_and_compensate) and same fine-tuned head
  (hw_features -> quantized_head_finetune), bit for bit, chip offsets
  AND SA-noise configurations included: under a noise field the offline
  oracle evaluates the session's recorded per-absolute-column field
  (``session.feature_noise_field()`` ->
  ``hw_features(sa_noise_field=...)``) instead of drawing fresh noise;
* a mixed inference+learning scheduler tick (live stream hops + session
  replay hops in the same batch) still issues exactly ONE fused-kernel
  launch per IMC layer — including with N concurrent sessions, whose
  per-tick launch count never scales with N;
* a session's wave of feature-replay streams initializes in ONE batched
  ``stream_init`` launch (``batch_init``), bit-identical to one-at-a-time
  B=1 admissions;
* the batched ``sga_update`` kernel (per-row learning rates) is
  bit-identical to the jnp optimizer path;
* ``finetune_epochs`` chunked across ticks equals the monolithic
  ``quantized_head_finetune``;
* a hot-swapped / ``install_custom``-ed profile serves bit-identically to
  a dedicated server on the refolded PackedHWParams — including a profile
  persisted through ``repro.checkpoint.profiles.ProfileStore`` across a
  server restart — and enabling customization never perturbs other
  streams' decisions;
* the wake replay advances its whole deferred run in ONE multi-hop
  launch, bit-identical to sequential single-hop replays.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl

from repro.checkpoint.profiles import ProfileStore
from repro.core import imc
from repro.core.onchip_training import (OnChipTrainConfig, apply_update,
                                        epoch_grads, finetune_epochs,
                                        finetune_init,
                                        quantized_head_finetune,
                                        sga_threshold)
from repro.kernels.sga_update import ops as sga_ops
from repro.models import kws as m
from repro.serving import (CustomizeConfig, StreamServer, VADConfig,
                           make_stream_geometry)
from repro.serving import stream as sv
from repro.training import kws as tr

L, HOP = 640, 64
CFG = m.KWSConfig(sample_len=L)
TRAIN = OnChipTrainConfig(epochs=23)


@pytest.fixture(scope="module")
def folded():
    params = m.init_params(jax.random.PRNGKey(5), CFG)
    state = m.init_state(CFG)
    return m.fold_params(params, state, CFG, pack=True)


def _chip(std=4.0):
    chans = {f"conv{i}": CFG.channels[i]
             for i in range(1, CFG.num_conv_layers)}
    return imc.sample_chip_offsets(
        jax.random.PRNGKey(9), chans,
        imc.IMCNoiseParams(mav_offset_std=std))


def _utterances(n, seed=0):
    rng = np.random.default_rng(seed)
    utts = [rng.uniform(-1, 1, L).astype(np.float32) for _ in range(n)]
    labels = [int(rng.integers(0, CFG.num_classes)) for _ in range(n)]
    return utts, labels


def _drive(srv, sess, live=None, max_steps=400):
    """Step the server until the session finishes, feeding the live
    stream one hop per tick (a genuinely mixed serving+learning load)."""
    pos = 0
    for _ in range(max_steps):
        if live is not None and pos < len(live):
            srv.submit("live", live[pos:pos + HOP])
            pos += HOP
        srv.step()
        if sess.done:
            return
    raise AssertionError(f"session stuck in phase {sess.phase}")


# ---------------------------------------------------------------------------
# The equivalence gate: session == offline loop, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.streaming
def test_session_matches_offline_loop(folded):
    """Enrollment through live hops + tick-resumable calibration +
    batched-kernel fine-tuning must land on EXACTLY the offline
    customize_onchip result for the same utterances."""
    hw = folded
    offs = _chip()
    srv = StreamServer(hw, CFG, hop=HOP, slots=4, use_kernel=True,
                       chip_offsets=offs)
    rng = np.random.default_rng(1)
    live = rng.uniform(-1, 1, L + 60 * HOP).astype(np.float32)
    srv.submit("live", live[:L])

    utts, labels = _utterances(5)
    sess = srv.customize("user", CustomizeConfig(
        train=TRAIN, epochs_per_tick=7, layers_per_tick=2))
    for lab, u in zip(labels, utts):
        sess.enroll(lab, u)
    sess.finish_enrollment()
    _drive(srv, sess, live=live[L:])
    assert sess.phase == "swapped"
    res = sess.result

    # the hop-aligned enrollment padding makes the recorded windows the
    # raw utterances — the offline loop runs on the identical inputs
    recorded = np.stack(sess.windows)
    np.testing.assert_array_equal(recorded, np.stack(utts))

    hw_c = tr.calibrate_and_compensate(hw, recorded, offs, CFG,
                                       sa_noise_std=1.0, seed=0)
    hw_cp, _ = m.as_hw_params(hw_c)
    for name in CFG.imc_layer_names():
        np.testing.assert_array_equal(res.bias[name],
                                      np.asarray(hw_cp.bias[name]),
                                      err_msg=name)
    feats = tr.hw_features(hw_c, recorded, CFG, chip_offsets=offs)
    w_ref, b_ref = quantized_head_finetune(
        jnp.asarray(feats), jnp.asarray(labels), hw_cp.fc_w, hw_cp.fc_b,
        TRAIN)
    np.testing.assert_array_equal(res.fc_w, np.asarray(w_ref))
    np.testing.assert_array_equal(res.fc_b, np.asarray(b_ref))
    # the compensation moved at least one bias (the run exercised it)
    assert any(
        not np.array_equal(res.bias[n], np.asarray(
            m.as_hw_params(hw)[0].bias[n]))
        for n in CFG.imc_layer_names())
    assert res.energy["uj_per_finetune_step"] > 0
    s = srv.stats()
    assert s["customization"]["sessions"][0]["phase"] == "swapped"
    assert s["learn_hops"] > 0


@pytest.mark.streaming
def test_session_matches_offline_loop_with_sa_noise(folded):
    """The noise-field-aware oracle: with SA noise enabled on the server,
    the session's captured features follow each stream's per-absolute-
    column field — and the offline loop, fed the session's recorded field
    (``feature_noise_field`` -> ``hw_features(sa_noise_field=...)``),
    lands on the SAME compensated biases and fine-tuned head bit for bit.
    This closes the former SA-noise-free scope of the contract."""
    hw = folded
    offs = _chip()
    srv = StreamServer(hw, CFG, hop=HOP, slots=4, use_kernel=True,
                       chip_offsets=offs, sa_noise_std=1.1)
    rng = np.random.default_rng(21)
    live = rng.uniform(-1, 1, L + 60 * HOP).astype(np.float32)
    srv.submit("live", live[:L])

    utts, labels = _utterances(4, seed=22)
    sess = srv.customize("user", CustomizeConfig(
        train=TRAIN, epochs_per_tick=7, layers_per_tick=2))
    for lab, u in zip(labels, utts):
        sess.enroll(lab, u)
    sess.finish_enrollment()
    _drive(srv, sess, live=live[L:])
    assert sess.phase == "swapped"
    res = sess.result
    recorded = np.stack(sess.windows)

    field = sess.feature_noise_field()
    assert field is not None and field.std == 1.1
    hw_c = tr.calibrate_and_compensate(hw, recorded, offs, CFG,
                                       sa_noise_std=1.0, seed=0,
                                       sa_noise_field=field)
    hw_cp, _ = m.as_hw_params(hw_c)
    for name in CFG.imc_layer_names():
        np.testing.assert_array_equal(res.bias[name],
                                      np.asarray(hw_cp.bias[name]),
                                      err_msg=name)
    feats = tr.hw_features(hw_c, recorded, CFG, chip_offsets=offs,
                           sa_noise_field=field)
    w_ref, b_ref = quantized_head_finetune(
        jnp.asarray(feats), jnp.asarray(labels), hw_cp.fc_w, hw_cp.fc_b,
        TRAIN)
    np.testing.assert_array_equal(res.fc_w, np.asarray(w_ref))
    np.testing.assert_array_equal(res.fc_b, np.asarray(b_ref))
    # the field is load-bearing: a noise-free oracle sees different
    # features (so the old fresh-noise oracle could not match)
    feats0 = tr.hw_features(hw_c, recorded, CFG, chip_offsets=offs)
    assert not np.array_equal(feats, feats0)


@pytest.mark.streaming
def test_enrollment_capture_noise_oracle_without_compensation(folded):
    """compensate=False under SA noise: the head trains directly on the
    enrollment captures — live-stream field values at each utterance's
    completion window (hop indices > 1, unlike the replay captures) —
    and the offline oracle reproduces them through the same field."""
    hw = folded
    hwp, _ = m.as_hw_params(hw)
    srv = StreamServer(hw, CFG, hop=HOP, slots=2, use_kernel=True,
                       sa_noise_std=0.8)
    utts, labels = _utterances(3, seed=23)
    sess = srv.customize("user", CustomizeConfig(
        train=OnChipTrainConfig(epochs=7), compensate=False))
    for lab, u in zip(labels, utts):
        sess.enroll(lab, u)
    sess.finish_enrollment()
    _drive(srv, sess)
    field = sess.feature_noise_field()
    hops = [int(h) for h in np.asarray(field.hops)]
    # enrollment captures sit at distinct live-stream window indices
    assert len(set(hops)) == len(hops) and max(hops) > 1
    feats = tr.hw_features(hw, np.stack(sess.windows), CFG,
                           sa_noise_field=field)
    w_ref, b_ref = quantized_head_finetune(
        jnp.asarray(feats), jnp.asarray(labels), hwp.fc_w, hwp.fc_b,
        OnChipTrainConfig(epochs=7))
    np.testing.assert_array_equal(sess.result.fc_w, np.asarray(w_ref))
    np.testing.assert_array_equal(sess.result.fc_b, np.asarray(b_ref))


@pytest.mark.streaming
def test_customization_does_not_disturb_other_streams(folded):
    """The live stream's decision sequence on a server running a full
    enrollment session is bit-identical to a plain server's — learning
    rides the same launches without perturbing inference slots."""
    hw = folded
    offs = _chip()
    rng = np.random.default_rng(2)
    live = rng.uniform(-1, 1, L + 30 * HOP).astype(np.float32)

    plain = StreamServer(hw, CFG, hop=HOP, slots=4, use_kernel=True,
                         chip_offsets=offs)
    plain.submit("live", live)
    plain.finish("live")
    ev_plain = [e for e in plain.drain() if e["stream"] == "live"]

    srv = StreamServer(hw, CFG, hop=HOP, slots=4, use_kernel=True,
                       chip_offsets=offs)
    srv.submit("live", live)
    srv.finish("live")
    utts, labels = _utterances(3, seed=3)
    sess = srv.customize("user", CustomizeConfig(
        train=OnChipTrainConfig(epochs=11), epochs_per_tick=4))
    for lab, u in zip(labels, utts):
        sess.enroll(lab, u)
    sess.finish_enrollment()
    events = srv.drain()
    for _ in range(200):
        if sess.done:
            break
        events.extend(srv.step())
    assert sess.done
    ev_live = [e for e in events if e["stream"] == "live"]
    assert ev_live == ev_plain


# ---------------------------------------------------------------------------
# Batched replay admission: one stream_init launch per wave
# ---------------------------------------------------------------------------


@pytest.mark.streaming
def test_batched_replay_init_bitexact_vs_sequential(folded):
    """batch_init=True (whole admission/replay wave in one masked
    stream_init) produces bit-identical session results AND live decision
    sequences to the sequential B=1 admission path — under SA noise and
    chip offsets — while issuing strictly fewer batched init calls."""
    hw = folded
    offs = _chip()

    def run(batch_init):
        srv = StreamServer(hw, CFG, hop=HOP, slots=4, use_kernel=True,
                           chip_offsets=offs, sa_noise_std=0.9,
                           batch_init=batch_init)
        rng = np.random.default_rng(24)
        live = rng.uniform(-1, 1, L + 40 * HOP).astype(np.float32)
        srv.submit("live", live[:L])
        utts, labels = _utterances(3, seed=25)
        sess = srv.customize("user", CustomizeConfig(
            train=OnChipTrainConfig(epochs=9), epochs_per_tick=5))
        for lab, u in zip(labels, utts):
            sess.enroll(lab, u)
        sess.finish_enrollment()
        pos, events = L, []
        for _ in range(300):
            if pos < len(live):
                srv.submit("live", live[pos:pos + HOP])
                pos += HOP
            events.extend(srv.step())
            if sess.done:
                break
        assert sess.done, sess.phase
        return (sess.result, [e for e in events if e["stream"] == "live"],
                srv.stats()["batched_calls"])

    res_b, ev_b, calls_b = run(True)
    res_s, ev_s, calls_s = run(False)
    for name in CFG.imc_layer_names():
        np.testing.assert_array_equal(res_b.bias[name], res_s.bias[name],
                                      err_msg=name)
    np.testing.assert_array_equal(res_b.fc_w, res_s.fc_w)
    np.testing.assert_array_equal(res_b.fc_b, res_s.fc_b)
    assert ev_b == ev_s
    # live + enrollment + a 3-replay wave: 5 sequential inits collapse to
    # 3 batched calls (the wave is one)
    assert calls_b["init"] < calls_s["init"]


@pytest.mark.streaming
def test_replay_wave_inits_in_one_launch(folded, monkeypatch):
    """The tick that initializes a session's whole wave of feature-replay
    streams traces exactly one pallas_call per IMC layer — one batched
    stream_init for the wave, not one per replay stream."""
    hw = folded
    offs = _chip()
    srv = StreamServer(hw, CFG, hop=HOP, slots=5, use_kernel=True,
                       chip_offsets=offs)
    utts, labels = _utterances(3, seed=26)
    sess = srv.customize("user", CustomizeConfig(train=TRAIN))
    for lab, u in zip(labels, utts):
        sess.enroll(lab, u)
    sess.finish_enrollment()

    def replay_init_pending():
        # replay slots admitted last tick, first window buffered, not
        # yet initialized -> this tick's _admit_ready runs the wave
        n = sum(1 for rec in srv._slots
                if rec is not None and rec.internal and not rec.initialized
                and len(rec.buf) >= L)
        return n >= 3

    for _ in range(400):
        if replay_init_pending():
            break
        srv.step()
    assert replay_init_pending(), "never reached a replay init wave"

    jax.clear_caches()
    calls = []
    real = pl.pallas_call

    def counting(*args, **kwargs):
        calls.append(kwargs.get("grid"))
        return real(*args, **kwargs)

    monkeypatch.setattr(pl, "pallas_call", counting)
    srv.step()
    assert len(calls) == CFG.num_conv_layers - 1, calls


# ---------------------------------------------------------------------------
# Persistent profiles: save -> restart -> install_custom, bit-identical
# ---------------------------------------------------------------------------


@pytest.mark.streaming
def test_profile_store_restart_roundtrip(folded, tmp_path):
    """A profile persisted with ProfileStore and restored into a FRESH
    server (a restart: nothing shared but the folded base model) serves
    bit-identically to both a pre-restart install and a dedicated server
    on the refolded PackedHWParams."""
    hw = folded
    offs = _chip()
    srv = StreamServer(hw, CFG, hop=HOP, slots=2, use_kernel=True,
                       chip_offsets=offs)
    utts, labels = _utterances(4, seed=27)
    sess = srv.customize("user", CustomizeConfig(
        train=OnChipTrainConfig(epochs=9), epochs_per_tick=5))
    for lab, u in zip(labels, utts):
        sess.enroll(lab, u)
    sess.finish_enrollment()
    _drive(srv, sess)
    res = sess.result
    refolded = sess.refolded()

    store = ProfileStore(str(tmp_path))
    store.save("user", res)
    assert store.list() == ["user"]
    loaded = store.load("user")
    for name in CFG.imc_layer_names():
        np.testing.assert_array_equal(loaded.bias[name], res.bias[name],
                                      err_msg=name)
    np.testing.assert_array_equal(loaded.fc_w, res.fc_w)
    np.testing.assert_array_equal(loaded.fc_b, res.fc_b)
    assert loaded.epochs == res.epochs
    assert loaded.n_utterances == res.n_utterances

    rng = np.random.default_rng(28)
    wav = rng.uniform(-1, 1, L + 6 * HOP).astype(np.float32)

    def serve(install):
        s2 = StreamServer(hw, CFG, hop=HOP, slots=2, use_kernel=True,
                          chip_offsets=offs, seed=29)
        s2.install_custom("u", install)
        s2.submit("u", wav)
        s2.finish("u")
        return s2.drain()

    ev_pre = serve(res)                      # pre-restart profile object
    ev_post = serve(loaded)                  # restored from disk
    assert ev_pre == ev_post

    srv_ref = StreamServer(refolded, CFG, hop=HOP, slots=2,
                           use_kernel=True, chip_offsets=offs, seed=29)
    srv_ref.submit("u", wav)
    srv_ref.finish("u")
    assert ev_post == srv_ref.drain()


# ---------------------------------------------------------------------------
# One-launch-per-layer on mixed inference+learning ticks
# ---------------------------------------------------------------------------


@pytest.mark.streaming
def test_mixed_tick_one_fused_launch_per_layer(folded, monkeypatch):
    """A tick where a live inference hop and session feature-replay hops
    land in the same batch must trace exactly one pallas_call per IMC
    layer — learning forwards ride the inference launch, they do not add
    launches."""
    hw = folded
    offs = _chip()
    srv = StreamServer(hw, CFG, hop=HOP, slots=3, use_kernel=True,
                       chip_offsets=offs)
    rng = np.random.default_rng(4)
    live = rng.uniform(-1, 1, L + 200 * HOP).astype(np.float32)
    srv.submit("live", live[:L])
    pos = L

    utts, labels = _utterances(3, seed=5)
    sess = srv.customize("user", CustomizeConfig(train=TRAIN))
    for lab, u in zip(labels, utts):
        sess.enroll(lab, u)
    sess.finish_enrollment()

    def replay_hop_pending():
        # a replay slot that initialized last tick and will hop this tick
        return any(rec is not None and rec.internal and rec.initialized
                   and len(rec.buf) >= HOP for rec in srv._slots)

    for _ in range(400):
        if replay_hop_pending():
            break
        srv.submit("live", live[pos:pos + HOP])
        pos += HOP
        srv.step()
    assert replay_hop_pending(), "never reached a replay hop"
    assert sess.phase == "extracting"

    srv.submit("live", live[pos:pos + HOP])     # live hop rides along too
    jax.clear_caches()
    calls = []
    real = pl.pallas_call

    def counting(*args, **kwargs):
        calls.append(kwargs.get("grid"))
        return real(*args, **kwargs)

    monkeypatch.setattr(pl, "pallas_call", counting)
    srv.step()
    assert len(calls) == CFG.num_conv_layers - 1, calls


@pytest.mark.streaming
def test_concurrent_sessions_one_launch_and_offline_equal(folded,
                                                          monkeypatch):
    """N concurrent enrollment sessions on ONE server: a tick where BOTH
    sessions' replay hops ride the batch with a live inference hop still
    traces exactly one pallas_call per IMC layer (launches never scale
    with N), and each session's final result equals its own offline
    oracle on its own recorded utterances."""
    hw = folded
    offs = _chip()
    srv = StreamServer(hw, CFG, hop=HOP, slots=8, use_kernel=True,
                       chip_offsets=offs)
    rng = np.random.default_rng(30)
    live = rng.uniform(-1, 1, L + 400 * HOP).astype(np.float32)
    srv.submit("live", live[:L])
    pos = L

    tcfg = OnChipTrainConfig(epochs=9)
    sessions, per_sess = [], []
    for k in range(2):
        utts, labels = _utterances(2, seed=31 + k)
        # use_kernel=False keeps the SGA optimizer transition on the jnp
        # path (bit-identical — test-enforced above) so the traced tick
        # counts only the fused IMC launches
        s = srv.customize(f"user{k}", CustomizeConfig(
            train=tcfg, epochs_per_tick=5, layers_per_tick=2,
            use_kernel=False))
        for lab, u in zip(labels, utts):
            s.enroll(lab, u)
        s.finish_enrollment()
        sessions.append(s)
        per_sess.append((utts, labels))

    def replay_hops_ready():
        owners = set()
        for rec in srv._slots:
            if (rec is not None and rec.internal and rec.initialized
                    and len(rec.buf) >= HOP):
                # replay ids are "~cust{sid}u{j}" — strip the utterance
                owners.add(rec.stream_id[:rec.stream_id.rindex("u")])
        return len(owners) >= 2

    traced = False
    for _ in range(600):
        if not traced and replay_hops_ready():
            # both sessions' replay hops + the live hop in one batch
            srv.submit("live", live[pos:pos + HOP])
            pos += HOP
            jax.clear_caches()
            calls = []
            real = pl.pallas_call

            def counting(*args, **kwargs):
                calls.append(kwargs.get("grid"))
                return real(*args, **kwargs)

            monkeypatch.setattr(pl, "pallas_call", counting)
            srv.step()
            monkeypatch.setattr(pl, "pallas_call", real)
            assert len(calls) == CFG.num_conv_layers - 1, calls
            traced = True
            continue
        if pos < len(live):
            srv.submit("live", live[pos:pos + HOP])
            pos += HOP
        srv.step()
        if all(s.done for s in sessions):
            break
    assert traced, "never hit a tick with both sessions' replay hops"
    assert all(s.done for s in sessions), [s.phase for s in sessions]

    for s, (utts, labels) in zip(sessions, per_sess):
        recorded = np.stack(s.windows)
        np.testing.assert_array_equal(recorded, np.stack(utts))
        hw_c = tr.calibrate_and_compensate(hw, recorded, offs, CFG,
                                           sa_noise_std=1.0, seed=0)
        hw_cp, _ = m.as_hw_params(hw_c)
        for name in CFG.imc_layer_names():
            np.testing.assert_array_equal(s.result.bias[name],
                                          np.asarray(hw_cp.bias[name]),
                                          err_msg=name)
        feats = tr.hw_features(hw_c, recorded, CFG, chip_offsets=offs)
        w_ref, b_ref = quantized_head_finetune(
            jnp.asarray(feats), jnp.asarray(labels), hw_cp.fc_w,
            hw_cp.fc_b, tcfg)
        np.testing.assert_array_equal(s.result.fc_w, np.asarray(w_ref))
        np.testing.assert_array_equal(s.result.fc_b, np.asarray(b_ref))


# ---------------------------------------------------------------------------
# Step-wise core pieces
# ---------------------------------------------------------------------------


def test_finetune_epochs_chunked_resumable():
    """Any chunking of the epoch range equals the monolithic loop."""
    rng = np.random.default_rng(6)
    feats = jnp.asarray(rng.normal(size=(12, 32)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 10, 12).astype(np.int32))
    w0 = jnp.asarray(rng.normal(size=(32, 10)).astype(np.float32) * 0.05)
    b0 = jnp.zeros((10,))
    cfg = OnChipTrainConfig(epochs=30)
    w_ref, b_ref = quantized_head_finetune(feats, labels, w0, b0, cfg)

    state, fq, oh = finetune_init(feats, labels, w0, b0, cfg)
    for start, n in ((0, 7), (7, 7), (14, 7), (21, 9)):
        state = finetune_epochs(state, fq, oh, cfg, start, n)
    np.testing.assert_array_equal(np.asarray(state.w), np.asarray(w_ref))
    np.testing.assert_array_equal(np.asarray(state.b), np.asarray(b_ref))


def test_sga_update_batch_matches_jnp_apply():
    """The row-batched fused kernel (per-row lr/G_th) == the jnp
    SGA + SGD + quantize path, elementwise, for every row."""
    rng = np.random.default_rng(7)
    cfg = OnChipTrainConfig(epochs=1)
    rows = 3
    d, c = 40, 10
    states, grads, lrs = [], [], [1.0 / 16, 1.0 / 32, 1.0 / 128]
    for r in range(rows):
        w = cfg.weight_fmt.quantize(
            jnp.asarray(rng.normal(size=(d, c)).astype(np.float32) * 0.3))
        b = cfg.weight_fmt.quantize(
            jnp.asarray(rng.normal(size=(c,)).astype(np.float32) * 0.3))
        aw = cfg.accum_fmt.quantize(
            jnp.asarray(rng.normal(size=(d, c)).astype(np.float32) * 0.02))
        ab = jnp.zeros((c,))
        gw = cfg.grad_fmt.quantize(
            jnp.asarray(rng.normal(size=(d, c)).astype(np.float32) * 0.2))
        gb = cfg.grad_fmt.quantize(
            jnp.asarray(rng.normal(size=(c,)).astype(np.float32) * 0.2))
        states.append((w, b, aw, ab))
        grads.append((gw, gb))

    rows_w = jnp.stack([jnp.concatenate([w.ravel(), b.ravel()])
                        for (w, b, _, _) in states])
    rows_g = jnp.stack([jnp.concatenate([gw.ravel(), gb.ravel()])
                        for (gw, gb) in grads])
    rows_a = jnp.stack([jnp.concatenate([aw.ravel(), ab.ravel()])
                        for (_, _, aw, ab) in states])
    lr_arr = jnp.asarray(lrs)
    th_arr = jnp.stack([sga_threshold(lr, cfg.weight_fmt) for lr in lrs])
    nw, na = sga_ops.sga_update_batch(
        rows_w, rows_g, rows_a, lr_arr, th_arr,
        w_scale=cfg.weight_fmt.scale, w_max=cfg.weight_fmt.max_value,
        a_scale=cfg.accum_fmt.scale)

    from repro.core.onchip_training import HeadState
    for r in range(rows):
        w, b, aw, ab = states[r]
        gw, gb = grads[r]
        st = HeadState(w=w, b=b, accum_w=aw, accum_b=ab,
                       key=jax.random.PRNGKey(0))
        ref = apply_update(st, gw, gb, jnp.asarray(lrs[r]),
                           st.key, cfg)
        got_w = nw[r, :d * c].reshape(d, c)
        got_b = nw[r, d * c:d * c + c]
        got_aw = na[r, :d * c].reshape(d, c)
        got_ab = na[r, d * c:d * c + c]
        np.testing.assert_array_equal(np.asarray(got_w), np.asarray(ref.w))
        np.testing.assert_array_equal(np.asarray(got_b), np.asarray(ref.b))
        np.testing.assert_array_equal(np.asarray(got_aw),
                                      np.asarray(ref.accum_w))
        np.testing.assert_array_equal(np.asarray(got_ab),
                                      np.asarray(ref.accum_b))


def test_calibration_stepwise_matches_driver(folded):
    """compensate_layer_bias chunks (the tick-resumable path) == the
    monolithic calibrate_and_compensate driver."""
    hw = folded
    offs = _chip()
    rng = np.random.default_rng(8)
    xcal = rng.uniform(-1, 1, (4, L)).astype(np.float32)
    ref = tr.calibrate_and_compensate(hw, xcal, offs, CFG,
                                      sa_noise_std=1.0, seed=0)
    ref_hw, _ = m.as_hw_params(ref)

    hwp, _ = m.as_hw_params(hw)
    ideal = tr.calibration_ideal_counts(hw, xcal, CFG)
    keys = tr.calibration_layer_keys(CFG, seed=0)
    for name in CFG.imc_layer_names():
        got = tr.compensate_layer_bias(hwp.bias[name], ideal[name],
                                       offs[name], keys[name], 1.0)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(ref_hw.bias[name]),
                                      err_msg=name)


# ---------------------------------------------------------------------------
# Hot swap / profile install
# ---------------------------------------------------------------------------


@pytest.mark.streaming
def test_install_custom_matches_refolded_server(folded):
    """A profile installed into a fresh server's stream serves
    bit-identically to a dedicated server folded from the refolded
    PackedHWParams — the per-slot riders ARE the refolded model."""
    hw = folded
    offs = _chip()
    srv = StreamServer(hw, CFG, hop=HOP, slots=2, use_kernel=True,
                       chip_offsets=offs)
    utts, labels = _utterances(4, seed=9)
    sess = srv.customize("user", CustomizeConfig(
        train=OnChipTrainConfig(epochs=9), epochs_per_tick=5))
    for lab, u in zip(labels, utts):
        sess.enroll(lab, u)
    sess.finish_enrollment()
    _drive(srv, sess)
    res = sess.result
    refolded = sess.refolded()
    assert isinstance(refolded, m.PackedHWParams)

    rng = np.random.default_rng(10)
    wav = rng.uniform(-1, 1, L + 6 * HOP).astype(np.float32)

    srv_a = StreamServer(hw, CFG, hop=HOP, slots=2, use_kernel=True,
                         chip_offsets=offs, seed=11)
    srv_a.install_custom("u", res)
    srv_a.submit("u", wav)
    srv_a.finish("u")
    ev_a = srv_a.drain()

    srv_b = StreamServer(refolded, CFG, hop=HOP, slots=2, use_kernel=True,
                         chip_offsets=offs, seed=11)
    srv_b.submit("u", wav)
    srv_b.finish("u")
    ev_b = srv_b.drain()
    assert ev_a == ev_b
    assert len(ev_a) == 7


@pytest.mark.streaming
def test_hot_swap_changes_only_the_target_slot(folded):
    """After the swap, the target slot's rider rows hold the profile and
    every other slot's rows still hold the base model."""
    hw = folded
    hwp, _ = m.as_hw_params(hw)
    srv = StreamServer(hw, CFG, hop=HOP, slots=3, use_kernel=True)
    rng = np.random.default_rng(12)
    srv.submit("other", rng.uniform(-1, 1, L + 2 * HOP)
               .astype(np.float32))
    utts, labels = _utterances(3, seed=13)
    sess = srv.customize("user", CustomizeConfig(
        train=OnChipTrainConfig(epochs=5), compensate=False))
    for lab, u in zip(labels, utts):
        sess.enroll(lab, u)
    sess.finish_enrollment()
    _drive(srv, sess)
    assert sess.phase == "swapped"

    u_slot = srv._streams["user"].slot
    o_slot = srv._streams["other"].slot
    assert u_slot is not None and o_slot is not None
    np.testing.assert_array_equal(
        np.asarray(srv._slot_head_w[u_slot]), sess.result.fc_w)
    np.testing.assert_array_equal(
        np.asarray(srv._slot_head_w[o_slot]), np.asarray(hwp.fc_w))
    for name in CFG.imc_layer_names():
        np.testing.assert_array_equal(
            np.asarray(srv._slot_delta[name][o_slot]), 0.0)
    # compensate=False: the profile's biases equal the base (delta 0) and
    # fine-tuning ran directly on the enrollment features
    feats = tr.hw_features(hw, np.stack(sess.windows), CFG)
    w_ref, b_ref = quantized_head_finetune(
        jnp.asarray(feats), jnp.asarray(labels), hwp.fc_w, hwp.fc_b,
        OnChipTrainConfig(epochs=5))
    np.testing.assert_array_equal(sess.result.fc_w, np.asarray(w_ref))
    np.testing.assert_array_equal(sess.result.fc_b, np.asarray(b_ref))


# ---------------------------------------------------------------------------
# Multi-hop wake replay (serving follow-on satellite)
# ---------------------------------------------------------------------------


@pytest.mark.streaming
def test_multi_step_bitexact_vs_sequential(folded):
    """stream_multi_step == n sequential stream_steps, SA noise field
    included (per-absolute-column: the same columns get the same
    realizations no matter how they are batched)."""
    hw = folded
    geom = make_stream_geometry(CFG, HOP)
    audio = jax.random.uniform(jax.random.PRNGKey(14), (2, L + 3 * HOP),
                               minval=-1, maxval=1)
    keys = jnp.stack([jax.random.PRNGKey(1), jax.random.PRNGKey(2)])
    _, st0 = sv.stream_init(hw, audio[:, :L], keys, CFG, geom,
                            sa_noise_std=0.9, use_kernel=True)
    st = st0
    seq = []
    for t in range(1, 4):
        lg, st = sv.stream_step(hw, st,
                                audio[:, L + (t - 1) * HOP:L + t * HOP],
                                CFG, geom, sa_noise_std=0.9,
                                use_kernel=True)
        seq.append(np.asarray(lg))
    lg_m, st_m = sv.stream_multi_step(hw, st0, audio[:, L:L + 3 * HOP],
                                      CFG, geom, 3, sa_noise_std=0.9,
                                      use_kernel=True)
    np.testing.assert_array_equal(np.asarray(lg_m),
                                  np.stack(seq, axis=1))
    for a, b in zip(jax.tree_util.tree_leaves(st_m),
                    jax.tree_util.tree_leaves(st)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.streaming
def test_wake_replay_is_one_launch(folded, monkeypatch):
    """The wake replay drains its whole deferred run (margin + onset
    hops) in ONE fused launch per IMC layer instead of one per hop."""
    hw = folded
    rng = np.random.default_rng(15)
    wav = rng.uniform(-1, 1, L + 8 * HOP).astype(np.float32)
    wav[L + 1 * HOP:L + 4 * HOP] *= 1e-4     # 3 silent hops, then speech
    srv = StreamServer(hw, CFG, hop=HOP, slots=1, use_kernel=True,
                       vad=VADConfig(threshold_on_db=-40.0,
                                     threshold_off_db=-50.0,
                                     ema=0.0, wake_margin=3, hang=0))
    srv.submit("s", wav[:L + 4 * HOP])
    srv.step()                               # admission
    for _ in range(4):
        srv.step()                           # loud hop, then 3 deferred
    rec = srv._streams["s"]
    assert len(rec.pending) == 3
    srv.submit("s", wav[L + 4 * HOP:L + 5 * HOP])   # loud: wakes
    jax.clear_caches()
    calls = []
    real = pl.pallas_call

    def counting(*args, **kwargs):
        calls.append(kwargs.get("grid"))
        return real(*args, **kwargs)

    monkeypatch.setattr(pl, "pallas_call", counting)
    events = srv.step()
    assert len(events) == 4                  # 3 deferred + the onset hop
    assert len(calls) == CFG.num_conv_layers - 1, calls
