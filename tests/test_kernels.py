"""Per-kernel validation vs the pure-jnp oracles (interpret=True on CPU),
sweeping shapes and dtypes per the deliverable."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantize import ACT_Q, WEIGHT_Q
from repro.kernels.imc_mav import ops as mav_ops
from repro.kernels.imc_mav.ref import imc_mav_ref
from repro.kernels.int8_matmul.int8_matmul import int8_matmul
from repro.kernels.int8_matmul.ref import int8_matmul_ref
from repro.kernels.sga_update.ops import sga_update_tree
from repro.kernels.sga_update.ref import sga_update_ref


def _pm1(key, shape, dtype=jnp.float32):
    return jnp.where(jax.random.bernoulli(key, 0.5, shape), 1.0,
                     -1.0).astype(dtype)


@pytest.mark.parametrize("m,k,n", [(64, 72, 24), (300, 72, 96),
                                   (257, 48, 130), (512, 128, 576)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_imc_mav_shapes_dtypes(m, k, n, dtype):
    key = jax.random.PRNGKey(m * 7 + k + n)
    x = _pm1(key, (m, k), dtype)
    w = _pm1(jax.random.fold_in(key, 1), (k, n), dtype)
    bias = (jnp.round(jax.random.normal(jax.random.fold_in(key, 2),
                                        (n,)) * 10) * 2).astype(jnp.float32)
    flip = _pm1(jax.random.fold_in(key, 3), (n,), jnp.float32)
    out = mav_ops.mav_matmul(x, w, bias, flip)
    ref = imc_mav_ref(x, w, bias, flip)
    np.testing.assert_array_equal(np.asarray(out, np.float32),
                                  np.asarray(ref, np.float32))


def test_imc_mav_with_noise():
    key = jax.random.PRNGKey(0)
    x = _pm1(key, (128, 72))
    w = _pm1(jax.random.fold_in(key, 1), (72, 96))
    bias = jnp.zeros((96,))
    flip = jnp.ones((96,))
    noise = 4.0 * jax.random.normal(jax.random.fold_in(key, 2), (128, 96))
    out = mav_ops.mav_matmul(x, w, bias, flip, noise)
    ref = imc_mav_ref(x, w, bias, flip, noise)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # noise must actually flip some decisions
    clean = imc_mav_ref(x, w, bias, flip)
    assert np.mean(np.asarray(out) != np.asarray(clean)) > 0.01


def test_imc_mav_conv_path_matches_model():
    """conv_mav == the model's conv+mav_sa reference on a group conv."""
    from repro.core import imc
    key = jax.random.PRNGKey(5)
    x = _pm1(key, (2, 40, 48))
    w = _pm1(jax.random.fold_in(key, 1), (3, 24, 96))
    bias = (jnp.round(jax.random.normal(jax.random.fold_in(key, 2),
                                        (96,)) * 5) * 2)
    flip = _pm1(jax.random.fold_in(key, 3), (96,), jnp.float32)
    got = mav_ops.conv_mav(x, w, bias, flip, groups=2)
    counts = imc.binary_group_conv_counts(x, w, groups=2)
    want = imc.mav_sa(counts, bias, flip)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m,k,n", [(256, 128, 128), (256, 576, 128),
                                   (512, 128, 256)])
@pytest.mark.parametrize("shift", [0, 4, 7])
def test_int8_matmul_bitexact(m, k, n, shift):
    key = jax.random.PRNGKey(m + n + shift)
    x = jax.random.randint(key, (m, k), -127, 128, jnp.int8)
    w = jax.random.randint(jax.random.fold_in(key, 1), (k, n), -127, 128,
                           jnp.int8)
    b = jax.random.randint(jax.random.fold_in(key, 2), (n,), -1000, 1000,
                           jnp.int32)
    out = int8_matmul(x, w, b, shift=shift)
    ref = int8_matmul_ref(x, w, b, shift=shift)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("n", [1000, 1024, 5003])
@pytest.mark.parametrize("lr,g_th", [(1 / 16, 0.078125), (1 / 128, 0.5)])
def test_sga_update_kernel(n, lr, g_th):
    key = jax.random.PRNGKey(n)
    w = WEIGHT_Q.quantize(jax.random.uniform(key, (n,), minval=-1, maxval=1))
    g = jax.random.normal(jax.random.fold_in(key, 1), (n,)) * 0.05
    a = jax.random.uniform(jax.random.fold_in(key, 2), (n,),
                           minval=-0.05, maxval=0.05)
    nw, na = sga_update_tree({"w": w}, {"w": g}, {"w": a}, lr, g_th)
    rw, ra = sga_update_ref(w, g, a, lr, g_th)
    np.testing.assert_allclose(np.asarray(nw["w"]), np.asarray(rw),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(na["w"]), np.asarray(ra),
                               atol=1e-6)
