"""Bit-exact validation of the fused grouped IMC layer kernel
(repro.kernels.imc_mav.imc_fused / ops.fused_conv_mav) against the
binary_group_conv_counts + mav_sa + channel_shuffle + or_maxpool oracle,
across all five paper IMC layer shapes, plus the hw_forward wiring
(one pallas_call per layer, bit-identical to the jnp path)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl

from repro.core import imc
from repro.kernels.imc_mav import ops as mav_ops
from repro.kernels.imc_mav.ref import fused_conv_mav_ref as _oracle_ref
from repro.models import kws as m

# (c_in, c_out, groups, stride, pool) for the paper's IMC layers L2..L6
# (conv1..conv5 of KWSConfig: cpg=24, k=3)
PAPER_IMC_LAYERS = [
    pytest.param(24, 96, 1, 1, 2, id="L2-24to96-g1-pool2"),
    pytest.param(96, 192, 4, 1, 2, id="L3-96to192-g4-pool2"),
    pytest.param(192, 288, 8, 1, 1, id="L4-192to288-g8-nopool"),
    pytest.param(288, 384, 12, 1, 2, id="L5-288to384-g12-pool2"),
    pytest.param(384, 576, 16, 1, 2, id="L6-384to576-g16-pool2"),
]


def _pm1(key, shape):
    return jnp.where(jax.random.bernoulli(key, 0.5, shape), 1.0, -1.0)


def _oracle(x, w, bias, flip, groups, stride, pool, chip_offset=None,
            sa_key=None, sa_noise_std=0.0):
    return _oracle_ref(x, w, bias, flip, groups=groups, stride=stride,
                       pool=pool, chip_offset=chip_offset, sa_key=sa_key,
                       sa_noise_std=sa_noise_std)


@pytest.mark.parametrize("c_in,c_out,groups,stride,pool", PAPER_IMC_LAYERS)
@pytest.mark.parametrize("noisy", [False, True], ids=["clean", "noise"])
def test_fused_conv_mav_bitexact_paper_layers(c_in, c_out, groups, stride,
                                              pool, noisy):
    key = jax.random.PRNGKey(c_out * 3 + groups)
    x = _pm1(key, (2, 25, c_in))
    w = _pm1(jax.random.fold_in(key, 1), (3, c_in // groups, c_out))
    bias = jnp.round(
        jax.random.normal(jax.random.fold_in(key, 2), (c_out,)) * 8) * 2
    flip = _pm1(jax.random.fold_in(key, 3), (c_out,))
    chip_off = 4.0 * jax.random.normal(jax.random.fold_in(key, 4), (c_out,))
    sa_key = jax.random.fold_in(key, 5) if noisy else None
    std = 1.5 if noisy else 0.0

    got = mav_ops.fused_conv_mav(x, w, bias, flip, groups=groups,
                                 stride=stride, pool=pool,
                                 chip_offset=chip_off, sa_key=sa_key,
                                 sa_noise_std=std)
    want = _oracle(x, w, bias, flip, groups, stride, pool,
                   chip_offset=chip_off, sa_key=sa_key, sa_noise_std=std)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    if noisy:
        clean = _oracle(x, w, bias, flip, groups, stride, pool,
                        chip_offset=chip_off)
        assert np.mean(np.asarray(want) != np.asarray(clean)) > 0.001


def test_fused_conv_mav_stride_and_odd_t():
    """Stride > 1 and a T that leaves a pool remainder (truncated window)."""
    key = jax.random.PRNGKey(7)
    x = _pm1(key, (3, 29, 48))
    w = _pm1(jax.random.fold_in(key, 1), (3, 24, 96))
    bias = jnp.zeros((96,))
    flip = jnp.ones((96,))
    got = mav_ops.fused_conv_mav(x, w, bias, flip, groups=2, stride=2,
                                 pool=2)
    want = _oracle(x, w, bias, flip, groups=2, stride=2, pool=2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_group_pack_layout_paper_shapes():
    """The packing actually shares MXU lanes: every multi-group paper layer
    packs >= 2 groups per grid step and needs fewer grid steps than groups."""
    for (_, c_out, groups, _, _) in [p.values for p in PAPER_IMC_LAYERS]:
        cog = c_out // groups
        lt = imc.make_group_pack_layout(groups, cog, 3, 24)
        assert lt.packs * lt.gpb >= groups
        assert lt.gpb * cog <= lt.lanes
        if groups > 1:
            assert lt.gpb >= 2
            assert lt.packs < groups


def test_hw_forward_fused_bitexact_incl_noise_and_offsets():
    cfg = m.KWSConfig(sample_len=600)
    p = m.init_params(jax.random.PRNGKey(5), cfg)
    st = m.init_state(cfg)
    x = jnp.round(jax.random.uniform(jax.random.PRNGKey(6),
                                     (2, cfg.sample_len),
                                     minval=-1, maxval=1) * 127) / 127
    hw = m.fold_params(p, st, cfg)
    _, f_a = m.hw_forward(hw, x, cfg, use_kernel=False)
    _, f_b = m.hw_forward(hw, x, cfg, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(f_a), np.asarray(f_b))

    chans = {f"conv{i}": cfg.channels[i]
             for i in range(1, cfg.num_conv_layers)}
    offs = imc.sample_chip_offsets(jax.random.PRNGKey(9), chans,
                                   imc.IMCNoiseParams())
    rng = jax.random.PRNGKey(11)
    _, f_c = m.hw_forward(hw, x, cfg, chip_offsets=offs, sa_noise_std=1.0,
                          rng=rng, use_kernel=False)
    _, f_d = m.hw_forward(hw, x, cfg, chip_offsets=offs, sa_noise_std=1.0,
                          rng=rng, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(f_c), np.asarray(f_d))


def test_hw_forward_one_pallas_call_per_imc_layer(monkeypatch):
    """use_kernel=True must trace exactly one pallas_call per IMC layer —
    the group dimension lives in the kernel grid, not a Python loop."""
    calls = []
    real = pl.pallas_call

    def counting(*args, **kwargs):
        calls.append(kwargs.get("grid"))
        return real(*args, **kwargs)

    # fresh jit caches: other tests (e.g. streaming tails) may already have
    # traced same-shaped kernel calls, which would hide their pallas_call
    jax.clear_caches()
    monkeypatch.setattr(pl, "pallas_call", counting)
    # unique sample_len => fresh shapes => every layer retraces under jit
    cfg = m.KWSConfig(sample_len=616)
    p = m.init_params(jax.random.PRNGKey(0), cfg)
    st = m.init_state(cfg)
    hw = m.fold_params(p, st, cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (1, cfg.sample_len),
                           minval=-1, maxval=1)
    m.hw_forward(hw, x, cfg, use_kernel=True)
    assert len(calls) == cfg.num_conv_layers - 1        # conv1..conv5 only


def test_hw_forward_collect_counts_falls_back():
    """The chip's count-digitizing test mode still works with use_kernel."""
    cfg = m.KWSConfig(sample_len=600)
    p = m.init_params(jax.random.PRNGKey(2), cfg)
    st = m.init_state(cfg)
    hw = m.fold_params(p, st, cfg)
    x = jax.random.uniform(jax.random.PRNGKey(3), (1, cfg.sample_len),
                           minval=-1, maxval=1)
    lg, feats, counts = m.hw_forward(hw, x, cfg, collect_counts=True,
                                     use_kernel=True)
    assert set(counts) == {f"conv{i}" for i in range(cfg.num_conv_layers)}
    lg2, _ = m.hw_forward(hw, x, cfg, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(lg), np.asarray(lg2))
