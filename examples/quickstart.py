"""Quickstart: the paper's pipeline end-to-end at smoke scale (~2 min CPU).

  1. synthesize a keyword corpus,
  2. train the IMC-aware BNN briefly (annealed binarization),
  3. fold to the hardware path (in-memory BN grid),
  4. inject chip noise -> bias compensation,
  5. customize the classifier head on-chip (error scaling + SGA + RGP).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import imc
from repro.core.onchip_training import (OnChipTrainConfig, head_accuracy,
                                        quantized_head_finetune)
from repro.data import audio
from repro.models import kws as m
from repro.training import kws as tr

L = 1000
cfg = m.KWSConfig(sample_len=L)
(xtr, ytr), (xte, yte) = audio.make_gscd_like(train_per_class=16,
                                              test_per_class=6, length=L)
print("== 1) train (smoke budget) ==")
tcfg = tr.TrainConfig(epochs=18, batch_size=80, lr=3e-3, log_every=18,
                      alpha_schedule=((0.35, 2.0), (0.55, 5.0),
                                      (0.7, 12.0), (1.0, -8.0)))
params, state = tr.train_base(xtr, ytr, cfg, tcfg)

print("== 2) fold to hardware ==")
hw = m.fold_params(params, state, cfg)
print("   hw accuracy:", tr.evaluate_hw(hw, xte, yte, cfg))

print("== 3) chip noise + compensation ==")
chans = {f"conv{i}": cfg.channels[i] for i in range(1, cfg.num_conv_layers)}
noise = imc.IMCNoiseParams(mav_offset_std=8.0, sa_noise_std=1.0)
offs = imc.sample_chip_offsets(jax.random.PRNGKey(0), chans, noise)
print("   noisy   :", tr.evaluate_hw(hw, xte, yte, cfg, chip_offsets=offs,
                                     sa_noise_std=1.0))
hw_c = tr.calibrate_and_compensate(hw, xtr[:100], offs, cfg)
print("   compensated:", tr.evaluate_hw(hw_c, xte, yte, cfg,
                                        chip_offsets=offs, sa_noise_std=1.0))

print("== 4) on-chip customization (personal set) ==")
(xp_tr, yp_tr), (xp_te, yp_te) = audio.make_personal(
    train_per_class=3, test_per_class=4, length=L, accent_shift=0.18)
f_tr = tr.hw_features(hw_c, xp_tr, cfg, chip_offsets=offs, sa_noise_std=1.0)
f_te = tr.hw_features(hw_c, xp_te, cfg, chip_offsets=offs, sa_noise_std=1.0)
print("   before:", tr.evaluate_hw(hw_c, xp_te, yp_te, cfg,
                                   chip_offsets=offs, sa_noise_std=1.0))
ocfg = OnChipTrainConfig(epochs=400, error_scaling=True, sga=True, rgp=True)
w, b = quantized_head_finetune(f_tr, yp_tr, np.asarray(hw_c.fc_w),
                               np.asarray(hw_c.fc_b), ocfg)
print("   after :", float(head_accuracy(f_te, yp_te, w, b, ocfg)))
