"""Always-on streaming KWS quickstart: a few live audio streams through the
multi-stream serving engine.

  1. fold a model to the hardware path (reuses the cached trained model
     from benchmarks.kws_experiments if present, else folds an untrained
     one — the serving mechanics are identical),
  2. synthesize a few "microphone" streams: keyword utterances embedded in
     noise at random offsets,
  3. run the slot-based StreamServer with voice-activity gating: every
     step batches all live streams' fresh frames into ONE fused-kernel
     launch per IMC layer, each stream advancing a sliding decision window
     by `hop` samples at ~hop/window of the full per-decision work
     (frame-incremental reuse) — and hops the VAD classifies as silence
     skip the IMC stack entirely (no-op fill advance, leakage-only in the
     energy model), with a wake margin replaying the hops right before a
     speech onset so no keyword prefix is lost,
  4. print trigger events (posterior-smoothed + hysteresis + refractory)
     and the server's throughput / duty-cycle / per-decision MAC and
     energy accounting.

Run:  PYTHONPATH=src python examples/stream_kws.py
      REPRO_EXAMPLES_SMOKE=1 ... for a seconds-scale smoke run (used by
      tests/test_examples.py)
"""
import os
import pickle

import jax
import numpy as np

from repro.data import audio
from repro.models import kws as m
from repro.serving import DecisionConfig, StreamServer, VADConfig

SMOKE = os.environ.get("REPRO_EXAMPLES_SMOKE") == "1"
L, HOP = (640, 64) if SMOKE else (2000, 256)   # hop/window = 0.1 / 0.128
N_STREAMS = 1 if SMOKE else 3
TAIL_HOPS = 8 if SMOKE else 24
cfg = m.KWSConfig(sample_len=L)

pkl = os.path.join(os.path.dirname(__file__), "..", "results",
                   "kws_model.pkl")
if os.path.exists(pkl) and not SMOKE:
    with open(pkl, "rb") as f:
        params, state = pickle.load(f)
    params = jax.tree_util.tree_map(np.asarray, params)
    state = m.KWSState(*[jax.tree_util.tree_map(np.asarray, s)
                         for s in state])
    print("== folded the trained model from results/kws_model.pkl ==")
else:
    params = m.init_params(jax.random.PRNGKey(0), cfg)
    state = m.init_state(cfg)
    print("== no cached model (run benchmarks.kws_experiments for a "
          "trained one); folding an untrained net to demo the serving "
          "path ==")
hw = m.fold_params(params, state, cfg, pack=True)   # pack once, serve many

# synth streams: keyword clips at random offsets in low noise
rng = np.random.default_rng(0)
(clips, labels), _ = audio.make_gscd_like(train_per_class=1,
                                          test_per_class=1, length=L)
streams = {}
for i in range(N_STREAMS):
    # long stream, keyword early: the silent tail is what the VAD gates
    wav = 0.01 * rng.standard_normal(L + TAIL_HOPS * HOP).astype(np.float32)
    j = rng.integers(len(labels))
    at = int(rng.integers(0, 4 * HOP))
    wav[at:at + L] += clips[j].astype(np.float32)
    streams[f"mic{i}"] = (wav, int(labels[j]), at)

srv = StreamServer(hw, cfg, hop=HOP, slots=4, use_kernel=True,
                   decision=DecisionConfig(smooth=4, threshold_on=0.5,
                                           threshold_off=0.35,
                                           refractory=6),
                   # the 0.01-amplitude noise floor sits at ~-40 dBFS:
                   # well under the on threshold, so hops outside the
                   # embedded keyword windows are gated (leakage-only)
                   vad=VADConfig(threshold_on_db=-30.0,
                                 threshold_off_db=-36.0,
                                 wake_margin=2, hang=1))
print(f"== serving {len(streams)} streams "
      f"(window={L}, hop={HOP}, slots=4) ==")
for sid, (wav, kw, at) in streams.items():
    print(f"   {sid}: keyword {kw} at sample {at}")
    # feed in ~real-time-ish chunks, as a microphone driver would
    for off in range(0, len(wav), 517):
        srv.submit(sid, wav[off:off + 517])
    srv.finish(sid)

for ev in srv.drain():
    if ev["trigger"]:
        print(f"   TRIGGER {ev['stream']} hop {ev['hop']}: "
              f"keyword {ev['keyword']} (score {ev['score']:.2f})")

s = srv.stats()
print(f"== {s['decisions']} decisions, "
      f"{s['decisions_per_sec']} decisions/s, "
      f"streaming MACs/decision = "
      f"{s['macs_per_decision']['ratio']:.3f}x offline ==")
g = s["gated_energy"]
print(f"== VAD duty cycle {s['duty_cycle']:.2f} "
      f"({s['speech_hops']} speech / {s['gated_hops']} gated hops): "
      f"{g['gated_uj_per_decision']:.3f} uJ/decision vs "
      f"{g['ungated_uj_per_decision']:.3f} ungated "
      f"({g['reduction_vs_ungated']:.2f}x) ==")
