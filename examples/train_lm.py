"""End-to-end LM training driver on a reduced assigned architecture, with
checkpoint/restart (kill it mid-run and re-run: it resumes).

Run:  PYTHONPATH=src python examples/train_lm.py [arch]
"""
import sys

from repro.launch.train import train_loop

arch = sys.argv[1] if len(sys.argv) > 1 else "qwen3-moe-30b-a3b"
params, metrics = train_loop(arch, steps=40, reduced=True, batch=8, seq=64,
                             ckpt_dir="/tmp/repro_ckpt_" + arch,
                             ckpt_every=10, log_every=5)
print(f"[train_lm] {arch} final: {metrics}")
