"""Batched LM serving demo (prefill + decode slots) on a reduced config.

Run:  PYTHONPATH=src python examples/serve_lm.py [arch]
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0]] + (["--arch", sys.argv[1]]
                                if len(sys.argv) > 1 else [])
    main()
