"""On-chip customization ablation (the paper's Table IV) on a trained model,
plus the same loop run as a *serving workload* (an enrollment session on the
StreamServer — docs/CUSTOMIZATION.md), asserted bit-identical.

Uses the cached model from benchmarks (results/kws_model.pkl) if present,
otherwise trains briefly.  Shows each technique's contribution:
full-precision baseline vs naive-quantized vs +error-scaling vs +SGA vs +RGP.

Run:  PYTHONPATH=src python examples/customize_onchip.py
      REPRO_EXAMPLES_SMOKE=1 ... for a seconds-scale smoke run (used by
      tests/test_examples.py)
"""
import os
import pickle

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.onchip_training import (OnChipTrainConfig, head_accuracy,
                                        quantized_head_finetune)
from repro.data import audio
from repro.models import kws as m
from repro.serving import CustomizeConfig, StreamServer
from repro.training import kws as tr

SMOKE = os.environ.get("REPRO_EXAMPLES_SMOKE") == "1"
L = 640 if SMOKE else 2000
HOP = 64 if SMOKE else 256
EPOCHS = 40 if SMOKE else 600
cfg = m.KWSConfig(sample_len=L)
pkl = os.path.join(os.path.dirname(__file__), "..", "results",
                   "kws_model.pkl")
if os.path.exists(pkl) and not SMOKE:
    with open(pkl, "rb") as f:
        params, state = pickle.load(f)
    params = jax.tree_util.tree_map(jnp.asarray, params)
    state = m.KWSState(*[jax.tree_util.tree_map(jnp.asarray, s)
                         for s in state])
else:
    (xtr, ytr), _ = audio.make_gscd_like(
        train_per_class=4 if SMOKE else 24, test_per_class=2, length=L)
    params, state = tr.train_base(
        xtr, ytr, cfg,
        tr.TrainConfig(epochs=2 if SMOKE else 24,
                       batch_size=40 if SMOKE else 80, lr=3e-3),
        verbose=not SMOKE)

# fold ONCE (packed: the fused kernel's operands are precomputed here, not
# per evaluation call) and reuse the same PackedHWParams everywhere below
hw = m.fold_params(params, state, cfg, pack=True)
(xp_tr, yp_tr), (xp_te, yp_te) = audio.make_personal(
    train_per_class=3, test_per_class=2 if SMOKE else 6, length=L,
    accent_shift=0.18)
f_tr = tr.hw_features(hw, xp_tr, cfg)
f_te = tr.hw_features(hw, xp_te, cfg)
print(f"before customization: "
      f"{tr.evaluate_hw(hw, xp_te, yp_te, cfg):.3f}")
for name, kw in {
    "baseline (fp32)": dict(quantized=False),
    "quantized naive": dict(error_scaling=False, sga=False),
    "+ error scaling": dict(error_scaling=True, sga=False),
    "+ SGA": dict(error_scaling=True, sga=True),
    "+ RGP": dict(error_scaling=True, sga=True, rgp=True),
}.items():
    ocfg = OnChipTrainConfig(epochs=EPOCHS, **kw)
    w, b = quantized_head_finetune(jnp.asarray(f_tr), jnp.asarray(yp_tr),
                                   hw.hw.fc_w, hw.hw.fc_b, ocfg)
    acc = float(head_accuracy(jnp.asarray(f_te), jnp.asarray(yp_te), w, b,
                              ocfg))
    print(f"{name:18s}: {acc:.3f}")

# --- the same loop as a serving workload: an enrollment session -------------
# A few personal utterances enroll through a live stream; the fine-tune runs
# as scheduler-ticked background jobs.  With compensation off (no chip
# offsets here) the session must land on EXACTLY the offline loop's head.
n_enroll = 6 if SMOKE else 10
utts, labs = xp_tr[:n_enroll], yp_tr[:n_enroll]
tcfg = OnChipTrainConfig(epochs=EPOCHS, error_scaling=True, sga=True)
srv = StreamServer(hw, cfg, hop=HOP, slots=4, use_kernel=True)
sess = srv.customize("mic0", CustomizeConfig(train=tcfg, compensate=False,
                                             epochs_per_tick=32))
for wav, lab in zip(utts, labs):
    sess.enroll(int(lab), wav)
sess.finish_enrollment()
steps = 0
while not sess.done:
    srv.step()
    steps += 1
    assert steps < 2000, f"session stuck in phase {sess.phase}"
f_sub = tr.hw_features(hw, utts, cfg)
w_ref, b_ref = quantized_head_finetune(jnp.asarray(f_sub), jnp.asarray(labs),
                                       hw.hw.fc_w, hw.hw.fc_b, tcfg)
assert np.array_equal(sess.result.fc_w, np.asarray(w_ref))
assert np.array_equal(sess.result.fc_b, np.asarray(b_ref))
print(f"enrollment session   : {n_enroll} utterances, {steps} scheduler "
      f"ticks, bit-identical to the offline loop; "
      f"{sess.result.energy['uj_per_finetune_step']:.1f} uJ/fine-tune step")
