"""On-chip customization ablation (the paper's Table IV) on a trained model.

Uses the cached model from benchmarks (results/kws_model.pkl) if present,
otherwise trains briefly.  Shows each technique's contribution:
full-precision baseline vs naive-quantized vs +error-scaling vs +SGA vs +RGP.

Run:  PYTHONPATH=src python examples/customize_onchip.py
"""
import os
import pickle

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import imc
from repro.core.onchip_training import (OnChipTrainConfig, head_accuracy,
                                        quantized_head_finetune)
from repro.data import audio
from repro.models import kws as m
from repro.training import kws as tr

L = 2000
cfg = m.KWSConfig(sample_len=L)
pkl = os.path.join(os.path.dirname(__file__), "..", "results",
                   "kws_model.pkl")
if os.path.exists(pkl):
    with open(pkl, "rb") as f:
        params, state = pickle.load(f)
    params = jax.tree_util.tree_map(jnp.asarray, params)
    state = m.KWSState(*[jax.tree_util.tree_map(jnp.asarray, s)
                         for s in state])
else:
    (xtr, ytr), _ = audio.make_gscd_like(train_per_class=24,
                                         test_per_class=4, length=L)
    params, state = tr.train_base(
        xtr, ytr, cfg, tr.TrainConfig(epochs=24, batch_size=80, lr=3e-3))

# fold ONCE (packed: the fused kernel's operands are precomputed here, not
# per evaluation call) and reuse the same PackedHWParams everywhere below
hw = m.fold_params(params, state, cfg, pack=True)
(xp_tr, yp_tr), (xp_te, yp_te) = audio.make_personal(
    train_per_class=3, test_per_class=6, length=L, accent_shift=0.18)
f_tr = tr.hw_features(hw, xp_tr, cfg)
f_te = tr.hw_features(hw, xp_te, cfg)
print(f"before customization: "
      f"{tr.evaluate_hw(hw, xp_te, yp_te, cfg):.3f}")
for name, kw in {
    "baseline (fp32)": dict(quantized=False),
    "quantized naive": dict(error_scaling=False, sga=False),
    "+ error scaling": dict(error_scaling=True, sga=False),
    "+ SGA": dict(error_scaling=True, sga=True),
    "+ RGP": dict(error_scaling=True, sga=True, rgp=True),
}.items():
    ocfg = OnChipTrainConfig(epochs=600, **kw)
    w, b = quantized_head_finetune(jnp.asarray(f_tr), jnp.asarray(yp_tr),
                                   hw.hw.fc_w, hw.hw.fc_b, ocfg)
    acc = float(head_accuracy(jnp.asarray(f_te), jnp.asarray(yp_te), w, b,
                              ocfg))
    print(f"{name:18s}: {acc:.3f}")
