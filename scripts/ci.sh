#!/usr/bin/env bash
# CI gate: the tier-1 quick pass plus the streaming-equivalence and
# gating-equivalence contracts and the docs consistency check.
#
#   scripts/ci.sh            quick: everything but slow/streaming-marked
#                            tests, then the streaming bit-exactness tests
#                            (incl. the VAD-gating equivalence + wake-margin
#                            replay gates), then the docs check
#   scripts/ci.sh --full     the whole suite (tier-1 command verbatim)
#                            plus the docs check
#
# The `streaming` marker (pytest.ini) tags the serving equivalence tests,
# the gating/backpressure/dynamic-hop server tests and the long
# multi-stream soak: the quick pass deselects them wholesale, then re-runs
# the non-slow subset explicitly (the soak stays out — it is also marked
# `slow`).  The gating-equivalence gate is the acceptance contract that a
# VAD forced to "speech" leaves serving bit-identical to ungated
# streaming, SA noise and chip offsets included.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

if [[ "${1:-}" == "--full" ]]; then
    python -m pytest -x -q
    exec python scripts/check_docs.py
fi

python -m pytest -x -q -m "not slow and not streaming"
python -m pytest -x -q -m "streaming and not slow" tests/test_serving.py
# gating-equivalence gate (explicit, so a marker edit can't silently drop it)
python -m pytest -x -q tests/test_serving.py \
    -k "gated_forced_speech_bitexact or wake_margin_replays"
python scripts/check_docs.py
