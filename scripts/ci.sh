#!/usr/bin/env bash
# CI gate: the tier-1 quick pass plus the streaming-equivalence contract.
#
#   scripts/ci.sh            quick: everything but slow/streaming-marked
#                            tests, then the streaming bit-exactness tests
#   scripts/ci.sh --full     the whole suite (tier-1 command verbatim)
#
# The `streaming` marker (pytest.ini) tags the serving equivalence tests
# and the long multi-stream soak: the quick pass deselects them wholesale,
# then re-runs the equivalence subset explicitly (the soak stays out — it
# is also marked `slow`).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

if [[ "${1:-}" == "--full" ]]; then
    exec python -m pytest -x -q
fi

python -m pytest -x -q -m "not slow and not streaming"
python -m pytest -x -q -m "streaming and not slow" tests/test_serving.py
