#!/usr/bin/env bash
# CI gate: the tier-1 quick pass plus the streaming-equivalence,
# gating-equivalence and customization-equivalence contracts and the docs
# consistency check.
#
#   scripts/ci.sh            quick: everything but slow/streaming-marked
#                            tests, then the streaming bit-exactness tests
#                            (incl. the VAD-gating equivalence + wake-margin
#                            replay gates), the customization gates, the
#                            observability gate (telemetry bit-identity +
#                            auditor-in-raise-mode equivalence slice), the
#                            sharding gate (sharded == single-device
#                            bit-identity on 2 host-platform devices +
#                            the --devices 2 bench smoke), then the docs
#                            check
#   scripts/ci.sh --full     the whole suite (tier-1 command verbatim)
#                            plus the docs check
#
# The fault-recovery gate (tests/test_reliability.py) is the acceptance
# contract of the self-healing serving stack: canaries detect and
# localize an injected fault, recompensation heals it back to healthy,
# stuck columns are masked, the one-launch-per-layer invariant holds
# under fault + canary, and snapshot/restore resumes bit-identically.
#
# The `streaming` marker (pytest.ini) tags the serving equivalence tests,
# the gating/backpressure/dynamic-hop server tests and the long
# multi-stream soak: the quick pass deselects them wholesale, then re-runs
# the non-slow subset explicitly (the soak stays out — it is also marked
# `slow`).  The gating-equivalence gate is the acceptance contract that a
# VAD forced to "speech" leaves serving bit-identical to ungated
# streaming, SA noise and chip offsets included.  The
# customization-equivalence gate is the acceptance contract that an
# enrollment session driven through scheduler ticks lands on EXACTLY the
# offline customize loop's result (compensated biases + fine-tuned head)
# and that a mixed inference+learning tick still issues one fused-kernel
# launch per IMC layer.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

if [[ "${1:-}" == "--full" ]]; then
    python -m pytest -x -q
    exec python scripts/check_docs.py
fi

python -m pytest -x -q -m "not slow and not streaming"
python -m pytest -x -q -m "streaming and not slow" tests/test_serving.py
# gating-equivalence gate (explicit, so a marker edit can't silently drop it)
python -m pytest -x -q tests/test_serving.py \
    -k "gated_forced_speech_bitexact or wake_margin_replays"
# customization-equivalence gate (session == offline loop — clean AND
# SA-noise-field configs, the -k prefix matches both; one launch per
# layer on mixed inference+learning ticks; batched replay-wave init ==
# sequential; profiles restored from disk serve bit-identically)
python -m pytest -x -q tests/test_customize.py \
    -k "session_matches_offline_loop or mixed_tick_one_fused_launch \
        or batched_replay_init or profile_store_restart"
# fault-recovery gate (canary detect -> localize -> recompensate back to
# healthy; one fused launch per layer under fault + canary; snapshots
# restore bit-identically) plus the quick soak slice — the long
# randomized soaks stay out (marked slow)
python -m pytest -x -q tests/test_reliability.py \
    -k "canary_detects or drift_fault_heals or one_launch_per_layer \
        or snapshot_restore_bit_identical"
python -m pytest -x -q -m "streaming and not slow" tests/test_reliability.py
# observability gate (docs/OBSERVABILITY.md): registry/recorder/auditor
# unit contracts, telemetry-fully-on == telemetry-off bit-identity (SA
# noise, chip offsets, fault + canary + learning traffic) and the
# snapshot v2 round-trip — then the gating-equivalence slice re-run with
# the launch auditor armed in raise mode through the environment, so a
# doubled fused launch or a gate fill that touches a kernel aborts CI
python -m pytest -x -q tests/test_obs.py
REPRO_OBS_AUDIT=raise python -m pytest -x -q tests/test_serving.py \
    -k "gated_forced_speech_bitexact or wake_margin_replays"
# sharding gate (docs/SHARDING.md): the sharded-equivalence contract —
# a ShardedStreamServer (per-device slot pools behind the placement
# router) is bit-identical per stream to single-device serving, noise /
# chip offsets / faults / gating / snapshot bundles included, and the
# one-launch-per-layer audit holds PER DEVICE — run under a forced
# 2-device host platform so placement exercises real device boundaries,
# then the --devices 2 bench smoke (scaling section machinery end to
# end; the committed artifact's full regen command is in docs/SHARDING.md)
XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=2" \
    python -m pytest -x -q tests/test_sharded_serving.py -m "not slow"
XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=2" \
    python -m pytest -x -q tests/test_obs.py -k "device or sharded"
python -m benchmarks.run --streaming --devices 2 --stream-hops 2 \
    --streaming-out "$(mktemp -d)/BENCH_streaming.json" > /dev/null
# compiled-tick gate (docs/SERVING.md): the whole-tick fast path is
# bit-identical to the interpreted tick — a quick differential slice
# (gated + noise/chip configs, single-tick block routing, the byte-pinned
# golden decision trace), the auditor's compiled-cause rules with
# REPRO_OBS_AUDIT=raise armed through the environment, then the
# --streaming --compiled bench smoke (in-bench event-identity assert +
# raise-mode audit; the committed artifact's full regen command is in
# docs/SERVING.md).  The full differential matrix (faults, dynamic hop,
# autoscale, sharded, soak) runs under `-m compiled` in the full suite.
python -m pytest -x -q tests/test_compiled.py \
    -k "(block_bitident and (gated_clean or noise_and_chip)) \
        or routes_single_tick or golden_decision_trace \
        or auditor_compiled_cause_rules or audit_raise_clean_env"
python -m benchmarks.run --streaming --compiled --compiled-ticks 8 \
    --compiled-block 4 --stream-hops 2 \
    --streaming-out "$(mktemp -d)/BENCH_streaming.json" > /dev/null
python scripts/check_docs.py
