#!/usr/bin/env python3
"""Docs consistency gate: fail CI if README.md or docs/*.md reference
repo files, modules or CLI flags that do not exist.

Checked reference forms (inside backticks only — prose is free):

* path-like tokens whose first segment is a top-level repo directory
  (``src/...``, ``tests/...``) or that end in a known code/data extension
  — must exist on disk (trailing ``:line`` / ``::member`` suffixes are
  stripped);
* dotted module tokens ``repro.foo[.bar...]`` — ``src/repro/foo`` must
  exist as a package or module (deeper components may be attributes, so
  only the first level under ``repro`` is resolved);
* ``--flag`` tokens — the literal flag string must appear in some .py or
  .sh file under the repo (catches renamed/removed CLI options).

Run:  python scripts/check_docs.py
"""

from __future__ import annotations

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TOP_DIRS = {"src", "tests", "scripts", "benchmarks", "examples", "docs",
            "results"}
EXTS = (".py", ".sh", ".md", ".json", ".ini", ".pkl")


def doc_files():
    out = [os.path.join(ROOT, "README.md")]
    docs = os.path.join(ROOT, "docs")
    if os.path.isdir(docs):
        out += [os.path.join(docs, f) for f in sorted(os.listdir(docs))
                if f.endswith(".md")]
    return [p for p in out if os.path.exists(p)]


def repo_sources():
    srcs = []
    for dirpath, dirnames, filenames in os.walk(ROOT):
        dirnames[:] = [d for d in dirnames if not d.startswith(".")]
        for f in filenames:
            if f.endswith((".py", ".sh")):
                srcs.append(os.path.join(dirpath, f))
    return srcs


def extract_tokens(text):
    """(paths, modules, flags) referenced in backtick spans."""
    paths, modules, flags = set(), set(), set()
    for span in re.findall(r"`([^`\n]+)`", text):
        for word in span.split():
            word = word.strip(",;:()[]{}\"'")
            if word.startswith("--") and re.fullmatch(r"--[\w-]+", word):
                flags.add(word)
                continue
            word = word.split("::")[0]
            word = re.sub(r":\d+(-\d+)?$", "", word)
            if re.fullmatch(r"repro(\.[A-Za-z_]\w*)+", word):
                modules.add(word)
            elif "/" in word and not word.startswith(("http:", "https:")):
                first = word.split("/")[0]
                if first in TOP_DIRS or word.endswith(EXTS):
                    paths.add(word.rstrip("/"))
    return paths, modules, flags


def main() -> int:
    missing = []
    flag_corpus = None
    for doc in doc_files():
        rel = os.path.relpath(doc, ROOT)
        with open(doc) as f:
            text = f.read()
        paths, modules, flags = extract_tokens(text)
        for p in sorted(paths):
            if not os.path.exists(os.path.join(ROOT, p)):
                missing.append(f"{rel}: path `{p}` does not exist")
        for mod in sorted(modules):
            parts = mod.split(".")
            base = os.path.join(ROOT, "src", parts[0],
                                *([parts[1]] if len(parts) > 1 else []))
            if not (os.path.isdir(base) or os.path.exists(base + ".py")):
                missing.append(f"{rel}: module `{mod}` not found under src/")
        if flags:
            if flag_corpus is None:
                flag_corpus = "\n".join(
                    open(s, errors="replace").read()
                    for s in repo_sources())
            for fl in sorted(flags):
                if fl not in flag_corpus:
                    missing.append(
                        f"{rel}: flag `{fl}` not found in any .py/.sh")
    if missing:
        print("docs check FAILED:")
        for line in missing:
            print(f"  {line}")
        return 1
    print(f"docs check OK ({len(doc_files())} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
