#!/usr/bin/env python3
"""Docs consistency gate: fail CI if README.md or docs/*.md reference
repo files, modules or CLI flags that do not exist, or carry rotten code
snippets, dead cross-doc links, or stale benchmark-schema references.

Checked reference forms (inside backticks only — prose is free):

* path-like tokens whose first segment is a top-level repo directory
  (``src/...``, ``tests/...``) or that end in a known code/data extension
  — must exist on disk (trailing ``:line`` / ``::member`` / ``#key``
  suffixes are stripped);
* dotted module tokens ``repro.foo[.bar...]`` — ``src/repro/foo`` must
  exist as a package or module (deeper components may be attributes, so
  only the first level under ``repro`` is resolved);
* ``--flag`` tokens — the literal flag string must appear in some .py or
  .sh file under the repo (catches renamed/removed CLI options);
* bench-schema tokens ``results/BENCH_<x>.json#dotted.key.path`` — the
  JSON file must exist AND contain the dotted key path (integer segments
  index into lists), so docs describing a BENCH_*.json schema rot the
  moment a bench stops recording a documented key;
* fenced ```python blocks — each must compile, and its import statements
  are actually executed (with src/ on sys.path), so a renamed module or
  symbol breaks CI instead of silently rotting the snippet.

Plus (anywhere in the markdown, not just backticks):

* relative markdown links ``[text](path)`` — the target, resolved from
  the linking document's directory, must exist (anchors are stripped;
  absolute http(s)/mailto links are skipped) — dead cross-doc links
  between README/docs/* fail CI.

Run:  python scripts/check_docs.py
"""

from __future__ import annotations

import ast
import glob
import json
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TOP_DIRS = {"src", "tests", "scripts", "benchmarks", "examples", "docs",
            "results"}
EXTS = (".py", ".sh", ".md", ".json", ".ini", ".pkl")


def doc_files():
    out = [os.path.join(ROOT, "README.md")]
    docs = os.path.join(ROOT, "docs")
    if os.path.isdir(docs):
        out += [os.path.join(docs, f) for f in sorted(os.listdir(docs))
                if f.endswith(".md")]
    return [p for p in out if os.path.exists(p)]


def repo_sources():
    srcs = []
    for dirpath, dirnames, filenames in os.walk(ROOT):
        dirnames[:] = [d for d in dirnames if not d.startswith(".")]
        for f in filenames:
            if f.endswith((".py", ".sh")):
                srcs.append(os.path.join(dirpath, f))
    return srcs


def extract_tokens(text):
    """(paths, modules, flags, bench_keys) referenced in backtick spans.

    ``bench_keys`` are ``results/BENCH_<x>.json#dotted.key`` schema
    references: (json_path, dotted_key) pairs."""
    paths, modules, flags, bench_keys = set(), set(), set(), set()
    for span in re.findall(r"`([^`\n]+)`", text):
        for word in span.split():
            word = word.strip(",;:()[]{}\"'")
            if word.startswith("--") and re.fullmatch(r"--[\w-]+", word):
                flags.add(word)
                continue
            word = word.split("::")[0]
            word = re.sub(r":\d+(-\d+)?$", "", word)
            m = re.fullmatch(r"(results/BENCH_\w+\.json)#([\w.\-]+)", word)
            if m:
                bench_keys.add((m.group(1), m.group(2)))
            word = word.split("#")[0]      # other anchors: path part only
            if re.fullmatch(r"repro(\.[A-Za-z_]\w*)+", word):
                modules.add(word)
            elif "/" in word and not word.startswith(("http:", "https:")):
                first = word.split("/")[0]
                if first in TOP_DIRS or word.endswith(EXTS):
                    paths.add(word.rstrip("/"))
    return paths, modules, flags, bench_keys


def extract_md_links(text):
    """Relative markdown link targets ``[text](target)`` (anchors
    stripped; external/absolute/anchor-only links skipped)."""
    out = set()
    for target in re.findall(r"\[[^\]\n]*\]\(([^)\s]+)\)", text):
        target = target.split("#")[0]
        if not target or target.startswith(("http:", "https:", "mailto:",
                                            "/")):
            continue
        out.add(target)
    return out


def check_bench_key(json_rel, dotted, problems, rel, cache):
    """Walk a dotted key path through a bench JSON (int segments index
    lists); records a problem if the file or any segment is missing."""
    path = os.path.join(ROOT, json_rel)
    if json_rel not in cache:
        try:
            with open(path) as f:
                cache[json_rel] = json.load(f)
        except (OSError, ValueError) as e:
            cache[json_rel] = e
    node = cache[json_rel]
    if isinstance(node, Exception):
        problems.append(f"{rel}: bench ref `{json_rel}#{dotted}` — "
                        f"cannot load {json_rel}: {cache[json_rel]}")
        return
    walked = []
    for seg in dotted.split("."):
        walked.append(seg)
        if isinstance(node, list) and re.fullmatch(r"\d+", seg):
            idx = int(seg)
            if idx >= len(node):
                problems.append(
                    f"{rel}: bench ref `{json_rel}#{dotted}` — index "
                    f"{'.'.join(walked)} out of range")
                return
            node = node[idx]
        elif isinstance(node, dict) and seg in node:
            node = node[seg]
        else:
            problems.append(
                f"{rel}: bench ref `{json_rel}#{dotted}` — key "
                f"`{'.'.join(walked)}` not in the recorded schema")
            return


def extract_python_fences(text):
    """Bodies of ```python fenced blocks."""
    return re.findall(r"```python\n(.*?)```", text, flags=re.DOTALL)


def check_snippet(rel, idx, code, problems):
    """Compile the snippet and smoke-exec its imports (the cheap subset
    that catches renamed modules/symbols without running demo code)."""
    try:
        tree = ast.parse(code)
    except SyntaxError as e:
        problems.append(f"{rel}: python fence #{idx} does not parse: {e}")
        return
    imports = [node for node in tree.body
               if isinstance(node, (ast.Import, ast.ImportFrom))]
    if not imports:
        return
    src = os.path.join(ROOT, "src")
    if src not in sys.path:
        sys.path.insert(0, src)
    ns = {}
    for node in imports:
        stmt = ast.unparse(node)
        try:
            exec(compile(ast.Module(body=[node], type_ignores=[]),
                         f"<{rel} fence {idx}>", "exec"), ns)
        except Exception as e:
            problems.append(
                f"{rel}: python fence #{idx} import failed: "
                f"`{stmt}` -> {type(e).__name__}: {e}")


def main() -> int:
    missing = []
    flag_corpus = None
    bench_cache = {}
    for doc in doc_files():
        rel = os.path.relpath(doc, ROOT)
        with open(doc) as f:
            text = f.read()
        paths, modules, flags, bench_keys = extract_tokens(text)
        for p in sorted(paths):
            if "*" in p or "?" in p:
                if not glob.glob(os.path.join(ROOT, p)):
                    missing.append(
                        f"{rel}: glob `{p}` matches nothing")
            elif not os.path.exists(os.path.join(ROOT, p)):
                missing.append(f"{rel}: path `{p}` does not exist")
        for target in sorted(extract_md_links(text)):
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(doc), target))
            if not os.path.exists(resolved):
                missing.append(
                    f"{rel}: markdown link `{target}` does not resolve "
                    f"({os.path.relpath(resolved, ROOT)})")
        for json_rel, dotted in sorted(bench_keys):
            check_bench_key(json_rel, dotted, missing, rel, bench_cache)
        for mod in sorted(modules):
            parts = mod.split(".")
            base = os.path.join(ROOT, "src", parts[0],
                                *([parts[1]] if len(parts) > 1 else []))
            if not (os.path.isdir(base) or os.path.exists(base + ".py")):
                missing.append(f"{rel}: module `{mod}` not found under src/")
        if flags:
            if flag_corpus is None:
                flag_corpus = "\n".join(
                    open(s, errors="replace").read()
                    for s in repo_sources())
            for fl in sorted(flags):
                if fl not in flag_corpus:
                    missing.append(
                        f"{rel}: flag `{fl}` not found in any .py/.sh")
        for idx, code in enumerate(extract_python_fences(text)):
            check_snippet(rel, idx, code, missing)
    if missing:
        print("docs check FAILED:")
        for line in missing:
            print(f"  {line}")
        return 1
    print(f"docs check OK ({len(doc_files())} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
