from repro.training import kws

__all__ = ["kws"]
