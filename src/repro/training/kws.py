"""Training / recovery / customization drivers for the KWS model.

Covers the three phases of the paper:
  1. base QAT training on the GSCD-like corpus (§VI-A3, Adam),
  2. non-ideal-effect recovery: bias compensation + noise-aware fine-tuning
     (§IV-B, Table III),
  3. on-chip customization of the classifier head on the personal set
     (§III, Table IV) — delegated to repro.core.onchip_training.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compensation, imc
from repro.core.sa_noise import SANoiseField
from repro.models import kws
from repro.optim import adam, cosine_schedule


@dataclasses.dataclass
class TrainConfig:
    epochs: int = 30
    batch_size: int = 60
    lr: float = 0.01               # paper: Adam, lr 0.01 decayed
    lr_min: float = 1e-6
    seed: int = 0
    log_every: int = 50
    # annealed binarization: (fraction_of_epochs, alpha); None alpha = hard
    # (final hard phase adapts thresholds/head to the exact sign() features)
    # positive = tanh soft, negative = hard-forward surrogate-grad phase
    alpha_schedule: tuple = ((0.4, 2.0), (0.6, 5.0), (0.75, 12.0),
                             (0.9, -5.0), (1.0, -10.0))
    # polarization pull of latent weights toward +/-1 during soft phases
    polarize_weight: float = 1e-3


def _alpha_at(tcfg: "TrainConfig", epoch: int):
    frac = (epoch + 1) / max(1, tcfg.epochs)
    for upto, alpha in tcfg.alpha_schedule:
        if frac <= upto:
            return alpha
    return tcfg.alpha_schedule[-1][1] if tcfg.alpha_schedule else None


def _batches(x: np.ndarray, y: np.ndarray, bs: int, rng: np.random.Generator):
    idx = rng.permutation(len(y))
    for i in range(0, len(y) - bs + 1, bs):
        j = idx[i:i + bs]
        yield x[j], y[j]


def train_base(xtr: np.ndarray, ytr: np.ndarray,
               cfg: kws.KWSConfig = kws.PAPER_KWS,
               tcfg: TrainConfig = TrainConfig(),
               params=None, state=None,
               chip_offsets: Optional[Dict[str, jax.Array]] = None,
               sa_noise_std: float = 0.0,
               verbose: bool = True):
    """QAT training.  With chip_offsets/sa_noise_std set this is the paper's
    noise-aware recovery fine-tuning (start from trained params)."""
    if params is None:
        params = kws.init_params(jax.random.PRNGKey(tcfg.seed), cfg)
    if state is None:
        state = kws.init_state(cfg)

    steps_per_epoch = max(1, len(ytr) // tcfg.batch_size)
    opt = adam(cosine_schedule(tcfg.lr, tcfg.epochs * steps_per_epoch,
                               warmup_steps=steps_per_epoch // 2,
                               min_lr=tcfg.lr_min))
    opt_state = opt.init(params)

    def clamp_latents(p):
        """BNN practice: keep latent weights inside the quantizer range so
        STE gradients stay alive (clip-STE blocks out-of-range grads)."""
        p = dict(p)
        for i in range(1, cfg.num_conv_layers):
            name = f"conv{i}"
            g = p[name]["gamma"]
            g = jnp.where(jnp.abs(g) < 0.05,
                          jnp.where(g >= 0, 0.05, -0.05), g)
            p[name] = {**p[name], "w": jnp.clip(p[name]["w"], -1.0, 1.0),
                       "gamma": g}
        from repro.core.quantize import WEIGHT_Q
        p["fc"] = {"w": jnp.clip(p["fc"]["w"], -WEIGHT_Q.max_value,
                                 WEIGHT_Q.max_value),
                   "b": jnp.clip(p["fc"]["b"], -WEIGHT_Q.max_value,
                                 WEIGHT_Q.max_value)}
        return p

    @functools.partial(jax.jit, static_argnames=("soft_alpha",))
    def step(params, opt_state, state, x, y, rng, soft_alpha):
        def loss_fn(p):
            logits, new_state = kws.forward_train(
                p, state, x, cfg, chip_offsets=chip_offsets,
                sa_noise_std=sa_noise_std, rng=rng, soft_alpha=soft_alpha)
            loss = kws.cross_entropy(logits, y)
            if soft_alpha is not None and tcfg.polarize_weight:
                # pull latent conv weights toward +/-1 so the final hard
                # binarization is a small perturbation
                pol = sum(jnp.mean((1.0 - p[f"conv{i}"]["w"] ** 2) ** 2)
                          for i in range(1, cfg.num_conv_layers))
                loss = loss + tcfg.polarize_weight * pol
            return loss, (logits, new_state)
        (loss, (logits, new_state)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        params = clamp_latents(params)
        return params, opt_state, new_state, loss, kws.accuracy(logits, y)

    rng = np.random.default_rng(tcfg.seed)
    key = jax.random.PRNGKey(tcfg.seed + 1)
    t0 = time.time()
    it = 0
    for epoch in range(tcfg.epochs):
        alpha = _alpha_at(tcfg, epoch)
        for xb, yb in _batches(xtr, ytr, tcfg.batch_size, rng):
            key, sub = jax.random.split(key)
            params, opt_state, state, loss, acc = step(
                params, opt_state, state, jnp.asarray(xb), jnp.asarray(yb),
                sub, alpha)
            it += 1
            if verbose and it % tcfg.log_every == 0:
                print(f"  epoch {epoch} it {it} a={alpha} "
                      f"loss {float(loss):.4f} acc {float(acc):.3f} "
                      f"({time.time() - t0:.0f}s)", flush=True)
    return params, state


def evaluate(params, state, x: np.ndarray, y: np.ndarray,
             cfg: kws.KWSConfig = kws.PAPER_KWS, batch: int = 200) -> float:
    fwd = jax.jit(lambda xb: kws.forward_eval(params, state, xb, cfg)[0])
    correct = 0
    for i in range(0, len(y), batch):
        logits = fwd(jnp.asarray(x[i:i + batch]))
        correct += int(jnp.sum(jnp.argmax(logits, -1)
                               == jnp.asarray(y[i:i + batch])))
    return correct / len(y)


def _hw_batched(hw, x, cfg, out_index: int, *, chip_offsets, sa_noise_std,
                seed, batch, use_kernel, sa_noise_field):
    """Shared chunked hardware forward of evaluate_hw / hw_features.

    SA noise comes either from fresh per-chunk rng draws
    (``sa_noise_std``/``seed`` — the fleet-statistics mode) or from an
    explicit ``SANoiseField`` whose row n is example n's (stream key,
    window index) — the offline-oracle mode that reproduces a live
    stream's (or an enrollment session's) noise realizations bit-exactly.
    The field's rows ride along with their batch slice."""
    if sa_noise_field is not None:
        if sa_noise_std > 0.0:
            raise ValueError("pass either sa_noise_std or sa_noise_field, "
                             "not both")
        if sa_noise_field.keys.shape[0] != len(x):
            raise ValueError(
                f"sa_noise_field has {sa_noise_field.keys.shape[0]} rows "
                f"for {len(x)} examples")
        std, hop = float(sa_noise_field.std), int(sa_noise_field.hop)
        fwd = jax.jit(lambda xb, ks, hs: kws.hw_forward(
            hw, xb, cfg, chip_offsets=chip_offsets,
            sa_noise_field=SANoiseField(keys=ks, hops=hs, std=std, hop=hop),
            use_kernel=use_kernel)[out_index])
        outs = []
        for i in range(0, len(x), batch):
            outs.append(np.asarray(fwd(
                jnp.asarray(x[i:i + batch]),
                sa_noise_field.keys[i:i + batch],
                sa_noise_field.hops[i:i + batch])))
        return np.concatenate(outs, axis=0)
    fwd = jax.jit(lambda xb, k: kws.hw_forward(
        hw, xb, cfg, chip_offsets=chip_offsets, sa_noise_std=sa_noise_std,
        rng=k, use_kernel=use_kernel)[out_index])
    outs, key = [], jax.random.PRNGKey(seed)
    for i in range(0, len(x), batch):
        key, sub = jax.random.split(key)
        outs.append(np.asarray(fwd(jnp.asarray(x[i:i + batch]), sub)))
    return np.concatenate(outs, axis=0)


def evaluate_hw(hw, x: np.ndarray, y: np.ndarray,
                cfg: kws.KWSConfig = kws.PAPER_KWS,
                chip_offsets=None, sa_noise_std: float = 0.0,
                seed: int = 0, batch: int = 200,
                use_kernel: bool = False,
                sa_noise_field: Optional[SANoiseField] = None) -> float:
    """Hardware-path accuracy; ``hw`` is HWParams or PackedHWParams.
    ``sa_noise_field`` evaluates the per-absolute-column SA-noise field
    instead of fresh draws (see ``hw_features``)."""
    logits = _hw_batched(hw, x, cfg, 0, chip_offsets=chip_offsets,
                         sa_noise_std=sa_noise_std, seed=seed, batch=batch,
                         use_kernel=use_kernel,
                         sa_noise_field=sa_noise_field)
    return float(np.mean(np.argmax(logits, -1) == np.asarray(y)))


def hw_features(hw, x: np.ndarray,
                cfg: kws.KWSConfig = kws.PAPER_KWS,
                chip_offsets=None, sa_noise_std: float = 0.0,
                seed: int = 0, batch: int = 200,
                use_kernel: bool = False,
                sa_noise_field: Optional[SANoiseField] = None) -> np.ndarray:
    """GAP features through the hardware path — the customization feature
    buffer (§V-C stores these in SRAM for reuse across epochs).

    With ``sa_noise_field`` (repro.core.sa_noise.SANoiseField) the forward
    evaluates each example's per-absolute-column SA-noise field at its
    recorded (stream key, window index) instead of drawing fresh noise —
    the offline oracle of an enrollment session's feature captures
    (``CustomizationSession.feature_noise_field()``), bit-identical to
    what the streaming path computed."""
    return _hw_batched(hw, x, cfg, 1, chip_offsets=chip_offsets,
                       sa_noise_std=sa_noise_std, seed=seed, batch=batch,
                       use_kernel=use_kernel, sa_noise_field=sa_noise_field)


def calibration_ideal_counts(hw, xcal: np.ndarray,
                             cfg: kws.KWSConfig = kws.PAPER_KWS
                             ) -> Dict[str, jax.Array]:
    """The test-mode reference measurement: per-layer ideal (noise-free,
    offset-free) pre-SA counts of the calibration patterns.  First step of
    the resumable calibration (one forward; the per-layer compensation
    steps in ``compensate_layer_bias`` then consume it one layer at a
    time — a scheduler tick can run a bounded number of layers)."""
    hwp, _ = kws.as_hw_params(hw)
    xc = jnp.asarray(xcal)

    @jax.jit
    def ideal_counts():
        _, _, log = kws.hw_forward(hwp, xc, cfg, chip_offsets=None,
                                   sa_noise_std=0.0, collect_counts=True)
        return log

    return ideal_counts()


def compensate_layer_bias(bias_int: jax.Array, ideal_counts: jax.Array,
                          chip_offset: jax.Array, key: jax.Array,
                          sa_noise_std: float = 1.0,
                          macro: imc.IMCMacroConfig = imc.DEFAULT_MACRO,
                          return_est: bool = False):
    """One layer of test-mode compensation: measure (ideal + static chip
    offset + fresh SA read noise), estimate the per-channel discrepancy and
    fold it into the in-memory BN bias.  ``key`` must be the layer's slot
    of the PRNG split chain (see ``calibrate_and_compensate``) for the
    step-wise run to reproduce the monolithic one bit-exactly.
    ``return_est=True`` additionally returns the raw per-channel offset
    estimate — the caller can compare what the write was asked to cancel
    against what the clipped/parity bias grid could realize (the serving
    health monitor masks rail channels this way)."""
    measured = (ideal_counts + chip_offset
                + sa_noise_std * jax.random.normal(key, ideal_counts.shape))
    est = compensation.estimate_channel_offsets(ideal_counts, measured)
    new_bias = compensation.compensate_bias(bias_int, est, macro)
    if return_est:
        return new_bias, est
    return new_bias


def calibration_layer_keys(cfg: kws.KWSConfig, seed: int = 0
                           ) -> Dict[str, jax.Array]:
    """The per-layer measurement keys of the calibration split chain —
    shared by the monolithic driver and the tick-resumable serving path so
    both take identical SA-noise reads."""
    key = jax.random.PRNGKey(seed)
    out = {}
    for name in cfg.imc_layer_names():
        key, sub = jax.random.split(key)
        out[name] = sub
    return out


def calibrate_and_compensate(hw, xcal: np.ndarray,
                             chip_offsets: Dict[str, jax.Array],
                             cfg: kws.KWSConfig = kws.PAPER_KWS,
                             macro: imc.IMCMacroConfig = imc.DEFAULT_MACRO,
                             sa_noise_std: float = 1.0,
                             seed: int = 0,
                             sa_noise_field: Optional[SANoiseField] = None):
    """Paper §IV-B: estimate per-channel MAV offsets via the chip's TEST
    MODE (Fig 8) and fold the compensation into the in-memory BN biases.

    The test mode drives each macro with KNOWN input patterns and reads its
    (pre-SA) MAV result, so the measurement is layer-LOCAL with matched
    inputs — NOT a chained noisy forward (chaining corrupts deeper layers'
    inputs and the per-channel estimate degenerates: est err ~6 counts for
    offset std 8 in our ablation).  We simulate exactly that measurement:
    ideal counts + the chip's static offset + fresh SA noise per read,
    averaged over the calibration patterns.

    Driver over the resumable pieces (``calibration_ideal_counts`` +
    ``compensate_layer_bias`` with ``calibration_layer_keys``) — the
    serving enrollment sessions run the same pieces one-layer-per-tick and
    land on the same biases.  Accepts HWParams or PackedHWParams and
    returns the same kind (the compensated biases are re-packed —
    reprogramming the bias word lines).

    ``sa_noise_field`` lets the offline customization oracle thread one
    per-absolute-column noise-field spec through the whole pipeline
    (calibrate -> ``hw_features(sa_noise_field=...)`` -> fine-tune).  It
    does NOT perturb the calibration itself: the test mode digitizes the
    macros' *pre-SA* counts, so the inference-time SA field cannot reach
    the measurement — only the fresh per-read measurement noise
    (``sa_noise_std``/``seed``, identical in the session path) does.  The
    field's batch is validated against ``xcal`` so a mismatched oracle
    spec fails here instead of at the feature re-extraction."""
    if sa_noise_field is not None \
            and sa_noise_field.keys.shape[0] != len(xcal):
        raise ValueError(
            f"sa_noise_field has {sa_noise_field.keys.shape[0]} rows for "
            f"{len(xcal)} calibration utterances")
    hw, was_packed = kws.as_hw_params(hw)
    ideal_log = calibration_ideal_counts(hw, xcal, cfg)
    keys = calibration_layer_keys(cfg, seed)
    new_bias = dict(hw.bias)
    for name in cfg.imc_layer_names():
        new_bias[name] = compensate_layer_bias(
            hw.bias[name], ideal_log[name], chip_offsets[name], keys[name],
            sa_noise_std, macro)
    out = hw._replace(bias=new_bias)
    return kws.pack_hw_params(out, cfg) if was_packed is not None else out
