"""Mixture-of-Experts FFN (qwen3-moe / qwen2-moe style).

GShard-style capacity-based top-k routing with dense dispatch/combine
scatter-gathers: compile-friendly under pjit, and the (E, C, D) expert buffer
shards over the `model` mesh axis (expert parallelism) so dispatch/combine
lower to all-to-all on the production mesh.

qwen2-moe additionally has *shared* experts (always-on dense FFN branch) and
a sigmoid-weighted shared-expert gate; both are supported via config.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import (NO_SHARDING, ShardingPolicy, dense,
                                 dense_init)
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff_expert: int
    num_experts: int
    top_k: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0                # total shared intermediate size
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001


def moe_init(key, cfg: MoEConfig) -> Dict:
    ks = jax.random.split(key, 6)
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff_expert
    s = d ** -0.5
    p = {
        "router": dense_init(ks[0], d, e),
        # stacked expert weights: (E, D, F) / (E, F, D)
        "w_gate": jax.random.normal(ks[1], (e, d, f), jnp.float32) * s,
        "w_up": jax.random.normal(ks[2], (e, d, f), jnp.float32) * s,
        "w_down": jax.random.normal(ks[3], (e, f, d), jnp.float32)
        * (f ** -0.5),
    }
    if cfg.num_shared_experts > 0:
        fs = cfg.d_ff_shared or cfg.num_shared_experts * f
        p["shared"] = {
            "w_gate": dense_init(ks[4], d, fs),
            "w_up": dense_init(ks[4], d, fs),
            "w_down": dense_init(ks[5], fs, d),
        }
        p["shared_gate"] = dense_init(ks[5], d, 1)
    return p


def _expert_spec(policy: ShardingPolicy):
    if not policy.enabled:
        return None
    return policy.model_axis


def moe_apply(p: Dict, cfg: MoEConfig, x: jax.Array,
              policy: ShardingPolicy = NO_SHARDING
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss).

    GShard-style GROUPED dispatch: each batch row is a routing group, so the
    position-in-expert cumsum runs along the per-group axis (shardable over
    `data`) instead of the global token axis (an unshardable global scan
    that forced XLA to materialize multi-GB replicated dispatch state).
    The group->expert reshard of the (B, E, C, D) buffer lowers to the
    canonical MoE all-to-all on the production mesh.
    """
    b, s, d = x.shape
    cd = x.dtype
    e, k = cfg.num_experts, cfg.top_k

    router_logits = dense(p["router"], x).astype(jnp.float32)    # (B,S,E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)               # (B,S,k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch style)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(expert_idx[..., 0], e), axis=(0, 1))
    aux = e * jnp.sum(me * ce) * cfg.router_aux_weight

    # ---- per-group position in expert ----
    cap = int(cfg.capacity_factor * s * k / e) + 1
    fe = expert_idx.reshape(b, s * k)                             # (B, Sk)
    fg = gate_vals.reshape(b, s * k).astype(cd)
    onehot = jax.nn.one_hot(fe, e, dtype=jnp.int32)               # (B,Sk,E)
    pos = jnp.cumsum(onehot, axis=1) - 1
    pos = jnp.sum(pos * onehot, axis=-1)                          # (B, Sk)
    keep = pos < cap
    pos_c = jnp.where(keep, pos, cap - 1)
    tok = jnp.repeat(jnp.arange(s), k)[None, :]                   # (1, Sk)
    bidx = jnp.arange(b)[:, None]

    # ---- dispatch: (B, E, C, D), group-sharded scatter ----
    contrib = jnp.where(keep[..., None],
                        x[bidx, jnp.broadcast_to(tok, (b, s * k))], 0)
    buf = jnp.zeros((b, e, cap, d), cd)
    buf = buf.at[bidx, fe, pos_c].add(contrib)
    gspec = P(policy.data_axes, None, None, None)
    ep_ax = (policy.ep_axis if policy.enabled else None)
    if ep_ax == "data":
        ep_ax = policy.fsdp_axis
    elif ep_ax == "model":
        ep_ax = policy.model_axis
    experts_divide = (policy.enabled and ep_ax is not None
                      and e % policy.size(ep_ax) == 0)
    if policy.enabled:
        buf = jax.lax.with_sharding_constraint(buf, gspec)
        if experts_divide:
            # group -> expert reshard: THE MoE all-to-all
            espec = P(None, ep_ax, None, None)
            buf = jax.lax.with_sharding_constraint(buf, espec)
        else:
            # e.g. qwen2-moe's 60 experts on a 16-way axis: keep groups
            # data-sharded and run experts group-locally (weights gathered)
            espec = gspec

    # ---- expert computation: (B,E,C,D) x (E,D,F) ----
    h_gate = jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(cd))
    h_up = jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(cd))
    h = jax.nn.silu(h_gate) * h_up
    if policy.enabled:
        model_free = (not experts_divide) or (policy.ep_axis == "data")
        fm = (policy.model_axis
              if (model_free and cfg.d_ff_expert
                  % policy.size(policy.model_axis) == 0) else None)
        h = jax.lax.with_sharding_constraint(
            h, P(espec[0], espec[1], None, fm))
    out_buf = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(cd))
    if policy.enabled:
        out_buf = jax.lax.with_sharding_constraint(out_buf, espec)
        if experts_divide:
            # expert -> group reshard (all-to-all back)
            out_buf = jax.lax.with_sharding_constraint(out_buf, gspec)

    # ---- combine ----
    gathered = out_buf[bidx, fe, pos_c]                           # (B,Sk,D)
    weighted = jnp.where(keep[..., None], gathered, 0) * fg[..., None]
    out = jnp.zeros((b, s, d), cd)
    out = out.at[bidx, jnp.broadcast_to(tok, (b, s * k))].add(weighted)
    out = policy.btd(out)

    # ---- shared experts (qwen2-moe) ----
    if "shared" in p:
        sh = p["shared"]
        hs = jax.nn.silu(dense(sh["w_gate"], x)) * dense(sh["w_up"], x)
        hs = policy.btf(hs)
        shared_out = dense(sh["w_down"], hs)
        sg = jax.nn.sigmoid(dense(p["shared_gate"], x).astype(jnp.float32))
        out = out + shared_out * sg.astype(cd)

    return out, aux
