from repro.models import kws

__all__ = ["kws"]
