"""Shared layer library for the assigned LM-family architectures.

Pure-pytree JAX (no flax): every layer is (init_fn, apply_fn) over explicit
parameter dicts.  All matmuls run in bf16 with fp32 params (cast at use),
reductions in fp32.  Sharding constraints are injected through a
ShardingPolicy so the same code serves single-device smoke tests and the
512-chip production mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

COMPUTE_DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# Sharding policy
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Maps logical activation/parameter axes onto mesh axes.

    data_axes: mesh axes carrying the batch (e.g. ("pod", "data")).
    model_axis: mesh axis for tensor/expert parallelism.
    fsdp_axis: mesh axis over which parameters/optimizer state are sharded
      (ZeRO-3); None disables FSDP.
    enabled=False turns every constraint into a no-op (single-device tests).
    """

    data_axes: Tuple[str, ...] = ()
    model_axis: Optional[str] = None
    fsdp_axis: Optional[str] = None
    enabled: bool = False
    # sizes for divisibility checks (filled from the mesh)
    axis_sizes: Optional[Dict[str, int]] = None
    # §Perf knobs (see EXPERIMENTS.md):
    # MoE expert-parallel axis: "model" (baseline) or "data" (experts
    # stationary over data, TP over model — kills per-step expert gathers)
    ep_axis: str = "model"
    # serving: masked (elementwise) KV-cache writes instead of
    # dynamic-update-slice — DUS at a runtime index across a seq-sharded
    # cache trips XLA's replicate-then-repartition fallback
    serve_mode: bool = False

    def size(self, axis) -> int:
        if not self.axis_sizes:
            return 1
        if isinstance(axis, tuple):
            n = 1
            for a in axis:
                n *= self.axis_sizes.get(a, 1)
            return n
        return self.axis_sizes.get(axis, 1)

    def _maybe(self, x: jax.Array, spec: P) -> jax.Array:
        if not self.enabled:
            return x
        return jax.lax.with_sharding_constraint(x, spec)

    # logical constraint helpers ------------------------------------------
    def btd(self, x):            # (batch, seq, d_model)
        return self._maybe(x, P(self.data_axes or None, None, None))

    def btf(self, x):            # (batch, seq, ff/hidden) — TP-sharded cols
        return self._maybe(x, P(self.data_axes or None, None,
                                self.model_axis))

    def bthd(self, x):           # (batch, seq, heads, head_dim)
        h = x.shape[2]
        tp = self.size(self.model_axis)
        head_ax = self.model_axis if (tp > 1 and h % tp == 0) else None
        return self._maybe(x, P(self.data_axes or None, None, head_ax, None))

    def btv(self, x):            # (batch, seq, vocab) — logits
        return self._maybe(x, P(self.data_axes or None, None,
                                self.model_axis))

    def bt_seq_sharded(self, x):  # sequence parallelism for long KV caches
        return self._maybe(x, P(None, self.data_axes or None, None, None))


NO_SHARDING = ShardingPolicy()


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int) -> Dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: Dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(x.dtype)


def layernorm_init(d: int) -> Dict:
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p: Dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 1e4) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 1e4) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense projections
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, bias: bool = False,
               scale: Optional[float] = None) -> Dict:
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale)}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def dense(p: Dict, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# Attention (MHA / GQA, optional QK-norm & bias), with KV-cache support
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False            # qwen3 style
    rope_theta: float = 1e4
    causal: bool = True


def attn_init(key, cfg: AttnConfig) -> Dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, cfg.d_model, cfg.n_heads * cfg.head_dim,
                         cfg.qkv_bias),
        "wk": dense_init(k2, cfg.d_model, cfg.n_kv_heads * cfg.head_dim,
                         cfg.qkv_bias),
        "wv": dense_init(k3, cfg.d_model, cfg.n_kv_heads * cfg.head_dim,
                         cfg.qkv_bias),
        "wo": dense_init(k4, cfg.n_heads * cfg.head_dim, cfg.d_model),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(cfg.head_dim)
        p["k_norm"] = rmsnorm_init(cfg.head_dim)
    return p


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.repeat(k, n_rep, axis=2)


def attention(p: Dict, cfg: AttnConfig, x: jax.Array,
              policy: ShardingPolicy = NO_SHARDING,
              positions: Optional[jax.Array] = None,
              cache: Optional[Dict] = None,
              cache_index: Optional[jax.Array] = None,
              kv_override: Optional[jax.Array] = None
              ) -> Tuple[jax.Array, Optional[Dict]]:
    """Self- (or cross-, via kv_override) attention.

    cache: {"k","v"} of (B, S_max, Hkv, hd) for incremental decoding; the new
    kv is written at cache_index.  Returns (out, new_cache).
    """
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :] + (0 if cache_index is None
                                              else cache_index)
    q = dense(p["wq"], x).reshape(b, s, cfg.n_heads, cfg.head_dim)
    kv_src = x if kv_override is None else kv_override
    sk = kv_src.shape[1]
    k = dense(p["wk"], kv_src).reshape(b, sk, cfg.n_kv_heads, cfg.head_dim)
    v = dense(p["wv"], kv_src).reshape(b, sk, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    if kv_override is None:                     # RoPE only for self-attn
        q = apply_rope(q, positions, cfg.rope_theta)
        kv_pos = (jnp.arange(sk)[None, :] if cache_index is None
                  else jnp.arange(sk)[None, :] * 0 + positions)
        k = apply_rope(k, kv_pos if cache_index is not None
                       else jnp.arange(sk)[None, :], cfg.rope_theta)
    if cache is None:
        # decode (cache present): q is a single position — head-sharding it
        # would force the whole KV cache to reshard from its seq layout to a
        # head layout (an SPMD replicate-fallback); let q follow the cache.
        q = policy.bthd(q)

    new_cache = None
    if cache is not None:
        # decode: write new kv at cache_index, attend over the whole cache
        if policy.serve_mode and s == 1:
            # elementwise masked write: shardable across any seq sharding
            s_iota = jnp.arange(cache["k"].shape[1])[None, :, None, None]
            hit = (s_iota == cache_index)
            ck = jnp.where(hit, k.astype(cache["k"].dtype), cache["k"])
            cv = jnp.where(hit, v.astype(cache["v"].dtype), cache["v"])
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), cache_index, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), cache_index, axis=1)
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv
        sk = k.shape[1]

    n_rep = cfg.n_heads // cfg.n_kv_heads
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)

    scale = cfg.head_dim ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale  # (B,H,S,Sk)
    logits = logits.astype(jnp.float32)
    if cfg.causal and cache is None and kv_override is None and s == sk:
        mask = jnp.tril(jnp.ones((s, sk), bool))
        logits = jnp.where(mask[None, None], logits, -1e30)
    elif cache is not None:
        # decode: mask future cache slots
        valid = jnp.arange(sk)[None, None, None, :] <= (
            cache_index + jnp.arange(s)[None, None, :, None])
        logits = jnp.where(valid, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v)
    out = out.reshape(b, s, cfg.n_heads * cfg.head_dim)
    return dense(p["wo"], out), new_cache


# ---------------------------------------------------------------------------
# MLP: SwiGLU (llama-family) or GeLU (starcoder2-family)
# ---------------------------------------------------------------------------


def mlp_init(key, d_model: int, d_ff: int, gated: bool = True,
             bias: bool = False) -> Dict:
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d_model, d_ff, bias),
         "w_down": dense_init(ks[1], d_ff, d_model, bias)}
    if gated:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, bias)
    return p


def mlp(p: Dict, x: jax.Array, policy: ShardingPolicy = NO_SHARDING,
        gated: bool = True) -> jax.Array:
    up = dense(p["w_up"], x)
    if gated:
        h = jax.nn.silu(dense(p["w_gate"], x)) * up
    else:
        h = jax.nn.gelu(up)
    h = policy.btf(h)
    return dense(p["w_down"], h)


# ---------------------------------------------------------------------------
# Chunked gated linear attention core
# ---------------------------------------------------------------------------
# Both mLSTM (xLSTM) and SSD (Mamba2) are linear recurrences
#     S_t = a_t * S_{t-1} + b_t * k_t v_t^T ,   y_t = q_t . S_t
# with per-(head, step) scalar decay a_t and input gate b_t.  This single
# chunkwise-parallel kernel-shaped implementation serves both, giving
# MXU-friendly matmuls instead of a length-T sequential scan.


def gated_linear_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                           log_a: jax.Array, b: jax.Array,
                           chunk: int = 128,
                           initial_state: Optional[jax.Array] = None,
                           return_state: bool = False,
                           policy: "ShardingPolicy" = None):
    """q,k: (B,T,H,Dk); v: (B,T,H,Dv); log_a,b: (B,T,H) scalar gates.

    Returns y: (B,T,H,Dv) (+ final state (B,H,Dk,Dv) if return_state).
    T must be a multiple of ``chunk`` (pad upstream).
    """
    B, T, H, Dk = q.shape
    Dv = v.shape[-1]
    assert T % chunk == 0, (T, chunk)
    n = T // chunk
    rs = lambda x: x.reshape(B, n, chunk, *x.shape[2:]).swapaxes(0, 1)
    qc, kc, vc = rs(q), rs(k), rs(v)            # (n, B, c, H, D)
    lac, bc = rs(log_a), rs(b)                  # (n, B, c, H)

    # cumulative log-decay within the chunk, inclusive of step t
    cum = jnp.cumsum(lac, axis=2)               # (n,B,c,H)
    total = cum[:, :, -1:, :]                   # (n,B,1,H)

    if initial_state is None:
        s0 = jnp.zeros((B, H, Dk, Dv), jnp.float32)
    else:
        s0 = initial_state.astype(jnp.float32)

    def body(state, xs):
        qi, ki, vi, cumi, toti, bi = xs
        # inter-chunk: y_inter[t] = a(<=t) * q_t . S_prev
        decay_t = jnp.exp(cumi)                               # (B,c,H)
        y_inter = jnp.einsum("bchd,bhdv->bchv",
                             (qi * decay_t[..., None]).astype(jnp.float32),
                             state)
        # intra-chunk: y_intra[t] = sum_{j<=t} (a(j+1..t) b_j) (q_t.k_j) v_j
        rel = cumi[:, :, None, :] - cumi[:, None, :, :]        # (B,c,c,H) t,j
        mask = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])
        # clamp BEFORE exp: future positions have rel > 0 (potentially huge);
        # where(mask, exp(rel), 0) still differentiates exp there -> inf*0
        # = NaN in the backward pass
        rel = jnp.where(mask[None, :, :, None], rel, -1e30)
        gate = jnp.exp(rel)
        att = jnp.einsum("bchd,bjhd->bcjh", qi.astype(jnp.float32),
                         ki.astype(jnp.float32))
        att = att * gate * bi[:, None, :, :]                   # b_j
        y_intra = jnp.einsum("bcjh,bjhv->bchv", att,
                             vi.astype(jnp.float32))
        # state update: S = a(chunk) S + sum_j a(j+1..end) b_j k_j v_j^T
        tail = jnp.exp(toti - cumi) * bi                       # (B,c,H)
        kv = jnp.einsum("bchd,bchv->bhdv",
                        (ki * tail[..., None]).astype(jnp.float32),
                        vi.astype(jnp.float32))
        new_state = jnp.exp(toti[:, 0, :])[..., None, None] * state + kv
        # emit the chunk in compute dtype, head-sharded: the stacked scan
        # output is (n,B,c,H,Dv) — fp32 unsharded it dominated peak memory
        # (44GB/device on zamba2 prefill_32k)
        y_out = (y_inter + y_intra).astype(v.dtype)
        if policy is not None:
            y_out = policy.bthd(y_out)
        return new_state, y_out

    state, ys = jax.lax.scan(body, s0, (qc, kc, vc, cum, total, bc))
    y = ys.swapaxes(0, 1).reshape(B, T, H, Dv).astype(v.dtype)
    if return_state:
        return y, state
    return y


def gla_step(q, k, v, log_a, b, state):
    """Single decode step of the same recurrence.
    q,k: (B,H,Dk); v: (B,H,Dv); log_a,b: (B,H); state: (B,H,Dk,Dv)."""
    a = jnp.exp(log_a.astype(jnp.float32))[..., None, None]
    kv = jnp.einsum("bhd,bhv->bhdv", k.astype(jnp.float32),
                    v.astype(jnp.float32)) * b[..., None, None]
    new_state = a * state + kv
    y = jnp.einsum("bhd,bhdv->bhv", q.astype(jnp.float32), new_state)
    return y.astype(v.dtype), new_state
