"""The paper's IMC-aware KWS binary neural network (paper §II, Fig 1).

Topology (reconstruction notes in DESIGN.md §4):

  L1  binarized sinc conv  1 -> 24ch, k=15, stride 4          (digital)
  L2  binary group conv   24 -> 96,  k=3, cpg=24, pool 2      (IMC)
  L3  binary group conv   96 -> 192, k=3, cpg=24, pool 2      (IMC)
  L4  binary group conv  192 -> 288, k=3, cpg=24              (IMC)
  L5  binary group conv  288 -> 384, k=3, cpg=24, pool 2      (IMC, 2 macros)
  L6  binary group conv  384 -> 576, k=3, cpg=24, pool 2      (IMC, 2 macros)
  GAP -> FC 576 -> 10                                          (digital, 8-bit)

Every conv layer carries in-memory BN (folded to an integer word-line bias at
inference) and a ReActNet learnable pre-binarization offset (Fig 2, merged
into the bias at fold time).  Three forwards are provided:

  * ``forward_train``: float QAT path (STE binarization, live BN), with
    optional injected IMC noise for noise-aware fine-tuning (§IV-B);
  * ``forward_eval``:  float path with frozen (running) BN stats;
  * ``hw_forward``:    the bit/count-exact hardware path over folded params,
    with BN parity/range constraints, MAV offset + SA variation — the model
    of the silicon.  With use_kernel=True each IMC layer runs as one fused
    grouped Pallas ``imc_fused`` kernel (conv + epilogue + shuffle + pool,
    no HBM round trip — see repro.kernels.imc_mav).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import imc
from repro.core.binary import (binarize, binarize_sg, channel_shuffle,
                               or_maxpool, rsign)
from repro.core.quantize import ACT_Q, WEIGHT_Q
from repro.core.sa_noise import SANoiseField, field_window_noise

# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KWSConfig:
    channels: Tuple[int, ...] = (24, 96, 192, 288, 384, 576)
    kernels: Tuple[int, ...] = (15, 3, 3, 3, 3, 3)
    strides: Tuple[int, ...] = (4, 1, 1, 1, 1, 1)
    pools: Tuple[int, ...] = (1, 2, 2, 1, 2, 2)
    channels_per_group: int = 24
    num_classes: int = 10
    sample_len: int = 16_000
    sample_rate: int = 16_000
    bias_mapping: str = "best"          # paper §IV-A: pick best of 4
    bn_momentum: float = 0.9
    # 'batch': standard BN statistics; 'fixed': pure learned threshold
    # (gamma*counts/sqrt(fan_in)+beta) — the in-memory-BN hardware semantics,
    # and it preserves duty-cycle information through the sign activation.
    bn_mode: str = "fixed"

    @property
    def num_conv_layers(self) -> int:
        return len(self.channels)

    def groups(self, layer: int) -> int:
        if layer == 0:
            return 1
        return self.channels[layer - 1] // self.channels_per_group

    def imc_layer_names(self):
        """conv1..conv5: the IMC-mapped layers (conv0 = digital sinc)."""
        return [f"conv{i}" for i in range(1, self.num_conv_layers)]

    def param_count(self) -> Dict[str, int]:
        n_bin, n_fc, n_bn = 0, 0, 0
        for i in range(self.num_conv_layers):
            cin = 1 if i == 0 else self.channels[i - 1]
            n_bin += self.channels[i] * (cin // self.groups(i)) * self.kernels[i]
            n_bn += self.channels[i]
        n_fc = self.channels[-1] * self.num_classes + self.num_classes
        return {"binary": n_bin, "bn_bias": n_bn, "fc": n_fc,
                "total": n_bin + n_bn + n_fc,
                "model_bits": n_bin + n_bn * 8 + n_fc * 8}


PAPER_KWS = KWSConfig()


# ---------------------------------------------------------------------------
# Parameters / state
# ---------------------------------------------------------------------------


class KWSState(NamedTuple):
    """BN running statistics (frozen during customization, §III-A)."""
    mean: Dict[str, jax.Array]
    var: Dict[str, jax.Array]


def init_params(key: jax.Array, cfg: KWSConfig = PAPER_KWS) -> Dict:
    keys = jax.random.split(key, cfg.num_conv_layers + 1)
    params: Dict = {}
    # Sinc layer: learned band edges, mel-ish spaced initialization.
    n0 = cfg.channels[0]
    # init the learned filter bank where a 15-tap binary kernel has
    # resolution (>= ~1 kHz at 16 kHz sample rate)
    low = jnp.linspace(700.0, 6200.0, n0)
    band = jnp.full((n0,), 300.0) + jnp.linspace(0.0, 900.0, n0)
    # Threshold (beta) init: a *negative* pre-binarization threshold makes
    # sign() energy-selective — a matched filter's oscillating response
    # exceeds the threshold (duty cycle encodes amplitude) while mismatched
    # responses stay below it.  With zero thresholds sign() is amplitude-
    # blind (any tone gives a 50% duty square wave in every channel).  This
    # is exactly the role of the paper's learnable offset (Fig 2/3); we fold
    # the init into beta and keep the offset itself at the paper's zero init.
    params["conv0"] = {
        "low_hz": low, "band_hz": band,
        "gamma": jnp.ones((n0,)), "beta": jnp.full((n0,), -0.6),
        "offset": jnp.zeros((n0,)),
    }
    for i in range(1, cfg.num_conv_layers):
        cin_g = cfg.channels[i - 1] // cfg.groups(i)
        shape = (cfg.kernels[i], cin_g, cfg.channels[i])
        w = jax.random.normal(keys[i], shape) * 0.1
        params[f"conv{i}"] = {
            "w": w,
            "gamma": jnp.ones((cfg.channels[i],)),
            "beta": jnp.full((cfg.channels[i],), -0.25),
            "offset": jnp.zeros((cfg.channels[i],)),
        }
    d = cfg.channels[-1]
    params["fc"] = {
        "w": jax.random.normal(keys[-1], (d, cfg.num_classes))
        * (1.0 / jnp.sqrt(d)),
        "b": jnp.zeros((cfg.num_classes,)),
    }
    return params


def init_state(cfg: KWSConfig = PAPER_KWS) -> KWSState:
    mean = {f"conv{i}": jnp.zeros((cfg.channels[i],))
            for i in range(cfg.num_conv_layers)}
    var = {}
    for i in range(cfg.num_conv_layers):
        if cfg.bn_mode == "fixed":
            # fixed mode normalizes by sqrt(fan_in); the stats must carry
            # that from step 0 so fold_params is consistent untrained too
            cin = 1 if i == 0 else cfg.channels[i - 1]
            fan_in = (cin // cfg.groups(i)) * cfg.kernels[i]
            var[f"conv{i}"] = jnp.full((cfg.channels[i],),
                                       float(fan_in) - 1e-5)
        else:
            var[f"conv{i}"] = jnp.ones((cfg.channels[i],))
    return KWSState(mean=mean, var=var)


# ---------------------------------------------------------------------------
# Sinc filter bank (binarized SincNet front end, [11])
# ---------------------------------------------------------------------------


def sinc_kernel(low_hz: jax.Array, band_hz: jax.Array, k: int,
                sample_rate: int) -> jax.Array:
    """Band-pass windowed-sinc kernels, (k, 1, C). Binarized by the caller."""
    low = jnp.abs(low_hz) + 30.0
    high = jnp.clip(low + jnp.abs(band_hz), 30.0, sample_rate / 2 - 30.0)
    t = (jnp.arange(k) - (k - 1) / 2.0) / sample_rate        # (k,)
    window = 0.54 - 0.46 * jnp.cos(2 * jnp.pi * jnp.arange(k) / (k - 1))

    def bp(f):
        return 2 * f * jnp.sinc(2 * f * t)                    # (C,k) via vmap

    h = (jax.vmap(bp)(high) - jax.vmap(bp)(low)) * window     # (C, k)
    # Per-filter max-normalization: sign(h) (the binarized forward) is
    # invariant, but it keeps |h|<=1 so the binarize STE clip passes
    # gradients back to the learned band edges.
    h = h / (jnp.max(jnp.abs(h), axis=-1, keepdims=True) + 1e-6)
    return jnp.transpose(h)[:, None, :]                       # (k, 1, C)


# ---------------------------------------------------------------------------
# Shared conv plumbing
# ---------------------------------------------------------------------------


def _conv_counts(x: jax.Array, w_bin: jax.Array, stride: int,
                 groups: int) -> jax.Array:
    return imc.binary_group_conv_counts(x, w_bin, groups=groups, stride=stride)


def _batchnorm_train(counts, gamma, beta, running_mean, running_var,
                     momentum: float):
    mu = jnp.mean(counts, axis=(0, 1))
    var = jnp.var(counts, axis=(0, 1))
    y = gamma * (counts - mu) / jnp.sqrt(var + 1e-5) + beta
    new_mean = momentum * running_mean + (1 - momentum) * mu
    new_var = momentum * running_var + (1 - momentum) * var
    return y, new_mean, new_var


def _batchnorm_eval(counts, gamma, beta, mean, var):
    return gamma * (counts - mean) / jnp.sqrt(var + 1e-5) + beta


# ---------------------------------------------------------------------------
# Float forwards (QAT training / eval)
# ---------------------------------------------------------------------------


def _float_forward(params, state: KWSState, x: jax.Array, cfg: KWSConfig,
                   train: bool,
                   chip_offsets: Optional[Dict[str, jax.Array]] = None,
                   sa_noise_std: float = 0.0,
                   rng: Optional[jax.Array] = None,
                   soft_alpha: Optional[float] = None):
    """Common float path.  With chip_offsets/sa_noise it becomes the
    noise-aware (QAT) forward used for recovery fine-tuning.

    soft_alpha: annealed-binarization training (act = tanh(alpha*(y+off))).
    Hard sign gives no usable gradient signal on this task (the loss is a
    staircase in the trunk parameters); annealing alpha up and finishing with
    the hard path recovers a bit-exact binary model.  Inference/hardware
    paths always use hard sign."""
    new_mean, new_var = dict(state.mean), dict(state.var)
    h = x[..., None]                                   # (B, T, 1)
    for i in range(cfg.num_conv_layers):
        name = f"conv{i}"
        p = params[name]
        latent = (sinc_kernel(p["low_hz"], p["band_hz"], cfg.kernels[0],
                              cfg.sample_rate) if i == 0 else p["w"])
        # soft_alpha semantics: None -> hard STE; a > 0 -> tanh(a*x) soft
        # annealing; a < 0 -> hard forward with tanh'(|a|x) surrogate grad.
        if soft_alpha is not None and soft_alpha > 0:
            w = jnp.tanh(soft_alpha * latent)          # annealed binarization
        elif soft_alpha is not None and soft_alpha < 0:
            w = binarize_sg(latent, -soft_alpha)
        else:
            w = binarize(latent)
        counts = _conv_counts(h, w, cfg.strides[i], cfg.groups(i))
        if chip_offsets is not None and i > 0:
            counts = counts + chip_offsets[name]
        if sa_noise_std > 0.0 and rng is not None and i > 0:
            rng, sub = jax.random.split(rng)
            counts = counts + sa_noise_std * jax.random.normal(sub,
                                                               counts.shape)
        if cfg.bn_mode == "fixed":
            fan_in = w.shape[0] * w.shape[1]
            if soft_alpha is not None and soft_alpha < 0 and i > 0:
                # hard phase: train through the EXACT in-memory bias grid
                # (count domain, parity + [-64,64] constraints, STE) so the
                # trained network is bit-identical to the folded silicon.
                sigma = jnp.sqrt(float(fan_in))
                g_safe = jnp.where(jnp.abs(p["gamma"]) < 0.05,
                                   jnp.sign(p["gamma"]) * 0.05 + 1e-9,
                                   p["gamma"])
                b_eff = (p["beta"] + p["offset"]) * sigma / g_safe
                b_q = b_eff + jax.lax.stop_gradient(
                    imc.map_bias(b_eff, cfg.bias_mapping) - b_eff)
                flip = jnp.where(p["gamma"] >= 0, 1.0, -1.0)
                h = binarize_sg((counts + b_q) * flip, -soft_alpha)
                h = channel_shuffle(h, cfg.groups(i))
                if cfg.pools[i] > 1:
                    h = or_maxpool(h, cfg.pools[i], axis=1)
                new_mean[name] = jnp.zeros_like(state.mean[name])
                new_var[name] = jnp.full_like(state.var[name],
                                              float(fan_in)) - 1e-5
                continue
            y = p["gamma"] * counts / jnp.sqrt(float(fan_in)) + p["beta"]
            # running stats pinned to the fixed normalization (fold-exact)
            new_mean[name] = jnp.zeros_like(state.mean[name])
            new_var[name] = jnp.full_like(state.var[name],
                                          float(fan_in)) - 1e-5
        elif train:
            y, m, v = _batchnorm_train(counts, p["gamma"], p["beta"],
                                       state.mean[name], state.var[name],
                                       cfg.bn_momentum)
            new_mean[name], new_var[name] = m, v
        else:
            y = _batchnorm_eval(counts, p["gamma"], p["beta"],
                                state.mean[name], state.var[name])
        off = p["offset"].reshape((1,) * (y.ndim - 1) + (-1,))
        if soft_alpha is not None and soft_alpha > 0:
            h = jnp.tanh(soft_alpha * (y + off))
        elif soft_alpha is not None and soft_alpha < 0:
            h = binarize_sg(y + off, -soft_alpha)
        else:
            h = rsign(y, p["offset"])
        h = channel_shuffle(h, cfg.groups(i))          # Fig 9 digital block
        if cfg.pools[i] > 1:
            h = or_maxpool(h, cfg.pools[i], axis=1)
    feats = jnp.mean(h, axis=1)                        # GAP, in [-1, 1]
    feats = ACT_Q.quantize_ste(feats)                  # QAT on the feature buf
    wq = WEIGHT_Q.quantize_ste(params["fc"]["w"])      # 8-bit FC (QAT)
    bq = WEIGHT_Q.quantize_ste(params["fc"]["b"])
    logits = feats @ wq + bq
    return logits, feats, KWSState(mean=new_mean, var=new_var)


def forward_train(params, state, x, cfg: KWSConfig = PAPER_KWS,
                  chip_offsets=None, sa_noise_std: float = 0.0, rng=None,
                  soft_alpha=None):
    logits, _, new_state = _float_forward(params, state, x, cfg, True,
                                          chip_offsets, sa_noise_std, rng,
                                          soft_alpha=soft_alpha)
    return logits, new_state


def forward_eval(params, state, x, cfg: KWSConfig = PAPER_KWS):
    logits, feats, _ = _float_forward(params, state, x, cfg, False)
    return logits, feats


# ---------------------------------------------------------------------------
# Hardware folding (paper §IV-A) and the count-exact hardware path
# ---------------------------------------------------------------------------


class HWParams(NamedTuple):
    w_bin: Dict[str, jax.Array]       # ±1 weights per conv layer
    bias: Dict[str, jax.Array]        # folded integer-domain biases
    flip: Dict[str, jax.Array]        # BN-decoder sign (±1)
    fc_w: jax.Array                   # Q1.7
    fc_b: jax.Array


class PackedHWParams(NamedTuple):
    """HWParams plus the fused kernel's fold-time packed operands.

    Packing the block-diagonal weights / bias / flip once at fold time
    (``fold_params(pack=True)`` or ``pack_hw_params``) models programming
    the SRAM arrays: per decision only the data-dependent im2col patches
    are packed.  Everything that accepts HWParams (hw_forward, evaluate_hw,
    the serving engine) accepts a PackedHWParams transparently."""

    hw: HWParams
    packed: Dict[str, imc.PackedLayer]     # conv1..conv5


def as_hw_params(hw) -> Tuple[HWParams, Optional[Dict[str, imc.PackedLayer]]]:
    """Normalize an HWParams-or-PackedHWParams to (hw, packed-or-None)."""
    if isinstance(hw, PackedHWParams):
        return hw.hw, hw.packed
    return hw, None


def pack_hw_params(hw: HWParams, cfg: KWSConfig = PAPER_KWS) -> PackedHWParams:
    """Pack every IMC layer's fused-kernel operands once (fold time)."""
    hw, _ = as_hw_params(hw)
    packed = {}
    for i in range(1, cfg.num_conv_layers):
        name = f"conv{i}"
        packed[name] = imc.pack_layer(hw.w_bin[name], hw.bias[name],
                                      hw.flip[name], cfg.groups(i))
    return PackedHWParams(hw=hw, packed=packed)


def fold_params(params, state: KWSState, cfg: KWSConfig = PAPER_KWS,
                macro: imc.IMCMacroConfig = imc.DEFAULT_MACRO,
                bn_constraints: bool = True,
                fc_quant: bool = True,
                pack: bool = False):
    """Fold BN (+ learnable offsets) into biases; apply the IMC bias grid
    (parity + [-64,64]) for IMC layers; quantize the FC to 8 bits.

    ``bn_constraints=False`` / ``fc_quant=False`` give the Table III ablation
    points.  ``pack=True`` additionally packs the fused kernel's operands
    (returns PackedHWParams) so the per-decision path never repacks weights.
    """
    w_bin, bias, flip = {}, {}, {}
    for i in range(cfg.num_conv_layers):
        name = f"conv{i}"
        p = params[name]
        if i == 0:
            w = binarize(sinc_kernel(p["low_hz"], p["band_hz"],
                                     cfg.kernels[0], cfg.sample_rate))
        else:
            w = binarize(p["w"])
        w_bin[name] = w
        b, f = imc.fold_bn_to_bias(p["gamma"], p["beta"], state.mean[name],
                                   state.var[name], p["offset"])
        if not bn_constraints:
            bias[name] = b            # ablation: no hardware grid anywhere
        elif i == 0:
            # digital adder: fine fixed-point grid, no parity constraint
            bias[name] = jnp.round(b * 128.0) / 128.0
        else:
            bias[name] = imc.map_bias(b, cfg.bias_mapping, macro)
        flip[name] = f
    fw, fb = params["fc"]["w"], params["fc"]["b"]
    if fc_quant:
        fw, fb = WEIGHT_Q.quantize(fw), WEIGHT_Q.quantize(fb)
    hw = HWParams(w_bin=w_bin, bias=bias, flip=flip, fc_w=fw, fc_b=fb)
    return pack_hw_params(hw, cfg) if pack else hw


def hw_conv_layer(hw: HWParams, i: int, h: jax.Array,
                  cfg: KWSConfig = PAPER_KWS, *,
                  packed: Optional[imc.PackedLayer] = None,
                  chip_offset: Optional[jax.Array] = None,
                  sa_key: Optional[jax.Array] = None,
                  sa_noise: Optional[jax.Array] = None,
                  sa_noise_std: float = 0.0,
                  use_kernel: bool = False) -> jax.Array:
    """One conv layer of the hardware path on activations (B, T, C_in)
    (layer 0: (B, T, 1) audio): counts -> mav_sa -> shuffle -> OR-pool.

    Shared by ``hw_forward`` (full windows) and the streaming serving path
    (repro.serving.stream, which feeds per-hop tail slices) so both run the
    exact same op chain.  ``sa_noise`` is an explicit (B, t_conv, C_out)
    pre-pool noise realization, mutually exclusive with ``sa_key``; the
    caller passes None noise/offset for the digital layer 0."""
    name = f"conv{i}"
    if use_kernel and i > 0:
        from repro.kernels.imc_mav import ops as mav_ops
        return mav_ops.fused_conv_mav(
            h, hw.w_bin[name], hw.bias[name], hw.flip[name],
            groups=cfg.groups(i), stride=cfg.strides[i],
            pool=cfg.pools[i], chip_offset=chip_offset, sa_key=sa_key,
            sa_noise=sa_noise, sa_noise_std=sa_noise_std, packed=packed)
    counts = _conv_counts(h, hw.w_bin[name], cfg.strides[i], cfg.groups(i))
    if chip_offset is not None:
        counts = counts + chip_offset
    h = imc.mav_sa(counts, hw.bias[name], hw.flip[name],
                   mav_offset=None, sa_key=sa_key, sa_noise=sa_noise,
                   sa_noise_std=sa_noise_std)
    h = channel_shuffle(h, cfg.groups(i))              # Fig 9 digital block
    if cfg.pools[i] > 1:
        h = or_maxpool(h, cfg.pools[i], axis=1)
    return h


def hw_forward(hw, x: jax.Array, cfg: KWSConfig = PAPER_KWS,
               chip_offsets: Optional[Dict[str, jax.Array]] = None,
               sa_noise_std: float = 0.0,
               rng: Optional[jax.Array] = None,
               collect_counts: bool = False,
               use_kernel: bool = False,
               sa_noise: Optional[Dict[str, jax.Array]] = None,
               sa_noise_field: Optional[SANoiseField] = None):
    """The silicon path: integer counts -> in-memory BN -> SA sign.

    ``hw`` is an HWParams or a PackedHWParams (fold-time packed fused-kernel
    operands).  Returns (logits, features) and, with collect_counts, the
    per-layer pre-SA counts (the chip's test mode, used for bias-compensation
    calibration).

    With ``use_kernel=True`` every IMC layer (conv1..conv5) runs as exactly
    one fused ``pallas_call`` — grouped conv + chip offset + word-line bias +
    SA noise + flip + sign + channel shuffle + OR-maxpool, no pre-activation
    HBM round trip — bit-identical to the jnp path (noise included: both
    draw the SA realization from the same per-layer key).  ``collect_counts``
    (the chip's digitize-the-counts test mode) forces the unfused path, since
    the fused kernel never materializes counts — exactly like the silicon.

    SA noise comes from ``rng``/``sa_noise_std`` (fresh draw per layer),
    from ``sa_noise`` — an explicit per-layer dict of (B, t_conv, C_out)
    pre-pool realizations — or from ``sa_noise_field``, a
    ``repro.core.sa_noise.SANoiseField`` batch of (stream key, window
    index) pairs that is expanded to the same explicit form.  The
    streaming equivalence contract (repro.serving.stream) and the
    customization oracle (repro.training.kws.hw_features) use the
    field/explicit forms so offline windows reproduce the
    per-absolute-column noise field bit-exactly."""
    hw, packed_all = as_hw_params(hw)
    if sa_noise_field is not None:
        if sa_noise is not None or rng is not None or sa_noise_std > 0.0:
            raise ValueError("pass only one of rng / sa_noise / "
                             "sa_noise_std / sa_noise_field")
        if sa_noise_field.keys.shape[0] != x.shape[0]:
            raise ValueError(
                f"sa_noise_field has {sa_noise_field.keys.shape[0]} rows "
                f"for a batch of {x.shape[0]}")
        sa_noise = field_window_noise(sa_noise_field, cfg)
        sa_noise_std = sa_noise_field.std
    if rng is not None and sa_noise is not None:
        raise ValueError("pass either rng or explicit sa_noise, not both")
    counts_log: Dict[str, jax.Array] = {}
    use_fused = use_kernel and not collect_counts
    h = x[..., None]
    for i in range(cfg.num_conv_layers):
        name = f"conv{i}"
        key = None
        if rng is not None and sa_noise_std > 0.0 and i > 0:
            rng, key = jax.random.split(rng)
        noise_i = None
        if sa_noise is not None and i > 0:
            noise_i = sa_noise.get(name)
        off_i = None
        if chip_offsets is not None and i > 0:
            off_i = chip_offsets[name]
        if not collect_counts:
            packed_i = packed_all[name] if (packed_all and i > 0) else None
            h = hw_conv_layer(hw, i, h, cfg, packed=packed_i,
                              chip_offset=off_i, sa_key=key,
                              sa_noise=noise_i,
                              sa_noise_std=sa_noise_std if i > 0 else 0.0,
                              use_kernel=use_fused)
            continue
        counts = _conv_counts(h, hw.w_bin[name], cfg.strides[i],
                              cfg.groups(i))
        if off_i is not None:
            counts = counts + off_i
        counts_log[name] = counts
        h = imc.mav_sa(counts, hw.bias[name], hw.flip[name],
                       mav_offset=None, sa_key=key, sa_noise=noise_i,
                       sa_noise_std=sa_noise_std if i > 0 else 0.0)
        h = channel_shuffle(h, cfg.groups(i))          # Fig 9 digital block
        if cfg.pools[i] > 1:
            h = or_maxpool(h, cfg.pools[i], axis=1)
    feats = ACT_Q.quantize(jnp.mean(h, axis=1))
    logits = feats @ hw.fc_w + hw.fc_b
    if collect_counts:
        return logits, feats, counts_log
    return logits, feats


def silence_columns(hw, cfg: KWSConfig = PAPER_KWS,
                    chip_offsets: Optional[Dict[str, jax.Array]] = None
                    ) -> Dict[str, jax.Array]:
    """Each conv layer's steady-state response to silent (all-zero) audio:
    {conv_i: (C_i,)} — the gated-hop fill of the always-on serving path.

    Valid convolutions of a constant input are constant, so on a silent
    window every activation column of every layer equals a single (C_i,)
    vector determined by the folded biases (and the chip's static MAV
    offsets, which shift the zero-input counts and therefore belong in the
    fill).  SA noise is deliberately excluded: a gated hop never evaluates
    the sense amplifiers, so the fill is the noiseless response.  Computed
    once at server construction (``repro.serving.scheduler``); a gated hop
    then just shifts these vectors into the carries and the GAP ring
    (``repro.serving.stream.gated_step``) without touching the IMC arrays.
    """
    hwp, _ = as_hw_params(hw)
    h = jnp.zeros((1, cfg.sample_len, 1))
    out = {}
    for i in range(cfg.num_conv_layers):
        off = None
        if chip_offsets is not None and i > 0:
            off = chip_offsets[f"conv{i}"]
        h = hw_conv_layer(hwp, i, h, cfg, chip_offset=off, use_kernel=False)
        out[f"conv{i}"] = h[0, 0]
    return out


# ---------------------------------------------------------------------------
# Loss / metrics / layer stats for the energy model
# ---------------------------------------------------------------------------


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))


def layer_stats(cfg: KWSConfig = PAPER_KWS):
    """Per-layer op counts per decision, feeding repro.core.energy.
    Controller cycles are distributed over the chip's 160k cycles/decision
    proportionally to each layer's temporal occupancy (the utilization
    schedule of §V-A)."""
    from repro.core.energy import CYCLES_PER_DECISION
    stats = []
    t = cfg.sample_len
    t_per_layer = []
    for i in range(cfg.num_conv_layers):
        t = (t - cfg.kernels[i]) // cfg.strides[i] + 1
        t_per_layer.append(t)
        t //= cfg.pools[i]
    total_t = sum(t_per_layer) + cfg.channels[-1]
    t = cfg.sample_len
    for i in range(cfg.num_conv_layers):
        t = t_per_layer[i]
        cin = 1 if i == 0 else cfg.channels[i - 1]
        fan_in = (cin // cfg.groups(i)) * cfg.kernels[i]
        macs = t * cfg.channels[i] * fan_in
        stats.append({
            "name": f"conv{i}" if i else "sinc(L1)",
            "kind": "digital" if i == 0 else "imc",
            "macs": int(macs),
            "in_bits": int(t * cin * (8 if i == 0 else 1)),
            "out_bits": int(t * cfg.channels[i]),
            "cycles": int(t / total_t * CYCLES_PER_DECISION),
        })
    d = cfg.channels[-1]
    stats.append({
        "name": "gap+fc", "kind": "fc",
        "macs": int(d * cfg.num_classes + d),
        "in_bits": int(d * 8), "out_bits": int(cfg.num_classes * 8),
        "cycles": int(d / total_t * CYCLES_PER_DECISION),
    })
    return stats
