"""Unified decoder-LM covering the dense / MoE / xLSTM / Mamba2-hybrid / VLM
families of the assigned architectures.

Design:
  * stacked-layer parameters + ``jax.lax.scan`` over the stack: compact HLO,
    fast compiles on the 512-device dry-run, O(1) program size in depth;
  * optional ``jax.checkpoint`` (remat) around the scan body for training;
  * one code path serves train (full seq), prefill (full seq + cache write)
    and decode (single token + cache) — selected by the cache argument;
  * heterogeneous stacks (xLSTM sLSTM positions, Zamba2 shared-attention
    groups) are expressed as static *segments*, each internally homogeneous
    and scanned.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import moe as MOE
from repro.models import xlstm as XL
from repro.models.layers import NO_SHARDING, ShardingPolicy

COMPUTE = jnp.bfloat16


# ---------------------------------------------------------------------------
# Segments: a static plan of homogeneous layer groups
# ---------------------------------------------------------------------------


def seg_plan(cfg: ArchConfig):
    """Returns a list of (kind, count) with kind in
    {'attn_mlp','attn_moe','mlstm','slstm','zamba_group','mamba'}."""
    if cfg.family in ("dense", "vlm"):
        return [("attn_mlp", cfg.n_layers)]
    if cfg.family == "moe":
        return [("attn_moe", cfg.n_layers)]
    if cfg.family == "xlstm":
        plan, run = [], 0
        for i in range(cfg.n_layers):
            if i in cfg.slstm_positions:
                if run:
                    plan.append(("mlstm", run))
                    run = 0
                plan.append(("slstm", 1))
            else:
                run += 1
        if run:
            plan.append(("mlstm", run))
        return plan
    if cfg.family == "hybrid":
        k = cfg.attn_every
        groups, rem = divmod(cfg.n_layers, k)
        plan = [("zamba_group", groups * k)]      # groups x (attn + k mamba)
        if rem:
            plan.append(("mamba", rem))
        return plan
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# Per-layer init/apply for each kind
# ---------------------------------------------------------------------------


def _attn_block_init(key, cfg: ArchConfig, with_moe: bool) -> Dict:
    k1, k2 = jax.random.split(key)
    p = {"ln1": L.rmsnorm_init(cfg.d_model),
         "attn": L.attn_init(k1, cfg.attn_cfg()),
         "ln2": L.rmsnorm_init(cfg.d_model)}
    if with_moe:
        p["moe"] = MOE.moe_init(k2, cfg.moe)
    else:
        p["mlp"] = L.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.gated_mlp)
    return p


def _attn_block_apply(p, cfg: ArchConfig, h, policy, cache=None,
                      cache_index=None):
    """Returns (h, new_cache, aux_loss)."""
    a, new_cache = L.attention(p["attn"], cfg.attn_cfg(),
                               L.rmsnorm(p["ln1"], h), policy,
                               cache=cache, cache_index=cache_index)
    h = h + a
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        out, aux = MOE.moe_apply(p["moe"], cfg.moe,
                                 L.rmsnorm(p["ln2"], h), policy)
        h = h + out
    else:
        h = h + L.mlp(p["mlp"], L.rmsnorm(p["ln2"], h), policy,
                      cfg.gated_mlp)
    return policy.btd(h), new_cache, aux


def _seg_init(key, cfg: ArchConfig, kind: str, count: int) -> Dict:
    """Stacked params for one segment."""
    def one(k, kind):
        if kind in ("attn_mlp", "attn_moe"):
            return _attn_block_init(k, cfg, kind == "attn_moe")
        if kind == "mlstm":
            return XL.mlstm_init(k, cfg.xlstm)
        if kind == "slstm":
            return XL.slstm_init(k, cfg.xlstm)
        if kind == "mamba":
            return {"ln": L.rmsnorm_init(cfg.d_model),
                    "mamba": M2.mamba2_init(k, cfg.mamba)}
        raise ValueError(kind)

    if kind == "zamba_group":
        k1, k2 = jax.random.split(key)
        n = count  # total mamba layers in the groups
        stacked = jax.vmap(lambda k: one(k, "mamba"))(jax.random.split(k2, n))
        return {"shared_attn": _attn_block_init(k1, cfg, False),
                "mamba": stacked}
    if kind == "slstm":
        return one(key, "slstm")
    return jax.vmap(lambda k: one(k, kind))(jax.random.split(key, count))


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------


def init_lm(key, cfg: ArchConfig) -> Dict:
    plan = seg_plan(cfg)
    keys = jax.random.split(key, len(plan) + 3)
    params: Dict[str, Any] = {
        "embed": jax.random.normal(keys[0], (cfg.vocab_padded, cfg.d_model),
                                   jnp.float32) * 0.02,
        "ln_f": L.rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = jax.random.normal(
            keys[1], (cfg.d_model, cfg.vocab_padded),
            jnp.float32) * (cfg.d_model ** -0.5)
    params["segments"] = [
        _seg_init(keys[i + 2], cfg, kind, count)
        for i, (kind, count) in enumerate(plan)
    ]
    return params


# ---------------------------------------------------------------------------
# Segment forward (full-sequence; optional cache write for prefill)
# ---------------------------------------------------------------------------


def _maybe_remat(f, cfg: ArchConfig, train: bool):
    if cfg.remat and train:
        return jax.checkpoint(f,
                              policy=jax.checkpoint_policies.nothing_saveable)
    return f


def _seg_forward(seg_params, cfg: ArchConfig, kind: str, count: int, h,
                 policy: ShardingPolicy, train: bool):
    """Full-seq forward of one segment. Returns (h, aux)."""
    if kind in ("attn_mlp", "attn_moe"):
        def body(carry, lp):
            hh, aux = carry
            hh, _, a = _attn_block_apply(lp, cfg, hh, policy)
            return (hh, aux + a), None

        (h, aux), _ = jax.lax.scan(_maybe_remat(body, cfg, train),
                                   (h, jnp.zeros((), jnp.float32)),
                                   seg_params)
        return h, aux

    if kind == "mlstm":
        def body(hh, lp):
            return XL.mlstm_apply(lp, cfg.xlstm, hh, policy), None
        h, _ = jax.lax.scan(_maybe_remat(body, cfg, train), h, seg_params)
        return h, jnp.zeros((), jnp.float32)

    if kind == "slstm":
        return (XL.slstm_apply(seg_params, cfg.xlstm, h, policy),
                jnp.zeros((), jnp.float32))

    if kind == "mamba":
        def body(hh, lp):
            out = M2.mamba2_apply(lp["mamba"], cfg.mamba,
                                  L.rmsnorm(lp["ln"], hh), policy)
            return policy.btd(hh + out), None
        h, _ = jax.lax.scan(_maybe_remat(body, cfg, train), h, seg_params)
        return h, jnp.zeros((), jnp.float32)

    if kind == "zamba_group":
        k = cfg.attn_every
        groups = count // k
        stacked = jax.tree_util.tree_map(
            lambda a: a.reshape(groups, k, *a.shape[1:]), seg_params["mamba"])

        def inner(hh, lp):
            out = M2.mamba2_apply(lp["mamba"], cfg.mamba,
                                  L.rmsnorm(lp["ln"], hh), policy)
            return policy.btd(hh + out), None

        def outer(hh, glp):
            hh, _, _ = _attn_block_apply(seg_params["shared_attn"], cfg, hh,
                                         policy)
            hh, _ = jax.lax.scan(_maybe_remat(inner, cfg, train), hh, glp)
            return hh, None

        h, _ = jax.lax.scan(outer, h, stacked)
        return h, jnp.zeros((), jnp.float32)

    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Full forward (train / scoring)
# ---------------------------------------------------------------------------


def forward_lm(params, cfg: ArchConfig, tokens: jax.Array,
               policy: ShardingPolicy = NO_SHARDING,
               prefix_embeds: Optional[jax.Array] = None,
               train: bool = True) -> Tuple[jax.Array, jax.Array]:
    """tokens: (B, S) int32.  prefix_embeds: (B, P, D) modality stub.
    Returns (logits (B, S_total, Vpad) bf16, aux_loss)."""
    h = params["embed"].astype(COMPUTE)[tokens]
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(COMPUTE), h], axis=1)
    h = policy.btd(h)
    aux_total = jnp.zeros((), jnp.float32)
    for (kind, count), seg in zip(seg_plan(cfg), params["segments"]):
        h, aux = _seg_forward(seg, cfg, kind, count, h, policy, train)
        aux_total = aux_total + aux
    h = L.rmsnorm(params["ln_f"], h)
    unembed = (params["embed"].T if cfg.tie_embeddings
               else params["unembed"]).astype(COMPUTE)
    logits = h @ unembed
    logits = policy.btv(logits)
    return logits, aux_total


def lm_loss(logits: jax.Array, labels: jax.Array, vocab_size: int,
            label_offset: int = 0) -> jax.Array:
    """Causal-LM CE; masks the padded vocab tail.  label_offset drops leading
    prefix positions (VLM/audio stubs).

    Written with elementwise + reduction ops ONLY (no take_along_axis): a
    gather over the model-sharded vocab axis forces XLA to all-gather the
    full fp32 logits per device (40GB/device at train_4k scale).  The
    one-hot-select form keeps every (B,S,V) intermediate vocab-sharded."""
    if label_offset:
        logits = logits[:, label_offset:]
    v = logits.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, v), 2)
    masked = jnp.where(iota < vocab_size, logits.astype(jnp.float32), -jnp.inf)
    # stable logsumexp, all reductions over the sharded axis
    m = jnp.max(masked, axis=-1)                                   # (B,S)
    lse = m + jnp.log(jnp.sum(jnp.exp(masked - m[..., None]), axis=-1))
    correct = jnp.sum(
        jnp.where(iota == labels[..., None].astype(jnp.int32),
                  logits.astype(jnp.float32), 0.0), axis=-1)
    return jnp.mean(lse - correct)


# ---------------------------------------------------------------------------
# KV / state caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               policy: ShardingPolicy = NO_SHARDING,
               dtype=COMPUTE) -> list:
    """Cache pytree mirroring the segment plan."""
    caches = []
    for kind, count in seg_plan(cfg):
        if kind in ("attn_mlp", "attn_moe"):
            kv = lambda: jnp.zeros(
                (count, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype)
            caches.append({"k": kv(), "v": kv()})
        elif kind == "mlstm":
            c = XL.mlstm_init_cache(cfg.xlstm, batch, dtype)
            caches.append(jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (count,) + a.shape), c))
        elif kind == "slstm":
            caches.append(XL.slstm_init_cache(cfg.xlstm, batch))
        elif kind == "mamba":
            c = M2.mamba2_init_cache(cfg.mamba, batch, dtype)
            caches.append(jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (count,) + a.shape), c))
        elif kind == "zamba_group":
            k = cfg.attn_every
            groups = count // k
            mc = M2.mamba2_init_cache(cfg.mamba, batch, dtype)
            caches.append({
                "attn": {
                    "k": jnp.zeros((groups, batch, max_len, cfg.n_kv_heads,
                                    cfg.head_dim), dtype),
                    "v": jnp.zeros((groups, batch, max_len, cfg.n_kv_heads,
                                    cfg.head_dim), dtype)},
                "mamba": jax.tree_util.tree_map(
                    lambda a: jnp.broadcast_to(a, (count,) + a.shape), mc),
            })
    return caches


# ---------------------------------------------------------------------------
# Decode step (and prefill via forward + cache write)
# ---------------------------------------------------------------------------


def decode_step(params, cfg: ArchConfig, tokens: jax.Array, caches: list,
                index: jax.Array,
                policy: ShardingPolicy = NO_SHARDING
                ) -> Tuple[jax.Array, list]:
    """tokens: (B, 1); index: scalar int32 — position to write in the cache.
    Returns (logits (B, 1, Vpad), new_caches)."""
    h = params["embed"].astype(COMPUTE)[tokens]
    new_caches = []
    for (kind, count), seg, cache in zip(seg_plan(cfg), params["segments"],
                                         caches):
        if kind in ("attn_mlp", "attn_moe"):
            if not getattr(cfg, "scan_layers", True):
                # unrolled decode: avoids the scan's stacked-cache
                # dynamic-update-slice (an SPMD reshard per layer)
                ncs = []
                for i in range(count):
                    lp = jax.tree_util.tree_map(lambda a: a[i], seg)
                    lc = jax.tree_util.tree_map(lambda a: a[i], cache)
                    h, nci, _ = _attn_block_apply(lp, cfg, h, policy,
                                                  cache=lc,
                                                  cache_index=index)
                    ncs.append(nci)
                nc = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *ncs)
                new_caches.append(nc)
                continue

            def body(hh, xs):
                lp, lc = xs
                hh, nc, _ = _attn_block_apply(lp, cfg, hh, policy, cache=lc,
                                              cache_index=index)
                return hh, nc
            h, nc = jax.lax.scan(body, h, (seg, cache))
            new_caches.append(nc)
        elif kind == "mlstm":
            def body(hh, xs):
                lp, lc = xs
                hh, nc = XL.mlstm_step(lp, cfg.xlstm, hh, lc)
                return hh, nc
            h, nc = jax.lax.scan(body, h, (seg, cache))
            new_caches.append(nc)
        elif kind == "slstm":
            h, nc = XL.slstm_step(seg, cfg.xlstm, h, cache)
            new_caches.append(nc)
        elif kind == "mamba":
            def body(hh, xs):
                lp, lc = xs
                out, nc = M2.mamba2_step(lp["mamba"], cfg.mamba,
                                         L.rmsnorm(lp["ln"], hh), lc)
                return hh + out, nc
            h, nc = jax.lax.scan(body, h, (seg, cache))
            new_caches.append(nc)
        elif kind == "zamba_group":
            k = cfg.attn_every
            groups = count // k
            mamba_stacked = jax.tree_util.tree_map(
                lambda a: a.reshape(groups, k, *a.shape[1:]), seg["mamba"])
            mcache = jax.tree_util.tree_map(
                lambda a: a.reshape(groups, k, *a.shape[1:]), cache["mamba"])

            def inner(hh, xs):
                lp, lc = xs
                out, nc = M2.mamba2_step(lp["mamba"], cfg.mamba,
                                         L.rmsnorm(lp["ln"], hh), lc)
                return hh + out, nc

            def outer(hh, xs):
                glp, gc, acache = xs
                hh, ac, _ = _attn_block_apply(seg["shared_attn"], cfg, hh,
                                              policy, cache=acache,
                                              cache_index=index)
                hh, nc = jax.lax.scan(inner, hh, (glp, gc))
                return hh, (nc, ac)

            h, (nmc, nac) = jax.lax.scan(outer, h,
                                         (mamba_stacked, mcache,
                                          cache["attn"]))
            new_caches.append({
                "attn": nac,
                "mamba": jax.tree_util.tree_map(
                    lambda a: a.reshape(count, *a.shape[2:]), nmc)})
    h = L.rmsnorm(params["ln_f"], h)
    unembed = (params["embed"].T if cfg.tie_embeddings
               else params["unembed"]).astype(COMPUTE)
    logits = h @ unembed
    return logits, new_caches


def prefill(params, cfg: ArchConfig, tokens: jax.Array,
            policy: ShardingPolicy = NO_SHARDING,
            prefix_embeds: Optional[jax.Array] = None):
    """Full-sequence prefill: returns (last-position logits, caches filled
    for positions [0, S)).  For attention segments the K/V of the whole
    sequence are recomputed per layer into the cache (write-on-forward)."""
    b, s = tokens.shape
    h = params["embed"].astype(COMPUTE)[tokens]
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(COMPUTE), h], axis=1)
        s = h.shape[1]
    h = policy.btd(h)
    caches = []
    acfg = cfg.attn_cfg()
    for (kind, count), seg in zip(seg_plan(cfg), params["segments"]):
        if kind in ("attn_mlp", "attn_moe"):
            def body(hh, lp):
                xn = L.rmsnorm(lp["ln1"], hh)
                # materialize kv for the cache
                k = L.dense(lp["attn"]["wk"], xn).reshape(
                    b, s, acfg.n_kv_heads, acfg.head_dim)
                v = L.dense(lp["attn"]["wv"], xn).reshape(
                    b, s, acfg.n_kv_heads, acfg.head_dim)
                if acfg.qk_norm:
                    k = L.rmsnorm(lp["attn"]["k_norm"], k)
                k = L.apply_rope(k, jnp.arange(s)[None, :], acfg.rope_theta)
                hh, _, _ = _attn_block_apply(lp, cfg, hh, policy)
                return hh, {"k": k, "v": v}
            h, kv = jax.lax.scan(body, h, seg)
            caches.append(kv)
        else:
            # recurrent segments: run chunked forward, then rebuild final
            # states via the step path is wasteful; instead run the scan with
            # return_state through the apply fns (simplified: use full apply
            # then a single-step replay is unnecessary for the dry-run cells,
            # which decode from a fresh state or a given cache).
            h, _ = _seg_forward(seg, cfg, kind, count, h, policy, train=False)
            if kind == "mlstm":
                c = XL.mlstm_init_cache(cfg.xlstm, b)
                caches.append(jax.tree_util.tree_map(
                    lambda a: jnp.broadcast_to(a, (count,) + a.shape), c))
            elif kind == "slstm":
                caches.append(XL.slstm_init_cache(cfg.xlstm, b))
            elif kind == "zamba_group":
                groups = count // cfg.attn_every
                mc = M2.mamba2_init_cache(cfg.mamba, b)
                caches.append({
                    "attn": {
                        "k": jnp.zeros((groups, b, s, cfg.n_kv_heads,
                                        cfg.head_dim), COMPUTE),
                        "v": jnp.zeros((groups, b, s, cfg.n_kv_heads,
                                        cfg.head_dim), COMPUTE)},
                    "mamba": jax.tree_util.tree_map(
                        lambda a: jnp.broadcast_to(a, (count,) + a.shape),
                        mc)})
            else:
                c = M2.mamba2_init_cache(cfg.mamba, b)
                caches.append(jax.tree_util.tree_map(
                    lambda a: jnp.broadcast_to(a, (count,) + a.shape), c))
    h = L.rmsnorm(params["ln_f"], h[:, -1:])
    unembed = (params["embed"].T if cfg.tie_embeddings
               else params["unembed"]).astype(COMPUTE)
    return h @ unembed, caches
