"""Mamba2 (SSD) block, chunkwise-parallel, built on the shared GLA core.

The SSD recurrence (Mamba2, Dao & Gu 2024) is
    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t ,  y_t = C_t . h_t + D x_t
with a *scalar* per-head decay — i.e. exactly the gated-linear-attention
recurrence in repro.models.layers with q=C, k=B, v=x, log_a = dt*A, b = dt.
The chunked form keeps the MXU busy instead of a length-T sequential scan.

Decode keeps (conv_state, ssm_state) per layer: O(1) per token — this is why
zamba2/xlstm run the long_500k cell while full-attention archs skip it.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import (NO_SHARDING, ShardingPolicy, dense,
                                 dense_init, gated_linear_attention, gla_step,
                                 rmsnorm, rmsnorm_init)


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def mamba2_init(key, cfg: Mamba2Config) -> Dict:
    ks = jax.random.split(key, 5)
    di, dm = cfg.d_inner, cfg.d_model
    h = cfg.n_heads
    # in_proj packs [z, x, B, C, dt]
    d_in_proj = 2 * di + 2 * cfg.d_state + h
    return {
        "in_proj": dense_init(ks[0], dm, d_in_proj),
        "conv_w": jax.random.normal(ks[1],
                                    (cfg.d_conv, di + 2 * cfg.d_state),
                                    jnp.float32) * 0.2,
        "conv_b": jnp.zeros((di + 2 * cfg.d_state,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)),      # per-head decay
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": rmsnorm_init(di),
        "out_proj": dense_init(ks[2], di, dm),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv1d.  x: (B,T,C); w: (K,C).  With state (B,K-1,C)
    supports streaming; returns (y, new_state)."""
    k = w.shape[0]
    wc = w.astype(x.dtype)
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * wc[i][None, None, :]
            for i in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else None
    return jax.nn.silu(y + b.astype(x.dtype)), new_state


def _split_proj(zxbcdt: jax.Array, cfg: Mamba2Config):
    di, ds, h = cfg.d_inner, cfg.d_state, cfg.n_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * ds]
    dt = zxbcdt[..., di + di + 2 * ds:]
    return z, xbc, dt


def mamba2_apply(p: Dict, cfg: Mamba2Config, x: jax.Array,
                 policy: ShardingPolicy = NO_SHARDING,
                 chunk: int = 128) -> jax.Array:
    """Training / prefill forward. x: (B, T, D)."""
    b, t, _ = x.shape
    h, hd, ds = cfg.n_heads, cfg.head_dim, cfg.d_state
    zxbcdt = dense(p["in_proj"], x)
    z, xbc, dt = _split_proj(zxbcdt, cfg)
    xbc, _ = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xin = xbc[..., :cfg.d_inner]
    Bm = xbc[..., cfg.d_inner:cfg.d_inner + ds]                  # (B,T,N)
    Cm = xbc[..., cfg.d_inner + ds:]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"])                          # (B,T,H)
    A = -jnp.exp(p["A_log"])                                      # (H,) < 0
    log_a = dt * A                                                # (B,T,H)

    # GLA mapping: q=C, k=B (shared across heads -> broadcast), v=dt*x
    q = jnp.broadcast_to(Cm[:, :, None, :], (b, t, h, ds))
    k = jnp.broadcast_to(Bm[:, :, None, :], (b, t, h, ds))
    v = xin.reshape(b, t, h, hd)
    # shard the head axis: the broadcast otherwise replicates (B,T,H,N)
    # per device (44GB/device on zamba2 prefill_32k before this constraint)
    q, k, v = policy.bthd(q), policy.bthd(k), policy.bthd(v)
    pad = (-t) % chunk
    if pad:
        zeros = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) *
                                  (a.ndim - 2))
        q, k, v = zeros(q), zeros(k), zeros(v)
        log_a, dt = zeros(log_a), zeros(dt)
    y = gated_linear_attention(q, k, v, log_a, dt, chunk=chunk,
                               policy=policy if policy.enabled else None)
    y = y[:, :t]
    y = y.reshape(b, t, cfg.d_inner) + xin * jnp.repeat(
        p["D"], hd)[None, None, :].astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    y = policy.btf(y)
    return dense(p["out_proj"], y)


def mamba2_init_cache(cfg: Mamba2Config, batch: int, dtype=jnp.float32):
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1,
                           cfg.d_inner + 2 * cfg.d_state), dtype),
        "ssm": jnp.zeros((batch, cfg.n_heads, cfg.d_state, cfg.head_dim),
                         jnp.float32),
    }


def mamba2_step(p: Dict, cfg: Mamba2Config, x: jax.Array, cache: Dict,
                policy: ShardingPolicy = NO_SHARDING
                ) -> Tuple[jax.Array, Dict]:
    """Single-token decode. x: (B, 1, D)."""
    b = x.shape[0]
    h, hd, ds = cfg.n_heads, cfg.head_dim, cfg.d_state
    zxbcdt = dense(p["in_proj"], x)
    z, xbc, dt = _split_proj(zxbcdt, cfg)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                 state=cache["conv"])
    xin = xbc[..., :cfg.d_inner]
    Bm = xbc[..., cfg.d_inner:cfg.d_inner + ds]
    Cm = xbc[..., cfg.d_inner + ds:]
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    log_a = dt * A
    q = jnp.broadcast_to(Cm[:, 0, None, :], (b, h, ds))
    k = jnp.broadcast_to(Bm[:, 0, None, :], (b, h, ds))
    v = xin[:, 0].reshape(b, h, hd)
    y, new_ssm = gla_step(q, k, v, log_a, dt, cache["ssm"])
    y = y.reshape(b, 1, cfg.d_inner) + xin * jnp.repeat(
        p["D"], hd)[None, None, :].astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = dense(p["out_proj"], y)
    return out, {"conv": new_conv, "ssm": new_ssm}
