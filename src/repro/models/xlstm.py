"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable) and
sLSTM (scalar memory, true recurrence).

mLSTM is the gated-linear-attention recurrence with per-head scalar forget
gate -> reuses the chunked GLA core (MXU-friendly).  sLSTM has a nonlinear
hidden-to-gate dependency and runs as a time scan (its d_model is small in
xlstm-125m, so the sequential part is cheap relative to the mLSTM stack).

Block layout follows the paper: mLSTM blocks are pre-norm residual with an
up-projection (factor 2), causal conv, and learnable skip; sLSTM blocks are
post-up-projection-free with a gated FFN (factor 4/3).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import (NO_SHARDING, ShardingPolicy, dense,
                                 dense_init, gated_linear_attention, gla_step,
                                 rmsnorm, rmsnorm_init)


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    d_model: int
    n_heads: int = 4
    expand: int = 2          # mLSTM up-projection
    d_conv: int = 4
    ffn_factor: float = 4.0 / 3.0   # sLSTM FFN

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_init(key, cfg: XLSTMConfig) -> Dict:
    ks = jax.random.split(key, 8)
    dm, di = cfg.d_model, cfg.d_inner
    return {
        "norm": rmsnorm_init(dm),
        "up_l": dense_init(ks[0], dm, di),       # main path
        "up_r": dense_init(ks[1], dm, di),       # gate path
        "conv_w": jax.random.normal(ks[2], (cfg.d_conv, di),
                                    jnp.float32) * 0.2,
        "conv_b": jnp.zeros((di,), jnp.float32),
        "wq": dense_init(ks[3], di, di),
        "wk": dense_init(ks[4], di, di),
        "wv": dense_init(ks[5], di, di),
        "w_if": dense_init(ks[6], di, 2 * cfg.n_heads),  # input+forget gates
        "skip": jnp.ones((di,), jnp.float32),
        "out_norm": rmsnorm_init(di),
        "down": dense_init(ks[7], di, dm),
    }


def _mlstm_gates(p, xc, cfg: XLSTMConfig):
    gf = dense(p["w_if"], xc).astype(jnp.float32)
    i_pre, f_pre = jnp.split(gf, 2, axis=-1)          # (B,T,H)
    log_f = -jax.nn.softplus(-f_pre)                  # log sigmoid(f)
    i_gate = jnp.exp(jnp.minimum(i_pre, 0.0))         # stabilized exp input
    return log_f, i_gate


def mlstm_apply(p: Dict, cfg: XLSTMConfig, x: jax.Array,
                policy: ShardingPolicy = NO_SHARDING,
                chunk: int = 128) -> jax.Array:
    b, t, _ = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    xn = rmsnorm(p["norm"], x)
    left = dense(p["up_l"], xn)
    right = jax.nn.silu(dense(p["up_r"], xn))
    # causal conv on the main path
    k = p["conv_w"].shape[0]
    cw = p["conv_w"].astype(left.dtype)
    pad = jnp.zeros((b, k - 1, left.shape[-1]), left.dtype)
    xp = jnp.concatenate([pad, left], axis=1)
    xc = sum(xp[:, i:i + t, :] * cw[i][None, None, :]
             for i in range(k))
    xc = jax.nn.silu(xc + p["conv_b"].astype(left.dtype))

    q = dense(p["wq"], xc).reshape(b, t, h, hd)
    kk = dense(p["wk"], xc).reshape(b, t, h, hd) * (hd ** -0.5)
    v = dense(p["wv"], left).reshape(b, t, h, hd)
    log_f, i_gate = _mlstm_gates(p, xc, cfg)

    padn = (-t) % chunk
    if padn:
        z2 = lambda a: jnp.pad(a, ((0, 0), (0, padn)) + ((0, 0),) *
                               (a.ndim - 2))
        q, kk, v, log_f, i_gate = map(z2, (q, kk, v, log_f, i_gate))
    y = gated_linear_attention(q, kk, v, log_f, i_gate, chunk=chunk,
                               policy=policy if policy.enabled else None)
    y = y[:, :t].reshape(b, t, cfg.d_inner)
    y = rmsnorm(p["out_norm"], y) + xc * p["skip"].astype(x.dtype)
    y = y * right
    y = policy.btf(y)
    return x + dense(p["down"], y)


def mlstm_init_cache(cfg: XLSTMConfig, batch: int, dtype=jnp.float32):
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
        "state": jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.head_dim),
                           jnp.float32),
    }


def mlstm_step(p: Dict, cfg: XLSTMConfig, x: jax.Array, cache: Dict
               ) -> Tuple[jax.Array, Dict]:
    b = x.shape[0]
    h, hd = cfg.n_heads, cfg.head_dim
    xn = rmsnorm(p["norm"], x)
    left = dense(p["up_l"], xn)
    right = jax.nn.silu(dense(p["up_r"], xn))
    k = p["conv_w"].shape[0]
    cw = p["conv_w"].astype(left.dtype)
    xp = jnp.concatenate([cache["conv"].astype(left.dtype), left], axis=1)
    xc = sum(xp[:, i:i + 1, :] * cw[i][None, None, :]
             for i in range(k))
    xc = jax.nn.silu(xc + p["conv_b"].astype(left.dtype))
    new_conv = xp[:, 1:, :]

    q = dense(p["wq"], xc).reshape(b, h, hd)
    kk = dense(p["wk"], xc).reshape(b, h, hd) * (hd ** -0.5)
    v = dense(p["wv"], left).reshape(b, h, hd)
    log_f, i_gate = _mlstm_gates(p, xc, cfg)
    y, new_state = gla_step(q, kk, v, log_f[:, 0], i_gate[:, 0],
                            cache["state"])
    y = y.reshape(b, 1, cfg.d_inner)
    y = rmsnorm(p["out_norm"], y) + xc * p["skip"].astype(x.dtype)
    y = y * right
    return x + dense(p["down"], y), {"conv": new_conv, "state": new_state}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_init(key, cfg: XLSTMConfig) -> Dict:
    ks = jax.random.split(key, 4)
    dm = cfg.d_model
    hd = dm // cfg.n_heads
    d_ff = int(cfg.ffn_factor * dm)
    return {
        "norm": rmsnorm_init(dm),
        "w_gates": dense_init(ks[0], dm, 4 * dm),        # i, f, z, o
        # per-head recurrent matrices (block-diagonal R)
        "r_gates": jax.random.normal(ks[1], (cfg.n_heads, hd, 4 * hd),
                                     jnp.float32) * (hd ** -0.5),
        "out_norm": rmsnorm_init(dm),
        "ffn_up": dense_init(ks[2], dm, 2 * d_ff),       # gated
        "ffn_down": dense_init(ks[3], d_ff, dm),
    }


def slstm_cell(p, cfg: XLSTMConfig, wx: jax.Array, state):
    """wx: (B, 4*D) precomputed input contribution; state: (h, c, n, m)."""
    h_prev, c_prev, n_prev, m_prev = state
    b = h_prev.shape[0]
    nh, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
    rh = jnp.einsum("bhd,hde->bhe", h_prev.reshape(b, nh, hd),
                    p["r_gates"]).reshape(b, 4 * cfg.d_model)
    z_all = (wx + rh).astype(jnp.float32)
    i_pre, f_pre, z_pre, o_pre = jnp.split(z_all, 4, axis=-1)
    # stabilized exponential gating (xLSTM eq. 15-17)
    log_f = -jax.nn.softplus(-f_pre)
    m = jnp.maximum(log_f + m_prev, i_pre)
    i_g = jnp.exp(i_pre - m)
    f_g = jnp.exp(log_f + m_prev - m)
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    c = f_g * c_prev + i_g * z
    n = f_g * n_prev + i_g
    h = o * c / jnp.maximum(jnp.abs(n), 1.0)
    return (h, c, n, m)


def slstm_apply(p: Dict, cfg: XLSTMConfig, x: jax.Array,
                policy: ShardingPolicy = NO_SHARDING) -> jax.Array:
    b, t, dm = x.shape
    xn = rmsnorm(p["norm"], x)
    wx = dense(p["w_gates"], xn)                     # (B,T,4D)
    zeros = jnp.zeros((b, dm), jnp.float32)
    init = (zeros, zeros, zeros, zeros - 1e9)

    def body(state, wx_t):
        new = slstm_cell(p, cfg, wx_t, state)
        return new, new[0]

    _, hs = jax.lax.scan(body, init, wx.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).astype(x.dtype)            # (B,T,D)
    y = rmsnorm(p["out_norm"], y)
    up, gate = jnp.split(dense(p["ffn_up"], y), 2, axis=-1)
    y = dense(p["ffn_down"], jax.nn.gelu(gate) * up)
    return x + y


def slstm_init_cache(cfg: XLSTMConfig, batch: int):
    dm = cfg.d_model
    z = jnp.zeros((batch, dm), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": z - 1e9}


def slstm_step(p: Dict, cfg: XLSTMConfig, x: jax.Array, cache: Dict
               ) -> Tuple[jax.Array, Dict]:
    xn = rmsnorm(p["norm"], x)
    wx = dense(p["w_gates"], xn)[:, 0]
    state = (cache["h"], cache["c"], cache["n"], cache["m"])
    h, c, n, m = slstm_cell(p, cfg, wx, state)
    y = h[:, None, :].astype(x.dtype)
    y = rmsnorm(p["out_norm"], y)
    up, gate = jnp.split(dense(p["ffn_up"], y), 2, axis=-1)
    y = dense(p["ffn_down"], jax.nn.gelu(gate) * up)
    return x + y, {"h": h, "c": c, "n": n, "m": m}
