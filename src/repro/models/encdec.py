"""Encoder-decoder backbone for seamless-m4t-medium ([audio]).

Per the assignment, the modality frontend is a STUB: ``input_specs()``
provides precomputed speech-frame embeddings (B, S_enc, D); we implement the
transformer backbone — a bidirectional encoder stack and a causal decoder
stack with cross-attention — with the same scan-over-layers machinery as the
decoder-only families.  (The real model's conformer feature extractor is out
of scope by assignment; RoPE replaces learned positions — noted in DESIGN.md.)
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.layers import NO_SHARDING, ShardingPolicy

COMPUTE = jnp.bfloat16


def _enc_layer_init(key, cfg: ArchConfig) -> Dict:
    k1, k2 = jax.random.split(key)
    return {"ln1": L.rmsnorm_init(cfg.d_model),
            "attn": L.attn_init(k1, cfg.attn_cfg()),
            "ln2": L.rmsnorm_init(cfg.d_model),
            "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.gated_mlp)}


def _dec_layer_init(key, cfg: ArchConfig) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": L.rmsnorm_init(cfg.d_model),
            "self_attn": L.attn_init(k1, cfg.attn_cfg()),
            "ln_x": L.rmsnorm_init(cfg.d_model),
            "cross_attn": L.attn_init(k2, cfg.attn_cfg()),
            "ln2": L.rmsnorm_init(cfg.d_model),
            "mlp": L.mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.gated_mlp)}


def init_encdec(key, cfg: ArchConfig) -> Dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "embed": jax.random.normal(k1, (cfg.vocab_padded, cfg.d_model),
                                   jnp.float32) * 0.02,
        "encoder": jax.vmap(lambda k: _enc_layer_init(k, cfg))(
            jax.random.split(k2, cfg.n_encoder_layers)),
        "decoder": jax.vmap(lambda k: _dec_layer_init(k, cfg))(
            jax.random.split(k3, cfg.n_layers)),
        "ln_enc": L.rmsnorm_init(cfg.d_model),
        "ln_f": L.rmsnorm_init(cfg.d_model),
        "unembed": jax.random.normal(k4, (cfg.d_model, cfg.vocab_padded),
                                     jnp.float32) * (cfg.d_model ** -0.5),
    }


def _maybe_remat(f, cfg: ArchConfig, train: bool):
    if cfg.remat and train:
        return jax.checkpoint(f,
                              policy=jax.checkpoint_policies.nothing_saveable)
    return f


def encode(params, cfg: ArchConfig, frames: jax.Array,
           policy: ShardingPolicy = NO_SHARDING,
           train: bool = True) -> jax.Array:
    """frames: (B, S_enc, D) stub embeddings -> encoder memory."""
    acfg = cfg.attn_cfg()
    acfg_bi = L.AttnConfig(**{**acfg.__dict__, "causal": False})
    h = policy.btd(frames.astype(COMPUTE))

    def body(hh, lp):
        a, _ = L.attention(lp["attn"], acfg_bi, L.rmsnorm(lp["ln1"], hh),
                           policy)
        hh = hh + a
        hh = hh + L.mlp(lp["mlp"], L.rmsnorm(lp["ln2"], hh), policy,
                        cfg.gated_mlp)
        return policy.btd(hh), None

    h, _ = jax.lax.scan(_maybe_remat(body, cfg, train), h, params["encoder"])
    return L.rmsnorm(params["ln_enc"], h)


def _dec_layer(lp, cfg: ArchConfig, h, memory, policy,
               self_cache=None, cache_index=None):
    acfg = cfg.attn_cfg()
    a, new_cache = L.attention(lp["self_attn"], acfg,
                               L.rmsnorm(lp["ln1"], h), policy,
                               cache=self_cache, cache_index=cache_index)
    h = h + a
    x, _ = L.attention(lp["cross_attn"], acfg, L.rmsnorm(lp["ln_x"], h),
                       policy, kv_override=memory)
    h = h + x
    h = h + L.mlp(lp["mlp"], L.rmsnorm(lp["ln2"], h), policy, cfg.gated_mlp)
    return policy.btd(h), new_cache


def forward_encdec(params, cfg: ArchConfig, frames: jax.Array,
                   tokens: jax.Array,
                   policy: ShardingPolicy = NO_SHARDING,
                   train: bool = True) -> jax.Array:
    """Teacher-forced training forward. Returns logits (B, S_dec, Vpad)."""
    memory = encode(params, cfg, frames, policy, train)
    h = policy.btd(params["embed"].astype(COMPUTE)[tokens])

    def body(hh, lp):
        hh, _ = _dec_layer(lp, cfg, hh, memory, policy)
        return hh, None

    h, _ = jax.lax.scan(_maybe_remat(body, cfg, train), h, params["decoder"])
    h = L.rmsnorm(params["ln_f"], h)
    return h @ params["unembed"].astype(COMPUTE)


def init_dec_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=COMPUTE):
    kv = lambda: jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads,
                            cfg.head_dim), dtype)
    return {"k": kv(), "v": kv()}


def decode_step_encdec(params, cfg: ArchConfig, tokens: jax.Array,
                       memory: jax.Array, cache: Dict, index: jax.Array,
                       policy: ShardingPolicy = NO_SHARDING
                       ) -> Tuple[jax.Array, Dict]:
    """Single-token decode against a fixed encoder memory."""
    h = params["embed"].astype(COMPUTE)[tokens]

    def body(hh, xs):
        lp, lc = xs
        hh, nc = _dec_layer(lp, cfg, hh, memory, policy, self_cache=lc,
                            cache_index=index)
        return hh, nc

    h, new_cache = jax.lax.scan(body, h, (params["decoder"], cache))
    h = L.rmsnorm(params["ln_f"], h)
    return h @ params["unembed"].astype(COMPUTE), new_cache


def prefill_encdec(params, cfg: ArchConfig, frames: jax.Array,
                   tokens: jax.Array,
                   policy: ShardingPolicy = NO_SHARDING):
    """Prefill decoder self-attn cache on a token prefix."""
    b, s = tokens.shape
    memory = encode(params, cfg, frames, policy, train=False)
    h = policy.btd(params["embed"].astype(COMPUTE)[tokens])
    acfg = cfg.attn_cfg()

    def body(hh, lp):
        xn = L.rmsnorm(lp["ln1"], hh)
        k = L.dense(lp["self_attn"]["wk"], xn).reshape(
            b, s, acfg.n_kv_heads, acfg.head_dim)
        v = L.dense(lp["self_attn"]["wv"], xn).reshape(
            b, s, acfg.n_kv_heads, acfg.head_dim)
        k = L.apply_rope(k, jnp.arange(s)[None, :], acfg.rope_theta)
        hh, _ = _dec_layer(lp, cfg, hh, memory, policy)
        return hh, {"k": k, "v": v}

    h, kv = jax.lax.scan(body, h, params["decoder"])
    h = L.rmsnorm(params["ln_f"], h[:, -1:])
    return h @ params["unembed"].astype(COMPUTE), kv, memory
