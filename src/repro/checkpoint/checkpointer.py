"""Fault-tolerant checkpointing (deliverable: checkpoint/restart).

Production properties:
  * atomic: write to <dir>/tmp.<step>, fsync, rename to <dir>/step_<n> —
    a crash mid-save never corrupts the latest checkpoint;
  * complete training state: params, optimizer state, data cursor, RNG key,
    step — resume is bit-identical (tests/test_checkpoint.py proves it);
  * bounded retention (keep_last) + 'latest' discovery for auto-restart;
  * storage is plain .npz per pytree (offline container: no orbax/tensorstore
    dependency), with the pytree structure stored alongside as a treedef
    string; works for sharded arrays by saving per-host addressable shards
    (single-host here — the multi-host extension point is marked).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_pytree(path: str, tree) -> None:
    leaves, treedef = _flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    np.savez(path, **arrays)
    with open(path + ".treedef", "w") as f:
        f.write(str(treedef))


def load_pytree(path: str, like) -> Any:
    data = np.load(path, allow_pickle=False)
    leaves = [data[f"leaf_{i}"] for i in range(len(data.files))]
    _, treedef = _flatten(like)
    return jax.tree_util.tree_unflatten(
        treedef, [jax.numpy.asarray(l) for l in leaves])


class Checkpointer:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def save(self, step: int, params, opt_state, data_step: int,
             rng_key, extra: Optional[Dict] = None) -> str:
        tmp = tempfile.mkdtemp(prefix=f"tmp.{step}.", dir=self.dir)
        try:
            save_pytree(os.path.join(tmp, "params.npz"), params)
            save_pytree(os.path.join(tmp, "opt_state.npz"), opt_state)
            meta = {"step": step, "data_step": data_step,
                    "rng_key": np.asarray(rng_key).tolist(),
                    "extra": extra or {}}
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
                f.flush()
                os.fsync(f.fileno())
            final = self._step_dir(step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)                      # atomic commit
        except Exception:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()
        return self._step_dir(step)

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def all_steps(self):
        steps = []
        for name in os.listdir(self.dir):
            m = re.match(r"step_(\d+)$", name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "meta.json")):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, params_like, opt_like, step: Optional[int] = None):
        """Returns (params, opt_state, meta) or None if no checkpoint."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None
        d = self._step_dir(step)
        params = load_pytree(os.path.join(d, "params.npz"), params_like)
        opt_state = load_pytree(os.path.join(d, "opt_state.npz"), opt_like)
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        return params, opt_state, meta
