"""Persistent store for per-user customization profiles.

A finished enrollment session (repro.serving.customize) produces a
``CustomizationResult`` — compensated integer IMC biases, the fine-tuned
Q1.7 head, and the run's accounting.  At fleet scale that profile must
outlive the serving process: a user who enrolled once expects their
accuracy back after every server restart.  This module wires the result
into the checkpoint layer so ``StreamServer.install_custom`` can restore
profiles from disk, **bit-identical** to the pre-restart stream (the
arrays are exact fixed-point/integer grids, stored losslessly as .npz).

Storage layout: ONE ``<root>/<user_id>.npz`` file per user, holding the
``bias.<layer>`` arrays, ``fc_w``/``fc_b``, and the JSON-encoded
metadata (epochs, n_utterances, history, energy) as a ``meta`` entry.
A single file is what makes writes genuinely atomic: the profile is
serialized beside its destination, flushed and fsynced, then
``os.replace``d into place — a crash mid-save (including a re-save over
an existing profile) leaves either the complete old profile or the
complete new one, never a mix and never neither.
"""

from __future__ import annotations

import json
import os
import re
import tempfile
from typing import List, Optional

import numpy as np

_ID_RE = re.compile(r"^[A-Za-z0-9][A-Za-z0-9._-]*$")


def _check_id(user_id: str) -> str:
    if not _ID_RE.fullmatch(user_id):
        raise ValueError(
            f"invalid profile id {user_id!r}: use letters, digits, '.', "
            f"'_' or '-' (must not start with a separator)")
    return user_id


def save_profile(path: str, result, seq: Optional[int] = None) -> str:
    """Serialize one CustomizationResult to ``path`` (a .npz file),
    atomically: tmp file + fsync + ``os.replace`` — safe against crashes
    even when overwriting an existing profile.  ``seq`` is an optional
    monotonic save counter (``ProfileStore`` maintains it so ``latest``
    is deterministic on coarse-mtime filesystems).  Returns ``path``."""
    parent = os.path.dirname(os.path.abspath(path)) or "."
    os.makedirs(parent, exist_ok=True)
    arrays = {f"bias.{name}": np.asarray(v)
              for name, v in result.bias.items()}
    arrays["fc_w"] = np.asarray(result.fc_w)
    arrays["fc_b"] = np.asarray(result.fc_b)
    meta = {
        "epochs": int(result.epochs),
        "n_utterances": int(result.n_utterances),
        "history": result.history,
        "energy": result.energy,
        "bias_layers": sorted(result.bias.keys()),
    }
    if seq is not None:
        meta["seq"] = int(seq)
    arrays["meta"] = np.frombuffer(
        json.dumps(meta).encode("utf-8"), dtype=np.uint8)
    fd, tmp = tempfile.mkstemp(prefix=".tmp.profile.", suffix=".npz",
                               dir=parent)
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **arrays)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)                      # atomic commit
    except Exception:
        try:
            os.remove(tmp)
        except OSError:
            pass
        raise
    return path


def load_profile(path: str):
    """Load a profile saved by ``save_profile``.  Returns a
    CustomizationResult whose arrays are bit-identical to the saved ones
    (lossless .npz round trip on the fixed-point grids)."""
    from repro.serving.customize import CustomizationResult

    with np.load(path, allow_pickle=False) as data:
        meta = json.loads(bytes(data["meta"]).decode("utf-8"))
        bias = {name: data[f"bias.{name}"]
                for name in meta["bias_layers"]}
        return CustomizationResult(
            bias=bias, fc_w=data["fc_w"], fc_b=data["fc_b"],
            epochs=meta["epochs"], n_utterances=meta["n_utterances"],
            history=meta["history"], energy=meta["energy"])


class ProfileStore:
    """Directory of per-user customization profiles.

    ::

        store = ProfileStore("profiles/")
        store.save("alice", session.result)      # after enrollment
        ...                                      # server restarts
        srv.install_custom("alice-mic", store.load("alice"))

    The restored stream serves bit-identically to the pre-restart one
    (test-enforced: tests/test_customize.py profile round-trip)."""

    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self._max_seq: Optional[int] = None    # scanned once, then kept

    def _path(self, user_id: str) -> str:
        return os.path.join(self.dir, _check_id(user_id) + ".npz")

    def _seq(self, user_id: str) -> int:
        """The stored save counter (0 for pre-seq files)."""
        with np.load(self._path(user_id), allow_pickle=False) as data:
            meta = json.loads(bytes(data["meta"]).decode("utf-8"))
        return int(meta.get("seq", 0))

    def save(self, user_id: str, result) -> str:
        """Atomically persist ``result`` under ``user_id`` (replacing any
        previous profile).  Returns the profile path.  O(1) after the
        first save: the monotonic counter behind ``latest`` is scanned
        from disk once per store instance, then maintained in memory."""
        if self._max_seq is None:
            self._max_seq = max((self._seq(u) for u in self.list()),
                                default=0)
        seq = self._max_seq + 1
        path = save_profile(self._path(user_id), result, seq=seq)
        self._max_seq = seq
        return path

    def load(self, user_id: str):
        """The stored CustomizationResult (raises FileNotFoundError if
        the user never enrolled)."""
        path = self._path(user_id)
        if not os.path.exists(path):
            raise FileNotFoundError(f"no stored profile for {user_id!r}")
        return load_profile(path)

    def exists(self, user_id: str) -> bool:
        return os.path.exists(self._path(user_id))

    def mtime(self, user_id: str) -> Optional[int]:
        """The stored profile's modification time in integer nanoseconds
        (``st_mtime_ns`` — exact equality is meaningful, unlike the float
        seconds view), or None if no profile exists.  ``os.replace`` makes
        every ``save`` a fresh inode with a fresh mtime, so a changed
        value is a reliable staleness signal for live installs
        (``StreamServer`` evicts/reinstalls profiles whose mtime moved)."""
        try:
            return os.stat(self._path(user_id)).st_mtime_ns
        except FileNotFoundError:
            return None

    def list(self) -> List[str]:
        """User ids with a stored profile."""
        out = []
        for name in sorted(os.listdir(self.dir)):
            if name.endswith(".npz") and _ID_RE.fullmatch(name[:-4]):
                out.append(name[:-4])
        return out

    def delete(self, user_id: str) -> bool:
        """Remove a stored profile; returns whether one existed."""
        path = self._path(user_id)
        try:
            os.remove(path)
            return True
        except FileNotFoundError:
            return False

    def latest(self) -> Optional[str]:
        """Most recently saved user id (by the monotonic save counter —
        deterministic on coarse-mtime filesystems), or None."""
        ids = self.list()
        if not ids:
            return None
        return max(ids, key=lambda u: (self._seq(u),
                                       os.path.getmtime(self._path(u))))
