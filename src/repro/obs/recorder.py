"""Per-tick flight recorder: a bounded ring of structured serving events.

The recorder keeps the last ``capacity`` events of the serving loop —
admissions, evictions, SLO sheds, gate decisions, batched-call
composition, health-state transitions, heal-job progress and per-tick
analytical energy — so an alarm or crash can dump the recent history
without the server having logged anything in steady state.

Events are plain dicts ``{"seq", "tick", "kind", ...fields}``; ``seq`` is
a monotone sequence number that survives ring wraparound (``dropped()``
tells how many events fell off the ring).  The ring participates in
``StreamServer.snapshot()`` via ``snapshot()``/``restore()`` and can be
dumped to JSON-lines with ``dump(path)``.
"""

from __future__ import annotations

import json
from collections import deque

__all__ = ["FlightRecorder"]

_SNAP_VERSION = 1


class FlightRecorder:
    def __init__(self, capacity=256):
        if capacity < 1:
            raise ValueError("flight recorder capacity must be >= 1")
        self.capacity = int(capacity)
        self._ring = deque(maxlen=self.capacity)
        self._seq = 0

    def record(self, tick, kind, **fields):
        event = {"seq": self._seq, "tick": int(tick), "kind": str(kind)}
        event.update(fields)
        self._seq += 1
        self._ring.append(event)
        return event

    def events(self, kind=None):
        """Events oldest-first, optionally filtered by ``kind``."""
        if kind is None:
            return list(self._ring)
        return [e for e in self._ring if e["kind"] == kind]

    def __len__(self):
        return len(self._ring)

    def dropped(self):
        """How many events have fallen off the ring."""
        return self._seq - len(self._ring)

    def dump(self, path):
        """Write the ring oldest-first as JSON lines; returns the count."""
        events = self.events()
        with open(path, "w") as f:
            for event in events:
                f.write(json.dumps(event, sort_keys=True) + "\n")
        return len(events)

    def snapshot(self):
        return {"version": _SNAP_VERSION, "capacity": self.capacity,
                "seq": self._seq, "events": self.events()}

    def restore(self, payload):
        if payload.get("version") != _SNAP_VERSION:
            raise ValueError(
                f"unsupported recorder snapshot version "
                f"{payload.get('version')!r}")
        self.capacity = int(payload["capacity"])
        self._ring = deque(payload["events"], maxlen=self.capacity)
        self._seq = int(payload["seq"])
