"""Always-on launch auditor for the one-launch-per-layer invariant.

The serving contract says: every batched scheduler tick issues exactly ONE
fused ``pallas_call`` per IMC layer for *all* ready slots — inference,
canary and learning traffic combined — and a gated (silent-fill) tick
issues ZERO.  Until now that invariant only lived in tests that
monkeypatch ``pl.pallas_call``.  The auditor promotes it to an opt-in
runtime interceptor around the fused-kernel launch sites.

Two layers of evidence are combined:

* **call accounting** — the scheduler wraps every batched compute call in
  :meth:`LaunchAuditor.region`, attributing it to ``(tick, cause)`` where
  ``cause`` is one of ``init`` / ``hop`` / ``replay`` / ``gate``.  Each
  compute call implies ``imc_layers`` fused launches (conv0 runs in jnp).
* **trace verification** — inside a region the auditor intercepts
  ``pl.pallas_call`` so freshly-traced work is counted for real.  Kernels
  are jitted (including the per-layer ``imc_fused`` inner jit, whose
  per-shape traces are cached across outer traces), so a region
  legitimately traces anywhere from zero (all cache hits) up to
  ``imc_layers`` fresh launches — but never more: a per-slot or per-hop
  kernel loop would trace ``B x imc_layers`` on a fresh trace, and a
  gate region must trace nothing at all.

Per-tick rules (checked in :meth:`end_tick`):

* at most one batched ``hop`` call;
* at most one ``gate`` fill;
* at most one ``init`` wave when the server batches admissions
  (``batch_init=True``; unbatched servers legitimately issue one B=1 init
  call per admission);
* at most one ``compiled`` whole-tick block, and never alongside
  interpreted calls in the same tick — the block IS the tick's entire
  compute (a K-tick block attributes to its first tick; the remaining
  K-1 ticks legitimately show zero calls);
* no region traces more than ``imc_layers`` fresh launches (``gate``
  traces zero; a ``compiled`` region's scanned body contains exactly one
  batched step, so its trace is bounded exactly like a ``hop``'s — the
  scan re-issues it per step at run time, which is the point).

``mode`` selects what a violation does: ``"flag"`` appends to
:attr:`violations` (and the server surfaces them through ``stats()``),
``"raise"`` raises :class:`LaunchAuditError` — the CI observability gate
runs the streaming equivalence slice in raise mode.
"""

from __future__ import annotations

from collections import deque
from contextlib import contextmanager

from jax.experimental import pallas as pl

__all__ = ["LaunchAuditor", "LaunchAuditError", "AUDIT_MODES"]

AUDIT_MODES = ("off", "flag", "raise")

# causes whose region launches fused kernels (a gate region launches none)
_COMPUTE_CAUSES = ("init", "hop", "replay")
# a compiled whole-tick block (repro.serving.compiled) also launches
# fused kernels — at most ``imc_layers`` on a fresh trace, because the
# scanned body contains exactly one stream_step: the scan re-issues it
# per step at RUN time, but the auditor sees the trace, where
# one-launch-per-layer is structural.  It is accounted separately from
# _COMPUTE_CAUSES because its per-tick rule differs: the block IS the
# tick's entire compute, so it must be the only call in its tick.
_LAUNCH_CAUSES = _COMPUTE_CAUSES + ("compiled",)


class LaunchAuditError(RuntimeError):
    """A tick broke the one-launch-per-IMC-layer contract."""


class LaunchAuditor:
    def __init__(self, imc_layers, mode="flag", batch_init=True,
                 history=256, device=None):
        if mode not in AUDIT_MODES:
            raise ValueError(f"audit mode must be one of {AUDIT_MODES}, "
                             f"got {mode!r}")
        if imc_layers < 1:
            raise ValueError("imc_layers must be >= 1")
        self.imc_layers = int(imc_layers)
        self.mode = mode
        # in a sharded deployment the one-launch-per-layer contract is
        # per-device: each device pool owns its own auditor, and every
        # violation / stats dict carries the device label so fleet
        # rollups attribute launches to the pool that issued them
        self.device = device
        self.batch_init = bool(batch_init)
        self.violations = []
        self._ticks = 0
        self._calls = {c: 0 for c in _LAUNCH_CAUSES + ("gate",)}
        self._traced = 0
        self._tick = None
        self._tick_calls = None
        self._history = deque(maxlen=history)
        self._max_hop_calls = 0

    # -- tick lifecycle ---------------------------------------------------

    def begin_tick(self, tick):
        self._tick = int(tick)
        self._tick_calls = []

    def end_tick(self):
        if self._tick is None:
            return
        counts = {c: 0 for c in _LAUNCH_CAUSES + ("gate",)}
        for call in self._tick_calls:
            counts[call["cause"]] += 1
        if counts["hop"] > 1:
            self._violate("hop", f"{counts['hop']} batched hop calls in "
                          f"one tick (max 1)")
        if counts["gate"] > 1:
            self._violate("gate", f"{counts['gate']} gate fills in one "
                          f"tick (max 1)")
        if self.batch_init and counts["init"] > 1:
            self._violate("init", f"{counts['init']} init waves in one "
                          f"batched-admission tick (max 1)")
        if counts["compiled"] > 1:
            self._violate("compiled", f"{counts['compiled']} compiled "
                          f"blocks in one tick (max 1)")
        if counts["compiled"] and any(counts[c] for c in
                                      ("init", "hop", "replay", "gate")):
            others = {c: counts[c] for c in ("init", "hop", "replay",
                                             "gate") if counts[c]}
            self._violate("compiled", f"compiled block co-issued with "
                          f"interpreted calls {others} in one tick (the "
                          f"block must be the tick's entire compute)")
        launches = sum(counts[c] for c in _LAUNCH_CAUSES) * self.imc_layers
        self._history.append({"tick": self._tick, "calls": counts,
                              "launches": launches,
                              "launches_per_layer":
                                  launches // self.imc_layers})
        self._max_hop_calls = max(self._max_hop_calls, counts["hop"])
        self._ticks += 1
        self._tick = None
        self._tick_calls = None

    # -- launch-site interception ----------------------------------------

    @contextmanager
    def region(self, cause):
        """Wrap one batched call site; attributes + trace-verifies it."""
        if cause not in self._calls:
            raise ValueError(f"unknown launch cause {cause!r}")
        traced = []
        real = pl.pallas_call

        def counting(*args, **kwargs):
            traced.append(kwargs.get("grid"))
            return real(*args, **kwargs)

        pl.pallas_call = counting
        try:
            yield
        finally:
            pl.pallas_call = real
        self._on_call(cause, len(traced))

    def _on_call(self, cause, traced):
        self._calls[cause] += 1
        self._traced += traced
        if self._tick_calls is not None:
            self._tick_calls.append(
                {"cause": cause, "traced": traced,
                 "launches": (self.imc_layers
                              if cause in _LAUNCH_CAUSES else 0)})
        if cause == "gate":
            if traced:
                self._violate(cause, f"gate fill traced {traced} pallas "
                              f"launches (must trace 0)")
        elif traced > self.imc_layers:
            self._violate(cause, f"{cause} call traced {traced} pallas "
                          f"launches in one batched call (max "
                          f"{self.imc_layers} IMC layers)")

    def _violate(self, cause, detail):
        violation = {"tick": self._tick, "cause": cause, "detail": detail}
        if self.device is not None:
            violation["device"] = self.device
        self.violations.append(violation)
        if self.mode == "raise":
            where = (f" [device {self.device}]"
                     if self.device is not None else "")
            raise LaunchAuditError(
                f"tick {self._tick}{where}: [{cause}] {detail}")

    # -- reporting --------------------------------------------------------

    def history(self):
        """Recent per-tick launch attribution, oldest-first."""
        return list(self._history)

    def stats(self):
        if self.device is not None:
            return dict(self._stats_base(), device=self.device)
        return self._stats_base()

    def _stats_base(self):
        return {
            "mode": self.mode,
            "imc_layers": self.imc_layers,
            "ticks": self._ticks,
            "calls": dict(self._calls),
            "traced_launches": self._traced,
            "max_hop_calls_per_tick": self._max_hop_calls,
            "violations": len(self.violations),
        }
