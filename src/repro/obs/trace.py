"""Chrome/Perfetto trace export for the serving tick.

``TraceBuilder`` collects complete-events ("ph":"X") — one span per tick
section (gate -> batched hop -> decision -> riders -> health/learn jobs)
— with wall-clock duration and analytical-energy attributes, and writes
the Chrome trace-event JSON that both ``chrome://tracing`` and
https://ui.perfetto.dev load directly.

Timestamps are microseconds relative to the earliest event start —
rebased at export time, since the span that *starts* first (the
whole-tick span) is recorded last within a tick — so traces are
deterministic up to wall-clock jitter and diff cleanly.  Spans carry
arbitrary ``args`` (tick, slots, uJ, cause...), which Perfetto shows in
the selection panel.
"""

from __future__ import annotations

import json

__all__ = ["TraceBuilder"]


class TraceBuilder:
    def __init__(self, process_name="repro.serving"):
        # events hold absolute perf_counter seconds in "ts"; to_chrome()
        # rebases everything onto the earliest start at export time
        self._events = []
        self._process_name = process_name

    def __len__(self):
        return len(self._events)

    def span(self, name, t_start_s, t_end_s, tid=0, **args):
        """Record a complete span; times are ``time.perf_counter()`` values."""
        self._events.append({
            "name": str(name),
            "ph": "X",
            "ts": float(t_start_s),
            "dur": max(0.0, (t_end_s - t_start_s) * 1e6),
            "pid": 0,
            "tid": int(tid),
            "args": args,
        })

    def counter(self, name, t_s, **values):
        """Record a counter track sample (Perfetto renders as a graph)."""
        self._events.append({
            "name": str(name),
            "ph": "C",
            "ts": float(t_s),
            "pid": 0,
            "args": values,
        })

    def instant(self, name, t_s, **args):
        """Record an instant marker (admission, alarm, swap...)."""
        self._events.append({
            "name": str(name),
            "ph": "i",
            "ts": float(t_s),
            "pid": 0,
            "tid": 0,
            "s": "p",
            "args": args,
        })

    def to_chrome(self):
        meta = [{
            "name": "process_name",
            "ph": "M",
            "pid": 0,
            "args": {"name": self._process_name},
        }]
        t0 = min((e["ts"] for e in self._events), default=0.0)
        events = [dict(e, ts=(e["ts"] - t0) * 1e6) for e in self._events]
        return {"traceEvents": meta + events,
                "displayTimeUnit": "ms"}

    def dump(self, path):
        """Write Chrome trace-event JSON; returns the span/event count."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, indent=1)
            f.write("\n")
        return len(self._events)
