"""Observability for the serving stack (``repro.obs``).

Four pieces, all optional except the registry:

* :class:`MetricsRegistry` — labelled counters/gauges/histograms; the
  serving classes' ``stats()`` dicts are views over one shared registry,
  and snapshots round-trip it (always on: it *is* the counter storage).
* :class:`FlightRecorder` — bounded ring of structured per-tick events,
  dumpable on alarm/crash and included in ``StreamServer.snapshot()``.
* :class:`LaunchAuditor` — opt-in runtime interceptor enforcing the
  one-fused-launch-per-IMC-layer-per-tick contract, with ``flag`` and
  ``raise`` modes.
* :class:`TraceBuilder` — per-tick spans exported as Chrome/Perfetto
  trace JSON.

``ObsConfig`` selects which extras a ``StreamServer`` turns on; the
default (all off) is bit-identical to — and within noise as fast as —
the pre-telemetry server.  ``ObsConfig.from_env()`` reads
``REPRO_OBS_AUDIT`` / ``REPRO_OBS_RECORDER`` / ``REPRO_OBS_TRACE`` so CI
can flip the auditor on without touching call sites.
"""

from __future__ import annotations

import os
from dataclasses import dataclass

from .audit import AUDIT_MODES, LaunchAuditError, LaunchAuditor
from .metrics import MetricsRegistry, counter_property
from .recorder import FlightRecorder
from .trace import TraceBuilder

__all__ = [
    "AUDIT_MODES",
    "FlightRecorder",
    "LaunchAuditError",
    "LaunchAuditor",
    "MetricsRegistry",
    "ObsConfig",
    "TraceBuilder",
    "counter_property",
]


@dataclass(frozen=True)
class ObsConfig:
    """What telemetry a ``StreamServer`` runs beyond the registry.

    recorder   flight-recorder ring capacity in events; 0 disables it.
    audit      launch-auditor mode: "off", "flag" or "raise".
    trace      collect per-tick Perfetto spans (dump via
               ``StreamServer.trace.dump(path)``).
    """

    recorder: int = 0
    audit: str = "off"
    trace: bool = False

    def __post_init__(self):
        if self.audit not in AUDIT_MODES:
            raise ValueError(
                f"audit must be one of {AUDIT_MODES}, got {self.audit!r}")
        if self.recorder < 0:
            raise ValueError("recorder capacity must be >= 0")

    @classmethod
    def from_env(cls):
        """Build from ``REPRO_OBS_*`` env vars (read at call time)."""
        return cls(
            recorder=int(os.environ.get("REPRO_OBS_RECORDER", "0")),
            audit=os.environ.get("REPRO_OBS_AUDIT", "off"),
            trace=os.environ.get("REPRO_OBS_TRACE", "") not in
            ("", "0", "false"),
        )
