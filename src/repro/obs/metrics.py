"""Unified metrics registry for the serving stack.

One process-local registry holds every counter/gauge/histogram emitted by
``StreamServer``, ``HealthMonitor``, ``CustomizationManager``, VAD gating
and the analytical energy model.  Cells are keyed by ``(name, labels)``
where ``labels`` is a sorted tuple of ``(key, value)`` pairs, so the same
metric name can be split by layer / stream / slot / health state.

Three cell kinds:

* **counter** — monotonically incremented via :meth:`MetricsRegistry.inc`
  (but directly settable, so snapshot ``restore()`` and the
  registry-backed ``StreamServer`` attributes can rewind it);
* **gauge** — last-write-wins via :meth:`MetricsRegistry.set_gauge`;
* **histogram** — running ``count/sum/min/max`` summary via
  :meth:`MetricsRegistry.observe` (no buckets: the serving tick is the
  only hot path and a four-field summary keeps overhead flat).

The registry is plain Python data — ``snapshot()`` returns a
JSON-serializable payload and ``restore()`` round-trips it, which is how
``StreamServer.snapshot()`` persists every counter without a
hand-maintained key list.  ``merge()`` folds another registry in (summing
counters, last-write gauges, pooling histogram summaries) for multi-server
aggregation.  ``prometheus_text()`` renders the whole registry in the
Prometheus text exposition format.
"""

from __future__ import annotations

from dataclasses import dataclass, field

__all__ = [
    "MetricsRegistry",
    "counter_property",
]

_SNAP_VERSION = 1

COUNTER = "counter"
GAUGE = "gauge"
HISTOGRAM = "histogram"


def _label_key(labels):
    return tuple(sorted(labels.items()))


@dataclass
class _Hist:
    count: int = 0
    total: float = 0.0
    min: float = field(default=float("inf"))
    max: float = field(default=float("-inf"))

    def observe(self, value):
        value = float(value)
        self.count += 1
        self.total += value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value

    def merge(self, other):
        if other.count == 0:
            return
        self.count += other.count
        self.total += other.total
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)

    def summary(self):
        if self.count == 0:
            return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                    "mean": 0.0}
        return {"count": self.count, "sum": self.total, "min": self.min,
                "max": self.max, "mean": self.total / self.count}


class MetricsRegistry:
    """Labelled counters/gauges/histograms behind one snapshotable map."""

    def __init__(self):
        # name -> kind; (name, labelkey) -> number | _Hist
        self._kinds = {}
        self._cells = {}

    # -- write paths ------------------------------------------------------

    def _kind(self, name, kind):
        have = self._kinds.setdefault(name, kind)
        if have != kind:
            raise ValueError(
                f"metric {name!r} already registered as {have}, not {kind}")

    def inc(self, name, value=1, **labels):
        self._kind(name, COUNTER)
        key = (name, _label_key(labels))
        self._cells[key] = self._cells.get(key, 0) + value

    def set_counter(self, name, value, **labels):
        """Directly set a counter cell (snapshot restore / reset paths)."""
        self._kind(name, COUNTER)
        self._cells[(name, _label_key(labels))] = value

    def set_gauge(self, name, value, **labels):
        self._kind(name, GAUGE)
        self._cells[(name, _label_key(labels))] = value

    def observe(self, name, value, **labels):
        self._kind(name, HISTOGRAM)
        key = (name, _label_key(labels))
        cell = self._cells.get(key)
        if cell is None:
            cell = self._cells[key] = _Hist()
        cell.observe(value)

    # -- read paths -------------------------------------------------------

    def value(self, name, default=0, **labels):
        """Cell value for an exact label set (histograms: summary dict)."""
        cell = self._cells.get((name, _label_key(labels)))
        if cell is None:
            return default
        if isinstance(cell, _Hist):
            return cell.summary()
        return cell

    def total(self, name):
        """Sum of a counter/gauge across every label set (0 if absent)."""
        out = 0
        for (n, _), cell in self._cells.items():
            if n == name and not isinstance(cell, _Hist):
                out += cell
        return out

    def labels(self, name):
        """Every label dict registered under ``name``."""
        return [dict(lk) for (n, lk) in self._cells if n == name]

    def collect(self):
        """Nested view: ``{name: {"kind":..., "cells": [{labels, value}]}}``."""
        out = {}
        for (name, lk), cell in sorted(self._cells.items(),
                                       key=lambda kv: kv[0]):
            entry = out.setdefault(
                name, {"kind": self._kinds[name], "cells": []})
            value = cell.summary() if isinstance(cell, _Hist) else cell
            entry["cells"].append({"labels": dict(lk), "value": value})
        return out

    # -- lifecycle --------------------------------------------------------

    def snapshot(self):
        cells = []
        for (name, lk), cell in sorted(self._cells.items(),
                                       key=lambda kv: kv[0]):
            if isinstance(cell, _Hist):
                payload = {"count": cell.count, "sum": cell.total,
                           "min": cell.min, "max": cell.max}
            else:
                payload = cell
            cells.append([name, self._kinds[name], list(map(list, lk)),
                          payload])
        return {"version": _SNAP_VERSION, "cells": cells}

    def restore(self, payload):
        if payload.get("version") != _SNAP_VERSION:
            raise ValueError(
                f"unsupported metrics snapshot version "
                f"{payload.get('version')!r}")
        self._kinds.clear()
        self._cells.clear()
        for name, kind, lk, value in payload["cells"]:
            self._kinds.setdefault(name, kind)
            key = (name, tuple((k, v) for k, v in lk))
            if kind == HISTOGRAM:
                cell = _Hist()
                cell.count = value["count"]
                cell.total = value["sum"]
                cell.min = value["min"]
                cell.max = value["max"]
                self._cells[key] = cell
            else:
                self._cells[key] = value

    def merge(self, other):
        """Fold ``other`` in: counters sum, gauges last-write, hists pool."""
        for (name, lk), cell in other._cells.items():
            kind = other._kinds[name]
            self._kind(name, kind)
            key = (name, lk)
            if kind == COUNTER:
                self._cells[key] = self._cells.get(key, 0) + cell
            elif kind == GAUGE:
                self._cells[key] = cell
            else:
                mine = self._cells.get(key)
                if mine is None:
                    mine = self._cells[key] = _Hist()
                mine.merge(cell)

    # -- export -----------------------------------------------------------

    def prometheus_text(self):
        """Prometheus text exposition (dots become underscores)."""
        lines = []
        by_name = {}
        for (name, lk), cell in sorted(self._cells.items(),
                                       key=lambda kv: kv[0]):
            by_name.setdefault(name, []).append((lk, cell))
        for name, cells in by_name.items():
            kind = self._kinds[name]
            pname = name.replace(".", "_").replace("-", "_")
            ptype = {COUNTER: "counter", GAUGE: "gauge",
                     HISTOGRAM: "summary"}[kind]
            lines.append(f"# TYPE {pname} {ptype}")
            for lk, cell in cells:
                lab = ",".join(f'{k}="{v}"' for k, v in lk)
                lab = "{" + lab + "}" if lab else ""
                if isinstance(cell, _Hist):
                    lines.append(f"{pname}_count{lab} {cell.count}")
                    lines.append(f"{pname}_sum{lab} {cell.total}")
                else:
                    lines.append(f"{pname}{lab} {cell}")
        return "\n".join(lines) + "\n"


def counter_property(name, doc=None, **labels):
    """A registry-backed attribute: ``self._steps += 1`` keeps working.

    Builds a property whose getter/setter read and write one counter cell
    of ``self._metrics``, so the serving classes keep their historical
    attribute API (``srv._steps``, ``srv._init_calls``, ...) while every
    count lives in — and snapshots through — the registry.
    """

    def fget(self):
        return self._metrics.value(name, **labels)

    def fset(self, value):
        self._metrics.set_counter(name, value, **labels)

    return property(fget, fset, doc=doc or f"registry counter {name!r}")
