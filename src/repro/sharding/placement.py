"""Stream placement policy for the device-sharded serving tier.

The KWS accelerator shards by *streams*, not tensors: every device runs a
complete slot-pool engine (``repro.serving.scheduler.StreamServer``) over
its own folded copy of the model, and the only cross-device decision is
WHERE a new stream lands.  That decision is this module: a small,
deterministic, host-side policy the router
(``repro.serving.shard.ShardedStreamServer``) consults once per new
stream — there is no per-hop cross-device traffic at all.

Determinism is load-bearing (the sharded==single-device equivalence
tests replay placements): given identical load views the policy always
picks the same device, and every tie is broken by a rotating round-robin
cursor rather than dict order or hashing.

Strategies:

* ``least_loaded`` (default) — most free slots first, then shortest
  admission queue, then (optionally) lowest recent speech duty so an
  all-silent pool absorbs new talkers before a busy one, then the
  round-robin cursor.
* ``round_robin`` — ignore load, rotate.  Useful as the degenerate
  baseline in placement tests.

This replaces the LM-era ``repro.sharding.policy`` PartitionSpec rules,
which were quarantined to ``repro.launch.mesh_policy`` (they shard
tensors across a training mesh; serving pins whole streams to devices).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

__all__ = ["PlacementConfig", "PlacementPolicy", "PoolLoad", "STRATEGIES"]

STRATEGIES = ("least_loaded", "round_robin")


@dataclasses.dataclass(frozen=True)
class PoolLoad:
    """One device pool's load view, as sampled by the router at
    placement time.  ``duty`` is the pool's recent speech duty cycle in
    [0, 1] (None when the pool has not computed any hops yet)."""
    free_slots: int
    queue_depth: int
    duty: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class PlacementConfig:
    strategy: str = "least_loaded"
    # tie-break equally-free pools on recent speech duty (quietest pool
    # wins): balances *compute*, not just slot occupancy, when VAD gating
    # makes slot counts a poor proxy for work
    duty_aware: bool = False

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(f"placement strategy must be one of "
                             f"{STRATEGIES}, got {self.strategy!r}")


class PlacementPolicy:
    """Deterministic stream->device chooser over ``n_devices`` pools."""

    def __init__(self, n_devices: int,
                 cfg: Optional[PlacementConfig] = None):
        if n_devices < 1:
            raise ValueError("n_devices must be >= 1")
        self.n_devices = int(n_devices)
        self.cfg = cfg if cfg is not None else PlacementConfig()
        self._rr = 0          # rotating tie-break cursor

    def place(self, loads: Sequence[PoolLoad]) -> int:
        """Pick the device index for one new stream.  ``loads`` must have
        one entry per device, in device order."""
        if len(loads) != self.n_devices:
            raise ValueError(f"expected {self.n_devices} load entries, "
                             f"got {len(loads)}")
        if self.cfg.strategy == "round_robin":
            d = self._rr % self.n_devices
            self._rr += 1
            return d

        def key(d: int):
            ld = loads[d]
            duty = (ld.duty if (self.cfg.duty_aware
                                and ld.duty is not None) else 0.0)
            # most free slots, then shortest queue, then quietest pool,
            # then closest-after-the-cursor (rotates across exact ties)
            return (-ld.free_slots, ld.queue_depth, duty,
                    (d - self._rr) % self.n_devices)

        d = min(range(self.n_devices), key=key)
        self._rr = (d + 1) % self.n_devices
        return d

    # -- snapshot support (rides the sharded snapshot bundle) -------------

    def snapshot(self) -> dict:
        return {"strategy": self.cfg.strategy,
                "duty_aware": self.cfg.duty_aware, "rr": self._rr}

    def restore(self, snap: dict) -> None:
        if snap["strategy"] != self.cfg.strategy:
            raise ValueError(f"placement strategy mismatch: snapshot has "
                             f"{snap['strategy']!r}, policy is "
                             f"{self.cfg.strategy!r}")
        self._rr = int(snap["rr"])
