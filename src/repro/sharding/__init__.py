"""Serving-tier sharding: deterministic stream placement across devices.

This package exports exactly what the sharded serving tier uses — the
placement policy consulted by ``repro.serving.shard.ShardedStreamServer``
when a new stream needs a device.  The LM-training PartitionSpec rules
that used to live here (``repro.sharding.policy``) were quarantined to
``repro.launch.mesh_policy``: they shard *tensors* across a training
mesh, while the KWS serving tier shards *streams* across per-device slot
pools and never moves tensors between devices at all.
"""

from repro.sharding.placement import (PlacementConfig, PlacementPolicy,
                                      PoolLoad, STRATEGIES)

__all__ = ["PlacementConfig", "PlacementPolicy", "PoolLoad", "STRATEGIES"]
