from repro.sharding.policy import MeshPolicy

__all__ = ["MeshPolicy"]
