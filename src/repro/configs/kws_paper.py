"""The paper's own model (reconstruction notes: DESIGN.md §4)."""
from repro.models.kws import KWSConfig

CONFIG = KWSConfig()          # full 16000-sample, 6-layer BNN
