"""xlstm-125m [arXiv:2405.04517]: 12 blocks d=768, 4 heads, mLSTM backbone
with sLSTM blocks interleaved (paper's [7:1]-style ratio -> 2 sLSTM)."""
from repro.configs.base import ArchConfig
from repro.models.xlstm import XLSTMConfig

CONFIG = ArchConfig(
    name="xlstm-125m", family="xlstm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, head_dim=192,
    d_ff=0, vocab_size=50304,
    xlstm=XLSTMConfig(d_model=768, n_heads=4),
    slstm_positions=(5, 11),
    supports_long_context=True,
)
