"""zamba2-1.2b [arXiv:2411.15242]: 38 Mamba2 layers d=2048 (ssm_state 64)
with a SHARED attention block (32H MHA) applied every 7 layers; d_ff=8192
(shared block MLP), vocab 32000."""
from repro.configs.base import ArchConfig
from repro.models.mamba2 import Mamba2Config

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32000, rope_theta=1e4,
    mamba=Mamba2Config(d_model=2048, d_state=64, head_dim=64),
    attn_every=7,
    supports_long_context=True,
)
