"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B]: 24L d=2048 16H (kv=16,
head_dim 128) d_ff(expert)=1408, vocab 151936, 60 routed top-4 + 4 shared."""
from repro.configs.base import ArchConfig
from repro.models.moe import MoEConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=5632, vocab_size=151936, qkv_bias=True, rope_theta=1e6,
    moe=MoEConfig(d_model=2048, d_ff_expert=1408, num_experts=60, top_k=4,
                  num_shared_experts=4, d_ff_shared=5632),
)
