"""starcoder2-15b [arXiv:2402.19173]: 40L d=6144 48H (GQA kv=4, head_dim 128)
d_ff=24576 (non-gated GeLU), vocab 49152, RoPE, biases."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, head_dim=128,
    d_ff=24576, vocab_size=49152, gated_mlp=False, qkv_bias=True,
    rope_theta=1e5,
)
