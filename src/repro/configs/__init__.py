from repro.configs.base import ARCH_IDS, SHAPES, ArchConfig, get_config

__all__ = ["ARCH_IDS", "SHAPES", "ArchConfig", "get_config"]
