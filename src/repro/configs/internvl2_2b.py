"""internvl2-2b [arXiv:2404.16821] ([vlm]): InternViT frontend (STUB patch
embeddings per assignment) + internlm2-1.8b LM: 24L d=2048 16H (GQA kv=8,
head_dim 128) d_ff=8192, vocab 92553."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=92553, rope_theta=1e6,
    frontend="vision", frontend_len=256,   # precomputed ViT patch embeddings
)
