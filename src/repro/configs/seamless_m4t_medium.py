"""seamless-m4t-medium [arXiv:2308.11596] ([audio]): enc-dec backbone,
12 enc + 12 dec layers, d=1024 16H (kv=16, head_dim 64) d_ff=4096,
vocab 256206.  Speech frontend is a ShapeDtypeStruct stub per assignment."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, n_encoder_layers=12, d_model=1024, n_heads=16,
    n_kv_heads=16, head_dim=64, d_ff=4096, vocab_size=256206,
    gated_mlp=False, rope_theta=1e4,
    frontend="audio", frontend_len=1024,   # precomputed speech frames
)
