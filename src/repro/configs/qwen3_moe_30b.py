"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B]: 48L d=2048 32H (GQA kv=4,
head_dim 128, QK-norm) d_ff(expert)=768, vocab 151936, MoE 128 experts top-8."""
from repro.configs.base import ArchConfig
from repro.models.moe import MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=6144,  # dense-equivalent (unused; MoE on every layer)
    vocab_size=151936, qk_norm=True, rope_theta=1e6,
    moe=MoEConfig(d_model=2048, d_ff_expert=768, num_experts=128, top_k=8),
)
