"""Architecture config schema + registry for the assigned architectures."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

from repro.models.mamba2 import Mamba2Config
from repro.models.moe import MoEConfig
from repro.models.xlstm import XLSTMConfig


def pad_vocab(v: int, multiple: int = 256) -> int:
    return -(-v // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | xlstm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    qkv_bias: bool = False
    qk_norm: bool = False
    gated_mlp: bool = True
    rope_theta: float = 1e6
    tie_embeddings: bool = False
    # MoE
    moe: Optional[MoEConfig] = None
    # SSM / hybrid
    mamba: Optional[Mamba2Config] = None
    xlstm: Optional[XLSTMConfig] = None
    slstm_positions: Tuple[int, ...] = ()     # xlstm: indices of sLSTM blocks
    attn_every: int = 0          # zamba2: shared attn block every k mamba layers
    # encoder-decoder
    n_encoder_layers: int = 0
    # modality frontend stubs ([audio]/[vlm]): embeddings provided by input_specs
    frontend: Optional[str] = None            # 'audio' | 'vision'
    frontend_len: int = 256                   # frames / patches
    # training behaviour
    remat: bool = True
    scan_layers: bool = True      # False: unroll (decode SPMD experiments)
    # notes for DESIGN/EXPERIMENTS (skips, applicability)
    supports_long_context: bool = False       # sub-quadratic decode?

    @property
    def vocab_padded(self) -> int:
        return pad_vocab(self.vocab_size)

    def attn_cfg(self):
        from repro.models.layers import AttnConfig
        return AttnConfig(d_model=self.d_model, n_heads=self.n_heads,
                          n_kv_heads=self.n_kv_heads, head_dim=self.head_dim,
                          qkv_bias=self.qkv_bias, qk_norm=self.qk_norm,
                          rope_theta=self.rope_theta)

    def reduced(self) -> "ArchConfig":
        """A smoke-test-sized config of the same family (CPU, 1 device)."""
        kw: Dict = dict(
            n_layers=min(self.n_layers, 2), d_model=128,
            n_heads=4, n_kv_heads=min(4, max(1, self.n_kv_heads)),
            head_dim=32, d_ff=256, vocab_size=512, frontend_len=8)
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, d_model=128, d_ff_expert=64, num_experts=4,
                top_k=2,
                d_ff_shared=(64 if self.moe.num_shared_experts else 0))
        if self.mamba is not None:
            kw["mamba"] = dataclasses.replace(self.mamba, d_model=128,
                                              d_state=16, head_dim=32)
            kw["n_layers"] = min(self.n_layers, 5)
        if self.xlstm is not None:
            kw["xlstm"] = dataclasses.replace(self.xlstm, d_model=128,
                                              n_heads=4)
            kw["n_layers"] = 4
            kw["slstm_positions"] = (3,)
        if self.attn_every:
            kw["n_layers"] = 5
            kw["attn_every"] = 2
        if self.n_encoder_layers:
            kw["n_encoder_layers"] = 2
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "qwen3-moe-30b-a3b", "qwen2-moe-a2.7b", "xlstm-125m",
    "seamless-m4t-medium", "internlm2-20b", "mistral-large-123b",
    "starcoder2-15b", "qwen2.5-14b", "zamba2-1.2b", "internvl2-2b",
)

_MODULE_OF = {
    "qwen3-moe-30b-a3b": "qwen3_moe_30b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "xlstm-125m": "xlstm_125m",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "internlm2-20b": "internlm2_20b",
    "mistral-large-123b": "mistral_large_123b",
    "starcoder2-15b": "starcoder2_15b",
    "qwen2.5-14b": "qwen2_5_14b",
    "zamba2-1.2b": "zamba2_1_2b",
    "internvl2-2b": "internvl2_2b",
    "kws-paper": "kws_paper",
}


def get_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULE_OF[arch_id]}")
    return mod.CONFIG


# Input shapes assigned to the LM family (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}
