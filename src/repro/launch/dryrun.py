import os
os.environ["XLA_FLAGS"] = (os.environ.get("_DRYRUN_BASE_XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import/initialization (device count locks on first
#   backend init).  Only the dry-run sees 512 placeholder devices.

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape) cell, build the production mesh,
shard parameters/optimizer/batch per repro.launch.mesh_policy, and prove the
distributed program is coherent:

    jax.jit(step, in_shardings=...).lower(**specs).compile()

must succeed on the 16x16 single-pod mesh AND the 2x16x16 multi-pod mesh.
Records memory_analysis / cost_analysis / parsed collective bytes into a
JSON result consumed by EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-14b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all --multi-pod --out r.json
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import numpy as np


def should_skip(cfg, shape_name: str) -> Optional[str]:
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return ("long_500k requires sub-quadratic attention; "
                f"{cfg.name} is full-attention (DESIGN.md §6)")
    return None


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             opt_overrides: Optional[Dict[str, Any]] = None,
             policy_opts: Optional[Dict[str, Any]] = None) -> Dict:
    """Lower + compile one cell; return the §Dry-run/§Roofline record.
    policy_opts: §Perf knobs forwarded to MeshPolicy (no_fsdp, ep_axis,
    serve_mode)."""
    from repro.configs.base import SHAPES, get_config
    from repro.launch import analysis
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import (abstract_opt_state, abstract_params,
                                    cache_specs, input_specs,
                                    make_decode_step, make_prefill_step,
                                    make_train_step)
    from repro.launch.mesh_policy import MeshPolicy

    cfg = get_config(arch)
    if opt_overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **opt_overrides)
    skip = should_skip(cfg, shape_name)
    if skip:
        return {"arch": arch, "shape": shape_name, "status": "skip",
                "reason": skip}

    kind = SHAPES[shape_name]["kind"]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mp = MeshPolicy(mesh, **(policy_opts or {}))
    policy = mp.activation_policy()
    t0 = time.time()

    with mesh:
        batch = input_specs(cfg, shape_name)
        batch_sh = mp.shardings(mp.batch_specs(batch))
        params = abstract_params(cfg)
        pspecs = mp.param_specs(params)
        params_sh = mp.shardings(pspecs)

        if kind == "train":
            opt_state = abstract_opt_state(cfg)
            opt_sh = mp.shardings(mp.opt_state_specs(opt_state, pspecs))
            step = make_train_step(cfg, policy)
            jitted = jax.jit(step,
                             in_shardings=(params_sh, opt_sh, batch_sh),
                             out_shardings=(params_sh, opt_sh, None))
            lowered = jitted.lower(params, opt_state, batch)
        elif kind == "prefill":
            step = make_prefill_step(cfg, policy)
            # explicit output shardings for the produced KV cache: without
            # them XLA materializes the cache replicated (zamba2 prefill_32k
            # peaked at 44GB/device from its 43GB unsharded attention cache)
            out_struct = jax.eval_shape(step, params, batch)
            cache_sh_out = mp.shardings(mp.cache_specs(out_struct[1]))
            out_sh = ((None, cache_sh_out)
                      if len(out_struct) == 2
                      else (None, cache_sh_out, None))
            jitted = jax.jit(step, in_shardings=(params_sh, batch_sh),
                             out_shardings=out_sh)
            lowered = jitted.lower(params, batch)
        else:  # decode
            caches = cache_specs(cfg, shape_name)
            cache_sh = mp.shardings(mp.cache_specs(caches))
            step = make_decode_step(cfg, policy)
            jitted = jax.jit(step,
                             in_shardings=(params_sh, cache_sh, batch_sh),
                             out_shardings=(None, cache_sh))
            lowered = jitted.lower(params, caches, batch)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    # ---- proofs + roofline inputs ----
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = {
                "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
                "output_bytes": getattr(ma, "output_size_in_bytes", None),
                "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
                "peak_bytes": getattr(ma, "peak_memory_in_bytes", None),
            }
    except Exception as e:  # CPU backend may not support it
        mem = {"error": str(e)}

    cost_flops = None
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        if ca:
            cost_flops = float(ca.get("flops", -1.0))
    except Exception:
        pass

    hlo = compiled.as_text()
    chips = int(np.prod(mesh.devices.shape))
    roof = analysis.build_roofline(
        cfg, shape_name, chips=chips, hlo_text=hlo, cost_flops=cost_flops,
        bytes_per_device=(mem or {}).get("peak_bytes"))
    coll = analysis.parse_collective_bytes(hlo,
                                           while_multiplier=cfg.n_layers)

    return {
        "arch": arch, "shape": shape_name, "status": "ok",
        "multi_pod": multi_pod, "chips": chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_analysis": mem,
        "cost_analysis_flops": cost_flops,
        "collectives": {k: v for k, v in coll.items()},
        "roofline": roof.as_dict(),
    }


def main() -> None:
    from repro.configs.base import ARCH_IDS, SHAPES

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp_flag in meshes:
                tag = f"{arch} x {shape} ({'2x16x16' if mp_flag else '16x16'})"
                try:
                    rec = run_cell(arch, shape, mp_flag)
                    status = rec["status"]
                    extra = ""
                    if status == "ok":
                        r = rec["roofline"]
                        extra = (f" dominant={r['dominant']}"
                                 f" frac={r['roofline_fraction']:.3f}"
                                 f" compile={rec['compile_s']}s")
                    print(f"[dryrun] {tag}: {status}{extra}", flush=True)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape,
                           "multi_pod": mp_flag, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                    print(f"[dryrun] {tag}: ERROR {type(e).__name__}: {e}",
                          flush=True)
                results.append(rec)
                if args.out:
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1)
    ok = sum(1 for r in results if r["status"] == "ok")
    skip = sum(1 for r in results if r["status"] == "skip")
    err = sum(1 for r in results if r["status"] == "error")
    print(f"[dryrun] done: {ok} ok, {skip} skip, {err} error")
    if err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
