"""Elastic scaling: re-mesh and reshard from checkpoint on node failure.

At 1000+-node scale the practical recovery path after losing a slice is:
  1. detect the new healthy device set,
  2. rebuild the mesh with the largest valid (data, model) factorization,
  3. restore the latest checkpoint and let jit re-shard parameters onto the
     new mesh (jax device_put with the new NamedShardings),
  4. resume the data pipeline from the checkpointed cursor (the token
     pipeline is stateless-resumable: batch_at_step(step)).

This module implements the mesh-refactorization + re-shard logic; the test
(tests/test_elastic.py) shrinks a host-platform mesh from 8 to 4 devices and
verifies training continues bit-consistently from the checkpoint.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


def best_mesh_shape(n_devices: int, model_parallel_target: int
                    ) -> Tuple[int, int]:
    """Largest (data, model) grid for the available devices: keep model
    parallelism at the largest divisor of the target that fits (TP degree
    changes need divisibility with head/ff dims, so prefer powers of two)."""
    model = min(model_parallel_target, n_devices)
    while model > 1 and (n_devices % model != 0):
        model //= 2
    return n_devices // model, model


def remesh(devices=None, model_parallel_target: int = 16) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    data, model = best_mesh_shape(n, model_parallel_target)
    dev_array = np.asarray(devices[:data * model]).reshape(data, model)
    return Mesh(dev_array, ("data", "model"))


def reshard_to(mesh: Mesh, tree, spec_tree):
    """Move a pytree (restored from checkpoint on host) onto a new mesh."""
    from jax.sharding import NamedSharding
    shardings = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: hasattr(x, "_partitions") or x is None
        or str(type(x).__name__) == "PartitionSpec")
    return jax.device_put(tree, shardings)
