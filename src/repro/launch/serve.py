"""Batched serving driver (deliverable b): prefill + decode loop with
continuous batching slots, usable on CPU with reduced configs and lowering
cleanly on the production mesh (the decode/prefill dry-run cells are this
server's step functions).

Run:  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b \
          --reduced --requests 6 --max-new 12
"""

from __future__ import annotations

import argparse
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.launch.steps import (init_params_for, make_decode_step,
                                make_prefill_step)
from repro.models import lm as LM


class Server:
    """Slot-based batched decoder (continuous batching light): fixed B slots;
    each slot holds one request's cache position; finished slots refill."""

    def __init__(self, arch: str, reduced: bool = True, slots: int = 4,
                 max_len: int = 128, seed: int = 0):
        self.cfg = get_config(arch)
        if reduced:
            self.cfg = self.cfg.reduced()
        if self.cfg.family == "encdec":
            raise NotImplementedError("serve driver targets decoder LMs")
        self.slots = slots
        self.max_len = max_len
        self.params = init_params_for(self.cfg, jax.random.PRNGKey(seed))
        self.decode = jax.jit(make_decode_step(self.cfg))
        self.caches = LM.init_cache(self.cfg, slots, max_len)
        self.positions = np.zeros(slots, np.int32)
        self.tokens = np.full((slots, 1), 1, np.int32)

    def submit_and_run(self, prompts: List[np.ndarray], max_new: int = 16):
        """Greedy-decode each prompt (prefill via step-by-step feed for
        simplicity at smoke scale; the prefill_32k dry-run cell lowers the
        bulk prefill path)."""
        outs = []
        for prompt in prompts:
            # reset slot 0 state by zeroing its cache slice would need
            # per-slot reset; smoke scale: fresh cache per request
            caches = LM.init_cache(self.cfg, 1, self.max_len)
            tok = jnp.asarray(prompt[None, :1].astype(np.int32))
            generated = []
            pos = 0
            for t in range(len(prompt) - 1):    # teacher-forced prefill
                _, caches = self.decode(self.params, caches,
                                        {"tokens": tok,
                                         "index": jnp.int32(pos)})
                pos += 1
                tok = jnp.asarray(prompt[None, t + 1:t + 2].astype(np.int32))
            for _ in range(max_new):
                logits, caches = self.decode(self.params, caches,
                                             {"tokens": tok,
                                              "index": jnp.int32(pos)})
                pos += 1
                nxt = int(jnp.argmax(logits[0, -1, :self.cfg.vocab_size]))
                generated.append(nxt)
                tok = jnp.asarray([[nxt]], jnp.int32)
            outs.append(generated)
        return outs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    srv = Server(args.arch, reduced=True)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, srv.cfg.vocab_size, size=rng.integers(4, 10))
               for _ in range(args.requests)]
    t0 = time.time()
    outs = srv.submit_and_run(prompts, max_new=args.max_new)
    dt = time.time() - t0
    total_tokens = sum(len(o) for o in outs)
    for i, o in enumerate(outs):
        print(f"[serve] req{i}: {o}")
    print(f"[serve] {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens / dt:.1f} tok/s, CPU smoke scale)")


if __name__ == "__main__":
    main()
