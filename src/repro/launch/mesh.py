"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state (smoke tests must keep seeing 1 CPU device; only dryrun.py sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax use).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 (data, model).  Multi-pod: 2x16x16 (pod, data,
    model) — DP across pods, FSDP over `data`, TP/EP over `model`."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small host-platform mesh for distribution tests."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
