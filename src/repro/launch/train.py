"""Distributed LM training driver (deliverable b: end-to-end example), with
the fault-tolerance loop: checkpoint/restart, simulated failure injection,
straggler-aware dispatch notes, and optional int8 gradient compression (the
paper's SGA generalized to the DP all-reduce — DESIGN.md §5).

Run (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b --reduced \
      --steps 50 --ckpt-dir /tmp/ckpt --ckpt-every 20
Auto-resumes from the latest checkpoint in --ckpt-dir.
"""

from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import get_config
from repro.data.tokens import TokenPipelineConfig, batch_at_step
from repro.launch.steps import (init_params_for, make_optimizer,
                                make_train_step)
from repro.models.layers import NO_SHARDING


def train_loop(arch: str, steps: int, *, reduced: bool = True,
               batch: int = 8, seq: int = 64, lr: float = 3e-4,
               ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
               fail_at: Optional[int] = None, log_every: int = 10,
               seed: int = 0):
    """Returns (params, final_metrics).  ``fail_at`` raises a simulated
    failure at that step (the fault-tolerance test restarts the loop and
    checks the resumed trajectory)."""
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    pipe = TokenPipelineConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                               global_batch=batch, seed=seed)
    optimizer = make_optimizer(cfg, lr=lr, steps=steps)
    step_fn = jax.jit(make_train_step(cfg, NO_SHARDING, optimizer))

    params = init_params_for(cfg, jax.random.PRNGKey(seed))
    opt_state = optimizer.init(params)
    start_step = 0
    rng_key = jax.random.PRNGKey(seed + 1)

    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    if ckpt is not None:
        restored = ckpt.restore(params, opt_state)
        if restored is not None:
            params, opt_state, meta = restored
            start_step = meta["step"]
            rng_key = jnp.asarray(meta["rng_key"], jnp.uint32)
            print(f"[train] resumed from step {start_step}", flush=True)

    t0 = time.time()
    metrics = {}
    for step in range(start_step, steps):
        if fail_at is not None and step == fail_at:
            raise RuntimeError(f"simulated node failure at step {step}")
        tokens, labels = batch_at_step(pipe, step)
        model_batch = {"tokens": jnp.asarray(tokens.astype(np.int32)),
                       "labels": jnp.asarray(labels.astype(np.int32))}
        if cfg.family in ("vlm", "encdec"):
            model_batch["frames"] = jnp.ones(
                (batch, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
        params, opt_state, metrics = step_fn(params, opt_state, model_batch)
        if (step + 1) % log_every == 0:
            print(f"[train] step {step + 1} loss "
                  f"{float(metrics['loss']):.4f} "
                  f"({time.time() - t0:.0f}s)", flush=True)
        if ckpt is not None and (step + 1) % ckpt_every == 0:
            ckpt.save(step + 1, params, opt_state, data_step=step + 1,
                      rng_key=rng_key)
    return params, {k: float(v) for k, v in metrics.items()}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args()
    train_loop(args.arch, args.steps, reduced=args.reduced,
               batch=args.batch, seq=args.seq, lr=args.lr,
               ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
               fail_at=args.fail_at)
    print("[train] done")


if __name__ == "__main__":
    main()
