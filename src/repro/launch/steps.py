"""jit-able train / prefill / decode step builders + ShapeDtypeStruct input
specs for every (architecture x shape) cell.  Used by the dry-run, the
trainer and the server."""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ArchConfig
from repro.models import encdec as ED
from repro.models import lm as LM
from repro.models.layers import NO_SHARDING, ShardingPolicy
from repro.optim import adam, cosine_schedule

COMPUTE = jnp.bfloat16


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins — no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape_name: str) -> Dict[str, Any]:
    """Model inputs for one shape cell, as ShapeDtypeStructs."""
    sh = SHAPES[shape_name]
    b, s, kind = sh["global_batch"], sh["seq_len"], sh["kind"]
    sds = jax.ShapeDtypeStruct
    if cfg.family == "encdec":
        if kind == "train":
            return {"frames": sds((b, cfg.frontend_len, cfg.d_model),
                                  COMPUTE),
                    "tokens": sds((b, s), jnp.int32),
                    "labels": sds((b, s), jnp.int32)}
        if kind == "prefill":
            return {"frames": sds((b, cfg.frontend_len, cfg.d_model),
                                  COMPUTE),
                    "tokens": sds((b, s), jnp.int32)}
        # decode: one token against a full self-attn cache + encoder memory
        return {"tokens": sds((b, 1), jnp.int32),
                "memory": sds((b, cfg.frontend_len, cfg.d_model), COMPUTE),
                "index": sds((), jnp.int32)}
    if cfg.family == "vlm" and kind == "train":
        return {"frames": sds((b, cfg.frontend_len, cfg.d_model), COMPUTE),
                "tokens": sds((b, s), jnp.int32),
                "labels": sds((b, s), jnp.int32)}
    if kind == "train":
        return {"tokens": sds((b, s), jnp.int32),
                "labels": sds((b, s), jnp.int32)}
    if kind == "prefill":
        spec = {"tokens": sds((b, s), jnp.int32)}
        if cfg.family == "vlm":
            spec["frames"] = sds((b, cfg.frontend_len, cfg.d_model), COMPUTE)
        return spec
    # decode
    return {"tokens": sds((b, 1), jnp.int32),
            "index": sds((), jnp.int32)}


def cache_specs(cfg: ArchConfig, shape_name: str):
    """ShapeDtypeStructs of the decode cache for this cell."""
    sh = SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    if cfg.family == "encdec":
        return jax.eval_shape(lambda: ED.init_dec_cache(cfg, b, s))
    return jax.eval_shape(lambda: LM.init_cache(cfg, b, s))


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------


def make_optimizer(cfg: ArchConfig, lr: float = 3e-4, steps: int = 10_000):
    return adam(cosine_schedule(lr, steps, warmup_steps=200))


def make_train_step(cfg: ArchConfig, policy: ShardingPolicy = NO_SHARDING,
                    optimizer=None):
    optimizer = optimizer or make_optimizer(cfg)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            if cfg.family == "encdec":
                logits = ED.forward_encdec(p, cfg, batch["frames"],
                                           batch["tokens"], policy)
                loss = LM.lm_loss(logits, batch["labels"], cfg.vocab_size)
                return loss, loss
            prefix = batch.get("frames") if cfg.family == "vlm" else None
            logits, aux = LM.forward_lm(p, cfg, batch["tokens"], policy,
                                        prefix_embeds=prefix)
            offset = prefix.shape[1] if prefix is not None else 0
            loss = LM.lm_loss(logits, batch["labels"], cfg.vocab_size,
                              label_offset=offset)
            return loss + aux, loss

        (total, loss), grads = jax.value_and_grad(loss_fn,
                                                  has_aux=True)(params)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, {"loss": loss, "total": total}

    return train_step


def make_prefill_step(cfg: ArchConfig,
                      policy: ShardingPolicy = NO_SHARDING):
    if cfg.family == "encdec":
        def prefill_step(params, batch):
            logits, cache, memory = ED.prefill_encdec(
                params, cfg, batch["frames"], batch["tokens"], policy)
            return logits, cache, memory
        return prefill_step

    def prefill_step(params, batch):
        prefix = batch.get("frames") if cfg.family == "vlm" else None
        logits, caches = LM.prefill(params, cfg, batch["tokens"], policy,
                                    prefix_embeds=prefix)
        return logits, caches
    return prefill_step


def make_decode_step(cfg: ArchConfig,
                     policy: ShardingPolicy = NO_SHARDING):
    if cfg.family == "encdec":
        def decode_fn(params, caches, batch):
            return ED.decode_step_encdec(params, cfg, batch["tokens"],
                                         batch["memory"], caches,
                                         batch["index"], policy)
        return decode_fn

    def decode_fn(params, caches, batch):
        return LM.decode_step(params, cfg, batch["tokens"], caches,
                              batch["index"], policy)
    return decode_fn


def init_params_for(cfg: ArchConfig, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    if cfg.family == "encdec":
        return ED.init_encdec(key, cfg)
    return LM.init_lm(key, cfg)


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(lambda: init_params_for(cfg))


def abstract_opt_state(cfg: ArchConfig, optimizer=None):
    optimizer = optimizer or make_optimizer(cfg)
    params = abstract_params(cfg)
    return jax.eval_shape(optimizer.init, params)
