"""Per-architecture PartitionSpec policy for the LM production mesh.

Quarantined here from ``repro.sharding`` (which now holds the *serving*
placement machinery — see repro.sharding.placement and
repro.serving.shard): these tensor-layout rules are specific to the LM
training/decoding stack under ``repro.launch`` and are consumed only by
the dry-run driver and the distribution tests.  The KWS serving tier
shards by *stream placement* (whole streams pinned to per-device slot
pools), not by tensor partitioning, so none of these PartitionSpec rules
apply there.

Layout (DESIGN.md §5):
  * batch over ("pod","data") — DP across pods, plain DP within pod;
  * parameters + optimizer state sharded over "data" (FSDP/ZeRO-3) AND over
    "model" (TP) — column-parallel up-projections, row-parallel
    down-projections, expert-parallel MoE stacks, vocab-parallel embeddings;
  * KV caches: batch over "data", sequence over "model" (decode SP);
  * every `model`/`data` assignment is guarded by divisibility — anything
    that doesn't divide evenly is replicated on that axis (correct, just
    less sharded; XLA propagates the rest).

All functions return PartitionSpec pytrees usable as jit in_shardings /
out_shardings on the production mesh.
"""

from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.layers import ShardingPolicy

# natural (unstacked) trailing-rank and spec templates per parameter name.
# 'C' = column-parallel last dim, 'R' = row-parallel first-of-two,
# 'E' = expert-stacked 3D, 'V' = vocab-parallel, '-' = replicate.
_RULES = [
    (r"(wq|wk|wv|w_up|w_gate|up_l|up_r|in_proj|w_gates|ffn_up|w_if)/w$", "C"),
    (r"(wo|w_down|down|out_proj|ffn_down)/w$", "R"),
    (r"(wq|wk|wv|wo|w_up|w_gate|w_down|up_l|up_r|in_proj|out_proj|"
     r"w_gates|ffn_up|ffn_down|down|w_if)/b$", "B"),
    (r"router/w$", "Crep"),       # router: small, replicate cols
    (r"router/b$", "-"),
    (r"moe/w_gate$", "E"), (r"moe/w_up$", "E"), (r"moe/w_down$", "Ed"),
    (r"shared/w_gate/w$", "C"), (r"shared/w_up/w$", "C"),
    (r"shared/w_down/w$", "R"), (r"shared_gate/w$", "Crep"),
    (r"conv_w$", "Conv"), (r"conv_b$", "Bc"),
    (r"r_gates$", "-"),
    (r"embed$", "V"), (r"unembed$", "Vt"),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


class MeshPolicy:
    """Factory for sharding specs on a given mesh.

    §Perf knobs (EXPERIMENTS.md):
      no_fsdp     — replicate params over `data` (small models: the FSDP
                    all-gather dwarfs compute; DP grad sync remains);
      ep_axis     — "model" (baseline) or "data": MoE experts stationary on
                    the data axis, expert FFN TP over model;
      serve_mode  — weight-stationary inference: params 2D-sharded, batch
                    replicated, KV cache (seq over data, head_dim over
                    model); per-matmul collectives are activation-sized
                    (the paper's in-SRAM weights-never-move principle).
    """

    def __init__(self, mesh: Mesh, *, no_fsdp: bool = False,
                 ep_axis: str = "model", serve_mode: bool = False,
                 pure_dp: bool = False):
        self.mesh = mesh
        names = mesh.axis_names
        self.has_pod = "pod" in names
        self.data_axes: Tuple[str, ...] = (("pod", "data") if self.has_pod
                                           else ("data",))
        self.model_axis = "model" if "model" in names else None
        self.fsdp_axis = ("data" if ("data" in names and not no_fsdp)
                          else None)
        if pure_dp:
            # small models: model parallelism on a 16-way axis costs more in
            # activation reshards than it saves; fold the model axis into
            # data parallelism and replicate params (§Perf hillclimb 1)
            self.data_axes = self.data_axes + (("model",)
                                               if "model" in names else ())
            self.model_axis = None
            self.fsdp_axis = None
        self.ep_axis_name = ep_axis
        self.serve_mode = serve_mode
        self.sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    # -- helpers ----------------------------------------------------------
    def _fits(self, dim: int, axis) -> bool:
        if axis is None:
            return False
        n = (np.prod([self.sizes[a] for a in axis])
             if isinstance(axis, tuple) else self.sizes[axis])
        return dim % int(n) == 0

    def _m(self, dim: int):
        return self.model_axis if self._fits(dim, self.model_axis) else None

    def _f(self, dim: int):
        return self.fsdp_axis if self._fits(dim, self.fsdp_axis) else None

    def _b(self, dim: int):
        """Batch axes (largest prefix of data_axes that divides dim)."""
        if self._fits(dim, self.data_axes):
            return self.data_axes
        if self.has_pod and self._fits(dim, ("data",)):
            return ("data",)
        return None

    def activation_policy(self) -> ShardingPolicy:
        return ShardingPolicy(data_axes=self.data_axes,
                              model_axis=self.model_axis,
                              fsdp_axis=self.fsdp_axis, enabled=True,
                              axis_sizes=self.sizes,
                              ep_axis=self.ep_axis_name,
                              serve_mode=self.serve_mode)

    # -- parameter specs ---------------------------------------------------
    def _leaf_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        kind = None
        for pat, k in _RULES:
            if re.search(pat, path):
                kind = k
                break
        nd = len(shape)

        def pad(spec_tail):
            """prepend None for stacked leading dims"""
            return P(*([None] * (nd - len(spec_tail)) + list(spec_tail)))

        if kind == "C":
            return pad([self._f(shape[-2]), self._m(shape[-1])])
        if kind == "R":
            return pad([self._m(shape[-2]), self._f(shape[-1])])
        if kind in ("B", "Bc"):
            return pad([self._m(shape[-1])])
        if kind == "Crep":
            return pad([self._f(shape[-2]), None])
        if kind == "E":      # (E, D, F)
            if self.ep_axis_name == "data" and self._fits(shape[-3], "data"):
                # experts stationary over data, FFN TP over model: no
                # per-step expert weight gathers (§Perf hillclimb 2)
                return pad(["data", None, self._m(shape[-1])])
            if self._m(shape[-3]):   # baseline: experts over model, FSDP D
                return pad([self._m(shape[-3]), self._f(shape[-2]), None])
            # expert count not divisible (qwen2-moe: 60 on a 16-way axis):
            # shard the ffn dim over model instead — otherwise 12B of expert
            # weights (+Adam moments) are only fsdp-sharded (9.4GB/device)
            return pad([None, self._f(shape[-2]), self._m(shape[-1])])
        if kind == "Ed":     # (E, F, D)
            if self.ep_axis_name == "data" and self._fits(shape[-3], "data"):
                return pad(["data", self._m(shape[-2]), None])
            if self._m(shape[-3]):
                return pad([self._m(shape[-3]), None, self._f(shape[-2])])
            return pad([None, self._m(shape[-2]), self._f(shape[-1])])
        if kind == "Conv":   # (K, C)
            return pad([None, self._m(shape[-1])])
        # Embedding table: shard the FEATURE dim over model — a token gather
        # from a d-sharded table is local per shard.  (Vocab-sharding the
        # table turns lookup/scatter into XLA's replicate-then-repartition
        # fallback: ~120GB/step of full-vocab fp32 traffic at train_4k.)
        if kind == "V":      # (Vpad, D)
            return P(None, self._m(shape[1]))
        # Unembed: vocab-parallel (the logits matmul and the fused CE loss
        # keep every (B,S,V) intermediate vocab-sharded; D over fsdp would
        # conflict with batch-over-data — see lm_loss docstring).
        if kind == "Vt":     # (D, Vpad)
            return P(None, self._m(shape[1]))
        # default: replicate scalars/vectors; FSDP the biggest dim of big
        # tensors if possible
        if nd >= 2 and shape[-1] >= 1024 and self._f(shape[-1]):
            return pad([None, self._f(shape[-1])])
        return P()

    def param_specs(self, params) -> Any:
        return jax.tree_util.tree_map_with_path(
            lambda path, leaf: self._leaf_spec(_path_str(path), leaf.shape),
            params)

    def opt_state_specs(self, opt_state, param_specs) -> Any:
        """Adam moments shard like params; step counters replicate."""
        def map_like(x):
            if isinstance(x, type(None)):
                return None
            return x
        # OptState(step, mu, nu) where mu/nu mirror params (or None)
        from repro.optim.optimizers import OptState
        mu = param_specs if opt_state.mu is not None else None
        nu = param_specs if opt_state.nu is not None else None
        return OptState(step=P(), mu=mu, nu=nu)

    # -- data / cache specs -------------------------------------------------
    def batch_specs(self, batch_shape_tree) -> Any:
        """tokens/labels (B, S) -> P(batch_axes, None); frames (B,S,D)."""
        def spec(x):
            if len(x.shape) == 0:               # scalars (decode index)
                return P()
            b = self._b(x.shape[0])
            return P(*([b] + [None] * (len(x.shape) - 1)))
        return jax.tree_util.tree_map(spec, batch_shape_tree)

    def kv_cache_spec(self, shape) -> P:
        """(L, B, S, H, hd): batch->data, seq->model (decode SP).
        Serve mode: seq->data, head_dim->model (weight-stationary TP)."""
        # batch over data, seq over model (decode SP) — in serve mode the
        # cache WRITE uses the masked-where form (no DUS fallback)
        return P(None, self._b(shape[1]), self._m(shape[2]), None, None)

    def cache_specs(self, cache_tree) -> Any:
        def spec(x):
            s = x.shape
            if len(s) == 5:                     # stacked attention kv
                return self.kv_cache_spec(s)
            if len(s) == 4:                     # (L,B,K-1,C) conv or (B,H,d,d)
                return P(None, self._b(s[1]), None, self._m(s[-1]))
            if len(s) == 3:                     # (L?,B,C)
                return P(None, self._b(s[1]), None)
            if len(s) == 2:                     # (B, D) slstm state
                return P(self._b(s[0]), None)
            return P(*([None] * len(s)))
        return jax.tree_util.tree_map(spec, cache_tree)

    def shardings(self, spec_tree):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s), spec_tree,
            is_leaf=lambda x: isinstance(x, P))
