"""Pipeline parallelism (GPipe-style) as a library feature.

The assigned production mesh (data x model) covers all 40 cells with
FSDP x TP, but at 1000+-node scale a pipeline axis bounds the FSDP
all-gather ring size.  This module provides a `pipeline_apply` combinator:
layers are split into S stages along a `pipe` mesh axis; microbatches
stream through stages via `jax.lax.ppermute` inside shard_map, giving the
classic GPipe schedule (S + M - 1 ticks for M microbatches).

Quarantined under ``repro.launch`` with the rest of the LM stack (it was
written for the LM mesh, not the KWS serving tier); compose with
repro.launch.mesh_policy by adding a "pipe" axis to the mesh and passing
stage-sharded stacked params.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(layer_fn: Callable, mesh: Mesh, n_microbatches: int,
                   axis: str = "pipe"):
    """Returns fn(stage_params, x) running a GPipe pipeline over ``axis``.

    layer_fn(params_for_stage, x_microbatch) -> x_microbatch applies ONE
    stage's layers.  stage_params leaves are stacked over stages (leading
    dim = n_stages, sharded over ``axis``).  x: (batch, ...) with
    batch % n_microbatches == 0.
    """
    n_stages = mesh.shape[axis]

    def stage_program(params, x):
        # params: this stage's slice (leading dim 1); x: full batch view
        params = jax.tree_util.tree_map(lambda a: a[0], params)
        stage = jax.lax.axis_index(axis)
        mb = x.reshape(n_microbatches, -1, *x.shape[1:])
        n_ticks = n_stages + n_microbatches - 1
        buf = jnp.zeros_like(mb[0])
        outs = jnp.zeros_like(mb)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any)
            incoming = jnp.where(t < n_microbatches,
                                 mb[jnp.minimum(t, n_microbatches - 1)],
                                 jnp.zeros_like(buf))
            x_in = jnp.where(stage == 0, incoming, buf)
            y = layer_fn(params, x_in)
            # pass to the next stage
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            # last stage emits microbatch (t - (n_stages - 1))
            emit_idx = t - (n_stages - 1)
            valid = ((emit_idx >= 0) & (emit_idx < n_microbatches)
                     & (stage == n_stages - 1))
            outs = jax.lax.cond(
                valid,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(emit_idx, 0), 0),
                lambda o: o, outs)
            return nxt, outs

        _, outs = jax.lax.fori_loop(0, n_ticks, tick, (buf, outs))
        # only the last stage holds the outputs; replicate to all stages
        outs = jax.lax.psum(outs, axis)
        return outs.reshape(x.shape)

    pspec = jax.tree_util.tree_map(lambda _: P(axis), {"_": 0})["_"]

    def run(stage_params, x):
        in_specs = (jax.tree_util.tree_map(lambda _: P(axis), stage_params),
                    P())
        return shard_map(stage_program, mesh=mesh, in_specs=in_specs,
                         out_specs=P(), check_rep=False)(stage_params, x)

    return run
