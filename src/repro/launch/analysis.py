"""Roofline analysis from the compiled dry-run artifact (DESIGN.md §8).

Three terms per (arch x shape x mesh), in seconds:

  compute    = FLOPs / (chips * 197e12)           [bf16 peak, TPU v5e]
  memory     = HBM bytes / (chips * 819e9)
  collective = collective bytes / (chips * 50e9)  [~ICI link bw per chip]

FLOPs/HBM-bytes use exact parameter counts (jax.eval_shape) + standard
analytic activation/attention terms: XLA's cost_analysis does not multiply
while-loop (scan) bodies by their trip count, so the compiled counter
underestimates deep stacks; we therefore use the analytic terms as primary
and report cost_analysis alongside (EXPERIMENTS.md notes the comparison).

Collective bytes are parsed from the post-SPMD HLO text: operand sizes of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
with ops inside while bodies multiplied by the layer-scan trip count.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

import numpy as np

from repro.configs.base import SHAPES, ArchConfig

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link / chip

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "c64": 8,
                "s16": 2, "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[128,4096]' -> bytes.  Tuple shapes: sum components."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _parse_structure(hlo_text: str):
    """Walk the HLO module: per-computation collective bytes, while edges
    (parent_comp -> body/cond computations) with trip counts recovered from
    the loop condition's compare-against-constant, and call edges."""
    comp_coll: Dict[str, Dict[str, float]] = {}
    comp_consts: Dict[str, list] = {}
    while_edges = []               # (parent, body, cond)
    call_edges = []                # (parent, callee)
    current = "__top__"
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?([\w\.\-]+)\s*\([^)]*\)\s*->", ls)
        if m and ls.endswith("{"):
            current = m.group(1)
            continue
        mw = re.search(r"=.*\bwhile\(", ls)
        if mw:
            mb = re.search(r"body=%?([\w\.\-]+)", ls)
            mc = re.search(r"condition=%?([\w\.\-]+)", ls)
            if mb:
                while_edges.append((current, mb.group(1),
                                    mc.group(1) if mc else None))
            continue
        for pat in (r"to_apply=%?([\w\.\-]+)",
                    r"true_computation=%?([\w\.\-]+)",
                    r"false_computation=%?([\w\.\-]+)",
                    r"branch_computations=\{%?([\w\.\-]+)"):
            for mm in re.finditer(pat, ls):
                call_edges.append((current, mm.group(1)))
        mk = re.match(r"%?[\w\.\-]+ = s32\[\] constant\((\d+)\)", ls)
        if mk:
            comp_consts.setdefault(current, []).append(int(mk.group(1)))
        for op in COLLECTIVE_OPS:
            if (f"= {op}" in ls or f" {op}(" in ls
                    or f"{op}-start" in ls):
                rhs = ls.split(" = ", 1)
                shape_src = rhs[1] if len(rhs) == 2 else ls
                nbytes = _shape_bytes(shape_src.split("(")[0])
                comp_coll.setdefault(current, {}).setdefault(op, 0.0)
                comp_coll[current][op] += nbytes
                break
    return comp_coll, comp_consts, while_edges, call_edges


def parse_collective_bytes(hlo_text: str,
                           while_multiplier: int = 1) -> Dict[str, float]:
    """Per-device collective bytes, with while/scan bodies multiplied by
    their trip counts.

    Trip counts are recovered from each loop condition's
    compare-to-constant; if none is found, ``while_multiplier`` (the layer
    count) is used as the fallback.  Multipliers compose through nested
    loops and call edges (fixpoint propagation)."""
    comp_coll, comp_consts, while_edges, call_edges = _parse_structure(
        hlo_text)

    trip_of_body: Dict[str, int] = {}
    for parent, body, cond in while_edges:
        trip = None
        if cond and cond in comp_consts:
            cands = [c for c in comp_consts[cond] if c > 1]
            if cands:
                trip = max(cands)
        trip_of_body[body] = trip if trip is not None else while_multiplier

    mult: Dict[str, float] = {"__top__": 1.0}
    # fixpoint: propagate multipliers down while/call edges
    for _ in range(12):
        changed = False
        for parent, body, cond in while_edges:
            pm = mult.get(parent, None)
            if pm is None:
                continue
            m_new = pm * trip_of_body[body]
            if mult.get(body) != m_new:
                mult[body] = m_new
                changed = True
            if cond and mult.get(cond) != m_new:
                mult[cond] = m_new
        for parent, callee in call_edges:
            pm = mult.get(parent)
            if pm is None:
                continue
            if mult.get(callee, 0) < pm:
                mult[callee] = pm
                changed = True
        if not changed:
            break

    totals = {k: 0.0 for k in COLLECTIVE_OPS}
    for comp, per_op in comp_coll.items():
        m = mult.get(comp, 1.0)
        for op, b in per_op.items():
            totals[op] += b * m
    totals["total"] = sum(totals[k] for k in COLLECTIVE_OPS)
    return totals


# ---------------------------------------------------------------------------
# Analytic FLOPs / bytes
# ---------------------------------------------------------------------------


def param_counts(cfg: ArchConfig) -> Dict[str, float]:
    """Exact parameter counts from abstract init (no allocation)."""
    import jax
    from repro.launch.steps import abstract_params
    params = jax.eval_shape(lambda: abstract_params(cfg)) \
        if not hasattr(abstract_params(cfg), "keys") else abstract_params(cfg)
    leaves = jax.tree_util.tree_leaves(params)
    total = float(sum(np.prod(l.shape) for l in leaves))
    embed = float(np.prod(params["embed"].shape))
    if "unembed" in params:
        embed += float(np.prod(params["unembed"].shape))
    n_active = total
    if cfg.moe is not None:
        moe_leaves = 0.0
        for seg in params["segments"]:
            if isinstance(seg, dict) and "moe" in str(
                    jax.tree_util.tree_structure(seg)):
                pass
        # routed-expert params: (w_gate + w_up + w_down) per expert
        e, d, f = (cfg.moe.num_experts, cfg.moe.d_model, cfg.moe.d_ff_expert)
        routed = cfg.n_layers * e * (3 * d * f)
        n_active = total - routed * (1.0 - cfg.moe.top_k / e)
    return {"total": total, "embed": embed, "active": n_active,
            "active_nonembed": n_active - embed}


def _mixer_flops_per_token(cfg: ArchConfig, context: int) -> float:
    """Attention/SSM flops per token per layer (fwd), excluding projections
    (those are in the parameter term)."""
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        d_attn = cfg.n_heads * cfg.head_dim
        return 2.0 * 2.0 * context * d_attn        # QK^T + AV
    if cfg.family == "xlstm":
        x = cfg.xlstm
        c = 128.0
        dk = dv = x.head_dim
        return 2.0 * x.n_heads * (c * (dk + dv) + 3 * dk * dv)
    if cfg.family == "hybrid":
        mb = cfg.mamba
        c = 128.0
        dk, dv, h = mb.d_state, mb.head_dim, mb.n_heads
        return 2.0 * h * (c * (dk + dv) + 3 * dk * dv)
    return 0.0


def analytic_flops(cfg: ArchConfig, shape_name: str) -> Dict[str, float]:
    sh = SHAPES[shape_name]
    b, s, kind = sh["global_batch"], sh["seq_len"], sh["kind"]
    pc = param_counts(cfg)
    n = pc["active_nonembed"]
    d = cfg.d_model

    if kind == "train":
        tokens = b * (s + (cfg.frontend_len if cfg.family in ("vlm",)
                           else 0))
        base = 6.0 * n * tokens                     # fwd+bwd matmuls
        mixer = 3.0 * tokens * cfg.n_layers * _mixer_flops_per_token(
            cfg, context=s / 2)
        embed_flops = 6.0 * tokens * d * cfg.vocab_padded
        return {"flops": base + mixer + embed_flops, "tokens": tokens,
                "model_flops": 6.0 * pc["active"] * tokens}
    if kind == "prefill":
        tokens = b * s
        base = 2.0 * n * tokens
        mixer = tokens * cfg.n_layers * _mixer_flops_per_token(
            cfg, context=s / 2)
        embed_flops = 2.0 * tokens * d * cfg.vocab_padded
        return {"flops": base + mixer + embed_flops, "tokens": tokens,
                "model_flops": 2.0 * pc["active"] * tokens}
    # decode: one token per sequence, attention reads the full cache
    tokens = b * 1
    base = 2.0 * n * tokens
    mixer = tokens * cfg.n_layers * _mixer_flops_per_token(cfg, context=s)
    embed_flops = 2.0 * tokens * d * cfg.vocab_padded
    return {"flops": base + mixer + embed_flops, "tokens": tokens,
            "model_flops": 2.0 * pc["active"] * tokens}


def analytic_bytes(cfg: ArchConfig, shape_name: str) -> Dict[str, float]:
    """Approximate global HBM traffic per step."""
    sh = SHAPES[shape_name]
    b, s, kind = sh["global_batch"], sh["seq_len"], sh["kind"]
    pc = param_counts(cfg)
    d = cfg.d_model
    if kind == "train":
        tokens = b * s
        # params: read fwd (bf16) + read bwd + write grads + opt update
        # (read params+m+v fp32, write params+m+v fp32)
        pbytes = pc["total"] * (2 + 2 + 4 + 6 * 4)
        # activations: remat => ~2 fwd writes + 1 bwd read of layer inputs
        abytes = 3.0 * tokens * d * cfg.n_layers * 2
        return {"bytes": pbytes + abytes}
    if kind == "prefill":
        tokens = b * s
        pbytes = pc["total"] * 2
        abytes = 2.0 * tokens * d * cfg.n_layers * 2
        cache = 2.0 * b * s * cfg.n_kv_heads * cfg.head_dim * cfg.n_layers * 2
        return {"bytes": pbytes + abytes + cache}
    # decode
    pbytes = pc["total"] * 2
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        if cfg.moe:
            pbytes = pc["active"] * 2    # only routed-to experts are touched
        cache = 2.0 * b * s * cfg.n_kv_heads * cfg.head_dim * cfg.n_layers * 2
    else:
        # recurrent state read+write
        if cfg.family == "xlstm":
            x = cfg.xlstm
            st = b * x.n_heads * x.head_dim * x.head_dim * 4
        else:
            mb = cfg.mamba
            st = b * mb.n_heads * mb.d_state * mb.head_dim * 4
        cache = 2.0 * st * cfg.n_layers
    return {"bytes": pbytes + cache}


# ---------------------------------------------------------------------------
# Roofline assembly
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops: float               # analytic total (XLA scan-adjusted note)
    cost_analysis_flops: Optional[float]
    collective_bytes: float
    bytes_per_device: Optional[float]

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / max(self.hlo_flops, 1.0)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / max(terms): the bound with PERFECT
        compute/comm overlap."""
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        return ideal / max(self.bound_s, 1e-30)

    @property
    def roofline_fraction_serial(self) -> float:
        """useful-compute time / sum(terms): the bound with NO overlap —
        the honest baseline number; hillclimbing closes the gap between
        serial and overlapped."""
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        return ideal / max(self.compute_s + self.memory_s
                           + self.collective_s, 1e-30)

    def as_dict(self):
        d = dataclasses.asdict(self)
        d.update(dominant=self.dominant, useful_ratio=self.useful_ratio,
                 roofline_fraction=self.roofline_fraction,
                 roofline_fraction_serial=self.roofline_fraction_serial,
                 bound_s=self.bound_s)
        return d


def build_roofline(cfg: ArchConfig, shape_name: str, chips: int,
                   hlo_text: str,
                   cost_flops: Optional[float] = None,
                   bytes_per_device: Optional[float] = None) -> Roofline:
    fl = analytic_flops(cfg, shape_name)
    by = analytic_bytes(cfg, shape_name)
    # scan-body collectives fire once per layer
    coll = parse_collective_bytes(hlo_text, while_multiplier=cfg.n_layers)
    coll_bytes = coll["total"]
    return Roofline(
        arch=cfg.name, shape=shape_name, chips=chips,
        compute_s=fl["flops"] / (chips * PEAK_FLOPS),
        memory_s=by["bytes"] / (chips * HBM_BW),
        collective_s=coll_bytes / ICI_BW,   # per-device bytes already
        model_flops=fl["model_flops"],
        hlo_flops=fl["flops"],
        cost_analysis_flops=cost_flops,
        collective_bytes=coll_bytes,
        bytes_per_device=bytes_per_device,
    )
