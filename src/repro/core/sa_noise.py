"""The per-absolute-column sense-amplifier noise field (hardware model).

The silicon evaluates every activation column of every IMC layer through
the sense amplifiers exactly once; the SA read noise of that evaluation is
a property of the (stream, layer, column) triple, not of whichever code
path happens to compute it.  We model that as a deterministic *field*:

    noise(stream_key, layer, absolute_column)
        = std * normal(fold_in(fold_in(stream_key, layer), absolute_column))

so cached columns keep their realization across hops, a multi-hop batch
evaluates the same values as hop-by-hop stepping, and an *offline* window
forward can reproduce the streaming path bit-exactly by evaluating the
same field (``hw_forward(sa_noise_field=...)``).

This module is the field's single source of truth.  The serving layer
(repro.serving.stream) builds its per-hop tail evaluations from
``sa_noise_columns``; the offline oracle side (repro.models.kws.hw_forward,
repro.training.kws.hw_features / evaluate_hw) consumes an ``SANoiseField``
— a batch of (stream key, window index) pairs plus the hop size — and
expands it to full-window per-layer realizations with
``field_window_noise``.  That is what closes the customization
equivalence contract under SA noise: an enrollment session's captured
features follow each stream's own field, and the offline loop evaluates
the identical field instead of drawing fresh noise.

``cfg`` arguments are duck-typed (any object with ``num_conv_layers``,
``kernels``, ``strides``, ``pools``, ``channels`` and ``sample_len``), so
core stays import-free of the model layer.
"""

from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp


class SANoiseField(NamedTuple):
    """A batch of window-positions inside per-stream noise fields.

    keys: (N, 2) uint32 per-stream field keys (the stream's PRNG key —
          the server derives them as ``fold_in(base_key, stream_uid)``);
    hops: (N,) int32 window indices — window ``t`` of a stream occupies
          samples ``[t*hop, t*hop + window)`` and its layer-l conv
          columns sit at absolute indices ``t*n_new_l + local``;
    std:  the SA read-noise sigma (in counts);
    hop:  the stream hop in samples (must be a multiple of
          ``repro.serving.stream.hop_alignment(cfg)`` for the absolute
          column indexing to be exact).
    """

    keys: jax.Array
    hops: jax.Array
    std: float
    hop: int


def sa_noise_columns(key: jax.Array, layer: int, cols: jax.Array,
                     c_out: int, std: float) -> jax.Array:
    """Field values for one stream: (n_cols,) absolute conv column
    indices -> (n_cols, c_out).  Column ``a`` of layer ``l`` always yields
    the same realization for the same stream key — the SA evaluates each
    column once, and its noise sample is a property of that evaluation."""
    base = jax.random.fold_in(key, layer)
    return std * jax.vmap(
        lambda a: jax.random.normal(jax.random.fold_in(base, a),
                                    (c_out,)))(cols)


def layer_window_cols(cfg, hop: int) -> Dict[str, tuple]:
    """Per conv layer: ``(t_conv, n_new)`` — the full-window conv length
    and the fresh conv columns one hop contributes.  Matches the serving
    geometry (repro.serving.stream.make_stream_geometry) without needing
    it: both walk the same stride/pool recurrence."""
    t_in, d_in = cfg.sample_len, hop
    out = {}
    for i in range(cfg.num_conv_layers):
        k, s, p = cfg.kernels[i], cfg.strides[i], cfg.pools[i]
        t_conv = (t_in - k) // s + 1
        n_new = d_in // s
        out[f"conv{i}"] = (t_conv, n_new)
        t_in, d_in = t_conv // p, n_new // p
    return out


def field_window_noise(field: SANoiseField, cfg) -> Dict[str, jax.Array]:
    """Expand a field batch to full-window per-layer realizations:
    {conv_i: (N, t_conv_i, C_i)}, the ``hw_forward(sa_noise=...)`` layout.

    Row ``n`` evaluates stream ``keys[n]``'s field at window ``hops[n]``
    — bit-identical to the values the streaming path cached for those
    columns, which is what makes ``hw_forward(sa_noise_field=...)`` the
    offline oracle of a live stream (or of a customization session's
    feature captures) under SA noise."""
    cols_info = layer_window_cols(cfg, field.hop)

    def one(key, t):
        out = {}
        for i in range(1, cfg.num_conv_layers):
            t_conv, n_new = cols_info[f"conv{i}"]
            cols = t * n_new + jnp.arange(t_conv)
            out[f"conv{i}"] = sa_noise_columns(key, i, cols,
                                               cfg.channels[i], field.std)
        return out

    return jax.vmap(one)(field.keys, field.hops)
