"""Fixed-point quantization primitives for the IMC-KWS accelerator.

The paper's on-chip datapath is entirely fixed point (§III-B, §VI-A3):

    weight     : 1 sign bit, 7 decimal bits   (Q1.7,  step 1/128, range [-1, 127/128])
    activation : 1 sign, 3 integer, 4 decimal (Q1.3.4, step 1/16,  range [-8, 127/16])
    gradient   : 1 sign bit, 7 decimal bits   (Q1.7)
    error      : 1 sign bit, 7 decimal bits   (Q1.7)
    SGA accum  : 16-bit fixed point           (Q1.15 by default)

Everything here is pure JAX and jit/pjit friendly.  Quantizers use the
straight-through estimator (STE) so the same functions serve quantization-aware
training and the bit-exact inference/fine-tuning path.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QFormat:
    """A signed fixed-point format: 1 sign bit, ``int_bits`` integer bits and
    ``frac_bits`` fractional bits.

    Representable grid: k / 2**frac_bits for integer k in [qmin, qmax].
    """

    int_bits: int
    frac_bits: int
    name: str = ""

    @property
    def total_bits(self) -> int:
        return 1 + self.int_bits + self.frac_bits

    @property
    def scale(self) -> float:
        """Value of one LSB."""
        return 2.0 ** (-self.frac_bits)

    @property
    def qmax(self) -> int:
        return 2 ** (self.int_bits + self.frac_bits) - 1

    @property
    def qmin(self) -> int:
        return -(2 ** (self.int_bits + self.frac_bits))

    @property
    def max_value(self) -> float:
        return self.qmax * self.scale

    @property
    def min_value(self) -> float:
        return self.qmin * self.scale

    # ---- value-domain ops -------------------------------------------------
    def quantize(self, x: jax.Array) -> jax.Array:
        """Round-to-nearest-even onto the grid, saturating. Returns real values."""
        q = jnp.clip(jnp.round(x / self.scale), self.qmin, self.qmax)
        return q * self.scale

    def quantize_ste(self, x: jax.Array) -> jax.Array:
        """Quantize with a *clipped* straight-through gradient: identity
        inside the representable range, zero outside (PACT/DoReFa-style).
        Without the clip, Adam walks latent weights past the saturation
        boundary and the quantized layer silently dies."""
        grad_path = jnp.where(jnp.abs(x) <= self.max_value, x,
                              jax.lax.stop_gradient(x))
        return grad_path + jax.lax.stop_gradient(self.quantize(x) - grad_path)

    # ---- integer-domain ops ----------------------------------------------
    def to_int(self, x: jax.Array, dtype=jnp.int32) -> jax.Array:
        """Real value -> integer code (saturating round-to-nearest)."""
        return jnp.clip(jnp.round(x / self.scale), self.qmin, self.qmax).astype(dtype)

    def from_int(self, q: jax.Array) -> jax.Array:
        return q.astype(jnp.float32) * self.scale

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return self.name or f"Q1.{self.int_bits}.{self.frac_bits}"


# The paper's formats (§VI-A3).
WEIGHT_Q = QFormat(int_bits=0, frac_bits=7, name="weight:Q1.7")
ACT_Q = QFormat(int_bits=3, frac_bits=4, name="act:Q1.3.4")
GRAD_Q = QFormat(int_bits=0, frac_bits=7, name="grad:Q1.7")
ERROR_Q = QFormat(int_bits=0, frac_bits=7, name="error:Q1.7")
ACCUM_Q = QFormat(int_bits=0, frac_bits=15, name="accum:Q1.15")  # 16-bit SGA buffer


def quantize_ste(x: jax.Array, fmt: QFormat) -> jax.Array:
    return fmt.quantize_ste(x)


def error_scale_exponent(error: jax.Array, mode: str = "ceil",
                         max_exponent: Optional[int] = None) -> jax.Array:
    """Eq (2): s = ceil(log2(1 / max|error|)) — plus the floored/clamped
    variants the dynamic form needs in practice.

    Computed in integer/shift-friendly form; returns an int32 scalar.  A
    zero error tensor yields s = 0 (nothing to scale).

    ``mode="ceil"`` is the paper's Eq (2).  Note its fixed point: by
    construction 2**s * max|error| lands in [1, 2) — i.e. the largest
    scaled error sits AT or ABOVE the Q1.7 rail every batch, so on weakly
    separated features the dominant error saturates and learning can
    stall (the chip's fixed 1.375 factor recovers cleanly on the same
    features; see ``benchmarks/run.py --customize``'s ablation).

    ``mode="floor"`` takes s = floor(log2(1/max|error|)) instead:
    2**s * max|error| lands in (1/2, 1] — one bit of headroom, so the
    dominant error stays on-grid (it only touches the rail when
    max|error| is an exact power of two) while sub-LSB errors are still
    rescued from truncation.

    ``max_exponent`` clamps s from above (both modes): a hard bound on
    the barrel shifter, and a guard against pathological all-tiny error
    batches being amplified into pure quantization noise.
    """
    if mode not in ("ceil", "floor"):
        raise ValueError(f"mode={mode!r} must be 'ceil' or 'floor'")
    m = jnp.max(jnp.abs(error))
    safe = jnp.maximum(m, jnp.finfo(jnp.float32).tiny)
    log = jnp.log2(1.0 / safe)
    s = (jnp.ceil(log) if mode == "ceil"
         else jnp.floor(log)).astype(jnp.int32)
    if max_exponent is not None:
        s = jnp.minimum(s, jnp.int32(max_exponent))
    return jnp.where(m > 0, s, jnp.int32(0))


def scale_error(error: jax.Array, fmt: QFormat = ERROR_Q,
                fixed_scale: Optional[float] = None,
                mode: str = "ceil",
                max_exponent: Optional[int] = None):
    """Eq (1): ScaleError = error * 2**s, then quantize to ``fmt``.

    If ``fixed_scale`` is given it is used verbatim (the hardware mode: the
    paper fixes the factor to 1.375 = 1 + 1/4 + 1/8, shift-and-add friendly).
    ``mode``/``max_exponent`` select the dynamic exponent variant (see
    ``error_scale_exponent``).  Returns (scaled_quantized_error,
    scale_used).
    """
    if fixed_scale is not None:
        scale = jnp.float32(fixed_scale)
    else:
        s = error_scale_exponent(error, mode=mode, max_exponent=max_exponent)
        scale = jnp.exp2(s.astype(jnp.float32))
    return fmt.quantize(error * scale), scale


def stochastic_round(x: jax.Array, fmt: QFormat, key: jax.Array) -> jax.Array:
    """Stochastic rounding onto a fixed-point grid (used by ablations)."""
    y = x / fmt.scale
    lo = jnp.floor(y)
    p = y - lo
    up = jax.random.uniform(key, x.shape) < p
    q = jnp.clip(lo + up.astype(lo.dtype), fmt.qmin, fmt.qmax)
    return q * fmt.scale
