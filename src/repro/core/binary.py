"""Binarization primitives for the IMC-aware BNN (paper §II).

- ``binarize``: sign(x) in {-1, +1} with the standard BNN straight-through
  estimator (gradient passed where |x| <= 1, clipped outside).
- Learnable pre-binarization offset (ReActNet RSign, paper Fig 2): the
  activation is binarized as sign(x + offset) with a trainable per-channel
  offset.  At inference the offset merges into the in-memory BN bias.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.custom_vjp
def binarize(x: jax.Array) -> jax.Array:
    """sign(x) in {-1, +1} (zero maps to +1), STE backward with |x|<=1 clip."""
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


def _binarize_fwd(x):
    return binarize(x), x


def _binarize_bwd(x, g):
    # Clipped straight-through: pass gradient where |x| <= 1.
    return (g * (jnp.abs(x) <= 1.0).astype(g.dtype),)


binarize.defvjp(_binarize_fwd, _binarize_bwd)


@jax.custom_vjp
def binarize_sg(x: jax.Array, alpha: float) -> jax.Array:
    """Hard sign forward, tanh-derivative surrogate backward.

    Used in the final training phases: the forward pass is the bit-exact
    binary network (no train/eval gap), while gradients remain informative
    (alpha * sech^2(alpha*x) instead of the crude |x|<=1 box)."""
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


def _binarize_sg_fwd(x, alpha):
    return binarize_sg(x, alpha), (x, alpha)


def _binarize_sg_bwd(res, g):
    x, alpha = res
    t = jnp.tanh(alpha * x)
    return (g * alpha * (1.0 - t * t), None)


binarize_sg.defvjp(_binarize_sg_fwd, _binarize_sg_bwd)


def rsign(x: jax.Array, offset: jax.Array, channel_axis: int = -1) -> jax.Array:
    """ReActNet learnable-threshold binarization: sign(x + offset).

    ``offset`` is per-channel along ``channel_axis`` (paper Fig 2: a positive
    offset pushes more features to +1, a negative one to -1).
    """
    shape = [1] * x.ndim
    shape[channel_axis] = x.shape[channel_axis]
    return binarize(x + offset.reshape(shape))


def binary_matmul(x_bin: jax.Array, w_bin: jax.Array) -> jax.Array:
    """Inner product of ±1 operands; equals (#agree - #disagree) = XNOR-popcount
    rescaled.  On TPU this lowers onto the MXU (bf16 ±1 matmul) — the TPU-native
    analogue of the SRAM crossbar MAV (DESIGN.md §3)."""
    return jnp.matmul(x_bin, w_bin)


def channel_shuffle(x: jax.Array, groups: int) -> jax.Array:
    """ShuffleNet-style channel shuffle (paper Fig 9: the digital block after
    each IMC layer is 'BN decoder, channel shuffle and pooling').  Without it
    the grouped layers would be isolated channel towers."""
    if groups <= 1:
        return x
    c = x.shape[-1]
    assert c % groups == 0
    shape = x.shape[:-1]
    return (x.reshape(*shape, groups, c // groups)
            .swapaxes(-1, -2)
            .reshape(*shape, c))


def or_maxpool(x_bin: jax.Array, window: int, axis: int = 1) -> jax.Array:
    """Max-pool on ±1 activations == logical OR — matches the digital pooling
    block after each IMC layer (paper Fig 9)."""
    n = x_bin.shape[axis]
    n_out = n // window
    x = jax.lax.slice_in_dim(x_bin, 0, n_out * window, axis=axis)
    new_shape = x.shape[:axis] + (n_out, window) + x.shape[axis + 1:]
    return jnp.max(x.reshape(new_shape), axis=axis + 1)
