"""Distributed gradient compression with error feedback (beyond-paper).

The paper's two training tricks compose into a classic large-scale
distributed-optimization primitive:

  * *error scaling* (Eq 1-2)  ->  per-tensor dynamic power-of-two scaling
    before low-bit quantization of the gradient,
  * *small gradient accumulation* (Alg 1) -> the per-device **error-feedback
    residual**: whatever the quantizer drops is banked locally and re-injected
    into the next step, so no gradient mass is ever lost.

This module implements an int8 gradient all-reduce built from
all_to_all (int8, 1 byte/elem on the wire) + local int32 reduction +
all_gather (int8), cutting collective bytes ~4x vs fp32 ring all-reduce while
keeping SGD convergence (error feedback guarantees the residual is bounded by
one quantization step).  Used by the data-parallel trainer; validated
numerically in tests/test_grad_compress.py on a multi-device host platform.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

INT8_MAX = 127


def _pow2_scale(max_abs: jax.Array) -> jax.Array:
    """Power-of-two scale s.t. max_abs * scale <= INT8_MAX (shift-friendly,
    exactly the paper's Eq 2 applied to the int8 grid)."""
    safe = jnp.maximum(max_abs, jnp.finfo(jnp.float32).tiny)
    s = jnp.floor(jnp.log2(INT8_MAX / safe))
    return jnp.where(max_abs > 0, jnp.exp2(s), jnp.float32(1.0))


def quantize_int8(x: jax.Array, scale: jax.Array) -> jax.Array:
    return jnp.clip(jnp.round(x * scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) / scale


def compressed_allreduce_mean(grad: jax.Array, residual: jax.Array,
                              axis_name: str) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback int8 mean-all-reduce.  Must run inside shard_map/pmap
    with ``axis_name`` bound.

    grad, residual: identical shapes, local per-device values.
    Returns (mean_grad_approx, new_residual).

    Wire format: each device sends int8 shards (all_to_all) and receives int8
    results (all_gather) -> 2 bytes/element total vs 8 for fp32 ring
    all-reduce.
    """
    n = jax.lax.psum(1, axis_name)
    e = grad + residual                                   # error feedback
    # One scale for the whole group so the int32 reduction is exact.
    max_abs = jax.lax.pmax(jnp.max(jnp.abs(e)), axis_name)
    scale = _pow2_scale(max_abs)
    q = quantize_int8(e, scale)
    new_residual = e - dequantize_int8(q, scale)          # SGA-style banking

    # Pad the flattened gradient so it splits evenly across the axis.
    flat = q.reshape(-1)
    pad = (-flat.shape[0]) % n
    flat = jnp.pad(flat, (0, pad))
    shards = flat.reshape(n, -1)
    # all_to_all: device d receives shard d from every peer (int8 on the wire).
    gathered = jax.lax.all_to_all(shards, axis_name, split_axis=0,
                                  concat_axis=0, tiled=False)
    # Local exact reduction in int32, then requantize the *sum* to int8.
    local_sum = jnp.sum(gathered.astype(jnp.int32), axis=0)
    sum_max = jax.lax.pmax(jnp.max(jnp.abs(local_sum)), axis_name)
    sscale = _pow2_scale(sum_max.astype(jnp.float32))
    q_sum = quantize_int8(local_sum.astype(jnp.float32), sscale)
    # all_gather the int8 reduced shards back to everyone.
    full = jax.lax.all_gather(q_sum, axis_name, axis=0, tiled=False).reshape(-1)
    if pad:
        full = full[:-pad]
    # Dequant chain: q ~ e*scale, local_sum ~ sum(e)*scale, q_sum ~ local_sum*sscale
    # => mean = q_sum / (sscale * scale * n).
    mean = dequantize_int8(full.reshape(grad.shape), sscale) / (scale * n)
    return mean, new_residual


def exact_allreduce_mean(grad: jax.Array, axis_name: str) -> jax.Array:
    """fp32 reference path (for tests and the uncompressed trainer)."""
    return jax.lax.pmean(grad, axis_name)
