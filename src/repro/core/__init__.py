"""The paper's primary contribution: IMC-aware quantized inference and
on-chip quantized learning (error scaling + SGA + RGP), plus the analytical
chip energy model and the distributed generalization (gradient compression
with error feedback)."""

from repro.core import binary, compensation, energy, grad_compress, imc
from repro.core import onchip_training, quantize, sa_noise

__all__ = [
    "binary", "compensation", "energy", "grad_compress", "imc",
    "onchip_training", "quantize", "sa_noise",
]
