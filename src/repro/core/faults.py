"""Seeded silicon fault models for the IMC arrays (deployment-time
non-idealities, the hardware-model layer of the self-healing serving
stack).

The paper's recovery story (§IV-B bias compensation + §V-C fine-tuning)
is exercised exactly once, at enrollment — but deployed IMC silicon keeps
failing afterwards: sense-amplifier offsets drift with temperature and
aging, word lines and output columns get stuck, SRAM cells holding the
per-channel trim words flip, whole macros brown out.  This module is the
deterministic simulator of those failure modes, shaped so that *injection
rides the fused kernel's existing operands*:

* every fault reduces to a per-(layer, channel) **pre-sign count delta**
  — the same operand row the per-stream bias-delta riders use
  (``repro.serving.stream._merge_bias_delta``) — plus a stuck-column
  mask, so a faulted serving tick launches exactly the same one fused
  ``pallas_call`` per IMC layer as a healthy one (trace-enforced in
  tests/test_reliability.py);
* **offset drift** is a slow per-channel random walk layered on top of
  the static chip offsets (the same axis ``repro.core.imc
  .sample_chip_offsets`` draws) — step ``t``'s increment is a pure
  function of ``(seed, layer, t)``, so the walk is deterministic and a
  crash-restored server resumes it bit-identically;
* **stuck columns / word lines** pin a channel's SA output to ±1 by
  adding ±``stuck_magnitude`` pre-sign (a dominating rail, exactly how a
  shorted word line reads); a whole-**macro dropout** is a contiguous
  stuck range;
* **SRAM bit flips** hit the per-channel trim words in the macro's count
  path: flipping bit ``b`` of a trim word shifts that channel's counts
  by ``±flip_magnitude * 2^b``.  (A flipped *weight* cell's count error
  is input-dependent; against the test-mode drive patterns its mean
  effect is a constant per-channel count shift, which is what the rider
  carries — the residual input-dependence sits below the SA noise
  floor at realistic flip counts.)

Because drift and flips are plain count offsets, the paper's test-mode
recompensation (``repro.training.kws.compensate_layer_bias``) recovers
them exactly (up to the estimator's noise and the ±bias_range clip);
stuck rails saturate the clip and stay wrong — the health monitor
(repro.serving.health) masks those columns instead.

``cfg`` arguments are duck-typed (``imc_layer_names``, ``channels``), so
core stays import-free of the model layer.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Knobs of one chip's fault process.

    ``drift_std``: per-tick standard deviation (in counts) of the
    per-channel offset random walk — 0 disables drift; ``stuck_magnitude``
    is the pre-sign rail a stuck column reads (any value that dominates
    the count range pins the sign); ``flip_magnitude`` scales one flipped
    trim bit (bit ``b`` shifts the channel by ``±flip_magnitude * 2^b``);
    ``flip_bits`` bounds the bit position a random flip may hit."""

    drift_std: float = 0.0
    stuck_magnitude: float = 1e4
    flip_magnitude: int = 2
    flip_bits: int = 4
    seed: int = 0

    def __post_init__(self):
        if self.drift_std < 0.0:
            raise ValueError("drift_std must be >= 0")
        if self.stuck_magnitude <= 0.0:
            raise ValueError("stuck_magnitude must be > 0")
        if self.flip_magnitude < 1 or self.flip_bits < 1:
            raise ValueError("flip_magnitude and flip_bits must be >= 1")


class FaultModel:
    """Deterministic fault state of one chip's IMC layers.

    All mutation is either config-driven (``tick`` advances the drift
    walk) or explicit (``inject_*``); every random choice derives from
    ``FaultConfig.seed`` plus a monotonic counter, so two models with the
    same config and the same call sequence are bit-identical — and a
    ``snapshot()``/``restore()`` round trip resumes the process exactly
    (the crash-safety contract of repro.serving.scheduler snapshots).
    """

    def __init__(self, channels: Dict[str, int], fcfg: FaultConfig):
        self.fcfg = fcfg
        self.channels = dict(channels)
        self._names = sorted(channels, key=lambda n: int(n[4:]))
        self._key = jax.random.PRNGKey(fcfg.seed)
        self._drift = {n: np.zeros((c,), np.float32)
                       for n, c in channels.items()}
        self._flips = {n: np.zeros((c,), np.float32)
                       for n, c in channels.items()}
        self._stuck = {n: np.zeros((c,), np.int8)
                       for n, c in channels.items()}
        self._step = 0
        self._injections = 0
        self._dirty = False
        self.events: List[dict] = []

    @classmethod
    def for_config(cls, cfg, fcfg: FaultConfig) -> "FaultModel":
        """Build from a KWSConfig-like object (IMC layers conv1..convN)."""
        channels = {name: cfg.channels[int(name[4:])]
                    for name in cfg.imc_layer_names()}
        return cls(channels, fcfg)

    # -- process ------------------------------------------------------------

    @property
    def active(self) -> bool:
        """Whether any fault currently perturbs the chip."""
        return (any(v.any() for v in self._stuck.values())
                or any(v.any() for v in self._flips.values())
                or any(v.any() for v in self._drift.values()))

    def pop_dirty(self) -> bool:
        """True once after any state change (the scheduler's cue to
        refresh its rider operands)."""
        d = self._dirty
        self._dirty = False
        return d

    def tick(self) -> None:
        """Advance the drift walk one serving tick.  Step ``t``'s
        increment is ``drift_std * normal(fold(seed, layer, t))`` — a
        pure function of the config and the step index, so restoring a
        snapshot (drift arrays + step counter) resumes the identical
        walk."""
        t = self._step
        self._step += 1
        if self.fcfg.drift_std <= 0.0:
            return
        base = jax.random.fold_in(self._key, 0xD81F)
        for name in self._names:
            k = jax.random.fold_in(jax.random.fold_in(base, int(name[4:])),
                                   t)
            inc = self.fcfg.drift_std * jax.random.normal(
                k, (self.channels[name],))
            self._drift[name] = self._drift[name] + np.asarray(
                inc, np.float32)
        self._dirty = True

    # -- explicit injections ------------------------------------------------

    def _log(self, kind: str, **info) -> None:
        self.events.append({"kind": kind, "step": self._step, **info})
        self._dirty = True

    def inject_stuck(self, layer: str, channels, value: int = -1) -> None:
        """Pin columns of ``layer`` to ``value`` (+1/-1): a stuck word
        line / output column.  Unrecoverable by bias compensation (the
        rail saturates the ±bias_range clip) — the health monitor masks
        these columns instead of healing them."""
        if value not in (-1, 1):
            raise ValueError("stuck value must be +1 or -1")
        ch = np.atleast_1d(np.asarray(channels, np.int64))
        self._stuck[layer][ch] = np.int8(value)
        self._log("stuck", layer=layer, channels=[int(c) for c in ch],
                  value=int(value))

    def inject_macro_dropout(self, layer: str, start: int = 0,
                             width: Optional[int] = None) -> None:
        """Drop a whole macro: a contiguous channel range of ``layer``
        reads stuck low (the sense amps of a browned-out macro)."""
        c = self.channels[layer]
        width = c - start if width is None else width
        self.inject_stuck(layer, np.arange(start, min(start + width, c)),
                          value=-1)
        self.events[-1]["kind"] = "macro_dropout"

    def inject_bit_flips(self, n: int = 1,
                         layer: Optional[str] = None) -> None:
        """Flip ``n`` random SRAM trim bits (deterministic in the seed and
        the injection counter): each flip shifts one channel's counts by
        ``±flip_magnitude * 2^bit``.  ``layer=None`` spreads flips over
        all IMC layers."""
        key = jax.random.fold_in(jax.random.fold_in(self._key, 0xF11),
                                 self._injections)
        self._injections += 1
        flips = []
        for j in range(n):
            kj = jax.random.fold_in(key, j)
            kl, kc, kb, ks = jax.random.split(kj, 4)
            name = (layer if layer is not None else
                    self._names[int(jax.random.randint(
                        kl, (), 0, len(self._names)))])
            ch = int(jax.random.randint(kc, (), 0, self.channels[name]))
            bit = int(jax.random.randint(kb, (), 0, self.fcfg.flip_bits))
            sign = int(jax.random.randint(ks, (), 0, 2)) * 2 - 1
            delta = float(sign * self.fcfg.flip_magnitude * (1 << bit))
            self._flips[name][ch] += np.float32(delta)
            flips.append({"layer": name, "channel": ch, "bit": bit,
                          "delta": delta})
        self._log("bit_flips", flips=flips)

    def clear(self) -> None:
        """Repair everything (a chip swap / test harness reset)."""
        for name in self._names:
            self._drift[name][:] = 0.0
            self._flips[name][:] = 0.0
            self._stuck[name][:] = 0
        self._log("clear")

    # -- the rider view -----------------------------------------------------

    def deltas(self) -> Dict[str, np.ndarray]:
        """The combined per-(layer, channel) pre-sign count delta — what
        the scheduler adds to every slot's bias-delta rider row (drift +
        trim flips + the stuck rails)."""
        out = {}
        for name in self._names:
            out[name] = (self._drift[name] + self._flips[name]
                         + self._stuck[name].astype(np.float32)
                         * np.float32(self.fcfg.stuck_magnitude))
        return out

    def stuck_mask(self) -> Dict[str, np.ndarray]:
        """{layer: (C,) bool} — columns pinned by stuck/dropout faults."""
        return {name: self._stuck[name] != 0 for name in self._names}

    def stats(self) -> dict:
        stuck = {n: int((self._stuck[n] != 0).sum()) for n in self._names}
        return {
            "active": self.active,
            "step": self._step,
            "drift_std": self.fcfg.drift_std,
            "drift_rms": {
                n: round(float(np.sqrt(np.mean(self._drift[n] ** 2))), 4)
                for n in self._names if self._drift[n].any()},
            "stuck_channels": {n: c for n, c in stuck.items() if c},
            "flipped_channels": {
                n: int((self._flips[n] != 0).sum())
                for n in self._names if self._flips[n].any()},
            "injections": len(self.events),
        }

    # -- crash safety -------------------------------------------------------

    def snapshot(self) -> dict:
        """Plain-array state dict (consumed by StreamServer.snapshot)."""
        return {
            "step": self._step,
            "injections": self._injections,
            "drift": {n: self._drift[n].copy() for n in self._names},
            "flips": {n: self._flips[n].copy() for n in self._names},
            "stuck": {n: self._stuck[n].copy() for n in self._names},
            "events": [dict(e) for e in self.events],
        }

    def restore(self, snap: dict) -> None:
        """Resume from a ``snapshot()`` — the drift walk, counters and
        injected faults continue bit-identically."""
        self._step = int(snap["step"])
        self._injections = int(snap["injections"])
        for n in self._names:
            self._drift[n] = np.asarray(snap["drift"][n], np.float32).copy()
            self._flips[n] = np.asarray(snap["flips"][n], np.float32).copy()
            self._stuck[n] = np.asarray(snap["stuck"][n], np.int8).copy()
        self.events = [dict(e) for e in snap["events"]]
        self._dirty = True
