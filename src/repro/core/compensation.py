"""Bias compensation for IMC non-ideal effects (paper §IV-B).

The paper's recipe: run calibration inputs through the noisy macro in *test
mode* (Fig 8 exposes each macro's MAV/SA results), compare the convolution
results against the ideal ones, and fold a per-channel compensating bias —
derived from the statistics of the difference — into the in-memory BN bias
(possible because most BN biases sit well inside [-64, 64], Fig 7).  A few
epochs of noise-aware fine-tuning then recover the residual loss.

The estimator below is exactly that: per-channel mean of (noisy - ideal)
pre-activation counts over a calibration set, rounded onto the bias parity
grid, subtracted from the mapped bias, re-clipped.
"""

from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.core import imc


def estimate_channel_offsets(ideal_counts: jax.Array,
                             noisy_counts: jax.Array) -> jax.Array:
    """Mean per-channel discrepancy; channels on the last axis."""
    diff = noisy_counts - ideal_counts
    return jnp.mean(diff.reshape(-1, diff.shape[-1]), axis=0)


def compensate_bias(bias_int: jax.Array, offset_estimate: jax.Array,
                    macro: imc.IMCMacroConfig = imc.DEFAULT_MACRO) -> jax.Array:
    """Fold -offset into the mapped bias, respecting parity + range."""
    comp = imc.map_bias(-offset_estimate, method="best", macro=macro)
    return jnp.clip(bias_int + comp, -macro.bias_range, macro.bias_range)


def calibrate_layerwise(
    layer_counts_fn: Callable[[Dict[str, jax.Array] | None], Dict[str, jax.Array]],
    calib_inputs_present: bool = True,
) -> Dict[str, jax.Array]:
    """Generic calibration driver.

    ``layer_counts_fn(chip_offsets_or_None)`` must return a dict
    {layer_name: pre-SA counts} for the calibration batch; called once with the
    chip's noise realization and once with None (ideal).  Returns per-layer
    per-channel offset estimates.

    Note: the estimate for layer L is computed with *matched inputs* (the ideal
    binary activations feed both paths), mirroring the chip's test mode which
    drives each macro with known patterns rather than chaining noisy layers.
    """
    noisy = layer_counts_fn(True)
    ideal = layer_counts_fn(False)
    return {
        name: estimate_channel_offsets(ideal[name], noisy[name])
        for name in ideal
    }
