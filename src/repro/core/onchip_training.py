"""On-chip learning for model customization (paper §III, §V-C).

Fine-tunes ONLY the final classifier layer, entirely in fixed point:

    weight/gradient/error : Q1.7      activation : Q1.3.4
    SGA accumulators      : 16-bit fixed point (Q1.15)

and reproduces the paper's three enabling techniques:

  * Error scaling (Eq 1-2)           — rescue errors that underflow Q1.7,
  * Small Gradient Accumulation (Alg 1, Eq 3) — side-buffer sub-threshold
    gradients in 16-bit and release them when they cross G_th,
  * Random Gradient Prediction (Eq 4) — add quantize(N(0,1)/lambda).

plus the hardware loss path: LUT-based exp for softmax and 8-bit division
(§V-C).  The API is model-agnostic: any (features, labels, W, b) classifier
head can be customized — this is what generalizes the technique to the LM
architectures (DESIGN.md §6).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quantize import (ACCUM_Q, ACT_Q, ERROR_Q, GRAD_Q, WEIGHT_Q,
                                 QFormat, error_scale_exponent)

# ---------------------------------------------------------------------------
# Hardware softmax: LUT exp + 8-bit division (paper §V-C)
# ---------------------------------------------------------------------------

# The FC output is Q1.3.4.  After max-subtraction z' = z - max(z) lies on the
# Q1.3.4 grid in [-15.9375, 0]: exactly 256 grid points at step 1/16 -> one
# 256-entry LUT ("the look-up table can easily cover all situations with a
# small size register file").
_LUT_STEP = ACT_Q.scale                      # 1/16
_LUT_SIZE = 256
_LUT_MIN = -(_LUT_SIZE - 1) * _LUT_STEP       # -15.9375
# LUT entries stored as 8-bit unsigned fractions (Q0.8): exp(z') in (0, 1].
_EXP_LUT = jnp.round(jnp.exp(jnp.arange(_LUT_SIZE) * _LUT_STEP + _LUT_MIN)
                     * 256.0) / 256.0


def lut_softmax(logits_q: jax.Array) -> jax.Array:
    """Softmax over the last axis using the hardware LUT path.

    ``logits_q`` must already be on the Q1.3.4 grid.  Division is truncated to
    8 fractional bits, matching the fixed 8-bit divider.
    """
    z = logits_q - jnp.max(logits_q, axis=-1, keepdims=True)
    idx = jnp.clip(jnp.round((z - _LUT_MIN) / _LUT_STEP), 0, _LUT_SIZE - 1)
    e = _EXP_LUT[idx.astype(jnp.int32)]
    denom = jnp.sum(e, axis=-1, keepdims=True)
    p = e / jnp.maximum(denom, 1.0 / 256.0)
    return jnp.round(p * 256.0) / 256.0      # 8-bit division output


# ---------------------------------------------------------------------------
# Small Gradient Accumulation (Algorithm 1)
# ---------------------------------------------------------------------------


def sga_threshold(lr: jax.Array | float,
                  weight_fmt: QFormat = WEIGHT_Q) -> jax.Array:
    """Eq (3): G_th = (min(weight)/2) / LR, min(weight) = one weight LSB."""
    return (weight_fmt.scale / 2.0) / jnp.asarray(lr, jnp.float32)


def sga_step(grad: jax.Array, accum: jax.Array, g_th: jax.Array,
             accum_fmt: QFormat = ACCUM_Q) -> Tuple[jax.Array, jax.Array]:
    """One elementwise SGA step (Algorithm 1, magnitude-symmetric form).

    Sub-threshold gradients are banked into the 16-bit accumulator; once the
    bank itself crosses the threshold it is released as the update and reset.
    Returns (g_update, new_accum); both live on fixed-point grids so the whole
    optimizer state is 16-bit as in the paper.
    """
    small = jnp.abs(grad) < g_th
    banked = accum_fmt.quantize(accum + jnp.where(small, grad, 0.0))
    fire = small & (jnp.abs(banked) >= g_th)
    g_update = jnp.where(small, jnp.where(fire, banked, 0.0), grad)
    new_accum = jnp.where(fire, 0.0, banked)
    return g_update, new_accum


def rgp_noise(key: jax.Array, shape, lam: float,
              fmt: QFormat = GRAD_Q) -> jax.Array:
    """Eq (4): quantize(N(0,1)/lambda) on the gradient grid."""
    return fmt.quantize(jax.random.normal(key, shape) / lam)


# ---------------------------------------------------------------------------
# The full quantized last-layer fine-tuning loop
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OnChipTrainConfig:
    epochs: int = 1000
    lr_init: float = 1.0 / 16.0          # paper §VI-A3
    lr_min: float = 1.0 / 128.0
    lr_halve_every: int = 10
    error_scaling: bool = True
    # None -> dynamic Eq(2) per batch; the paper's chip fixes 1.375 (=1+1/4+1/8)
    fixed_error_scale: Optional[float] = None
    # dynamic-exponent variant (ignored with fixed_error_scale):
    # 'ceil' = the paper's Eq(2) — scaled-max lands AT/ABOVE the Q1.7
    # rail every batch (saturation can stall learning on weakly separated
    # features); 'floor' keeps one bit of headroom (scaled-max <= 1).
    # error_scale_max_exponent clamps the shift from above.
    error_scale_mode: str = "ceil"
    error_scale_max_exponent: Optional[int] = None
    sga: bool = True
    rgp: bool = False
    rgp_lambda: float = 8.0
    quantized: bool = True               # False -> full-precision GPU baseline
    seed: int = 0
    weight_fmt: QFormat = WEIGHT_Q
    act_fmt: QFormat = ACT_Q
    grad_fmt: QFormat = GRAD_Q
    error_fmt: QFormat = ERROR_Q
    accum_fmt: QFormat = ACCUM_Q


class HeadState(NamedTuple):
    w: jax.Array          # (D, C) on the weight grid
    b: jax.Array          # (C,)
    accum_w: jax.Array    # SGA banks
    accum_b: jax.Array
    key: jax.Array


def lr_schedule(cfg: OnChipTrainConfig, epoch: jax.Array) -> jax.Array:
    lr = cfg.lr_init * (0.5 ** (epoch // cfg.lr_halve_every))
    return jnp.maximum(lr, cfg.lr_min)


def head_logits(features_q: jax.Array, w: jax.Array, b: jax.Array,
                cfg: OnChipTrainConfig) -> jax.Array:
    """8-bit FC forward; output requantized onto the activation grid."""
    z = features_q @ w + b
    return cfg.act_fmt.quantize(z) if cfg.quantized else z


def epoch_grads(state: HeadState, epoch: jax.Array, features_q: jax.Array,
                labels_1hot: jax.Array, cfg: OnChipTrainConfig
                ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """The pre-optimizer half of one epoch: forward, hardware softmax,
    error scaling (Eq 1-2) and gradient quantization (+ optional RGP).

    Returns (gw, gb, lr, new_key) — everything ``apply_update`` (or the
    fused ``sga_update`` kernel) needs to transition the head state.  Split
    out of the epoch so the serving customization path
    (repro.serving.customize) can compute per-session gradients and batch
    the optimizer transition of many sessions into one kernel launch."""
    n = features_q.shape[0]
    lr = lr_schedule(cfg, epoch)

    logits = head_logits(features_q, state.w, state.b, cfg)
    if cfg.quantized:
        probs = lut_softmax(logits)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
    err = probs - labels_1hot                       # dCE/dlogits (per sample)

    if cfg.quantized:
        if cfg.error_scaling:
            if cfg.fixed_error_scale is not None:
                scale = jnp.float32(cfg.fixed_error_scale)
            else:
                scale = jnp.exp2(error_scale_exponent(
                    err, mode=cfg.error_scale_mode,
                    max_exponent=cfg.error_scale_max_exponent
                ).astype(jnp.float32))
        else:
            scale = jnp.float32(1.0)
        err = cfg.error_fmt.quantize(err * scale)
        # grad accumulated sample-by-sample into the gradient SRAM, then the
        # batch mean is what the scaling factor was calibrated against (§V-C).
        gw = cfg.grad_fmt.quantize(features_q.T @ err / n)
        gb = cfg.grad_fmt.quantize(jnp.sum(err, axis=0) / n)
    else:
        gw = features_q.T @ err / n
        gb = jnp.sum(err, axis=0) / n

    key = state.key
    if cfg.rgp and cfg.quantized:
        key, k1, k2 = jax.random.split(key, 3)
        gw = cfg.grad_fmt.quantize(gw + rgp_noise(k1, gw.shape, cfg.rgp_lambda,
                                                  cfg.grad_fmt))
        gb = cfg.grad_fmt.quantize(gb + rgp_noise(k2, gb.shape, cfg.rgp_lambda,
                                                  cfg.grad_fmt))
    return gw, gb, lr, key


def apply_update(state: HeadState, gw: jax.Array, gb: jax.Array,
                 lr: jax.Array, key: jax.Array,
                 cfg: OnChipTrainConfig) -> HeadState:
    """The optimizer half of one epoch: SGA banking (Alg 1) + SGD step +
    weight quantization.  This is the jnp reference of the fused
    ``repro.kernels.sga_update`` kernel (bit-identical on the fixed-point
    grids — the kernel equivalence test drives both)."""
    accum_w, accum_b = state.accum_w, state.accum_b
    if cfg.sga and cfg.quantized:
        g_th = sga_threshold(lr, cfg.weight_fmt)
        gw, accum_w = sga_step(gw, accum_w, g_th, cfg.accum_fmt)
        gb, accum_b = sga_step(gb, accum_b, g_th, cfg.accum_fmt)

    if cfg.quantized:
        w = cfg.weight_fmt.quantize(state.w - lr * gw)
        b = cfg.weight_fmt.quantize(state.b - lr * gb)
    else:
        w = state.w - lr * gw
        b = state.b - lr * gb
    return HeadState(w, b, accum_w, accum_b, key)


def _epoch_step(state: HeadState, epoch: jax.Array, features_q: jax.Array,
                labels_1hot: jax.Array, cfg: OnChipTrainConfig) -> HeadState:
    """One full-batch epoch (the chip reads the whole 90-utterance set)."""
    gw, gb, lr, key = epoch_grads(state, epoch, features_q, labels_1hot, cfg)
    return apply_update(state, gw, gb, lr, key, cfg)


def finetune_init(features: jax.Array, labels: jax.Array,
                  w0: jax.Array, b0: jax.Array, cfg: OnChipTrainConfig,
                  num_classes: Optional[int] = None
                  ) -> Tuple[HeadState, jax.Array, jax.Array]:
    """Quantize the feature buffer / initial head and build the optimizer
    state.  Returns (state, features_q, labels_1hot) — feed them to
    ``finetune_epochs`` (resumable: any chunking of the epoch range gives
    the same final state as one monolithic run)."""
    c = num_classes or w0.shape[-1]
    labels_1hot = jax.nn.one_hot(labels, c)
    feats = cfg.act_fmt.quantize(features) if cfg.quantized else features
    w = cfg.weight_fmt.quantize(w0) if cfg.quantized else w0
    b = cfg.weight_fmt.quantize(b0) if cfg.quantized else b0
    state = HeadState(
        w=w, b=b,
        accum_w=jnp.zeros_like(w), accum_b=jnp.zeros_like(b),
        key=jax.random.PRNGKey(cfg.seed),
    )
    return state, feats, labels_1hot


def finetune_epochs(state: HeadState, features_q: jax.Array,
                    labels_1hot: jax.Array, cfg: OnChipTrainConfig,
                    start_epoch: int, num_epochs: int) -> HeadState:
    """Run ``num_epochs`` full-batch epochs starting at ``start_epoch``.

    The epoch index drives the LR schedule, so chunked calls
    (0..k, k..n) compose bit-identically to one 0..n call — this is what
    lets a scheduler tick run a bounded number of fine-tune steps and
    resume next tick (repro.serving.customize)."""
    def body(e, st):
        return _epoch_step(st, e, features_q, labels_1hot, cfg)

    return jax.lax.fori_loop(start_epoch, start_epoch + num_epochs, body,
                             state)


def quantized_head_finetune(features: jax.Array, labels: jax.Array,
                            w0: jax.Array, b0: jax.Array,
                            cfg: OnChipTrainConfig,
                            num_classes: Optional[int] = None
                            ) -> Tuple[jax.Array, jax.Array]:
    """Customize a classifier head on-device.

    features: (N, D) pre-classifier activations (the SRAM feature buffer),
    labels:   (N,) int class ids.
    Returns the fine-tuned (w, b) on the weight grid (or fp32 for the
    full-precision baseline).  Model-agnostic: works for the KWS GAP features
    or any LM pooled hidden state.  Equals ``finetune_init`` +
    ``finetune_epochs(0, cfg.epochs)`` — the step-wise form the serving
    enrollment sessions resume across scheduler ticks.
    """
    state, feats, labels_1hot = finetune_init(features, labels, w0, b0, cfg,
                                              num_classes)
    state = finetune_epochs(state, feats, labels_1hot, cfg, 0, cfg.epochs)
    return state.w, state.b


def head_accuracy(features: jax.Array, labels: jax.Array, w: jax.Array,
                  b: jax.Array, cfg: OnChipTrainConfig) -> jax.Array:
    feats = cfg.act_fmt.quantize(features) if cfg.quantized else features
    logits = head_logits(feats, w, b, cfg)
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
