"""Analytical energy/latency/area model of the KWS accelerator (paper §VI-B).

The container has no 28nm chip, so — as for any accelerator paper — the chip
numbers are reproduced with a calibrated analytical model.  Calibration
anchors, all taken from the paper:

  * latency: 160 ms/decision @ 1 MHz, 1.6 ms @ 100 MHz (=> 160k cycles/decision)
  * training: 765 ms/epoch @ 1 MHz  (=> 765k cycles/epoch)
  * power: 89.5 uW @ 1 MHz ... 2833 uW (inference) @ 100 MHz
  * energy/decision: 89.5uW x 160ms = 14.3 uJ  (the title's 14 uJ)
  * split: solving the two operating points gives
        P_leak ~ 61.8 uW,  E_dynamic ~ 4.43 uJ/decision
    consistent with Fig 16 (leakage dominates at low clock).
  * dynamic breakdown (Fig 15): FC+buffer ~ large, IMC controller ~ large,
    L1 digital ~ 18%, analog MAV ~ 3%.
  * area: 1 mm^2; IMC macros ~70%, digital ~19%, RF+SRAM buffer ~11% (Fig 18);
    training circuits ~5% (9187 gates).

The model charges energy per *event* (binary MAC in IMC, digital 8-bit MAC,
SRAM access, controller cycle) with per-event constants fitted to the anchors,
and reports the same tables/figures the paper does.  It is used by
``benchmarks/table5_energy.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

# ---------------------------------------------------------------------------
# Hardware constants (28nm, 0.9V, TT corner) — fitted, see module docstring
# ---------------------------------------------------------------------------

LEAKAGE_W = 61.8e-6            # static power, whole chip
CYCLES_PER_DECISION = 160_000  # 160 ms @ 1 MHz
CYCLES_PER_TRAIN_EPOCH = 765_000

# Per-event dynamic energies (joules).  Fit targets: E_dyn ~ 4.4 uJ/decision
# with the Fig 15 proportions (FC+buffer and IMC controller dominant,
# L1 digital ~18%, analog MAV ~3%).
E_IMC_MAC = 1.3e-15            # one +/-1 MAC inside the array ("analog ~3%")
E_DIG_MAC8 = 0.6e-12           # 8-bit digital MAC (L1 sinc PEs, FC)
E_SRAM_RD_BIT = 0.6e-12        # SRAM buffer read, per bit
E_SRAM_WR_BIT = 0.7e-12
E_CTRL_CYCLE = 12.0e-12        # IMC controller + FSM flip-flops, per cycle
E_LUT_LOOKUP = 0.8e-12         # exp LUT access (training)
E_DIV8 = 1.6e-12               # 8-bit divider op (training)

AREA_MM2 = 1.0
AREA_FRAC = {"imc_macros": 0.70, "digital": 0.19, "buffers": 0.11}
TRAIN_AREA_FRAC = 0.05         # +9187 gates


@dataclasses.dataclass
class LayerEnergy:
    name: str
    kind: str                   # 'digital' | 'imc' | 'fc'
    macs: int                   # multiply-accumulates per decision
    sram_read_bits: int
    sram_write_bits: int
    ctrl_cycles: int

    @property
    def dynamic_j(self) -> float:
        e_mac = {"digital": E_DIG_MAC8, "imc": E_IMC_MAC, "fc": E_DIG_MAC8}[self.kind]
        return (self.macs * e_mac
                + self.sram_read_bits * E_SRAM_RD_BIT
                + self.sram_write_bits * E_SRAM_WR_BIT
                + self.ctrl_cycles * E_CTRL_CYCLE)


@dataclasses.dataclass
class ChipReport:
    layers: List[LayerEnergy]
    freq_hz: float = 1e6
    # None -> the paper's full-window 160k cycles; the streaming path passes
    # its (smaller) per-hop cycle count so leakage is charged for the time
    # the chip is actually awake per decision.
    cycles_per_decision: int | None = None

    @property
    def dynamic_j_per_decision(self) -> float:
        return sum(l.dynamic_j for l in self.layers)

    @property
    def latency_s(self) -> float:
        cycles = (CYCLES_PER_DECISION if self.cycles_per_decision is None
                  else self.cycles_per_decision)
        return cycles / self.freq_hz

    @property
    def energy_j_per_decision(self) -> float:
        return self.dynamic_j_per_decision + LEAKAGE_W * self.latency_s

    @property
    def power_w(self) -> float:
        return self.energy_j_per_decision / self.latency_s

    @property
    def total_ops(self) -> int:
        return sum(2 * l.macs for l in self.layers)      # 1 MAC = 2 ops

    @property
    def tops_per_w(self) -> float:
        return (self.total_ops / self.energy_j_per_decision) / 1e12

    def breakdown(self) -> Dict[str, float]:
        total = self.dynamic_j_per_decision
        return {l.name: l.dynamic_j / total for l in self.layers}


def kws_chip_report(layer_stats: List[dict], freq_hz: float = 1e6) -> ChipReport:
    """Build the report from per-layer op counts produced by the model config.

    ``layer_stats``: [{name, kind, macs, in_bits, out_bits, cycles}, ...].
    """
    layers = [
        LayerEnergy(
            name=s["name"], kind=s["kind"], macs=s["macs"],
            sram_read_bits=s.get("in_bits", 0),
            sram_write_bits=s.get("out_bits", 0),
            ctrl_cycles=s.get("cycles", 0),
        )
        for s in layer_stats
    ]
    return ChipReport(layers=layers, freq_hz=freq_hz)


def kws_streaming_report(streaming_stats: List[dict],
                         freq_hz: float = 1e6) -> ChipReport:
    """Per-decision chip report for the frame-incremental streaming path.

    ``streaming_stats`` comes from ``repro.serving.stream
    .streaming_layer_stats``: each conv layer's events scale by its tail
    fraction (~hop/window).  Latency — and therefore the leakage charge,
    which dominates at 1 MHz (Fig 16) — scales with the summed per-hop
    cycles instead of the fixed 160k full-window cycles, so the report shows
    the uJ-equivalent of the hop/window work reduction."""
    rep = kws_chip_report(streaming_stats, freq_hz)
    rep.cycles_per_decision = max(1, sum(int(s.get("cycles", 0))
                                         for s in streaming_stats))
    return rep


def streaming_energy_summary(offline_stats: List[dict],
                             streaming_stats: List[dict],
                             freq_hz: float = 1e6) -> dict:
    """Offline vs streaming energy/decision side by side (machine-readable,
    consumed by benchmarks/run.py --streaming)."""
    off = kws_chip_report(offline_stats, freq_hz)
    strm = kws_streaming_report(streaming_stats, freq_hz)
    return {
        "freq_hz": freq_hz,
        "offline_uj_per_decision": off.energy_j_per_decision * 1e6,
        "streaming_uj_per_decision": strm.energy_j_per_decision * 1e6,
        "energy_ratio": (strm.energy_j_per_decision
                         / off.energy_j_per_decision),
        "offline_dynamic_uj": off.dynamic_j_per_decision * 1e6,
        "streaming_dynamic_uj": strm.dynamic_j_per_decision * 1e6,
    }


def vad_stats(hop_samples: int) -> dict:
    """Op counts of the always-on VAD front end per hop, same row schema as
    ``kws.layer_stats``: one 8-bit MAC per sample (square + accumulate of
    the energy EMA), one SRAM read per buffered sample, one controller
    cycle per sample, an 8-bit state write.  This is the only digital block
    awake on a gated (silent) hop."""
    return {
        "name": "vad", "kind": "digital",
        "macs": int(hop_samples),
        "in_bits": int(hop_samples * 8),
        "out_bits": 8,
        "cycles": int(hop_samples),
    }


def gated_energy_summary(offline_stats: List[dict],
                         streaming_stats: List[dict], *,
                         hop_samples: int, duty_cycle: float,
                         freq_hz: float = 1e6) -> dict:
    """Duty-cycled energy of the voice-activity-gated always-on path.

    Every hop runs the VAD detector (``vad_stats``).  A *speech* hop
    additionally runs the frame-incremental IMC stack (the streaming
    report).  A *gated* (silent) hop charges **leakage only** for the
    VAD's awake cycles plus the VAD's own dynamic energy — the IMC arrays,
    controller and FC never switch, exactly the chip's sleep story.  The
    per-decision average weighs the two by ``duty_cycle`` (the fraction of
    hops with speech); the silent hops' "no keyword" decision is made by
    the VAD itself, so every hop still counts as a decision.

    Consumed by ``benchmarks/run.py --streaming`` and the StreamServer's
    ``stats()`` (with the measured duty cycle)."""
    if not 0.0 <= duty_cycle <= 1.0:
        raise ValueError(f"duty_cycle={duty_cycle} must be in [0, 1]")
    strm = kws_streaming_report(streaming_stats, freq_hz)
    v = vad_stats(hop_samples)
    vad_dynamic_j = LayerEnergy(
        name=v["name"], kind=v["kind"], macs=v["macs"],
        sram_read_bits=v["in_bits"], sram_write_bits=v["out_bits"],
        ctrl_cycles=v["cycles"]).dynamic_j
    vad_leak_j = LEAKAGE_W * v["cycles"] / freq_hz
    idle_j = vad_dynamic_j + vad_leak_j
    active_j = strm.energy_j_per_decision + idle_j   # VAD runs every hop
    gated_j = duty_cycle * active_j + (1.0 - duty_cycle) * idle_j
    base = streaming_energy_summary(offline_stats, streaming_stats, freq_hz)
    return {
        "freq_hz": freq_hz,
        "duty_cycle": duty_cycle,
        "hop_samples": hop_samples,
        "offline_uj_per_decision": base["offline_uj_per_decision"],
        "ungated_uj_per_decision": active_j * 1e6,
        "idle_uj_per_hop": idle_j * 1e6,
        "vad_dynamic_uj": vad_dynamic_j * 1e6,
        "vad_leakage_uj": vad_leak_j * 1e6,
        "gated_uj_per_decision": gated_j * 1e6,
        "reduction_vs_ungated": active_j / gated_j,
        "reduction_vs_offline": (base["offline_uj_per_decision"] * 1e-6
                                 / gated_j),
    }


def customization_energy_summary(n_utts: int, feat_dim: int,
                                 num_classes: int, epochs: int,
                                 freq_hz: float = 1e6) -> dict:
    """Analytical energy of one on-chip customization run (§V-C).

    One fine-tune step = one full-batch epoch over the SRAM feature
    buffer: an 8-bit FC forward (n x d x c MACs), the LUT softmax + 8-bit
    division (n x c each), the error/gradient passes (~2x the forward
    MACs: the error outer product and the bias sum), the feature-buffer
    reads and the weight/SGA-bank read-modify-write.  Consumed by
    ``benchmarks/run.py --customize`` and the session results
    (repro.serving.customize) as uJ-per-fine-tune-step."""
    macs = n_utts * (feat_dim * num_classes + num_classes) * 3
    lut = n_utts * num_classes
    div = n_utts * num_classes
    sram = (n_utts * feat_dim * 8                      # feature buffer read
            + feat_dim * num_classes * 8 * 2           # weight r/w
            + feat_dim * num_classes * 16)             # SGA bank (16-bit)
    per_step = training_energy_j(1, freq_hz, macs_per_epoch=macs,
                                 lut_ops=lut, div_ops=div, sram_bits=sram)
    total = training_energy_j(epochs, freq_hz, macs_per_epoch=macs,
                              lut_ops=lut, div_ops=div, sram_bits=sram)
    return {
        "freq_hz": freq_hz,
        "n_utterances": n_utts,
        "epochs": epochs,
        "uj_per_finetune_step": per_step * 1e6,
        "total_uj": total * 1e6,
        "seconds_per_step": CYCLES_PER_TRAIN_EPOCH / freq_hz,
    }


def recovery_energy_summary(offline_stats: List[dict], *, n_cal: int,
                            bias_bits: int, freq_hz: float = 1e6) -> dict:
    """Analytical energy of one self-healing recompensation pass
    (repro.serving.health): the §IV-B test mode re-runs ``n_cal``
    calibration windows through the full stack with the counts digitized
    instead of sign-compressed (charged as full offline decisions — the
    test mode has no streaming reuse), then re-programs the implicated
    layers' bias words (``bias_bits`` SRAM writes).  Consumed by the
    health monitor's accounting and ``benchmarks/run.py --faults``."""
    rep = kws_chip_report(offline_stats, freq_hz)
    measure_j = n_cal * rep.energy_j_per_decision
    reprogram_j = bias_bits * E_SRAM_WR_BIT
    return {
        "freq_hz": freq_hz,
        "n_cal_windows": n_cal,
        "bias_bits": bias_bits,
        "measure_uj": measure_j * 1e6,
        "reprogram_uj": reprogram_j * 1e6,
        "total_uj": (measure_j + reprogram_j) * 1e6,
    }


def training_energy_j(num_epochs: int, freq_hz: float = 1e6,
                      macs_per_epoch: int = 0, lut_ops: int = 0,
                      div_ops: int = 0, sram_bits: int = 0) -> float:
    """Energy of an on-chip customization run (training power ~105uW @1MHz)."""
    t = num_epochs * CYCLES_PER_TRAIN_EPOCH / freq_hz
    dyn = (macs_per_epoch * E_DIG_MAC8 + lut_ops * E_LUT_LOOKUP
           + div_ops * E_DIV8 + sram_bits * (E_SRAM_RD_BIT + E_SRAM_WR_BIT)
           ) * num_epochs
    return dyn + LEAKAGE_W * t
