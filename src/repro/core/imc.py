"""Functional model of the SRAM in-memory-computing (IMC) macro (paper §IV).

The physical macro ([17], Fig 6): 8 banks of 64x64 8T SRAM cells per macro
(4KB).  Binary weights live in the array; activations precharge read bitlines;
multiply-and-average (MAV) happens by charge sharing on AVG_P/AVG_N lines, and a
sense amplifier (SA) converts the analog difference to a 1-bit output.  Batch
norm executes *in memory*: the BN bias is one word-line of +/-1 cells driven by
input 1, so

  - the bias is an integer in [-64, 64],
  - its parity is fixed by the array width (even for a 64-wide array),
  - the SA output is sign(sum_i x_i w_i + bias + analog noise).

This module provides the bit/count-exact functional model of all of that, plus
the two non-ideal effects the paper compensates:

  * MAV offset  — a static per-bank (per output channel) analog mismatch,
                  drawn once per *chip* (Monte-Carlo over PVT corners),
  * SA variation — per-evaluation comparator noise near the threshold.

Everything is expressed in the integer "count" domain of the array (the analog
averaging /64 divides both sides of the comparison and is absorbed into the
threshold — DESIGN.md §3), so the model is exact and jit-friendly.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.binary import binarize

# ---------------------------------------------------------------------------
# Macro geometry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class IMCMacroConfig:
    rows: int = 64                 # word lines per bank
    cols: int = 64                 # bit lines per bank
    banks_per_macro: int = 8       # one bank computes one output channel slice
    bias_rows: int = 1             # word lines reserved for in-memory BN

    @property
    def macro_bits(self) -> int:
        return self.rows * self.cols * self.banks_per_macro

    @property
    def macro_bytes(self) -> int:
        return self.macro_bits // 8

    @property
    def bias_range(self) -> int:
        """|bias| <= cols (one word-line of +/-1 cells)."""
        return self.cols

    @property
    def bias_parity_even(self) -> bool:
        """Sum of an even number of +/-1 cells is even."""
        return self.cols % 2 == 0


DEFAULT_MACRO = IMCMacroConfig()


@dataclasses.dataclass(frozen=True)
class IMCNoiseParams:
    """Noise magnitudes in array-count units (1 count = one +/-1 product)."""

    mav_offset_std: float = 4.0    # static per-channel MAV mismatch
    sa_noise_std: float = 1.0      # per-evaluation SA comparator noise

    def none(self) -> "IMCNoiseParams":
        return IMCNoiseParams(0.0, 0.0)


# ---------------------------------------------------------------------------
# In-memory BN folding + bias mapping (paper §IV-A)
# ---------------------------------------------------------------------------


def fold_bn_to_bias(gamma: jax.Array, beta: jax.Array, mean: jax.Array,
                    var: jax.Array, act_offset: jax.Array,
                    eps: float = 1e-5) -> Tuple[jax.Array, jax.Array]:
    """Fold BN (+ the learnable pre-binarization offset, Fig 2) into a single
    integer-domain threshold.

    The binary activation is sign(gamma*(a-mean)/sigma + beta + act_offset).
    For gamma > 0 this equals sign(a + b) with
        b = (beta + act_offset) * sigma / gamma - mean,
    and for gamma < 0 the SA output must be inverted (the digital "BN decoder"
    in Fig 9 handles the sign).  Returns (bias_real, flip) with flip in
    {+1, -1}.
    """
    sigma = jnp.sqrt(var + eps)
    g = jnp.where(gamma == 0, 1e-12, gamma)
    b = (beta + act_offset) * sigma / g - mean
    flip = jnp.where(gamma >= 0, 1.0, -1.0)
    return b, flip


def map_bias(bias: jax.Array, method: str = "best",
             macro: IMCMacroConfig = DEFAULT_MACRO) -> jax.Array:
    """Quantize a real BN bias onto the in-memory grid.

    The grid: integers of fixed parity (even for a 64-wide array) clipped to
    [-cols, cols].  The paper evaluates four mappings — ``add`` (round toward
    +inf), ``sub`` (toward -inf), ``abs_add`` (away from zero), ``abs_sub``
    (toward zero) — and keeps the best; ``best`` here selects round-to-nearest
    on the parity grid, which is what "lowest accuracy drop" converges to.
    """
    step = 2 if macro.bias_parity_even else 1
    half = step / 2.0
    if method == "add":
        q = jnp.ceil(bias / step) * step
    elif method == "sub":
        q = jnp.floor(bias / step) * step
    elif method == "abs_add":
        q = jnp.sign(bias) * jnp.ceil(jnp.abs(bias) / step) * step
    elif method == "abs_sub":
        q = jnp.sign(bias) * jnp.floor(jnp.abs(bias) / step) * step
    elif method == "best":
        q = jnp.round(bias / step) * step
    else:
        raise ValueError(f"unknown bias mapping method: {method}")
    return jnp.clip(q, -macro.bias_range, macro.bias_range)


BIAS_MAPPING_METHODS = ("add", "sub", "abs_add", "abs_sub", "best")


# ---------------------------------------------------------------------------
# Chip instance: static Monte-Carlo noise realization
# ---------------------------------------------------------------------------


def sample_chip_offsets(key: jax.Array, channels_per_layer: Dict[str, int],
                        noise: IMCNoiseParams) -> Dict[str, jax.Array]:
    """Draw the static MAV offsets of one fabricated chip.

    One offset per output channel per IMC layer (each output channel is served
    by one bank / AVG-line pair, so the mismatch is static per channel).
    """
    offsets = {}
    for name, c in sorted(channels_per_layer.items()):
        key, sub = jax.random.split(key)
        offsets[name] = noise.mav_offset_std * jax.random.normal(sub, (c,))
    return offsets


# ---------------------------------------------------------------------------
# The MAV + SA forward path
# ---------------------------------------------------------------------------


def mav_sa(counts: jax.Array, bias_int: jax.Array, flip: jax.Array,
           mav_offset: jax.Array | None = None,
           sa_key: jax.Array | None = None,
           sa_noise_std: float = 0.0,
           sa_noise: jax.Array | None = None) -> jax.Array:
    """The macro's analog epilogue: sign(counts + bias + noise) with BN-decoder
    sign correction.  ``counts`` has channels on the last axis; ``bias_int``,
    ``flip`` and ``mav_offset`` are per-channel.

    The SA-noise realization comes either from ``sa_key``/``sa_noise_std``
    (drawn here, one value per evaluation) or as an explicit ``sa_noise``
    array broadcastable to ``counts`` — the streaming serving path draws its
    noise from a per-absolute-column field so cached columns keep the exact
    realization they were evaluated with (repro.serving.stream).  Both are
    added at the same point in the float chain, so the paths stay
    bit-identical."""
    pre = counts + bias_int
    if mav_offset is not None:
        pre = pre + mav_offset
    if sa_key is not None and sa_noise_std > 0.0:
        pre = pre + sa_noise_std * jax.random.normal(sa_key, pre.shape)
    elif sa_noise is not None:
        pre = pre + sa_noise
    return binarize(pre * flip)


def binary_group_conv_counts(x_bin: jax.Array, w_bin: jax.Array,
                             groups: int, stride: int = 1) -> jax.Array:
    """Integer conv counts for a 1-D binary group convolution.

    x_bin: (B, T, C_in) in {-1,+1};  w_bin: (K, C_in//groups, C_out) in {-1,+1}.
    Returns (B, T_out, C_out) integer-valued counts (sum of +/-1 products) —
    exactly what accumulates on the AVG lines before the SA.
    """
    dn = jax.lax.conv_dimension_numbers(x_bin.shape, w_bin.shape,
                                        ("NWC", "WIO", "NWC"))
    out = jax.lax.conv_general_dilated(
        x_bin.astype(jnp.float32), w_bin.astype(jnp.float32),
        window_strides=(stride,), padding="VALID",
        dimension_numbers=dn, feature_group_count=groups)
    return out


# ---------------------------------------------------------------------------
# Group-pack layout for the fused IMC layer kernel
# ---------------------------------------------------------------------------
#
# One IMC layer is `groups` independent small matmuls (fan-in k*cpg = 72,
# 24-96 output channels each).  Launching one MXU matmul per group pads every
# group's outputs to 128 lanes (~5x wasted columns for cog=24-48).  Instead we
# pack `gpb = lanes // cog` groups into one grid step: their patches are
# concatenated along the contraction axis and their weights placed on the
# diagonal of a (gpb*kg, gpb*cog) block-diagonal matrix, so one 128-lane MXU
# pass computes gpb groups at once (off-diagonal zeros contribute nothing,
# exactly like unused word lines).  The kernel grid is then
# (packs = ceil(groups/gpb), M-tiles).


@dataclasses.dataclass(frozen=True)
class GroupPackLayout:
    """Static geometry of one packed grouped layer.

    groups/cog: conv groups and output channels per group;
    kg: per-group fan-in (k * c_in_per_group);
    gpb: groups packed per grid step (share one 128-lane MXU pass);
    packs: grid extent over packed group blocks;
    lanes: MXU lane width the pack is sized against.
    """

    groups: int
    cog: int
    kg: int
    gpb: int
    packs: int
    lanes: int = 128

    @property
    def g_pad(self) -> int:
        """Groups padded up to a whole number of packs."""
        return self.packs * self.gpb

    @property
    def k_pack(self) -> int:
        """Contraction extent of one pack (gpb groups' fan-ins stacked)."""
        return self.gpb * self.kg

    @property
    def n_pack(self) -> int:
        """Output lanes of one pack (gpb groups' channels side by side)."""
        return self.gpb * self.cog


# Static pytree node: layouts ride inside PackedHWParams through jit
# boundaries as aux data (they are shape metadata, not arrays).
jax.tree_util.register_static(GroupPackLayout)


def make_group_pack_layout(groups: int, cog: int, k: int, cpg: int,
                           lanes: int = 128) -> GroupPackLayout:
    kg = k * cpg
    gpb = max(1, min(groups, lanes // cog)) if cog <= lanes else 1
    packs = -(-groups // gpb)
    return GroupPackLayout(groups=groups, cog=cog, kg=kg, gpb=gpb,
                           packs=packs, lanes=lanes)


def pack_grouped_weights(w: jax.Array, layout: GroupPackLayout) -> jax.Array:
    """(k, cpg, c_out) grouped weights -> (packs, k_pack, n_pack) block-diag.

    Pack p, slot j holds group g = p*gpb + j at diagonal block
    [j*kg:(j+1)*kg, j*cog:(j+1)*cog]; groups beyond `groups` are zero.
    """
    k, cpg, c_out = w.shape
    lt = layout
    wall = w.reshape(lt.kg, c_out)
    wall = jnp.pad(wall, ((0, 0), (0, lt.g_pad * lt.cog - c_out)))
    wg = wall.reshape(lt.kg, lt.g_pad, lt.cog).transpose(1, 0, 2)
    wg = wg.reshape(lt.packs, lt.gpb, lt.kg, lt.cog)
    bd = jnp.zeros((lt.packs, lt.gpb, lt.kg, lt.gpb, lt.cog), w.dtype)
    for j in range(lt.gpb):
        bd = bd.at[:, j, :, j, :].set(wg[:, j])
    return bd.reshape(lt.packs, lt.k_pack, lt.n_pack)


def pack_channel_param(v: jax.Array, layout: GroupPackLayout,
                       fill: float = 0.0) -> jax.Array:
    """Per-output-channel vector (c_out,) -> (packs, n_pack).

    Channels are group-contiguous pre-shuffle, so a pack's n_pack channels
    are one contiguous span; padded groups get `fill` (0 for bias/offset,
    1 for flip)."""
    lt = layout
    v = jnp.pad(v, (0, lt.g_pad * lt.cog - v.shape[0]), constant_values=fill)
    return v.reshape(lt.packs, lt.n_pack)


def pack_grouped_patches(x: jax.Array, layout: GroupPackLayout, k: int,
                         stride: int, t_use: int | None = None) -> jax.Array:
    """im2col per group, packed: (B, T, C_in) -> (packs, B*t_use, k_pack).

    Column layout within a pack matches pack_grouped_weights: slot j's fan-in
    is flattened (tap-major, channel-minor) at offset j*kg.  ``t_use`` limits
    the window positions (the caller truncates to a whole number of pool
    windows so OR-pooling can fuse into the kernel)."""
    b, t, c_in = x.shape
    lt = layout
    cpg = lt.kg // k
    t_out = (t - k) // stride + 1
    if t_use is None:
        t_use = t_out
    idx = jnp.arange(t_use)[:, None] * stride + jnp.arange(k)[None, :]
    win = x[:, idx, :]                                  # (B, t_use, k, C_in)
    win = jnp.pad(win, ((0, 0), (0, 0), (0, 0), (0, lt.g_pad * cpg - c_in)))
    win = win.reshape(b, t_use, k, lt.g_pad, cpg).transpose(0, 1, 3, 2, 4)
    win = win.reshape(b * t_use, lt.packs, lt.k_pack)
    return win.transpose(1, 0, 2)


class PackedLayer(NamedTuple):
    """Fold-time packed operands of one fused IMC layer.

    The block-diagonal weights and per-channel bias/flip are packed once
    (``pack_layer``) and MXU-lane padded, so the per-decision path only packs
    the data-dependent im2col patches — the programming of the SRAM arrays
    happens at fold time, not per decision.  ``layout`` is a static pytree
    node, so a PackedLayer passes transparently through jit."""

    layout: GroupPackLayout
    wp: jax.Array          # (packs, k_pad, n_pad) block-diagonal ±1 weights
    bias_p: jax.Array      # (packs, n_pad) word-line bias
    flip_p: jax.Array      # (packs, n_pad) BN-decoder sign (pad lanes = +1)


def _pad_axis(x: jax.Array, axis: int, mult: int, value: float = 0.0):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def pack_layer(w: jax.Array, bias: jax.Array, flip: jax.Array,
               groups: int, lanes: int = 128) -> PackedLayer:
    """Pack one grouped layer's static operands for the fused kernel.

    Identical padding to what ops.fused_conv_mav applies per call, so the
    precomputed and on-the-fly paths are bit-identical."""
    k, cpg, c_out = w.shape
    layout = make_group_pack_layout(groups, c_out // groups, k, cpg, lanes)
    k_pad = -(-layout.k_pack // lanes) * lanes
    n_pad = -(-layout.n_pack // lanes) * lanes
    wp = _pad_axis(_pad_axis(pack_grouped_weights(w, layout), 1, k_pad),
                   2, n_pad)
    bias_p = _pad_axis(pack_channel_param(bias, layout), 1, n_pad)
    flip_p = _pad_axis(pack_channel_param(flip, layout, fill=1.0), 1, n_pad,
                       value=1.0)
    return PackedLayer(layout=layout, wp=wp, bias_p=bias_p, flip_p=flip_p)


# ---------------------------------------------------------------------------
# Macro allocation / utilization accounting (paper Fig 8, §V-A)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerMapping:
    name: str
    weight_bits: int
    products_per_output: int      # fan-in of one SA decision
    out_channels: int
    macros: int
    banks: int
    utilization: float            # temporal utilization (pooling idles layers)


def map_layer_to_macros(name: str, c_out: int, c_in_per_group: int, k: int,
                        utilization: float,
                        macro: IMCMacroConfig = DEFAULT_MACRO) -> LayerMapping:
    """Allocate IMC banks for one binary conv layer.

    Each output channel needs ceil(fan_in / rows_available) bank columns plus
    the BN bias word-line; banks are grouped 8-to-a-macro (each bank serves one
    output at a time, Fig 6).
    """
    fan_in = c_in_per_group * k
    rows_avail = macro.rows - macro.bias_rows
    banks_per_channel = max(1, -(-fan_in // rows_avail))
    # 64 columns per bank hold 64 output channels' worth of one weight row each;
    # capacity-wise a bank stores rows*cols bits.
    weight_bits = c_out * fan_in + c_out * macro.cols  # weights + bias lines
    banks = -(-weight_bits // (macro.rows * macro.cols))
    macros = -(-banks // macro.banks_per_macro)
    return LayerMapping(name=name, weight_bits=weight_bits,
                        products_per_output=fan_in, out_channels=c_out,
                        macros=macros, banks=banks, utilization=utilization)
