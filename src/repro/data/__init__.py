from repro.data import audio, tokens

__all__ = ["audio", "tokens"]
