"""Deterministic, shardable, resumable synthetic token pipeline for the LM
architectures (offline container: no real corpora).

Production properties implemented:
  * deterministic in (seed, step, host) — any host can regenerate any batch,
  * O(1) resume: the cursor is just the step counter (checkpointed),
  * per-host sharding: host h of H draws the h-th slice of the global batch,
    so data-parallel groups never duplicate samples,
  * packing: documents of random length packed into fixed seq_len with EOS.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0
    eos_id: int = 1

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


def batch_at_step(cfg: TokenPipelineConfig, step: int):
    """Return (tokens, labels) uint32 arrays of shape (host_batch, seq_len).

    Labels are next-token targets (shifted), with EOS boundaries from the
    packing.  Markov-ish structure (token depends on previous token) so
    the model has learnable signal in smoke tests.
    """
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id]))
    b, s, v = cfg.host_batch, cfg.seq_len, cfg.vocab_size
    base = rng.integers(2, v, size=(b, s), dtype=np.int64)
    # cheap short-range structure: mix previous token into the current one
    mixed = base.copy()
    mixed[:, 1:] = (base[:, 1:] + (mixed[:, :-1] // 3)) % (v - 2) + 2
    # document packing: EOS roughly every ~256 tokens
    doc_break = rng.random((b, s)) < (1.0 / 256.0)
    mixed[doc_break] = cfg.eos_id
    tokens = mixed.astype(np.uint32)
    labels = np.roll(tokens, -1, axis=1)
    labels[:, -1] = cfg.eos_id
    return tokens, labels
