"""Synthetic keyword-spotting corpus (GSCD stand-in) + personal sets.

The Google Speech Commands dataset and the paper's private 3-speaker personal
set are not available offline, so we synthesize a corpus with the same
statistical *structure* (DESIGN.md §4):

  * 10 keyword classes.  Each class is a distinct spectro-temporal signature
    (2-3 "phoneme" segments; each segment = harmonic stack with class-specific
    formant trajectory + chirp + amplitude modulation).  The binarized sinc
    filter bank front-end of the model is exactly the right inductive bias to
    separate these.
  * Speakers.  A speaker is a (pitch, formant-scale, tempo, breathiness)
    tuple.  Training speakers are drawn around the neutral voice; *personal*
    speakers (the customization target) carry a systematic accent shift —
    formants scaled and tempo skewed — which degrades the base model the same
    way regional accents degrade the paper's (Table IV's premise).
  * Augmentation follows §VI-A3: Gaussian noise with amplitude in
    [0.001, 0.015] and random time shift in [-0.5s, 0.5s].

Everything is deterministic in the seed and pure NumPy (data pipeline stays
off the accelerator, as in any production input pipeline).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

SAMPLE_RATE = 16_000
NUM_CLASSES = 10
KEYWORDS = ("yes", "no", "up", "down", "left", "right", "stop", "go", "on", "off")


@dataclasses.dataclass(frozen=True)
class Speaker:
    pitch: float          # fundamental, Hz
    formant_scale: float  # multiplies all formant frequencies
    tempo: float          # 1.0 = nominal segment durations
    noise_floor: float


def _speaker(rng: np.random.Generator, accent_shift: float = 0.0) -> Speaker:
    """accent_shift = 0: GSCD-like population; > 0: 'personal' accent."""
    return Speaker(
        pitch=float(rng.uniform(95, 240)),
        formant_scale=float(rng.uniform(0.95, 1.05) * (1.0 + accent_shift)),
        tempo=float(rng.uniform(0.92, 1.08) * (1.0 + 0.5 * accent_shift)),
        noise_floor=float(rng.uniform(0.002, 0.006)),
    )


# Class signatures: per segment (formant_1 Hz, formant_2 Hz, chirp factor,
# AM rate Hz).  Spread across the audible band so a 24-filter learned filter
# bank can separate them.
def _class_segments(c: int) -> list:
    # Each class owns a frequency band (multiplicative spacing 1.33 >> the
    # +/-5% speaker formant spread) plus a distinct temporal signature
    # (segment count, AM rate).  A ~0.18 accent shift (personal set) pushes
    # utterances toward the neighbouring band — the distribution shift that
    # customization must fix.
    # Bands live in 1-7 kHz: a binarized 15-tap filter at 16 kHz can only
    # resolve sign-oscillation periods <= its support (~1 kHz and up), so the
    # synthetic corpus puts the discriminative energy where the paper's
    # front-end has resolution.
    base = 1050.0 * (1.23 ** c)                  # 1.05 .. 6.7 kHz
    segs = []
    n_seg = 2 + (c % 2)
    for j in range(n_seg):
        f1 = base * (1.0 + 0.10 * j)
        f2 = min(f1 * 1.55, 7500.0)
        chirp = (-1) ** (c + j) * 0.12
        am = 4.0 + 3.0 * ((c * 3 + j) % 4)
        segs.append((f1, f2, chirp, am))
    return segs


def synthesize_utterance(c: int, spk: Speaker, rng: np.random.Generator,
                         augment: bool = True,
                         length: int = SAMPLE_RATE) -> np.ndarray:
    segs = _class_segments(c)
    # active speech ~55% of the window (scales with reduced smoke lengths)
    dur_samples = int(0.55 * length / spk.tempo)
    seg_len = max(8, min(dur_samples, length) // len(segs))
    sig = np.zeros(length, dtype=np.float64)
    start = max(0, (length - seg_len * len(segs)) // 2)
    t = np.arange(seg_len) / SAMPLE_RATE
    for j, (f1, f2, chirp, am) in enumerate(segs):
        f1 = f1 * spk.formant_scale
        f2 = f2 * spk.formant_scale
        env = np.sin(np.pi * np.arange(seg_len) / seg_len) ** 2
        inst1 = f1 * (1.0 + chirp * t)
        inst2 = f2 * (1.0 - 0.5 * chirp * t)
        ph1 = 2 * np.pi * np.cumsum(inst1) / SAMPLE_RATE
        ph2 = 2 * np.pi * np.cumsum(inst2) / SAMPLE_RATE
        php = 2 * np.pi * spk.pitch * t
        mod = 0.6 + 0.4 * np.cos(2 * np.pi * am * t)
        seg = env * mod * (0.55 * np.sin(ph1) + 0.3 * np.sin(ph2)
                           + 0.15 * np.sin(php))
        s0 = start + j * seg_len
        sig[s0:s0 + seg_len] += seg
    sig += spk.noise_floor * rng.standard_normal(length)

    if augment:                                  # §VI-A3 augmentation
        sig += rng.uniform(0.001, 0.015) * rng.standard_normal(length)
        # paper: +/-0.5s shift on a 1s window; scale to the window so the
        # keyword stays (partially) inside at reduced smoke lengths
        shift = int(rng.uniform(-0.22, 0.22) * length)
        sig = np.roll(sig, shift)
        if shift > 0:
            sig[:shift] = 0.0
        elif shift < 0:
            sig[shift:] = 0.0

    peak = np.max(np.abs(sig)) + 1e-9
    sig = sig / peak * 0.9
    # 8-bit raw audio input (paper §II): quantize onto the int8 grid.
    return np.round(sig * 127.0) / 127.0


def make_dataset(seed: int, n_per_class: int, n_speakers: int,
                 accent_shift: float = 0.0, augment: bool = True,
                 length: int = SAMPLE_RATE) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (audio float32 (N, length) on the int8 grid, labels int32 (N,))."""
    rng = np.random.default_rng(seed)
    speakers = [_speaker(rng, accent_shift) for _ in range(n_speakers)]
    xs, ys = [], []
    for c in range(NUM_CLASSES):
        for i in range(n_per_class):
            spk = speakers[(c * n_per_class + i) % n_speakers]
            xs.append(synthesize_utterance(c, spk, rng, augment, length))
            ys.append(c)
    x = np.stack(xs).astype(np.float32)
    y = np.asarray(ys, dtype=np.int32)
    perm = rng.permutation(len(y))
    return x[perm], y[perm]


def make_gscd_like(seed: int = 0, train_per_class: int = 120,
                   test_per_class: int = 30, length: int = SAMPLE_RATE):
    """The 'original dataset' stand-in (many speakers, no accent shift)."""
    xtr, ytr = make_dataset(seed, train_per_class, n_speakers=40,
                            accent_shift=0.0, augment=True, length=length)
    xte, yte = make_dataset(seed + 1, test_per_class, n_speakers=12,
                            accent_shift=0.0, augment=False, length=length)
    return (xtr, ytr), (xte, yte)


def make_personal(seed: int = 100, train_per_class: int = 3,
                  test_per_class: int = 17, n_people: int = 3,
                  accent_shift: float = 0.22, length: int = SAMPLE_RATE):
    """The personal set (§VI-A2): 3 people, 3 utterances/keyword/person for
    training (90 utterances), the rest for test; systematic accent."""
    rng = np.random.default_rng(seed)
    people = [_speaker(rng, accent_shift) for _ in range(n_people)]
    xtr, ytr, xte, yte = [], [], [], []
    for c in range(NUM_CLASSES):
        for p, spk in enumerate(people):
            for i in range(train_per_class):
                xtr.append(synthesize_utterance(c, spk, rng, False, length))
                ytr.append(c)
            for i in range(test_per_class):
                xte.append(synthesize_utterance(c, spk, rng, False, length))
                yte.append(c)
    to = lambda a, d: np.asarray(a, dtype=d)
    return ((np.stack(xtr).astype(np.float32), to(ytr, np.int32)),
            (np.stack(xte).astype(np.float32), to(yte, np.int32)))
