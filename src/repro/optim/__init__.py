from repro.optim.optimizers import (Optimizer, adam, clip_by_global_norm,
                                    cosine_schedule, sgd, step_decay_schedule)
from repro.optim.quantized import QuantizedSGDState, quantized_sgd_init, quantized_sgd_step

__all__ = [
    "Optimizer", "adam", "sgd", "cosine_schedule", "step_decay_schedule",
    "clip_by_global_norm", "QuantizedSGDState", "quantized_sgd_init",
    "quantized_sgd_step",
]
