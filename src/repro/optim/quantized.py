"""Fixed-point SGD with SGA banking, as a generic optimizer (paper Alg 1).

This wraps the paper's on-chip update rule (weight grid Q1.7, 16-bit SGA
accumulators, optional RGP noise) into the same pytree-optimizer shape as
repro.optim.optimizers, so it can drive *any* head — including distributed
ones (the SGA state shards like a second momentum buffer).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.onchip_training import rgp_noise, sga_step, sga_threshold
from repro.core.quantize import ACCUM_Q, GRAD_Q, WEIGHT_Q, QFormat


class QuantizedSGDState(NamedTuple):
    step: jax.Array
    accum: object            # SGA banks, one per parameter leaf
    key: jax.Array


def quantized_sgd_init(params, seed: int = 0) -> QuantizedSGDState:
    return QuantizedSGDState(
        step=jnp.zeros((), jnp.int32),
        accum=jax.tree_util.tree_map(jnp.zeros_like, params),
        key=jax.random.PRNGKey(seed),
    )


def quantized_sgd_step(grads, state: QuantizedSGDState, params,
                       lr: jax.Array | float,
                       sga: bool = True,
                       rgp_lambda: Optional[float] = None,
                       weight_fmt: QFormat = WEIGHT_Q,
                       grad_fmt: QFormat = GRAD_Q,
                       accum_fmt: QFormat = ACCUM_Q
                       ) -> Tuple[object, QuantizedSGDState]:
    lr = jnp.asarray(lr, jnp.float32)
    g_th = sga_threshold(lr, weight_fmt)
    key = state.key
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    accum_leaves = treedef.flatten_up_to(state.accum)
    param_leaves = treedef.flatten_up_to(params)

    new_params, new_accum = [], []
    for g, a, p in zip(leaves, accum_leaves, param_leaves):
        g = grad_fmt.quantize(g)
        if rgp_lambda is not None:
            key, sub = jax.random.split(key)
            g = grad_fmt.quantize(g + rgp_noise(sub, g.shape, rgp_lambda,
                                                grad_fmt))
        if sga:
            g, a = sga_step(g, a, g_th, accum_fmt)
        new_params.append(weight_fmt.quantize(p - lr * g))
        new_accum.append(a)

    return (jax.tree_util.tree_unflatten(treedef, new_params),
            QuantizedSGDState(step=state.step + 1,
                              accum=jax.tree_util.tree_unflatten(treedef,
                                                                 new_accum),
                              key=key))
