"""Minimal production optimizer library (pure pytrees, optax-style API).

Implemented from scratch (the container is offline): Adam(W), SGD+momentum,
cosine and step-decay schedules, global-norm clipping.  All states are
pytrees so they shard/checkpoint exactly like parameters (FSDP shards the
Adam moments over the `data` axis — see repro.launch.mesh_policy).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]


def cosine_schedule(base_lr: float, total_steps: int,
                    warmup_steps: int = 0, min_lr: float = 0.0) -> Schedule:
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(1.0, warmup_steps)
        prog = jnp.clip((step - warmup_steps)
                        / jnp.maximum(1.0, total_steps - warmup_steps), 0.0, 1.0)
        cos = min_lr + 0.5 * (base_lr - min_lr) * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup_steps, warm, cos)
    return fn


def step_decay_schedule(base_lr: float, decay: float, every: int,
                        min_lr: float = 0.0) -> Schedule:
    """The paper's customization schedule: halve every N epochs, floor at
    min_lr (§VI-A3: 1/16 -> x0.5 every 10 epochs -> 1/128)."""
    def fn(step):
        lr = base_lr * decay ** (jnp.asarray(step) // every)
        return jnp.maximum(lr, min_lr)
    return fn


class OptState(NamedTuple):
    step: jax.Array
    mu: object        # first moment / momentum pytree (or None-like zeros)
    nu: object        # second moment pytree (Adam only)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[object], OptState]
    update: Callable[[object, OptState, object], Tuple[object, OptState]]
    schedule: Schedule


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gnorm


def adam(schedule: Schedule, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0,
         clip_norm: Optional[float] = None) -> Optimizer:
    def init(params):
        z = jax.tree_util.tree_map(jnp.zeros_like, params)
        return OptState(step=jnp.zeros((), jnp.int32), mu=z,
                        nu=jax.tree_util.tree_map(jnp.zeros_like, params))

    def update(grads, state, params):
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        lr = schedule(step)
        b1c = 1 - b1 ** step.astype(jnp.float32)
        b2c = 1 - b2 ** step.astype(jnp.float32)

        mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                                    state.mu, grads)
        nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                                    state.nu, grads)

        def upd(p, m, v):
            mh, vh = m / b1c, v / b2c
            return p - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p)

        new_params = jax.tree_util.tree_map(upd, params, mu, nu)
        return new_params, OptState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update, schedule=schedule)


def sgd(schedule: Schedule, momentum: float = 0.0,
        clip_norm: Optional[float] = None) -> Optimizer:
    def init(params):
        mu = jax.tree_util.tree_map(jnp.zeros_like, params)
        return OptState(step=jnp.zeros((), jnp.int32), mu=mu, nu=None)

    def update(grads, state, params):
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        lr = schedule(step)
        if momentum:
            mu = jax.tree_util.tree_map(lambda m, g: momentum * m + g,
                                        state.mu, grads)
        else:
            mu = grads
        new_params = jax.tree_util.tree_map(lambda p, m: p - lr * m, params, mu)
        return new_params, OptState(step=step, mu=mu if momentum else state.mu,
                                    nu=None)

    return Optimizer(init=init, update=update, schedule=schedule)
