"""jit'd public wrappers around the imc_mav Pallas kernel: padding to tile
boundaries, im2col for the binary group conv, and the (B, T, C) activation
interface used by repro.models.kws."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.imc_mav.imc_mav import imc_mav


def _pad_to(x: jax.Array, axis: int, mult: int):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def mav_matmul(x: jax.Array, w: jax.Array, bias: jax.Array, flip: jax.Array,
               noise: jax.Array | None = None, interpret: bool = True
               ) -> jax.Array:
    """Tile-padded entry: x (M,K) ±1, w (K,N) ±1 -> (M,N) ±1."""
    m0, n0 = x.shape[0], w.shape[1]
    bm, bn = 256, 128
    x, _ = _pad_to(x, 0, bm)
    x, _ = _pad_to(x, 1, 128)
    w, _ = _pad_to(w, 0, 128)
    w, _ = _pad_to(w, 1, bn)
    bias, _ = _pad_to(bias, 0, bn)
    flip = jnp.pad(flip, (0, bias.shape[0] - flip.shape[0]),
                   constant_values=1.0)
    if noise is not None:
        noise, _ = _pad_to(noise, 0, bm)
        noise, _ = _pad_to(noise, 1, bn)
    out = imc_mav(x, w, bias, flip, noise, bm=bm, bn=bn, interpret=interpret)
    return out[:m0, :n0]


def mav_sa_apply(counts: jax.Array, bias: jax.Array, flip: jax.Array,
                 sa_key: jax.Array | None, sa_noise_std: float,
                 interpret: bool = True) -> jax.Array:
    """Epilogue-only path used when counts are already computed (the model's
    conv produces counts; the kernel fuses bias+noise+SA)."""
    b, t, c = counts.shape
    x = counts.reshape(b * t, c)
    noise = None
    if sa_key is not None and sa_noise_std > 0:
        noise = sa_noise_std * jax.random.normal(sa_key, x.shape)
    # identity "matmul": route counts through the epilogue with W=I is
    # wasteful — use the epilogue math directly in jnp instead; the full
    # kernel path is exercised via conv_mav below.
    pre = x + bias[None, :]
    if noise is not None:
        pre = pre + noise
    pre = pre * flip[None, :]
    out = jnp.where(pre >= 0, 1.0, -1.0).astype(counts.dtype)
    return out.reshape(b, t, c)


def _im2col(x: jax.Array, k: int, stride: int) -> jax.Array:
    """x (B, T, C) -> patches (B, T_out, k*C)."""
    b, t, c = x.shape
    t_out = (t - k) // stride + 1
    idx = jnp.arange(t_out)[:, None] * stride + jnp.arange(k)[None, :]
    patches = x[:, idx, :]                       # (B, T_out, k, C)
    return patches.reshape(b, t_out, k * c)


def conv_mav(x: jax.Array, w: jax.Array, bias: jax.Array, flip: jax.Array,
             groups: int, stride: int = 1,
             sa_key: jax.Array | None = None, sa_noise_std: float = 0.0,
             interpret: bool = True) -> jax.Array:
    """Full IMC layer through the Pallas kernel: binary group conv (as an
    im2col matmul per group) + in-memory BN + SA.

    x: (B, T, C_in) ±1;  w: (K, C_in//groups, C_out) ±1.
    """
    b, t, c_in = x.shape
    k, cpg, c_out = w.shape
    cog = c_out // groups
    t_out = (t - k) // stride + 1
    outs = []
    key = sa_key
    for g in range(groups):
        xg = x[..., g * cpg:(g + 1) * cpg]
        wg = w[..., g * cog:(g + 1) * cog]            # (K, cpg, cog)
        patches = _im2col(xg, k, stride).reshape(b * t_out, k * cpg)
        wmat = wg.reshape(k * cpg, cog)
        noise = None
        if key is not None and sa_noise_std > 0:
            key, sub = jax.random.split(key)
            noise = sa_noise_std * jax.random.normal(
                sub, (b * t_out, cog), jnp.float32)
        og = mav_matmul(patches, wmat, bias[g * cog:(g + 1) * cog],
                        flip[g * cog:(g + 1) * cog], noise,
                        interpret=interpret)
        outs.append(og.reshape(b, t_out, cog))
    return jnp.concatenate(outs, axis=-1)
