"""jit'd public wrappers around the imc_mav Pallas kernels: padding to tile
boundaries, im2col for the binary group conv, and the (B, T, C) activation
interface used by repro.models.kws.

**One-launch-per-layer invariant.**  ``fused_conv_mav`` is the inference
hot path: the whole IMC layer (grouped binary conv + static chip offset +
in-memory BN bias + SA noise + BN-decoder flip + SA sign + channel shuffle
+ OR-maxpool) in exactly one ``pallas_call``, with the weight packs in the
kernel grid and the batch in the M tiling — so the launch count of a
forward (or of a whole fleet of batched streams, see
repro.serving.scheduler) is one per IMC layer, period.
``fused_conv_mav_step`` is the time-sliced streaming entry: same packed
operands, same single launch, but M covers only a hop's carry + fresh
columns (~hop/window of the full-window work — repro.serving.stream owns
the geometry).

**Per-absolute-column SA-noise field.**  The ``sa_noise`` operand is an
explicit pre-pool noise realization, (B, t_conv, C_out).  The streaming
path evaluates it from a field keyed by
``fold_in(fold_in(stream_key, layer), absolute_column)``: a column's noise
sample is a property of its single sense-amplifier evaluation, so it rides
along with the cached activation across hops, and an offline window that
evaluates the same field reproduces the streaming output bit-exactly.
``sa_key``/``sa_noise_std`` is the alternative fresh-draw form used by the
non-streaming forward; the two are mutually exclusive.

Both fused entries are shape-stable jit-pure functions, so they compose
under ``lax.scan``: the compiled whole-tick block (repro.serving.compiled)
traces ``fused_conv_mav_step`` once per layer inside its scan body and the
runtime re-issues that single launch per fused tick — the
one-launch-per-layer invariant carries into the K-tick fast path for free.

The per-group ``conv_mav`` loop below is kept as the seed baseline the
fused kernel is benchmarked against (benchmarks/run.py::imc_fused_bench).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import imc
from repro.kernels import default_interpret
from repro.kernels.imc_mav.imc_mav import imc_fused, imc_mav


def _pad_to(x: jax.Array, axis: int, mult: int, value: float = 0.0):
    # one pad implementation shared with the fold-time packing
    # (core.imc.pack_layer) so both paths stay bit-identical by construction
    padded = imc._pad_axis(x, axis, mult, value)
    return padded, padded.shape[axis] - x.shape[axis]


def mav_matmul(x: jax.Array, w: jax.Array, bias: jax.Array, flip: jax.Array,
               noise: jax.Array | None = None, interpret: bool | None = None
               ) -> jax.Array:
    """Tile-padded entry: x (M,K) ±1, w (K,N) ±1 -> (M,N) ±1."""
    if interpret is None:
        interpret = default_interpret()
    m0, n0 = x.shape[0], w.shape[1]
    bm, bn = 256, 128
    x, _ = _pad_to(x, 0, bm)
    x, _ = _pad_to(x, 1, 128)
    w, _ = _pad_to(w, 0, 128)
    w, _ = _pad_to(w, 1, bn)
    bias, _ = _pad_to(bias, 0, bn)
    flip = jnp.pad(flip, (0, bias.shape[0] - flip.shape[0]),
                   constant_values=1.0)
    if noise is not None:
        noise, _ = _pad_to(noise, 0, bm)
        noise, _ = _pad_to(noise, 1, bn)
    out = imc_mav(x, w, bias, flip, noise, bm=bm, bn=bn, interpret=interpret)
    return out[:m0, :n0]


def _im2col(x: jax.Array, k: int, stride: int) -> jax.Array:
    """x (B, T, C) -> patches (B, T_out, k*C)."""
    b, t, c = x.shape
    t_out = (t - k) // stride + 1
    idx = jnp.arange(t_out)[:, None] * stride + jnp.arange(k)[None, :]
    patches = x[:, idx, :]                       # (B, T_out, k, C)
    return patches.reshape(b, t_out, k * c)


def conv_mav(x: jax.Array, w: jax.Array, bias: jax.Array, flip: jax.Array,
             groups: int, stride: int = 1,
             sa_key: jax.Array | None = None, sa_noise_std: float = 0.0,
             interpret: bool | None = None) -> jax.Array:
    """Seed per-group-loop path: one tiny ``pallas_call`` per conv group,
    each padding its output channels to 128 lanes.  Superseded by
    ``fused_conv_mav`` on the hot path; kept as the benchmark baseline.

    x: (B, T, C_in) ±1;  w: (K, C_in//groups, C_out) ±1.
    """
    b, t, c_in = x.shape
    k, cpg, c_out = w.shape
    cog = c_out // groups
    t_out = (t - k) // stride + 1
    outs = []
    key = sa_key
    for g in range(groups):
        xg = x[..., g * cpg:(g + 1) * cpg]
        wg = w[..., g * cog:(g + 1) * cog]            # (K, cpg, cog)
        patches = _im2col(xg, k, stride).reshape(b * t_out, k * cpg)
        wmat = wg.reshape(k * cpg, cog)
        noise = None
        if key is not None and sa_noise_std > 0:
            key, sub = jax.random.split(key)
            noise = sa_noise_std * jax.random.normal(
                sub, (b * t_out, cog), jnp.float32)
        og = mav_matmul(patches, wmat, bias[g * cog:(g + 1) * cog],
                        flip[g * cog:(g + 1) * cog], noise,
                        interpret=interpret)
        outs.append(og.reshape(b, t_out, cog))
    return jnp.concatenate(outs, axis=-1)


def fused_conv_mav(x: jax.Array, w: jax.Array, bias: jax.Array,
                   flip: jax.Array, groups: int, stride: int = 1,
                   pool: int = 1,
                   chip_offset: jax.Array | None = None,
                   sa_key: jax.Array | None = None,
                   sa_noise_std: float = 0.0,
                   sa_noise: jax.Array | None = None,
                   interpret: bool | None = None,
                   packed: imc.PackedLayer | None = None) -> jax.Array:
    """The whole IMC layer in one ``pallas_call``: grouped binary conv +
    static chip offset + in-memory BN bias + SA noise + BN-decoder flip +
    SA sign + channel shuffle + OR-maxpool.

    x: (B, T, C_in) ±1;  w: (K, C_in//groups, C_out) ±1;
    bias/flip/chip_offset: (C_out,).  Returns (B, T_pool, C_out) ±1 in the
    *post-shuffle* channel order — the shuffle is the kernel's output index
    map (see imc_mav.py), not a separate pass.

    The SA noise realization is either drawn here from ``sa_key`` (same
    key/shape as ``core.imc.mav_sa``) or supplied explicitly as ``sa_noise``
    (B, t_out, C_out) — the streaming path evaluates a per-absolute-column
    noise field so cached columns keep their realization across hops.
    ``packed`` (see ``core.imc.pack_layer``) supplies the fold-time packed
    weights/bias/flip so only the data-dependent patches are packed per call.

    Bit-identical (noise path included) to

        counts = imc.binary_group_conv_counts(x, w, groups, stride)
        h = imc.mav_sa(counts + chip_offset, bias, flip, sa_key=...,
                       sa_noise_std=..., sa_noise=...)
        h = or_maxpool(channel_shuffle(h, groups), pool, axis=1)
    """
    if interpret is None:
        interpret = default_interpret()
    b, t, c_in = x.shape
    k, cpg, c_out = w.shape
    cog = c_out // groups
    t_out = (t - k) // stride + 1
    t_pool = t_out // pool
    t_use = t_pool * pool
    if t_use <= 0:
        raise ValueError(
            f"fused_conv_mav: input T={t} yields no complete pool window "
            f"(k={k}, stride={stride}, pool={pool}) — input too short for "
            f"this layer")
    if packed is None:
        packed = imc.pack_layer(w, bias, flip, groups)
    layout = packed.layout
    assert layout == imc.make_group_pack_layout(groups, cog, k, cpg), \
        "packed operands do not match this layer's shape"
    k_pad, n_pad = packed.wp.shape[1], packed.wp.shape[2]

    xp = imc.pack_grouped_patches(x, layout, k, stride, t_use)
    off = (jnp.zeros((c_out,), jnp.float32) if chip_offset is None
           else chip_offset.astype(jnp.float32))
    offp = imc.pack_channel_param(off, layout)

    noisep = None
    if sa_key is not None and sa_noise_std > 0:
        # Same draw as the jnp path (imc.mav_sa over (B, t_out, C_out)) so
        # the fused layer is bit-identical noise included.
        noise = sa_noise_std * jax.random.normal(sa_key, (b, t_out, c_out))
    elif sa_noise is not None:
        noise = sa_noise
    else:
        noise = None
    if noise is not None:
        noise = noise[:, :t_use].reshape(b * t_use, c_out)
        noise = jnp.pad(noise, ((0, 0), (0, layout.g_pad * cog - c_out)))
        noisep = noise.reshape(b * t_use, layout.packs,
                               layout.n_pack).transpose(1, 0, 2)

    # M-tile: multiple of the pool window (windows never straddle a tile or
    # the zero padding — M0 = B*t_use is already a whole number of windows).
    m0 = b * t_use
    bm_out = -(-min(256, -(-m0 // pool)) // 8) * 8
    bm = bm_out * pool
    xp, _ = _pad_to(xp, 1, bm)
    xp, _ = _pad_to(xp, 2, k_pad)
    offp, _ = _pad_to(offp, 1, n_pad)
    if noisep is not None:
        noisep, _ = _pad_to(noisep, 1, bm)
        noisep, _ = _pad_to(noisep, 2, n_pad)

    out = imc_fused(xp, packed.wp, offp, packed.bias_p, packed.flip_p,
                    noisep, gpb=layout.gpb, cog=cog,
                    pool=pool, bm=bm, interpret=interpret)
    # (M_pad/pool, cog, g_pad): crop pad rows/groups; flattening (cog,
    # groups) is exactly channel_shuffle's a*groups + g order.
    out = out[:b * t_pool, :, :groups]
    return out.reshape(b, t_pool, c_out)


def fused_conv_mav_step(x_tail: jax.Array, w: jax.Array, bias: jax.Array,
                        flip: jax.Array, groups: int, stride: int = 1,
                        pool: int = 1,
                        chip_offset: jax.Array | None = None,
                        sa_noise: jax.Array | None = None,
                        interpret: bool | None = None,
                        packed: imc.PackedLayer | None = None) -> jax.Array:
    """Time-sliced (frame-incremental) entry into the fused IMC layer.

    ``x_tail`` (B, T_tail, C_in) is the layer's streaming tail: the carry
    columns cached from the previous hop (the k-1 conv overlap plus, on
    odd-length pooling layers, the conv column the previous window's
    OR-maxpool truncated) followed by the hop's fresh input columns
    (repro.serving.stream computes the geometry).  Same pack layout as the
    full-window call; the kernel grid is restricted to the new output
    columns because M = B * T_tail_use instead of B * T_window — the
    per-hop work is the hop/window fraction of a full decision.

    The caller guarantees the tail starts on a pool-window boundary of the
    full window, so the fused OR-maxpool pairs exactly the columns the
    offline path pairs.  ``sa_noise`` (B, t_conv_tail, C_out) must hold the
    noise-field values of the tail's absolute conv columns for the noisy
    path to stay bit-identical to the offline window."""
    t_tail = x_tail.shape[1]
    k = w.shape[0]
    t_conv = (t_tail - k) // stride + 1
    if t_conv < pool:
        raise ValueError(
            f"fused_conv_mav_step: tail T={t_tail} yields {t_conv} conv "
            f"columns — not enough for one pool-{pool} window")
    return fused_conv_mav(x_tail, w, bias, flip, groups=groups,
                          stride=stride, pool=pool, chip_offset=chip_offset,
                          sa_noise=sa_noise, interpret=interpret,
                          packed=packed)
