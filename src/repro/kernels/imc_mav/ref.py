"""Pure-jnp oracle for the imc_mav kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def imc_mav_ref(x: jax.Array, w: jax.Array, bias: jax.Array,
                flip: jax.Array, noise: jax.Array | None = None) -> jax.Array:
    counts = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    pre = counts + bias[None, :]
    if noise is not None:
        pre = pre + noise
    pre = pre * flip[None, :]
    return jnp.where(pre >= 0, 1.0, -1.0).astype(x.dtype)
