"""Pure-jnp oracle for the imc_mav kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def imc_mav_ref(x: jax.Array, w: jax.Array, bias: jax.Array,
                flip: jax.Array, noise: jax.Array | None = None) -> jax.Array:
    counts = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    pre = counts + bias[None, :]
    if noise is not None:
        pre = pre + noise
    pre = pre * flip[None, :]
    return jnp.where(pre >= 0, 1.0, -1.0).astype(x.dtype)


def fused_conv_mav_ref(x: jax.Array, w: jax.Array, bias: jax.Array,
                       flip: jax.Array, groups: int, stride: int = 1,
                       pool: int = 1, chip_offset: jax.Array | None = None,
                       sa_key: jax.Array | None = None,
                       sa_noise_std: float = 0.0) -> jax.Array:
    """Oracle for ops.fused_conv_mav: the whole IMC layer via the model's
    count-exact primitives (conv counts -> mav_sa -> shuffle -> OR-pool)."""
    from repro.core import imc
    from repro.core.binary import channel_shuffle, or_maxpool

    counts = imc.binary_group_conv_counts(x, w, groups=groups, stride=stride)
    if chip_offset is not None:
        counts = counts + chip_offset
    h = imc.mav_sa(counts, bias, flip, sa_key=sa_key,
                   sa_noise_std=sa_noise_std)
    h = channel_shuffle(h, groups)
    if pool > 1:
        h = or_maxpool(h, pool, axis=1)
    return h
