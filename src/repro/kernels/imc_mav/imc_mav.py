"""Pallas TPU kernel: the IMC macro's MAV + in-memory-BN + SA epilogue.

TPU-native adaptation of the SRAM crossbar (DESIGN.md §3): the ±1 inner
product runs on the MXU as a bf16 matmul over VMEM-resident tiles; the
in-memory BN bias add, optional analog-noise injection and the SA 1-bit
decision are fused into the epilogue so pre-activations never touch HBM —
mirroring how the macro never digitizes the analog MAV value.

Layout: X (M, K) ±1 activations/patches, W (K, N) ±1 weights, bias (N,)
integer word-line bias, flip (N,) BN-decoder sign, optional noise (M, N)
(MAV offset + SA variation realization).  K is the macro fan-in (<= 64 per
bank physically; padded to 128 here for MXU lane alignment — zero padding
contributes 0 to the count, exactly like unused word lines).  The W tile is
grid-invariant along M so weights stay VMEM-resident across the batch grid,
the TPU analogue of weight-stationary in-SRAM storage.

Two kernels live here:

* ``imc_mav`` — the original single-matmul tile kernel (one launch per group;
  kept as the per-group reference path and for generic ±1 matmuls);
* ``imc_fused`` — the whole-IMC-layer kernel used by the model's hardware
  path.  Grid/packing layout (see ``repro.core.imc.GroupPackLayout``):

    grid = (packs, M-tiles), packs = ceil(groups / gpb), gpb = 128 // cog

  Each grid step multiplies one pack of ``gpb`` groups at once: their im2col
  patches are concatenated along the contraction axis (k_pack = gpb*kg) and
  their weights sit on the diagonal of a (k_pack, n_pack) block-diagonal
  matrix, so small per-group channel counts (24-96) share the 128 MXU lanes
  instead of each padding to 128.  The epilogue fuses the entire digital
  block after the macro — static chip offset, integer word-line bias, SA
  noise, BN-decoder flip, SA sign, OR-maxpool over ``pool`` adjacent window
  positions — and the channel shuffle is realized as the *output index map*:
  the output array is (M/pool, cog, groups) with pack p writing lane-slab
  [..., p*gpb:(p+1)*gpb], which flattens to the shuffled channel order
  a*groups + g with no separate shuffle pass.  ±1 activations therefore go
  conv -> pool without any pre-activation ever touching HBM, mirroring how
  the macro never digitizes the analog MAV value.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mav_kernel(x_ref, w_ref, b_ref, f_ref, o_ref):
    counts = jnp.dot(x_ref[...], w_ref[...],
                     preferred_element_type=jnp.float32)
    pre = (counts + b_ref[...][None, :]) * f_ref[...][None, :]
    o_ref[...] = jnp.where(pre >= 0, 1.0, -1.0).astype(o_ref.dtype)


def _mav_kernel_noise(x_ref, w_ref, b_ref, f_ref, n_ref, o_ref):
    counts = jnp.dot(x_ref[...], w_ref[...],
                     preferred_element_type=jnp.float32)
    pre = counts + b_ref[...][None, :] + n_ref[...]
    pre = pre * f_ref[...][None, :]
    o_ref[...] = jnp.where(pre >= 0, 1.0, -1.0).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "interpret"))
def imc_mav(x: jax.Array, w: jax.Array, bias: jax.Array, flip: jax.Array,
            noise: jax.Array | None = None, *, bm: int = 256, bn: int = 128,
            interpret: bool = True) -> jax.Array:
    """sign((x @ w + bias [+ noise]) * flip) with VMEM-fused epilogue.

    x: (M, K) ±1; w: (K, N) ±1; bias/flip: (N,); noise: (M, N) or None.
    M, N must be multiples of (bm, bn) — ops.py pads.  K is unblocked (macro
    fan-in, small).
    """
    m, k = x.shape
    _, n = w.shape
    grid = (m // bm, n // bn)
    x_spec = pl.BlockSpec((bm, k), lambda i, j: (i, 0))
    w_spec = pl.BlockSpec((k, bn), lambda i, j: (0, j))   # M-invariant: stays
    b_spec = pl.BlockSpec((bn,), lambda i, j: (j,))       # resident in VMEM
    o_spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    if noise is None:
        return pl.pallas_call(
            _mav_kernel, grid=grid,
            in_specs=[x_spec, w_spec, b_spec, b_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
            interpret=interpret,
        )(x, w, bias, flip)
    n_spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return pl.pallas_call(
        _mav_kernel_noise, grid=grid,
        in_specs=[x_spec, w_spec, b_spec, b_spec, n_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x, w, bias, flip, noise)


# ---------------------------------------------------------------------------
# Whole-layer fused kernel (grouped conv + epilogue + shuffle + OR-pool)
# ---------------------------------------------------------------------------


def _epilogue(counts, off, bias, flip, noise, o_ref, *, gpb, cog, pool):
    """Shared fused epilogue: ((counts + off) + bias [+ noise]) * flip ->
    sign -> OR-maxpool over `pool` adjacent rows -> (rows/pool, cog, gpb).

    The float-add order matches core.imc.mav_sa exactly (counts + chip
    offset, then bias, then SA noise, then the BN-decoder flip) so the fused
    path is bit-identical to the jnp oracle, noise included."""
    pre = (counts + off[None, :]) + bias[None, :]
    if noise is not None:
        pre = pre + noise
    pre = pre * flip[None, :]
    act = jnp.where(pre >= 0, 1.0, -1.0)
    act = act[:, :gpb * cog].reshape(-1, pool, gpb, cog)
    act = jnp.max(act, axis=1)                       # OR-pool on ±1 == max
    o_ref[...] = jnp.transpose(act, (0, 2, 1)).astype(o_ref.dtype)


def _fused_kernel(x_ref, w_ref, off_ref, b_ref, f_ref, o_ref, *,
                  gpb, cog, pool):
    counts = jnp.dot(x_ref[...], w_ref[...],
                     preferred_element_type=jnp.float32)
    _epilogue(counts, off_ref[...], b_ref[...], f_ref[...], None, o_ref,
              gpb=gpb, cog=cog, pool=pool)


def _fused_kernel_noise(x_ref, w_ref, off_ref, b_ref, f_ref, n_ref, o_ref, *,
                        gpb, cog, pool):
    counts = jnp.dot(x_ref[...], w_ref[...],
                     preferred_element_type=jnp.float32)
    _epilogue(counts, off_ref[...], b_ref[...], f_ref[...], n_ref[...],
              o_ref, gpb=gpb, cog=cog, pool=pool)


@functools.partial(jax.jit,
                   static_argnames=("gpb", "cog", "pool", "bm", "interpret"))
def imc_fused(xp: jax.Array, wp: jax.Array, off: jax.Array, bias: jax.Array,
              flip: jax.Array, noise: jax.Array | None = None, *,
              gpb: int, cog: int, pool: int = 1, bm: int = 256,
              interpret: bool = True) -> jax.Array:
    """One ``pallas_call`` for a whole grouped IMC layer.

    xp:   (packs, M, k_pad)  packed ±1 im2col patches (zero K-padding);
    wp:   (packs, k_pad, n_pad) block-diagonal ±1 weights;
    off/bias/flip: (packs, n_pad) per-channel chip offset / word-line bias /
          BN-decoder sign;
    noise: (packs, M, n_pad) optional SA-noise realization.

    M must be a multiple of ``bm`` and ``bm`` a multiple of ``pool`` (the
    caller pads on pool-window boundaries, so padded rows never share an
    OR-pool window with real rows).  Returns (M // pool, cog, packs*gpb):
    flattening the last two axes is exactly the post-shuffle channel order.
    """
    packs, m, k_pad = xp.shape
    n_pad = wp.shape[-1]
    grid = (packs, m // bm)
    x_spec = pl.BlockSpec((None, bm, k_pad), lambda p, i: (p, i, 0))
    w_spec = pl.BlockSpec((None, k_pad, n_pad), lambda p, i: (p, 0, 0))
    c_spec = pl.BlockSpec((None, n_pad), lambda p, i: (p, 0))
    o_spec = pl.BlockSpec((bm // pool, cog, gpb), lambda p, i: (i, 0, p))
    out_shape = jax.ShapeDtypeStruct((m // pool, cog, packs * gpb), xp.dtype)
    if noise is None:
        return pl.pallas_call(
            functools.partial(_fused_kernel, gpb=gpb, cog=cog, pool=pool),
            grid=grid,
            in_specs=[x_spec, w_spec, c_spec, c_spec, c_spec],
            out_specs=o_spec, out_shape=out_shape, interpret=interpret,
        )(xp, wp, off, bias, flip)
    n_spec = pl.BlockSpec((None, bm, n_pad), lambda p, i: (p, i, 0))
    return pl.pallas_call(
        functools.partial(_fused_kernel_noise, gpb=gpb, cog=cog, pool=pool),
        grid=grid,
        in_specs=[x_spec, w_spec, c_spec, c_spec, c_spec, n_spec],
        out_specs=o_spec, out_shape=out_shape, interpret=interpret,
    )(xp, wp, off, bias, flip, noise)
