"""Pallas TPU kernel: the IMC macro's MAV + in-memory-BN + SA epilogue.

TPU-native adaptation of the SRAM crossbar (DESIGN.md §3): the ±1 inner
product runs on the MXU as a bf16 matmul over VMEM-resident tiles; the
in-memory BN bias add, optional analog-noise injection and the SA 1-bit
decision are fused into the epilogue so pre-activations never touch HBM —
mirroring how the macro never digitizes the analog MAV value.

Layout: X (M, K) ±1 activations/patches, W (K, N) ±1 weights, bias (N,)
integer word-line bias, flip (N,) BN-decoder sign, optional noise (M, N)
(MAV offset + SA variation realization).  K is the macro fan-in (<= 64 per
bank physically; padded to 128 here for MXU lane alignment — zero padding
contributes 0 to the count, exactly like unused word lines).  The W tile is
grid-invariant along M so weights stay VMEM-resident across the batch grid,
the TPU analogue of weight-stationary in-SRAM storage.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mav_kernel(x_ref, w_ref, b_ref, f_ref, o_ref):
    counts = jnp.dot(x_ref[...], w_ref[...],
                     preferred_element_type=jnp.float32)
    pre = (counts + b_ref[...][None, :]) * f_ref[...][None, :]
    o_ref[...] = jnp.where(pre >= 0, 1.0, -1.0).astype(o_ref.dtype)


def _mav_kernel_noise(x_ref, w_ref, b_ref, f_ref, n_ref, o_ref):
    counts = jnp.dot(x_ref[...], w_ref[...],
                     preferred_element_type=jnp.float32)
    pre = counts + b_ref[...][None, :] + n_ref[...]
    pre = pre * f_ref[...][None, :]
    o_ref[...] = jnp.where(pre >= 0, 1.0, -1.0).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "interpret"))
def imc_mav(x: jax.Array, w: jax.Array, bias: jax.Array, flip: jax.Array,
            noise: jax.Array | None = None, *, bm: int = 256, bn: int = 128,
            interpret: bool = True) -> jax.Array:
    """sign((x @ w + bias [+ noise]) * flip) with VMEM-fused epilogue.

    x: (M, K) ±1; w: (K, N) ±1; bias/flip: (N,); noise: (M, N) or None.
    M, N must be multiples of (bm, bn) — ops.py pads.  K is unblocked (macro
    fan-in, small).
    """
    m, k = x.shape
    _, n = w.shape
    grid = (m // bm, n // bn)
    x_spec = pl.BlockSpec((bm, k), lambda i, j: (i, 0))
    w_spec = pl.BlockSpec((k, bn), lambda i, j: (0, j))   # M-invariant: stays
    b_spec = pl.BlockSpec((bn,), lambda i, j: (j,))       # resident in VMEM
    o_spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    if noise is None:
        return pl.pallas_call(
            _mav_kernel, grid=grid,
            in_specs=[x_spec, w_spec, b_spec, b_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
            interpret=interpret,
        )(x, w, bias, flip)
    n_spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return pl.pallas_call(
        _mav_kernel_noise, grid=grid,
        in_specs=[x_spec, w_spec, b_spec, b_spec, n_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x, w, bias, flip, noise)
