"""Public wrapper: pad to tile boundaries, Q-format value-domain interface."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import ACT_Q, WEIGHT_Q, QFormat
from repro.kernels.int8_matmul.int8_matmul import int8_matmul


def _pad(x, axis, mult, value=0):
    p = (-x.shape[axis]) % mult
    if p == 0:
        return x
    w = [(0, 0)] * x.ndim
    w[axis] = (0, p)
    return jnp.pad(x, w, constant_values=value)


def quantized_fc(feats: jax.Array, w: jax.Array, b: jax.Array,
                 act_fmt: QFormat = ACT_Q, w_fmt: QFormat = WEIGHT_Q,
                 interpret: bool = True) -> jax.Array:
    """Value-domain FC through the int8 kernel.

    feats real (M, D) -> codes via act_fmt; w/b via w_fmt.  The product grid
    is act_fmt.scale * w_fmt.scale; the kernel right-shift brings it back to
    act_fmt's grid: shift = frac(act)+frac(w) - frac(act) = frac(w).
    Returns real values on the act grid, shape (M, N).
    """
    m0, n0 = feats.shape[0], w.shape[1]
    xq = act_fmt.to_int(feats, jnp.int8)
    wq = w_fmt.to_int(w, jnp.int8)
    # bias joins the accumulator on the product grid
    bq = jnp.round(b / (act_fmt.scale * w_fmt.scale)).astype(jnp.int32)
    xq = _pad(_pad(xq, 0, 256), 1, 128)
    wq = _pad(_pad(wq, 0, 128), 1, 128)
    bq = _pad(bq, 0, 128)
    out = int8_matmul(xq, wq, bq, shift=w_fmt.frac_bits, out_max=act_fmt.qmax,
                      interpret=interpret)
    return out[:m0, :n0].astype(jnp.float32) * act_fmt.scale
