"""Pallas TPU kernel: int8 x int8 -> int32 matmul with fixed-point requant.

The digital FC classifier + on-chip-training datapath of the chip (§V-C):
8-bit operands, 32-bit accumulate, shift-based rescale back onto the Q-grid
(multiplication by the error-scaling factor 1.375 = shift-and-add on chip;
here the shift exponent is a kernel scalar).  No float in the datapath.

MXU note: TPU MXUs execute s8xs8->s32 natively; interpret=True validates the
integer semantics on CPU bit-exactly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _int8_kernel(x_ref, w_ref, b_ref, o_ref, *, shift: int, out_max: int):
    acc = jnp.dot(x_ref[...].astype(jnp.int32), w_ref[...].astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    acc = acc + b_ref[...][None, :]
    # rounding right-shift: (acc + 2^(s-1)) >> s, saturate to the out grid
    if shift > 0:
        acc = (acc + (1 << (shift - 1))) >> shift
    acc = jnp.clip(acc, -out_max - 1, out_max)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("shift", "out_max", "bm", "bn",
                                             "interpret"))
def int8_matmul(x: jax.Array, w: jax.Array, bias: jax.Array, *,
                shift: int = 7, out_max: int = 127, bm: int = 256,
                bn: int = 128, interpret: bool = True) -> jax.Array:
    """x: (M, K) int8, w: (K, N) int8, bias: (N,) int32 ->
    (M, N) int8 codes = clip((x@w + bias) >> shift)."""
    m, k = x.shape
    _, n = w.shape
    grid = (m // bm, n // bn)
    kern = functools.partial(_int8_kernel, shift=shift, out_max=out_max)
    return pl.pallas_call(
        kern, grid=grid,
        in_specs=[pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
                  pl.BlockSpec((k, bn), lambda i, j: (0, j)),
                  pl.BlockSpec((bn,), lambda i, j: (j,))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int8),
        interpret=interpret,
    )(x, w, bias)
