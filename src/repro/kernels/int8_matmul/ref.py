"""Pure-jnp oracle for the int8_matmul kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_matmul_ref(x: jax.Array, w: jax.Array, bias: jax.Array,
                    shift: int = 7, out_max: int = 127) -> jax.Array:
    acc = jnp.dot(x.astype(jnp.int32), w.astype(jnp.int32)) + bias[None, :]
    if shift > 0:
        acc = (acc + (1 << (shift - 1))) >> shift
    return jnp.clip(acc, -out_max - 1, out_max).astype(jnp.int8)
