"""Pure-jnp oracle for the fused SGA update kernel."""

from __future__ import annotations

import jax.numpy as jnp


def sga_update_ref(w, g, accum, lr, g_th, w_scale=1.0 / 128,
                   w_max=127.0 / 128, a_scale=2.0 ** -15):
    small = jnp.abs(g) < g_th
    banked = jnp.round((accum + jnp.where(small, g, 0.0)) / a_scale) * a_scale
    fire = small & (jnp.abs(banked) >= g_th)
    g_upd = jnp.where(small, jnp.where(fire, banked, 0.0), g)
    new_a = jnp.where(fire, 0.0, banked)
    new_w = w - lr * g_upd
    new_w = jnp.clip(jnp.round(new_w / w_scale) * w_scale, -w_max - w_scale,
                     w_max)
    return new_w, new_a
