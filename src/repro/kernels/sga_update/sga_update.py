"""Pallas TPU kernel: fused Small-Gradient-Accumulation optimizer update.

One elementwise pass implementing paper Algorithm 1 + the SGD weight update
+ Q1.7 weight quantization: reads (w, g, accum), writes (w', accum') — the
whole optimizer state transition in a single VMEM-resident sweep (on chip
this is the gradient-SRAM + threshold-compare unit; on TPU it saves 2x HBM
round-trips vs separate ops for large embedding/FC tables).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sga_kernel(w_ref, g_ref, a_ref, wo_ref, ao_ref, *,
                lr: float, g_th: float, w_scale: float, w_max: float,
                a_scale: float):
    w, g, a = w_ref[...], g_ref[...], a_ref[...]
    small = jnp.abs(g) < g_th
    banked = jnp.round((a + jnp.where(small, g, 0.0)) / a_scale) * a_scale
    fire = small & (jnp.abs(banked) >= g_th)
    g_upd = jnp.where(small, jnp.where(fire, banked, 0.0), g)
    new_a = jnp.where(fire, 0.0, banked)
    new_w = w - lr * g_upd
    new_w = jnp.clip(jnp.round(new_w / w_scale) * w_scale, -w_max - w_scale,
                     w_max)
    wo_ref[...] = new_w.astype(wo_ref.dtype)
    ao_ref[...] = new_a.astype(ao_ref.dtype)


def _sga_rows_kernel(lr_ref, th_ref, w_ref, g_ref, a_ref, wo_ref, ao_ref, *,
                     w_scale: float, w_max: float, a_scale: float):
    """Row-batched variant: each grid row is one session's flattened
    optimizer state with its OWN (lr, g_th) scalars — the learning-rate
    schedule position differs per enrollment session, so the scalars ride
    as operands instead of static compile-time constants."""
    lr, g_th = lr_ref[0, 0], th_ref[0, 0]
    w, g, a = w_ref[...], g_ref[...], a_ref[...]
    small = jnp.abs(g) < g_th
    banked = jnp.round((a + jnp.where(small, g, 0.0)) / a_scale) * a_scale
    fire = small & (jnp.abs(banked) >= g_th)
    g_upd = jnp.where(small, jnp.where(fire, banked, 0.0), g)
    new_a = jnp.where(fire, 0.0, banked)
    new_w = w - lr * g_upd
    new_w = jnp.clip(jnp.round(new_w / w_scale) * w_scale, -w_max - w_scale,
                     w_max)
    wo_ref[...] = new_w.astype(wo_ref.dtype)
    ao_ref[...] = new_a.astype(ao_ref.dtype)


@functools.partial(jax.jit, static_argnames=("w_scale", "w_max", "a_scale",
                                             "block", "interpret"))
def sga_update_rows(w: jax.Array, g: jax.Array, accum: jax.Array,
                    lr: jax.Array, g_th: jax.Array, *,
                    w_scale: float = 1.0 / 128, w_max: float = 127.0 / 128,
                    a_scale: float = 2.0 ** -15, block: int = 1024,
                    interpret: bool = True):
    """Batched fused SGA update: one ``pallas_call`` for B sessions.

    w/g/accum: (B, N) with N % block == 0 (ops.py pads); lr/g_th: (B,)
    per-row scalars.  Row b transitions exactly like
    ``sga_update(w[b], g[b], accum[b], lr=lr[b], g_th=g_th[b])`` — the
    serving customization scheduler stacks every active session's
    (head, bias, SGA bank) into rows so a mixed tick's optimizer work is
    one launch regardless of how many users are enrolling."""
    b, n = w.shape
    kern = functools.partial(_sga_rows_kernel, w_scale=w_scale, w_max=w_max,
                             a_scale=a_scale)
    spec = pl.BlockSpec((1, block), lambda i, j: (i, j))
    s_spec = pl.BlockSpec((1, 1), lambda i, j: (i, 0))
    return pl.pallas_call(
        kern, grid=(b, n // block),
        in_specs=[s_spec, s_spec, spec, spec, spec],
        out_specs=(spec, spec),
        out_shape=(jax.ShapeDtypeStruct((b, n), w.dtype),
                   jax.ShapeDtypeStruct((b, n), accum.dtype)),
        interpret=interpret,
    )(lr.reshape(b, 1).astype(jnp.float32),
      g_th.reshape(b, 1).astype(jnp.float32), w, g, accum)


@functools.partial(jax.jit, static_argnames=("lr", "g_th", "w_scale",
                                             "w_max", "a_scale", "block",
                                             "interpret"))
def sga_update(w: jax.Array, g: jax.Array, accum: jax.Array, *,
               lr: float, g_th: float, w_scale: float = 1.0 / 128,
               w_max: float = 127.0 / 128, a_scale: float = 2.0 ** -15,
               block: int = 1024, interpret: bool = True):
    """All inputs flat (N,) with N % block == 0 (ops.py pads).
    Returns (new_w, new_accum)."""
    n = w.shape[0]
    kern = functools.partial(_sga_kernel, lr=lr, g_th=g_th, w_scale=w_scale,
                             w_max=w_max, a_scale=a_scale)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        kern, grid=(n // block,),
        in_specs=[spec, spec, spec],
        out_specs=(spec, spec),
        out_shape=(jax.ShapeDtypeStruct((n,), w.dtype),
                   jax.ShapeDtypeStruct((n,), accum.dtype)),
        interpret=interpret,
    )(w, g, accum)
