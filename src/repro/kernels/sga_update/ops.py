"""Public wrapper: pytree-flat SGA update through the Pallas kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.sga_update.sga_update import sga_update


def sga_update_tree(params, grads, accums, lr: float, g_th: float,
                    interpret: bool = True):
    """Apply the fused update leaf-wise; shapes preserved."""
    leaves_w, treedef = jax.tree_util.tree_flatten(params)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_a = treedef.flatten_up_to(accums)
    new_w, new_a = [], []
    for w, g, a in zip(leaves_w, leaves_g, leaves_a):
        shape = w.shape
        flat = lambda x: x.reshape(-1)
        n = w.size
        pad = (-n) % 1024
        wp = jnp.pad(flat(w), (0, pad))
        gp = jnp.pad(flat(g), (0, pad))
        ap = jnp.pad(flat(a), (0, pad))
        nw, na = sga_update(wp, gp, ap, lr=float(lr), g_th=float(g_th),
                            interpret=interpret)
        new_w.append(nw[:n].reshape(shape))
        new_a.append(na[:n].reshape(shape))
    return (jax.tree_util.tree_unflatten(treedef, new_w),
            jax.tree_util.tree_unflatten(treedef, new_a))
