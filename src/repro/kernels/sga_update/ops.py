"""Public wrappers: pytree-flat and session-batched SGA updates through
the Pallas kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.sga_update.sga_update import sga_update, sga_update_rows


def sga_update_tree(params, grads, accums, lr: float, g_th: float,
                    interpret: bool = True):
    """Apply the fused update leaf-wise; shapes preserved."""
    leaves_w, treedef = jax.tree_util.tree_flatten(params)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_a = treedef.flatten_up_to(accums)
    new_w, new_a = [], []
    for w, g, a in zip(leaves_w, leaves_g, leaves_a):
        shape = w.shape
        flat = lambda x: x.reshape(-1)
        n = w.size
        pad = (-n) % 1024
        wp = jnp.pad(flat(w), (0, pad))
        gp = jnp.pad(flat(g), (0, pad))
        ap = jnp.pad(flat(a), (0, pad))
        nw, na = sga_update(wp, gp, ap, lr=float(lr), g_th=float(g_th),
                            interpret=interpret)
        new_w.append(nw[:n].reshape(shape))
        new_a.append(na[:n].reshape(shape))
    return (jax.tree_util.tree_unflatten(treedef, new_w),
            jax.tree_util.tree_unflatten(treedef, new_a))


def sga_update_batch(w: jax.Array, g: jax.Array, accum: jax.Array,
                     lr: jax.Array, g_th: jax.Array, *,
                     w_scale: float = 1.0 / 128, w_max: float = 127.0 / 128,
                     a_scale: float = 2.0 ** -15,
                     interpret: bool | None = None):
    """Session-batched fused SGA update: ONE ``pallas_call`` for B rows.

    w/g/accum: (B, N) stacked flattened optimizer states (one row per
    enrollment session — repro.serving.customize packs [fc_w, fc_b] and
    their SGA banks per row); lr/g_th: (B,) per-row scalars, since each
    session sits at its own point of the LR schedule.  Pads N to the
    kernel block and crops back; returns (new_w, new_accum)."""
    if interpret is None:
        interpret = default_interpret()
    b, n = w.shape
    pad = (-n) % 1024
    wp = jnp.pad(w, ((0, 0), (0, pad)))
    gp = jnp.pad(g, ((0, 0), (0, pad)))
    ap = jnp.pad(accum, ((0, 0), (0, pad)))
    nw, na = sga_update_rows(wp, gp, ap, jnp.asarray(lr, jnp.float32),
                             jnp.asarray(g_th, jnp.float32),
                             w_scale=w_scale, w_max=w_max, a_scale=a_scale,
                             interpret=interpret)
    return nw[:, :n], na[:, :n]
