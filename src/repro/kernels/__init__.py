"""Pallas TPU kernels for the accelerator's compute hot spots:

  imc_mav     — binary MAV + in-memory BN + SA sign (the IMC macro)
  int8_matmul — 8-bit fixed-point FC fwd (inference + on-chip training)
  sga_update  — fused Algorithm-1 optimizer sweep

Each package: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd public
wrapper), ref.py (pure-jnp oracle).  Validated in interpret mode on CPU;
BlockSpecs are MXU/VMEM-aligned for the TPU target.  Wrappers take
``interpret=None`` and resolve it via ``default_interpret()`` — compiled on
TPU, interpreter elsewhere.
"""

from __future__ import annotations

import functools


@functools.cache
def default_interpret() -> bool:
    """Pallas interpret mode is only needed off-TPU: compile on a TPU
    backend, interpret (CPU/GPU correctness mode) otherwise."""
    import jax
    return jax.default_backend() != "tpu"
