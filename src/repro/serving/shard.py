"""Device-sharded serving: per-device slot pools behind a host router.

Scale-out for the always-on KWS fleet.  The paper's accelerator is a
complete inference engine per chip — weights folded into the IMC arrays,
decisions local — so the natural multi-device deployment is N independent
slot pools (one full ``StreamServer`` per device, its folded model and
carry buffers resident on that device) with a thin host-side router in
front.  Nothing per-hop ever crosses a device boundary:

* **placement** — a new stream is pinned to one device for life by the
  deterministic policy in ``repro.sharding.placement`` (most free slots,
  then shortest queue, optionally duty-aware, round-robin tie-break);
  replay waves, canaries and customization sessions stay on the stream's
  device because they ride that pool's batched launches;
* **per-device invariants** — every serving contract holds per pool:
  each router ``step()`` ticks every pool once, and each pool issues at
  most ONE fused launch per IMC layer for all its ready slots
  (``LaunchAuditor`` carries the pool's ``device`` label, so violations
  and stats are attributable);
* **all-gather only for telemetry** — ``stats()`` materializes one small
  counter vector per device and gathers them host-side into the fleet
  rollup; that is the only cross-device data motion in the tier.

**Bit-identity with single-device serving** (test-enforced in
``tests/test_sharded_serving.py``): the router assigns every external
stream a GLOBAL uid in submission order and pins it via
``StreamServer.submit(uid=...)``.  A stream's SA-noise field key is
``fold_in(base_key, uid)``, so with every pool sharing the same ``seed``
a stream's noise field — and therefore its full decision sequence,
chip offsets, fault deltas and gating included — is identical no matter
which pool it lands on, and identical to a single-device server fed the
same streams.  Per-pool ``FaultModel``s are built from one shared
``FaultConfig`` (same seed), and every pool ticks its model once per
router tick, so drift trajectories stay in lockstep with the
single-device oracle.

**Sharded snapshots**: ``snapshot()`` bundles every pool's v2 snapshot
plus the router state (stream->device map, global uid counter, placement
cursor) into one atomically-written npz; ``restore()`` on a freshly
constructed identically-configured sharded server resumes
bit-identically.

Device binding follows ``launch/mesh.py``'s idiom — devices are resolved
at construction time, never at import time: ``devices=N`` takes the
first N entries of ``jax.devices()`` (wrapping if fewer exist, which is
how the equivalence tests run N logical pools on one physical device;
CI gets real host-platform devices via
``XLA_FLAGS=--xla_force_host_platform_device_count``).
"""

from __future__ import annotations

import json
import os
import tempfile
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.scheduler import StreamServer
from repro.sharding.placement import (PlacementConfig, PlacementPolicy,
                                      PoolLoad)

__all__ = ["ShardedStreamServer"]

# fleet counter vector layout: one row per device, gathered host-side in
# stats() — the tier's single cross-device collective
_GATHER_KEYS = ("decisions", "speech_hops", "gated_hops", "learn_hops",
                "rejected_streams", "queue_depth", "hop_wall_s")


class ShardedStreamServer:
    """N per-device ``StreamServer`` pools behind a placement router."""

    def __init__(self, hw, cfg, *, hop: int,
                 devices: Union[int, Sequence] = 2,
                 slots: int = 4,
                 placement: Optional[PlacementConfig] = None,
                 parallel: bool = False,
                 faults=None,
                 seed: int = 0,
                 **server_kw):
        """``devices`` is a count (resolved against ``jax.devices()`` at
        construction, wrapping when fewer physical devices exist) or an
        explicit device sequence.  ``slots`` is PER DEVICE.  ``faults``
        must be a ``FaultConfig`` (each pool builds its own seeded
        ``FaultModel`` so injections replay identically per pool) — a
        shared ``FaultModel`` instance would double-tick across pools.
        Remaining ``server_kw`` is forwarded verbatim to every pool.

        ``parallel=True`` dispatches pool ticks on one thread per device
        (``jax.default_device`` is thread-local, so each tick stays
        pinned); the default sequential dispatch keeps per-device wall
        attribution clean, which is what the scaling bench reports."""
        if isinstance(devices, int):
            if devices < 1:
                raise ValueError("devices must be >= 1")
            avail = jax.devices()
            self.devices = [avail[d % len(avail)] for d in range(devices)]
        else:
            self.devices = list(devices)
        if faults is not None:
            from repro.core import faults as flt
            if isinstance(faults, flt.FaultModel):
                raise ValueError(
                    "sharded serving needs a FaultConfig, not a "
                    "FaultModel: each pool builds its own seeded model "
                    "so injections replay identically on every device")
        self.n_devices = len(self.devices)
        self.cfg = cfg
        self.parallel = bool(parallel)
        self._pool_exec = (ThreadPoolExecutor(max_workers=self.n_devices)
                           if self.parallel else None)
        self.policy = PlacementPolicy(self.n_devices, placement)
        self.pools: List[StreamServer] = []
        for d, dev in enumerate(self.devices):
            with jax.default_device(dev):
                # per-device weight residency: each pool computes against
                # its own copy of the folded model (the chip's in-SRAM
                # weights), so no launch ever reads across devices
                hw_d = jax.device_put(hw, dev)
                kw = dict(server_kw)
                if kw.get("chip_offsets") is not None:
                    kw["chip_offsets"] = jax.device_put(
                        kw["chip_offsets"], dev)
                self.pools.append(StreamServer(
                    hw_d, cfg, hop=hop, slots=slots, faults=faults,
                    seed=seed, device_label=d, **kw))
        # global uid counter: starts past whatever every pool reserved at
        # construction (health canaries reserve one uid each, identically
        # across pools AND in the single-device oracle), then advances
        # once per accepted external stream in submission order
        self._next_uid = self.pools[0]._uid
        self._where: Dict[str, int] = {}
        self._steps = 0

    # -- routing ----------------------------------------------------------

    def _loads(self) -> List[PoolLoad]:
        out = []
        for srv in self.pools:
            total = srv._speech_hops + srv._gated_hops
            out.append(PoolLoad(
                free_slots=sum(r is None for r in srv._slots),
                queue_depth=len(srv._queue),
                duty=(srv._speech_hops / total) if total else None))
        return out

    def _route(self, stream_id: str) -> int:
        """Device index owning ``stream_id``, placing it if new.  A new
        stream is created empty on its pool with the next GLOBAL uid, so
        its SA-noise field matches the single-device oracle's."""
        d = self._where.get(stream_id)
        if d is not None:
            return d
        d = self.policy.place(self._loads())
        with jax.default_device(self.devices[d]):
            res = self.pools[d].submit(stream_id,
                                       np.zeros((0,), np.float32),
                                       uid=self._next_uid)
        if res == "rejected":
            return -1
        self._where[stream_id] = d
        self._next_uid += 1
        return d

    def where(self, stream_id: str) -> Optional[int]:
        """Device index a stream was placed on (None if never admitted)."""
        return self._where.get(stream_id)

    # -- stream lifecycle (delegated to the owning pool) -------------------

    def submit(self, stream_id: str, chunk, user_id: Optional[str] = None):
        """Route + append audio.  Returns the pool's placement verdict
        ('slot' / 'queued') or 'rejected' when the chosen pool's
        admission queue is full (nothing is buffered; the uid is not
        consumed, matching a single-device rejection)."""
        d = self._route(stream_id)
        if d < 0:
            return "rejected"
        with jax.default_device(self.devices[d]):
            return self.pools[d].submit(stream_id, chunk, user_id=user_id)

    def finish(self, stream_id: str) -> None:
        self.pools[self._where[stream_id]].finish(stream_id)

    def evict(self, stream_id: str) -> None:
        d = self._where[stream_id]
        with jax.default_device(self.devices[d]):
            self.pools[d].evict(stream_id)

    def customize(self, stream_id: str, ccfg=None):
        """Open an enrollment session on the stream's pool (placing the
        stream first if it does not exist yet) — the session's replay
        waves and background jobs all stay device-local."""
        d = self._route(stream_id)
        if d < 0:
            raise RuntimeError(f"cannot place stream {stream_id!r}: "
                               f"chosen pool's admission queue is full")
        with jax.default_device(self.devices[d]):
            return self.pools[d].customize(stream_id, ccfg)

    def install_custom(self, stream_id: str, result) -> None:
        d = self._route(stream_id)
        if d < 0:
            raise RuntimeError(f"cannot place stream {stream_id!r}: "
                               f"chosen pool's admission queue is full")
        with jax.default_device(self.devices[d]):
            self.pools[d].install_custom(stream_id, result)

    # -- fault / health fan-out -------------------------------------------

    @property
    def fault_models(self):
        """Per-device FaultModels (empty list when faults are off).  A
        chip-global fault campaign injects into EVERY model — same seed,
        same draws, so all pools (and the single-device oracle) mutate
        identically."""
        return [srv.faults for srv in self.pools
                if srv.faults is not None]

    # -- ticking ----------------------------------------------------------

    def _tick_pool(self, d: int) -> List[dict]:
        with jax.default_device(self.devices[d]):
            events = self.pools[d].step()
        for ev in events:
            ev["device"] = d
        return events

    def _block_pool(self, d: int, max_ticks: Optional[int]) -> List[dict]:
        with jax.default_device(self.devices[d]):
            events = self.pools[d].step_block(max_ticks)
        for ev in events:
            ev["device"] = d
        return events

    def step(self) -> List[dict]:
        """One fleet tick: every pool steps exactly once (sequentially by
        default, one thread per device with ``parallel=True``).  Events
        are returned in device order, each tagged with its ``device``."""
        if self._pool_exec is not None:
            futs = [self._pool_exec.submit(self._tick_pool, d)
                    for d in range(self.n_devices)]
            events = [ev for f in futs for ev in f.result()]
        else:
            events = [ev for d in range(self.n_devices)
                      for ev in self._tick_pool(d)]
        self._steps += 1
        return events

    def step_block(self, max_ticks: Optional[int] = None) -> List[dict]:
        """Serve up to ``max_ticks`` steady-state ticks PER POOL as one
        compiled dispatch each (``StreamServer.step_block``) — the
        whole-tick fast path, per device.  Pools advance independently
        (each fuses as many ticks as its own structural boundaries
        allow), so unlike ``step()`` this does not keep pools in tick
        lockstep; per-stream decision sequences are still bit-identical
        because streams never interact across pools.  Events are
        returned in device order, tagged with their ``device``.  Pools
        without ``compiled=`` just run one interpreted tick."""
        if self._pool_exec is not None:
            futs = [self._pool_exec.submit(self._block_pool, d, max_ticks)
                    for d in range(self.n_devices)]
            events = [ev for f in futs for ev in f.result()]
        else:
            events = [ev for d in range(self.n_devices)
                      for ev in self._block_pool(d, max_ticks)]
        self._steps += 1
        return events

    def drain(self, max_steps: int = 10_000) -> List[dict]:
        """Step the fleet until no pool can make progress (in compiled
        blocks when the pools were built with ``compiled=``)."""
        events: List[dict] = []
        blocks = any(srv._compiled is not None for srv in self.pools)

        def view():
            return [(len(srv._queue),
                     [None if r is None else len(r.buf)
                      for r in srv._slots]) for srv in self.pools]

        for _ in range(max_steps):
            before = view()
            events.extend(self.step_block() if blocks else self.step())
            if view() == before:
                break
        return events

    def active_streams(self) -> List[str]:
        return [sid for srv in self.pools for sid in srv.active_streams()]

    # -- fleet telemetry ---------------------------------------------------

    def stats(self) -> dict:
        """Fleet rollup + per-device detail.  The rollup sums one small
        per-device counter vector gathered host-side — the sharded tier's
        only cross-device data motion (decisions never leave their
        device)."""
        per_device = [srv.stats() for srv in self.pools]
        vecs = [jax.device_put(
                    jnp.asarray([float(s[k]) if s[k] is not None else 0.0
                                 for k in _GATHER_KEYS], jnp.float32),
                    dev)
                for s, dev in zip(per_device, self.devices)]
        gathered = np.asarray(jnp.stack(vecs))      # host-side all-gather
        tot = dict(zip(_GATHER_KEYS, gathered.sum(axis=0).tolist()))
        total_hops = tot["speech_hops"] + tot["gated_hops"]
        fleet = {
            "decisions": int(tot["decisions"]),
            "speech_hops": int(tot["speech_hops"]),
            "gated_hops": int(tot["gated_hops"]),
            "learn_hops": int(tot["learn_hops"]),
            "rejected_streams": int(tot["rejected_streams"]),
            "queue_depth": int(tot["queue_depth"]),
            "duty_cycle": (round(tot["speech_hops"] / total_hops, 4)
                           if total_hops else None),
            "hop_wall_s": round(tot["hop_wall_s"], 4),
            "decisions_per_sec": (round(tot["decisions"]
                                        / tot["hop_wall_s"], 2)
                                  if tot["hop_wall_s"] > 0 else None),
        }
        out = {
            "devices": self.n_devices,
            "steps": self._steps,
            "streams_placed": len(self._where),
            "placement": self.policy.snapshot(),
            "fleet": fleet,
            "per_device": per_device,
        }
        if any(srv.health is not None for srv in self.pools):
            states = [srv.health.state if srv.health is not None else None
                      for srv in self.pools]
            out["health"] = {"states": states,
                             "healthy": all(s in (None, "healthy")
                                            for s in states)}
        audits = [s.get("obs", {}).get("audit") for s in per_device]
        if any(a is not None for a in audits):
            out["audit"] = {
                "violations": sum(a["violations"] for a in audits
                                  if a is not None),
                "per_device": audits,
            }
        return out

    # -- sharded snapshot bundle ------------------------------------------

    def snapshot(self, path: Optional[str] = None):
        """Bundle every pool's snapshot plus the router state into one
        unit.  In-memory form: ``{"spec": ..., "arrays": ...}`` with pool
        arrays prefixed ``d{i}_``.  With ``path``: one npz, written
        atomically (tmp + fsync + ``os.replace``), restoring
        bit-identically on an identically-configured sharded server.
        Take it at fleet tick boundaries (between ``step()`` calls)."""
        arrays: Dict[str, np.ndarray] = {}
        pool_specs = []
        for d, srv in enumerate(self.pools):
            snap = srv.snapshot()
            pool_specs.append(snap["spec"])
            for k, v in snap["arrays"].items():
                arrays[f"d{d}_{k}"] = v
        spec = {
            "version": 1,
            "kind": "sharded",
            "devices": self.n_devices,
            "router": {"next_uid": self._next_uid,
                       "where": dict(self._where),
                       "steps": self._steps,
                       "policy": self.policy.snapshot()},
            "pools": pool_specs,
        }
        if path is None:
            return {"spec": spec, "arrays": arrays}
        payload = dict(arrays)
        payload["meta"] = np.frombuffer(
            json.dumps(spec).encode("utf-8"), dtype=np.uint8)
        parent = os.path.dirname(os.path.abspath(path)) or "."
        os.makedirs(parent, exist_ok=True)
        fd, tmp = tempfile.mkstemp(prefix=".tmp.shardsnap.", suffix=".npz",
                                   dir=parent)
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(f, **payload)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)                   # atomic commit
        except Exception:
            try:
                os.remove(tmp)
            except OSError:
                pass
            raise
        return path

    def restore(self, snap) -> None:
        """Restore a sharded bundle (path or in-memory dict) into THIS
        freshly constructed, identically-configured sharded server —
        same device count, per-pool configuration and wiring.  Resumes
        bit-identically, router placement state included."""
        if isinstance(snap, (str, os.PathLike)):
            with np.load(snap, allow_pickle=False) as data:
                spec = json.loads(bytes(data["meta"]).decode("utf-8"))
                arrays = {k: data[k] for k in data.files if k != "meta"}
        else:
            spec, arrays = snap["spec"], snap["arrays"]
        if spec.get("kind") != "sharded" or spec.get("version") != 1:
            raise ValueError(f"not a v1 sharded snapshot bundle: "
                             f"kind={spec.get('kind')!r} "
                             f"version={spec.get('version')!r}")
        if spec["devices"] != self.n_devices:
            raise ValueError(f"snapshot has {spec['devices']} device "
                             f"pools, this server has {self.n_devices}")
        for d, (srv, pool_spec) in enumerate(zip(self.pools,
                                                 spec["pools"])):
            prefix = f"d{d}_"
            pool_arrays = {k[len(prefix):]: v for k, v in arrays.items()
                           if k.startswith(prefix)}
            with jax.default_device(self.devices[d]):
                srv.restore({"spec": pool_spec, "arrays": pool_arrays})
        router = spec["router"]
        self._next_uid = int(router["next_uid"])
        self._where = {sid: int(d) for sid, d in router["where"].items()}
        self._steps = int(router["steps"])
        self.policy.restore(router["policy"])
