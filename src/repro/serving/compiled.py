"""Whole-tick compiled fast path: K scheduler ticks in ONE dispatch.

The Python tick (repro.serving.scheduler.StreamServer.step) is correct
but host-bound: every tick dispatches a handful of tiny jitted calls
(VAD, masked hop, decision head, gated fill) around the one fused launch
per IMC layer, and at CPU-interpret speeds the dispatch + sync overhead
dominates the actual IMC work.  This module compiles the *steady-state*
portion of the tick — gate -> batched hop -> decision head -> rider
updates (noise-field advance, GAP ring shift, hop counters) — into a
jitted ``lax.scan`` body over the fixed slot layout, so serving a whole
block of K ticks is one host->device round trip.

**What runs inside the scan** (dispatch 2, the main block):

* per scan step, at most one new hop per slot: a ``lax.cond``-gated
  masked ``stream_step`` (or its per-slot-rider customized variant) +
  ``decision_step`` for the computed slots, then a ``lax.cond``-gated
  masked ``gated_step`` for the slots whose deferred silent hop aged out
  of the wake margin.  Masked rows ride verbatim — exactly the Python
  tick's masking contract, so one trace of the body launches at most one
  fused kernel per IMC layer (auditor cause ``"compiled"``).

**What stays in Python** (and forces the block boundary — ``horizon()``
returns 0 and ``step()`` falls back to the interpreted tick):

* structural events: admissions (a slotted stream's first full window),
  evictions are fine mid-block but a non-empty admission queue is not,
  SLO shedding, slot autoscaling *resizes* (counter bookkeeping is
  replayed host-side; a resize due within the block shrinks the block),
  dynamic-hop retargets (the horizon is clipped so a retarget can only
  land exactly at the block end, where the Python path applies it),
* session traffic: active customization sessions, health canaries,
  profile-store sweeps, ``force_compute``/internal streams,
* per-tick Chrome tracing (``obs.trace``) — span timing is host-side by
  nature.

**Wake-margin replay without dynamic shapes.**  The scan cannot defer a
variable number of hops, so the block is scanned over a per-slot *hop
timeline* index j (not the tick index): the VAD block (dispatch 1, a
jitted scan of ``vad_step`` over the K ticks) returns the speech flags
to the host, and a host-side fate simulation — the single source of
truth for events, counters and bookkeeping — derives each hop's fate
exactly as the Python tick would have: a silent hop is *filled* once
``wake_margin`` newer hops are all silent, *computed* (as part of a wake
replay) if speech arrives within the margin, and stays deferred
host-side past the block end otherwise.  Multi-hop replays become plain
per-hop ``stream_step``s of the scan (``stream_multi_step`` is
test-enforced bit-identical to sequential steps), per-slot hop order is
preserved, and all batched ops are row-independent, so the compiled
block is **bit-identical** to K Python ticks — decisions, carries,
decision/VAD state, SA-noise fields, chip offsets, fault bias-delta
riders and registry counters included (wall-clock counters excluded;
``tests/_equiv.py`` enforces the rest).

Fault drift mid-block is honored: the host ticks the fault model K
times up front and, when the chip delta actually changes inside the
block, stages per-scan-step delta operands mapped by each hop's
*compute* tick (a wake replay reads the delta of its wake tick, exactly
like the Python replay call).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving import decision as dec
from repro.serving import stream as sv
from repro.serving import vad as vd

__all__ = ["CompiledTickConfig", "CompiledTick"]


@dataclasses.dataclass(frozen=True)
class CompiledTickConfig:
    """``block``: the hard cap on ticks fused into one dispatch
    (``step_block`` clamps any caller-passed ``max_ticks`` to it, so the
    padded scan length — and with it jit retracing — stays bounded;
    ``step()`` always uses K=1 blocks).  Block and
    timeline lengths are padded up to powers of two with all-False masks
    so the scan re-traces per size bucket, not per length."""

    block: int = 8

    def __post_init__(self):
        if self.block < 1:
            raise ValueError("block must be >= 1")


jax.tree_util.register_static(CompiledTickConfig)


def _pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


class CompiledTick:
    """Compiled-block engine bolted onto one ``StreamServer``.

    Owns the jitted VAD-block and main-block callables (cached per
    (hop-multiplier, rider-mode) — jax re-traces per shape bucket) and
    the host fate simulation that replays the Python tick's bookkeeping
    from the block's staged masks.  Holds no serving state of its own,
    so snapshots/restores need no compiled-path awareness."""

    def __init__(self, srv, ccfg: CompiledTickConfig):
        self._srv = srv
        self.cfg = ccfg
        self._vad_block = None
        self._main_cache: Dict[tuple, object] = {}

    # -- eligibility --------------------------------------------------------

    def horizon(self, max_ticks: int) -> int:
        """How many ticks may be fused into one block right now (0 =
        this tick needs the Python path).  Conservative by design: any
        condition the compiled block does not model exactly falls back —
        the Python tick is always correct, and one interpreted tick
        usually clears the condition (admission wave, resize, shed)."""
        srv = self._srv
        if max_ticks < 1 or not srv.streaming:
            return 0
        if srv.trace is not None:
            return 0
        if (srv._health is not None or srv._profiles is not None
                or srv._cust is not None):
            return 0
        if srv._queue:
            return 0
        hop = srv.geom.hop
        window = srv.geom.window
        avail = 0
        any_live = False
        for rec in srv._slots:
            if rec is None:
                continue
            any_live = True
            if rec.internal or rec.force_compute:
                return 0
            if rec.initialized:
                avail = max(avail, len(rec.buf) // hop)
            elif len(rec.buf) >= window:
                return 0                     # admission wave due
        if not any_live or avail == 0:
            return 0
        k = min(max_ticks, avail)
        if srv.acfg is not None and srv.acfg.max_lag_s is not None:
            max_lag = int(srv.acfg.max_lag_s * srv.cfg.sample_rate)
            for rec in srv._streams.values():
                if rec.finished or rec.internal or rec.force_compute:
                    continue
                if sum(map(len, rec.pending)) + len(rec.buf) > max_lag:
                    return 0                 # shed due
        if srv.acfg is not None and srv.max_slots > srv.min_slots:
            # a scale-down may fire at a tick START once idle_ticks
            # accrues to the threshold; keep every in-block tick (the
            # first included) strictly below it
            k = min(k, srv.acfg.scale_down_after - srv._idle_ticks - 1)
        if srv.hcfg is not None:
            if srv._mult != 1:
                # a narrow retarget can land at ANY tick end while
                # widened; one-tick blocks keep it at the block boundary
                k = min(k, 1)
            thr = srv.hcfg.widen_after
            if srv.hcfg.calm_silence is not None:
                thr = min(thr, srv.hcfg.calm_silence)
            # a widen at the FINAL tick end is fine (applied host-side
            # after the block, like the Python tick's tail)
            k = min(k, thr - srv._calm_ticks)
        return max(k, 0)

    # -- jitted blocks ------------------------------------------------------

    def _vad_fn(self):
        if self._vad_block is None:
            vcfg = self._srv.vcfg

            def vad_block(vstate, audio, active):
                return vd.vad_scan(vcfg, vstate, audio, active)

            self._vad_block = jax.jit(vad_block)
        return self._vad_block

    def _main_fn(self, mult: int, cust: bool, per_tick_chip: bool,
                 gated: bool):
        key = (mult, cust, per_tick_chip, gated)
        if key not in self._main_cache:
            # deferred to call time: scheduler.py's package import runs
            # this module's top level before _select_state exists
            from repro.serving.scheduler import _select_state
            srv = self._srv
            eng = srv._bundle(mult)["engine"]
            cfg, geom, kw, hw = srv.cfg, eng.geom, eng._kw, srv._hw
            dcfg = srv.dcfg

            def block(state, dstate, audio, cm, fm, delta, hw_, hb_,
                      chip, fills):
                def body(carry, xs):
                    st, ds = carry
                    a, cmj, fmj, chipj = xs

                    def compute(op):
                        st, ds = op
                        if cust:
                            d = delta
                            if per_tick_chip:
                                d = {n: d[n] + chipj[n] for n in d}
                            lg, new = sv.stream_step(
                                hw, st, a, cfg, geom, **kw, bias_delta=d,
                                head_w=hw_, head_b=hb_)
                        else:
                            lg, new = sv.stream_step(hw, st, a, cfg, geom,
                                                     **kw)
                        st2 = _select_state(cmj, new, st)
                        ds2, out = dec.decision_step(dcfg, ds, lg, cmj)
                        return st2, ds2, (out.trigger, out.keyword,
                                          out.score)

                    def skip(op):
                        st, ds = op
                        nb = st.hop.shape[0]
                        return st, ds, (
                            jnp.zeros((nb,), bool),
                            jnp.zeros((nb,), jnp.int32),
                            jnp.zeros((nb,), ds.posteriors.dtype))

                    st, ds, out = jax.lax.cond(cmj.any(), compute, skip,
                                               (st, ds))
                    if gated:
                        def fill(s_):
                            new = sv.gated_step(s_, cfg, geom, fills)
                            return _select_state(fmj, new, s_)

                        st = jax.lax.cond(fmj.any(), fill, lambda s_: s_,
                                          st)
                    return (st, ds), out

                (state, dstate), outs = jax.lax.scan(
                    body, (state, dstate), (audio, cm, fm, chip))
                return state, dstate, outs

            self._main_cache[key] = jax.jit(block)
        return self._main_cache[key]

    # -- the compiled block --------------------------------------------------

    def run(self, k: int) -> List[dict]:
        """Serve ``k`` ticks in one compiled block.  ``k`` must come from
        ``horizon()`` — the caller guarantees no structural event can
        fire inside the block (except at its very end).  Bit-identical
        to ``k`` Python ``step()`` calls."""
        srv = self._srv
        hop = srv.geom.hop
        window = srv.geom.window
        n = srv.slots
        m = srv.vcfg.wake_margin if srv.vcfg is not None else 0
        tick0 = srv._steps
        mult0 = srv._mult
        t_start = time.perf_counter()
        if srv._audit is not None:
            srv._audit.begin_tick(tick0)

        # fault model in lockstep: per-tick chip delta sequence (the
        # Python tick refreshes the rider operand at each tick start)
        chip_seq: Optional[list] = None
        if srv._faults is not None:
            chip_seq = []
            for _ in range(k):
                srv._faults.tick()
                if srv._faults.pop_dirty():
                    srv._refresh_chip_delta()
                chip_seq.append(srv._chip_delta_j)
            if all(c is chip_seq[0] for c in chip_seq):
                chip_seq = None       # constant across the block: the
                #                       current rider operand covers it

        # stage the block's ready hops (the Python tick consumes one hop
        # per ready slot per tick; readiness is a per-slot prefix since
        # nothing is submitted mid-block)
        ready = np.zeros((k, n), bool)
        audio = np.zeros((k, n, hop), np.float32)
        recs: Dict[int, object] = {}
        seq: Dict[int, list] = {}     # slot -> pending + fresh hop chunks
        p0: Dict[int, int] = {}       # slot -> deferred hops entering
        nready: Dict[int, int] = {}   # slot -> fresh ready hops staged
        rem0: Dict[int, int] = {}     # slot -> buffered samples left
        for s, rec in enumerate(srv._slots):
            if rec is None or not rec.initialized:
                continue
            rs = min(k, len(rec.buf) // hop)
            recs[s] = rec
            p0[s] = len(rec.pending)
            nready[s] = rs
            # one reshape, not rs tiny copies: row views stage the block
            chunks = np.asarray(rec.buf[:rs * hop],
                                np.float32).reshape(rs, hop)
            rec.buf = rec.buf[rs * hop:]
            rem0[s] = len(rec.buf)
            seq[s] = list(rec.pending) + list(chunks)
            ready[:rs, s] = True
            audio[:rs, s] = chunks

        with srv._region("compiled"):
            # dispatch 1: the VAD block (no IMC kernels) — flags come
            # back to the host so the fate simulation below is the one
            # source of truth for masks, events and counters
            if srv.vcfg is not None:
                kp = _pow2(k)
                audio_p = np.zeros((kp, n, hop), np.float32)
                audio_p[:k] = audio
                ready_p = np.zeros((kp, n), bool)
                ready_p[:k] = ready
                srv._vstate, flags = self._vad_fn()(
                    srv._vstate, jnp.asarray(audio_p),
                    jnp.asarray(ready_p))
                speech = np.asarray(flags)[:k] & ready
            else:
                speech = ready.copy()

            # host fate simulation: replicate the Python tick's
            # classification exactly — per tick, per slot (slot order):
            # speech wakes + replays any deferred hops, silence defers
            # the hop and ages the oldest out of the wake margin
            pend = {s: list(range(p0[s])) for s in recs}
            sched = []
            for t in range(k):
                tk = {"replays": [], "regular": [], "fills": []}
                for s in sorted(recs):
                    if not ready[t, s]:
                        continue
                    j = p0[s] + t
                    if speech[t, s]:
                        if pend[s]:
                            tk["replays"].append((s, pend[s] + [j]))
                            pend[s] = []
                        else:
                            tk["regular"].append((s, j))
                    else:
                        pend[s].append(j)
                        if len(pend[s]) > m:
                            tk["fills"].append((s, pend[s].pop(0)))
                sched.append(tk)

            # masks over the hop-timeline index j (per slot, hop j is
            # its j-th hop since block start: deferred-entering hops
            # first, then the freshly staged ones)
            jcap = max((p0[s] + nready[s] for s in recs), default=0)
            cm = np.zeros((max(jcap, 1), n), bool)
            fm = np.zeros((max(jcap, 1), n), bool)
            comp_tick: Dict[tuple, int] = {}
            jmax = 0
            for t, tk in enumerate(sched):
                for s, js in tk["replays"]:
                    for j in js:
                        cm[j, s] = True
                        comp_tick[(s, j)] = t
                        jmax = max(jmax, j + 1)
                for s, j in tk["regular"]:
                    cm[j, s] = True
                    comp_tick[(s, j)] = t
                    jmax = max(jmax, j + 1)
                for s, j in tk["fills"]:
                    fm[j, s] = True
                    jmax = max(jmax, j + 1)

            trig = kwd = sc = None
            if jmax > 0:
                jp = _pow2(jmax)
                audio_tl = np.zeros((jp, n, hop), np.float32)
                for s in recs:
                    for j, ch in enumerate(seq[s][:jmax]):
                        audio_tl[j, s] = ch
                cm_p = np.zeros((jp, n), bool)
                cm_p[:jmax] = cm[:jmax]
                fm_p = np.zeros((jp, n), bool)
                fm_p[:jmax] = fm[:jmax]

                cust = srv._cust_on
                per_tick_chip = chip_seq is not None
                gated = srv.vcfg is not None
                delta = hw_ = hb_ = chip = fills = None
                if cust:
                    if per_tick_chip:
                        # stage per-scan-step chip deltas mapped by each
                        # hop's COMPUTE tick (a wake replay reads its
                        # wake tick's delta, like the Python replay call)
                        delta = srv._slot_delta
                        hw_, hb_ = srv._slot_head_w, srv._slot_head_b
                        chip = {
                            name: np.zeros((jp, n, srv.cfg.channels[
                                int(name[4:])]), np.float32)
                            for name in srv.cfg.imc_layer_names()}
                        for (s, j), t in comp_tick.items():
                            d = chip_seq[t]
                            if d is not None:
                                for name in chip:
                                    chip[name][j, s] = np.asarray(d[name])
                        chip = {name: jnp.asarray(v)
                                for name, v in chip.items()}
                    else:
                        delta, hw_, hb_ = srv._slot_custom_args()
                if gated:
                    fills = (srv._slot_fills
                             if cust and srv._slot_fills is not None
                             else srv._fills)

                fn = self._main_fn(srv._mult, cust, per_tick_chip, gated)
                srv._state, srv._dstate, outs = fn(
                    srv._state, srv._dstate, jnp.asarray(audio_tl),
                    jnp.asarray(cm_p), jnp.asarray(fm_p) if gated else None,
                    delta, hw_, hb_, chip, fills)
                trig, kwd, sc = jax.device_get(outs)   # one transfer
            jax.block_until_ready((srv._state, srv._dstate))
        dt = time.perf_counter() - t_start
        srv._hop_wall_s += dt
        if comp_tick:
            per_slot = {}
            for (s, _j) in comp_tick:
                per_slot[s] = per_slot.get(s, 0) + 1
            for s, cnt in per_slot.items():
                recs[s].wall_s += dt * cnt / len(comp_tick)

        # host replay of the per-tick bookkeeping, in tick order — the
        # exact side-effect sequence of k Python ticks
        events_all: List[dict] = []
        for t in range(k):
            tick = tick0 + t
            self._sim_autoscale()
            tk = sched[t]
            tick_events: List[dict] = []
            for s in sorted(recs):
                if not ready[t, s]:
                    continue
                rec = recs[s]
                if speech[t, s]:
                    rec.silent_run = 0
                    if rec.pending:
                        rec.pending = []   # drained by the wake replay
                else:
                    rec.silent_run += 1
                    rec.pending.append(audio[t, s])
                    if len(rec.pending) > m:
                        aged = rec.pending.pop(0)
                        rec.recent = np.concatenate(
                            [rec.recent, aged])[-window:]
                        rec.consumed += hop
                        rec.gated_hops += 1
                        srv._gated_hops += 1
            for s, js in tk["replays"]:
                rec = recs[s]
                srv._replay_calls += 1
                for j in js:
                    srv._decisions += 1
                    srv._speech_hops += 1
                    rec.recent = np.concatenate(
                        [rec.recent, seq[s][j]])[-window:]
                    rec.consumed += hop
                    rec.hops += 1
                    ev = {"stream": rec.stream_id, "hop": rec.hops - 1,
                          "keyword": int(kwd[j, s]),
                          "score": float(sc[j, s]),
                          "trigger": bool(trig[j, s])}
                    tick_events.append(ev)
                    if ev["trigger"]:
                        rec.triggers.append(ev)
            if tk["regular"]:
                srv._hop_calls += 1
                for s, j in tk["regular"]:
                    rec = recs[s]
                    srv._speech_hops += 1
                    rec.hops += 1
                    rec.consumed += hop
                    rec.recent = np.concatenate(
                        [rec.recent, seq[s][j]])[-window:]
                srv._decisions += len(tk["regular"])
                for s, j in tk["regular"]:
                    rec = recs[s]
                    ev = {"stream": rec.stream_id, "hop": rec.hops - 1,
                          "keyword": int(kwd[j, s]),
                          "score": float(sc[j, s]),
                          "trigger": bool(trig[j, s])}
                    tick_events.append(ev)
                    if ev["trigger"]:
                        rec.triggers.append(ev)
            if tk["fills"]:
                srv._gate_calls += 1

            # retire drained finished streams (evaluated on the VIRTUAL
            # buffer length: staging consumed the block's hops up front)
            for s, rec in enumerate(list(srv._slots)):
                if rec is None or not rec.finished:
                    continue
                if rec.initialized and s in recs:
                    remaining = (rem0[s]
                                 + max(nready[s] - (t + 1), 0) * hop)
                else:
                    remaining = len(rec.buf)
                if remaining < (hop if rec.initialized else window):
                    srv._free_slot(rec)
            srv._steps += 1
            silent_t = (bool(ready[t].any())
                        and not bool((speech[t] & ready[t]).any()))
            srv._retarget_hop(tick_events, woke=bool(tk["replays"]),
                              silent=silent_t)
            if srv.hcfg is not None and t < k - 1:
                assert srv._mult == mult0, \
                    "hop retarget fired inside a compiled block"
            n_replay_hops = sum(len(js) for _, js in tk["replays"])
            computed = n_replay_hops + len(tk["regular"])
            gated_n = len(tk["fills"])
            if srv._rec is not None and (computed or gated_n
                                         or tick_events):
                uj = srv._tick_uj(computed, gated_n)
                srv._rec.record(tick, "tick", init=0, computed=computed,
                                gated=gated_n, replays=len(tk["replays"]),
                                decisions=len(tick_events),
                                uj=round(uj, 4))
                srv._metrics.observe("serving.tick_uj", uj)
            events_all.extend(tick_events)

        if srv._audit is not None:
            srv._audit.end_tick()
            for t in range(1, k):
                srv._audit.begin_tick(tick0 + t)
                srv._audit.end_tick()
        srv._compiled_blocks += 1
        srv._compiled_ticks += k
        return events_all

    def _sim_autoscale(self) -> None:
        """Replay ``_autoscale``'s counter bookkeeping for one in-block
        tick.  The admission queue is empty (horizon precondition) so no
        pressure accrues, and the horizon keeps ``idle_ticks`` strictly
        below the scale-down threshold — a due resize always lands on a
        Python tick."""
        srv = self._srv
        if srv.acfg is None or srv.max_slots <= srv.min_slots:
            return
        srv._pressure_ticks = 0
        free_tail = 0
        for rec in reversed(srv._slots):
            if rec is None:
                free_tail += 1
            else:
                break
        if free_tail and srv.slots > srv.min_slots:
            srv._idle_ticks += 1
            assert srv._idle_ticks < srv.acfg.scale_down_after, \
                "slot resize fired inside a compiled block"
        else:
            srv._idle_ticks = 0
