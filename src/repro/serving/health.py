"""Canary health monitoring + self-healing recompensation for serving.

A deployed chip fails silently: an SA offset drifting past the decision
margin produces confidently wrong keywords, not errors.  This module
closes the loop the paper leaves at enrollment — it *detects* silicon
faults (repro.core.faults) in production and re-runs the paper's §IV-B
test-mode bias compensation online to heal them:

* **canary windows** — the monitor owns a small set of known calibration
  inputs; every ``interval`` ticks it submits one as an *internal stream*
  (the repro.serving.customize replay pattern: ``[hop zeros, window]``,
  captured at ``window + hop``), so the canary's init rides the batched
  admission wave and its hop rides the SAME batched launch as live
  traffic — health monitoring adds ZERO extra pallas_calls
  (trace-enforced in tests/test_reliability.py).  The canary stream
  reuses one reserved uid, so its per-absolute-column SA-noise field is
  fixed and the expected per-layer outputs are computed once, on the
  jnp reference path (``use_kernel=False`` — bit-identical to the fused
  kernel by the repo-wide contract, and zero launches);
* **per-layer divergence** — the captured ``StreamState`` exposes every
  IMC layer's output columns (layer i's carry into layer i+1, the GAP
  ring for the last layer); comparing them channel-wise against the
  expected state localizes the faulty layer AND the faulty columns,
  exactly what the recompensation job needs;
* **health state machine** — ``healthy -> degraded`` on the first failing
  canary, ``-> quarantined`` after ``quarantine_after`` consecutive
  failures (detection confirmed; the recovery job launches),
  ``-> recovering`` once the recompensated biases are hot-swapped in,
  ``-> healthy`` after ``recover_after`` consecutive clean canaries.
  While not healthy, every decision event the server emits carries
  ``degraded: True`` — graceful degradation instead of silent wrong
  answers;
* **self-healing** — the recovery job re-runs the paper's test mode as a
  tick-resumable background job (the repro.serving.customize calibration
  pattern): one tick of ``calibration_ideal_counts`` (the digitize-the-
  counts mode — zero IMC launches), then ``layers_per_tick`` layers per
  tick of ``compensate_layer_bias`` against the enrollment-time baseline,
  measuring the *current* fault deltas; the resulting integer bias deltas
  hot-swap in through the scheduler's chip-global rider row (the same
  pre-sign operand the per-slot customization deltas use).  Drift and
  trim-bit flips heal to sub-count residuals; stuck rails saturate the
  ±bias_range clip and cannot heal — channels still divergent after
  ``stuck_after`` post-heal canaries are **permanently masked** (excluded
  from future divergence checks; their columns are written off, as the
  silicon would fuse them out).  A layer that keeps failing only in
  *aggregate* — no single maskable column — healed as far as integer bias
  writes can go (a fractional fault leaves a ±0.5-count residual that
  deterministically flips a subset of SA cells): its best-effort heal is
  **accepted** and the current fault+heal delta frozen into the expected
  reference (rebaselining), so later canaries measure NEW faults against
  the accepted chip instead of re-healing a residual forever.

The monitor requires ``streaming=True`` (divergence reads the carries /
GAP ring) and a fixed hop (a dynamic-hop retarget would rebuild the
canary's state mid-capture, like enrollment).  Canaries pause while the
server has no live traffic — there is nothing to protect and the chip
sleeps — so ``drain()`` still terminates.

Everything here is snapshot-safe: ``snapshot()``/``restore()`` round-trip
the state machine, the pending canary, the masked columns and a
mid-flight recovery job bit-identically (``StreamServer.snapshot``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy
from repro.models import kws
from repro.obs import counter_property
from repro.serving import stream as sv


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Canary cadence, divergence thresholds and recovery pacing.

    ``interval``: ticks between canary submissions; ``calib_windows``:
    calibration inputs for the recompensation measurement (canary
    comparisons always use window 0, so the expected state is fixed);
    ``divergence_frac``: fraction of mismatching state cells that fails a
    layer; ``channel_frac``: per-channel row-mismatch fraction that
    implicates the channel (a stuck column flips ~half its rows, so keep
    this below 0.5); ``quarantine_after``/``recover_after``: consecutive
    failing/clean canaries to confirm a fault / declare recovery;
    ``stuck_after``: post-heal failing canaries before still-divergent
    channels are permanently masked; ``layers_per_tick`` bounds the
    recompensation work per tick; ``recal_sa_noise_std`` is the test-mode
    measurement noise (paper §IV-B — test mode can average repeated SA
    reads, so values below 1.0 model an N-read-averaged measurement);
    ``recal_scope`` picks what a recovery recompensates: ``"prefix"``
    (default) heals conv1..flagged — minimal latency — while ``"all"``
    re-runs the full enrollment-time §IV-B pass over every array, which
    also catches canary-invisible faults in layers the localization
    never flagged."""

    interval: int = 8
    calib_windows: int = 2
    divergence_frac: float = 0.05
    channel_frac: float = 0.4
    quarantine_after: int = 2
    recover_after: int = 2
    stuck_after: int = 2
    layers_per_tick: int = 2
    recal_sa_noise_std: float = 1.0
    recal_scope: str = "prefix"
    seed: int = 0
    auto_recover: bool = True

    def __post_init__(self):
        if self.interval < 1 or self.calib_windows < 1:
            raise ValueError("interval and calib_windows must be >= 1")
        if not (0.0 < self.channel_frac <= 1.0
                and 0.0 < self.divergence_frac <= 1.0):
            raise ValueError("divergence_frac and channel_frac must be "
                             "in (0, 1]")
        if min(self.quarantine_after, self.recover_after, self.stuck_after,
               self.layers_per_tick) < 1:
            raise ValueError("state-machine counts must be >= 1")
        if self.recal_scope not in ("prefix", "all"):
            raise ValueError("recal_scope must be 'prefix' or 'all'")


class HealthMonitor:
    """One server's canary scheduler, divergence localizer and recovery
    driver.  Constructed by ``StreamServer(health=HealthConfig(...))``;
    the scheduler calls ``on_step`` (captures) and ``tick`` (recovery
    work + canary spawns) from inside ``step()``."""

    STATES = ("healthy", "degraded", "quarantined", "recovering")

    # counters live in the server's metrics registry (repro.obs.metrics);
    # the attribute API and snapshot()/restore() keep working through
    # these registry-backed properties
    canaries = counter_property("health.canaries")
    failed_canaries = counter_property("health.failed_canaries")
    recoveries = counter_property("health.recoveries")
    recovery_energy_uj = counter_property("health.recovery_energy_uj")

    def __init__(self, srv, hcfg: HealthConfig):
        if not srv.streaming:
            raise ValueError("health monitoring requires streaming=True "
                             "(divergence reads the per-layer carries and "
                             "the GAP ring)")
        if srv.hcfg is not None:
            raise ValueError("health monitoring requires a fixed hop "
                             "(dynamic_hop retargets would rebuild the "
                             "canary state mid-capture)")
        self.hcfg = hcfg
        self.srv = srv
        self._metrics = srv._metrics      # backs the counter properties
        self.state = "healthy"
        # reserved uid: the canary's SA-noise field key is fixed, so the
        # expected per-layer outputs are computed once and reused forever
        self._uid = srv._uid
        srv._uid += 1
        window, hop = srv.geom.window, srv.geom.hop
        self._xcal = np.asarray(jax.random.uniform(
            jax.random.PRNGKey(hcfg.seed), (hcfg.calib_windows, window),
            minval=-1.0, maxval=1.0), np.float32)
        self._wav = np.concatenate([np.zeros((hop,), np.float32),
                                    self._xcal[0]])
        self._expected = None            # lazily computed reference state
        self._pending: Optional[dict] = None
        self._canary_n = 0
        self._last_spawn = -(10 ** 9)    # first canary fires immediately
        self._fail_streak = 0
        self._ok_streak = 0
        self._post_heal_fails = 0
        self.canaries = 0
        self.failed_canaries = 0
        self.recoveries = 0
        self.recovery_energy_uj = 0.0
        self.detected_tick: Optional[int] = None
        self.quarantined_tick: Optional[int] = None
        self.implicated: Dict[str, List[int]] = {}
        self.divergence: Dict[str, float] = {}
        self.masked = {name: np.zeros((srv.cfg.channels[int(name[4:])],),
                                      bool)
                       for name in srv.cfg.imc_layer_names()}
        # the accepted reference delta: per-channel fault+heal residuals
        # FROZEN into the expected canary state when a column is written
        # off (masked) or a layer's best-effort heal is accepted — later
        # canaries detect NEW faults relative to this accepted baseline,
        # while a frozen residual that drifts again re-diverges
        self._ref_delta = {
            name: np.zeros((srv.cfg.channels[int(name[4:])],), np.float32)
            for name in srv.cfg.imc_layer_names()}
        self.accepted_layers: List[str] = []
        self._healed: List[str] = []     # layers with >= 1 applied heal
        self._frozen_layers: List[str] = []  # layers whose FULL delta is
        #                                      part of the accepted baseline
        self.history: List[dict] = [{"tick": 0, "state": "healthy"}]
        self._recovery: Optional[dict] = None

    # -- the expected (clean-chip) canary state ------------------------------

    def _ensure_expected(self) -> None:
        """Per-layer expected outputs of canary window 0 on the *accepted*
        chip: the jnp reference path (zero pallas launches), same noise
        field (the reserved uid's key), same chip offsets — bit-identical
        to what the live canary's rows compute when no unaccepted fault is
        active.  Masked (written-off) columns and accepted best-effort
        heals carry their FROZEN fault+heal delta into the reference
        (``_ref_delta``), so their divergence — and its downstream
        propagation — compares clean; anything NOT yet accepted still
        diverges and is detected."""
        if self._expected is not None:
            return
        srv = self.srv
        cfg, geom = srv.cfg, srv.geom
        kw = {k: v for k, v in srv._engine_kw.items() if k != "streaming"}
        kw["use_kernel"] = False
        if any(d.any() for d in self._ref_delta.values()):
            hwp, _ = kws.as_hw_params(srv._hw)
            kw["bias_delta"] = {
                name: jnp.asarray(self._ref_delta[name])[None]
                for name in cfg.imc_layer_names()}
            kw["head_w"] = hwp.fc_w[None]
            kw["head_b"] = hwp.fc_b[None]
        key = jax.random.fold_in(srv._base_key, self._uid)[None]
        wav = self._wav
        _, st = sv.stream_init(srv._hw, jnp.asarray(wav[None, :geom.window]),
                               key, cfg, geom, **kw)
        _, st = sv.stream_step(srv._hw, st,
                               jnp.asarray(wav[None, geom.window:]),
                               cfg, geom, **kw)
        self._expected = {"carries": [np.asarray(c[0]) for c in st.carries],
                          "ring": np.asarray(st.ring[0])}

    # -- per-tick hooks (called by StreamServer.step) ------------------------

    def on_step(self, srv) -> None:
        """Capture the pending canary's per-layer state right after the
        batched hop (before slots retire), then evaluate divergence."""
        p = self._pending
        if p is None:
            return
        rec = srv._streams.get(p["stream"])
        if (rec is None or rec.slot is None or not rec.initialized
                or rec.consumed < p["target"]):
            return
        s = rec.slot
        carries = [np.asarray(c[s]) for c in srv._state.carries]
        ring = np.asarray(srv._state.ring[s])
        srv._drop_internal(p["stream"])
        self._pending = None
        self._evaluate(srv, carries, ring)

    def tick(self, srv) -> None:
        """Recovery work first (a heal mid-tick must not race a pending
        canary — apply drops it), then canary spawning."""
        self._recovery_tick(srv)
        live = any(rec is not None and not rec.internal
                   for rec in srv._slots) or any(
            not rec.internal for rec in srv._queue)
        if (self._pending is None and live
                and srv._steps - self._last_spawn >= self.hcfg.interval):
            self._ensure_expected()
            sid = f"~canary{self._canary_n}"
            srv._submit_internal(sid, self._wav, uid=self._uid)
            self._pending = {"stream": sid,
                             "target": srv.geom.window + srv.geom.hop}
            self._last_spawn = srv._steps
            self._canary_n += 1
            self.canaries += 1

    # -- divergence + state machine ------------------------------------------

    @staticmethod
    def _unshuffle(a: np.ndarray, groups: int) -> np.ndarray:
        """Invert the post-MAV channel shuffle (repro.core.binary
        .channel_shuffle) on the last axis, so divergence is reported in
        *bias-channel* coordinates — the coordinates faults are injected
        in and the recompensation writes back to."""
        if groups <= 1:
            return a
        c = a.shape[-1]
        return (a.reshape(a.shape[:-1] + (c // groups, groups))
                .swapaxes(-1, -2).reshape(a.shape))

    def _transition(self, srv, state: str) -> None:
        if state != self.state:
            prev = self.state
            self.state = state
            self.history.append({"tick": srv._steps, "state": state})
            self._metrics.inc("health.transitions", to=state)
            self._metrics.set_gauge("health.state",
                                    self.STATES.index(state))
            if srv._rec is not None:
                srv._rec.record(srv._steps, "health", state=state,
                                prev=prev)

    def _evaluate(self, srv, carries: List[np.ndarray],
                  ring: np.ndarray) -> None:
        """Compare the captured canary state against the clean expectation
        layer by layer.  ``carries[m]`` holds layer m's output columns
        (layer m+1's input carry); the GAP ring holds the last layer's.
        Masked channels are excluded; a layer fails on any implicated
        channel or on total mismatch >= divergence_frac."""
        # the reference may have been invalidated while this canary was in
        # flight (mask change, heal apply, snapshot restore) — recompute
        # against the CURRENT masks, which is also the correct semantics
        self._ensure_expected()
        cfg = srv.cfg
        last = cfg.num_conv_layers - 1
        flagged: Dict[str, List[int]] = {}
        self.divergence = {}
        rows: List[tuple] = []
        for m in range(1, cfg.num_conv_layers):
            if m < last:
                obs, ref = carries[m], self._expected["carries"][m]
            else:
                obs, ref = ring, self._expected["ring"]
            if obs.shape[0] == 0:          # zero-width carry: no view
                continue
            g = cfg.groups(m)
            obs, ref = self._unshuffle(obs, g), self._unshuffle(ref, g)
            mism = obs != ref
            mism[:, self.masked[f"conv{m}"]] = False
            frac = mism.mean(axis=0)
            total = float(mism.mean())
            self.divergence[f"conv{m}"] = round(total, 4)
            bad = np.where(frac >= self.hcfg.channel_frac)[0]
            rows.append((f"conv{m}", total, bad))
        # alarm on the thresholds, but localize to the EARLIEST layer
        # with ANY divergence: corruption amplifies as it feeds forward,
        # so a fault sub-threshold at its own layer (one flipped column
        # barely moving the tail) routinely crosses the alarm threshold
        # only downstream — flagging the first super-threshold layer
        # would heal (and mask!) innocent layers forever while the true
        # cause stays untouched.  The reference carries the accepted
        # baseline, so any nonzero mismatch upstream is a real,
        # unaccepted fault.
        if any(bad.size or total >= self.hcfg.divergence_frac
               for _, total, bad in rows):
            for name, total, bad in rows:
                if bad.size or total > 0.0:
                    flagged[name] = [int(c) for c in bad]
                    break
        if flagged:
            self.failed_canaries += 1
            self._fail_streak += 1
            self._ok_streak = 0
            self.implicated = flagged
            if self.state == "healthy":
                self.detected_tick = srv._steps
                self._transition(srv, "degraded")
            if (self.state == "degraded"
                    and self._fail_streak >= self.hcfg.quarantine_after):
                self.quarantined_tick = srv._steps
                self._transition(srv, "quarantined")
                if self.hcfg.auto_recover and self._recovery is None:
                    self._start_recovery(list(flagged))
            elif self.state == "recovering":
                self._post_heal_fails += 1
                # defer the write-off while a recovery job is in flight:
                # the reference only absorbs the new heal (and any rail
                # channels the job masked) at apply time, so a canary
                # landing between measurement and apply sees stale
                # divergence that is about to clear
                ripe = {n: c for n, c in flagged.items()
                        if n in self._healed}
                if (self._post_heal_fails >= self.hcfg.stuck_after
                        and ripe and self._recovery is None):
                    # repeated heals didn't take.  Columns still failing
                    # on their own (implicated) saturate the bias clip —
                    # stuck rails: write them off permanently.  A layer
                    # failing only in aggregate healed as far as integer
                    # bias writes can go (a fractional fault leaves a
                    # ±0.5-count residual that flips a fixed subset of SA
                    # cells): accept the best-effort heal.  Either way,
                    # REBASELINE: freeze the current fault+heal delta of
                    # every layer a heal has been APPLIED to (its
                    # remaining delta is best-effort residual by
                    # construction) into the expected reference.  Scoping
                    # the freeze to healed layers matters both ways:
                    # sub-count residuals on healed upstream layers flip
                    # cells in columns the tail-only divergence check
                    # never sees — surfacing as unfixable divergence
                    # DOWNSTREAM that only a frozen baseline clears —
                    # while a concurrent never-healed fault keeps
                    # diverging, so it is flagged and healed next instead
                    # of silently absorbed.  Later canaries measure NEW
                    # faults against the accepted chip.  The write-off is
                    # gated on the layer being in ``_healed``: a flagged
                    # layer no heal has covered yet (the prefix ladder is
                    # still climbing toward it) falls through to a
                    # renewed recovery below instead — masking a channel
                    # the test mode never tried to fix would write off
                    # perfectly healable silicon.
                    chip = srv._chip_delta_j
                    for name, chans in ripe.items():
                        if chans:
                            self.masked[name][np.asarray(chans,
                                                         np.int64)] = True
                        elif name not in self.accepted_layers:
                            self.accepted_layers.append(name)
                            if name not in self._frozen_layers:
                                self._frozen_layers.append(name)
                    for name in self._healed:
                        if name not in self._frozen_layers:
                            self._frozen_layers.append(name)
                    if chip is not None:
                        for name in self._frozen_layers:
                            self._ref_delta[name] = np.asarray(
                                chip[name], np.float32).copy()
                        for name, m_ in self.masked.items():
                            if m_.any() and name not in self._frozen_layers:
                                self._ref_delta[name][m_] = np.asarray(
                                    chip[name], np.float32)[m_]
                    self._post_heal_fails = 0
                    self._expected = None   # the reference now carries
                    #                         the frozen accepted deltas
                elif self.hcfg.auto_recover and self._recovery is None:
                    self._start_recovery(list(flagged))  # renewed drift
        else:
            self._fail_streak = 0
            self._ok_streak += 1
            self._post_heal_fails = 0
            if (self.state != "healthy"
                    and self._ok_streak >= self.hcfg.recover_after
                    and self._recovery is None):
                self.implicated = {}
                self._transition(srv, "healthy")

    # -- self-healing: the paper's test mode as a background job -------------

    def _start_recovery(self, layers: List[str]) -> None:
        """Recompensate every layer up to and including the flagged one
        (``recal_scope="prefix"``), or every IMC layer (``"all"``).
        The canary only observes each layer's TAIL columns, so a fault
        can be invisible at its own layer (no tail row flips) while its
        hidden columns corrupt the next layer's inputs — divergence at
        layer m implicates every layer <= m.  The test-mode measurement
        is per-layer and direct (it drives calibration patterns through
        the array itself), so healing the whole prefix fixes any of
        those culprits; on a genuinely clean layer it re-derives the
        pristine bias — a no-op.  ``"all"`` extends the same argument to
        faults the canary cannot see at all (a last-layer fault that
        flips no observed cell of the calibration windows still gets
        measured, and cancelled, by the direct test mode)."""
        if self.hcfg.recal_scope == "all":
            todo = list(self.masked.keys())
        else:
            m = max(int(name[4:]) for name in layers)
            todo = [f"conv{i}" for i in range(1, m + 1)]
        self._recovery = {"phase": "ideal", "layers": todo,
                          "idx": 0, "ideal": None, "keys": None, "bias": {}}

    def _fault_measurement(self, srv, name: str, c: int) -> jnp.ndarray:
        """What the test mode measures beyond the enrollment baseline:
        the chip's *current* fault delta on this layer (the physical
        counts contain it; the recompensation estimates and cancels
        exactly this)."""
        if srv._faults is not None:
            return jnp.asarray(srv._faults.deltas()[name])
        return jnp.zeros((c,))

    def _recovery_tick(self, srv) -> None:
        from repro.training import kws as tr
        job = self._recovery
        if job is None:
            return
        cfg = srv.cfg
        hwp, _ = kws.as_hw_params(srv._hw)
        if job["phase"] == "ideal":
            # the digitize-the-counts reference forward: jnp collect_counts
            # path, zero IMC launches — one tick, like enrollment
            job["ideal"] = {k: np.asarray(v) for k, v in
                            tr.calibration_ideal_counts(
                                srv._hw, jnp.asarray(self._xcal),
                                cfg).items()}
            job["keys"] = {k: np.asarray(v) for k, v in
                           tr.calibration_layer_keys(
                               cfg, self.hcfg.seed + 1
                               + self.recoveries).items()}
            job["phase"] = "layers"
            if srv._rec is not None:
                srv._rec.record(srv._steps, "heal", phase="ideal",
                                layers=list(job["layers"]))
            return
        if job["phase"] == "layers":
            offs = srv._engine_kw["chip_offsets"] or {}
            todo = job["layers"][job["idx"]:
                                 job["idx"] + self.hcfg.layers_per_tick]
            for name in todo:
                c = cfg.channels[int(name[4:])]
                off = offs.get(name)
                baseline = jnp.asarray(job["ideal"][name])
                if off is not None:
                    baseline = baseline + off
                # measured = baseline + fault + noise; the estimator's mean
                # over the calibration windows isolates the fault, and the
                # compensated bias is re-derived from the PRISTINE stored
                # bias (the chip's golden image), so repeated recoveries
                # replace — never stack — the heal
                new_bias, est = tr.compensate_layer_bias(
                    jnp.asarray(hwp.bias[name]), baseline,
                    self._fault_measurement(srv, name, c),
                    jnp.asarray(job["keys"][name]),
                    self.hcfg.recal_sa_noise_std, return_est=True)
                job["bias"][name] = np.asarray(new_bias)
                # the write was asked to cancel `est`; what the clipped
                # parity grid realized is `new_bias - stored`.  A channel
                # whose requested correction overshoots the write by more
                # than one grid step is a rail (stuck column / macro
                # dropout — the fault dominates any finite bias): the
                # test mode has MEASURED it as unhealable, so mask it
                # here, at its own layer, instead of waiting for post-heal
                # canaries to write off whichever downstream layer the
                # corruption happens to surface at
                requested = (np.asarray(hwp.bias[name], np.float32)
                             - np.asarray(est, np.float32))
                shortfall = np.abs(np.asarray(new_bias, np.float32)
                                   - requested)
                rails = shortfall > 2.0
                if rails.any():
                    self.masked[name][rails] = True
            job["idx"] += self.hcfg.layers_per_tick
            if job["idx"] >= len(job["layers"]):
                job["phase"] = "apply"
            if srv._rec is not None:
                srv._rec.record(srv._steps, "heal", phase="layers",
                                done=min(job["idx"], len(job["layers"])),
                                total=len(job["layers"]))
            return
        if job["phase"] == "apply":
            heal = {name: (np.asarray(b, np.float32)
                           - np.asarray(hwp.bias[name], np.float32))
                    for name, b in job["bias"].items()}
            srv._set_heal_delta(heal)
            bias_bits = sum(8 * v.shape[0] for v in heal.values())
            e = energy.recovery_energy_summary(
                kws.layer_stats(cfg), n_cal=self.hcfg.calib_windows,
                bias_bits=bias_bits)
            self.recovery_energy_uj += e["total_uj"]
            self.recoveries += 1
            if srv._rec is not None:
                srv._rec.record(srv._steps, "heal", phase="apply",
                                layers=sorted(heal),
                                uj=round(e["total_uj"], 4))
            # a canary launched before the heal would mix pre/post-heal
            # hops — drop it; the next interval spawns a clean one
            if self._pending is not None:
                srv._drop_internal(self._pending["stream"])
                self._pending = None
            # re-freeze accepted entries: a re-heal REPLACES the layer's
            # heal (new measurement noise realization), moving written-off
            # columns and frozen layers off their frozen reference —
            # track them to the healed chip, or their stale frozen values
            # poison every downstream layer's divergence forever
            chip = srv._chip_delta_j
            if chip is not None:
                for name in heal:
                    if name not in self._healed:
                        self._healed.append(name)
                    cur = np.asarray(chip[name], np.float32)
                    if name in self._frozen_layers:
                        self._ref_delta[name] = cur.copy()
                    elif self.masked[name].any():
                        mask = self.masked[name]
                        self._ref_delta[name][mask] = cur[mask]
                self._expected = None
            # NOTE: _post_heal_fails survives the re-heal — it counts
            # consecutive failing canaries since the FIRST heal, so a
            # fault that re-heals without ever coming clean still reaches
            # stuck_after and gets its columns masked (a reset here would
            # loop heal -> fail -> re-heal forever)
            self._ok_streak = 0
            self._transition(srv, "recovering")
            self._recovery = None

    # -- accounting ----------------------------------------------------------

    def stats(self) -> dict:
        return {
            "state": self.state,
            "canaries": self.canaries,
            "failed_canaries": self.failed_canaries,
            "detected_tick": self.detected_tick,
            "quarantined_tick": self.quarantined_tick,
            "recoveries": self.recoveries,
            "recovery_energy_uj": round(self.recovery_energy_uj, 4),
            "recovery_in_flight": self._recovery is not None,
            "implicated": self.implicated,
            "divergence": self.divergence,
            "masked_channels": {
                name: [int(c) for c in np.where(m)[0]]
                for name, m in self.masked.items() if m.any()},
            "accepted_layers": list(self.accepted_layers),
            "history": list(self.history),
        }

    # -- crash safety --------------------------------------------------------

    def snapshot(self) -> dict:
        """Plain-data state (consumed by StreamServer.snapshot).  The
        expected reference is NOT serialized — it is a pure function of
        the server config and the reserved uid, recomputed lazily."""
        return {
            "state": self.state, "uid": self._uid,
            "canary_n": self._canary_n, "last_spawn": self._last_spawn,
            "fail_streak": self._fail_streak, "ok_streak": self._ok_streak,
            "post_heal_fails": self._post_heal_fails,
            "canaries": self.canaries,
            "failed_canaries": self.failed_canaries,
            "recoveries": self.recoveries,
            "recovery_energy_uj": self.recovery_energy_uj,
            "detected_tick": self.detected_tick,
            "quarantined_tick": self.quarantined_tick,
            "pending": dict(self._pending) if self._pending else None,
            "implicated": {k: list(v) for k, v in self.implicated.items()},
            "divergence": dict(self.divergence),
            "masked": {k: v.copy() for k, v in self.masked.items()},
            "ref_delta": {k: v.copy() for k, v in self._ref_delta.items()},
            "accepted_layers": list(self.accepted_layers),
            "healed": list(self._healed),
            "frozen_layers": list(self._frozen_layers),
            "history": [dict(h) for h in self.history],
            "recovery": ({
                "phase": self._recovery["phase"],
                "layers": list(self._recovery["layers"]),
                "idx": self._recovery["idx"],
                "ideal": (None if self._recovery["ideal"] is None else
                          {k: np.asarray(v)
                           for k, v in self._recovery["ideal"].items()}),
                "keys": (None if self._recovery["keys"] is None else
                         {k: np.asarray(v)
                          for k, v in self._recovery["keys"].items()}),
                "bias": {k: np.asarray(v)
                         for k, v in self._recovery["bias"].items()},
            } if self._recovery else None),
        }

    def restore(self, snap: dict) -> None:
        self.state = str(snap["state"])
        self._uid = int(snap["uid"])
        self._canary_n = int(snap["canary_n"])
        self._last_spawn = int(snap["last_spawn"])
        self._fail_streak = int(snap["fail_streak"])
        self._ok_streak = int(snap["ok_streak"])
        self._post_heal_fails = int(snap["post_heal_fails"])
        self.canaries = int(snap["canaries"])
        self.failed_canaries = int(snap["failed_canaries"])
        self.recoveries = int(snap["recoveries"])
        self.recovery_energy_uj = float(snap["recovery_energy_uj"])
        self.detected_tick = (None if snap["detected_tick"] is None
                              else int(snap["detected_tick"]))
        self.quarantined_tick = (None if snap["quarantined_tick"] is None
                                 else int(snap["quarantined_tick"]))
        self._pending = (dict(snap["pending"]) if snap["pending"]
                         else None)
        self.implicated = {k: [int(c) for c in v]
                           for k, v in snap["implicated"].items()}
        self.divergence = {k: float(v)
                           for k, v in snap["divergence"].items()}
        for name in self.masked:
            self.masked[name] = np.asarray(snap["masked"][name], bool).copy()
            self._ref_delta[name] = np.asarray(snap["ref_delta"][name],
                                               np.float32).copy()
        self.accepted_layers = [str(n) for n in snap["accepted_layers"]]
        self._healed = [str(n) for n in snap["healed"]]
        self._frozen_layers = [str(n) for n in snap["frozen_layers"]]
        self.history = [dict(h) for h in snap["history"]]
        r = snap["recovery"]
        self._recovery = (None if r is None else {
            "phase": str(r["phase"]), "layers": list(r["layers"]),
            "idx": int(r["idx"]),
            "ideal": (None if r["ideal"] is None else
                      {k: np.asarray(v) for k, v in r["ideal"].items()}),
            "keys": (None if r["keys"] is None else
                     {k: np.asarray(v) for k, v in r["keys"].items()}),
            "bias": {k: np.asarray(v) for k, v in r["bias"].items()},
        })
        self._expected = None
