"""Per-hop decision logic: posterior smoothing + hysteresis + refractory.

One logit vector per hop is a noisy instantaneous view of a keyword; the
deployment-standard decision rule (as in the "Hello Edge" MCU pipeline and
the paper's decision-per-window semantics) smooths posteriors over a few
hops and gates triggers so one utterance fires exactly once:

* **smoothing** — the posterior is averaged over the last ``smooth`` hops
  (a ring of softmax outputs; the average divides by the number of hops
  actually seen, so young streams are not diluted by zero padding);
* **hysteresis** — after a trigger the detector disarms until the smoothed
  score falls below ``threshold_off``; it re-arms only then, so a keyword
  that stays above ``threshold_on`` across many hops fires once;
* **refractory** — a hard minimum of ``refractory`` hops between triggers,
  bounding the decision rate even with pathological score trajectories.

Everything is batched over streams (leading axis) and mask-aware: the
scheduler advances only the slots that actually hopped this step.
``decision_step`` is a pure function of ``(DecisionState, logits, mask)``,
so the compiled whole-tick fast path (repro.serving.compiled) scans it
unchanged right behind ``stream_step`` — the decision emitted inside a
fused K-tick block is bitwise the one the interpreted tick would emit.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DecisionConfig:
    smooth: int = 5                 # hops of posterior smoothing
    threshold_on: float = 0.7       # smoothed posterior to fire
    threshold_off: float = 0.5      # re-arm level (hysteresis)
    refractory: int = 10            # min hops between triggers
    background_class: Optional[int] = None   # class that never triggers


jax.tree_util.register_static(DecisionConfig)


class DecisionState(NamedTuple):
    posteriors: jax.Array           # (B, smooth, K) softmax ring
    seen: jax.Array                 # (B,) hops accumulated (<= smooth)
    armed: jax.Array                # (B,) bool — hysteresis state
    refractory: jax.Array           # (B,) int32 hops until re-fire allowed
    last_kw: jax.Array              # (B,) int32 keyword of the last trigger


class DecisionOut(NamedTuple):
    trigger: jax.Array              # (B,) bool — keyword fired this hop
    keyword: jax.Array              # (B,) int32 argmax keyword
    score: jax.Array                # (B,) smoothed posterior of `keyword`
    posterior: jax.Array            # (B, K) smoothed posterior vector


def decision_init(n: int, num_classes: int,
                  dcfg: DecisionConfig = DecisionConfig()) -> DecisionState:
    return DecisionState(
        posteriors=jnp.zeros((n, dcfg.smooth, num_classes)),
        seen=jnp.zeros((n,), jnp.int32),
        armed=jnp.ones((n,), bool),
        refractory=jnp.zeros((n,), jnp.int32),
        last_kw=jnp.zeros((n,), jnp.int32))


def decision_step(dcfg: DecisionConfig, state: DecisionState,
                  logits: jax.Array,
                  active: Optional[jax.Array] = None):
    """Advance the decision state with one hop of logits (B, K).

    ``active`` masks which streams actually hopped: inactive streams keep
    their state verbatim and never trigger.  Returns (new_state, DecisionOut).
    """
    b = logits.shape[0]
    if active is None:
        active = jnp.ones((b,), bool)
    post = jax.nn.softmax(logits, axis=-1)
    ring = jnp.concatenate([state.posteriors[:, 1:], post[:, None]], axis=1)
    seen = jnp.minimum(state.seen + 1, dcfg.smooth)
    smoothed = jnp.sum(ring, axis=1) / jnp.maximum(seen, 1)[:, None]

    scored = smoothed
    if dcfg.background_class is not None:
        scored = scored.at[:, dcfg.background_class].set(-jnp.inf)
    keyword = jnp.argmax(scored, axis=-1).astype(jnp.int32)
    score = jnp.take_along_axis(smoothed, keyword[:, None], axis=1)[:, 0]

    can_fire = (state.armed & (state.refractory == 0)
                & (score >= dcfg.threshold_on))
    trigger = can_fire & active
    # hysteresis tracks the *last-fired* keyword: re-arm when ITS smoothed
    # posterior decays below threshold_off (the utterance actually ended),
    # not when the instantaneous argmax moves elsewhere
    last_score = jnp.take_along_axis(smoothed, state.last_kw[:, None],
                                     axis=1)[:, 0]
    rearm = last_score <= dcfg.threshold_off
    new_armed = jnp.where(trigger, False, state.armed | rearm)
    new_refractory = jnp.where(trigger, dcfg.refractory,
                               jnp.maximum(state.refractory - 1, 0))
    new_last_kw = jnp.where(trigger, keyword, state.last_kw)

    mask = active
    new_state = DecisionState(
        posteriors=jnp.where(mask[:, None, None], ring, state.posteriors),
        seen=jnp.where(mask, seen, state.seen),
        armed=jnp.where(mask, new_armed, state.armed),
        refractory=jnp.where(mask, new_refractory, state.refractory),
        last_kw=jnp.where(mask, new_last_kw, state.last_kw))
    return new_state, DecisionOut(trigger=trigger, keyword=keyword,
                                  score=score, posterior=smoothed)


def reset_slot(state: DecisionState, slot: int) -> DecisionState:
    """Zero one slot's decision state (stream admission / eviction)."""
    return DecisionState(
        posteriors=state.posteriors.at[slot].set(0.0),
        seen=state.seen.at[slot].set(0),
        armed=state.armed.at[slot].set(True),
        refractory=state.refractory.at[slot].set(0),
        last_kw=state.last_kw.at[slot].set(0))
