"""Voice-activity gating: the always-on power front end (paper §VI, Fig 16).

The chip's 14uJ/decision budget is an *always-on* story: leakage dominates
at 1 MHz, so the decisive lever is not making a decision cheaper but not
making one at all when nobody is speaking (DeltaKWS, arXiv 2405.03905,
reaches 36nJ/decision almost entirely on temporal sparsity).  This module
is the cheap digital detector that buys that sparsity: a per-hop
log-energy estimate, smoothed by an EMA and classified speech/silence
through hysteresis thresholds — the same smoothing + hysteresis shape as
the decision head (repro.serving.decision), because it plays the same
role one stage earlier.

Semantics per hop of audio:

* **level** — the hop's mean-square energy in dBFS, folded into an EMA
  (``ema`` keeps the detector from chattering on single quiet frames);
* **hysteresis** — silence -> speech at ``threshold_on_db``; speech ->
  silence only below ``threshold_off_db``, so a keyword whose energy dips
  mid-utterance is not cut;
* **hangover** — after the level falls below the off threshold the
  detector holds "speech" for ``hang`` more hops, covering trailing
  low-energy phonemes;
* **wake margin** — ``wake_margin`` is consumed by the scheduler, not
  here: the last ``wake_margin`` silent hops are buffered (deferred, not
  discarded) so a speech onset replays them through the real IMC path and
  no keyword prefix is lost to detector latency.

Everything is batched over streams (leading axis) and mask-aware, exactly
like the decision head; ``force`` pins the classification for tests and
for the gated-vs-ungated equivalence contract (``force="speech"`` must
make the gated scheduler bit-identical to the ungated one).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

_FLOOR_DB = -120.0                 # silence level the EMA starts from
_EPS = 1e-12                       # keeps log10 finite on all-zero hops


@dataclasses.dataclass(frozen=True)
class VADConfig:
    threshold_on_db: float = -40.0   # silence -> speech above this level
    threshold_off_db: float = -50.0  # speech -> silence below this level
    ema: float = 0.6                 # log-energy EMA (0 = no smoothing)
    hang: int = 2                    # hops speech is held after the level
    #                                  drops below threshold_off_db
    wake_margin: int = 2             # silent hops buffered for replay on a
    #                                  speech onset (scheduler-side)
    force: Optional[str] = None      # 'speech' | 'silence' override (tests,
    #                                  equivalence gate)

    def __post_init__(self):
        if self.force not in (None, "speech", "silence"):
            raise ValueError(f"force={self.force!r} must be None, "
                             f"'speech' or 'silence'")
        if self.threshold_off_db > self.threshold_on_db:
            raise ValueError("threshold_off_db must not exceed "
                             "threshold_on_db (hysteresis band)")
        if self.hang < 0 or self.wake_margin < 0:
            raise ValueError("hang and wake_margin must be >= 0")


jax.tree_util.register_static(VADConfig)


class VADState(NamedTuple):
    """Per-stream detector state (leading axis = batch of streams)."""

    level_db: jax.Array             # (B,) smoothed log-energy, dBFS
    speech: jax.Array               # (B,) bool — current classification
    hang: jax.Array                 # (B,) int32 hangover countdown
    seen: jax.Array                 # (B,) int32 hops observed


def vad_init(n: int) -> VADState:
    return VADState(level_db=jnp.full((n,), _FLOOR_DB),
                    speech=jnp.zeros((n,), bool),
                    hang=jnp.zeros((n,), jnp.int32),
                    seen=jnp.zeros((n,), jnp.int32))


def frame_energy_db(audio: jax.Array) -> jax.Array:
    """Mean-square energy of one hop in dBFS: (B, hop) -> (B,)."""
    return 10.0 * jnp.log10(jnp.mean(jnp.square(audio), axis=-1) + _EPS)


def vad_step(vcfg: VADConfig, state: VADState, audio: jax.Array,
             active: Optional[jax.Array] = None
             ) -> Tuple[VADState, jax.Array]:
    """Classify one hop of audio (B, hop) per stream.

    ``active`` masks which streams actually have a fresh hop: inactive
    streams keep their state verbatim and report their previous
    classification.  Returns (new_state, speech_flags (B,) bool).
    """
    b = audio.shape[0]
    if active is None:
        active = jnp.ones((b,), bool)
    e = frame_energy_db(audio)
    level = jnp.where(state.seen > 0,
                      vcfg.ema * state.level_db + (1.0 - vcfg.ema) * e, e)
    # hysteresis: the live threshold depends on the current classification
    hot = jnp.where(state.speech,
                    level >= vcfg.threshold_off_db,
                    level >= vcfg.threshold_on_db)
    hang = jnp.where(hot, jnp.int32(vcfg.hang),
                     jnp.maximum(state.hang - 1, 0))
    # the pre-decrement counter gates the hold, so hang=N keeps speech for
    # exactly N hops after the level falls below threshold_off_db
    speech = hot | (state.speech & (state.hang > 0))
    if vcfg.force == "speech":
        speech = jnp.ones((b,), bool)
    elif vcfg.force == "silence":
        speech = jnp.zeros((b,), bool)

    new_state = VADState(
        level_db=jnp.where(active, level, state.level_db),
        speech=jnp.where(active, speech, state.speech),
        hang=jnp.where(active, hang, state.hang),
        seen=jnp.where(active, state.seen + 1, state.seen))
    return new_state, jnp.where(active, speech, state.speech)


def vad_scan(vcfg: VADConfig, state: VADState, audio: jax.Array,
             active: jax.Array) -> Tuple[VADState, jax.Array]:
    """Classify K hops in one ``lax.scan``: audio (K, B, hop) + active
    (K, B) -> (final state, speech flags (K, B)).

    One dispatch for a whole compiled serving block
    (repro.serving.compiled) instead of K ``vad_step`` calls; the body IS
    ``vad_step``, so the state trajectory and every flag are bit-identical
    to K sequential steps (the masked writes also make padded all-inactive
    rows/steps exact no-ops, which is what lets the block pad K up to a
    power of two without perturbing the detector)."""

    def body(st, xs):
        a, act = xs
        st, flags = vad_step(vcfg, st, a, act)
        return st, flags

    return jax.lax.scan(body, state, (audio, active))


def vad_reset_slot(state: VADState, slot: int) -> VADState:
    """Zero one slot's detector state (stream admission / eviction)."""
    return VADState(level_db=state.level_db.at[slot].set(_FLOOR_DB),
                    speech=state.speech.at[slot].set(False),
                    hang=state.hang.at[slot].set(0),
                    seen=state.seen.at[slot].set(0))
