"""Frame-incremental streaming inference over the folded KWS model.

The paper's accelerator is *always-on*: it emits one decision per hop of a
sliding audio window.  Recomputing the whole window per decision (what
``hw_forward`` does) wastes exactly the work the 14uJ/decision budget
forbids — the overlap between consecutive windows is ``1 - hop/window`` of
every layer.  This module computes each hop incrementally:

* every layer's activation columns are indexed by *absolute time*.  When the
  hop is a multiple of ``hop_alignment(cfg)`` (the product of all strides
  and pool windows, 64 samples for the paper net), consecutive windows'
  overlapping columns are **identical** at every layer, pool pairs included,
  so cached columns can be reused verbatim;
* per hop, each layer only computes its tail: the hop's fresh columns plus a
  tiny carry — the k-1 conv overlap columns and, on layers whose conv length
  is odd, the one conv column the previous window's OR-maxpool truncated
  (that carry IS the pool ring state: the truncated column is recomputed and
  pooled next hop, exactly as the offline window would);
* SA noise is drawn from a **per-absolute-column field**
  (``fold_in(fold_in(stream_key, layer), abs_col)``), mirroring the silicon:
  each column is evaluated by the sense amplifier exactly once, and its
  realization rides along with the cached activation.  Column ``a`` of
  layer ``l`` yields the same (C_out,) realization no matter which hop or
  which code path evaluates it, so cached columns never need re-noising and
  offline windows can evaluate the same field (``window_sa_noise``) and
  feed it to ``hw_forward(sa_noise=...)`` — which is how the streaming path
  is test-enforced bit-identical to per-window ``hw_forward`` on every hop,
  noise and chip offsets included.  The per-hop evaluation is hoisted into
  ONE batched key derivation for all layers and streams
  (``hop_sa_noise_fields``), not a vmapped ``fold_in`` per layer.

``StreamEngine`` wraps init/step as jitted functions over a batch of
streams.  **One-launch-per-layer invariant:** a ``stream_step`` over a
batch of B streams issues exactly one fused ``pallas_call`` per IMC layer
(conv1..conv5) regardless of B — the scheduler
(repro.serving.scheduler) rides every live slot on the same launch, masked
slots included.  ``stream_multi_step`` advances n consecutive hops in the
same single launch per layer (each layer's tail just extends by the extra
hops' fresh columns) — the wake replay's batched drain.  Per-stream
customization (repro.serving.customize) rides two optional operands:
``bias_delta`` — integer compensated-bias deltas entering the kernel on
the pre-sign (noise) operand, exactly where the word-line bias lands —
and ``head_w``/``head_b``, a per-stream FC head; both are bit-exact
against refolding the params (integer adds; the GAP/FC math has no float
rounding on the fixed-point grids).  ``streaming=False`` selects the
recompute fallback, which calls ``hw_forward`` on the full window per hop
and is bit-identical to it by construction.

``gated_step`` is the voice-activity-gated no-op advance: a hop the VAD
(repro.serving.vad) classified as silence shifts the layer carries and the
GAP ring by their per-hop column counts **without launching any IMC
kernel** — the shifted-in columns are the folded net's constant
steady-state response to silent audio (``repro.models.kws.silence_columns``),
so the state geometry stays hop-exact while the chip sleeps (leakage-only
in the energy model, ``repro.core.energy.gated_energy_summary``).

Everything here is pure pytree-in / pytree-out over ``StreamState``, which
is what the compiled whole-tick fast path (repro.serving.compiled) relies
on: it puts ONE ``stream_step`` / ``gated_step`` pair inside a
``lax.scan`` body and fuses K ticks into a single dispatch — the scan
re-issues the same one-launch-per-layer step per tick at run time, so the
invariant (and bit-identity to K interpreted ticks) is structural, not
re-proved per block.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quantize import ACT_Q
from repro.core.sa_noise import sa_noise_columns
from repro.models import kws

# ---------------------------------------------------------------------------
# Geometry: what each layer computes per hop
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerGeom:
    """Static per-layer streaming geometry (one hop).

    t_in/t_conv/t_out: the layer's full-window input / conv / post-pool
    lengths; d_in/d_out: fresh input/output columns per hop; conv_lo: local
    conv column where the per-hop tail starts (pool-aligned by
    construction); tail_in: input columns consumed per hop; carry =
    tail_in - d_in: columns cached across hops (conv overlap + pool phase).
    """

    t_in: int
    t_conv: int
    t_out: int
    d_in: int
    d_out: int
    conv_lo: int
    tail_in: int
    carry: int


@dataclasses.dataclass(frozen=True)
class StreamGeometry:
    window: int
    hop: int
    layers: Tuple[LayerGeom, ...]          # one per conv layer (0..5)

    @property
    def t_feat(self) -> int:
        """Final layer's pooled length — the GAP ring extent."""
        return self.layers[-1].t_out

    @property
    def d_feat(self) -> int:
        """Fresh final-layer columns per hop (GAP ring shift)."""
        return self.layers[-1].d_out


jax.tree_util.register_static(LayerGeom)
jax.tree_util.register_static(StreamGeometry)


def hop_alignment(cfg: kws.KWSConfig) -> int:
    """Smallest hop (in samples) with full column reuse: the product of all
    strides and pool windows (64 for the paper net).  Any multiple works."""
    a = 1
    for i in range(cfg.num_conv_layers):
        a *= cfg.strides[i] * cfg.pools[i]
    return a


def make_stream_geometry(cfg: kws.KWSConfig, hop: int) -> StreamGeometry:
    """Static per-layer tail/carry geometry for a hop size.

    Raises if ``hop`` is not a multiple of ``hop_alignment(cfg)`` (pool
    pairs would straddle hops and cached columns would go stale) or if the
    hop is too small to produce at least one fresh column everywhere."""
    align = hop_alignment(cfg)
    if hop % align or hop <= 0:
        raise ValueError(
            f"hop={hop} must be a positive multiple of {align} "
            f"(prod of strides*pools) for bit-exact column reuse")
    if hop >= cfg.sample_len:
        raise ValueError(f"hop={hop} must be smaller than the "
                         f"window ({cfg.sample_len})")
    layers = []
    t_in, d_in = cfg.sample_len, hop
    for i in range(cfg.num_conv_layers):
        k, s, p = cfg.kernels[i], cfg.strides[i], cfg.pools[i]
        t_conv = (t_in - k) // s + 1
        t_out = t_conv // p
        n_new = d_in // s                  # fresh conv columns per hop
        d_out = n_new // p
        assert d_in % s == 0 and n_new % p == 0, "hop_alignment violated"
        if d_out < 1 or d_out > t_out:
            raise ValueError(
                f"layer {i}: hop yields {d_out} fresh columns of {t_out} — "
                f"hop/window ratio unusable at this depth")
        conv_lo = p * (t_out - d_out)      # pool-aligned tail start
        tail_in = t_in - s * conv_lo
        layers.append(LayerGeom(t_in=t_in, t_conv=t_conv, t_out=t_out,
                                d_in=d_in, d_out=d_out, conv_lo=conv_lo,
                                tail_in=tail_in, carry=tail_in - d_in))
        t_in, d_in = t_out, d_out
    return StreamGeometry(window=cfg.sample_len, hop=hop,
                          layers=tuple(layers))


# ---------------------------------------------------------------------------
# Per-absolute-column SA-noise field (primitives live in repro.core.sa_noise
# — the hardware-model layer — so the offline oracle side can evaluate the
# same field without importing serving; this module keeps the hop-geometry
# views of it)
# ---------------------------------------------------------------------------


def window_sa_noise(key: jax.Array, cfg: kws.KWSConfig,
                    geom: StreamGeometry, hop_index,
                    std: float) -> Dict[str, jax.Array]:
    """The full-window view of the noise field: per-layer (1, t_conv, C)
    arrays for window ``hop_index``, in ``hw_forward(sa_noise=...)`` layout.
    Feeding this to hw_forward reproduces the streaming path bit-exactly —
    the offline oracle for the equivalence tests and the recompute engine's
    noise source."""
    noise = {}
    for i in range(1, cfg.num_conv_layers):
        lg = geom.layers[i]
        n_new = lg.d_out * cfg.pools[i]
        cols = hop_index * n_new + jnp.arange(lg.t_conv)
        noise[f"conv{i}"] = sa_noise_columns(key, i, cols, cfg.channels[i],
                                             std)[None]
    return noise


def _hop_sa_noise(keys: jax.Array, hops: jax.Array, layer: int,
                  cfg: kws.KWSConfig, geom: StreamGeometry,
                  std: float) -> jax.Array:
    """Field values for one hop's tail conv columns, batched over streams:
    keys (B, 2), hops (B,) -> (B, t_conv_tail, C).  Single-layer form —
    ``stream_step`` uses the cross-layer ``hop_sa_noise_fields`` hoist."""
    lg = geom.layers[layer]
    n_new = lg.d_out * cfg.pools[layer]
    n_tail = lg.t_conv - lg.conv_lo

    def one(key, hop):
        cols = hop * n_new + lg.conv_lo + jnp.arange(n_tail)
        return sa_noise_columns(key, layer, cols, cfg.channels[layer], std)

    return jax.vmap(one)(keys, hops)


def hop_sa_noise_fields(keys: jax.Array, hops: jax.Array,
                        cfg: kws.KWSConfig, geom: StreamGeometry,
                        std: float, n_hops: int = 1) -> Dict[str, jax.Array]:
    """All IMC layers' tail noise-field values for one hop in ONE batched
    key derivation: keys (B, 2), hops (B,) -> {conv_i: (B, n_tail_i, C_i)}.

    ``n_hops > 1`` extends each layer's tail to cover a run of consecutive
    hops starting at ``hops`` (the wake-replay batching: the deferred
    silent hops plus the onset hop advance in ONE multi-hop launch).  The
    field itself is per-absolute-column, so the multi-hop evaluation is
    bit-identical to evaluating the same columns hop by hop.

    Bit-identical to calling ``_hop_sa_noise`` per layer (the field is
    unchanged), but the ``fold_in(fold_in(key, layer), col)`` chain for
    every (layer, column) pair of the hop is flattened into a single
    vmapped hash over ~sum(n_tail_i) elements instead of one tiny vmapped
    draw per layer per hop — the cross-stream dedup the ROADMAP called out
    (~5 separate key-derivation kernels per hop per stream batch in noisy
    mode).  The per-layer normal draws remain separate because each
    layer's channel width differs (threefry draws are not prefix-stable
    across shapes, so a single padded draw would change the field)."""
    specs = []                       # (layer, n_tail, c_out) + static cols
    col_chunks = []
    lid_chunks = []
    for i in range(1, cfg.num_conv_layers):
        lg = geom.layers[i]
        n_new = lg.d_out * cfg.pools[i]
        n_tail = lg.t_conv - lg.conv_lo + (n_hops - 1) * n_new
        specs.append((i, n_tail, cfg.channels[i]))
        col_chunks.append((n_new, lg.conv_lo, n_tail))
        lid_chunks.append(jnp.full((n_tail,), i, jnp.int32))
    layer_ids = jnp.concatenate(lid_chunks)            # (sum n_tail,)

    def one_stream(key, hop):
        cols = jnp.concatenate([
            hop * n_new + lo + jnp.arange(n)
            for (n_new, lo, n) in col_chunks])
        flat_keys = jax.vmap(
            lambda l, a: jax.random.fold_in(jax.random.fold_in(key, l), a)
        )(layer_ids, cols)                             # one batched hash
        out, base = {}, 0
        for (i, n_tail, c_out) in specs:
            ks = flat_keys[base:base + n_tail]
            out[f"conv{i}"] = std * jax.vmap(
                lambda k: jax.random.normal(k, (c_out,)))(ks)
            base += n_tail
        return out

    return jax.vmap(one_stream)(keys, hops)


# ---------------------------------------------------------------------------
# Stream state + init/step
# ---------------------------------------------------------------------------


class StreamState(NamedTuple):
    """Per-stream incremental state (leading axis = batch of streams).

    ``audio_carry``/``carries`` are the layers' ring tails (the only
    activation columns that must survive a hop); ``ring`` is the final
    layer's full pooled window, feeding GAP; ``hop`` counts decided windows
    (window t's columns live at absolute index t*shift + local); ``key`` is
    the per-stream noise-field key."""

    audio_carry: jax.Array                 # (B, carry_0) raw samples
    carries: Tuple[jax.Array, ...]         # (B, carry_i, C_{i-1}), i=1..
    ring: jax.Array                        # (B, t_feat, C_last)
    hop: jax.Array                         # (B,) int32
    key: jax.Array                         # (B, 2) uint32


class WindowState(NamedTuple):
    """Recompute-fallback state: the raw audio window only."""

    window: jax.Array                      # (B, window)
    hop: jax.Array                         # (B,) int32
    key: jax.Array                         # (B, 2) uint32


def zeros_state(cfg: kws.KWSConfig, geom: StreamGeometry,
                n: int) -> StreamState:
    carries = tuple(
        jnp.zeros((n, geom.layers[i].carry, cfg.channels[i - 1]))
        for i in range(1, cfg.num_conv_layers))
    return StreamState(
        audio_carry=jnp.zeros((n, geom.layers[0].carry)),
        carries=carries,
        ring=jnp.zeros((n, geom.t_feat, cfg.channels[-1])),
        hop=jnp.zeros((n,), jnp.int32),
        key=jnp.zeros((n, 2), jnp.uint32))


def zeros_window_state(cfg: kws.KWSConfig, n: int) -> WindowState:
    return WindowState(window=jnp.zeros((n, cfg.sample_len)),
                       hop=jnp.zeros((n,), jnp.int32),
                       key=jnp.zeros((n, 2), jnp.uint32))


def _tail(x: jax.Array, n: int) -> jax.Array:
    """Last ``n`` columns of axis 1 — unlike ``x[:, -n:]`` this stays an
    empty slice when a layer's carry is 0 (k == stride, no pool phase)."""
    return x[:, x.shape[1] - n:]


def _gap_fc(hw: kws.HWParams, ring: jax.Array):
    feats = ACT_Q.quantize(jnp.mean(ring, axis=1))
    return feats @ hw.fc_w + hw.fc_b, feats


def _ring_logits(hwp: kws.HWParams, ring: jax.Array,
                 head_w: Optional[jax.Array],
                 head_b: Optional[jax.Array]) -> jax.Array:
    """GAP + FC with an optional per-stream head: ``head_w`` (B, D, C) /
    ``head_b`` (B, C) replace the shared folded FC for every stream (the
    scheduler broadcasts the base head into the rows of uncustomized
    slots, so only hot-swapped slots actually diverge).  The per-row
    matvec is the same contraction the shared matmul performs row-wise, so
    a row whose head equals the base head produces the base logits."""
    if head_w is None:
        return _gap_fc(hwp, ring)[0]
    feats = ACT_Q.quantize(jnp.mean(ring, axis=1))
    return jax.vmap(lambda f, w, b: f @ w + b)(feats, head_w, head_b)


def _merge_bias_delta(noise: Optional[jax.Array],
                      delta: Optional[jax.Array],
                      n_cols: int) -> Optional[jax.Array]:
    """Fold a per-stream bias delta (B, C) into the per-column pre-SA
    operand (B, n_cols, C).  The fused kernel adds this operand exactly
    where the word-line bias lands (pre-sign), so an integer delta rides
    the existing SA-noise input and a customized stream's IMC layers run in
    the SAME batched launch as every other slot — per-slot compensated
    biases without per-slot kernels.  With no SA noise the operand is the
    broadcast delta alone (integers: bit-exact vs refolding the bias)."""
    if delta is None:
        return noise
    d = delta[:, None, :]
    if noise is None:
        return jnp.broadcast_to(d, (delta.shape[0], n_cols, delta.shape[1]))
    return noise + d


def stream_init(hw, window: jax.Array, keys: jax.Array,
                cfg: kws.KWSConfig, geom: StreamGeometry, *,
                chip_offsets: Optional[Dict[str, jax.Array]] = None,
                sa_noise_std: float = 0.0,
                use_kernel: bool = True,
                bias_delta: Optional[Dict[str, jax.Array]] = None,
                head_w: Optional[jax.Array] = None,
                head_b: Optional[jax.Array] = None):
    """Process a stream's first full window (B, window) and build its
    incremental state.  Equivalent to hw_forward on the window (hop 0 of
    the noise field), plus capturing each layer's ring tail.

    ``bias_delta`` ({conv_i: (B, C_i)}) and ``head_w``/``head_b`` are the
    per-stream customization riders (repro.serving.customize): integer
    bias deltas from bias compensation and a fine-tuned FC head, applied
    per batch row."""
    hwp, packed = kws.as_hw_params(hw)
    b = window.shape[0]
    hops0 = jnp.zeros((b,), jnp.int32)
    h = window[..., None]
    carries = []
    for i in range(cfg.num_conv_layers):
        noise = off = packed_i = None
        if i > 0:
            carries.append(_tail(h, geom.layers[i].carry))
            lg = geom.layers[i]
            if sa_noise_std > 0.0:
                cols = jnp.arange(lg.t_conv)
                noise = jax.vmap(lambda k: sa_noise_columns(
                    k, i, cols, cfg.channels[i], sa_noise_std))(keys)
            if bias_delta is not None:
                noise = _merge_bias_delta(noise, bias_delta[f"conv{i}"],
                                          lg.t_conv)
            if chip_offsets is not None:
                off = chip_offsets[f"conv{i}"]
            packed_i = packed[f"conv{i}"] if packed else None
        h = kws.hw_conv_layer(hwp, i, h, cfg, packed=packed_i,
                              chip_offset=off, sa_noise=noise,
                              use_kernel=use_kernel)
    logits = _ring_logits(hwp, h, head_w, head_b)
    state = StreamState(audio_carry=_tail(window, geom.layers[0].carry),
                        carries=tuple(carries), ring=h,
                        hop=hops0 + 1, key=keys)
    return logits, state


def _stream_advance(hw, state: StreamState, audio: jax.Array,
                    cfg: kws.KWSConfig, geom: StreamGeometry, n_hops: int, *,
                    chip_offsets, sa_noise_std, use_kernel, bias_delta,
                    head_w, head_b):
    """Shared body of ``stream_step`` / ``stream_multi_step``: advance a
    batch of streams by ``n_hops`` consecutive hops with ONE fused-kernel
    launch per IMC layer — each layer's tail simply extends by the extra
    hops' fresh columns, and the per-absolute-column noise field covers
    the extended tail (``hop_sa_noise_fields(n_hops=...)``).  Returns
    (per-hop logits [(B, C)] * n_hops, new state)."""
    hwp, packed = kws.as_hw_params(hw)
    x = jnp.concatenate([state.audio_carry, audio], axis=1)
    new_audio_carry = _tail(x, geom.layers[0].carry)
    h = kws.hw_conv_layer(hwp, 0, x[..., None], cfg)
    noise_all = None
    if sa_noise_std > 0.0:
        noise_all = hop_sa_noise_fields(state.key, state.hop, cfg, geom,
                                        sa_noise_std, n_hops=n_hops)
    new_carries = []
    for i in range(1, cfg.num_conv_layers):
        lg = geom.layers[i]
        name = f"conv{i}"
        inp = jnp.concatenate([state.carries[i - 1], h], axis=1)
        new_carries.append(_tail(inp, lg.carry))
        noise = noise_all[name] if noise_all is not None else None
        if bias_delta is not None:
            t_conv_tail = (inp.shape[1] - cfg.kernels[i]) // cfg.strides[i] + 1
            noise = _merge_bias_delta(noise, bias_delta[name], t_conv_tail)
        off = chip_offsets[name] if chip_offsets is not None else None
        if use_kernel:
            from repro.kernels.imc_mav import ops as mav_ops
            h = mav_ops.fused_conv_mav_step(
                inp, hwp.w_bin[name], hwp.bias[name], hwp.flip[name],
                groups=cfg.groups(i), stride=cfg.strides[i],
                pool=cfg.pools[i], chip_offset=off, sa_noise=noise,
                packed=packed[name] if packed else None)
        else:
            h = kws.hw_conv_layer(hwp, i, inp, cfg, chip_offset=off,
                                  sa_noise=noise, use_kernel=False)
    logits_hops = []
    for j in range(1, n_hops + 1):
        ring = jnp.concatenate([state.ring, h[:, :j * geom.d_feat]],
                               axis=1)[:, -geom.t_feat:]
        logits_hops.append(_ring_logits(hwp, ring, head_w, head_b))
    new_state = StreamState(audio_carry=new_audio_carry,
                            carries=tuple(new_carries), ring=ring,
                            hop=state.hop + n_hops, key=state.key)
    return logits_hops, new_state


def stream_step(hw, state: StreamState, audio: jax.Array,
                cfg: kws.KWSConfig, geom: StreamGeometry, *,
                chip_offsets: Optional[Dict[str, jax.Array]] = None,
                sa_noise_std: float = 0.0,
                use_kernel: bool = True,
                bias_delta: Optional[Dict[str, jax.Array]] = None,
                head_w: Optional[jax.Array] = None,
                head_b: Optional[jax.Array] = None):
    """Advance a batch of streams by one hop: audio (B, hop) -> (logits,
    new state).  Each layer computes only its tail (carry + fresh columns)
    — one fused-kernel launch per IMC layer for the whole batch — and the
    decision is re-formed from the GAP ring.  Bit-identical to hw_forward
    on the corresponding full window (the equivalence tests drive both).
    ``bias_delta``/``head_w``/``head_b`` are the per-stream customization
    riders (see ``stream_init``)."""
    logits_hops, new_state = _stream_advance(
        hw, state, audio, cfg, geom, 1, chip_offsets=chip_offsets,
        sa_noise_std=sa_noise_std, use_kernel=use_kernel,
        bias_delta=bias_delta, head_w=head_w, head_b=head_b)
    return logits_hops[0], new_state


def stream_multi_step(hw, state: StreamState, audio: jax.Array,
                      cfg: kws.KWSConfig, geom: StreamGeometry,
                      n_hops: int, *,
                      chip_offsets: Optional[Dict[str, jax.Array]] = None,
                      sa_noise_std: float = 0.0,
                      use_kernel: bool = True,
                      bias_delta: Optional[Dict[str, jax.Array]] = None,
                      head_w: Optional[jax.Array] = None,
                      head_b: Optional[jax.Array] = None):
    """Advance by ``n_hops`` consecutive hops in ONE fused-kernel launch
    per IMC layer: audio (B, n_hops*hop) -> (logits (B, n_hops, C), new
    state).  Bit-identical to ``n_hops`` sequential ``stream_step`` calls
    (same columns, same per-absolute-column noise realizations — the
    columns are just computed in one tail instead of n) — the VAD wake
    replay uses this to drain its deferred hops in one launch instead of
    one launch per deferred hop."""
    logits_hops, new_state = _stream_advance(
        hw, state, audio, cfg, geom, n_hops, chip_offsets=chip_offsets,
        sa_noise_std=sa_noise_std, use_kernel=use_kernel,
        bias_delta=bias_delta, head_w=head_w, head_b=head_b)
    return jnp.stack(logits_hops, axis=1), new_state


def window_init(hw, window: jax.Array, keys: jax.Array,
                cfg: kws.KWSConfig, geom: StreamGeometry, *,
                chip_offsets=None, sa_noise_std: float = 0.0,
                use_kernel: bool = True, bias_delta=None,
                head_w=None, head_b=None):
    """Recompute-fallback init: hw_forward on the first window."""
    logits, state = _window_forward(hw, window, keys,
                                    jnp.zeros((window.shape[0],), jnp.int32),
                                    cfg, geom, chip_offsets=chip_offsets,
                                    sa_noise_std=sa_noise_std,
                                    use_kernel=use_kernel,
                                    bias_delta=bias_delta,
                                    head_w=head_w, head_b=head_b)
    return logits, state


def window_step(hw, state: WindowState, audio: jax.Array,
                cfg: kws.KWSConfig, geom: StreamGeometry, *,
                chip_offsets=None, sa_noise_std: float = 0.0,
                use_kernel: bool = True, bias_delta=None,
                head_w=None, head_b=None):
    """Recompute-fallback hop: slide the audio window, rerun hw_forward on
    all of it.  Bit-identical to the streaming path (same noise field),
    just ~window/hop times the work — the baseline --streaming benches
    against."""
    window = jnp.concatenate([state.window[:, geom.hop:], audio], axis=1)
    return _window_forward(hw, window, state.key, state.hop, cfg, geom,
                           chip_offsets=chip_offsets,
                           sa_noise_std=sa_noise_std, use_kernel=use_kernel,
                           bias_delta=bias_delta, head_w=head_w,
                           head_b=head_b)


def window_multi_step(hw, state: WindowState, audio: jax.Array,
                      cfg: kws.KWSConfig, geom: StreamGeometry,
                      n_hops: int, *, chip_offsets=None,
                      sa_noise_std: float = 0.0, use_kernel: bool = True,
                      bias_delta=None, head_w=None, head_b=None):
    """Recompute-fallback twin of ``stream_multi_step``: ``n_hops``
    sequential full-window recomputes in one call — the recompute path has
    no launch-count story to improve, so this only unifies the scheduler's
    wake-replay entry.  Returns (logits (B, n_hops, C), state)."""
    logits = []
    for j in range(n_hops):
        lg, state = window_step(hw, state,
                                audio[:, j * geom.hop:(j + 1) * geom.hop],
                                cfg, geom, chip_offsets=chip_offsets,
                                sa_noise_std=sa_noise_std,
                                use_kernel=use_kernel,
                                bias_delta=bias_delta, head_w=head_w,
                                head_b=head_b)
        logits.append(lg)
    return jnp.stack(logits, axis=1), state


def _window_forward(hw, window, keys, hops, cfg, geom, *, chip_offsets,
                    sa_noise_std, use_kernel, bias_delta=None,
                    head_w=None, head_b=None):
    noise = None
    if sa_noise_std > 0.0:
        per_layer = jax.vmap(
            lambda k, t: window_sa_noise(k, cfg, geom, t, sa_noise_std))(
                keys, hops)
        noise = {name: v[:, 0] for name, v in per_layer.items()}
    if bias_delta is not None:
        b = window.shape[0]
        noise = dict(noise) if noise is not None else {}
        for i in range(1, cfg.num_conv_layers):
            name = f"conv{i}"
            noise[name] = _merge_bias_delta(noise.get(name),
                                            bias_delta[name],
                                            geom.layers[i].t_conv)
    logits, feats = kws.hw_forward(hw, window, cfg,
                                   chip_offsets=chip_offsets,
                                   sa_noise_std=sa_noise_std, sa_noise=noise,
                                   use_kernel=use_kernel)
    if head_w is not None:
        logits = jax.vmap(lambda f, w, b: f @ w + b)(feats, head_w, head_b)
    return logits, WindowState(window=window, hop=hops + 1, key=keys)


# ---------------------------------------------------------------------------
# Voice-activity-gated no-op advance (no IMC launch)
# ---------------------------------------------------------------------------


def silence_fills(cfg: kws.KWSConfig,
                  sil: Dict[str, jax.Array]) -> Tuple[jax.Array, ...]:
    """Order the per-layer silence columns (``kws.silence_columns``) into
    the tuple ``gated_step`` consumes: fills[i] is the constant (C_i,)
    steady-state output column of conv layer i on silent audio — the value
    shifted into layer i+1's carry (and, for the last layer, the GAP ring)
    on a gated hop."""
    return tuple(sil[f"conv{i}"] for i in range(cfg.num_conv_layers))


def retention_fills(hw, cfg: kws.KWSConfig, *, key: jax.Array,
                    sa_noise_std: float,
                    chip_offsets: Optional[Dict[str, jax.Array]] = None
                    ) -> Tuple[jax.Array, ...]:
    """SA-retention ("comfort noise") silence fills: the chip-accurate
    alternative to the noiseless constant of ``silence_fills``.

    ``kws.silence_columns`` models a gated hop as the *ideal* constant
    response to silence — correct for an array whose outputs are recomputed
    on wake.  On silicon the sleeping macros instead *retain* the last
    latched sense-amplifier read of the silent input, which carries one
    frozen SA-noise realization: each layer's fill is its silence response
    evaluated once WITH a deterministic SA read (one noise draw per layer,
    derived from ``key``), and that retained column — not the fresh ideal
    one — feeds the next layer's retention evaluation.  Deterministic in
    ``key``, so gated advances stay reproducible and snapshot-safe.  With
    ``sa_noise_std=0`` this degenerates to exactly ``silence_fills``
    (the default the tests pin)."""
    hwp, _ = kws.as_hw_params(hw)
    h = jnp.zeros((1, cfg.sample_len, 1))
    fills = []
    for i in range(cfg.num_conv_layers):
        off = sa_key = None
        if i > 0:
            if chip_offsets is not None:
                off = chip_offsets[f"conv{i}"]
            if sa_noise_std > 0.0:
                sa_key = jax.random.fold_in(key, i)
        h = kws.hw_conv_layer(hwp, i, h, cfg, chip_offset=off,
                              sa_key=sa_key, sa_noise_std=sa_noise_std,
                              use_kernel=False)
        col = h[0, 0]
        fills.append(col)
        # the retained column is what downstream layers see while asleep
        h = jnp.broadcast_to(col, (1, h.shape[1], col.shape[0]))
    return tuple(fills)


def gated_step(state: StreamState, cfg: kws.KWSConfig, geom: StreamGeometry,
               fills: Tuple[jax.Array, ...]) -> StreamState:
    """Advance a batch of streams by one *silent* hop without computing.

    The VAD classified the hop as silence, so no IMC kernel launches:
    every carry and the GAP ring shift by their per-hop column counts, the
    shifted-in columns being each layer's constant response to silent
    audio (valid convolutions of a constant input are constant, so the
    fill is a single (C_i,) vector per layer).  The audio carry shifts in
    zeros (the unsampled microphone).  ``hop`` still advances, keeping the
    absolute-column noise field aligned for the next computed hop.

    This is the energy model's leakage-only hop: the only digital activity
    is the VAD front end (see ``repro.core.energy.gated_energy_summary``).
    On all-speech audio ``gated_step`` never runs, which is why gating with
    the VAD forced to "speech" stays bit-identical to ungated streaming.

    Each ``fills`` entry is either a shared (C_i,) silence column or a
    per-stream (B, C_i) one — hot-swapped slots carry compensated biases,
    so their silence response differs from the base chip's
    (repro.serving.customize recomputes it at swap time)."""
    b = state.hop.shape[0]

    def _fill(f, d):
        if f.ndim == 1:
            return jnp.broadcast_to(f, (b, d, f.shape[0]))
        return jnp.broadcast_to(f[:, None, :], (b, d, f.shape[-1]))

    audio_carry = _tail(
        jnp.concatenate([state.audio_carry,
                         jnp.zeros((b, geom.hop))], axis=1),
        geom.layers[0].carry)
    new_carries = []
    for i in range(1, cfg.num_conv_layers):
        lg = geom.layers[i]
        new_carries.append(_tail(
            jnp.concatenate([state.carries[i - 1],
                             _fill(fills[i - 1], lg.d_in)], axis=1),
            lg.carry))
    ring_fill = _fill(fills[-1], geom.d_feat)
    ring = jnp.concatenate([state.ring[:, geom.d_feat:], ring_fill], axis=1)
    return StreamState(audio_carry=audio_carry, carries=tuple(new_carries),
                       ring=ring, hop=state.hop + 1, key=state.key)


def gated_window_step(state: WindowState, geom: StreamGeometry
                      ) -> WindowState:
    """Recompute-fallback twin of ``gated_step``: slide the raw window by
    one hop of zeros (silence) without running hw_forward."""
    b = state.hop.shape[0]
    window = jnp.concatenate(
        [state.window[:, geom.hop:], jnp.zeros((b, geom.hop))], axis=1)
    return WindowState(window=window, hop=state.hop + 1, key=state.key)


# ---------------------------------------------------------------------------
# Jitted engine over a fixed batch of streams
# ---------------------------------------------------------------------------


class StreamEngine:
    """Init/step over a fixed-size batch of streams, jit-compiled once.

    ``streaming=True`` runs the frame-incremental path; ``streaming=False``
    the recompute fallback (full hw_forward per hop, bit-identical by
    construction).  The scheduler (repro.serving.scheduler) owns slots,
    masking and admission; this class owns the pure compute."""

    def __init__(self, hw, cfg: kws.KWSConfig, hop: int, *,
                 chip_offsets: Optional[Dict[str, jax.Array]] = None,
                 sa_noise_std: float = 0.0, use_kernel: bool = True,
                 streaming: bool = True):
        self.cfg = cfg
        self.geom = make_stream_geometry(cfg, hop)
        self.streaming = streaming
        kw = dict(chip_offsets=chip_offsets, sa_noise_std=sa_noise_std,
                  use_kernel=use_kernel)
        self._kw = kw
        self._hw = hw
        init = stream_init if streaming else window_init
        step = stream_step if streaming else window_step
        geom = self.geom
        self._init = jax.jit(lambda w, k: init(hw, w, k, cfg, geom, **kw))
        self._step = jax.jit(lambda s, a: step(hw, s, a, cfg, geom, **kw))
        # customized (per-stream bias delta + head) and multi-hop variants,
        # jitted on first use so the plain serving path never pays for them
        self._init_cust = None
        self._step_cust = None
        self._multi: Dict[int, object] = {}
        self._multi_cust: Dict[int, object] = {}

    def zeros_state(self, n: int):
        if self.streaming:
            return zeros_state(self.cfg, self.geom, n)
        return zeros_window_state(self.cfg, n)

    def init(self, window: jax.Array, keys: jax.Array):
        """First full window (B, window) -> (logits, state)."""
        return self._init(window, keys)

    def step(self, state, audio: jax.Array):
        """One hop (B, hop) -> (logits, state)."""
        return self._step(state, audio)

    def init_custom(self, window: jax.Array, keys: jax.Array,
                    bias_delta, head_w, head_b):
        """``init`` with the per-stream customization riders."""
        if self._init_cust is None:
            hw, cfg, geom, kw = self._hw, self.cfg, self.geom, self._kw
            fn = stream_init if self.streaming else window_init
            self._init_cust = jax.jit(
                lambda w, k, d, hwt, hb: fn(hw, w, k, cfg, geom, **kw,
                                            bias_delta=d, head_w=hwt,
                                            head_b=hb))
        return self._init_cust(window, keys, bias_delta, head_w, head_b)

    def step_custom(self, state, audio: jax.Array, bias_delta,
                    head_w, head_b):
        """``step`` with the per-stream customization riders — still one
        fused-kernel launch per IMC layer for the whole batch."""
        if self._step_cust is None:
            hw, cfg, geom, kw = self._hw, self.cfg, self.geom, self._kw
            fn = stream_step if self.streaming else window_step
            self._step_cust = jax.jit(
                lambda s, a, d, hwt, hb: fn(hw, s, a, cfg, geom, **kw,
                                            bias_delta=d, head_w=hwt,
                                            head_b=hb))
        return self._step_cust(state, audio, bias_delta, head_w, head_b)

    def multi_step(self, state, audio: jax.Array, n_hops: int,
                   bias_delta=None, head_w=None, head_b=None):
        """``n_hops`` hops in one call — and, on the streaming path, one
        fused-kernel launch per IMC layer (the wake-replay batching).
        Returns (logits (B, n_hops, C), state)."""
        hw, cfg, geom, kw = self._hw, self.cfg, self.geom, self._kw
        fn = stream_multi_step if self.streaming else window_multi_step
        if bias_delta is None and head_w is None:
            if n_hops not in self._multi:
                self._multi[n_hops] = jax.jit(
                    lambda s, a: fn(hw, s, a, cfg, geom, n_hops, **kw))
            return self._multi[n_hops](state, audio)
        if n_hops not in self._multi_cust:
            self._multi_cust[n_hops] = jax.jit(
                lambda s, a, d, hwt, hb: fn(hw, s, a, cfg, geom, n_hops,
                                            **kw, bias_delta=d, head_w=hwt,
                                            head_b=hb))
        return self._multi_cust[n_hops](state, audio, bias_delta, head_w,
                                        head_b)


# ---------------------------------------------------------------------------
# Work accounting (feeds core.energy's streaming report)
# ---------------------------------------------------------------------------


def streaming_layer_stats(cfg: kws.KWSConfig, geom: StreamGeometry):
    """Per-decision op counts of the *streaming* path, same schema as
    ``kws.layer_stats``: each conv layer only touches its tail columns, so
    MACs / SRAM traffic / controller cycles scale by the tail fraction.
    The GAP+FC row is unchanged (it runs in full every decision)."""
    base = kws.layer_stats(cfg)
    out = []
    for i, s in enumerate(base):
        if i >= cfg.num_conv_layers:        # gap+fc row
            out.append(dict(s))
            continue
        lg = geom.layers[i]
        frac = (lg.t_conv - lg.conv_lo) / lg.t_conv
        cin = 1 if i == 0 else cfg.channels[i - 1]
        out.append({
            **s,
            "macs": int(round(s["macs"] * frac)),
            "in_bits": int(lg.tail_in * cin * (8 if i == 0 else 1)),
            "out_bits": int(lg.d_out * cfg.channels[i]),
            "cycles": int(round(s["cycles"] * frac)),
        })
    return out
