"""On-device customization as a serving workload (paper §III, §V-C).

The paper's headline capability — on-chip learning that recovers a
personal speaker's accuracy (bias compensation + last-layer fine-tuning
with error scaling and small-gradient accumulation) — deployed the way an
always-on product ships it: as **enrollment sessions** against the live
StreamServer (Cioflan et al., arXiv 2403.07802, frame exactly this
on-device-learning-at-the-edge loop).

A ``CustomizationSession`` attaches to a live stream and walks the
paper's pipeline as scheduler-ticked background jobs:

1. **enrollment** — labeled user utterances are submitted into the
   attached stream and ride its normal batched hops (the per-stream
   carries + GAP ring); at each utterance's completion hop the session
   captures the GAP feature vector straight from the stream state — the
   §V-C SRAM feature buffer, recorded with ZERO extra forward passes;
2. **calibration / bias compensation** (§IV-B) — the chip's test mode
   over the recorded utterances, one bounded chunk of layers per tick
   (``repro.training.kws.calibration_ideal_counts`` /
   ``compensate_layer_bias`` — the same pieces the offline driver runs);
3. **feature re-extraction** — compensation changed the IMC biases, so
   the feature buffer is recomputed by replaying the recorded windows as
   *internal replay streams* through the scheduler: the replays ride the
   SAME one-fused-launch-per-layer batched hop as the inference streams
   (their compensated biases ride the per-slot bias-delta operand), so a
   mixed inference+learning tick still issues exactly one fused-kernel
   launch per IMC layer — test-enforced;
4. **fine-tuning** (§III) — the quantized last-layer loop (error scaling
   + SGA) runs a bounded number of epochs per tick; every active
   session's optimizer transition is stacked into ONE batched
   ``sga_update`` kernel launch (``repro.kernels.sga_update.ops
   .sga_update_batch`` — per-row learning rates, since sessions sit at
   different points of the LR schedule);
5. **hot swap** — the finished profile (compensated biases + fine-tuned
   head) is written into the attached stream's per-slot rider rows
   (bias delta, FC head, silence fill); other slots' rows and states are
   untouched.  ``session.refolded()`` returns the equivalent
   ``PackedHWParams`` for persistence, and
   ``StreamServer.install_custom`` re-installs a saved profile.

**Equivalence contract** (test-enforced, chip offsets AND SA-noise
configurations included): the session's compensated biases and
fine-tuned (w, b) are bit-identical to the offline loop on the same
recorded utterances (``calibrate_and_compensate`` -> ``hw_features`` ->
``quantized_head_finetune``).  Under an SA-noise field, every feature
capture follows its stream's per-absolute-column field
(repro.core.sa_noise); the session records each capture's (stream key,
window index) origin, and ``session.feature_noise_field()`` hands the
offline oracle the exact same field to evaluate
(``hw_features(sa_noise_field=...)``) instead of drawing fresh noise.
Everything in the streaming path that the session touches is exact on
the fixed-point grids: the bias delta is an integer rider on the
pre-sign operand, and the GAP/FC math has no float rounding (±1 ring
sums and Q1.3.4 x Q1.7 dot products are exactly representable), so the
per-slot head matvec equals the shared matmul bit-for-bit.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy
from repro.core.sa_noise import SANoiseField
from repro.core.onchip_training import (HeadState, OnChipTrainConfig,
                                        apply_update, epoch_grads,
                                        finetune_init, head_accuracy,
                                        sga_threshold)
from repro.core.quantize import ACT_Q
from repro.models import kws
from repro.serving import stream as sv
from repro.training import kws as tr


@dataclasses.dataclass(frozen=True)
class CustomizeConfig:
    """Knobs of one enrollment session.

    ``train`` is the paper's on-chip loop config (epochs, LR schedule,
    error scaling, SGA, RGP).  The default uses the CHIP's error-scaling
    mode — the fixed shift-add-friendly 1.375 factor (§V-C) — rather than
    the dynamic Eq-2 exponent: the dynamic ceil always lands the largest
    error at/above the Q1.7 rail, which can stall learning on weakly
    separated features, while the silicon's fixed factor recovers cleanly
    (see benchmarks/run.py --customize).  ``epochs_per_tick`` /
    ``layers_per_tick`` bound the work one scheduler tick may spend on
    this session;
    ``compensate`` runs the §IV-B test-mode bias compensation before
    fine-tuning (skips straight to fine-tuning on the enrollment features
    when off — no re-extraction needed, the biases did not change);
    ``use_kernel`` routes the optimizer transition through the fused
    ``sga_update`` Pallas kernel (bit-identical to the jnp path);
    ``auto_swap`` hot-swaps the result into the attached stream the tick
    fine-tuning finishes."""

    train: OnChipTrainConfig = OnChipTrainConfig(epochs=200,
                                                 fixed_error_scale=1.375)
    epochs_per_tick: int = 10
    layers_per_tick: int = 2
    compensate: bool = True
    calib_sa_noise_std: float = 1.0
    calib_seed: int = 0
    use_kernel: bool = True
    auto_swap: bool = True

    def __post_init__(self):
        if self.epochs_per_tick < 1 or self.layers_per_tick < 1:
            raise ValueError("epochs_per_tick and layers_per_tick must "
                             "be >= 1")


@dataclasses.dataclass
class CustomizationResult:
    """A finished user profile: full compensated integer biases for the
    IMC layers, the fine-tuned Q1.7 head, and the run's accounting."""

    bias: Dict[str, np.ndarray]
    fc_w: np.ndarray
    fc_b: np.ndarray
    epochs: int
    n_utterances: int
    history: List[dict]
    energy: dict


def result_riders(result: CustomizationResult, hw, cfg: kws.KWSConfig,
                  chip_offsets=None, with_fills: bool = False) -> dict:
    """Translate a result into the scheduler's per-slot riders: integer
    bias deltas vs the base chip, the replacement head, and (for gated
    servers) the compensated net's silence-fill columns."""
    hwp, _ = kws.as_hw_params(hw)
    delta = {name: np.asarray(result.bias[name])
             - np.asarray(hwp.bias[name])
             for name in cfg.imc_layer_names()}
    out = {"delta": delta,
           "head": (np.asarray(result.fc_w), np.asarray(result.fc_b)),
           "fills": None}
    if with_fills:
        hw_c = refold(result, hw, cfg, pack=False)
        sils = kws.silence_columns(hw_c, cfg, chip_offsets=chip_offsets)
        out["fills"] = tuple(np.asarray(f)
                             for f in sv.silence_fills(cfg, sils))
    return out


def refold(result: CustomizationResult, hw, cfg: kws.KWSConfig,
           pack: bool = True):
    """The customized model as ordinary (Packed)HWParams: base binary
    weights, compensated biases, fine-tuned head — what a dedicated
    engine would serve, and what the hot-swapped slot must match
    bit-for-bit (SA-noise-free)."""
    hwp, _ = kws.as_hw_params(hw)
    bias = dict(hwp.bias)
    for name in cfg.imc_layer_names():
        bias[name] = jnp.asarray(result.bias[name])
    out = hwp._replace(bias=bias, fc_w=jnp.asarray(result.fc_w),
                       fc_b=jnp.asarray(result.fc_b))
    return kws.pack_hw_params(out, cfg) if pack else out


class CustomizationSession:
    """One user's enrollment/fine-tuning session (created by
    ``StreamServer.customize``).  Drive it by calling ``enroll`` for each
    labeled utterance, then ``finish_enrollment()``; the server's
    ``step()`` loop does the rest in the background.  ``phase`` walks
    enrolling -> calibrating -> extracting -> training -> ready ->
    swapped (compensation off skips calibrating/extracting)."""

    def __init__(self, manager: "CustomizationManager", sid: int,
                 stream_id: str, ccfg: CustomizeConfig):
        self._mgr = manager
        self.sid = sid
        self.stream_id = stream_id
        self.ccfg = ccfg
        self.phase = "enrolling"
        self.windows: List[np.ndarray] = []      # recorded utterance windows
        self.labels: List[int] = []
        self.features: List[Optional[np.ndarray]] = []
        # per-feature noise-field origin: {"key": (2,) uint32, "hop": int}
        # — which stream's field, at which window index, produced the
        # capture (the offline oracle's coordinates under SA noise)
        self.feature_origins: List[Optional[dict]] = []
        self.history: List[dict] = []
        self.result: Optional[CustomizationResult] = None
        self._enroll_done = False
        self._captures: List[dict] = []
        self._total = 0                          # stream sample position
        self._ideal = None                       # calibration state
        self._calib_keys = None
        self._new_bias = None
        self._calib_idx = 0
        self._replays_spawned = False
        self._head: Optional[HeadState] = None   # fine-tune state
        self._featsq = None
        self._onehot = None
        self._epoch = 0
        self._grads_fn = None

    # -- enrollment ---------------------------------------------------------

    def enroll(self, label: int, utterance: np.ndarray) -> None:
        """Submit one labeled utterance (exactly one decision window of
        audio) into the attached stream.  The submission is pre-padded
        with silence so the utterance's last sample lands on a hop
        boundary: the stream window at the completion hop IS the
        utterance, and the capture rides the normal batched hops."""
        if self.phase != "enrolling":
            raise ValueError(f"session is {self.phase}, not enrolling")
        srv = self._mgr.srv
        window = srv.geom.window
        utterance = np.asarray(utterance, np.float32)
        if utterance.shape != (window,):
            raise ValueError(f"utterance must be one window "
                             f"({window} samples), got {utterance.shape}")
        hop = srv.geom.hop
        pad = (-self._total) % hop
        wav = (np.concatenate([np.zeros((pad,), np.float32), utterance])
               if pad else utterance)
        srv.submit(self.stream_id, wav)
        self._total += pad + window
        self.windows.append(utterance.copy())
        self.labels.append(int(label))
        self.features.append(None)
        self.feature_origins.append(None)
        self._captures.append({"stream": self.stream_id,
                               "target": self._total,
                               "index": len(self.windows) - 1,
                               "kind": "enroll"})

    def finish_enrollment(self) -> None:
        if not self.windows:
            raise ValueError("enroll at least one utterance first")
        self._enroll_done = True

    # -- results ------------------------------------------------------------

    @property
    def done(self) -> bool:
        return self.phase in ("ready", "swapped")

    def refolded(self, pack: bool = True):
        if self.result is None:
            raise ValueError("session not finished")
        return refold(self.result, self._mgr.srv._hw, self._mgr.srv.cfg,
                      pack=pack)

    def feature_noise_field(self) -> Optional[SANoiseField]:
        """The per-absolute-column SA-noise field the session's feature
        buffer was captured under: row n is feature n's (stream key,
        window index).  Feed it to ``repro.training.kws.hw_features(
        sa_noise_field=...)`` and the offline forward reproduces the
        captured features bit-exactly — the noise-aware offline oracle of
        the session-vs-offline equivalence contract.  ``None`` when the
        server runs noise-free (the oracle then draws nothing)."""
        std = self._mgr.srv._engine_kw["sa_noise_std"]
        if not std:
            return None
        if any(o is None for o in self.feature_origins):
            raise ValueError("feature buffer not fully captured yet "
                             f"(phase {self.phase})")
        return SANoiseField(
            keys=jnp.asarray(np.stack([o["key"]
                                       for o in self.feature_origins])),
            hops=jnp.asarray([o["hop"] for o in self.feature_origins],
                             jnp.int32),
            std=float(std), hop=int(self._mgr.srv.geom.hop))


class CustomizationManager:
    """Per-server registry of sessions + the background-job engine the
    scheduler ticks (captures, calibration chunks, replay spawns, batched
    fine-tune epochs, hot swaps)."""

    def __init__(self, srv):
        if not srv.streaming:
            raise ValueError("customization requires streaming=True (the "
                             "feature captures read the GAP ring)")
        self.srv = srv
        self.sessions: List[CustomizationSession] = []
        self._next_sid = 0

    # -- session lifecycle --------------------------------------------------

    def start(self, stream_id: str,
              ccfg: Optional[CustomizeConfig]) -> CustomizationSession:
        ccfg = ccfg or CustomizeConfig()
        for s in self.sessions:
            if s.stream_id == stream_id and not s.done:
                raise ValueError(f"stream {stream_id} already has an "
                                 f"active session ({s.phase})")
        srv = self.srv
        rec = srv._streams.get(stream_id)
        if rec is None:
            if srv.submit(stream_id, np.zeros((0,), np.float32)) \
                    == "rejected":
                raise RuntimeError(
                    f"cannot open a session for {stream_id}: the "
                    f"admission queue is full (backpressure) — retry "
                    f"when a slot frees")
            rec = srv._streams[stream_id]
        rec.force_compute = True           # enrollment hops never gate
        sess = CustomizationSession(self, self._next_sid, stream_id, ccfg)
        sess._total = rec.consumed + len(rec.buf) + sum(
            map(len, rec.pending))
        self._next_sid += 1
        self.sessions.append(sess)
        srv._metrics.inc("customize.sessions")
        if srv._rec is not None:
            srv._rec.record(srv._steps, "session", stream=stream_id,
                            sid=sess.sid, phase="enrolling")
        return sess

    # -- per-tick hooks (called by StreamServer.step) -----------------------

    def on_step(self, srv) -> None:
        """Feature captures: runs right after the batched hop, before
        slots retire, so the GAP ring still holds the completion window's
        activations."""
        for sess in self.sessions:
            for cap in list(sess._captures):
                rec = srv._streams.get(cap["stream"])
                if (rec is None or rec.slot is None or not rec.initialized
                        or rec.consumed < cap["target"]):
                    continue
                if rec.consumed > cap["target"]:
                    raise RuntimeError(
                        f"capture overshoot on {cap['stream']}: consumed "
                        f"{rec.consumed} > target {cap['target']} — was "
                        f"the stream shed or retargeted mid-enrollment?")
                ring = srv._state.ring[rec.slot]
                feats = np.asarray(ACT_Q.quantize(jnp.mean(ring, axis=0)),
                                   np.float32)
                sess.features[cap["index"]] = feats
                # the capture's noise-field coordinates: this stream's key
                # at the completion window's index — what the offline
                # oracle must evaluate to reproduce the feature under SA
                # noise (window t occupies [t*hop, t*hop + window))
                sess.feature_origins[cap["index"]] = {
                    "key": np.asarray(jax.random.fold_in(srv._base_key,
                                                         rec.uid)),
                    "hop": (cap["target"] - srv.geom.window)
                    // srv.geom.hop,
                }
                if cap["kind"] == "enroll":
                    sess.windows[cap["index"]] = rec.recent.copy()
                else:                      # replay stream: single-use
                    srv._drop_internal(cap["stream"])
                sess._captures.remove(cap)

    def tick(self, srv) -> None:
        """Advance every session by a bounded amount of background work."""
        for sess in self.sessions:
            if sess.phase == "enrolling":
                if sess._enroll_done and not sess._captures:
                    if sess.ccfg.compensate:
                        sess.phase = "calibrating"
                    else:
                        self._start_training(sess, base_bias=True)
            elif sess.phase == "calibrating":
                self._calibrate_chunk(sess)
            elif sess.phase == "extracting":
                self._extract(sess)
        self._train_round()
        for sess in self.sessions:
            if sess.phase == "ready" and sess.ccfg.auto_swap:
                self.swap(sess)
        srv._metrics.set_gauge(
            "customize.active_sessions",
            sum(1 for s in self.sessions if not s.done))

    # -- calibration / bias compensation ------------------------------------

    def _calibrate_chunk(self, sess: CustomizationSession) -> None:
        srv, cfg = self.srv, self.srv.cfg
        hwp, _ = kws.as_hw_params(srv._hw)
        if sess._ideal is None:
            # tick 1: the test-mode reference forward over the recorded
            # utterances (collect_counts — unfused by construction, like
            # the silicon's digitize-the-counts mode: zero IMC launches)
            sess._ideal = tr.calibration_ideal_counts(
                srv._hw, np.stack(sess.windows), cfg)
            sess._calib_keys = tr.calibration_layer_keys(
                cfg, sess.ccfg.calib_seed)
            sess._new_bias = {k: np.asarray(v)
                              for k, v in hwp.bias.items()}
            return
        offs = srv._engine_kw["chip_offsets"] or {}
        names = cfg.imc_layer_names()
        for name in names[sess._calib_idx:
                          sess._calib_idx + sess.ccfg.layers_per_tick]:
            off = offs.get(name)
            if off is None:
                off = jnp.zeros((sess._ideal[name].shape[-1],))
            sess._new_bias[name] = np.asarray(tr.compensate_layer_bias(
                jnp.asarray(sess._new_bias[name]), sess._ideal[name], off,
                sess._calib_keys[name], sess.ccfg.calib_sa_noise_std))
        sess._calib_idx += sess.ccfg.layers_per_tick
        if sess._calib_idx >= len(names):
            sess._ideal = None             # free the counts log
            sess.features = [None] * len(sess.windows)
            sess.feature_origins = [None] * len(sess.windows)
            sess.phase = "extracting"

    # -- feature re-extraction under the compensated biases ------------------

    def _extract(self, sess: CustomizationSession) -> None:
        srv = self.srv
        if not sess._replays_spawned:
            hwp, _ = kws.as_hw_params(srv._hw)
            delta = {name: sess._new_bias[name] - np.asarray(hwp.bias[name])
                     for name in srv.cfg.imc_layer_names()}
            head = (np.asarray(hwp.fc_w), np.asarray(hwp.fc_b))
            hop, window = srv.geom.hop, srv.geom.window
            for j, win in enumerate(sess.windows):
                sid = f"~cust{sess.sid}u{j}"
                wav = np.concatenate([np.zeros((hop,), np.float32), win])
                srv._submit_internal(sid, wav,
                                     custom={"delta": delta, "head": head,
                                             "fills": None})
                # init consumes the window [silence-hop, win[:-hop]]; one
                # batched hop later the state window is exactly ``win``
                sess._captures.append({"stream": sid,
                                       "target": window + hop,
                                       "index": j, "kind": "replay"})
            sess._replays_spawned = True
            return
        if not sess._captures:
            self._start_training(sess, base_bias=False)

    # -- fine-tuning ----------------------------------------------------------

    def _start_training(self, sess: CustomizationSession,
                        base_bias: bool) -> None:
        hwp, _ = kws.as_hw_params(self.srv._hw)
        if base_bias:
            sess._new_bias = {k: np.asarray(v) for k, v in hwp.bias.items()}
        feats = np.stack(sess.features)
        labels = np.asarray(sess.labels, np.int32)
        state, featsq, onehot = finetune_init(
            jnp.asarray(feats), jnp.asarray(labels), hwp.fc_w, hwp.fc_b,
            sess.ccfg.train, num_classes=self.srv.cfg.num_classes)
        sess._head, sess._featsq, sess._onehot = state, featsq, onehot
        sess._epoch = 0
        sess.phase = "training"

    def _train_round(self) -> None:
        """Run each training session's bounded epoch budget for this tick.
        Within every round, all kernel-eligible sessions' optimizer
        transitions are stacked into ONE batched ``sga_update`` launch
        (per-row lr/G_th — each session sits at its own schedule point)."""
        import jax

        active = [s for s in self.sessions if s.phase == "training"]
        if not active:
            return
        budget = {s.sid: min(s.ccfg.epochs_per_tick,
                             s.ccfg.train.epochs - s._epoch)
                  for s in active}
        for r in range(max(budget.values())):
            batch = [s for s in active if r < budget[s.sid]]
            if not batch:
                break
            grads = []
            for s in batch:
                if s._grads_fn is None:
                    tcfg, fq, oh = s.ccfg.train, s._featsq, s._onehot
                    s._grads_fn = jax.jit(
                        lambda st, e, _t=tcfg, _f=fq, _o=oh:
                        epoch_grads(st, e, _f, _o, _t))
                grads.append(s._grads_fn(s._head,
                                         jnp.asarray(s._epoch, jnp.int32)))
            # one fused launch per (weight, accum) format group — formats
            # set the kernel's quantization grids, so sessions with
            # different OnChipTrainConfig formats cannot share rows
            fmt_groups: Dict[tuple, List[int]] = {}
            for i, s in enumerate(batch):
                if (s.ccfg.use_kernel and s.ccfg.train.quantized
                        and s.ccfg.train.sga):
                    fmt = (s.ccfg.train.weight_fmt, s.ccfg.train.accum_fmt)
                    fmt_groups.setdefault(fmt, []).append(i)
            kernel_rows = {i for idx in fmt_groups.values() for i in idx}
            for idx in fmt_groups.values():
                self._kernel_update([batch[i] for i in idx],
                                    [grads[i] for i in idx])
            for i, s in enumerate(batch):
                if i in kernel_rows:
                    continue
                gw, gb, lr, key = grads[i]
                s._head = apply_update(s._head, gw, gb, lr, key,
                                       s.ccfg.train)
            for s in batch:
                s._epoch += 1
            self.srv._metrics.inc("customize.epochs", len(batch))
        for s in active:
            if budget[s.sid] > 0:
                acc = float(head_accuracy(s._featsq,
                                          jnp.asarray(s.labels),
                                          s._head.w, s._head.b,
                                          s.ccfg.train))
                s.history.append({"epoch": s._epoch,
                                  "train_accuracy": acc})
            if s._epoch >= s.ccfg.train.epochs:
                self._finish(s)

    def _kernel_update(self, sessions, grads) -> None:
        """One fused ``sga_update`` launch for every session row: flatten
        each session's [fc_w, fc_b] (and its SGA banks) into one row,
        apply Algorithm 1 + the SGD step + Q1.7 quantization elementwise,
        unpack.  Bit-identical to the jnp ``apply_update`` path on the
        fixed-point grids."""
        from repro.kernels.sga_update import ops as sga_ops

        tcfg0 = sessions[0].ccfg.train
        rows_w, rows_g, rows_a, lrs, gths = [], [], [], [], []
        shapes = []
        for s, (gw, gb, lr, key) in zip(sessions, grads):
            st = s._head
            shapes.append((st.w.shape, st.b.shape))
            rows_w.append(jnp.concatenate([st.w.ravel(), st.b.ravel()]))
            rows_g.append(jnp.concatenate([gw.ravel(), gb.ravel()]))
            rows_a.append(jnp.concatenate([st.accum_w.ravel(),
                                            st.accum_b.ravel()]))
            lrs.append(lr)
            gths.append(sga_threshold(lr, s.ccfg.train.weight_fmt))
        fmt_w, fmt_a = tcfg0.weight_fmt, tcfg0.accum_fmt
        nw, na = sga_ops.sga_update_batch(
            jnp.stack(rows_w), jnp.stack(rows_g), jnp.stack(rows_a),
            jnp.stack(lrs), jnp.stack(gths),
            w_scale=fmt_w.scale, w_max=fmt_w.max_value,
            a_scale=fmt_a.scale)
        for i, (s, (gw, gb, lr, key)) in enumerate(zip(sessions, grads)):
            (ws, bs) = shapes[i]
            nw_i, na_i = nw[i], na[i]
            n_w = int(np.prod(ws))
            s._head = HeadState(
                w=nw_i[:n_w].reshape(ws),
                b=nw_i[n_w:n_w + int(np.prod(bs))].reshape(bs),
                accum_w=na_i[:n_w].reshape(ws),
                accum_b=na_i[n_w:n_w + int(np.prod(bs))].reshape(bs),
                key=key)

    def _finish(self, sess: CustomizationSession) -> None:
        d = int(sess._featsq.shape[1])
        c = self.srv.cfg.num_classes
        e = energy.customization_energy_summary(
            n_utts=len(sess.windows), feat_dim=d, num_classes=c,
            epochs=sess.ccfg.train.epochs)
        sess.result = CustomizationResult(
            bias={k: np.asarray(v) for k, v in sess._new_bias.items()},
            fc_w=np.asarray(sess._head.w), fc_b=np.asarray(sess._head.b),
            epochs=sess._epoch, n_utterances=len(sess.windows),
            history=list(sess.history), energy=e)
        sess.phase = "ready"
        srv = self.srv
        if srv._rec is not None:
            srv._rec.record(srv._steps, "session", stream=sess.stream_id,
                            sid=sess.sid, phase="ready",
                            epochs=sess._epoch)

    # -- hot swap -------------------------------------------------------------

    def swap(self, sess: CustomizationSession) -> None:
        """Write the finished profile into the attached stream's slot
        riders (bias delta + head + silence fill).  Only that slot's rows
        change; every other slot — state, decisions, riders — is
        untouched."""
        if sess.result is None:
            raise ValueError("session not finished")
        srv = self.srv
        rec = srv._streams.get(sess.stream_id)
        riders = result_riders(sess.result, srv._hw, srv.cfg,
                               chip_offsets=srv._engine_kw["chip_offsets"],
                               with_fills=srv._fills is not None)
        if rec is not None:
            rec.custom = riders
            rec.force_compute = False      # normal VAD gating resumes
            if rec.slot is not None:
                srv._write_slot_custom(rec.slot, riders)
        sess.phase = "swapped"
        srv._metrics.inc("customize.swaps")
        if srv._rec is not None:
            srv._rec.record(srv._steps, "session", stream=sess.stream_id,
                            sid=sess.sid, phase="swapped")

    # -- accounting -----------------------------------------------------------

    def stats(self) -> dict:
        # aggregate counts are views over the server's metrics registry
        reg = self.srv._metrics
        return {
            "sessions": [
                {"stream": s.stream_id, "phase": s.phase,
                 "utterances": len(s.windows), "epoch": s._epoch,
                 "train_accuracy": (s.history[-1]["train_accuracy"]
                                    if s.history else None)}
                for s in self.sessions
            ],
            "sessions_started": reg.value("customize.sessions"),
            "epochs_total": reg.value("customize.epochs"),
            "swaps": reg.value("customize.swaps"),
        }
