"""Multi-stream batched scheduler for always-on KWS serving.

Slot-based continuous-batching-light (the KWS analogue of
``repro.launch.serve``'s decoder slots): a fixed pool of stream slots,
each holding one live audio stream's incremental ``StreamState``.

**One-launch-per-layer invariant.**  Every ``step()`` batches ALL
hop-ready slots' fresh frames into one ``stream_step`` call — i.e. exactly
one fused ``pallas_call`` per IMC layer for the whole fleet of streams,
the M-tiling of the fused kernel amortizing the weight-stationary packs
across streams.  Slots that are not ready this step ride along masked
(their state is restored verbatim; their logits are ignored), so the
launch count is independent of readiness.  This includes learning work:
a customization session's replay hops (repro.serving.customize) are just
rows of the same batch, and hot-swapped slots' compensated biases /
fine-tuned heads ride per-slot operands of the same launch.  A wake
replay (below) adds one extra multi-hop launch per layer for the waking
slot — the whole deferred run in one call, not one per deferred hop.

**Voice-activity gating** (``vad=VADConfig(...)``): each hop of each
stream is first classified speech/silence by the cheap digital energy
detector (repro.serving.vad).  Silent hops launch NO IMC kernels:

* the last ``wake_margin`` silent hops are *deferred* — buffered host-side
  with the jax state untouched — so a speech onset replays them through
  the real IMC path (ONE multi-hop launch per layer for the whole
  deferred run, bit-identical to replaying hop by hop) and a keyword
  straddling the silence->speech edge keeps its prefix (if the silent run
  never exceeds the margin, the gated decision sequence is bit-identical
  to ungated streaming);
* silent hops older than the margin are *gated*: the state advances by a
  masked no-op column fill (``stream.gated_step`` — each layer's constant
  silence response shifts into the carries and the GAP ring), charged
  leakage-only in the energy model
  (``repro.core.energy.gated_energy_summary``);
* gated/deferred hops emit no decision events — the VAD's "silence" IS
  the decision — and the decision head stays frozen (mask-aware).

With ``VADConfig(force="speech")`` every hop computes and the server is
bit-identical to an ungated one (the CI equivalence gate).

**Dynamic hop** (``dynamic_hop=DynamicHopConfig(...)``): when every
active slot's smoothed posterior stays below ``calm_score`` for
``widen_after`` consecutive ticks, the effective hop doubles (up to
``max_multiplier`` x the base hop — any multiple of
``hop_alignment(cfg)`` keeps column reuse exact); activity (a hot
posterior or a VAD wake) snaps it back to the base hop.  A hop change
rebuilds every live slot's ``StreamState`` from its retained last window
of consumed audio (the streaming geometry — carry sizes, fresh-column
counts — is hop-dependent, so states cannot be carried across).

**Admission control / backpressure** (``admission=AdmissionConfig(...)``):
``submit`` returns ``"rejected"`` (and buffers nothing) once the wait
queue holds ``max_queue`` streams; a stream whose buffered backlog
exceeds the ``max_lag_s`` latency SLO is shed — its oldest audio is
dropped to the low-water mark and it re-initializes from the freshest
window; the slot pool autoscales between ``min_slots`` and ``max_slots``
(grow under sustained queue pressure, shrink after sustained idle slots).

Host side, each stream owns a ring buffer of pending samples
(``submit()`` appends arbitrary-sized chunks); a stream is admitted to a
free slot immediately, waits in the admission queue otherwise, and is
evicted when its producer calls ``finish()`` and its buffer drains (or
explicitly via ``evict()``).  Admission runs the stream's first full
window (``stream_init``): with ``batch_init`` (default) every slot whose
first window is ready this tick — fresh admissions and a customization
session's whole wave of feature-replay streams alike — initializes in
ONE masked batched ``stream_init`` call (one fused launch per IMC layer
for the wave, bit-identical to one-at-a-time; ``batch_init=False`` keeps
the sequential B=1 path).

**Customization** (``customize(stream_id)`` / ``install_custom``): an
enrollment/fine-tuning session (repro.serving.customize) rides the same
machinery — enrollment hops on the live stream, calibration + SGA
fine-tune as bounded background jobs per tick, feature-replay streams as
internal slots of the same batch, and the finished profile hot-swapped
into the stream's per-slot rider rows (bias delta + FC head + silence
fill) without touching other slots.

**Fault injection + health** (``faults=FaultConfig(...)`` /
``health=HealthConfig(...)``): a seeded silicon fault model
(repro.core.faults) rides the batched launches as a chip-global pre-sign
count delta added to every slot's bias-delta rider row — fault injection
launches ZERO extra kernels and the one-launch-per-layer invariant holds
under fault.  The health monitor (repro.serving.health) submits periodic
canary windows as internal streams of the same batch, localizes faulty
layers/columns from the captured carries/ring, drives the healthy ->
degraded -> quarantined -> recovering state machine, re-runs the paper's
test-mode bias compensation as a tick-resumable background job and
hot-swaps the heal through the same rider row (``_set_heal_delta``);
decision events carry ``degraded`` flags while the chip is unhealthy.

**Profiles at admission** (``profiles=ProfileStore(...)``):
``submit(stream_id, chunk, user_id=...)`` auto-installs the user's stored
profile onto the assigned slot; a per-tick staleness sweep re-installs
profiles whose store mtime moved and resets streams whose profile was
deleted.

**Crash safety**: ``snapshot()`` serializes the complete serving state —
slot carries and GAP rings, decision/VAD state, noise-field keys, fault
and health state, mid-flight customization sessions — to an atomically
written .npz (tmp+fsync+``os.replace``, the ProfileStore idiom);
``restore()`` on a freshly constructed identically-configured server
resumes bit-identically to an uninterrupted run (test-enforced).

Per-hop logits flow into the shared decision head
(repro.serving.decision): smoothing + hysteresis + refractory, batched and
mask-aware.  ``stats()`` reports per-stream and aggregate decisions/sec,
hop latency, duty cycle, shed/reject counts, the gated analytical
uJ/decision and per-session customization progress.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import json
import os
import pickle
import tempfile
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy
from repro.models import kws
from repro.obs import (FlightRecorder, LaunchAuditor, MetricsRegistry,
                       ObsConfig, TraceBuilder, counter_property)
from repro.serving import decision as dec
from repro.serving import stream as sv
from repro.serving import vad as vd


@dataclasses.dataclass(frozen=True)
class DynamicHopConfig:
    """Widen the hop when nothing interesting is happening.

    A tick is *calm* when no computed hop's smoothed posterior reaches
    ``calm_score`` (silence-only ticks are calm by construction).  After
    ``widen_after`` consecutive calm ticks the effective hop doubles,
    capped at ``max_multiplier`` x the base hop and at what the stream
    geometry admits; any hot posterior or VAD wake narrows back to the
    base hop immediately.

    ``calm_silence`` (duty-aware widening): a separate, typically smaller
    calm-tick threshold used when the whole tick was VAD-silent (every
    ready hop gated) — silence earns the wider hop faster than merely
    unconvincing speech.  None (the default) keeps one threshold for
    both, bit-identical to the pre-knob behavior; streams submitted with
    ``force="speech"``/``force_compute`` never count as silent, so forced
    paths are unaffected."""

    max_multiplier: int = 4
    widen_after: int = 6
    calm_score: float = 0.35
    calm_silence: Optional[int] = None

    def __post_init__(self):
        if self.max_multiplier < 1:
            raise ValueError("max_multiplier must be >= 1")
        if self.widen_after < 1:
            raise ValueError("widen_after must be >= 1")
        if self.calm_silence is not None and self.calm_silence < 1:
            raise ValueError("calm_silence must be >= 1 (or None)")


jax.tree_util.register_static(DynamicHopConfig)


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Admission control, latency SLO and slot autoscaling.

    ``max_queue``: streams allowed to wait for a slot; further ``submit``s
    of new streams return ``"rejected"``.  ``max_lag_s``: per-stream
    backlog SLO in seconds of audio; a stream over it is shed to the
    low-water mark (half the SLO, never below one window) and re-admitted
    from its freshest window.  ``min_slots``/``max_slots`` bound the slot
    pool (both default to the constructor's ``slots`` — no autoscaling);
    the pool grows after ``scale_up_after`` consecutive ticks with a
    non-empty queue and shrinks after ``scale_down_after`` consecutive
    ticks with idle trailing slots."""

    max_queue: Optional[int] = 8
    max_lag_s: Optional[float] = None
    min_slots: Optional[int] = None
    max_slots: Optional[int] = None
    scale_up_after: int = 2
    scale_down_after: int = 6

    def __post_init__(self):
        if self.max_queue is not None and self.max_queue < 0:
            raise ValueError("max_queue must be >= 0 (or None)")
        if self.scale_up_after < 1 or self.scale_down_after < 1:
            raise ValueError("scale_up/down_after must be >= 1")


jax.tree_util.register_static(AdmissionConfig)


@dataclasses.dataclass
class _Stream:
    stream_id: str
    uid: int
    buf: np.ndarray                       # pending samples (host ring tail)
    slot: Optional[int] = None
    initialized: bool = False
    finished: bool = False                # producer called finish()
    hops: int = 0                         # decisions made (incl. window 0)
    triggers: List[dict] = dataclasses.field(default_factory=list)
    wall_s: float = 0.0                   # server time attributed to it
    recent: np.ndarray = dataclasses.field(     # last consumed window —
        default_factory=lambda: np.zeros((0,), np.float32))  # hop-retarget
    #                                       re-init source
    pending: List[np.ndarray] = dataclasses.field(   # deferred silent hops
        default_factory=list)                        # (<= wake_margin)
    silent_run: int = 0                   # consecutive silent hops
    gated_hops: int = 0                   # fill-advanced (no-compute) hops
    sheds: int = 0
    shed_samples: int = 0
    # -- customization (repro.serving.customize) --------------------------
    internal: bool = False                # session-owned replay stream: no
    #                                       decision events, no admission
    #                                       bookkeeping, exempt from SLO
    force_compute: bool = False           # bypass VAD gating (enrollment /
    #                                       replay hops must run the IMC
    #                                       path so captures stay exact)
    consumed: int = 0                     # samples advanced through the
    #                                       stream state (capture targets)
    custom: Optional[dict] = None         # per-stream riders: {"delta":
    #                                       {conv_i: (C_i,)}, "head":
    #                                       (fc_w, fc_b), "fills": tuple}
    # -- profile store (repro.checkpoint.profiles) ------------------------
    user_id: Optional[str] = None         # owner in the profile store
    profile_mtime: Optional[int] = None   # installed profile's st_mtime_ns
    #                                       (None: no profile installed)


def _select_state(mask: jax.Array, new, old):
    def sel(n, o):
        m = mask.reshape(mask.shape + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)
    return jax.tree_util.tree_map(sel, new, old)


def _scatter_slot(state, one, slot):
    return jax.tree_util.tree_map(lambda full, o: full.at[slot].set(o[0]),
                                  state, one)


# -- crash-safe snapshot codec ----------------------------------------------
#
# A generic tree -> (JSON spec, array table) encoder: arrays are stored
# losslessly as .npz entries (the fixed-point grids round-trip exactly,
# which is what makes restore bit-identical), registered NamedTuples
# round-trip by class name, and config dataclasses fall back to pickle
# bytes stored as uint8 arrays.  Snapshots are an own-file trust domain
# (like the profile store): only restore snapshots you wrote.

def _snap_class(name: str):
    if name == "HeadState":
        from repro.core.onchip_training import HeadState
        return HeadState
    return {"StreamState": sv.StreamState,
            "WindowState": sv.WindowState,
            "DecisionState": dec.DecisionState,
            "VADState": vd.VADState}[name]


def _snap_encode(obj, arrays: Dict[str, np.ndarray]) -> dict:
    if obj is None:
        return {"t": "none"}
    if isinstance(obj, (bool, int, float, str)):
        return {"t": "v", "v": obj}
    if isinstance(obj, np.integer):
        return {"t": "v", "v": int(obj)}
    if isinstance(obj, np.floating):
        return {"t": "v", "v": float(obj)}
    if isinstance(obj, (np.ndarray, jax.Array)):
        k = f"a{len(arrays)}"
        arrays[k] = np.asarray(obj)
        return {"t": "arr", "k": k}
    if isinstance(obj, tuple) and hasattr(obj, "_fields"):
        return {"t": "nt", "c": type(obj).__name__,
                "items": [_snap_encode(x, arrays) for x in obj]}
    if isinstance(obj, tuple):
        return {"t": "tuple", "items": [_snap_encode(x, arrays)
                                        for x in obj]}
    if isinstance(obj, list):
        return {"t": "list", "items": [_snap_encode(x, arrays)
                                       for x in obj]}
    if isinstance(obj, dict):
        keys = list(obj.keys())
        if not all(isinstance(k, str) for k in keys):
            raise TypeError(f"snapshot dicts need str keys: {keys!r}")
        return {"t": "dict", "keys": keys,
                "items": [_snap_encode(obj[k], arrays) for k in keys]}
    k = f"a{len(arrays)}"
    arrays[k] = np.frombuffer(pickle.dumps(obj), dtype=np.uint8)
    return {"t": "pkl", "k": k}


def _snap_decode(spec: dict, arrays: Dict[str, np.ndarray]):
    t = spec["t"]
    if t == "none":
        return None
    if t == "v":
        return spec["v"]
    if t == "arr":
        return np.asarray(arrays[spec["k"]])
    if t == "pkl":
        return pickle.loads(bytes(np.asarray(arrays[spec["k"]])))
    if t == "nt":
        cls = _snap_class(spec["c"])
        return cls(*[_snap_decode(x, arrays) for x in spec["items"]])
    if t == "tuple":
        return tuple(_snap_decode(x, arrays) for x in spec["items"])
    if t == "list":
        return [_snap_decode(x, arrays) for x in spec["items"]]
    if t == "dict":
        return {k: _snap_decode(x, arrays)
                for k, x in zip(spec["keys"], spec["items"])}
    raise ValueError(f"unknown snapshot node type {t!r}")


class StreamServer:
    """Admit / batch / gate / decide / evict over an autoscaling slot pool."""

    # Every counter lives in the metrics registry (repro.obs.metrics); the
    # historical attribute API (``srv._steps += 1`` and external readers
    # like the concurrent-session bench's per-tick call deltas) is kept by
    # registry-backed properties.  snapshot()/restore() round-trip the
    # whole registry, so there is no hand-maintained key list to drift.
    _steps = counter_property("serving.steps")
    _hop_wall_s = counter_property("serving.hop_wall_s")
    _decisions = counter_property("serving.decisions")
    _speech_hops = counter_property("serving.hops", kind="speech")
    _gated_hops = counter_property("serving.hops", kind="gated")
    _learn_hops = counter_property("serving.hops", kind="learn")
    _rejected = counter_property("serving.rejected_streams")
    _shed_events = counter_property("serving.shed", what="events")
    _shed_samples = counter_property("serving.shed", what="samples")
    _calm_ticks = counter_property("serving.dynhop.calm_ticks")
    _pressure_ticks = counter_property("serving.autoscale.pressure_ticks")
    _idle_ticks = counter_property("serving.autoscale.idle_ticks")
    _hop_retargets = counter_property("serving.hop_retargets")
    _init_calls = counter_property("serving.batched_calls", cause="init")
    _hop_calls = counter_property("serving.batched_calls", cause="hop")
    _replay_calls = counter_property("serving.batched_calls",
                                     cause="replay")
    _gate_calls = counter_property("serving.batched_calls", cause="gate")
    _profile_swaps = counter_property("serving.profile_swaps")
    # compiled fast-path accounting (repro.serving.compiled): dispatch
    # counts only — every Python-tick counter above is replayed exactly,
    # so these are the ONLY registry keys that differ between a compiled
    # and an interpreted run (tests/_equiv.py excludes them)
    _compiled_blocks = counter_property("serving.compiled", what="blocks")
    _compiled_ticks = counter_property("serving.compiled", what="ticks")

    def __init__(self, hw, cfg: kws.KWSConfig, *, hop: int, slots: int = 4,
                 chip_offsets: Optional[Dict[str, jax.Array]] = None,
                 sa_noise_std: float = 0.0, use_kernel: bool = True,
                 streaming: bool = True,
                 decision: dec.DecisionConfig = dec.DecisionConfig(),
                 vad: Optional[vd.VADConfig] = None,
                 dynamic_hop: Optional[DynamicHopConfig] = None,
                 admission: Optional[AdmissionConfig] = None,
                 batch_init: bool = True,
                 faults=None, health=None, profiles=None,
                 silence_fill: str = "constant",
                 obs: Optional[ObsConfig] = None,
                 device_label: Optional[int] = None,
                 compiled=None,
                 seed: int = 0):
        # the registry backs every counter attribute — create it before
        # the first counter write below
        self._metrics = MetricsRegistry()
        self.obs = obs if obs is not None else ObsConfig.from_env()
        # ``device_label`` names this server's device pool in a sharded
        # deployment (repro.serving.shard): the launch auditor and fleet
        # stats rollups attribute per-device launches through it
        self.device_label = device_label
        self._rec = (FlightRecorder(self.obs.recorder)
                     if self.obs.recorder else None)
        self._audit = (LaunchAuditor(cfg.num_conv_layers - 1,
                                     mode=self.obs.audit,
                                     batch_init=batch_init,
                                     device=device_label)
                       if self.obs.audit != "off" else None)
        self.trace = TraceBuilder() if self.obs.trace else None
        self._uj_consts: Dict[int, tuple] = {}   # mult -> (speech, gated)
        self.cfg = cfg
        self.streaming = streaming
        self.base_hop = hop
        self.batch_init = batch_init
        self.dcfg = decision
        self.vcfg = vad
        self.hcfg = dynamic_hop
        self.acfg = admission
        self._hw = hw
        self._engine_kw = dict(chip_offsets=chip_offsets,
                               sa_noise_std=sa_noise_std,
                               use_kernel=use_kernel, streaming=streaming)
        self.min_slots = slots
        self.max_slots = slots
        if admission is not None:
            if admission.min_slots is not None:
                self.min_slots = admission.min_slots
            if admission.max_slots is not None:
                self.max_slots = admission.max_slots
            if not (1 <= self.min_slots <= slots <= self.max_slots):
                raise ValueError(
                    f"need 1 <= min_slots ({self.min_slots}) <= slots "
                    f"({slots}) <= max_slots ({self.max_slots})")
        self.slots = slots

        if silence_fill not in ("constant", "retention"):
            raise ValueError(f"silence_fill={silence_fill!r}: use "
                             f"'constant' or 'retention'")
        self.silence_fill = silence_fill
        self._fills = None
        if vad is not None and streaming:
            if silence_fill == "retention":
                # chip-accurate gated fill: hold one *noisy* SA read per
                # column (what the retained array actually latched) instead
                # of the noiseless silence response
                self._fills = sv.retention_fills(
                    hw, cfg,
                    key=jax.random.fold_in(jax.random.PRNGKey(seed), 0x517),
                    sa_noise_std=sa_noise_std, chip_offsets=chip_offsets)
            else:
                sils = kws.silence_columns(hw, cfg,
                                           chip_offsets=chip_offsets)
                self._fills = sv.silence_fills(cfg, sils)

        # customization (repro.serving.customize): once enabled, batched
        # hops route through the per-slot (bias delta, FC head) variant so
        # hot-swapped and learning slots share the one-launch-per-layer
        # batch with everyone else
        self._cust = None                 # CustomizationManager
        self._cust_on = False
        self._slot_delta = None           # {conv_i: (slots, C_i)}
        self._slot_head_w = None          # (slots, D, num_classes)
        self._slot_head_b = None          # (slots, num_classes)
        self._slot_fills = None           # per-layer (slots, C_i) if VAD

        self._mult = 1
        self._mults: Dict[int, dict] = {}
        bundle = self._bundle(1)
        self._state = bundle["engine"].zeros_state(slots)
        self._dstate = dec.decision_init(slots, cfg.num_classes, decision)
        self._vstate = vd.vad_init(slots) if vad is not None else None
        self._slots: List[Optional[_Stream]] = [None] * slots
        self._queue: collections.deque[_Stream] = collections.deque()
        self._streams: Dict[str, _Stream] = {}
        self._base_key = jax.random.PRNGKey(seed)
        self._uid = 0
        self._steps = 0
        self._hop_wall_s = 0.0
        self._decisions = 0
        self._speech_hops = 0
        self._gated_hops = 0
        self._learn_hops = 0
        self._rejected = 0
        self._shed_events = 0
        self._shed_samples = 0
        self._calm_ticks = 0
        self._pressure_ticks = 0
        self._idle_ticks = 0
        self._hop_retargets = 0
        # batched-compute accounting: each counter is one batched jax call
        # = one fused-kernel launch per IMC layer (however many slots /
        # sessions ride it) — zero IMC launches for gate calls.  The
        # concurrent-session bench derives its one-launch-per-layer-per-
        # tick assertion from per-tick deltas of these.
        self._init_calls = 0               # batched stream_init waves
        self._hop_calls = 0                # batched single-hop calls
        self._replay_calls = 0             # multi-hop wake-replay calls
        self._gate_calls = 0               # masked no-op fill calls
        self._compiled_blocks = 0
        self._compiled_ticks = 0

        # compiled whole-tick fast path (repro.serving.compiled):
        # ``compiled=True`` (defaults) or a CompiledTickConfig turns
        # steady-state ticks into single-dispatch blocks — ``step()``
        # serves one-tick blocks, ``step_block()`` up to ``block`` ticks
        # per dispatch; any tick the block cannot model exactly falls back
        # to the interpreted path, bit-identically.  Imported lazily
        # (compiled.py imports _select_state from this module).
        self._compiled = None
        if compiled:
            from repro.serving.compiled import (CompiledTick,
                                                CompiledTickConfig)
            ccfg = (compiled if isinstance(compiled, CompiledTickConfig)
                    else CompiledTickConfig())
            self._compiled = CompiledTick(self, ccfg)

        self._decide = jax.jit(
            lambda dstate, logits, active: dec.decision_step(
                self.dcfg, dstate, logits, active))
        self._scatter = jax.jit(_scatter_slot)
        if vad is not None:
            vcfg = vad
            self._vad_fn = jax.jit(
                lambda vs, audio, active: vd.vad_step(vcfg, vs, audio,
                                                      active))

        # -- robustness: faults, health monitoring, profile store ----------
        self._profiles = profiles              # ProfileStore or None
        self._profile_swaps = 0
        self._heal_delta = None                # {conv_i: np (C_i,)} healing
        #                                        bias correction (counts)
        self._chip_delta_j = None              # cached jnp fault+heal sum
        self._faults = None
        if faults is not None:
            from repro.core import faults as flt
            self._faults = (faults if isinstance(faults, flt.FaultModel)
                            else flt.FaultModel.for_config(cfg, faults))
            # route every batched call through the rider variant up front
            # so fault deltas can hot-swap in without a mid-run mode flip
            self._enable_customization()
            if self._faults.pop_dirty():
                self._refresh_chip_delta()
        self._health = None
        if health is not None:
            from repro.serving import health as hl
            self._health = hl.HealthMonitor(self, health)

    # -- hop-multiplier engine table ----------------------------------------

    def _bundle(self, mult: int) -> dict:
        """Engine + jitted masked hop/gate functions for one hop multiple."""
        if mult not in self._mults:
            eng = sv.StreamEngine(self._hw, self.cfg, self.base_hop * mult,
                                  **self._engine_kw)

            def hop_masked(state, audio, mask, _step=eng._step):
                logits, new_state = _step(state, audio)
                return logits, _select_state(mask, new_state, state)

            # masked batched init: a whole admission wave — live streams
            # and session replay streams alike — runs its first full
            # window in ONE stream_init call (one fused launch per IMC
            # layer for the wave) instead of a B=1 launch per admission;
            # rows not in the mask keep their state verbatim
            def init_masked(state, windows, keys, mask, _init=eng._init):
                logits, new_state = _init(windows, keys)
                return logits, _select_state(mask, new_state, state)

            step_fn = sv.stream_step if self.streaming else sv.window_step
            init_fn = sv.stream_init if self.streaming else sv.window_init

            def init_cust_masked(state, windows, keys, mask, deltas, hw_,
                                 hb_, _kw=eng._kw, _geom=eng.geom):
                logits, new_state = init_fn(self._hw, windows, keys,
                                            self.cfg, _geom, **_kw,
                                            bias_delta=deltas, head_w=hw_,
                                            head_b=hb_)
                return logits, _select_state(mask, new_state, state)

            def hop_cust_masked(state, audio, mask, deltas, hw_, hb_,
                                _kw=eng._kw, _geom=eng.geom):
                logits, new_state = step_fn(self._hw, state, audio, self.cfg,
                                            _geom, **_kw, bias_delta=deltas,
                                            head_w=hw_, head_b=hb_)
                return logits, _select_state(mask, new_state, state)

            if self.streaming:
                def gate_masked(state, mask, _geom=eng.geom):
                    new = sv.gated_step(state, self.cfg, _geom, self._fills)
                    return _select_state(mask, new, state)

                def gate_cust_masked(state, mask, fills, _geom=eng.geom):
                    new = sv.gated_step(state, self.cfg, _geom, fills)
                    return _select_state(mask, new, state)
            else:
                def gate_masked(state, mask, _geom=eng.geom):
                    new = sv.gated_window_step(state, _geom)
                    return _select_state(mask, new, state)

                def gate_cust_masked(state, mask, fills, _geom=eng.geom):
                    new = sv.gated_window_step(state, _geom)
                    return _select_state(mask, new, state)

            self._mults[mult] = {"engine": eng, "hop": jax.jit(hop_masked),
                                 "hop_cust": jax.jit(hop_cust_masked),
                                 "init": jax.jit(init_masked),
                                 "init_cust": jax.jit(init_cust_masked),
                                 "gate": jax.jit(gate_masked),
                                 "gate_cust": jax.jit(gate_cust_masked),
                                 "replay": {}, "replay_cust": {}}
        return self._mults[mult]

    def _replay_fn(self, bundle: dict, n_hops: int, cust: bool):
        """Masked multi-hop replay for one deferred-run length: ONE fused
        launch per IMC layer for the whole n-hop run (streaming mode; the
        recompute fallback loops internally) instead of one launch per
        deferred hop.  Jitted per (hop-multiple, n_hops, cust)."""
        cache = bundle["replay_cust" if cust else "replay"]
        if n_hops not in cache:
            eng = bundle["engine"]
            multi_fn = (sv.stream_multi_step if self.streaming
                        else sv.window_multi_step)
            if cust:
                def replay(state, audio, mask, deltas, hw_, hb_,
                           _kw=eng._kw, _geom=eng.geom):
                    logits, new_state = multi_fn(
                        self._hw, state, audio, self.cfg, _geom, n_hops,
                        **_kw, bias_delta=deltas, head_w=hw_, head_b=hb_)
                    return logits, _select_state(mask, new_state, state)
            else:
                def replay(state, audio, mask, _kw=eng._kw, _geom=eng.geom):
                    logits, new_state = multi_fn(self._hw, state, audio,
                                                 self.cfg, _geom, n_hops,
                                                 **_kw)
                    return logits, _select_state(mask, new_state, state)
            cache[n_hops] = jax.jit(replay)
        return cache[n_hops]

    @property
    def engine(self) -> sv.StreamEngine:
        return self._bundle(self._mult)["engine"]

    @property
    def geom(self) -> sv.StreamGeometry:
        return self.engine.geom

    @property
    def hop(self) -> int:
        """Current effective hop (base_hop x dynamic multiplier)."""
        return self.base_hop * self._mult

    @property
    def hop_multiplier(self) -> int:
        return self._mult

    # -- observability helpers ----------------------------------------------

    @property
    def metrics(self) -> MetricsRegistry:
        """The server's metrics registry (always on: it backs stats())."""
        return self._metrics

    @property
    def recorder(self):
        """The flight recorder (None unless ``obs.recorder > 0``)."""
        return self._rec

    @property
    def auditor(self):
        """The launch auditor (None unless ``obs.audit != 'off'``)."""
        return self._audit

    def _region(self, cause: str):
        """Launch-auditor region around one batched call site (no-op
        context when the auditor is off)."""
        if self._audit is None:
            return contextlib.nullcontext()
        return self._audit.region(cause)

    def _tick_uj(self, computed: int, gated: int) -> float:
        """Analytical uJ for one tick's hop composition, from constants
        precomputed per hop-multiplier (computed hops are charged the
        full ungated per-decision energy, gated fills leakage only)."""
        consts = self._uj_consts.get(self._mult)
        if consts is None:
            offline = kws.layer_stats(self.cfg)
            streaming = sv.streaming_layer_stats(self.cfg, self.geom)
            g = energy.gated_energy_summary(offline, streaming,
                                            hop_samples=self.hop,
                                            duty_cycle=1.0)
            consts = (g["ungated_uj_per_decision"], g["idle_uj_per_hop"])
            self._uj_consts[self._mult] = consts
        return computed * consts[0] + gated * consts[1]

    # -- customization: per-slot riders + session manager -------------------

    def _base_head(self):
        hwp, _ = kws.as_hw_params(self._hw)
        return hwp.fc_w, hwp.fc_b

    def _enable_customization(self) -> None:
        """Materialize the per-slot customization arrays (zero bias deltas,
        the base FC head in every row) and route batched hops through the
        per-slot variant from now on.  Rows with base values are bit-exact
        no-ops, so uncustomized slots are unaffected."""
        if self._cust_on:
            return
        self._cust_on = True
        n = self.slots
        cfg = self.cfg
        fw, fb = self._base_head()
        self._slot_delta = {
            f"conv{i}": jnp.zeros((n, cfg.channels[i]))
            for i in range(1, cfg.num_conv_layers)}
        self._slot_head_w = jnp.broadcast_to(fw, (n,) + fw.shape)
        self._slot_head_b = jnp.broadcast_to(fb, (n,) + fb.shape)
        if self._fills is not None:
            self._slot_fills = tuple(
                jnp.broadcast_to(f, (n,) + f.shape) for f in self._fills)
        for s, rec in enumerate(self._slots):
            if rec is not None and rec.custom is not None:
                self._write_slot_custom(s, rec.custom)

    def _write_slot_custom(self, s: int, custom: Optional[dict]) -> None:
        """Sync slot ``s``'s rider rows with a stream's customization
        (``None`` resets to base).  Called on admission, eviction and
        hot-swap — the swap touches only row ``s``, other slots' rows (and
        their carries/rings) are untouched."""
        if not self._cust_on:
            return
        fw, fb = self._base_head()
        if custom is None:
            for name in self._slot_delta:
                self._slot_delta[name] = self._slot_delta[name].at[s].set(0.0)
            self._slot_head_w = self._slot_head_w.at[s].set(fw)
            self._slot_head_b = self._slot_head_b.at[s].set(fb)
            if self._slot_fills is not None:
                self._slot_fills = tuple(
                    t.at[s].set(f) for t, f in zip(self._slot_fills,
                                                   self._fills))
            return
        for name in self._slot_delta:
            self._slot_delta[name] = self._slot_delta[name].at[s].set(
                jnp.asarray(custom["delta"][name]))
        self._slot_head_w = self._slot_head_w.at[s].set(
            jnp.asarray(custom["head"][0]))
        self._slot_head_b = self._slot_head_b.at[s].set(
            jnp.asarray(custom["head"][1]))
        if self._slot_fills is not None and custom.get("fills") is not None:
            self._slot_fills = tuple(
                t.at[s].set(jnp.asarray(f))
                for t, f in zip(self._slot_fills, custom["fills"]))

    def _slot_custom_args(self):
        delta = self._slot_delta
        chip = self._chip_delta_j
        if chip is not None:
            # the chip-global fault+heal offset rides every slot's existing
            # bias-delta row — same operands, same launches: injection and
            # healing are free at serve time
            delta = {k: v + chip[k][None] for k, v in delta.items()}
        return (delta, self._slot_head_w, self._slot_head_b)

    def _row_custom(self, rec: "_Stream"):
        """Rider args for the sequential B=1 init paths (``batch_init``
        off, hop-retarget re-inits), combining the stream's own
        customization with the chip-global fault/heal delta.  None when
        neither applies (base init path)."""
        chip = self._chip_delta_j
        if not self._cust_on or (rec.custom is None and chip is None):
            return None
        cfg = self.cfg
        if rec.custom is not None:
            delta = {name: jnp.asarray(rec.custom["delta"][name])
                     for name in cfg.imc_layer_names()}
            hw1, hb1 = (jnp.asarray(rec.custom["head"][0]),
                        jnp.asarray(rec.custom["head"][1]))
        else:
            delta = {name: jnp.zeros((cfg.channels[int(name[4:])],))
                     for name in cfg.imc_layer_names()}
            hw1, hb1 = self._base_head()
        if chip is not None:
            delta = {k: v + chip[k] for k, v in delta.items()}
        return ({k: v[None] for k, v in delta.items()},
                hw1[None], hb1[None])

    # -- fault injection + self-healing -------------------------------------

    @property
    def faults(self):
        """The live FaultModel (None unless constructed with ``faults=``).
        Inject through it between ticks — the next ``step()`` notices the
        dirty flag and refreshes the rider operands."""
        return self._faults

    @property
    def health(self):
        """The HealthMonitor (None unless constructed with ``health=``)."""
        return self._health

    def _refresh_chip_delta(self) -> None:
        """Rebuild the cached chip-global per-layer count delta = injected
        faults + healing correction.  None when the chip is pristine and
        unhealed, which keeps the rider rows bit-exact base values."""
        fault = (self._faults.deltas()
                 if self._faults is not None and self._faults.active
                 else None)
        if fault is None and self._heal_delta is None:
            self._chip_delta_j = None
            return
        out = {}
        for name in self.cfg.imc_layer_names():
            v = np.zeros((self.cfg.channels[int(name[4:])],), np.float32)
            if fault is not None:
                v = v + fault[name]
            if self._heal_delta is not None and name in self._heal_delta:
                v = v + self._heal_delta[name]
            out[name] = jnp.asarray(v)
        self._chip_delta_j = out

    def _set_heal_delta(self, heal: Dict[str, np.ndarray]) -> None:
        """Hot-swap a healing bias correction (per-layer pre-sign count
        deltas, from the health monitor's background recompensation) into
        every batched launch.  Entries replace any previous heal for the
        same layer — recoveries are recomputed from the pristine stored
        bias, so repeated heals never stack."""
        self._enable_customization()
        cur = dict(self._heal_delta or {})
        cur.update({k: np.asarray(v, np.float32) for k, v in heal.items()})
        self._heal_delta = cur
        self._refresh_chip_delta()

    def customize(self, stream_id: str, ccfg=None):
        """Open an enrollment/fine-tuning session attached to a live
        stream (created empty if absent): labeled utterances submitted via
        ``session.enroll`` ride the stream's normal batched hops, then the
        paper's on-chip loop (bias compensation -> error-scaled + SGA
        fine-tune) runs as bounded background jobs inside ``step()``.  See
        repro.serving.customize.  Returns the CustomizationSession."""
        from repro.serving import customize as cz
        if self.hcfg is not None:
            raise ValueError("customization requires a fixed hop "
                             "(dynamic_hop retargets would break the "
                             "enrollment capture alignment)")
        if self._cust is None:
            self._cust = cz.CustomizationManager(self)
        self._enable_customization()
        return self._cust.start(stream_id, ccfg)

    def install_custom(self, stream_id: str, result) -> None:
        """Hot-swap a finished customization (a CustomizationResult — e.g.
        a persisted user profile) into a stream: its slot's bias-delta /
        FC-head / silence-fill rows are reprogrammed in place; every other
        slot's rows and states are untouched.  The stream is created
        (empty) if it does not exist yet, so a profile can be installed
        before its first audio arrives."""
        from repro.serving import customize as cz
        self._enable_customization()
        rec = self._streams.get(stream_id)
        if rec is None:
            rec = _Stream(stream_id=stream_id, uid=self._uid,
                          buf=np.zeros((0,), np.float32))
            self._uid += 1
            self._streams[stream_id] = rec
            self._queue.append(rec)
            self._try_admit()
        rec.custom = cz.result_riders(result, self._hw, self.cfg,
                                      chip_offsets=self._engine_kw
                                      ["chip_offsets"],
                                      with_fills=self._fills is not None)
        if rec.slot is not None:
            self._write_slot_custom(rec.slot, rec.custom)

    def _submit_internal(self, stream_id: str, wav: np.ndarray,
                         custom: Optional[dict] = None,
                         uid: Optional[int] = None) -> "_Stream":
        """Enqueue a session-owned replay stream: rides the normal slot
        machinery and the SAME batched launches, but emits no decision
        events, bypasses the admission-queue bound and is exempt from SLO
        shedding.  Finished on arrival — it retires as soon as its audio
        drains (the session captures its features first).  ``uid`` pins
        the stream's noise-field key to a reserved uid (health canaries
        reuse one key so every canary sees the identical field)."""
        rec = _Stream(stream_id=stream_id,
                      uid=self._uid if uid is None else uid,
                      buf=np.asarray(wav, np.float32), internal=True,
                      force_compute=True, custom=custom, finished=True)
        if uid is None:
            self._uid += 1
        self._streams[stream_id] = rec
        self._queue.append(rec)
        self._try_admit()
        return rec

    def _drop_internal(self, stream_id: str) -> None:
        rec = self._streams.pop(stream_id, None)
        if rec is None:
            return
        rec.finished = True
        rec.buf = rec.buf[:0]
        rec.pending = []
        if rec.slot is not None:
            self._free_slot(rec)
        elif rec in self._queue:
            self._queue.remove(rec)

    # -- stream lifecycle ---------------------------------------------------

    def submit(self, stream_id: str, chunk: np.ndarray,
               user_id: Optional[str] = None,
               uid: Optional[int] = None) -> str:
        """Append audio to a stream (created on first submit).  Returns the
        stream's placement: 'slot' (live), 'queued' (awaiting a slot) or
        'rejected' (admission queue full — nothing was buffered; the
        caller may retry later).

        ``user_id`` (needs ``profiles=`` at construction) associates the
        stream with a profile-store user: their stored customization is
        auto-installed onto whichever slot the stream lands on, and the
        per-tick staleness sweep re-installs it if the store's copy
        changes (or resets to base if it is deleted).

        ``uid`` pins the stream's noise-field identity instead of drawing
        from this server's counter — the sharded router
        (repro.serving.shard) assigns GLOBAL uids in submission order so
        a stream's per-absolute-column SA-noise field is the same no
        matter which device pool it lands on (and identical to what a
        single-device server would have drawn).  The local counter jumps
        past any pinned uid so internally spawned streams (canaries,
        session replays) never collide with routed ones."""
        rec = self._streams.get(stream_id)
        if rec is None:
            if (self.acfg is not None and self.acfg.max_queue is not None
                    and all(r is not None for r in self._slots)
                    and len(self._queue) >= self.acfg.max_queue):
                self._rejected += 1
                if self._rec is not None:
                    self._rec.record(self._steps, "reject",
                                     stream=stream_id)
                return "rejected"
            rec = _Stream(stream_id=stream_id,
                          uid=self._uid if uid is None else int(uid),
                          buf=np.zeros((0,), np.float32))
            self._uid = (self._uid + 1 if uid is None
                         else max(self._uid, int(uid) + 1))
            self._streams[stream_id] = rec
            self._queue.append(rec)
            self._try_admit()
        if rec.finished:
            raise ValueError(f"stream {stream_id} already finished")
        if user_id is not None and user_id != rec.user_id:
            if self._profiles is None:
                raise ValueError("submit(user_id=...) needs a profile "
                                 "store: construct with profiles=")
            self._attach_profile(rec, user_id)
        rec.buf = np.concatenate([rec.buf, np.asarray(chunk, np.float32)])
        return "slot" if rec.slot is not None else "queued"

    # -- profile store: auto-install + staleness sweep ----------------------

    def _attach_profile(self, rec: "_Stream", user_id: str) -> None:
        """Associate ``rec`` with a store user and install their profile
        if one exists.  A user with no stored profile serves the base
        model but stays associated — a later enrollment save is picked up
        by the staleness sweep."""
        rec.user_id = user_id
        rec.profile_mtime = None
        if self._profiles.mtime(user_id) is not None:
            self._install_profile(rec)

    def _install_profile(self, rec: "_Stream") -> None:
        """(Re)load ``rec.user_id``'s stored profile and program its rider
        rows.  The mtime is read *before* the load: if the file is
        replaced mid-install the recorded stamp is stale and the next
        sweep simply reinstalls."""
        from repro.serving import customize as cz
        rec.profile_mtime = self._profiles.mtime(rec.user_id)
        result = self._profiles.load(rec.user_id)
        self._enable_customization()
        rec.custom = cz.result_riders(result, self._hw, self.cfg,
                                      chip_offsets=self._engine_kw
                                      ["chip_offsets"],
                                      with_fills=self._fills is not None)
        if rec.slot is not None:
            self._write_slot_custom(rec.slot, rec.custom)

    def _reset_profile(self, rec: "_Stream") -> None:
        rec.custom = None
        rec.profile_mtime = None
        if rec.slot is not None:
            self._write_slot_custom(rec.slot, None)

    def _check_profiles(self) -> None:
        """Stale-profile eviction (once per tick): any live stream whose
        stored profile changed under it (``st_mtime_ns`` moved — every
        ``ProfileStore.save`` is a fresh inode) is re-installed from the
        fresh file; a stream whose profile was deleted drops back to the
        base model."""
        if self._profiles is None:
            return
        for rec in self._streams.values():
            if rec.user_id is None:
                continue
            m = self._profiles.mtime(rec.user_id)
            if m == rec.profile_mtime:
                continue
            self._profile_swaps += 1
            if m is None:
                self._reset_profile(rec)
            else:
                try:
                    self._install_profile(rec)
                except FileNotFoundError:  # deleted between stat and load
                    self._reset_profile(rec)

    def finish(self, stream_id: str) -> None:
        """Producer signals end-of-stream: the slot is freed once the
        buffered audio drains below one hop."""
        self._streams[stream_id].finished = True

    def evict(self, stream_id: str) -> None:
        """Drop a stream immediately, freeing its slot."""
        rec = self._streams[stream_id]
        rec.finished = True
        rec.buf = rec.buf[:0]
        rec.pending = []
        if rec.slot is not None:
            self._free_slot(rec)
        elif rec in self._queue:
            self._queue.remove(rec)

    def _free_slot(self, rec: _Stream) -> None:
        s = rec.slot
        self._slots[s] = None
        rec.slot = None
        self._write_slot_custom(s, None)
        if self._rec is not None:
            self._rec.record(self._steps, "evict", stream=rec.stream_id,
                             slot=s, internal=rec.internal)
        self._try_admit()

    def _try_admit(self) -> None:
        for s in range(self.slots):
            if self._slots[s] is None and self._queue:
                rec = self._queue.popleft()
                rec.slot = s
                rec.initialized = False
                self._slots[s] = rec
                self._write_slot_custom(s, rec.custom)

    # -- backpressure: latency SLO shedding + slot autoscaling --------------

    def _enforce_slo(self) -> None:
        """Shed streams whose buffered backlog exceeds the latency SLO:
        drop the oldest audio down to the low-water mark (half the SLO,
        never below one window) and re-initialize from the freshest
        window.  Continuity across the cut is gone anyway, so the state is
        rebuilt rather than fed stale audio late."""
        if self.acfg is None or self.acfg.max_lag_s is None:
            return
        max_lag = int(self.acfg.max_lag_s * self.cfg.sample_rate)
        keep = max(self.geom.window, max_lag // 2)
        for rec in self._streams.values():
            if rec.finished or rec.internal or rec.force_compute:
                # learning work is exempt: shedding an enrollment utterance
                # would silently corrupt the captured feature buffer
                continue
            backlog = sum(map(len, rec.pending)) + len(rec.buf)
            if backlog <= max_lag:
                continue
            total = (np.concatenate(rec.pending + [rec.buf])
                     if rec.pending else rec.buf)
            dropped = backlog - keep
            rec.buf = total[-keep:]
            rec.pending = []
            rec.silent_run = 0
            rec.initialized = False
            rec.sheds += 1
            rec.shed_samples += dropped
            self._shed_events += 1
            self._shed_samples += dropped
            if self._rec is not None:
                self._rec.record(self._steps, "shed",
                                 stream=rec.stream_id, samples=dropped)

    def _autoscale(self) -> None:
        if self.acfg is None or self.max_slots <= self.min_slots:
            return
        if self._queue and self.slots < self.max_slots:
            self._idle_ticks = 0
            self._pressure_ticks += 1
            if self._pressure_ticks >= self.acfg.scale_up_after:
                self._resize(min(self.max_slots,
                                 self.slots + len(self._queue)))
                self._pressure_ticks = 0
            return
        self._pressure_ticks = 0
        free_tail = 0
        for rec in reversed(self._slots):
            if rec is None:
                free_tail += 1
            else:
                break
        if free_tail and not self._queue and self.slots > self.min_slots:
            self._idle_ticks += 1
            if self._idle_ticks >= self.acfg.scale_down_after:
                self._resize(max(self.min_slots, self.slots - free_tail))
                self._idle_ticks = 0
        else:
            self._idle_ticks = 0

    def _resize(self, n: int) -> None:
        """Grow (append zero rows) or shrink (crop trailing free slots) the
        batched state pytrees.  Jitted functions re-trace on the new batch
        shape automatically."""
        if n == self.slots:
            return
        if n > self.slots:
            grow = n - self.slots

            def pad(a):
                return jnp.concatenate(
                    [a, jnp.zeros((grow,) + a.shape[1:], a.dtype)])

            def pad_rows(a, row):
                return jnp.concatenate(
                    [a, jnp.broadcast_to(row, (grow,) + row.shape)])

            self._state = jax.tree_util.tree_map(pad, self._state)
            self._dstate = jax.tree_util.tree_map(pad, self._dstate)
            if self._vstate is not None:
                self._vstate = jax.tree_util.tree_map(pad, self._vstate)
            if self._cust_on:
                fw, fb = self._base_head()
                self._slot_delta = {k: pad(v)
                                    for k, v in self._slot_delta.items()}
                self._slot_head_w = pad_rows(self._slot_head_w, fw)
                self._slot_head_b = pad_rows(self._slot_head_b, fb)
                if self._slot_fills is not None:
                    self._slot_fills = tuple(
                        pad_rows(t, f) for t, f in zip(self._slot_fills,
                                                       self._fills))
            self._slots.extend([None] * grow)
        else:
            assert all(r is None for r in self._slots[n:]), \
                "only trailing free slots can be cropped"
            self._state = jax.tree_util.tree_map(lambda a: a[:n],
                                                 self._state)
            self._dstate = jax.tree_util.tree_map(lambda a: a[:n],
                                                  self._dstate)
            if self._vstate is not None:
                self._vstate = jax.tree_util.tree_map(lambda a: a[:n],
                                                      self._vstate)
            if self._cust_on:
                self._slot_delta = {k: v[:n]
                                    for k, v in self._slot_delta.items()}
                self._slot_head_w = self._slot_head_w[:n]
                self._slot_head_b = self._slot_head_b[:n]
                if self._slot_fills is not None:
                    self._slot_fills = tuple(t[:n]
                                             for t in self._slot_fills)
            self._slots = self._slots[:n]
        self.slots = n
        self._try_admit()

    # -- dynamic hop --------------------------------------------------------

    def _feasible_mult(self, mult: int) -> bool:
        try:
            sv.make_stream_geometry(self.cfg, self.base_hop * mult)
            return True
        except ValueError:
            return False

    def _set_mult(self, mult: int) -> None:
        """Retarget the effective hop.  The streaming geometry (carry
        sizes, fresh-column counts) is hop-dependent, so every live slot's
        ``StreamState`` is rebuilt from its retained last window of
        consumed audio via ``stream_init`` on the new-hop engine; deferred
        silent hops are pushed back into the buffer for re-consumption at
        the new hop size.  With SA noise enabled the rebuilt stream's
        noise field restarts at window 0 (a re-init is a fresh programming
        of the array), so the bit-exactness contract is scoped to a fixed
        hop."""
        if mult == self._mult:
            return
        bundle = self._bundle(mult)
        eng = bundle["engine"]
        window = self.geom.window
        new_state = eng.zeros_state(self.slots)
        for s, rec in enumerate(self._slots):
            if rec is None or not rec.initialized:
                continue
            if rec.pending:
                rec.buf = np.concatenate(rec.pending + [rec.buf])
                rec.pending = []
            rec.silent_run = 0
            if len(rec.recent) >= window:
                key = jax.random.fold_in(self._base_key, rec.uid)[None]
                t0 = time.perf_counter()
                d1 = self._row_custom(rec)
                if d1 is not None:
                    _, one = eng.init_custom(
                        jnp.asarray(rec.recent[None, -window:]), key, *d1)
                else:
                    _, one = eng.init(
                        jnp.asarray(rec.recent[None, -window:]), key)
                new_state = self._scatter(new_state, one, s)
                dt = time.perf_counter() - t0
                rec.wall_s += dt
                self._hop_wall_s += dt
            else:
                rec.initialized = False     # re-admit from the buffer
        self._state = new_state
        self._mult = mult
        self._hop_retargets += 1
        if self._rec is not None:
            self._rec.record(self._steps, "hop_retarget", mult=mult,
                             hop=self.base_hop * mult)

    def _retarget_hop(self, events: List[dict], woke: bool,
                      silent: bool = False) -> None:
        if self.hcfg is None:
            return
        max_score = max((e["score"] for e in events), default=0.0)
        if woke or max_score >= self.hcfg.calm_score:
            self._calm_ticks = 0
            if self._mult != 1:
                self._set_mult(1)
            return
        self._calm_ticks += 1
        after = self.hcfg.widen_after
        if silent and self.hcfg.calm_silence is not None:
            after = self.hcfg.calm_silence   # duty-aware: silence widens
            #                                  faster than low-score speech
        if self._calm_ticks >= after:
            self._calm_ticks = 0
            # clamp to the cap so non-power-of-two max_multipliers are
            # still reachable (any integer multiple of the base hop keeps
            # hop_alignment, so mult=3 etc. is geometrically fine)
            nxt = min(self._mult * 2, self.hcfg.max_multiplier)
            if nxt != self._mult and self._feasible_mult(nxt):
                self._set_mult(nxt)

    # -- the batched hop ----------------------------------------------------

    def _admit_ready(self):
        """Initialize any slotted stream whose buffer holds a full window.
        Returns (init_mask, init_logits) rows for this step's decisions.

        With ``batch_init`` (the default) the whole wave of ready slots —
        fresh admissions and session feature-replay streams alike — runs
        its first windows in ONE masked ``stream_init`` call: one fused
        launch per IMC layer for the wave, instead of a B=1 launch per
        admission (the enrollment-phase launch saving; bit-identical, the
        init math is row-parallel and exact on the fixed-point grids)."""
        window = self.geom.window
        init_mask = np.zeros((self.slots,), bool)
        init_logits = np.zeros((self.slots, self.cfg.num_classes),
                               np.float32)
        todo = [(s, rec) for s, rec in enumerate(self._slots)
                if rec is not None and not rec.initialized
                and len(rec.buf) >= window]
        if not todo:
            return init_mask, init_logits

        def _book(rec, s, first, dt):
            rec.wall_s += dt
            rec.initialized = True
            rec.hops += 1
            rec.consumed += window
            rec.recent = first.copy()
            rec.pending = []
            rec.silent_run = 0
            self._dstate = dec.reset_slot(self._dstate, s)
            if self._vstate is not None:
                self._vstate = vd.vad_reset_slot(self._vstate, s)
            init_mask[s] = True
            if self._rec is not None:
                self._rec.record(self._steps, "admit",
                                 stream=rec.stream_id, slot=s,
                                 internal=rec.internal)

        if self.batch_init:
            windows = np.zeros((self.slots, window), np.float32)
            keys = np.zeros((self.slots, 2), np.uint32)
            for s, rec in todo:
                windows[s] = rec.buf[:window]
                rec.buf = rec.buf[window:]   # the state carries the
                #                              overlap; later hops feed
                #                              fresh samples only
                keys[s] = np.asarray(
                    jax.random.fold_in(self._base_key, rec.uid))
            bundle = self._bundle(self._mult)
            mask = np.zeros((self.slots,), bool)
            for s, _ in todo:
                mask[s] = True
            mask_j = jnp.asarray(mask)
            t0 = time.perf_counter()
            with self._region("init"):
                if self._cust_on:
                    logits, self._state = bundle["init_cust"](
                        self._state, jnp.asarray(windows),
                        jnp.asarray(keys), mask_j,
                        *self._slot_custom_args())
                else:
                    logits, self._state = bundle["init"](
                        self._state, jnp.asarray(windows),
                        jnp.asarray(keys), mask_j)
                logits.block_until_ready()
            dt = time.perf_counter() - t0
            self._hop_wall_s += dt
            self._init_calls += 1
            if self.trace is not None:
                self.trace.span("init", t0, t0 + dt, tick=self._steps,
                                slots=len(todo))
            for s, rec in todo:
                _book(rec, s, windows[s], dt / len(todo))
                init_logits[s] = np.asarray(logits[s])
            return init_mask, init_logits

        for s, rec in todo:
            first = rec.buf[:window]
            rec.buf = rec.buf[window:]
            key = jax.random.fold_in(self._base_key, rec.uid)[None]
            t0 = time.perf_counter()
            d1 = self._row_custom(rec)
            with self._region("init"):
                if d1 is not None:
                    logits, one = self.engine.init_custom(
                        jnp.asarray(first[None]), key, *d1)
                else:
                    logits, one = self.engine.init(jnp.asarray(first[None]),
                                                   key)
            self._state = self._scatter(self._state, one, s)
            dt = time.perf_counter() - t0
            # the window-0 decision counts toward throughput, so its time
            # must count too (decisions_per_sec = decisions / hop_wall_s)
            self._hop_wall_s += dt
            self._init_calls += 1
            _book(rec, s, first, dt)
            init_logits[s] = np.asarray(logits[0])
        return init_mask, init_logits

    def step(self) -> List[dict]:
        """One scheduler tick.  Returns this tick's decision events (one
        per deciding stream; gated hops emit none).

        With ``compiled=`` a steady-state tick runs as a one-tick
        compiled block (one VAD dispatch + one fused scan dispatch,
        repro.serving.compiled) and any structural tick — admissions,
        sheds, resizes, session/health traffic — falls back to the
        interpreted path; both produce bit-identical events, state and
        counters (dispatch accounting aside)."""
        if self._compiled is not None and self._compiled.horizon(1) == 1:
            return self._compiled.run(1)
        return self._step_python()

    def step_block(self, max_ticks: Optional[int] = None) -> List[dict]:
        """Serve up to ``max_ticks`` steady-state ticks in ONE compiled
        dispatch, returning their concatenated decision events in tick
        order — bit-identical to calling ``step()`` that many times.
        The compiled config's ``block`` is a hard per-dispatch cap (it
        bounds the padded scan length, so jit retraces stay bounded no
        matter what callers pass); the block also ends early at any
        structural boundary (``CompiledTick.horizon``).  A tick the
        compiled path cannot model at all runs interpreted.  Without
        ``compiled=`` this is exactly one interpreted ``step()``."""
        if self._compiled is None:
            return self._step_python()
        cap = self._compiled.cfg.block
        k = self._compiled.horizon(cap if max_ticks is None
                                   else min(max_ticks, cap))
        if k < 1:
            return self._step_python()
        return self._compiled.run(k)

    def _step_python(self) -> List[dict]:
        """One interpreted scheduler tick: SLO shedding, autoscaling,
        admissions, VAD classification, wake replays, then ONE batched hop
        over every speech-ready slot and ONE masked no-op fill over every
        gated slot, then the batched decision update.  This is the
        reference semantics the compiled fast path is proven against."""
        tick = self._steps
        t_tick = time.perf_counter()
        if self._audit is not None:
            self._audit.begin_tick(tick)
        self._check_profiles()
        if self._faults is not None:
            self._faults.tick()                 # advance offset drift
            if self._faults.pop_dirty():
                self._refresh_chip_delta()      # riders pick up new deltas
        self._enforce_slo()
        self._autoscale()
        bundle = self._bundle(self._mult)
        hop = self.geom.hop
        window = self.geom.window
        init_mask, init_logits = self._admit_ready()

        ready = np.zeros((self.slots,), bool)
        audio = np.zeros((self.slots, hop), np.float32)
        for s, rec in enumerate(self._slots):
            if (rec is not None and rec.initialized and not init_mask[s]
                    and len(rec.buf) >= hop):
                ready[s] = True
                audio[s] = rec.buf[:hop]
                rec.buf = rec.buf[hop:]

        if self.vcfg is None:
            speech = ready.copy()
        else:
            self._vstate, sp = self._vad_fn(self._vstate,
                                            jnp.asarray(audio),
                                            jnp.asarray(ready))
            speech = np.asarray(sp) & ready
            for s, rec in enumerate(self._slots):
                # enrollment/replay hops must run the real IMC path — a
                # gated (fill-advanced) hop would corrupt the captured
                # feature buffer, so learning streams bypass the VAD gate
                if ready[s] and rec is not None and rec.force_compute:
                    speech[s] = True
        # a tick is *silent* when hops ran but none carried speech — the
        # duty-aware dynamic hop widens faster on these (force_compute
        # streams count as speech, so forced paths never look silent)
        silent_tick = bool(ready.any()) and not bool((speech & ready).any())

        compute_mask = np.zeros((self.slots,), bool)
        fill_mask = np.zeros((self.slots,), bool)
        replays: List[tuple] = []
        for s, rec in enumerate(self._slots):
            if not ready[s]:
                continue
            chunk = audio[s]
            if speech[s]:
                rec.silent_run = 0
                if rec.pending:           # wake: replay the deferred hops
                    replays.append((s, rec.pending + [chunk]))
                    rec.pending = []
                else:
                    compute_mask[s] = True
            else:
                rec.silent_run += 1
                rec.pending.append(chunk)
                if len(rec.pending) > self.vcfg.wake_margin:
                    aged = rec.pending.pop(0)
                    fill_mask[s] = True   # advance by the no-op fill
                    rec.recent = np.concatenate([rec.recent,
                                                 aged])[-window:]
                    rec.consumed += hop
                    rec.gated_hops += 1
                    self._gated_hops += 1

        events: List[dict] = []

        # wake replays: the deferred silent hops plus the onset hop run the
        # real IMC path for this slot in ONE multi-hop launch per IMC layer
        # (the tail just extends by the deferred hops' fresh columns), so
        # the keyword prefix the VAD latency would have cut is decided
        # exactly as if ungated — bit-identical to replaying hop by hop
        for s, chunks in replays:
            rec = self._slots[s]
            n = len(chunks)
            mask = np.zeros((self.slots,), bool)
            mask[s] = True
            mask_j = jnp.asarray(mask)
            a = np.zeros((self.slots, n * hop), np.float32)
            a[s] = np.concatenate(chunks)
            t0 = time.perf_counter()
            with self._region("replay"):
                if self._cust_on:
                    fn = self._replay_fn(bundle, n, cust=True)
                    lg, self._state = fn(self._state, jnp.asarray(a),
                                         mask_j, *self._slot_custom_args())
                else:
                    fn = self._replay_fn(bundle, n, cust=False)
                    lg, self._state = fn(self._state, jnp.asarray(a),
                                         mask_j)
            self._replay_calls += 1
            outs = []
            for j in range(n):
                self._dstate, out = self._decide(self._dstate, lg[:, j],
                                                 mask_j)
                outs.append(out)
            outs[-1].score.block_until_ready()
            dt = time.perf_counter() - t0
            rec.wall_s += dt
            self._hop_wall_s += dt
            if self.trace is not None:
                self.trace.span("replay", t0, t0 + dt, tick=tick,
                                stream=rec.stream_id, hops=n)
            for j, (ch, out) in enumerate(zip(chunks, outs)):
                self._decisions += 1
                self._speech_hops += 1
                rec.recent = np.concatenate([rec.recent, ch])[-window:]
                rec.consumed += hop
                rec.hops += 1
                ev = {"stream": rec.stream_id, "hop": rec.hops - 1,
                      "keyword": int(out.keyword[s]),
                      "score": float(out.score[s]),
                      "trigger": bool(out.trigger[s])}
                events.append(ev)
                if ev["trigger"]:
                    rec.triggers.append(ev)

        logits = init_logits
        if compute_mask.any():
            t0 = time.perf_counter()
            mask_j = jnp.asarray(compute_mask)
            with self._region("hop"):
                if self._cust_on:
                    hop_logits, self._state = bundle["hop_cust"](
                        self._state, jnp.asarray(audio), mask_j,
                        *self._slot_custom_args())
                else:
                    hop_logits, self._state = bundle["hop"](
                        self._state, jnp.asarray(audio), mask_j)
                hop_logits.block_until_ready()
            dt = time.perf_counter() - t0
            self._hop_wall_s += dt
            self._hop_calls += 1
            n_active = int(compute_mask.sum())
            if self.trace is not None:
                self.trace.span("hop", t0, t0 + dt, tick=tick,
                                slots=n_active)
            for s, rec in enumerate(self._slots):
                if compute_mask[s]:
                    if rec.internal:
                        self._learn_hops += 1
                    else:
                        self._speech_hops += 1
                    rec.hops += 1
                    rec.wall_s += dt / n_active
                    rec.consumed += hop
                    rec.recent = np.concatenate([rec.recent,
                                                 audio[s]])[-window:]
            logits = np.where(compute_mask[:, None], np.asarray(hop_logits),
                              init_logits)

        if fill_mask.any():
            t0 = time.perf_counter()
            with self._region("gate"):
                if self._cust_on and self._slot_fills is not None:
                    self._state = bundle["gate_cust"](
                        self._state, jnp.asarray(fill_mask),
                        self._slot_fills)
                else:
                    self._state = bundle["gate"](self._state,
                                                 jnp.asarray(fill_mask))
                jax.block_until_ready(self._state)
            dt = time.perf_counter() - t0
            self._hop_wall_s += dt
            self._gate_calls += 1
            if self.trace is not None:
                self.trace.span("gate", t0, t0 + dt, tick=tick,
                                slots=int(fill_mask.sum()))

        internal = np.asarray([rec is not None and rec.internal
                               for rec in self._slots])
        decide_mask = (init_mask | compute_mask) & ~internal
        if bool(decide_mask.any()):
            t0 = time.perf_counter()
            self._dstate, out = self._decide(self._dstate,
                                             jnp.asarray(logits),
                                             jnp.asarray(decide_mask))
            self._decisions += int(decide_mask.sum())
            if self.trace is not None:
                out.score.block_until_ready()
                self.trace.span("decide", t0, time.perf_counter(),
                                tick=tick, slots=int(decide_mask.sum()))
            trig = np.asarray(out.trigger)
            kwd = np.asarray(out.keyword)
            score = np.asarray(out.score)
            for s, rec in enumerate(self._slots):
                if rec is None or not decide_mask[s]:
                    continue
                ev = {"stream": rec.stream_id, "hop": rec.hops - 1,
                      "keyword": int(kwd[s]), "score": float(score[s]),
                      "trigger": bool(trig[s])}
                events.append(ev)
                if ev["trigger"]:
                    rec.triggers.append(ev)

        # feature captures must see the post-hop states before slots retire
        t_riders = time.perf_counter() if self.trace is not None else 0.0
        if self._cust is not None:
            self._cust.on_step(self)
        if self._health is not None:
            self._health.on_step(self)          # canary carry/ring capture

        # decisions emitted while the chip is not healthy are flagged so
        # downstream consumers can discount (or re-request) them
        if self._health is not None:
            degraded = self._health.state != "healthy"
            for ev in events:
                ev["degraded"] = degraded

        # retire drained finished streams
        for rec in list(self._slots):
            if (rec is not None and rec.finished
                    and len(rec.buf) < (hop if rec.initialized
                                        else window)):
                self._free_slot(rec)
        self._steps += 1
        self._retarget_hop(events, woke=bool(replays), silent=silent_tick)
        # background learning jobs: calibration layers, feature-replay
        # spawns, bounded fine-tune epochs, hot swaps
        if self._cust is not None:
            self._cust.tick(self)
        # health background work: canary spawns + tick-resumable
        # recompensation (calibration layers, heal hot-swap)
        if self._health is not None:
            self._health.tick(self)

        # -- per-tick telemetry (composition, analytical uJ, spans) --------
        n_replay_hops = sum(len(chunks) for _, chunks in replays)
        computed = (int(init_mask.sum()) + int(compute_mask.sum())
                    + n_replay_hops)
        gated = int(fill_mask.sum())
        if self._rec is not None or self.trace is not None:
            uj = self._tick_uj(computed, gated)
            if self._rec is not None and (computed or gated or events):
                self._rec.record(tick, "tick",
                                 init=int(init_mask.sum()),
                                 computed=computed, gated=gated,
                                 replays=len(replays),
                                 decisions=len(events), uj=round(uj, 4))
                self._metrics.observe("serving.tick_uj", uj)
            if self.trace is not None:
                now = time.perf_counter()
                self.trace.span("riders", t_riders, now, tick=tick)
                self.trace.span("tick", t_tick, now, tick=tick,
                                computed=computed, gated=gated,
                                decisions=len(events), uj=round(uj, 4))
        if self._audit is not None:
            self._audit.end_tick()
        return events

    def drain(self, max_steps: int = 10_000) -> List[dict]:
        """Step until no slot can make progress and the queue is empty."""
        events: List[dict] = []
        for _ in range(max_steps):
            before = (len(self._queue),
                      [None if r is None else len(r.buf)
                       for r in self._slots])
            # compiled servers drain in whole blocks; tick count and all
            # serving state stay bit-identical to one-step draining
            events.extend(self.step() if self._compiled is None
                          else self.step_block())
            after = (len(self._queue),
                     [None if r is None else len(r.buf)
                      for r in self._slots])
            if after == before:
                break
        return events

    # -- crash-safe snapshots ------------------------------------------------

    def snapshot(self, path: Optional[str] = None):
        """Serialize the complete serving state — slot carries and GAP
        rings, decision/VAD state, per-stream buffers and noise-field
        keys, queue order, fault/health state, the healing delta and
        every mid-flight customization session — so a restarted process
        can ``restore()`` and continue **bit-identically** to an
        uninterrupted run (test-enforced).

        Take snapshots at tick boundaries (between ``step()`` calls —
        that is the only consistent cut).  With ``path`` the snapshot is
        written as one .npz, atomically (tmp + fsync + ``os.replace``,
        the ProfileStore idiom): a crash mid-save leaves the previous
        snapshot intact.  Without ``path`` the in-memory snapshot dict is
        returned (useful for tests and warm standbys)."""
        arrays: Dict[str, np.ndarray] = {}
        spec = {
            "version": 2,
            "config": {"sample_len": self.cfg.sample_len,
                       "base_hop": self.base_hop,
                       "streaming": self.streaming,
                       "sa_noise_std": float(
                           self._engine_kw["sa_noise_std"]),
                       "vad": self.vcfg is not None},
            "slots_n": self.slots,
            "mult": self._mult,
            "uid": self._uid,
            "base_key": _snap_encode(np.asarray(self._base_key), arrays),
            "state": _snap_encode(self._state, arrays),
            "dstate": _snap_encode(self._dstate, arrays),
            "vstate": _snap_encode(self._vstate, arrays),
            "streams": {sid: _snap_encode(dict(vars(rec)), arrays)
                        for sid, rec in self._streams.items()},
            "queue": [rec.stream_id for rec in self._queue],
            "slot_ids": [None if rec is None else rec.stream_id
                         for rec in self._slots],
            # v2: the whole metrics registry rides along — every counter
            # (serving, health, customization) round-trips without a
            # hand-maintained key list
            "counters": self._metrics.snapshot(),
            "recorder": (self._rec.snapshot()
                         if self._rec is not None else None),
            "cust_on": self._cust_on,
            "heal": _snap_encode(self._heal_delta, arrays),
            "faults": _snap_encode(
                self._faults.snapshot() if self._faults is not None
                else None, arrays),
            "health": _snap_encode(
                self._health.snapshot() if self._health is not None
                else None, arrays),
            "cust": self._snap_sessions(arrays),
        }
        if path is None:
            return {"spec": spec, "arrays": arrays}
        payload = dict(arrays)
        payload["meta"] = np.frombuffer(
            json.dumps(spec).encode("utf-8"), dtype=np.uint8)
        parent = os.path.dirname(os.path.abspath(path)) or "."
        os.makedirs(parent, exist_ok=True)
        fd, tmp = tempfile.mkstemp(prefix=".tmp.snapshot.", suffix=".npz",
                                   dir=parent)
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(f, **payload)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)                  # atomic commit
        except Exception:
            try:
                os.remove(tmp)
            except OSError:
                pass
            raise
        return path

    _SESS_SKIP = ("_mgr", "_grads_fn")   # back-ref / jit closure: rebuilt

    def _snap_sessions(self, arrays):
        if self._cust is None:
            return None
        sessions = []
        for sess in self._cust.sessions:
            d = {k: v for k, v in vars(sess).items()
                 if k not in self._SESS_SKIP}
            sessions.append(_snap_encode(d, arrays))
        return {"next_sid": self._cust._next_sid, "sessions": sessions}

    def restore(self, snap) -> None:
        """Restore a snapshot (a path or an in-memory snapshot dict) into
        THIS server, which must be freshly constructed with the same
        configuration — model/hw, hop, slot bounds, noise std and chip
        offsets, decision/VAD/admission configs and the same ``faults=``
        / ``health=`` / ``profiles=`` wiring.  (The snapshot stores
        serving *state*; the configuration is code.)  After restore the
        server continues bit-identically to the uninterrupted original,
        including SA-noise fields (per-stream keys are restored verbatim)
        and in-flight enrollment sessions."""
        if isinstance(snap, (str, os.PathLike)):
            with np.load(snap, allow_pickle=False) as data:
                spec = json.loads(bytes(data["meta"]).decode("utf-8"))
                arrays = {k: data[k] for k in data.files if k != "meta"}
        else:
            spec, arrays = snap["spec"], snap["arrays"]
        if spec.get("version") not in (1, 2):
            raise ValueError(f"unknown snapshot version: "
                             f"{spec.get('version')!r}")
        c = spec["config"]
        if (c["sample_len"] != self.cfg.sample_len
                or c["base_hop"] != self.base_hop
                or bool(c["streaming"]) != self.streaming
                or bool(c["vad"]) != (self.vcfg is not None)):
            raise ValueError(f"snapshot/server configuration mismatch: "
                             f"snapshot has {c}")
        n = int(spec["slots_n"])
        if not (self.min_slots <= n <= self.max_slots):
            raise ValueError(f"snapshot slot count {n} outside this "
                             f"server's [{self.min_slots}, "
                             f"{self.max_slots}]")
        self.slots = n
        self._mult = int(spec["mult"])
        self._bundle(self._mult)                  # engine for this hop
        self._uid = int(spec["uid"])
        self._base_key = jnp.asarray(_snap_decode(spec["base_key"],
                                                  arrays))

        def jaxify(tree):
            return jax.tree_util.tree_map(jnp.asarray, tree)

        self._state = jaxify(_snap_decode(spec["state"], arrays))
        self._dstate = jaxify(_snap_decode(spec["dstate"], arrays))
        v = _snap_decode(spec["vstate"], arrays)
        self._vstate = jaxify(v) if v is not None else None
        self._streams = {}
        for sid, s_spec in spec["streams"].items():
            self._streams[sid] = _Stream(**_snap_decode(s_spec, arrays))
        self._queue = collections.deque(self._streams[sid]
                                        for sid in spec["queue"])
        self._slots = [None if sid is None else self._streams[sid]
                       for sid in spec["slot_ids"]]
        counters = spec["counters"]
        if spec["version"] >= 2:
            self._metrics.restore(counters)
        else:                       # v1: per-attribute dict; the setattrs
            for k, val in counters.items():   # write through the registry
                setattr(self, k, val)         # properties
        if spec.get("recorder") is not None and self._rec is not None:
            self._rec.restore(spec["recorder"])
        # riders rebuild from scratch at the restored slot count; per-slot
        # rows re-materialize deterministically from each stream's
        # ``custom`` dict, the chip-global row from heal + fault state
        self._cust_on = False
        self._slot_delta = None
        self._slot_head_w = None
        self._slot_head_b = None
        self._slot_fills = None
        self._heal_delta = _snap_decode(spec["heal"], arrays)
        f = _snap_decode(spec["faults"], arrays)
        if (f is None) != (self._faults is None):
            raise ValueError("snapshot fault-model mismatch: construct "
                             "the server with the same faults= wiring")
        if f is not None:
            self._faults.restore(f)
            self._faults.pop_dirty()
        h = _snap_decode(spec["health"], arrays)
        if (h is None) != (self._health is None):
            raise ValueError("snapshot health mismatch: construct the "
                             "server with the same health= wiring")
        if h is not None:
            self._health.restore(h)
        if spec["cust_on"]:
            self._enable_customization()
        self._refresh_chip_delta()
        cust = spec["cust"]
        if cust is None:
            self._cust = None
        else:
            from repro.serving import customize as cz
            self._cust = cz.CustomizationManager(self)
            self._cust._next_sid = int(cust["next_sid"])
            for s_spec in cust["sessions"]:
                d = _snap_decode(s_spec, arrays)
                sess = cz.CustomizationSession.__new__(
                    cz.CustomizationSession)
                sess._mgr = self._cust
                sess._grads_fn = None             # jit closure: re-traced
                for k, val in d.items():
                    setattr(sess, k, val)
                if sess._head is not None:
                    sess._head = jaxify(sess._head)
                self._cust.sessions.append(sess)

    # -- accounting ---------------------------------------------------------

    def active_streams(self) -> List[str]:
        return [r.stream_id for r in self._slots if r is not None]

    def stats(self) -> dict:
        offline = kws.layer_stats(self.cfg)
        streaming = sv.streaming_layer_stats(self.cfg, self.geom)
        macs_off = sum(s["macs"] for s in offline)
        macs_str = sum(s["macs"] for s in streaming)
        per_stream = {
            rec.stream_id: {
                "hops": rec.hops,
                "gated_hops": rec.gated_hops,
                "triggers": len(rec.triggers),
                "sheds": rec.sheds,
                "wall_s": round(rec.wall_s, 4),
            }
            for rec in self._streams.values() if not rec.internal
        }
        total_hops = self._speech_hops + self._gated_hops
        duty = (self._speech_hops / total_hops) if total_hops else None
        out = {
            "mode": "streaming" if self.streaming else "recompute",
            "silence_fill": self.silence_fill,
            "slots": self.slots,
            "slot_range": [self.min_slots, self.max_slots],
            "queue_depth": len(self._queue),
            "rejected_streams": self._rejected,
            "shed": {"events": self._shed_events,
                     "samples": self._shed_samples},
            "steps": self._steps,
            "decisions": self._decisions,
            "base_hop": self.base_hop,
            "hop": self.hop,
            "hop_multiplier": self._mult,
            "hop_retargets": self._hop_retargets,
            "speech_hops": self._speech_hops,
            "gated_hops": self._gated_hops,
            "learn_hops": self._learn_hops,
            # each entry is one batched jax call; init/hop/replay calls
            # cost one fused-kernel launch per IMC layer (any number of
            # slots per call), gate calls launch nothing
            "batched_calls": {
                "init": self._init_calls,
                "hop": self._hop_calls,
                "replay": self._replay_calls,
                "gate": self._gate_calls,
            },
            "duty_cycle": round(duty, 4) if duty is not None else None,
            "hop_wall_s": round(self._hop_wall_s, 4),
            "decisions_per_sec": round(
                self._decisions / self._hop_wall_s, 2)
                if self._hop_wall_s > 0 else None,
            "macs_per_decision": {
                "offline": macs_off,
                "streaming": macs_str,
                "ratio": round(macs_str / macs_off, 4),
            },
            "per_stream": per_stream,
        }
        if self._cust is not None:
            out["customization"] = self._cust.stats()
        if self._compiled is not None:
            out["compiled"] = {"block": self._compiled.cfg.block,
                               "blocks": self._compiled_blocks,
                               "ticks": self._compiled_ticks}
        out["obs"] = {"metrics": len(self._metrics._cells)}
        if self._rec is not None:
            out["obs"]["recorder"] = {"events": len(self._rec),
                                      "capacity": self._rec.capacity,
                                      "dropped": self._rec.dropped()}
        if self._audit is not None:
            out["obs"]["audit"] = self._audit.stats()
        if self._profiles is not None:
            out["profile_swaps"] = self._profile_swaps
        if self._faults is not None:
            out["faults"] = self._faults.stats()
        if self._health is not None:
            out["health"] = self._health.stats()
        if self.vcfg is not None:
            out["gated_energy"] = {
                k: round(v, 4) if isinstance(v, float) else v
                for k, v in energy.gated_energy_summary(
                    offline, streaming, hop_samples=self.hop,
                    duty_cycle=duty if duty is not None else 1.0).items()
            }
        return out
